//! The pluggable inference-backend contract.
//!
//! Everything above the runtime (coordinator, CLI, examples, benches,
//! tests) drives model execution through [`InferenceBackend`] +
//! [`Executable`] trait objects, so the same scenario/QoS/serving code runs
//! against either implementation:
//!
//!   * `engine::Engine` (cargo feature `xla`, off by default): the real
//!     PJRT CPU client executing AOT-compiled HLO artifacts built by
//!     `python/compile/`;
//!   * [`crate::runtime::analytic::AnalyticBackend`] (always available):
//!     a hermetic, pure-Rust reference backend that synthesises its
//!     manifest, datasets and per-layer costs from `model::stats` +
//!     `util::rng` — no artifacts, no native libraries, fully
//!     deterministic for a given seed.
//!
//! [`load_backend`] picks the implementation: real artifacts when they
//! exist and the `xla` feature is enabled, the analytic backend otherwise.

use std::path::Path;
use std::rc::Rc;

use anyhow::Result;

use super::manifest::{ExecSpec, Manifest};
use crate::data::Dataset;
use crate::tensor::Tensor;

/// A runtime input value (model input or Grad-CAM label vector).
pub enum RtInput<'a> {
    F32(&'a Tensor),
    I32(&'a [i32]),
}

/// Per-executable call/latency accounting. For the PJRT engine these are
/// measured wall times; for the analytic backend they are deterministic
/// simulated costs derived from the model's mult-add counts.
#[derive(Clone, Copy, Debug, Default)]
pub struct ExecCounters {
    pub calls: u64,
    pub total_exec_ns: u64,
    pub compile_ns: u64,
}

/// One loaded model executable (full model, head, tail, Grad-CAM, ...).
pub trait Executable {
    fn spec(&self) -> &ExecSpec;

    /// Execute with the given inputs; returns the single output tensor.
    fn run(&self, inputs: &[RtInput<'_>]) -> Result<Tensor>;

    fn counters(&self) -> ExecCounters;

    /// Mean execution time per call, ns.
    fn mean_exec_ns(&self) -> f64 {
        let c = self.counters();
        if c.calls == 0 {
            0.0
        } else {
            c.total_exec_ns as f64 / c.calls as f64
        }
    }
}

/// A model-serving runtime: manifest metadata, datasets, fixtures and
/// named executables.
pub trait InferenceBackend {
    /// Short implementation name ("xla" | "analytic").
    fn name(&self) -> &'static str;

    /// Execution platform description (PJRT platform name or "analytic").
    fn platform(&self) -> String;

    fn manifest(&self) -> &Manifest;

    /// Get (loading and caching on first use) an executable by name.
    fn executable(&self, name: &str) -> Result<Rc<dyn Executable>>;

    /// Load a dataset split by manifest name ("train" | "test" | "ice").
    fn dataset(&self, split: &str) -> Result<Dataset>;

    /// Read a golden-output fixture tensor.
    fn fixture(&self, name: &str) -> Result<Tensor>;

    /// Names of currently cached (loaded) executables, sorted.
    fn cached(&self) -> Vec<String>;
}

/// Open the best available backend for `dir` serving the default
/// architecture (VGG16). Equivalent to
/// [`load_backend_for(dir, Arch::Vgg16)`](load_backend_for).
pub fn load_backend(dir: &Path) -> Result<Box<dyn InferenceBackend>> {
    load_backend_for(dir, crate::model::Arch::Vgg16)
}

/// Open the best available backend for `dir` serving `arch`:
///
/// * with the `xla` feature and a built `dir/manifest.json`, the real
///   PJRT engine over the AOT artifacts — VGG16 only (the python AOT
///   pipeline exports the slim VGG); other archs fall through to the
///   analytic backend, which synthesises their geometry;
/// * otherwise the hermetic analytic backend (ignores `dir`; synthesises
///   everything in memory for the requested arch).
pub fn load_backend_for(
    dir: &Path,
    arch: crate::model::Arch,
) -> Result<Box<dyn InferenceBackend>> {
    #[cfg(feature = "xla")]
    {
        if arch == crate::model::Arch::Vgg16
            && dir.join("manifest.json").exists()
        {
            return Ok(Box::new(super::engine::Engine::load(dir)?));
        }
    }
    #[cfg(not(feature = "xla"))]
    let _ = dir;
    Ok(Box::new(super::analytic::AnalyticBackend::new(
        super::analytic::AnalyticConfig { seed: 0, arch },
    )))
}
