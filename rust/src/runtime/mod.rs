//! PJRT runtime: the AOT bridge between the python build path and the Rust
//! serving path. `HLO text -> HloModuleProto -> XlaComputation -> compile ->
//! execute` on the CPU PJRT client (see /opt/xla-example/README.md for why
//! text, not serialized protos, is the interchange format).

pub mod engine;
pub mod manifest;

pub use engine::{Engine, LoadedExec, RtInput};
pub use manifest::{ExecSpec, Manifest};
