//! Model-serving runtime with pluggable inference backends.
//!
//! The [`InferenceBackend`] / [`Executable`] traits ([`backend`]) are the
//! contract every layer above the runtime programs against. Two
//! implementations exist:
//!
//!   * [`analytic`] — the default, hermetic pure-Rust reference backend:
//!     synthesises manifest, datasets and deterministic inference from
//!     `model::stats` + `util::rng`; builds and runs everywhere (CI,
//!     laptops, embedded targets) with no artifacts or native libraries;
//!   * `engine` (cargo feature `xla`) — the PJRT/XLA AOT bridge from the
//!     python build path: `HLO text -> HloModuleProto -> XlaComputation ->
//!     compile -> execute` on the CPU PJRT client (see
//!     /opt/xla-example/README.md for why text, not serialized protos, is
//!     the interchange format). Requires built `artifacts/` and the
//!     vendored `xla` crate.
//!
//! [`load_backend`] selects the implementation for a given artifacts
//! directory; [`manifest`] is the shared typed artifact contract.

pub mod analytic;
pub mod backend;
#[cfg(feature = "xla")]
pub mod engine;
pub mod manifest;

pub use analytic::{AnalyticBackend, AnalyticConfig};
pub use backend::{
    load_backend, load_backend_for, ExecCounters, Executable,
    InferenceBackend, RtInput,
};
#[cfg(feature = "xla")]
pub use engine::{Engine, LoadedExec};
pub use manifest::{ExecSpec, Manifest};
