//! PJRT execution engine (cargo feature `xla`): loads HLO-text artifacts,
//! compiles them on the CPU PJRT client, pre-builds weight literals, and
//! runs them from the L3 hot path. Python never executes here. This is the
//! real-artifact implementation of [`crate::runtime::InferenceBackend`];
//! the default build uses [`crate::runtime::analytic`] instead.
//!
//! Performance notes (see EXPERIMENTS.md §Perf):
//!   * executables are compiled once and cached by name;
//!   * weight literals are built once per executable at load time, and the
//!     per-call argument vector borrows them (`execute` takes
//!     `Borrow<Literal>`), so a hot-path inference allocates only the input
//!     literal;
//!   * wall-clock execution time is tracked per executable for profiling.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use super::backend::{ExecCounters, Executable, InferenceBackend, RtInput};
use super::manifest::{ExecSpec, Manifest};
use crate::tensor::Tensor;

/// One compiled artifact with its pre-built weight literals.
pub struct LoadedExec {
    pub spec: ExecSpec,
    exe: xla::PjRtLoadedExecutable,
    weights: Vec<xla::Literal>,
    counters: RefCell<ExecCounters>,
}

fn f32_literal(shape: &[usize], data: &[f32]) -> Result<xla::Literal> {
    let n: usize = shape.iter().product();
    if n != data.len() {
        bail!("literal shape {shape:?} wants {n} values, got {}", data.len());
    }
    let bytes: &[u8] = unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
    };
    xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::F32,
        shape,
        bytes,
    )
    .map_err(|e| anyhow!("building f32 literal: {e:?}"))
}

fn i32_literal(shape: &[usize], data: &[i32]) -> Result<xla::Literal> {
    let n: usize = shape.iter().product();
    if n != data.len() {
        bail!("literal shape {shape:?} wants {n} values, got {}", data.len());
    }
    let bytes: &[u8] = unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
    };
    xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::S32,
        shape,
        bytes,
    )
    .map_err(|e| anyhow!("building i32 literal: {e:?}"))
}

impl LoadedExec {
    /// Execute with the given inputs (weights appended automatically).
    /// Returns the single output tensor (all our artifacts are lowered with
    /// `return_tuple=True` and one result).
    pub fn run(&self, inputs: &[RtInput]) -> Result<Tensor> {
        if inputs.len() != self.spec.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                self.spec.name,
                self.spec.inputs.len(),
                inputs.len()
            );
        }
        let mut args: Vec<xla::Literal> =
            Vec::with_capacity(inputs.len());
        for (spec, input) in self.spec.inputs.iter().zip(inputs) {
            let lit = match (input, spec.dtype.as_str()) {
                (RtInput::F32(t), "float32") => {
                    if t.shape() != spec.shape.as_slice() {
                        bail!(
                            "{}: input '{}' shape {:?} != expected {:?}",
                            self.spec.name, spec.name, t.shape(), spec.shape
                        );
                    }
                    f32_literal(t.shape(), t.data())?
                }
                (RtInput::I32(v), "int32") => i32_literal(&spec.shape, v)?,
                (_, dt) => bail!(
                    "{}: input '{}' dtype mismatch (artifact wants {dt})",
                    self.spec.name, spec.name
                ),
            };
            args.push(lit);
        }
        // Borrowed arg vector: inputs by value, weights by reference.
        let mut borrowed: Vec<&xla::Literal> =
            Vec::with_capacity(args.len() + self.weights.len());
        borrowed.extend(args.iter());
        borrowed.extend(self.weights.iter());

        let t0 = Instant::now();
        let result = self
            .exe
            .execute::<&xla::Literal>(&borrowed)
            .map_err(|e| anyhow!("{}: execute: {e:?}", self.spec.name))?;
        let out = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("{}: readback: {e:?}", self.spec.name))?;
        let elapsed = t0.elapsed().as_nanos() as u64;
        {
            let mut c = self.counters.borrow_mut();
            c.calls += 1;
            c.total_exec_ns += elapsed;
        }
        let out = out
            .to_tuple1()
            .map_err(|e| anyhow!("{}: untuple: {e:?}", self.spec.name))?;
        let values = out
            .to_vec::<f32>()
            .map_err(|e| anyhow!("{}: to_vec: {e:?}", self.spec.name))?;
        let shape = self.spec.outputs[0].shape.clone();
        Tensor::new(shape, values)
    }

    pub fn counters(&self) -> ExecCounters {
        *self.counters.borrow()
    }

    /// Mean wall time per call, ns.
    pub fn mean_exec_ns(&self) -> f64 {
        let c = self.counters.borrow();
        if c.calls == 0 {
            0.0
        } else {
            c.total_exec_ns as f64 / c.calls as f64
        }
    }
}

/// Artifact registry + PJRT client + executable cache.
pub struct Engine {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    dir: PathBuf,
    cache: RefCell<HashMap<String, Rc<LoadedExec>>>,
    /// Weight files are shared between executables of the same weight set;
    /// cache the raw vectors to avoid re-reading.
    weight_files: RefCell<HashMap<String, Rc<Vec<f32>>>>,
}

impl Engine {
    /// Load the manifest and start the PJRT CPU client.
    pub fn load(artifacts_dir: &Path) -> Result<Engine> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("starting PJRT CPU client: {e:?}"))?;
        Ok(Engine {
            client,
            manifest,
            dir: artifacts_dir.to_path_buf(),
            cache: RefCell::new(HashMap::new()),
            weight_files: RefCell::new(HashMap::new()),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn weight_data(&self, file: &str) -> Result<Rc<Vec<f32>>> {
        if let Some(w) = self.weight_files.borrow().get(file) {
            return Ok(w.clone());
        }
        let data = crate::data::read_f32_file(&self.dir.join(file))?;
        let rc = Rc::new(data);
        self.weight_files
            .borrow_mut()
            .insert(file.to_string(), rc.clone());
        Ok(rc)
    }

    /// Get (compiling and caching on first use) an executable by name.
    pub fn executable(&self, name: &str) -> Result<Rc<LoadedExec>> {
        if let Some(e) = self.cache.borrow().get(name) {
            return Ok(e.clone());
        }
        let spec = self.manifest.executable(name)?.clone();
        let hlo_path = self.dir.join(&spec.hlo);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&hlo_path)
            .map_err(|e| {
                anyhow!("parsing {}: {e:?}", hlo_path.display())
            })?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
        let mut weights = Vec::with_capacity(spec.weights.len());
        for w in &spec.weights {
            let data = self.weight_data(&w.file)?;
            weights.push(
                f32_literal(&w.shape, &data)
                    .with_context(|| format!("weight {}", w.name))?,
            );
        }
        let loaded = Rc::new(LoadedExec {
            spec,
            exe,
            weights,
            counters: RefCell::new(ExecCounters {
                compile_ns: t0.elapsed().as_nanos() as u64,
                ..Default::default()
            }),
        });
        self.cache
            .borrow_mut()
            .insert(name.to_string(), loaded.clone());
        Ok(loaded)
    }

    /// Load a dataset split by manifest name ("train" | "test" | "ice").
    pub fn dataset(&self, split: &str) -> Result<crate::data::Dataset> {
        let spec = self
            .manifest
            .datasets
            .get(split)
            .ok_or_else(|| anyhow!("no dataset split '{split}'"))?;
        crate::data::Dataset::load(
            &self.dir,
            split,
            &spec.images,
            &spec.labels,
            spec.count,
            &spec.image_shape,
        )
    }

    /// Read a fixture tensor (golden outputs from python).
    pub fn fixture(&self, name: &str) -> Result<Tensor> {
        let (file, shape) = self
            .manifest
            .fixtures
            .get(name)
            .ok_or_else(|| anyhow!("no fixture '{name}'"))?
            .clone();
        let data = crate::data::read_f32_file(&self.dir.join(file))?;
        Tensor::new(shape, data)
    }

    /// Names of currently cached (compiled) executables.
    pub fn cached(&self) -> Vec<String> {
        let mut v: Vec<String> =
            self.cache.borrow().keys().cloned().collect();
        v.sort();
        v
    }
}

impl Executable for LoadedExec {
    fn spec(&self) -> &ExecSpec {
        &self.spec
    }

    fn run(&self, inputs: &[RtInput<'_>]) -> Result<Tensor> {
        LoadedExec::run(self, inputs)
    }

    fn counters(&self) -> ExecCounters {
        LoadedExec::counters(self)
    }

    fn mean_exec_ns(&self) -> f64 {
        LoadedExec::mean_exec_ns(self)
    }
}

impl InferenceBackend for Engine {
    fn name(&self) -> &'static str {
        "xla"
    }

    fn platform(&self) -> String {
        Engine::platform(self)
    }

    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn executable(&self, name: &str) -> Result<Rc<dyn Executable>> {
        let e: Rc<dyn Executable> = Engine::executable(self, name)?;
        Ok(e)
    }

    fn dataset(&self, split: &str) -> Result<crate::data::Dataset> {
        Engine::dataset(self, split)
    }

    fn fixture(&self, name: &str) -> Result<Tensor> {
        Engine::fixture(self, name)
    }

    fn cached(&self) -> Vec<String> {
        Engine::cached(self)
    }
}
