//! Hermetic analytic reference backend: a pure-Rust [`InferenceBackend`]
//! that needs no artifacts, no Python and no native libraries.
//!
//! It synthesises everything the framework consumes — manifest metadata,
//! labelled datasets, per-layer volumetrics and deterministic "inference"
//! — from `model` statistics and the seedable `util::rng` stream, so the
//! whole design loop (saliency candidates -> scenario simulation -> QoS
//! suggestion -> serving) runs end-to-end on any machine, CI runner or
//! embedded target, with bit-identical results for a given seed.
//!
//! The synthetic model is a prototype-correlation classifier over the
//! configured architecture's slim geometry (VGG16 by default; ResNet-18
//! and MobileNetV2 via [`AnalyticConfig::arch`] — cut names, latent
//! shapes, exported splits, CS curve and the accuracy model all follow
//! the arch, while prototypes and datasets stay shared so cross-arch
//! sweeps classify the same frames):
//!
//!   * each class `c` has a fixed ±1 prototype `p_c` of input length;
//!   * an image of class `c` is `1.0 + 0.25 p_c + 0.05 eta` (eta a ±1
//!     per-pixel noise stream), so clean inputs classify by correlation
//!     with an enormous margin; a small seeded fraction of images is
//!     generated from the *wrong* prototype, which fixes the backend's
//!     accuracy at the manifest's recorded values;
//!   * `head_L*` projects the centered input through a seeded ±1 block
//!     code into the split's latent shape (a linear bottleneck); `tail_L*`
//!     correlates the latent against the projected prototypes, so
//!     head->tail composes to the full model's predictions;
//!   * UDP loss corruption (zeroed byte ranges — input pixels are never
//!     0.0 by construction) is detected per row; the damage probability
//!     `1 - (1-q)^4` of a corrupted fraction `q` deterministically
//!     (via a content hash) collapses the row to a pseudo-random class,
//!     reproducing the paper's Fig. 4 accuracy-vs-loss behaviour;
//!   * per-executable latency counters are simulated from the model's
//!     mult-add counts instead of wall time, so perf accounting is
//!     deterministic too.

use std::cell::RefCell;
use std::collections::{BTreeMap, HashMap};
use std::path::PathBuf;
use std::rc::Rc;

use anyhow::{anyhow, bail, Result};

use super::backend::{ExecCounters, Executable, InferenceBackend, RtInput};
use super::manifest::{
    ArgSpec, CsCurveSpec, DatasetSpec, ExecSpec, Manifest, ModelInfo,
    SplitEvalRow,
};
use crate::data::Dataset;
use crate::model::{self, Arch, Cut, Shape};
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// Base seed of every synthetic stream (prototypes, datasets, codes).
const BASE_SEED: u64 = 0x5E1A_B001;
/// Simulated throughput behind the analytic latency counters, MACs/s.
const ANALYTIC_MACS_PER_SEC: f64 = 1e11;
/// Fraction of images generated from a wrong prototype per split.
const GEN_ERR_TEST: f64 = 0.03;
const GEN_ERR_ICE: f64 = 0.04;
/// Extra deterministic misclassification rate of the lite model.
const LITE_FLIP_RATE: f64 = 0.10;

/// Exported VGG split points (the paper's Fig. 2 candidates) and the
/// split accuracies the synthetic manifest records for them.
const SPLITS: [usize; 5] = [5, 9, 11, 13, 15];
const SPLIT_ACC: [f64; 5] = [0.952, 0.958, 0.961, 0.965, 0.968];

/// Synthetic raw VGG CS curve: local maxima exactly at the exported splits
/// (plus layer 1, below the default `min_layer`).
const CS_RAW: [f64; 18] = [
    0.05, 0.10, 0.08, 0.12, 0.20, 0.35, 0.18, 0.22, 0.30, 0.46, 0.38, 0.55,
    0.44, 0.66, 0.58, 0.83, 0.70, 0.92,
];

/// The seeded accuracy model, keyed off the architecture the manifest
/// advertises: `(full-model flip rate, base test accuracy, ICE accuracy)`.
/// VGG16 keeps a zero flip rate (the original backend behaviour, and the
/// exact head->tail composition its tests pin); the other architectures
/// flip a deterministic content-hashed fraction of predictions so their
/// measured accuracy lands on the recorded values — which makes the
/// accuracy-vs-latency trade across architectures non-degenerate.
fn arch_accuracy(arch: Arch) -> (f64, f64, f64) {
    match arch {
        Arch::Vgg16 => (0.0, 0.97, 0.96),
        Arch::ResNet18 => (0.012, 0.958, 0.948),
        Arch::MobileNetV2 => (0.03, 0.941, 0.931),
    }
}

/// Exported split-point ids per architecture (cut indices into
/// [`model::split_points`] of the slim network). Every arch exports cut
/// id 5, so cross-arch sweep specs can share `sc@5`.
fn arch_splits(arch: Arch) -> Vec<usize> {
    match arch {
        Arch::Vgg16 => SPLITS.to_vec(),
        Arch::ResNet18 => vec![3, 5, 7],
        Arch::MobileNetV2 => vec![5, 9, 12, 15],
    }
}

/// Synthetic raw CS curve for `n` cut points with local maxima exactly at
/// `splits`: a rising base trend, damped at non-split positions. VGG keeps
/// its original hand-shaped table.
fn arch_cs_raw(arch: Arch, n: usize, splits: &[usize]) -> Vec<f64> {
    if arch == Arch::Vgg16 {
        return CS_RAW.to_vec();
    }
    (0..n)
        .map(|i| {
            let base = (i + 1) as f64 / n as f64;
            if splits.contains(&i) {
                base
            } else {
                base * 0.7
            }
        })
        .collect()
}

/// Recorded split accuracies: monotone in depth, just under the arch's
/// base accuracy (the fine-tuned split models of the paper's Fig. 2).
fn arch_split_acc(arch: Arch, splits: &[usize]) -> Vec<f64> {
    if arch == Arch::Vgg16 {
        return SPLIT_ACC.to_vec();
    }
    let (_, base, _) = arch_accuracy(arch);
    let n = splits.len();
    (0..n).map(|k| base - 0.002 * (n - k) as f64).collect()
}

/// The 50%-bottleneck latent shape of a crossing tensor (channel
/// dimension halved) — the one formula behind the manifest's exported
/// latent shapes and the on-demand chain executables (mirrors
/// [`crate::model::Cut::latent_bytes`]).
fn bottleneck_latent([c, h, w]: [usize; 3]) -> [usize; 3] {
    [(c / 2).max(1), h, w]
}

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

fn fnv1a(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h = (h ^ b as u64).wrapping_mul(FNV_PRIME);
    }
    h
}

fn hash_f32s(mut h: u64, vals: &[f32]) -> u64 {
    for v in vals {
        h = fnv1a(h, &v.to_le_bytes());
    }
    h
}

/// Map a hash to a uniform fraction in [0, 1).
fn hash_frac(h: u64) -> f64 {
    (h >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
}

/// Split a chain-executable name into (kind, cut ids, batch):
/// `mid_L4_L11_b1` → ("mid", [4, 11], 1) and `tail_chain_L4_L11_b16` →
/// ("chain-tail", [4, 11], 16). Returns `None` for any other name.
fn parse_chain_exec(name: &str) -> Option<(&'static str, Vec<usize>, usize)> {
    let (kind, rest) = if let Some(r) = name.strip_prefix("mid_") {
        ("mid", r)
    } else if let Some(r) = name.strip_prefix("tail_chain_") {
        ("chain-tail", r)
    } else if let Some(r) = name.strip_prefix("head_") {
        ("head", r)
    } else if let Some(r) = name.strip_prefix("tail_") {
        ("tail", r)
    } else {
        return None;
    };
    let mut cuts = Vec::new();
    let mut batch = None;
    for tok in rest.split('_') {
        if batch.is_some() {
            return None; // tokens after the batch suffix
        }
        if let Some(l) = tok.strip_prefix('L') {
            cuts.push(l.parse().ok()?);
        } else if let Some(b) = tok.strip_prefix('b') {
            batch = Some(b.parse().ok()?);
        } else {
            return None;
        }
    }
    Some((kind, cuts, batch?))
}

fn sign_stream(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| if rng.chance(0.5) { 1.0 } else { -1.0 }).collect()
}

fn one_hot(class: usize, num_classes: usize) -> Vec<f32> {
    let mut v = vec![0.0f32; num_classes];
    v[class] = 1.0;
    v
}

fn argmax(scores: &[f64]) -> usize {
    let mut best = 0;
    for (i, s) in scores.iter().enumerate() {
        if *s > scores[best] {
            best = i;
        }
    }
    best
}

/// Detect zeroed (corruption) bytes in a row and decide — via a content
/// hash, deterministically — whether the damage flips the row to a
/// pseudo-random class. Returns the row hash for downstream draws.
fn damage_check(
    row: &[f32],
    family_hash: u64,
    num_classes: usize,
) -> (u64, Option<usize>) {
    let h = hash_f32s(family_hash, row);
    let zeros = row.iter().filter(|v| **v == 0.0).count();
    if zeros > 0 {
        let q = zeros as f64 / row.len() as f64;
        let p = 1.0 - (1.0 - q).powi(4);
        if hash_frac(h) < p {
            return (h, Some((h % num_classes as u64) as usize));
        }
    }
    (h, None)
}

/// What an analytic executable computes per input row.
enum Body {
    /// Prototype-correlation classifier (full / lite / Pallas variants).
    Classifier { flip_rate: f64 },
    /// Bottleneck encoder into the split's latent shape.
    Head { signs: Rc<Vec<f32>> },
    /// Mid-chain re-encoder: folds the latent of one cut into the latent
    /// of a deeper cut through a seeded ±1 block code — the composition
    /// `mid ∘ head` is itself a signed fold, so chain tails classify with
    /// the same algebra (and accuracy) as single-split tails. A latent the
    /// damage model judges destroyed is forwarded as all-zeros, which the
    /// next stage's damage check flips with probability 1 (corruption
    /// cascades down the chain instead of being silently washed out).
    Mid { signs: Rc<Vec<f32>> },
    /// Latent-space classifier over the projected prototypes (the flip
    /// rate mirrors the arch's full-model accuracy). Chain tails use the
    /// prototypes projected through the whole `head ∘ mid…` composition.
    Tail { w_protos: Vec<Vec<f64>>, flip_rate: f64 },
    /// Per-image cumulative-saliency value of one feature layer.
    GradCam { cs_raw: f64 },
}

struct AnalyticExec {
    spec: ExecSpec,
    body: Body,
    protos: Rc<Vec<Vec<f32>>>,
    /// Input-image element count (score normalisation constant).
    n_input: usize,
    num_classes: usize,
    /// Hash domain shared across batch sizes of the same model family, so
    /// `*_b1` and `*_b16` decide flips/damage identically per image.
    family_hash: u64,
    /// Simulated cost of one call, ns (mult-adds / analytic throughput).
    sim_exec_ns: u64,
    counters: RefCell<ExecCounters>,
}

impl AnalyticExec {
    fn correlate(&self, row: &[f32]) -> Vec<f64> {
        let mut scores = Vec::with_capacity(self.num_classes);
        for proto in self.protos.iter() {
            let mut acc = 0.0f64;
            for (&p, &x) in proto.iter().zip(row) {
                acc += p as f64 * (x as f64 - 1.0);
            }
            scores.push(acc / self.n_input as f64);
        }
        scores
    }

    fn classifier_row(&self, row: &[f32], flip_rate: f64) -> Vec<f32> {
        let nc = self.num_classes;
        let (h, damaged) = damage_check(row, self.family_hash, nc);
        if let Some(c) = damaged {
            return one_hot(c, nc);
        }
        let scores = self.correlate(row);
        if flip_rate > 0.0 {
            let h2 = h.wrapping_mul(0x9E37_79B9_7F4A_7C15);
            if hash_frac(h2) < flip_rate {
                let top = argmax(&scores);
                let wrong = (top + 1 + (h % (nc as u64 - 1)) as usize) % nc;
                return one_hot(wrong, nc);
            }
        }
        scores.iter().map(|s| *s as f32).collect()
    }

    fn mid_row(&self, row: &[f32], signs: &[f32], latent_len: usize)
        -> Vec<f32>
    {
        let nc = self.num_classes;
        let (_, damaged) = damage_check(row, self.family_hash, nc);
        if damaged.is_some() {
            // Poison the forwarded latent: all-zero rows trip the next
            // stage's damage check with probability 1.
            return vec![0.0; latent_len];
        }
        let mut sums = vec![0.0f64; latent_len];
        for (j, (&s, &x)) in signs.iter().zip(row).enumerate() {
            // Latents are affine-encoded (1 + 0.5·v): center by the same
            // convention the tail uses, so mid ∘ head composes linearly.
            sums[j % latent_len] += s as f64 * ((x as f64 - 1.0) / 0.5);
        }
        sums.iter()
            .map(|v| {
                let lat = (1.0 + 0.5 * v) as f32;
                if lat == 0.0 {
                    1e-30
                } else {
                    lat
                }
            })
            .collect()
    }

    fn head_row(&self, row: &[f32], signs: &[f32], latent_len: usize)
        -> Vec<f32>
    {
        let mut sums = vec![0.0f64; latent_len];
        for (j, (&s, &x)) in signs.iter().zip(row).enumerate() {
            sums[j % latent_len] += s as f64 * (x as f64 - 1.0);
        }
        sums.iter()
            .map(|v| {
                let lat = (1.0 + 0.5 * v) as f32;
                // The encoder never emits exact 0.0 — zeros mark corruption.
                if lat == 0.0 {
                    1e-30
                } else {
                    lat
                }
            })
            .collect()
    }

    fn tail_row(&self, row: &[f32], w_protos: &[Vec<f64>], flip_rate: f64)
        -> Vec<f32>
    {
        let nc = self.num_classes;
        let (h, damaged) = damage_check(row, self.family_hash, nc);
        if let Some(c) = damaged {
            return one_hot(c, nc);
        }
        let mut scores = Vec::with_capacity(nc);
        for w in w_protos {
            let mut acc = 0.0f64;
            for (&wj, &x) in w.iter().zip(row) {
                acc += wj * ((x as f64 - 1.0) / 0.5);
            }
            scores.push(acc / self.n_input as f64);
        }
        if flip_rate > 0.0 {
            let h2 = h.wrapping_mul(0x9E37_79B9_7F4A_7C15);
            if hash_frac(h2) < flip_rate {
                let top = argmax(&scores);
                let wrong = (top + 1 + (h % (nc as u64 - 1)) as usize) % nc;
                return one_hot(wrong, nc);
            }
        }
        scores.iter().map(|s| *s as f32).collect()
    }

    fn gradcam_row(&self, row: &[f32], cs_raw: f64) -> f32 {
        let h = hash_f32s(self.family_hash, row);
        (cs_raw * (1.0 + 0.1 * (hash_frac(h) - 0.5))) as f32
    }
}

impl Executable for AnalyticExec {
    fn spec(&self) -> &ExecSpec {
        &self.spec
    }

    fn run(&self, inputs: &[RtInput<'_>]) -> Result<Tensor> {
        if inputs.len() != self.spec.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                self.spec.name,
                self.spec.inputs.len(),
                inputs.len()
            );
        }
        for (arg, input) in self.spec.inputs.iter().zip(inputs) {
            match (input, arg.dtype.as_str()) {
                (RtInput::F32(t), "float32") => {
                    if t.shape() != arg.shape.as_slice() {
                        bail!(
                            "{}: input '{}' shape {:?} != expected {:?}",
                            self.spec.name,
                            arg.name,
                            t.shape(),
                            arg.shape
                        );
                    }
                }
                (RtInput::I32(v), "int32") => {
                    let want: usize = arg.shape.iter().product();
                    if v.len() != want {
                        bail!(
                            "{}: input '{}' wants {want} i32 values, got {}",
                            self.spec.name,
                            arg.name,
                            v.len()
                        );
                    }
                }
                (_, dt) => bail!(
                    "{}: input '{}' dtype mismatch (artifact wants {dt})",
                    self.spec.name,
                    arg.name
                ),
            }
        }
        let RtInput::F32(x) = &inputs[0] else {
            bail!("{}: first input must be float32", self.spec.name);
        };
        let batch = self.spec.batch;
        let row_len = x.len() / batch.max(1);
        let out_shape = self.spec.outputs[0].shape.clone();
        let out_elems: usize = out_shape.iter().product();
        let mut out = Vec::with_capacity(out_elems);
        for b in 0..batch {
            let row = &x.data()[b * row_len..(b + 1) * row_len];
            match &self.body {
                Body::Classifier { flip_rate } => {
                    out.extend(self.classifier_row(row, *flip_rate));
                }
                Body::Head { signs } => {
                    let latent_len = out_elems / batch;
                    out.extend(self.head_row(row, signs, latent_len));
                }
                Body::Mid { signs } => {
                    let latent_len = out_elems / batch;
                    out.extend(self.mid_row(row, signs, latent_len));
                }
                Body::Tail { w_protos, flip_rate } => {
                    out.extend(self.tail_row(row, w_protos, *flip_rate));
                }
                Body::GradCam { cs_raw } => {
                    out.push(self.gradcam_row(row, *cs_raw));
                }
            }
        }
        {
            let mut c = self.counters.borrow_mut();
            c.calls += 1;
            c.total_exec_ns += self.sim_exec_ns;
        }
        Tensor::new(out_shape, out)
    }

    fn counters(&self) -> ExecCounters {
        *self.counters.borrow()
    }
}

/// Configuration of the analytic backend.
#[derive(Clone, Copy, Debug, Default)]
pub struct AnalyticConfig {
    /// Extra seed folded into every synthetic stream; 0 is the canonical
    /// deterministic default used by tests and CI.
    pub seed: u64,
    /// Architecture the backend serves (manifest geometry, split points,
    /// executables, accuracy model). Defaults to VGG16 — the original
    /// backend, byte-identical to the pre-zoo behaviour.
    pub arch: Arch,
}

/// The hermetic analytic backend (see module docs).
pub struct AnalyticBackend {
    seed_mix: u64,
    /// Extra hash folded into per-arch streams (0 for VGG16, keeping the
    /// original backend bit-identical).
    arch_mix: u64,
    /// Full-model flip rate of the arch's seeded accuracy model.
    arch_flip: f64,
    manifest: Manifest,
    protos: Rc<Vec<Vec<f32>>>,
    n_input: usize,
    full_ma: u64,
    lite_ma: u64,
    /// The arch's slim split points: per-cut head/tail/bottleneck MACs
    /// behind the latency counters of every split executable, including
    /// the on-demand `mid_*` / `tail_chain_*` / unexported-cut ones.
    cuts: Vec<Cut>,
    cache: RefCell<HashMap<String, Rc<AnalyticExec>>>,
    datasets: RefCell<HashMap<String, Dataset>>,
}

/// The slim network geometry each arch's backend is built around.
fn slim_network_of(arch: Arch) -> model::Network {
    match arch {
        Arch::Vgg16 => model::vgg16_slim(32, 0.125, 64, 10),
        Arch::ResNet18 => model::resnet18_cifar(10),
        Arch::MobileNetV2 => model::mobilenetv2_cifar(0.5, 10),
    }
}

impl AnalyticBackend {
    pub fn new(cfg: AnalyticConfig) -> AnalyticBackend {
        let seed_mix = cfg.seed.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let arch = cfg.arch;
        let arch_mix = if arch == Arch::Vgg16 {
            0
        } else {
            fnv1a(FNV_OFFSET, arch.as_str().as_bytes())
        };
        let slim = slim_network_of(arch);
        let cuts = model::split_points(&slim);
        let manifest = synth_manifest(arch, &slim, &cuts);
        let m = &manifest.model;
        let n_input = 3 * m.img_size * m.img_size;
        // Prototypes and datasets are deliberately arch-independent: all
        // backends classify the same synthetic frames, so sweeps over the
        // arch axis share one dataset.
        let protos: Vec<Vec<f32>> = (0..m.num_classes)
            .map(|c| {
                let mut rng = Rng::new(
                    BASE_SEED
                        .wrapping_add(0x100 + c as u64)
                        .wrapping_add(seed_mix),
                );
                sign_stream(&mut rng, n_input)
            })
            .collect();
        let lite_ma =
            model::vgg16_slim(32, 0.0625, 48, m.num_classes).mult_adds();
        AnalyticBackend {
            seed_mix,
            arch_mix,
            arch_flip: arch_accuracy(arch).0,
            full_ma: slim.mult_adds(),
            lite_ma,
            cuts,
            manifest,
            protos: Rc::new(protos),
            n_input,
            cache: RefCell::new(HashMap::new()),
            datasets: RefCell::new(HashMap::new()),
        }
    }

    /// Latent shape of a split: the shared 50%-bottleneck formula, so the
    /// on-demand chain executables stay bit-consistent with the
    /// manifest's exported latent shapes.
    fn latent_shape_of(&self, s: usize) -> [usize; 3] {
        bottleneck_latent(self.manifest.model.feature_shapes[s])
    }

    fn latent_len_of(&self, s: usize) -> usize {
        let [c, h, w] = self.latent_shape_of(s);
        c * h * w
    }

    /// Seeded ±1 block code folding the latent of cut `from` into the
    /// latent of cut `to` (the mid-chain re-encoder's weights).
    fn mid_signs(&self, from: usize, to: usize) -> Vec<f32> {
        let mut rng = Rng::new(
            BASE_SEED
                .wrapping_add(0x31D0)
                .wrapping_add(from as u64 * 0x1_0007)
                .wrapping_add(to as u64 * 0x101)
                .wrapping_add(self.seed_mix)
                .wrapping_add(self.arch_mix),
        );
        sign_stream(&mut rng, self.latent_len_of(from))
    }

    /// Prototypes projected through `head(chain[0])` then every mid
    /// re-encoder along the chain — the weights of a chain tail. The
    /// composition of signed folds is a signed fold, so these classify
    /// with the same margin structure as single-split tail weights.
    fn chain_weights(&self, chain: &[usize]) -> Vec<Vec<f64>> {
        let signs = self.head_signs(chain[0]);
        let mut len = self.latent_len_of(chain[0]);
        let mut w_protos: Vec<Vec<f64>> = self
            .protos
            .iter()
            .map(|proto| {
                let mut w = vec![0.0f64; len];
                for (j, (&s, &p)) in signs.iter().zip(proto).enumerate() {
                    w[j % len] += s as f64 * p as f64;
                }
                w
            })
            .collect();
        for pair in chain.windows(2) {
            let ms = self.mid_signs(pair[0], pair[1]);
            let next_len = self.latent_len_of(pair[1]);
            w_protos = w_protos
                .iter()
                .map(|w| {
                    let mut out = vec![0.0f64; next_len];
                    for (j, (&s, &v)) in ms.iter().zip(w).enumerate() {
                        out[j % next_len] += s as f64 * v;
                    }
                    out
                })
                .collect();
            len = next_len;
        }
        debug_assert!(w_protos.iter().all(|w| w.len() == len));
        w_protos
    }

    /// Synthesize the spec of an on-demand segment executable —
    /// `mid_L{a}_L{b}_b{n}`, `tail_chain_L{a}_L{b}..._b{n}`, or a plain
    /// `head_L{s}_b{n}` / `tail_L{s}_b{n}` at a cut the manifest does not
    /// export. The analytic model needs no trained artifacts, so any
    /// structurally valid cut id (everything but the terminal split
    /// point) is admissible; exported splits keep their manifest specs
    /// (this path only runs on a manifest miss).
    fn synth_chain_spec(&self, name: &str) -> Option<ExecSpec> {
        let (kind, cuts, batch) = parse_chain_exec(name)?;
        if batch == 0 || !model::is_ordered_chain(&cuts) {
            return None;
        }
        if cuts.iter().any(|&c| c + 1 >= self.cuts.len()) {
            return None;
        }
        let nc = self.manifest.model.num_classes;
        let img = self.manifest.model.img_size;
        let latent_arg = |s: usize, label: &str| {
            let [c, h, w] = self.latent_shape_of(s);
            arg(label, vec![batch, c, h, w], "float32")
        };
        match kind {
            "head" if cuts.len() == 1 => Some(mk_exec(
                name.to_string(),
                "head",
                batch,
                Some(cuts[0]),
                None,
                Some(self.latent_shape_of(cuts[0])),
                vec![arg("x", vec![batch, 3, img, img], "float32")],
                vec![latent_arg(cuts[0], "latent")],
            )),
            "tail" if cuts.len() == 1 => Some(mk_exec(
                name.to_string(),
                "tail",
                batch,
                Some(cuts[0]),
                None,
                Some(self.latent_shape_of(cuts[0])),
                vec![latent_arg(cuts[0], "latent")],
                vec![arg("logits", vec![batch, nc], "float32")],
            )),
            "mid" if cuts.len() == 2 => Some(mk_exec(
                name.to_string(),
                "mid",
                batch,
                None,
                None,
                Some(self.latent_shape_of(cuts[1])),
                vec![latent_arg(cuts[0], "latent")],
                vec![latent_arg(cuts[1], "latent")],
            )),
            "chain-tail" if cuts.len() >= 2 => {
                let last = *cuts.last().unwrap();
                Some(mk_exec(
                    name.to_string(),
                    "chain-tail",
                    batch,
                    None,
                    None,
                    Some(self.latent_shape_of(last)),
                    vec![latent_arg(last, "latent")],
                    vec![arg("logits", vec![batch, nc], "float32")],
                ))
            }
            _ => None,
        }
    }

    fn head_signs(&self, split: usize) -> Vec<f32> {
        let mut rng = Rng::new(
            BASE_SEED
                .wrapping_add(0x5EAD + split as u64 * 0x101)
                .wrapping_add(self.seed_mix)
                .wrapping_add(self.arch_mix),
        );
        sign_stream(&mut rng, self.n_input)
    }

    /// Per-image mult-adds behind the simulated latency of one exec kind.
    fn cost_per_image(&self, spec: &ExecSpec) -> u64 {
        match spec.kind.as_str() {
            "lite" => self.lite_ma,
            "gradcam" => 3 * self.full_ma,
            "mid" => {
                // Segment MACs between the two cuts plus the incoming
                // decoder and outgoing encoder of the bottlenecks.
                match parse_chain_exec(&spec.name) {
                    Some((_, cuts, _)) if cuts.len() == 2 => {
                        let (a, b) = (&self.cuts[cuts[0]], &self.cuts[cuts[1]]);
                        b.head_mult_adds - a.head_mult_adds
                            + a.bottleneck_mult_adds().1
                            + b.bottleneck_mult_adds().0
                    }
                    _ => self.full_ma,
                }
            }
            "chain-tail" => match parse_chain_exec(&spec.name) {
                Some((_, cuts, _)) if !cuts.is_empty() => {
                    // Identical to the plain tail cost at the last cut.
                    let last = &self.cuts[*cuts.last().unwrap()];
                    last.tail_mult_adds + last.bottleneck_mult_adds().1
                }
                _ => self.full_ma,
            },
            "head" | "tail" => {
                let split = spec.split_layer.unwrap_or(SPLITS[0]);
                let (head, tail) = self
                    .cuts
                    .get(split)
                    .map(|c| c.split_compute())
                    .unwrap_or((self.full_ma, self.full_ma));
                if spec.kind == "head" {
                    head
                } else {
                    tail
                }
            }
            _ => self.full_ma,
        }
    }

    fn build_exec(&self, spec: ExecSpec) -> Result<AnalyticExec> {
        let nc = self.manifest.model.num_classes;
        let family_hash = if matches!(spec.kind.as_str(), "mid" | "chain-tail")
        {
            // Chain executables hash their full name: distinct chains get
            // distinct damage/flip streams (the pre-chain kinds keep the
            // original tag so every existing stream stays bit-identical).
            fnv1a(
                fnv1a(FNV_OFFSET, spec.kind.as_bytes()),
                spec.name.as_bytes(),
            )
            .wrapping_add(self.seed_mix)
            .wrapping_add(self.arch_mix)
        } else {
            let h = fnv1a(FNV_OFFSET, spec.kind.as_bytes());
            let tag = spec
                .split_layer
                .or(spec.gradcam_layer)
                .unwrap_or(usize::MAX) as u64;
            fnv1a(h, &tag.to_le_bytes())
                .wrapping_add(self.seed_mix)
                .wrapping_add(self.arch_mix)
        };
        let body = match spec.kind.as_str() {
            "mid" => {
                let (_, cuts, _) = parse_chain_exec(&spec.name)
                    .ok_or_else(|| {
                        anyhow!("{}: malformed mid exec name", spec.name)
                    })?;
                Body::Mid {
                    signs: Rc::new(self.mid_signs(cuts[0], cuts[1])),
                }
            }
            "chain-tail" => {
                let (_, cuts, _) = parse_chain_exec(&spec.name)
                    .ok_or_else(|| {
                        anyhow!("{}: malformed chain tail name", spec.name)
                    })?;
                Body::Tail {
                    w_protos: self.chain_weights(&cuts),
                    flip_rate: self.arch_flip,
                }
            }
            "full" => Body::Classifier { flip_rate: self.arch_flip },
            "lite" => Body::Classifier { flip_rate: LITE_FLIP_RATE },
            "head" => {
                let split = spec
                    .split_layer
                    .ok_or_else(|| anyhow!("{}: head without split", spec.name))?;
                Body::Head { signs: Rc::new(self.head_signs(split)) }
            }
            "tail" => {
                let split = spec
                    .split_layer
                    .ok_or_else(|| anyhow!("{}: tail without split", spec.name))?;
                let latent_len: usize = spec.inputs[0].shape[1..]
                    .iter()
                    .product();
                let signs = self.head_signs(split);
                let w_protos = self
                    .protos
                    .iter()
                    .map(|proto| {
                        let mut w = vec![0.0f64; latent_len];
                        for (j, (&s, &p)) in
                            signs.iter().zip(proto).enumerate()
                        {
                            w[j % latent_len] += s as f64 * p as f64;
                        }
                        w
                    })
                    .collect();
                Body::Tail { w_protos, flip_rate: self.arch_flip }
            }
            "gradcam" => {
                let layer = spec.gradcam_layer.ok_or_else(|| {
                    anyhow!("{}: gradcam without layer", spec.name)
                })?;
                Body::GradCam {
                    cs_raw: self.manifest.cs_curve.raw[layer],
                }
            }
            other => bail!("{}: unknown analytic kind '{other}'", spec.name),
        };
        let ma = self.cost_per_image(&spec);
        let sim_exec_ns = (spec.batch as f64 * ma as f64
            / ANALYTIC_MACS_PER_SEC
            * 1e9) as u64;
        Ok(AnalyticExec {
            body,
            protos: self.protos.clone(),
            n_input: self.n_input,
            num_classes: nc,
            family_hash,
            sim_exec_ns,
            counters: RefCell::new(ExecCounters::default()),
            spec,
        })
    }

    fn gen_dataset(&self, name: &str) -> Result<Dataset> {
        let spec = self
            .manifest
            .datasets
            .get(name)
            .ok_or_else(|| anyhow!("no dataset split '{name}'"))?;
        let err = if name == "ice" { GEN_ERR_ICE } else { GEN_ERR_TEST };
        let nc = self.manifest.model.num_classes;
        let n = self.n_input;
        let mut rng = Rng::new(
            (BASE_SEED ^ fnv1a(FNV_OFFSET, name.as_bytes()))
                .wrapping_add(self.seed_mix),
        );
        let mut data = Vec::with_capacity(spec.count * n);
        let mut labels = Vec::with_capacity(spec.count);
        for _ in 0..spec.count {
            let label = rng.below(nc as u64) as usize;
            let mut realized = label;
            if rng.chance(err) {
                realized =
                    (label + 1 + rng.below(nc as u64 - 1) as usize) % nc;
            }
            let proto = &self.protos[realized];
            for &p in proto.iter() {
                let e: f32 = if rng.chance(0.5) { 1.0 } else { -1.0 };
                data.push(1.0f32 + 0.25f32 * p + 0.05f32 * e);
            }
            labels.push(label as i32);
        }
        let mut shape = vec![spec.count];
        shape.extend_from_slice(&spec.image_shape);
        Ok(Dataset {
            name: name.to_string(),
            images: Tensor::new(shape, data)?,
            labels,
        })
    }
}

impl InferenceBackend for AnalyticBackend {
    fn name(&self) -> &'static str {
        "analytic"
    }

    fn platform(&self) -> String {
        "analytic (hermetic pure-Rust reference backend)".to_string()
    }

    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn executable(&self, name: &str) -> Result<Rc<dyn Executable>> {
        if let Some(e) = self.cache.borrow().get(name) {
            return Ok(e.clone());
        }
        // Chain executables (mid-segment re-encoders and composed chain
        // tails) are synthesized on demand: pre-declaring every ordered
        // cut chain in the manifest would be combinatorial.
        let spec = match self.manifest.executable(name) {
            Ok(s) => s.clone(),
            Err(e) => match self.synth_chain_spec(name) {
                Some(s) => s,
                None => return Err(e),
            },
        };
        let exec = Rc::new(self.build_exec(spec)?);
        self.cache
            .borrow_mut()
            .insert(name.to_string(), exec.clone());
        Ok(exec)
    }

    fn dataset(&self, split: &str) -> Result<Dataset> {
        if let Some(d) = self.datasets.borrow().get(split) {
            return Ok(d.clone());
        }
        let d = self.gen_dataset(split)?;
        self.datasets
            .borrow_mut()
            .insert(split.to_string(), d.clone());
        Ok(d)
    }

    fn fixture(&self, name: &str) -> Result<Tensor> {
        let (_, shape) = self
            .manifest
            .fixtures
            .get(name)
            .ok_or_else(|| anyhow!("no fixture '{name}'"))?
            .clone();
        match name {
            "test16_logits" => {
                let test = self.dataset("test")?;
                let exec = self.executable("full_fwd_b16")?;
                let out = exec.run(&[RtInput::F32(&test.batch(0, 16)?)])?;
                debug_assert_eq!(out.shape(), shape.as_slice());
                Ok(out)
            }
            other => bail!("analytic backend has no fixture '{other}'"),
        }
    }

    fn cached(&self) -> Vec<String> {
        let mut v: Vec<String> =
            self.cache.borrow().keys().cloned().collect();
        v.sort();
        v
    }
}

fn arg(name: &str, shape: Vec<usize>, dtype: &str) -> ArgSpec {
    ArgSpec {
        name: name.to_string(),
        shape,
        dtype: dtype.to_string(),
    }
}

#[allow(clippy::too_many_arguments)]
fn mk_exec(
    name: String,
    kind: &str,
    batch: usize,
    split_layer: Option<usize>,
    gradcam_layer: Option<usize>,
    latent_shape: Option<[usize; 3]>,
    inputs: Vec<ArgSpec>,
    outputs: Vec<ArgSpec>,
) -> ExecSpec {
    ExecSpec {
        hlo: format!("analytic://{name}"),
        name,
        kind: kind.to_string(),
        batch,
        split_layer,
        gradcam_layer,
        latent_shape,
        inputs,
        weights: Vec::new(),
        outputs,
    }
}

/// Build the synthetic manifest for one arch's slim model geometry: cut
/// names become the layer names, cut crossing shapes the feature shapes,
/// and the exported splits / CS curve / accuracies come from the seeded
/// per-arch model ([`arch_splits`], [`arch_cs_raw`], [`arch_accuracy`]).
fn synth_manifest(arch: Arch, slim: &model::Network, cuts: &[Cut])
    -> Manifest
{
    let num_classes = 10usize;
    let img = 32usize;
    let feature_shapes: Vec<[usize; 3]> = cuts
        .iter()
        .map(|c| {
            let Shape::Chw(ch, h, w) = c.out else {
                unreachable!("split-point crossings are CHW")
            };
            [ch, h, w]
        })
        .collect();
    let (_, base_acc, ice_acc) = arch_accuracy(arch);
    let splits = arch_splits(arch);
    let split_acc = arch_split_acc(arch, &splits);
    let cs_raw = arch_cs_raw(arch, cuts.len(), &splits);
    let (arch_name, width_mult, hidden) = match arch {
        Arch::Vgg16 => ("vgg16-slim-analytic", 0.125, 64),
        Arch::ResNet18 => ("resnet18-analytic", 1.0, 0),
        Arch::MobileNetV2 => ("mobilenetv2-analytic", 0.5, 0),
    };
    let model_info = ModelInfo {
        arch: arch_name.to_string(),
        width_mult,
        num_classes,
        img_size: img,
        hidden,
        layer_names: cuts.iter().map(|c| c.name.clone()).collect(),
        feature_shapes: feature_shapes.clone(),
        total_params: slim.total_params(),
        base_test_accuracy: base_acc,
        ice_accuracy: ice_acc,
    };

    let mut datasets = BTreeMap::new();
    for (name, count) in [("train", 64usize), ("test", 256), ("ice", 256)] {
        datasets.insert(
            name.to_string(),
            DatasetSpec {
                images: format!("analytic://{name}/images"),
                labels: format!("analytic://{name}/labels"),
                count,
                image_shape: vec![3, img, img],
            },
        );
    }

    let lo = cs_raw.iter().cloned().fold(f64::INFINITY, f64::min);
    let hi = cs_raw.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let cs_curve = CsCurveSpec {
        norm: cs_raw.iter().map(|v| (v - lo) / (hi - lo)).collect(),
        raw: cs_raw,
        candidates: splits.clone(),
    };

    let latent_of = |s: usize| -> [usize; 3] { bottleneck_latent(feature_shapes[s]) };
    let split_eval: Vec<SplitEvalRow> = splits
        .iter()
        .zip(split_acc.iter())
        .map(|(&s, &acc)| {
            let [c, h, w] = feature_shapes[s];
            let [zc, zh, zw] = latent_of(s);
            SplitEvalRow {
                layer: s,
                layer_name: model_info.layer_names[s].clone(),
                accuracy: acc,
                latent_shape: latent_of(s),
                latent_bytes_per_image: (zc * zh * zw * 4) as u64,
                feature_bytes_per_image: (c * h * w * 4) as u64,
            }
        })
        .collect();

    let img_shape = |b: usize| vec![b, 3, img, img];
    let logit_shape = |b: usize| vec![b, num_classes];
    let mut executables = BTreeMap::new();
    let mut add = |spec: ExecSpec| {
        executables.insert(spec.name.clone(), spec);
    };
    for b in [1usize, 4, 16] {
        add(mk_exec(
            format!("full_fwd_b{b}"),
            "full",
            b,
            None,
            None,
            None,
            vec![arg("x", img_shape(b), "float32")],
            vec![arg("logits", logit_shape(b), "float32")],
        ));
    }
    add(mk_exec(
        "full_fwd_pallas_b4".to_string(),
        "full",
        4,
        None,
        None,
        None,
        vec![arg("x", img_shape(4), "float32")],
        vec![arg("logits", logit_shape(4), "float32")],
    ));
    for b in [1usize, 16] {
        add(mk_exec(
            format!("full_fwd_lite_b{b}"),
            "lite",
            b,
            None,
            None,
            None,
            vec![arg("x", img_shape(b), "float32")],
            vec![arg("logits", logit_shape(b), "float32")],
        ));
    }
    for &s in &splits {
        let [zc, zh, zw] = latent_of(s);
        for b in [1usize, 16] {
            add(mk_exec(
                format!("head_L{s}_b{b}"),
                "head",
                b,
                Some(s),
                None,
                Some(latent_of(s)),
                vec![arg("x", img_shape(b), "float32")],
                vec![arg("latent", vec![b, zc, zh, zw], "float32")],
            ));
            add(mk_exec(
                format!("tail_L{s}_b{b}"),
                "tail",
                b,
                Some(s),
                None,
                Some(latent_of(s)),
                vec![arg("latent", vec![b, zc, zh, zw], "float32")],
                vec![arg("logits", logit_shape(b), "float32")],
            ));
        }
    }
    for l in 0..cuts.len() {
        add(mk_exec(
            format!("gradcam_L{l}_b16"),
            "gradcam",
            16,
            None,
            Some(l),
            None,
            vec![
                arg("x", img_shape(16), "float32"),
                arg("y", vec![16], "int32"),
            ],
            vec![arg("cs", vec![16], "float32")],
        ));
    }

    let mut fixtures = BTreeMap::new();
    fixtures.insert(
        "test16_logits".to_string(),
        (
            "analytic://fixtures/test16_logits".to_string(),
            vec![16, num_classes],
        ),
    );

    Manifest {
        dir: PathBuf::from("analytic://"),
        fast: false,
        model: model_info,
        lite_accuracy: Some(0.88),
        datasets,
        class_names: (0..num_classes).map(|c| format!("class_{c}")).collect(),
        cs_curve,
        split_eval,
        executables,
        fixtures,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn backend() -> AnalyticBackend {
        AnalyticBackend::new(AnalyticConfig::default())
    }

    fn accuracy(b: &AnalyticBackend, exec_name: &str, n: usize) -> f64 {
        let test = b.dataset("test").unwrap();
        let exec = b.executable(exec_name).unwrap();
        let batch = exec.spec().batch;
        let mut correct = 0usize;
        let mut start = 0;
        while start + batch <= n {
            let x = test.batch(start, batch).unwrap();
            let logits = exec.run(&[RtInput::F32(&x)]).unwrap();
            for (p, l) in logits
                .argmax_last()
                .iter()
                .zip(test.batch_labels(start, batch))
            {
                if *p == *l as usize {
                    correct += 1;
                }
            }
            start += batch;
        }
        correct as f64 / n as f64
    }

    #[test]
    fn manifest_is_well_formed() {
        let b = backend();
        let m = b.manifest();
        assert_eq!(m.model.num_classes, 10);
        assert_eq!(m.model.feature_shapes.len(), 18);
        assert_eq!(m.available_splits(), SPLITS.to_vec());
        assert_eq!(m.gradcam_layers().len(), 18);
        assert!(m.executables.contains_key("full_fwd_lite_b1"));
        assert!(m.fixtures.contains_key("test16_logits"));
    }

    #[test]
    fn cs_candidates_are_the_exported_splits() {
        let b = backend();
        let curve = crate::coordinator::CsCurve::from_manifest(b.manifest());
        assert_eq!(curve.candidates(2), SPLITS.to_vec());
    }

    #[test]
    fn datasets_are_deterministic_and_nonzero() {
        let (a, b) = (backend(), backend());
        let da = a.dataset("test").unwrap();
        let db = b.dataset("test").unwrap();
        assert_eq!(da.images.data(), db.images.data());
        assert_eq!(da.labels, db.labels);
        assert_eq!(da.len(), 256);
        assert!(da.images.data().iter().all(|v| *v != 0.0));
    }

    #[test]
    fn full_model_reaches_manifest_accuracy() {
        let b = backend();
        let acc = accuracy(&b, "full_fwd_b16", 256);
        assert!(
            (acc - b.manifest().model.base_test_accuracy).abs() < 0.05,
            "full accuracy {acc}"
        );
    }

    #[test]
    fn lite_model_is_worse_than_full() {
        let b = backend();
        let full = accuracy(&b, "full_fwd_b16", 128);
        let lite = accuracy(&b, "full_fwd_lite_b16", 128);
        assert!(lite < full, "lite {lite} vs full {full}");
        assert!(lite > 0.5, "lite {lite} must beat chance");
    }

    #[test]
    fn head_tail_compose_to_full_predictions() {
        let b = backend();
        let test = b.dataset("test").unwrap();
        let full = b.executable("full_fwd_b16").unwrap();
        let x = test.batch(0, 16).unwrap();
        let want = full.run(&[RtInput::F32(&x)]).unwrap().argmax_last();
        for &s in &SPLITS {
            let head = b.executable(&format!("head_L{s}_b16")).unwrap();
            let tail = b.executable(&format!("tail_L{s}_b16")).unwrap();
            let z = head.run(&[RtInput::F32(&x)]).unwrap();
            let got = tail.run(&[RtInput::F32(&z)]).unwrap().argmax_last();
            assert_eq!(got, want, "split L{s} diverges from full model");
        }
    }

    #[test]
    fn corruption_decays_accuracy() {
        let b = backend();
        let test = b.dataset("test").unwrap();
        let exec = b.executable("full_fwd_b1").unwrap();
        let n = 64usize;
        let mut clean_ok = 0;
        let mut corrupt_ok = 0;
        for i in 0..n {
            let x = test.batch(i, 1).unwrap();
            let mut bad = x.clone();
            bad.zero_byte_range(0, (bad.byte_len() / 2) as u32);
            let label = test.labels[i] as usize;
            if exec.run(&[RtInput::F32(&x)]).unwrap().argmax_last()[0]
                == label
            {
                clean_ok += 1;
            }
            if exec.run(&[RtInput::F32(&bad)]).unwrap().argmax_last()[0]
                == label
            {
                corrupt_ok += 1;
            }
        }
        assert!(
            corrupt_ok + 8 < clean_ok,
            "corruption barely matters: {corrupt_ok} vs {clean_ok}"
        );
    }

    #[test]
    fn executions_are_deterministic_and_cached() {
        let b = backend();
        let test = b.dataset("test").unwrap();
        let x = test.batch(0, 1).unwrap();
        let e1 = b.executable("full_fwd_b1").unwrap();
        let e2 = b.executable("full_fwd_b1").unwrap();
        assert!(Rc::ptr_eq(&e1, &e2));
        let a = e1.run(&[RtInput::F32(&x)]).unwrap();
        let bb = e1.run(&[RtInput::F32(&x)]).unwrap();
        assert_eq!(a.data(), bb.data());
        assert!(b.cached().contains(&"full_fwd_b1".to_string()));
        assert_eq!(e1.counters().calls, 2);
        assert!(e1.mean_exec_ns() > 0.0);
    }

    #[test]
    fn wrong_shapes_and_names_are_rejected() {
        let b = backend();
        let test = b.dataset("test").unwrap();
        let exec = b.executable("full_fwd_b16").unwrap();
        let x = test.batch(0, 1).unwrap();
        assert!(exec.run(&[RtInput::F32(&x)]).is_err());
        assert!(b.executable("nope").is_err());
        assert!(b.dataset("nope").is_err());
        assert!(b.fixture("nope").is_err());
    }

    #[test]
    fn fixture_matches_full_forward() {
        let b = backend();
        let test = b.dataset("test").unwrap();
        let exec = b.executable("full_fwd_b16").unwrap();
        let x = test.batch(0, 16).unwrap();
        let got = exec.run(&[RtInput::F32(&x)]).unwrap();
        let want = b.fixture("test16_logits").unwrap();
        assert_eq!(got.shape(), want.shape());
        assert_eq!(got.data(), want.data());
    }

    #[test]
    fn gradcam_values_track_the_cs_curve() {
        let b = backend();
        let test = b.dataset("test").unwrap();
        let x = test.batch(0, 16).unwrap();
        let y = test.batch_labels(0, 16);
        for l in [0usize, 9, 17] {
            let exec = b.executable(&format!("gradcam_L{l}_b16")).unwrap();
            let cs = exec
                .run(&[RtInput::F32(&x), RtInput::I32(y)])
                .unwrap();
            assert_eq!(cs.shape(), &[16]);
            let mean = cs.data().iter().map(|v| *v as f64).sum::<f64>()
                / 16.0;
            assert!(
                (mean - CS_RAW[l]).abs() < 0.1 * CS_RAW[l] + 0.02,
                "layer {l}: mean {mean} vs raw {}",
                CS_RAW[l]
            );
            assert!(cs.data().iter().all(|v| *v >= 0.0));
        }
    }

    #[test]
    fn seeds_change_the_streams() {
        let a = AnalyticBackend::new(AnalyticConfig {
            seed: 1,
            ..AnalyticConfig::default()
        });
        let b = backend();
        let da = a.dataset("test").unwrap();
        let db = b.dataset("test").unwrap();
        assert_ne!(da.images.data(), db.images.data());
    }

    fn arch_backend(arch: Arch) -> AnalyticBackend {
        AnalyticBackend::new(AnalyticConfig { seed: 0, arch })
    }

    #[test]
    fn arch_backends_are_well_formed() {
        for arch in Arch::ALL {
            let b = arch_backend(arch);
            let m = b.manifest();
            assert_eq!(Arch::infer(&m.model.arch), arch);
            assert_eq!(m.available_splits(), arch_splits(arch));
            assert_eq!(
                m.model.layer_names.len(),
                m.model.feature_shapes.len()
            );
            assert_eq!(m.gradcam_layers().len(), m.model.layer_names.len());
            // The synthetic CS curve's local maxima are exactly the
            // exported splits for every arch, not just VGG.
            let curve =
                crate::coordinator::CsCurve::from_manifest(m);
            assert_eq!(curve.candidates(2), arch_splits(arch), "{arch:?}");
        }
    }

    #[test]
    fn arch_backends_reach_their_recorded_accuracy() {
        for arch in Arch::ALL {
            let b = arch_backend(arch);
            let acc = accuracy(&b, "full_fwd_b16", 256);
            let base = b.manifest().model.base_test_accuracy;
            assert!(
                (acc - base).abs() < 0.05,
                "{arch:?}: measured {acc} vs recorded {base}"
            );
        }
    }

    #[test]
    fn datasets_are_arch_independent() {
        // The arch axis shares one synthetic dataset: sweeps load it once.
        let v = arch_backend(Arch::Vgg16).dataset("test").unwrap();
        let r = arch_backend(Arch::ResNet18).dataset("test").unwrap();
        let m = arch_backend(Arch::MobileNetV2).dataset("test").unwrap();
        assert_eq!(v.images.data(), r.images.data());
        assert_eq!(v.images.data(), m.images.data());
        assert_eq!(v.labels, r.labels);
        assert_eq!(v.labels, m.labels);
    }

    #[test]
    fn arch_split_executables_run_end_to_end() {
        for arch in [Arch::ResNet18, Arch::MobileNetV2] {
            let b = arch_backend(arch);
            let test = b.dataset("test").unwrap();
            let x = test.batch(0, 16).unwrap();
            for &s in &arch_splits(arch) {
                let head =
                    b.executable(&format!("head_L{s}_b16")).unwrap();
                let tail =
                    b.executable(&format!("tail_L{s}_b16")).unwrap();
                let z = head.run(&[RtInput::F32(&x)]).unwrap();
                let spec_latent = head.spec().latent_shape.unwrap();
                assert_eq!(
                    z.shape()[1..],
                    spec_latent[..],
                    "{arch:?} head L{s}"
                );
                let logits = tail.run(&[RtInput::F32(&z)]).unwrap();
                assert_eq!(logits.shape(), &[16, 10]);
            }
        }
    }

    #[test]
    fn chain_execs_synthesize_and_compose() {
        // head -> mid -> chain tail over [5, 13]: the double fold is
        // algebraically a single signed fold, so the chain's predictions
        // track the full model closely on clean inputs.
        let b = backend();
        let test = b.dataset("test").unwrap();
        let x = test.batch(0, 16).unwrap();
        let head = b.executable("head_L5_b16").unwrap();
        let mid = b.executable("mid_L5_L13_b16").unwrap();
        let tail = b.executable("tail_chain_L5_L13_b16").unwrap();
        let z5 = head.run(&[RtInput::F32(&x)]).unwrap();
        let z13 = mid.run(&[RtInput::F32(&z5)]).unwrap();
        assert_eq!(z13.shape()[1..], mid.spec().latent_shape.unwrap()[..]);
        assert!(z13.data().iter().all(|v| *v != 0.0));
        let logits = tail.run(&[RtInput::F32(&z13)]).unwrap();
        assert_eq!(logits.shape(), &[16, 10]);
        // Accuracy over a larger slice stays near the recorded base.
        let n = 128usize;
        let (head, mid, tail) = (
            b.executable("head_L5_b16").unwrap(),
            b.executable("mid_L5_L13_b16").unwrap(),
            b.executable("tail_chain_L5_L13_b16").unwrap(),
        );
        let mut correct = 0usize;
        for start in (0..n).step_by(16) {
            let x = test.batch(start, 16).unwrap();
            let z = head.run(&[RtInput::F32(&x)]).unwrap();
            let z = mid.run(&[RtInput::F32(&z)]).unwrap();
            let logits = tail.run(&[RtInput::F32(&z)]).unwrap();
            for (p, l) in logits
                .argmax_last()
                .iter()
                .zip(test.batch_labels(start, 16))
            {
                if *p == *l as usize {
                    correct += 1;
                }
            }
        }
        let acc = correct as f64 / n as f64;
        let base = b.manifest().model.base_test_accuracy;
        assert!(acc > base - 0.12, "chain accuracy {acc} vs base {base}");
    }

    #[test]
    fn poisoned_mid_latent_flips_the_chain_tail() {
        // A latent the damage model judges destroyed is forwarded as
        // all-zeros; the chain tail's damage check then fires with
        // probability 1, so corruption cascades instead of washing out.
        let b = backend();
        let test = b.dataset("test").unwrap();
        let x = test.batch(0, 1).unwrap();
        let head = b.executable("head_L5_b1").unwrap();
        let mid = b.executable("mid_L5_L13_b1").unwrap();
        let mut z = head.run(&[RtInput::F32(&x)]).unwrap();
        // Zero the whole latent: q = 1 makes the damage flip certain
        // (p = 1 - (1-q)^4 = 1), so the cascade is tested
        // deterministically.
        z.zero_byte_range(0, z.byte_len() as u32);
        let out = mid.run(&[RtInput::F32(&z)]).unwrap();
        assert!(
            out.data().iter().all(|v| *v == 0.0),
            "a destroyed latent must be forwarded as all-zero poison"
        );
        let tail = b.executable("tail_chain_L5_L13_b1").unwrap();
        let logits = tail.run(&[RtInput::F32(&out)]).unwrap();
        // One-hot pseudo-random class, not a correlation score vector.
        let ones = logits.data().iter().filter(|v| **v == 1.0).count();
        assert_eq!(ones, 1);
    }

    #[test]
    fn malformed_chain_exec_names_are_rejected() {
        let b = backend();
        assert!(b.executable("mid_L5_L5_b1").is_err()); // not increasing
        assert!(b.executable("mid_L13_L5_b1").is_err());
        assert!(b.executable("mid_L5_L17_b1").is_err()); // terminal cut
        assert!(b.executable("mid_L5_L40_b1").is_err()); // out of range
        assert!(b.executable("mid_L5_b1").is_err()); // needs two cuts
        assert!(b.executable("tail_chain_L5_b1").is_err()); // single cut
        assert!(b.executable("tail_chain_L5_L13_b0").is_err());
        assert!(b.executable("mid_L5_L13").is_err()); // no batch
        assert!(b.executable("mid_L5_L13_b1_x").is_err());
        assert!(b.executable("head_L40_b1").is_err());
    }

    #[test]
    fn unexported_cuts_synthesize_head_tail_and_compose() {
        // The analytic model needs no trained artifacts, so any
        // structurally valid cut works — `mc@4,11` from the CLI resolves
        // head_L4 / mid_L4_L11 / tail_chain_L4_L11 even though 4 is not
        // among the manifest's exported splits.
        let b = backend();
        assert!(!b.manifest().available_splits().contains(&4));
        let test = b.dataset("test").unwrap();
        let x = test.batch(0, 16).unwrap();
        let head = b.executable("head_L4_b16").unwrap();
        let mid = b.executable("mid_L4_L11_b16").unwrap();
        let tail = b.executable("tail_chain_L4_L11_b16").unwrap();
        let z = head.run(&[RtInput::F32(&x)]).unwrap();
        let z = mid.run(&[RtInput::F32(&z)]).unwrap();
        let logits = tail.run(&[RtInput::F32(&z)]).unwrap();
        let mut correct = 0usize;
        for (p, l) in
            logits.argmax_last().iter().zip(test.batch_labels(0, 16))
        {
            if *p == *l as usize {
                correct += 1;
            }
        }
        assert!(correct >= 12, "chain over unexported cuts: {correct}/16");
        // Exported splits still resolve through the manifest spec.
        assert!(b.manifest().executable("head_L4_b16").is_err());
        assert!(b.manifest().executable("head_L5_b16").is_ok());
    }

    #[test]
    fn chain_execs_have_segment_scale_latency_counters() {
        // The mid segment's simulated cost sits strictly between zero and
        // the full model's, and the chain tail costs the same as the
        // plain tail at its last cut.
        let b = backend();
        let test = b.dataset("test").unwrap();
        let x = test.batch(0, 1).unwrap();
        let head = b.executable("head_L5_b1").unwrap();
        let z = head.run(&[RtInput::F32(&x)]).unwrap();
        let mid = b.executable("mid_L5_L13_b1").unwrap();
        mid.run(&[RtInput::F32(&z)]).unwrap();
        let full = b.executable("full_fwd_b1").unwrap();
        full.run(&[RtInput::F32(&x)]).unwrap();
        assert!(mid.counters().total_exec_ns > 0);
        assert!(mid.counters().total_exec_ns < full.counters().total_exec_ns);
    }

    #[test]
    fn weaker_archs_flip_some_predictions() {
        // The seeded accuracy model must differentiate the archs: the
        // MobileNet backend (3% flip rate) classifies strictly fewer test
        // frames correctly than the flip-free VGG backend on the shared
        // dataset.
        let v = arch_backend(Arch::Vgg16);
        let m = arch_backend(Arch::MobileNetV2);
        let va = accuracy(&v, "full_fwd_b16", 256);
        let ma = accuracy(&m, "full_fwd_b16", 256);
        assert!(ma < va, "mobilenet {ma} vs vgg {va}");
    }
}
