//! Typed view of `artifacts/manifest.json` — the contract between the
//! python AOT pipeline (`python/compile/aot.py`) and the Rust serving path.

use std::collections::BTreeMap;
use std::fs;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

#[derive(Clone, Debug)]
pub struct ModelInfo {
    pub arch: String,
    pub width_mult: f64,
    pub num_classes: usize,
    pub img_size: usize,
    pub hidden: usize,
    pub layer_names: Vec<String>,
    /// (C, H, W) of each of the 18 feature layers.
    pub feature_shapes: Vec<[usize; 3]>,
    pub total_params: u64,
    pub base_test_accuracy: f64,
    pub ice_accuracy: f64,
}

#[derive(Clone, Debug)]
pub struct DatasetSpec {
    pub images: String,
    pub labels: String,
    pub count: usize,
    pub image_shape: Vec<usize>,
}

#[derive(Clone, Debug)]
pub struct CsCurveSpec {
    /// Min-max normalized CS value per feature layer.
    pub norm: Vec<f64>,
    pub raw: Vec<f64>,
    /// Candidate split points (local maxima), as computed at build time.
    pub candidates: Vec<usize>,
}

#[derive(Clone, Debug)]
pub struct SplitEvalRow {
    pub layer: usize,
    pub layer_name: String,
    /// Test accuracy of the fine-tuned split model (Fig. 2's second curve).
    pub accuracy: f64,
    pub latent_shape: [usize; 3],
    pub latent_bytes_per_image: u64,
    pub feature_bytes_per_image: u64,
}

#[derive(Clone, Debug)]
pub struct ArgSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

#[derive(Clone, Debug)]
pub struct WeightSpec {
    pub name: String,
    pub file: String,
    pub shape: Vec<usize>,
}

#[derive(Clone, Debug)]
pub struct ExecSpec {
    pub name: String,
    pub hlo: String,
    pub kind: String,
    pub batch: usize,
    pub split_layer: Option<usize>,
    pub gradcam_layer: Option<usize>,
    pub latent_shape: Option<[usize; 3]>,
    pub inputs: Vec<ArgSpec>,
    pub weights: Vec<WeightSpec>,
    pub outputs: Vec<ArgSpec>,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub fast: bool,
    pub model: ModelInfo,
    /// Test accuracy of the lightweight LC model, when exported.
    pub lite_accuracy: Option<f64>,
    pub datasets: BTreeMap<String, DatasetSpec>,
    pub class_names: Vec<String>,
    pub cs_curve: CsCurveSpec,
    pub split_eval: Vec<SplitEvalRow>,
    pub executables: BTreeMap<String, ExecSpec>,
    pub fixtures: BTreeMap<String, (String, Vec<usize>)>,
}

fn shape3(j: &Json) -> Result<[usize; 3]> {
    let v = j.usize_vec()?;
    if v.len() != 3 {
        bail!("expected a 3-dim shape, got {v:?}");
    }
    Ok([v[0], v[1], v[2]])
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = fs::read_to_string(&path).with_context(|| {
            format!(
                "reading {} — run `make artifacts` first",
                path.display()
            )
        })?;
        Self::parse(dir, &text)
    }

    pub fn parse(dir: &Path, text: &str) -> Result<Manifest> {
        let j = Json::parse(text).context("parsing manifest.json")?;

        let m = j.get("model")?;
        let model = ModelInfo {
            arch: m.get("arch")?.str()?.to_string(),
            width_mult: m.get("width_mult")?.f64()?,
            num_classes: m.get("num_classes")?.usize()?,
            img_size: m.get("img_size")?.usize()?,
            hidden: m.get("hidden")?.usize()?,
            layer_names: m
                .get("layer_names")?
                .arr()?
                .iter()
                .map(|v| Ok(v.str()?.to_string()))
                .collect::<Result<_>>()?,
            feature_shapes: m
                .get("feature_shapes")?
                .arr()?
                .iter()
                .map(shape3)
                .collect::<Result<_>>()?,
            total_params: m.get("total_params")?.f64()? as u64,
            base_test_accuracy: m.get("base_test_accuracy")?.f64()?,
            ice_accuracy: m.get("ice_accuracy")?.f64()?,
        };

        let d = j.get("dataset")?;
        let mut datasets = BTreeMap::new();
        for name in ["train", "test", "ice"] {
            let s = d.get(name)?;
            datasets.insert(
                name.to_string(),
                DatasetSpec {
                    images: s.get("images")?.str()?.to_string(),
                    labels: s.get("labels")?.str()?.to_string(),
                    count: s.get("count")?.usize()?,
                    image_shape: s.get("image_shape")?.usize_vec()?,
                },
            );
        }
        let class_names = d
            .get("class_names")?
            .arr()?
            .iter()
            .map(|v| Ok(v.str()?.to_string()))
            .collect::<Result<_>>()?;

        let c = j.get("cs_curve")?;
        let cs_curve = CsCurveSpec {
            norm: c.get("norm")?.f64_vec()?,
            raw: c.get("raw")?.f64_vec()?,
            candidates: c.get("candidates")?.usize_vec()?,
        };

        let split_eval = j
            .get("split_eval")?
            .arr()?
            .iter()
            .map(|r| {
                Ok(SplitEvalRow {
                    layer: r.get("layer")?.usize()?,
                    layer_name: r.get("layer_name")?.str()?.to_string(),
                    accuracy: r.get("accuracy")?.f64()?,
                    latent_shape: shape3(r.get("latent_shape")?)?,
                    latent_bytes_per_image: r
                        .get("latent_bytes_per_image")?
                        .f64()? as u64,
                    feature_bytes_per_image: r
                        .get("feature_bytes_per_image")?
                        .f64()? as u64,
                })
            })
            .collect::<Result<Vec<_>>>()?;

        let mut executables = BTreeMap::new();
        for e in j.get("executables")?.arr()? {
            let parse_args = |key: &str| -> Result<Vec<ArgSpec>> {
                e.get(key)?
                    .arr()?
                    .iter()
                    .map(|a| {
                        Ok(ArgSpec {
                            name: a.get("name")?.str()?.to_string(),
                            shape: a.get("shape")?.usize_vec()?,
                            dtype: a
                                .opt("dtype")
                                .map(|d| d.str().map(str::to_string))
                                .transpose()?
                                .unwrap_or_else(|| "float32".to_string()),
                        })
                    })
                    .collect()
            };
            let weights = e
                .get("weights")?
                .arr()?
                .iter()
                .map(|w| {
                    Ok(WeightSpec {
                        name: w.get("name")?.str()?.to_string(),
                        file: w.get("file")?.str()?.to_string(),
                        shape: w.get("shape")?.usize_vec()?,
                    })
                })
                .collect::<Result<_>>()?;
            let spec = ExecSpec {
                name: e.get("name")?.str()?.to_string(),
                hlo: e.get("hlo")?.str()?.to_string(),
                kind: e.get("kind")?.str()?.to_string(),
                batch: e.opt("batch").map(|b| b.usize()).transpose()?
                    .unwrap_or(1),
                split_layer: e
                    .opt("split_layer")
                    .map(|v| v.usize())
                    .transpose()?,
                gradcam_layer: e.opt("layer").map(|v| v.usize()).transpose()?,
                latent_shape: e
                    .opt("latent_shape")
                    .map(shape3)
                    .transpose()?,
                inputs: parse_args("inputs")?,
                weights,
                outputs: parse_args("outputs")?,
            };
            executables.insert(spec.name.clone(), spec);
        }

        let mut fixtures = BTreeMap::new();
        if let Some(fx) = j.opt("fixtures") {
            if let Json::Obj(m) = fx {
                for (k, v) in m {
                    fixtures.insert(
                        k.clone(),
                        (
                            v.get("file")?.str()?.to_string(),
                            v.get("shape")?.usize_vec()?,
                        ),
                    );
                }
            }
        }

        let lite_accuracy = j
            .opt("lite_model")
            .and_then(|l| l.opt("test_accuracy"))
            .map(|v| v.f64())
            .transpose()?;

        Ok(Manifest {
            dir: dir.to_path_buf(),
            fast: j.opt("fast").map(|f| f.bool()).transpose()?.unwrap_or(false),
            model,
            lite_accuracy,
            datasets,
            class_names,
            cs_curve,
            split_eval,
            executables,
            fixtures,
        })
    }

    /// The architecture this manifest's artifacts belong to, inferred
    /// from the `model.arch` string (unknown strings mean VGG16, the
    /// original geometry). Scenario costing and split enumeration key off
    /// this.
    pub fn arch(&self) -> crate::model::Arch {
        crate::model::Arch::infer(&self.model.arch)
    }

    pub fn executable(&self, name: &str) -> Result<&ExecSpec> {
        self.executables
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("no executable '{name}' in manifest"))
    }

    /// Split layers that have exported head/tail artifacts.
    pub fn available_splits(&self) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .executables
            .values()
            .filter(|e| e.kind == "head")
            .filter_map(|e| e.split_layer)
            .collect();
        v.sort_unstable();
        v.dedup();
        v
    }

    /// Feature layers with an exported Grad-CAM CS artifact.
    pub fn gradcam_layers(&self) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .executables
            .values()
            .filter(|e| e.kind == "gradcam")
            .filter_map(|e| e.gradcam_layer)
            .collect();
        v.sort_unstable();
        v.dedup();
        v
    }

    pub fn split_eval_for(&self, layer: usize) -> Option<&SplitEvalRow> {
        self.split_eval.iter().find(|r| r.layer == layer)
    }

    /// Bytes of one input frame on the wire for the RC scenario, derived
    /// from the full-model executable's input tensor description (shape
    /// beyond the batch dimension × dtype size). Falls back to the dense
    /// `3 × img² × f32` assumption only when the manifest describes no
    /// full-model executable.
    pub fn input_bytes_per_frame(&self) -> u64 {
        let input = self
            .executables
            .values()
            .filter(|e| e.kind == "full")
            .min_by_key(|e| e.batch)
            .and_then(|e| e.inputs.first());
        match input {
            Some(a) if a.shape.len() > 1 => {
                let elems: u64 =
                    a.shape[1..].iter().map(|d| *d as u64).product();
                elems * dtype_bytes(&a.dtype)
            }
            _ => (3 * self.model.img_size * self.model.img_size * 4) as u64,
        }
    }
}

/// Size in bytes of one element of a manifest dtype (f32 when unknown).
fn dtype_bytes(dtype: &str) -> u64 {
    match dtype {
        "float64" | "int64" | "uint64" => 8,
        "float32" | "int32" | "uint32" => 4,
        "float16" | "bfloat16" | "int16" | "uint16" => 2,
        "int8" | "uint8" | "bool" => 1,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1, "fast": true,
      "model": {"arch": "vgg16-slim", "width_mult": 0.125,
        "num_classes": 10, "img_size": 32, "hidden": 64,
        "layer_names": ["block1_conv1"], "feature_shapes": [[8, 32, 32]],
        "total_params": 235378, "base_test_accuracy": 0.97,
        "ice_accuracy": 0.96},
      "dataset": {
        "train": {"images": "dataset/train_images.bin",
          "labels": "dataset/train_labels.bin", "count": 4,
          "image_shape": [3, 32, 32]},
        "test": {"images": "t.bin", "labels": "tl.bin", "count": 2,
          "image_shape": [3, 32, 32]},
        "ice": {"images": "i.bin", "labels": "il.bin", "count": 2,
          "image_shape": [3, 32, 32]},
        "class_names": ["circle", "square"]},
      "cs_curve": {"norm": [0.0, 1.0, 0.5], "raw": [1, 2, 1.5],
        "candidates": [1]},
      "split_eval": [{"layer": 1, "layer_name": "block1_conv2",
        "accuracy": 0.9, "latent_shape": [4, 32, 32],
        "latent_bytes_per_image": 16384,
        "feature_bytes_per_image": 32768, "seconds": 1.0}],
      "executables": [
        {"name": "head_L1_b1", "hlo": "head_L1_b1.hlo.txt", "kind": "head",
         "batch": 1, "split_layer": 1, "latent_shape": [4, 32, 32],
         "inputs": [{"name": "x", "shape": [1, 3, 32, 32],
                     "dtype": "float32"}],
         "weights": [{"name": "conv0_w", "file": "weights/base/conv0_w.bin",
                      "shape": [8, 3, 3, 3]}],
         "outputs": [{"name": "latent", "shape": [1, 4, 32, 32]}],
         "hlo_chars": 10}],
      "fixtures": {"test16_logits": {"file": "fixtures/test16_logits.bin",
        "shape": [16, 10]}}
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(Path::new("/tmp"), SAMPLE).unwrap();
        assert_eq!(m.model.num_classes, 10);
        assert_eq!(m.model.feature_shapes[0], [8, 32, 32]);
        assert_eq!(m.datasets["train"].count, 4);
        assert_eq!(m.cs_curve.candidates, vec![1]);
        assert_eq!(m.split_eval[0].latent_shape, [4, 32, 32]);
        assert!(m.fast);
    }

    #[test]
    fn arch_is_inferred_from_the_model_string() {
        let m = Manifest::parse(Path::new("/tmp"), SAMPLE).unwrap();
        assert_eq!(m.arch(), crate::model::Arch::Vgg16);
    }

    #[test]
    fn executable_lookup() {
        let m = Manifest::parse(Path::new("/tmp"), SAMPLE).unwrap();
        let e = m.executable("head_L1_b1").unwrap();
        assert_eq!(e.kind, "head");
        assert_eq!(e.split_layer, Some(1));
        assert_eq!(e.weights[0].shape, vec![8, 3, 3, 3]);
        assert!(m.executable("nope").is_err());
    }

    #[test]
    fn available_splits_and_fixtures() {
        let m = Manifest::parse(Path::new("/tmp"), SAMPLE).unwrap();
        assert_eq!(m.available_splits(), vec![1]);
        assert!(m.gradcam_layers().is_empty());
        assert_eq!(m.fixtures["test16_logits"].1, vec![16, 10]);
        assert_eq!(m.split_eval_for(1).unwrap().accuracy, 0.9);
        assert!(m.split_eval_for(2).is_none());
    }

    #[test]
    fn missing_key_is_error() {
        assert!(Manifest::parse(Path::new("/tmp"), "{}").is_err());
    }

    #[test]
    fn input_bytes_prefer_full_exec_then_fall_back() {
        // SAMPLE has no full-model executable: dense f32 fallback.
        let mut m = Manifest::parse(Path::new("/tmp"), SAMPLE).unwrap();
        assert_eq!(m.input_bytes_per_frame(), (3 * 32 * 32 * 4) as u64);
        // With a full executable described, the input tensor wins — here a
        // uint8-quantized 3x32x32 input (batch dim excluded).
        let head = m.executable("head_L1_b1").unwrap().clone();
        let mut full = head.clone();
        full.name = "full_fwd_b1".to_string();
        full.kind = "full".to_string();
        full.inputs[0].shape = vec![1, 3, 32, 32];
        full.inputs[0].dtype = "uint8".to_string();
        m.executables.insert(full.name.clone(), full);
        assert_eq!(m.input_bytes_per_frame(), (3 * 32 * 32) as u64);
        // The smallest-batch full executable is the reference.
        let mut full16 = head.clone();
        full16.name = "full_fwd_b16".to_string();
        full16.kind = "full".to_string();
        full16.batch = 16;
        full16.inputs[0].shape = vec![16, 3, 64, 64];
        m.executables.insert(full16.name.clone(), full16);
        assert_eq!(m.input_bytes_per_frame(), (3 * 32 * 32) as u64);
    }
}
