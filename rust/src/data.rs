//! Dataset loader for the raw-binary tensors written by
//! `python/compile/dataset.py` (little-endian f32 images, i32 labels; shapes
//! come from the manifest).

use std::fs;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::tensor::Tensor;

/// A labelled image set (test set, ICE-Lab stream, ...).
#[derive(Clone, Debug)]
pub struct Dataset {
    pub name: String,
    pub images: Tensor,
    pub labels: Vec<i32>,
}

pub fn read_f32_file(path: &Path) -> Result<Vec<f32>> {
    let bytes = fs::read(path)
        .with_context(|| format!("reading {}", path.display()))?;
    if bytes.len() % 4 != 0 {
        bail!("{}: length {} not a multiple of 4", path.display(), bytes.len());
    }
    Ok(bytes
        .chunks_exact(4)
        .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
        .collect())
}

pub fn read_i32_file(path: &Path) -> Result<Vec<i32>> {
    let bytes = fs::read(path)
        .with_context(|| format!("reading {}", path.display()))?;
    if bytes.len() % 4 != 0 {
        bail!("{}: length {} not a multiple of 4", path.display(), bytes.len());
    }
    Ok(bytes
        .chunks_exact(4)
        .map(|b| i32::from_le_bytes([b[0], b[1], b[2], b[3]]))
        .collect())
}

pub fn write_f32_file(path: &Path, data: &[f32]) -> Result<()> {
    let mut bytes = Vec::with_capacity(data.len() * 4);
    for v in data {
        bytes.extend_from_slice(&v.to_le_bytes());
    }
    fs::write(path, bytes)
        .with_context(|| format!("writing {}", path.display()))
}

impl Dataset {
    /// Load a split recorded in the manifest's `dataset` section.
    pub fn load(
        artifacts_dir: &Path,
        name: &str,
        images_rel: &str,
        labels_rel: &str,
        count: usize,
        image_shape: &[usize],
    ) -> Result<Dataset> {
        let data = read_f32_file(&artifacts_dir.join(images_rel))?;
        let mut shape = vec![count];
        shape.extend_from_slice(image_shape);
        let images = Tensor::new(shape, data)
            .with_context(|| format!("dataset '{name}' image tensor"))?;
        let labels = read_i32_file(&artifacts_dir.join(labels_rel))?;
        if labels.len() != count {
            bail!("dataset '{name}': {} labels for {count} images",
                  labels.len());
        }
        for &l in &labels {
            if l < 0 {
                bail!("dataset '{name}': negative label {l}");
            }
        }
        Ok(Dataset { name: name.to_string(), images, labels })
    }

    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    /// Image batch [count, C, H, W] starting at `start`.
    pub fn batch(&self, start: usize, count: usize) -> Result<Tensor> {
        self.images.slice_rows(start, count)
    }

    pub fn batch_labels(&self, start: usize, count: usize) -> &[i32] {
        &self.labels[start..start + count]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir() -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!(
            "sei_data_test_{}",
            std::process::id()
        ));
        fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn f32_roundtrip() {
        let d = tmpdir();
        let p = d.join("x.bin");
        let data = vec![1.5f32, -2.0, 0.0, 3.25];
        write_f32_file(&p, &data).unwrap();
        assert_eq!(read_f32_file(&p).unwrap(), data);
    }

    #[test]
    fn i32_parsing() {
        let d = tmpdir();
        let p = d.join("y.bin");
        fs::write(&p, 7i32.to_le_bytes()).unwrap();
        assert_eq!(read_i32_file(&p).unwrap(), vec![7]);
    }

    #[test]
    fn rejects_ragged_file() {
        let d = tmpdir();
        let p = d.join("bad.bin");
        fs::write(&p, [0u8; 5]).unwrap();
        assert!(read_f32_file(&p).is_err());
    }

    #[test]
    fn dataset_load_and_batch() {
        let d = tmpdir();
        let n = 4usize;
        let img: Vec<f32> = (0..n * 3 * 2 * 2).map(|v| v as f32).collect();
        write_f32_file(&d.join("img.bin"), &img).unwrap();
        let mut lb = Vec::new();
        for i in 0..n as i32 {
            lb.extend_from_slice(&i.to_le_bytes());
        }
        fs::write(d.join("lab.bin"), lb).unwrap();
        let ds = Dataset::load(&d, "t", "img.bin", "lab.bin", n, &[3, 2, 2])
            .unwrap();
        assert_eq!(ds.len(), 4);
        let b = ds.batch(1, 2).unwrap();
        assert_eq!(b.shape(), &[2, 3, 2, 2]);
        assert_eq!(ds.batch_labels(1, 2), &[1, 2]);
    }

    #[test]
    fn dataset_rejects_label_mismatch() {
        let d = tmpdir();
        write_f32_file(&d.join("i2.bin"), &vec![0.0; 12]).unwrap();
        let mut two = Vec::new();
        two.extend_from_slice(&0i32.to_le_bytes());
        two.extend_from_slice(&1i32.to_le_bytes());
        fs::write(d.join("l2.bin"), two).unwrap();
        // 12 floats = one [3,2,2] image, but two labels -> mismatch.
        assert!(
            Dataset::load(&d, "t", "i2.bin", "l2.bin", 1, &[3, 2, 2]).is_err()
        );
        // and an image-count mismatch is also rejected
        assert!(
            Dataset::load(&d, "t", "i2.bin", "l2.bin", 2, &[3, 2, 2]).is_err()
        );
    }
}
