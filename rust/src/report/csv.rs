//! Tiny CSV writer for the bench outputs (plot-ready series).

use std::fmt::Write as _;

pub struct Csv {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Csv {
    pub fn new(header: &[&str]) -> Csv {
        Csv {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "csv row width");
        self.rows.push(cells);
    }

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        let esc = |c: &str| -> String {
            if c.contains(',') || c.contains('"') || c.contains('\n') {
                format!("\"{}\"", c.replace('"', "\"\""))
            } else {
                c.to_string()
            }
        };
        let _ = writeln!(
            out,
            "{}",
            self.header.iter().map(|h| esc(h)).collect::<Vec<_>>().join(",")
        );
        for r in &self.rows {
            let _ = writeln!(
                out,
                "{}",
                r.iter().map(|c| esc(c)).collect::<Vec<_>>().join(",")
            );
        }
        out
    }

    pub fn write(&self, path: &std::path::Path) -> anyhow::Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, self.to_string())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_rows() {
        let mut c = Csv::new(&["a", "b"]);
        c.row(vec!["1".into(), "2".into()]);
        assert_eq!(c.to_string(), "a,b\n1,2\n");
    }

    #[test]
    fn escapes_commas_and_quotes() {
        let mut c = Csv::new(&["x"]);
        c.row(vec!["a,b\"c".into()]);
        assert_eq!(c.to_string(), "x\n\"a,b\"\"c\"\n");
    }

    #[test]
    #[should_panic]
    fn rejects_ragged() {
        let mut c = Csv::new(&["a", "b"]);
        c.row(vec!["1".into()]);
    }
}
