//! Accuracy-vs-latency Pareto frontier extraction for design-space sweeps.
//!
//! The sweep engine ([`crate::coordinator::sweep`]) evaluates every point of
//! a condition × placement grid; this module reduces those points to the set
//! an engineer actually has to choose from — the configurations for which no
//! other configuration is at least as accurate *and* at least as fast. The
//! frontier is returned as indices into the caller's slice so it composes
//! with any point representation (sweep points, suggestions, raw tuples).
//!
//! # Example
//!
//! Extract the frontier of three designs — the slow-but-accurate and the
//! fast-but-weaker design survive, the dominated middle one does not:
//!
//! ```
//! use sei::report::pareto::pareto_frontier;
//!
//! // (accuracy, latency): higher accuracy is better, lower latency is better.
//! let points = [
//!     (0.90, 10.0), // fast, decent            -> on the frontier
//!     (0.89, 25.0), // slower AND less accurate -> dominated
//!     (0.97, 40.0), // slowest but most accurate -> on the frontier
//! ];
//! let frontier = pareto_frontier(&points);
//! assert_eq!(frontier, vec![0, 2]);
//! ```

/// Indices of the non-dominated points of `points`, where each point is
/// `(accuracy, latency)` with accuracy maximized and latency minimized.
///
/// A point *dominates* another when it is at least as good on both axes and
/// strictly better on at least one. The result is sorted by latency
/// ascending (ties broken by index), accuracy is strictly increasing along
/// it, and exact duplicates keep only the lowest index — so the frontier of
/// a given point set is unique and deterministic regardless of input order.
///
/// Points with a NaN coordinate are never part of the frontier.
pub fn pareto_frontier(points: &[(f64, f64)]) -> Vec<usize> {
    let mut order: Vec<usize> = (0..points.len())
        .filter(|&i| !points[i].0.is_nan() && !points[i].1.is_nan())
        .collect();
    // Latency ascending; at equal latency highest accuracy first, so the
    // sweep below keeps exactly one representative per latency value.
    order.sort_by(|&a, &b| {
        points[a]
            .1
            .partial_cmp(&points[b].1)
            .unwrap()
            .then(points[b].0.partial_cmp(&points[a].0).unwrap())
            .then(a.cmp(&b))
    });
    let mut frontier = Vec::new();
    let mut best_acc = f64::NEG_INFINITY;
    for &i in &order {
        if points[i].0 > best_acc {
            best_acc = points[i].0;
            frontier.push(i);
        }
    }
    frontier
}

/// True when `a` dominates `b`: at least as accurate and at least as fast,
/// strictly better on one axis. Used by the frontier property tests.
pub fn dominates(a: (f64, f64), b: (f64, f64)) -> bool {
    a.0 >= b.0 && a.1 <= b.1 && (a.0 > b.0 || a.1 < b.1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::{check, Config};

    #[test]
    fn empty_input_empty_frontier() {
        assert!(pareto_frontier(&[]).is_empty());
    }

    #[test]
    fn single_point_is_frontier() {
        assert_eq!(pareto_frontier(&[(0.5, 1.0)]), vec![0]);
    }

    #[test]
    fn dominated_point_is_dropped() {
        let pts = [(0.9, 10.0), (0.8, 20.0), (0.95, 30.0)];
        assert_eq!(pareto_frontier(&pts), vec![0, 2]);
    }

    #[test]
    fn equal_latency_keeps_most_accurate() {
        let pts = [(0.8, 10.0), (0.9, 10.0)];
        assert_eq!(pareto_frontier(&pts), vec![1]);
    }

    #[test]
    fn exact_duplicates_keep_first_index() {
        let pts = [(0.9, 10.0), (0.9, 10.0)];
        assert_eq!(pareto_frontier(&pts), vec![0]);
    }

    #[test]
    fn nan_points_are_excluded() {
        let pts = [(f64::NAN, 1.0), (0.9, f64::NAN), (0.5, 2.0)];
        assert_eq!(pareto_frontier(&pts), vec![2]);
    }

    #[test]
    fn order_independence() {
        let a = [(0.9, 10.0), (0.8, 20.0), (0.95, 30.0), (0.99, 5.0)];
        let b = [(0.99, 5.0), (0.95, 30.0), (0.8, 20.0), (0.9, 10.0)];
        let fa: Vec<(f64, f64)> =
            pareto_frontier(&a).iter().map(|&i| a[i]).collect();
        let fb: Vec<(f64, f64)> =
            pareto_frontier(&b).iter().map(|&i| b[i]).collect();
        assert_eq!(fa, fb);
    }

    #[test]
    fn property_frontier_is_nondominated_and_sorted() {
        check("pareto_frontier", Config::default(), |case| {
            let n = case.sized_range(1, 40) as usize;
            let pts: Vec<(f64, f64)> = (0..n)
                .map(|_| (case.f64(0.0, 1.0), case.f64(0.0, 1e9)))
                .collect();
            let frontier = pareto_frontier(&pts);
            if frontier.is_empty() {
                return Err("nonempty input must yield a frontier".into());
            }
            // Sorted by latency, strictly increasing accuracy.
            for w in frontier.windows(2) {
                let (a, b) = (pts[w[0]], pts[w[1]]);
                if b.1 < a.1 {
                    return Err(format!("not sorted by latency: {a:?} {b:?}"));
                }
                if b.0 <= a.0 {
                    return Err(format!(
                        "accuracy not strictly increasing: {a:?} {b:?}"
                    ));
                }
            }
            // No frontier point dominated by any point.
            for &f in &frontier {
                for (j, &p) in pts.iter().enumerate() {
                    if j != f && dominates(p, pts[f]) {
                        return Err(format!(
                            "frontier point {f} {:?} dominated by {j} {p:?}",
                            pts[f]
                        ));
                    }
                }
            }
            // Every dropped point is dominated by (or duplicates) a
            // frontier point.
            for (j, &p) in pts.iter().enumerate() {
                if frontier.contains(&j) {
                    continue;
                }
                let covered = frontier
                    .iter()
                    .any(|&f| dominates(pts[f], p) || pts[f] == p);
                if !covered {
                    return Err(format!("dropped point {j} {p:?} uncovered"));
                }
            }
            Ok(())
        });
    }
}
