//! Shared order statistics for report reduction.
//!
//! The single source of truth for percentile computation: every report
//! (scenario, streaming, sweep) quotes the same *nearest-rank* percentile
//! so p95/p99 columns are comparable across subsystems.

/// Nearest-rank percentile of an **ascending-sorted** slice.
///
/// Returns the smallest element such that at least `q·n` of the values
/// are `<=` it (rank `⌈q·n⌉`, 1-based), i.e. the classic nearest-rank
/// definition. `q` is clamped to (0, 1]; an empty slice yields 0.
///
/// Note the subtle indexing: the naive `sorted[(n as f64 * q) as usize]`
/// is *not* nearest-rank — for n = 20, q = 0.95 it indexes element 19
/// (the maximum) instead of element 18 (the 19th value, below which 95%
/// of the sample lies).
pub fn percentile(sorted: &[u64], q: f64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    let n = sorted.len();
    let rank = ((q * n as f64).ceil() as usize).clamp(1, n);
    sorted[rank - 1]
}

/// Nearest-rank percentile of an **unsorted** slice, by selection.
///
/// Same contract as [`percentile`] (rank `⌈q·n⌉`, 1-based; empty → 0) but
/// O(n) per call via `select_nth_unstable` instead of an O(n log n) sort of
/// a full clone — this is the per-report hot path once a run carries 10⁵
/// client streams, each wanting its own p95/p99. The slice is reordered
/// (partitioned around the selected rank), not sorted.
pub fn percentile_mut(values: &mut [u64], q: f64) -> u64 {
    if values.is_empty() {
        return 0;
    }
    let n = values.len();
    let rank = ((q * n as f64).ceil() as usize).clamp(1, n);
    *values.select_nth_unstable(rank - 1).1
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_is_zero() {
        assert_eq!(percentile(&[], 0.95), 0);
    }

    #[test]
    fn nearest_rank_for_twenty_samples() {
        // 1..=20: p95 is the 19th value (ceil(0.95*20) = 19), not the max.
        let v: Vec<u64> = (1..=20).collect();
        assert_eq!(percentile(&v, 0.95), 19);
        assert_eq!(percentile(&v, 0.99), 20);
        assert_eq!(percentile(&v, 0.50), 10);
        assert_eq!(percentile(&v, 1.0), 20);
    }

    #[test]
    fn single_sample() {
        assert_eq!(percentile(&[7], 0.5), 7);
        assert_eq!(percentile(&[7], 0.99), 7);
    }

    #[test]
    fn rank_bounds_are_clamped() {
        let v = [1, 2, 3];
        assert_eq!(percentile(&v, 0.0), 1); // clamped to rank 1
        assert_eq!(percentile(&v, 1.0), 3);
    }

    #[test]
    fn selection_empty_is_zero() {
        let mut v: Vec<u64> = vec![];
        assert_eq!(percentile_mut(&mut v, 0.95), 0);
    }

    #[test]
    fn selection_single_sample() {
        assert_eq!(percentile_mut(&mut [7], 0.5), 7);
        assert_eq!(percentile_mut(&mut [7], 0.99), 7);
        assert_eq!(percentile_mut(&mut [7], 0.0), 7);
        assert_eq!(percentile_mut(&mut [7], 1.0), 7);
    }

    #[test]
    fn selection_all_equal() {
        for &q in &[0.0, 0.5, 0.95, 0.99, 1.0] {
            let mut v = [42u64; 9];
            assert_eq!(percentile_mut(&mut v, q), 42);
        }
    }

    #[test]
    fn selection_rank_bounds_are_clamped() {
        // p0 clamps to rank 1 (the minimum), p100 to rank n (the maximum),
        // regardless of input order.
        let mut v = [3u64, 1, 2];
        assert_eq!(percentile_mut(&mut v, 0.0), 1);
        let mut v = [3u64, 1, 2];
        assert_eq!(percentile_mut(&mut v, 1.0), 3);
    }

    /// Property: selection on a shuffled copy agrees with the sorted
    /// nearest-rank reference at every quoted quantile.
    #[test]
    fn prop_selection_matches_sorted_reference() {
        use crate::util::propcheck::{check, Config};
        check("percentile_mut_matches", Config::default(), |c| {
            let n = c.sized_range(1, 200);
            let v: Vec<u64> =
                (0..n).map(|_| c.rng.below(1_000_000)).collect();
            let mut sorted = v.clone();
            sorted.sort_unstable();
            for &q in &[0.0, 0.5, 0.9, 0.95, 0.99, 1.0] {
                let mut scratch = v.clone();
                if percentile_mut(&mut scratch, q) != percentile(&sorted, q)
                {
                    return Err(format!("divergence at q={q}"));
                }
            }
            Ok(())
        });
    }

    /// Property: the fraction of samples <= percentile(q) is >= q, and
    /// the result is always an element of the input.
    #[test]
    fn prop_nearest_rank_contract() {
        use crate::util::propcheck::{check, Config};
        check("percentile_contract", Config::default(), |c| {
            let n = c.sized_range(1, 200);
            let mut v: Vec<u64> =
                (0..n).map(|_| c.rng.below(1_000_000)).collect();
            v.sort_unstable();
            for &q in &[0.5, 0.9, 0.95, 0.99] {
                let p = percentile(&v, q);
                if !v.contains(&p) {
                    return Err("not an element".into());
                }
                let frac = v.iter().filter(|&&x| x <= p).count() as f64
                    / n as f64;
                if frac + 1e-12 < q {
                    return Err(format!("coverage {frac} < {q}"));
                }
            }
            Ok(())
        });
    }
}
