//! Renderers for the paper's three figures. The benches compute the data
//! series; these functions format them the way the paper presents them
//! (plus CSV for external plotting).

use crate::util::table::{ascii_plot, render};

/// Fig. 2: CS curve vs per-layer split accuracy.
/// `rows`: (layer index, name, is_pool, cs_norm, split_accuracy or NaN).
pub fn fig2_report(rows: &[(usize, String, bool, f64, f64)]) -> String {
    let xs: Vec<f64> = rows.iter().map(|r| r.0 as f64).collect();
    let cs: Vec<f64> = rows.iter().map(|r| r.3).collect();
    let acc: Vec<f64> = rows
        .iter()
        .map(|r| if r.4.is_nan() { 0.0 } else { r.4 })
        .collect();
    let mut out = String::from(
        "Fig. 2 — Cumulative Saliency vs split accuracy per layer\n\n",
    );
    out.push_str(&ascii_plot(
        "normalized CS (*) and split accuracy (o) vs feature layer",
        "feature layer index (0..17)",
        &xs,
        &[("CS (normalized)", cs), ("split accuracy", acc)],
        12,
    ));
    out.push('\n');
    let table_rows: Vec<Vec<String>> = rows
        .iter()
        .map(|(i, name, pool, cs, acc)| {
            vec![
                format!("{i}{}", if *pool { " (*)" } else { "" }),
                name.clone(),
                format!("{cs:.4}"),
                if acc.is_nan() {
                    "—".to_string()
                } else {
                    format!("{:.3}", acc)
                },
            ]
        })
        .collect();
    out.push_str(&render(
        &["layer", "name", "CS (norm)", "split accuracy"],
        &table_rows,
    ));
    out
}

/// Fig. 3: SC latency vs loss rate for two split points + constraint line.
pub fn fig3_report(
    loss_rates: &[f64],
    series: &[(String, Vec<f64>)],
    constraint_s: f64,
) -> String {
    let mut out = String::from(
        "Fig. 3 — split-point selection under packet loss (TCP, 1 Gb/s FD)\n\n",
    );
    let mut plot_series: Vec<(&str, Vec<f64>)> = series
        .iter()
        .map(|(n, v)| (n.as_str(), v.clone()))
        .collect();
    let constraint = vec![constraint_s; loss_rates.len()];
    plot_series.push(("constraint", constraint));
    out.push_str(&ascii_plot(
        "mean frame latency [s] vs packet loss rate",
        "packet loss rate",
        loss_rates,
        &plot_series,
        14,
    ));
    out.push('\n');
    let mut header = vec!["loss".to_string()];
    header.extend(series.iter().map(|(n, _)| n.clone()));
    header.push(format!("constraint {constraint_s:.3} s"));
    let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let rows: Vec<Vec<String>> = loss_rates
        .iter()
        .enumerate()
        .map(|(i, l)| {
            let mut r = vec![format!("{:.0}%", l * 100.0)];
            for (_, v) in series {
                r.push(format!("{:.4} s", v[i]));
            }
            let worst = series
                .iter()
                .map(|(_, v)| v[i])
                .fold(f64::NEG_INFINITY, f64::max);
            r.push(if worst <= constraint_s { "ok" } else { "VIOLATED" }
                .to_string());
            r
        })
        .collect();
    out.push_str(&render(&header_refs, &rows));
    out
}

/// Fig. 4: RC accuracy (left) and latency (right) vs loss, TCP vs UDP.
pub fn fig4_report(
    loss_rates: &[f64],
    tcp_acc: &[f64],
    udp_acc: &[f64],
    tcp_lat: &[f64],
    udp_lat: &[f64],
) -> String {
    let mut out = String::from(
        "Fig. 4 — protocol selection in the RC scenario (1 Gb/s FD)\n\n",
    );
    out.push_str(&ascii_plot(
        "LEFT: accuracy vs loss rate",
        "packet loss rate",
        loss_rates,
        &[("TCP", tcp_acc.to_vec()), ("UDP", udp_acc.to_vec())],
        10,
    ));
    out.push('\n');
    out.push_str(&ascii_plot(
        "RIGHT: mean latency [s] vs loss rate",
        "packet loss rate",
        loss_rates,
        &[("TCP", tcp_lat.to_vec()), ("UDP", udp_lat.to_vec())],
        10,
    ));
    out.push('\n');
    let rows: Vec<Vec<String>> = loss_rates
        .iter()
        .enumerate()
        .map(|(i, l)| {
            vec![
                format!("{:.0}%", l * 100.0),
                format!("{:.3}", tcp_acc[i]),
                format!("{:.3}", udp_acc[i]),
                format!("{:.5} s", tcp_lat[i]),
                format!("{:.5} s", udp_lat[i]),
            ]
        })
        .collect();
    out.push_str(&render(
        &["loss", "TCP acc", "UDP acc", "TCP latency", "UDP latency"],
        &rows,
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig2_renders() {
        let rows = vec![
            (0, "block1_conv1".to_string(), false, 0.1, 0.5),
            (2, "block1_pool".to_string(), true, 0.4, f64::NAN),
        ];
        let r = fig2_report(&rows);
        assert!(r.contains("block1_pool") && r.contains("(*)"));
        assert!(r.contains("—"));
    }

    #[test]
    fn fig3_flags_violations() {
        let r = fig3_report(
            &[0.0, 0.05],
            &[("SC@L11".to_string(), vec![0.01, 0.09])],
            0.05,
        );
        assert!(r.contains("ok"));
        assert!(r.contains("VIOLATED"));
    }

    #[test]
    fn fig4_renders_both_panels() {
        let r = fig4_report(
            &[0.0, 0.1],
            &[0.97, 0.97],
            &[0.97, 0.5],
            &[0.001, 0.01],
            &[0.001, 0.001],
        );
        assert!(r.contains("LEFT") && r.contains("RIGHT"));
        assert!(r.contains("TCP acc"));
    }
}
