//! Report generators: render the paper's figures/tables as aligned text +
//! ASCII plots, and emit machine-readable CSV/JSON next to them.

pub mod csv;
pub mod figures;

pub use figures::{fig2_report, fig3_report, fig4_report};
