//! Report generators: render the paper's figures/tables as aligned text +
//! ASCII plots, emit machine-readable CSV/JSON next to them, and reduce
//! design-space sweeps to their accuracy-vs-latency Pareto frontier
//! ([`pareto`]).

pub mod csv;
pub mod figures;
pub mod pareto;
pub mod stats;

pub use figures::{fig2_report, fig3_report, fig4_report};
pub use pareto::pareto_frontier;
pub use stats::{percentile, percentile_mut};
