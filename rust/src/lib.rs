//! # Split-Et-Impera
//!
//! A framework for the design of distributed deep learning applications
//! (reproduction of Capogrosso et al., 2023). The library answers the
//! paper's design question — *where should a DNN be split between an edge
//! device and a server, and under which transport, to meet the
//! application's QoS constraints?* — with four cooperating subsystems:
//!
//! 1. **Saliency-driven split search** ([`coordinator::saliency`]): ingest
//!    the Grad-CAM *Cumulative Saliency* curve (computed by per-layer
//!    model executables, see [`runtime`]) and propose candidate split
//!    points at its local maxima.
//! 2. **Communication-aware simulation** ([`netsim`],
//!    [`coordinator::scenario`]): replay LC / RC / SC pipelines — and
//!    multi-tier MC pipelines placing k ordered cuts across a sensor →
//!    edge → cloud device chain, one channel per hop — over a
//!    discrete-event channel model (TCP/UDP, latency, capacity, interface
//!    speed, saboteur) with per-frame model inference.
//! 3. **Closed-loop streaming** ([`coordinator::streaming`]): a queueing,
//!    multi-client serving simulator — client streams feed per-resource
//!    FIFO queues (per-client sensor compute, per-hop uplink/downlink
//!    lanes, shared mid-chain tiers, a size-or-deadline batched server),
//!    so per-frame latency includes waiting time and throughput saturates
//!    at the bottleneck resource under overload. `run_scenario` rides
//!    this engine.
//! 4. **QoS suggestion** ([`coordinator::suggest`]): rank configurations by
//!    accuracy, simulate the shortlist, and report which designs satisfy
//!    the application's latency/accuracy requirements (per-frame deadline
//!    hit-rate, [`coordinator::qos::QosRequirements::min_hit_rate`]).
//! 5. **Design-space sweeps** ([`coordinator::sweep`]): expand a
//!    declarative [`coordinator::sweep::SweepSpec`] — a cartesian grid over
//!    network condition, protocol, scenario kind (incl. MC cut chains),
//!    model scale, architecture ([`model::Arch`]), serving load (clients
//!    × offered FPS) and device tier chains — into jobs, execute them on
//!    a deterministic worker pool
//!    (byte-identical reports at any thread count), and reduce them to an
//!    accuracy-vs-latency Pareto frontier ([`report::pareto`]) with
//!    per-constraint satisfaction counts.
//!
//! Models are described in an explicit **DAG layer-graph IR**
//! ([`model::layer`]): split points are *graph cuts* — single-tensor
//! frontiers of the topological order ([`model::cut`]) — which keeps
//! split selection meaningful for the whole zoo (VGG16, ResNet-18 with
//! residual skips, MobileNetV2 with inverted residuals) and structurally
//! excludes cuts a skip connection would cross.
//!
//! Inference is pluggable ([`runtime::InferenceBackend`]): the default
//! build runs every entry point hermetically on the pure-Rust analytic
//! reference backend ([`runtime::analytic`]) — no artifacts, no Python, no
//! native libraries — while the `xla` cargo feature swaps in the PJRT
//! engine (`runtime::engine`, compiled only under that feature) that
//! executes the real AOT-compiled XLA artifacts produced by the python
//! build path (`python/compile/`).
//!
//! A guided tour of the layer structure and the paper-section → module map
//! lives in `docs/ARCHITECTURE.md` at the repository root.

pub mod coordinator;
pub mod data;
pub mod model;
pub mod netsim;
pub mod report;
pub mod runtime;
pub mod tensor;
pub mod util;
