//! TCP transport model (Reno-style) over the simulated full-duplex channel.
//!
//! Models the mechanisms that produce the paper's Fig. 3/4 latency
//! behaviour under loss: cumulative ACKs, slow start + congestion
//! avoidance, fast retransmit on three duplicate ACKs, retransmission
//! timeout with exponential backoff and Karn's rule for RTT sampling.
//! Reliability is exact: every payload byte is delivered exactly once, in
//! order, for any saboteur rate < 1 (verified by property tests).
//!
//! Connection state (cwnd, ssthresh, sRTT, RTO) persists across messages of
//! a persistent connection, matching a streaming frame-by-frame workload.

use super::event::{EventQueue, SimTime};
use super::link::Link;
use super::packet::{segment, Packet};

#[derive(Clone, Debug)]
pub struct TcpConfig {
    pub mss: u32,
    /// Initial congestion window in segments (RFC 6928 default 10).
    pub init_cwnd_segments: u32,
    pub init_rto_ns: SimTime,
    pub min_rto_ns: SimTime,
    pub max_rto_ns: SimTime,
    /// Duplicate-ACK threshold for fast retransmit.
    pub dupack_threshold: u32,
    /// Safety cap on simulator events per message (loss < 1 terminates
    /// with probability 1; the cap converts a modelling bug into an error).
    pub max_events: u64,
}

impl Default for TcpConfig {
    fn default() -> Self {
        TcpConfig {
            mss: super::packet::TCP_MSS,
            init_cwnd_segments: 10,
            init_rto_ns: 50_000_000, // 50 ms before the first RTT sample
            // 2 ms: LAN/datacenter-tuned minimum RTO, consistent with the
            // simulated 100 µs-latency channel (srtt + 4·rttvar ≈ 1-2 ms).
            // The Linux WAN default of 200 ms would make any single timeout
            // blow a 50 ms frame budget and mask Fig. 3's gradual
            // degradation.
            min_rto_ns: 2_000_000,
            // Backoff cap: 200 ms. On a LAN a multi-second RTO (the RFC
            // 6298 60 s-class cap) is a pathological tail that would
            // dominate every mean latency plot.
            max_rto_ns: 200_000_000,
            dupack_threshold: 3,
            max_events: 50_000_000,
        }
    }
}

/// Congestion/RTT state that survives across messages on one connection.
#[derive(Clone, Debug)]
pub struct TcpState {
    pub cwnd: f64,
    pub ssthresh: f64,
    pub srtt_ns: Option<f64>,
    pub rttvar_ns: f64,
    pub rto_ns: SimTime,
}

impl TcpState {
    pub fn new(cfg: &TcpConfig) -> Self {
        TcpState {
            cwnd: (cfg.init_cwnd_segments * cfg.mss) as f64,
            ssthresh: 1e18,
            srtt_ns: None,
            rttvar_ns: 0.0,
            rto_ns: cfg.init_rto_ns,
        }
    }

    /// Recompute RTO from the current estimator state (clears exponential
    /// backoff once the connection is making forward progress again —
    /// modern stacks do this via timestamps even when Karn's rule blocks
    /// the RTT sample itself).
    fn refresh_rto(&mut self, cfg: &TcpConfig) {
        if let Some(srtt) = self.srtt_ns {
            let rto = srtt + (4.0 * self.rttvar_ns).max(1e6);
            self.rto_ns =
                (rto as SimTime).clamp(cfg.min_rto_ns, cfg.max_rto_ns);
        }
    }

    fn sample_rtt(&mut self, cfg: &TcpConfig, rtt_ns: f64) {
        match self.srtt_ns {
            None => {
                self.srtt_ns = Some(rtt_ns);
                self.rttvar_ns = rtt_ns / 2.0;
            }
            Some(srtt) => {
                self.rttvar_ns =
                    0.75 * self.rttvar_ns + 0.25 * (srtt - rtt_ns).abs();
                self.srtt_ns = Some(0.875 * srtt + 0.125 * rtt_ns);
            }
        }
        let rto = self.srtt_ns.unwrap() + (4.0 * self.rttvar_ns).max(1e6);
        self.rto_ns =
            (rto as SimTime).clamp(cfg.min_rto_ns, cfg.max_rto_ns);
    }
}

/// Per-message statistics.
#[derive(Clone, Copy, Debug, Default)]
pub struct TcpMessageStats {
    pub segments: u64,
    pub data_packets_sent: u64,
    pub data_packets_lost: u64,
    pub retransmits: u64,
    pub fast_retransmits: u64,
    pub timeouts: u64,
    pub acks_sent: u64,
    pub acks_lost: u64,
    pub wire_bytes: u64,
}

#[derive(Clone, Copy, Debug)]
pub struct TcpMessageResult {
    /// Message handed to the stack -> receiver holds every byte.
    pub delivery_latency_ns: SimTime,
    /// Message handed to the stack -> sender saw everything acked.
    pub ack_latency_ns: SimTime,
    pub stats: TcpMessageStats,
}

enum Ev {
    /// Data segment arrives at the receiver (seg index).
    Data { seg: usize },
    /// Cumulative ACK arrives back at the sender.
    Ack { ack_no: u64 },
    /// Retransmission timer (stale if epoch mismatches).
    Rto { epoch: u64 },
}

struct SegInfo {
    offset: u64,
    payload: u32,
    sent_at: SimTime,
    retransmitted: bool,
}

/// Sends one application message reliably over (data_link, ack_link).
/// `start` is the absolute simulated time the message is handed to TCP.
pub fn send_message(
    cfg: &TcpConfig,
    state: &mut TcpState,
    data_link: &mut Link,
    ack_link: &mut Link,
    len: u64,
    start: SimTime,
) -> Result<TcpMessageResult, String> {
    assert!(len > 0, "empty message");
    let segs: Vec<SegInfo> = segment(len, cfg.mss)
        .into_iter()
        .map(|(offset, payload)| SegInfo {
            offset,
            payload,
            sent_at: 0,
            retransmitted: false,
        })
        .collect();
    let mut segs = segs;
    let nseg = segs.len();

    let mut q: EventQueue<Ev> = EventQueue::new();
    q.advance_to(start);

    let mut st = TcpMessageStats { segments: nseg as u64, ..Default::default() };

    // Sender state.
    let mut snd_una: usize = 0; // first unacked segment index
    let mut snd_nxt: usize = 0; // next never-sent segment index
    let mut dup_acks: u32 = 0;
    let mut recover: usize = 0; // fast-recovery high-water segment index
    let mut in_recovery = false;
    let mut rto_epoch: u64 = 0;

    // Receiver state.
    let mut received = vec![false; nseg];
    let mut rcv_next: usize = 0; // first not-yet-in-order segment
    let mut delivered_at: Option<SimTime> = None;

    // Bytes in flight (snd_una..snd_nxt), maintained incrementally: the
    // windowed sum was the simulator's hottest loop (O(window) per try_send
    // step, O(window^2) per window) — see EXPERIMENTS.md §Perf.
    let mut flight: u64 = 0;
    let flight_bytes = |una: usize, nxt: usize, segs: &[SegInfo]| -> u64 {
        segs[una..nxt].iter().map(|s| s.payload as u64).sum()
    };

    macro_rules! transmit {
        ($q:expr, $seg:expr, $retx:expr) => {{
            let now = $q.now();
            let s = &mut segs[$seg];
            s.sent_at = now;
            if $retx {
                s.retransmitted = true;
                st.retransmits += 1;
            }
            let pkt = Packet::data(s.offset, s.payload, now);
            let out = data_link.send(now, pkt.wire_bytes());
            st.data_packets_sent += 1;
            st.wire_bytes += pkt.wire_bytes() as u64;
            if out.dropped {
                st.data_packets_lost += 1;
            } else {
                $q.schedule(out.arrival, Ev::Data { seg: $seg });
            }
        }};
    }

    macro_rules! arm_rto {
        ($q:expr) => {{
            rto_epoch += 1;
            $q.schedule_in(state.rto_ns, Ev::Rto { epoch: rto_epoch });
        }};
    }

    macro_rules! try_send {
        ($q:expr) => {{
            while snd_nxt < nseg {
                let payload = segs[snd_nxt].payload as u64;
                if flight + payload > state.cwnd as u64 {
                    break;
                }
                transmit!($q, snd_nxt, false);
                snd_nxt += 1;
                flight += payload;
            }
        }};
    }

    try_send!(q);
    arm_rto!(q);

    let mut events: u64 = 0;
    while snd_una < nseg {
        let Some((_, ev)) = q.pop() else {
            return Err(format!(
                "tcp deadlock: una={snd_una}/{nseg} nxt={snd_nxt} \
                 cwnd={:.0}",
                state.cwnd
            ));
        };
        events += 1;
        if events > cfg.max_events {
            return Err("tcp event cap exceeded".into());
        }
        match ev {
            Ev::Data { seg } => {
                if !received[seg] {
                    received[seg] = true;
                    while rcv_next < nseg && received[rcv_next] {
                        rcv_next += 1;
                    }
                    if rcv_next == nseg && delivered_at.is_none() {
                        delivered_at = Some(q.now());
                    }
                }
                // Cumulative ACK (ack number = bytes in order).
                let ack_no = if rcv_next == nseg {
                    len
                } else {
                    segs[rcv_next].offset
                };
                let ack = Packet::ack(ack_no, q.now());
                let out = ack_link.send(q.now(), ack.wire_bytes());
                st.acks_sent += 1;
                st.wire_bytes += ack.wire_bytes() as u64;
                if out.dropped {
                    st.acks_lost += 1;
                } else {
                    q.schedule(out.arrival, Ev::Ack { ack_no });
                }
            }
            Ev::Ack { ack_no } => {
                let acked_to = segs
                    .partition_point(|s| s.offset + s.payload as u64 <= ack_no);
                if acked_to > snd_una {
                    // New data acknowledged.
                    let newest = &segs[acked_to - 1];
                    if !newest.retransmitted {
                        // Karn: sample only segments sent exactly once.
                        state.sample_rtt(
                            cfg,
                            (q.now() - newest.sent_at) as f64,
                        );
                    }
                    let newly: u64 =
                        flight_bytes(snd_una, acked_to, &segs);
                    debug_assert_eq!(
                        flight,
                        flight_bytes(snd_una, snd_nxt, &segs)
                    );
                    flight -= newly.min(flight);
                    snd_una = acked_to;
                    snd_nxt = snd_nxt.max(snd_una);
                    dup_acks = 0;
                    state.refresh_rto(cfg); // forward progress: clear backoff
                    if in_recovery {
                        if snd_una > recover || snd_una >= nseg {
                            in_recovery = false;
                            state.cwnd = state.ssthresh;
                        } else {
                            // NewReno partial ACK (RFC 6582): the segment
                            // right after the ACK is also missing —
                            // retransmit it now instead of waiting for an
                            // RTO. Without this, every extra loss in a
                            // window costs a full backed-off timeout and
                            // latency explodes at percent-level loss.
                            transmit!(q, snd_una, true);
                            arm_rto!(q);
                        }
                    }
                    if !in_recovery {
                        if state.cwnd < state.ssthresh {
                            state.cwnd += newly as f64; // slow start
                        } else {
                            state.cwnd += (cfg.mss as f64)
                                * (cfg.mss as f64)
                                / state.cwnd; // congestion avoidance
                        }
                    }
                    if snd_una < nseg {
                        arm_rto!(q);
                    }
                    try_send!(q);
                } else if snd_una < nseg {
                    dup_acks += 1;
                    if in_recovery {
                        // NewReno-ish: inflate to keep the pipe full.
                        state.cwnd += cfg.mss as f64;
                        // If the recovery retransmission itself was lost,
                        // dup ACKs keep arriving with no partial ACK to
                        // repair it; re-retransmit every threshold dupACKs
                        // (RACK-style robustness) instead of stalling into
                        // a backed-off RTO.
                        if dup_acks % (2 * cfg.dupack_threshold) == 0 {
                            transmit!(q, snd_una, true);
                            arm_rto!(q);
                        }
                        try_send!(q);
                    } else if {
                        // Early retransmit (RFC 5827): with fewer than 4
                        // segments in flight there can never be 3 dupACKs;
                        // lower the threshold so small-window losses are
                        // repaired without a timeout. Essential once heavy
                        // loss has collapsed cwnd to a couple of segments.
                        let flight_segs = snd_nxt - snd_una;
                        let thr = if flight_segs < 4 {
                            (flight_segs.saturating_sub(1)).max(1) as u32
                        } else {
                            cfg.dupack_threshold
                        };
                        dup_acks == thr
                    } {
                        // Fast retransmit + fast recovery.
                        state.ssthresh = (flight as f64 / 2.0)
                            .max((2 * cfg.mss) as f64);
                        state.cwnd = state.ssthresh
                            + (cfg.dupack_threshold * cfg.mss) as f64;
                        in_recovery = true;
                        recover = snd_nxt;
                        st.fast_retransmits += 1;
                        transmit!(q, snd_una, true);
                        arm_rto!(q);
                    }
                }
            }
            Ev::Rto { epoch } => {
                if epoch != rto_epoch || snd_una >= nseg {
                    continue; // stale timer
                }
                st.timeouts += 1;
                state.ssthresh =
                    (flight as f64 / 2.0).max((2 * cfg.mss) as f64);
                state.cwnd = cfg.mss as f64;
                state.rto_ns = (state.rto_ns * 2).min(cfg.max_rto_ns);
                // Enter NewReno-style recovery for the whole outstanding
                // flight so the remaining holes are repaired one-per-RTT by
                // partial ACKs rather than by a chain of backed-off RTOs.
                in_recovery = true;
                recover = snd_nxt;
                dup_acks = 0;
                transmit!(q, snd_una, true);
                arm_rto!(q);
            }
        }
    }

    let delivered = delivered_at.ok_or("acked before delivered?")?;
    Ok(TcpMessageResult {
        delivery_latency_ns: delivered - start,
        ack_latency_ns: q.now() - start,
        stats: st,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::link::LinkConfig;
    use crate::util::rng::Rng;

    fn links(loss: f64, seed: u64) -> (Link, Link) {
        let cfg = LinkConfig::basic(100_000, 1e9, loss);
        let mut rng = Rng::new(seed);
        (
            Link::new(cfg.clone(), rng.fork()),
            Link::new(cfg, rng.fork()),
        )
    }

    fn send(len: u64, loss: f64, seed: u64) -> TcpMessageResult {
        let cfg = TcpConfig::default();
        let mut state = TcpState::new(&cfg);
        let (mut d, mut a) = links(loss, seed);
        send_message(&cfg, &mut state, &mut d, &mut a, len, 0).unwrap()
    }

    #[test]
    fn lossless_single_segment() {
        let r = send(1000, 0.0, 0);
        assert_eq!(r.stats.data_packets_sent, 1);
        assert_eq!(r.stats.retransmits, 0);
        // serialization (1040 B @1Gb/s = 8.32 µs) + 100 µs propagation
        assert_eq!(r.delivery_latency_ns, 108_320);
        // + ACK: 0.32 µs serialization + 100 µs back
        assert_eq!(r.ack_latency_ns, 208_640);
    }

    #[test]
    fn lossless_large_message_no_retx() {
        let r = send(800_000, 0.0, 1);
        assert_eq!(r.stats.retransmits, 0);
        assert_eq!(r.stats.timeouts, 0);
        assert_eq!(r.stats.segments, 548);
        // Must beat naive one-packet-per-RTT by far (pipelining works).
        assert!(r.delivery_latency_ns < 20_000_000, "{r:?}");
        // And cannot beat pure serialization of all wire bytes.
        let min_ns = (800_000.0 * 8.0 / 1e9 * 1e9) as u64;
        assert!(r.delivery_latency_ns > min_ns);
    }

    #[test]
    fn slow_start_grows_cwnd() {
        let cfg = TcpConfig::default();
        let mut state = TcpState::new(&cfg);
        let (mut d, mut a) = links(0.0, 2);
        let before = state.cwnd;
        send_message(&cfg, &mut state, &mut d, &mut a, 500_000, 0).unwrap();
        assert!(state.cwnd > before);
        assert!(state.srtt_ns.is_some());
    }

    #[test]
    fn lossy_delivery_is_reliable() {
        for seed in 0..20 {
            let r = send(100_000, 0.05, seed);
            assert!(r.stats.data_packets_lost > 0 || seed > 15);
            assert!(r.delivery_latency_ns > 0);
        }
    }

    #[test]
    fn loss_increases_latency_on_average() {
        let avg = |loss: f64| -> f64 {
            (0..24)
                .map(|s| send(200_000, loss, 100 + s).delivery_latency_ns as f64)
                .sum::<f64>()
                / 24.0
        };
        let l0 = avg(0.0);
        let l5 = avg(0.05);
        assert!(l5 > l0 * 1.2, "l0={l0} l5={l5}");
    }

    #[test]
    fn retransmissions_recover_losses() {
        let r = send(300_000, 0.08, 3);
        assert!(r.stats.retransmits >= r.stats.data_packets_lost.min(1));
        assert!(
            r.stats.fast_retransmits + r.stats.timeouts > 0,
            "{:?}",
            r.stats
        );
    }

    #[test]
    fn rto_backoff_caps() {
        let cfg = TcpConfig::default();
        let mut s = TcpState::new(&cfg);
        s.rto_ns = cfg.max_rto_ns;
        s.sample_rtt(&cfg, 1e14);
        assert!(s.rto_ns <= cfg.max_rto_ns);
    }

    #[test]
    fn rtt_estimator_converges() {
        let cfg = TcpConfig::default();
        let mut s = TcpState::new(&cfg);
        for _ in 0..50 {
            s.sample_rtt(&cfg, 2_000_000.0); // 2 ms RTT
        }
        assert!((s.srtt_ns.unwrap() - 2e6).abs() < 1e4);
        // rto -> srtt + max(4*var, 1ms) ~ 3 ms once variance decays
        assert!(s.rto_ns >= cfg.min_rto_ns && s.rto_ns < 3_200_000,
                "{}", s.rto_ns);
        // and a tiny-RTT link clamps at the floor
        let mut s2 = TcpState::new(&cfg);
        for _ in 0..50 {
            s2.sample_rtt(&cfg, 200_000.0); // 0.2 ms RTT
        }
        assert_eq!(s2.rto_ns, cfg.min_rto_ns);
    }

    #[test]
    fn deterministic_given_seed() {
        let a = send(123_456, 0.03, 9);
        let b = send(123_456, 0.03, 9);
        assert_eq!(a.delivery_latency_ns, b.delivery_latency_ns);
        assert_eq!(a.stats.retransmits, b.stats.retransmits);
    }

    #[test]
    fn persistent_state_speeds_up_second_message() {
        let cfg = TcpConfig::default();
        let mut state = TcpState::new(&cfg);
        let (mut d, mut a) = links(0.0, 4);
        let first =
            send_message(&cfg, &mut state, &mut d, &mut a, 400_000, 0)
                .unwrap();
        let t1 = first.ack_latency_ns;
        let second = send_message(
            &cfg, &mut state, &mut d, &mut a, 400_000, t1,
        )
        .unwrap();
        // cwnd is warm: the second message needs fewer RTT rounds.
        assert!(second.delivery_latency_ns <= first.delivery_latency_ns);
    }
}
