//! Discrete-event simulation kernel: virtual clock + time-ordered event
//! queue. The SCNSL library the paper builds on is a SystemC discrete-event
//! network simulator; this module is the equivalent kernel, generic over the
//! event payload so the transport models and the scenario engine reuse it.
//!
//! Three interchangeable backends implement the same pop order:
//!
//! * [`QueueKind::Wheel`] — a hierarchical timing wheel (multi-level
//!   64-slot buckets over the full 64-bit time space, per-level occupancy
//!   bitmaps, coarse levels cascading into finer ones). O(1) amortized
//!   schedule/pop; the fast path for 10⁵–10⁶ pending events, where the
//!   heap's cache-missing sift loops dominate the simulation.
//! * [`QueueKind::Calendar`] — an indexed event calendar (binary heap keyed
//!   on the packed `(time_ns, seq)` u128). O(log n) per operation; the
//!   default.
//! * [`QueueKind::LinearScan`] — the historical O(n)-per-pop next-event
//!   scan, retained as a differential oracle.
//!
//! All three backends select the globally minimal packed `(time, seq)` key
//! — the key is unique because `seq` strictly increases — so their pop
//! sequences are identical by construction and
//! `tests/calendar_equivalence.rs` pins byte-identical simulation output
//! between them. See `docs/ARCHITECTURE.md` for the wheel's bucket math
//! and the determinism argument.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Simulated time in nanoseconds.
pub type SimTime = u64;

pub const NS_PER_SEC: f64 = 1e9;

pub fn secs(t: SimTime) -> f64 {
    t as f64 / NS_PER_SEC
}

pub fn from_secs(s: f64) -> SimTime {
    (s * NS_PER_SEC).round() as SimTime
}

/// Which event-queue backend an [`EventQueue`] uses. All three produce the
/// same pop order (minimal `(time, seq)` key first); they differ only in
/// cost.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QueueKind {
    /// Hierarchical timing wheel: O(1) amortized schedule/pop.
    Wheel,
    /// Indexed calendar: binary heap, O(log n) schedule/pop. Default.
    Calendar,
    /// Unindexed O(n) min-scan per pop. Oracle / baseline only.
    LinearScan,
}

impl QueueKind {
    /// Parse a user-facing backend name (CLI `--queue`, sweep `"queue"`).
    pub fn parse(s: &str) -> Option<QueueKind> {
        match s {
            "wheel" => Some(QueueKind::Wheel),
            "calendar" => Some(QueueKind::Calendar),
            "linear" | "linear-scan" => Some(QueueKind::LinearScan),
            _ => None,
        }
    }
}

struct Entry<E> {
    /// (time << 64 | seq) packed so ordering is a single u128 compare —
    /// the heap's sift loops are the simulator's hottest comparisons
    /// (EXPERIMENTS.md §Perf). Ties broken by insertion sequence => stable
    /// FIFO at equal times.
    key: u128,
    event: E,
}

impl<E> Entry<E> {
    #[inline]
    fn time(&self) -> SimTime {
        (self.key >> 64) as SimTime
    }
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.key == other.key
    }
}

impl<E> Eq for Entry<E> {}

impl<E> Ord for Entry<E> {
    #[inline]
    fn cmp(&self, other: &Self) -> Ordering {
        // Reversed: BinaryHeap is a max-heap, we want earliest first.
        other.key.cmp(&self.key)
    }
}

impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Bits of simulated time per wheel level: 64 slots each.
const SLOT_BITS: u32 = 6;
const SLOTS: usize = 1 << SLOT_BITS;
/// 11 levels × 6 bits = 66 bits ≥ the full 64-bit [`SimTime`] space, so
/// the coarsest levels double as the overflow region: any schedulable
/// time has a home bucket and far-future events simply park high up until
/// a cascade carries them down.
const LEVELS: usize = (SimTime::BITS as usize).div_ceil(SLOT_BITS as usize);

/// One wheel bucket. `entries` retains its allocation across drain/reuse
/// cycles (lazy bucket reuse): a drained bucket is reset to
/// `sorted = false` with `entries.clear()`, keeping capacity.
struct Bucket<E> {
    entries: Vec<Entry<E>>,
    /// Level-0 buckets are sorted by full key — *descending*, so draining
    /// pops the minimum off the back — on first open. The sort is needed
    /// because a cascade can append an *earlier-seq* entry after a
    /// directly scheduled later-seq one, so raw insertion order is not
    /// FIFO. While a bucket is open, a direct insert carries a strictly
    /// larger seq than anything inside (same slot ⟹ same timestamp ⟹
    /// larger packed key) and goes to the front; a cascade can never
    /// target an open bucket (cascades fire only when level 0 is entirely
    /// empty).
    sorted: bool,
}

/// Hierarchical timing wheel over the packed `(time << 64) | seq` key.
///
/// Level `l` buckets times by bit group `[6l, 6l+6)`; an entry lives at
/// the *highest* level where its time differs from the wheel `base` (level
/// 0 if equal above bit 6). Invariants, relative to `base` (which only
/// advances, and only to values ≤ every pending time):
///
/// * every pending time `t` satisfies `t >= base`, so at an entry's level
///   the differing bit group of `t` is *greater* than `base`'s — lower
///   slots at that level are provably empty, and the occupancy bitmap's
///   `trailing_zeros` finds the earliest slot directly;
/// * all entries at level `l` precede all entries at any level `m > l`
///   (they agree with `base` on group `m` where the level-`m` entries
///   exceed it), so the lowest non-empty level holds the global minimum;
/// * a level-0 bucket holds exactly one timestamp (all higher groups are
///   pinned to `base`), so after the one-time sort its drain order is the
///   exact `(time, seq)` order.
///
/// Popping from a level-`l > 0` bucket advances `base` to the bucket's
/// time prefix and redistributes its entries, each landing at a strictly
/// lower level — so an entry cascades at most `LEVELS - 1` times over its
/// lifetime and both operations are O(1) amortized.
struct TimingWheel<E> {
    buckets: Vec<Bucket<E>>,
    /// Per-level slot-occupancy bitmaps; bit `s` set ⟺ bucket `(l, s)`
    /// holds undrained entries.
    occupied: [u64; LEVELS],
    base: SimTime,
    len: usize,
    /// Scratch storage for cascades; capacity persists across pops.
    spare: Vec<Entry<E>>,
}

impl<E> TimingWheel<E> {
    fn new() -> Self {
        TimingWheel {
            buckets: (0..LEVELS * SLOTS)
                .map(|_| Bucket { entries: Vec::new(), sorted: false })
                .collect(),
            occupied: [0; LEVELS],
            base: 0,
            len: 0,
            spare: Vec::new(),
        }
    }

    /// Level + slot of time `t` (`t >= self.base` always holds: the queue
    /// clamps schedules to `now`, and `base` never exceeds pending times).
    #[inline]
    fn place(&self, t: SimTime) -> (usize, usize) {
        let diff = t ^ self.base;
        let level = if diff == 0 {
            0
        } else {
            (63 - diff.leading_zeros()) as usize / SLOT_BITS as usize
        };
        let slot = ((t >> (SLOT_BITS * level as u32)) & (SLOTS as u64 - 1))
            as usize;
        (level, slot)
    }

    #[inline]
    fn insert(&mut self, entry: Entry<E>) {
        let (level, slot) = self.place(entry.time());
        let bucket = &mut self.buckets[level * SLOTS + slot];
        if bucket.sorted {
            // Open (draining) level-0 bucket: same timestamp, strictly
            // larger seq than everything inside — front of the descending
            // order. Rare path: only an event scheduling another event at
            // the *current* instant lands here.
            bucket.entries.insert(0, entry);
        } else {
            bucket.entries.push(entry);
        }
        self.occupied[level] |= 1 << slot;
    }

    fn push(&mut self, entry: Entry<E>) {
        self.insert(entry);
        self.len += 1;
    }

    fn pop(&mut self) -> Option<Entry<E>> {
        if self.len == 0 {
            return None;
        }
        loop {
            let level = (0..LEVELS)
                .find(|&l| self.occupied[l] != 0)
                .expect("len > 0 with empty wheel");
            let slot = self.occupied[level].trailing_zeros() as usize;
            if level == 0 {
                let bucket = &mut self.buckets[slot];
                if !bucket.sorted {
                    bucket
                        .entries
                        .sort_unstable_by(|a, b| b.key.cmp(&a.key));
                    bucket.sorted = true;
                }
                let entry =
                    bucket.entries.pop().expect("occupied bucket empty");
                if bucket.entries.is_empty() {
                    bucket.sorted = false;
                    self.occupied[0] &= !(1 << slot);
                }
                self.len -= 1;
                return Some(entry);
            }
            // Cascade: advance `base` to the bucket's time prefix (groups
            // above `level` from the old base, group `level` = slot, lower
            // groups zero) and redistribute — every entry re-lands at a
            // strictly lower level.
            let above = SLOT_BITS * (level as u32 + 1);
            let high = if above >= SimTime::BITS {
                0
            } else {
                (self.base >> above) << above
            };
            self.base = high | ((slot as u64) << (SLOT_BITS * level as u32));
            self.occupied[level] &= !(1 << slot);
            let mut moved = std::mem::replace(
                &mut self.buckets[level * SLOTS + slot].entries,
                std::mem::take(&mut self.spare),
            );
            for entry in moved.drain(..) {
                self.insert(entry);
            }
            self.spare = moved;
        }
    }
}

enum Backend<E> {
    Wheel(TimingWheel<E>),
    Calendar(BinaryHeap<Entry<E>>),
    LinearScan(Vec<Entry<E>>),
}

impl<E> Backend<E> {
    fn len(&self) -> usize {
        match self {
            Backend::Wheel(w) => w.len,
            Backend::Calendar(h) => h.len(),
            Backend::LinearScan(v) => v.len(),
        }
    }

    fn push(&mut self, entry: Entry<E>) {
        match self {
            Backend::Wheel(w) => w.push(entry),
            Backend::Calendar(h) => h.push(entry),
            Backend::LinearScan(v) => v.push(entry),
        }
    }

    fn pop(&mut self) -> Option<Entry<E>> {
        match self {
            Backend::Wheel(w) => w.pop(),
            Backend::Calendar(h) => h.pop(),
            Backend::LinearScan(v) => {
                // O(n) scan for the minimal packed key. The key is unique
                // (seq strictly increases), so the minimum is unambiguous
                // and matches what the heap would pop. swap_remove is fine:
                // order within the vec carries no meaning.
                let mut best = 0usize;
                for i in 1..v.len() {
                    if v[i].key < v[best].key {
                        best = i;
                    }
                }
                if v.is_empty() {
                    None
                } else {
                    Some(v.swap_remove(best))
                }
            }
        }
    }
}

/// Time-ordered event queue with a monotonic virtual clock.
pub struct EventQueue<E> {
    backend: Backend<E>,
    now: SimTime,
    next_seq: u64,
    processed: u64,
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        Self::with_kind(QueueKind::Calendar)
    }

    /// Like [`EventQueue::with_kind`], but pre-sizes the backend for an
    /// expected number of concurrently pending events, so steady-state
    /// scheduling performs no backend growth allocations. The wheel sizes
    /// itself lazily per bucket and ignores the hint.
    pub fn with_kind_and_capacity(kind: QueueKind, cap: usize) -> Self {
        let mut q = Self::with_kind(kind);
        match &mut q.backend {
            Backend::Wheel(_) => {}
            Backend::Calendar(h) => h.reserve(cap),
            Backend::LinearScan(v) => v.reserve(cap),
        }
        q
    }

    pub fn with_kind(kind: QueueKind) -> Self {
        let backend = match kind {
            QueueKind::Wheel => Backend::Wheel(TimingWheel::new()),
            QueueKind::Calendar => {
                Backend::Calendar(BinaryHeap::with_capacity(64))
            }
            QueueKind::LinearScan => {
                Backend::LinearScan(Vec::with_capacity(64))
            }
        };
        EventQueue {
            backend,
            now: 0,
            next_seq: 0,
            processed: 0,
        }
    }

    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Number of events popped so far (perf metric: events/second).
    pub fn processed(&self) -> u64 {
        self.processed
    }

    pub fn len(&self) -> usize {
        self.backend.len()
    }

    pub fn is_empty(&self) -> bool {
        self.backend.len() == 0
    }

    /// Schedule an event at absolute time `t`. Scheduling in the past is a
    /// logic error in every model built on this kernel.
    pub fn schedule(&mut self, t: SimTime, event: E) {
        debug_assert!(
            t >= self.now,
            "event scheduled in the past ({t} < {})",
            self.now
        );
        let seq = self.next_seq;
        self.next_seq += 1;
        let t = t.max(self.now);
        self.backend.push(Entry {
            key: ((t as u128) << 64) | seq as u128,
            event,
        });
    }

    pub fn schedule_in(&mut self, dt: SimTime, event: E) {
        self.schedule(self.now + dt, event);
    }

    /// Pop the earliest event, advancing the clock to its timestamp.
    pub fn pop(&mut self) -> Option<(SimTime, E)> {
        self.backend.pop().map(|e| {
            let t = e.time();
            debug_assert!(t >= self.now, "time went backwards");
            self.now = t;
            self.processed += 1;
            (t, e.event)
        })
    }

    /// Advance the clock without an event (compute phases).
    pub fn advance_to(&mut self, t: SimTime) {
        assert!(t >= self.now, "advance_to into the past");
        self.now = t;
    }
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.schedule(30, "c");
        q.schedule(10, "a");
        q.schedule(20, "b");
        assert_eq!(q.pop(), Some((10, "a")));
        assert_eq!(q.pop(), Some((20, "b")));
        assert_eq!(q.pop(), Some((30, "c")));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn ties_are_fifo() {
        let mut q = EventQueue::new();
        q.schedule(5, 1);
        q.schedule(5, 2);
        q.schedule(5, 3);
        assert_eq!(q.pop().unwrap().1, 1);
        assert_eq!(q.pop().unwrap().1, 2);
        assert_eq!(q.pop().unwrap().1, 3);
    }

    #[test]
    fn clock_advances_monotonically() {
        let mut q = EventQueue::new();
        q.schedule(100, ());
        q.schedule(50, ());
        let mut last = 0;
        while let Some((t, _)) = q.pop() {
            assert!(t >= last);
            last = t;
        }
        assert_eq!(q.now(), 100);
    }

    #[test]
    fn schedule_in_is_relative() {
        let mut q = EventQueue::new();
        q.schedule(10, "x");
        q.pop();
        q.schedule_in(5, "y");
        assert_eq!(q.pop(), Some((15, "y")));
    }

    #[test]
    fn conversions_roundtrip() {
        assert_eq!(from_secs(1.5), 1_500_000_000);
        assert!((secs(2_000_000_000) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn processed_counter() {
        let mut q = EventQueue::new();
        for i in 0..10 {
            q.schedule(i, i);
        }
        while q.pop().is_some() {}
        assert_eq!(q.processed(), 10);
    }

    fn xorshift(seed: u64) -> impl FnMut() -> u64 {
        let mut s = seed;
        move || {
            s ^= s << 13;
            s ^= s >> 7;
            s ^= s << 17;
            s
        }
    }

    /// Drive all three backends through the same interleaved schedule/pop
    /// workload and assert identical `(time, payload)` pop sequences.
    fn differential(seed: u64, iters: u64, mut dt: impl FnMut(u64) -> u64) {
        let mut qs = [
            EventQueue::with_kind(QueueKind::Wheel),
            EventQueue::with_kind(QueueKind::Calendar),
            EventQueue::with_kind(QueueKind::LinearScan),
        ];
        let mut rnd = xorshift(seed);
        let mut pending = 0usize;
        for i in 0..iters {
            // Absolute target with a saturating add so far-future offsets
            // near u64::MAX cannot overflow the clock.
            let t = qs[0].now().saturating_add(dt(rnd()));
            for q in &mut qs {
                q.schedule(t, i);
            }
            pending += 1;
            // Interleave pops so the clocks advance mid-stream.
            if rnd() % 3 == 0 && pending > 0 {
                let [a, b, c] = &mut qs;
                let x = a.pop();
                assert_eq!(x, b.pop());
                assert_eq!(x, c.pop());
                pending -= 1;
            }
        }
        loop {
            let [a, b, c] = &mut qs;
            let x = a.pop();
            assert_eq!(x, b.pop());
            assert_eq!(x, c.pop());
            if x.is_none() {
                break;
            }
        }
        assert_eq!(qs[0].processed(), qs[1].processed());
        assert_eq!(qs[0].processed(), qs[2].processed());
        assert_eq!(qs[0].now(), qs[1].now());
        assert_eq!(qs[0].now(), qs[2].now());
    }

    /// Differential pin at the kernel level: an interleaved schedule/pop
    /// workload pops the identical `(time, payload)` sequence from all
    /// backends. (The end-to-end pin lives in tests/calendar_equivalence.)
    #[test]
    fn backends_pop_identically() {
        differential(0x5EED_CAFE, 500, |r| r % 1000);
    }

    /// Heavy same-time ties: only 8 distinct offsets over 500 events, so
    /// wheel buckets hold long seq runs (including runs interleaved by
    /// cascades) and FIFO-at-equal-times must still hold exactly.
    #[test]
    fn backends_agree_under_same_time_ties() {
        differential(0xA11_50_71ED, 500, |r| (r % 8) * 250);
    }

    /// Far-future times: offsets up to 2^60 ns land in the wheel's
    /// coarsest (overflow) levels and cascade down through many levels
    /// before popping; mixture with near-term events keeps both regimes
    /// active in one run.
    #[test]
    fn backends_agree_with_far_future_overflow_times() {
        differential(0xFA_F07_0FF, 300, |r| {
            let shift = (r >> 32) % 61; // 0..=60
            (r & 0xFFFF) << shift
        });
    }

    /// The wheel must survive the degenerate single-bucket regime: every
    /// event at the exact same absolute time.
    #[test]
    fn wheel_drains_one_big_tie_bucket_in_seq_order() {
        let mut q = EventQueue::with_kind(QueueKind::Wheel);
        for i in 0..1000u64 {
            q.schedule(7_777_777, i);
        }
        for i in 0..1000u64 {
            assert_eq!(q.pop(), Some((7_777_777, i)));
        }
        assert_eq!(q.pop(), None);
    }

    /// An event scheduled at the *current* instant while its timestamp's
    /// bucket is mid-drain must pop after every already-pending event at
    /// that time (it has the larger seq), on all backends.
    #[test]
    fn schedule_at_now_while_draining_pops_last() {
        for kind in
            [QueueKind::Wheel, QueueKind::Calendar, QueueKind::LinearScan]
        {
            let mut q = EventQueue::with_kind(kind);
            q.schedule(5, 0u64);
            q.schedule(5, 1);
            q.schedule(5, 2);
            assert_eq!(q.pop(), Some((5, 0)));
            q.schedule(5, 3); // same instant, bucket already open
            assert_eq!(q.pop(), Some((5, 1)));
            assert_eq!(q.pop(), Some((5, 2)));
            assert_eq!(q.pop(), Some((5, 3)));
            assert_eq!(q.pop(), None);
        }
    }

    /// The processed counter is 64-bit end to end: feeding a queue whose
    /// counter sits just below `u32::MAX` must carry past the 32-bit
    /// boundary without wrapping. (Counter saturation at 10⁶-stream scale
    /// — ~10⁷ events per run, ~400 runs to overflow u32 — is why.)
    #[test]
    fn processed_counter_is_u64_past_the_u32_boundary() {
        let mut q = EventQueue::with_kind(QueueKind::Wheel);
        q.processed = u32::MAX as u64 - 2;
        for i in 0..6u64 {
            q.schedule(i, i);
        }
        while q.pop().is_some() {}
        assert_eq!(q.processed(), u32::MAX as u64 + 4);
        assert!(q.processed() > u32::MAX as u64);
    }

    #[test]
    fn queue_kind_parses_cli_names() {
        assert_eq!(QueueKind::parse("wheel"), Some(QueueKind::Wheel));
        assert_eq!(QueueKind::parse("calendar"), Some(QueueKind::Calendar));
        assert_eq!(QueueKind::parse("linear"), Some(QueueKind::LinearScan));
        assert_eq!(
            QueueKind::parse("linear-scan"),
            Some(QueueKind::LinearScan)
        );
        assert_eq!(QueueKind::parse("heap"), None);
    }

    /// Capacity pre-sizing must not change behaviour.
    #[test]
    fn with_capacity_matches_default_behaviour() {
        for kind in
            [QueueKind::Wheel, QueueKind::Calendar, QueueKind::LinearScan]
        {
            let mut q = EventQueue::<u64>::with_kind_and_capacity(kind, 1024);
            q.schedule(3, 1);
            q.schedule(1, 2);
            assert_eq!(q.pop(), Some((1, 2)));
            assert_eq!(q.pop(), Some((3, 1)));
        }
    }
}
