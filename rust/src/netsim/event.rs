//! Discrete-event simulation kernel: virtual clock + time-ordered event
//! queue. The SCNSL library the paper builds on is a SystemC discrete-event
//! network simulator; this module is the equivalent kernel, generic over the
//! event payload so the transport models and the scenario engine reuse it.
//!
//! Two interchangeable backends implement the same pop order:
//!
//! * [`QueueKind::Calendar`] — an indexed event calendar (binary heap keyed
//!   on the packed `(time_ns, seq)` u128). O(log n) per operation; the
//!   default, and the only sane choice at 10⁴–10⁶ pending events.
//! * [`QueueKind::LinearScan`] — the historical O(n)-per-pop next-event
//!   scan, retained as a differential oracle: both backends select the
//!   globally minimal packed key, so their pop sequences are identical by
//!   construction and `tests/calendar_equivalence.rs` pins byte-identical
//!   simulation output between them.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Simulated time in nanoseconds.
pub type SimTime = u64;

pub const NS_PER_SEC: f64 = 1e9;

pub fn secs(t: SimTime) -> f64 {
    t as f64 / NS_PER_SEC
}

pub fn from_secs(s: f64) -> SimTime {
    (s * NS_PER_SEC).round() as SimTime
}

/// Which event-queue backend an [`EventQueue`] uses. Both produce the same
/// pop order (minimal `(time, seq)` key first); they differ only in cost.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QueueKind {
    /// Indexed calendar: binary heap, O(log n) schedule/pop. Default.
    Calendar,
    /// Unindexed O(n) min-scan per pop. Oracle / baseline only.
    LinearScan,
}

struct Entry<E> {
    /// (time << 64 | seq) packed so ordering is a single u128 compare —
    /// the heap's sift loops are the simulator's hottest comparisons
    /// (EXPERIMENTS.md §Perf). Ties broken by insertion sequence => stable
    /// FIFO at equal times.
    key: u128,
    event: E,
}

impl<E> Entry<E> {
    #[inline]
    fn time(&self) -> SimTime {
        (self.key >> 64) as SimTime
    }
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.key == other.key
    }
}

impl<E> Eq for Entry<E> {}

impl<E> Ord for Entry<E> {
    #[inline]
    fn cmp(&self, other: &Self) -> Ordering {
        // Reversed: BinaryHeap is a max-heap, we want earliest first.
        other.key.cmp(&self.key)
    }
}

impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

enum Backend<E> {
    Calendar(BinaryHeap<Entry<E>>),
    LinearScan(Vec<Entry<E>>),
}

impl<E> Backend<E> {
    fn len(&self) -> usize {
        match self {
            Backend::Calendar(h) => h.len(),
            Backend::LinearScan(v) => v.len(),
        }
    }

    fn push(&mut self, entry: Entry<E>) {
        match self {
            Backend::Calendar(h) => h.push(entry),
            Backend::LinearScan(v) => v.push(entry),
        }
    }

    fn pop(&mut self) -> Option<Entry<E>> {
        match self {
            Backend::Calendar(h) => h.pop(),
            Backend::LinearScan(v) => {
                // O(n) scan for the minimal packed key. The key is unique
                // (seq strictly increases), so the minimum is unambiguous
                // and matches what the heap would pop. swap_remove is fine:
                // order within the vec carries no meaning.
                let mut best = 0usize;
                for i in 1..v.len() {
                    if v[i].key < v[best].key {
                        best = i;
                    }
                }
                if v.is_empty() {
                    None
                } else {
                    Some(v.swap_remove(best))
                }
            }
        }
    }
}

/// Time-ordered event queue with a monotonic virtual clock.
pub struct EventQueue<E> {
    backend: Backend<E>,
    now: SimTime,
    next_seq: u64,
    processed: u64,
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        Self::with_kind(QueueKind::Calendar)
    }

    pub fn with_kind(kind: QueueKind) -> Self {
        let backend = match kind {
            QueueKind::Calendar => {
                Backend::Calendar(BinaryHeap::with_capacity(64))
            }
            QueueKind::LinearScan => {
                Backend::LinearScan(Vec::with_capacity(64))
            }
        };
        EventQueue {
            backend,
            now: 0,
            next_seq: 0,
            processed: 0,
        }
    }

    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Number of events popped so far (perf metric: events/second).
    pub fn processed(&self) -> u64 {
        self.processed
    }

    pub fn len(&self) -> usize {
        self.backend.len()
    }

    pub fn is_empty(&self) -> bool {
        self.backend.len() == 0
    }

    /// Schedule an event at absolute time `t`. Scheduling in the past is a
    /// logic error in every model built on this kernel.
    pub fn schedule(&mut self, t: SimTime, event: E) {
        debug_assert!(
            t >= self.now,
            "event scheduled in the past ({t} < {})",
            self.now
        );
        let seq = self.next_seq;
        self.next_seq += 1;
        let t = t.max(self.now);
        self.backend.push(Entry {
            key: ((t as u128) << 64) | seq as u128,
            event,
        });
    }

    pub fn schedule_in(&mut self, dt: SimTime, event: E) {
        self.schedule(self.now + dt, event);
    }

    /// Pop the earliest event, advancing the clock to its timestamp.
    pub fn pop(&mut self) -> Option<(SimTime, E)> {
        self.backend.pop().map(|e| {
            let t = e.time();
            debug_assert!(t >= self.now, "time went backwards");
            self.now = t;
            self.processed += 1;
            (t, e.event)
        })
    }

    /// Advance the clock without an event (compute phases).
    pub fn advance_to(&mut self, t: SimTime) {
        assert!(t >= self.now, "advance_to into the past");
        self.now = t;
    }
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.schedule(30, "c");
        q.schedule(10, "a");
        q.schedule(20, "b");
        assert_eq!(q.pop(), Some((10, "a")));
        assert_eq!(q.pop(), Some((20, "b")));
        assert_eq!(q.pop(), Some((30, "c")));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn ties_are_fifo() {
        let mut q = EventQueue::new();
        q.schedule(5, 1);
        q.schedule(5, 2);
        q.schedule(5, 3);
        assert_eq!(q.pop().unwrap().1, 1);
        assert_eq!(q.pop().unwrap().1, 2);
        assert_eq!(q.pop().unwrap().1, 3);
    }

    #[test]
    fn clock_advances_monotonically() {
        let mut q = EventQueue::new();
        q.schedule(100, ());
        q.schedule(50, ());
        let mut last = 0;
        while let Some((t, _)) = q.pop() {
            assert!(t >= last);
            last = t;
        }
        assert_eq!(q.now(), 100);
    }

    #[test]
    fn schedule_in_is_relative() {
        let mut q = EventQueue::new();
        q.schedule(10, "x");
        q.pop();
        q.schedule_in(5, "y");
        assert_eq!(q.pop(), Some((15, "y")));
    }

    #[test]
    fn conversions_roundtrip() {
        assert_eq!(from_secs(1.5), 1_500_000_000);
        assert!((secs(2_000_000_000) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn processed_counter() {
        let mut q = EventQueue::new();
        for i in 0..10 {
            q.schedule(i, i);
        }
        while q.pop().is_some() {}
        assert_eq!(q.processed(), 10);
    }

    /// Differential pin at the kernel level: an interleaved schedule/pop
    /// workload pops the identical `(time, payload)` sequence from both
    /// backends. (The end-to-end pin lives in tests/calendar_equivalence.)
    #[test]
    fn backends_pop_identically() {
        let mut a = EventQueue::with_kind(QueueKind::Calendar);
        let mut b = EventQueue::with_kind(QueueKind::LinearScan);
        // xorshift64 so the schedule is deterministic but unstructured.
        let mut s: u64 = 0x5EED_CAFE;
        let mut rnd = move || {
            s ^= s << 13;
            s ^= s >> 7;
            s ^= s << 17;
            s
        };
        let mut pending = 0usize;
        for i in 0..500u64 {
            let dt = rnd() % 1000;
            a.schedule_in(dt, i);
            b.schedule_in(dt, i);
            pending += 1;
            // Interleave pops so the clocks advance mid-stream.
            if rnd() % 3 == 0 && pending > 0 {
                assert_eq!(a.pop(), b.pop());
                pending -= 1;
            }
        }
        loop {
            let (x, y) = (a.pop(), b.pop());
            assert_eq!(x, y);
            if x.is_none() {
                break;
            }
        }
        assert_eq!(a.processed(), b.processed());
        assert_eq!(a.now(), b.now());
    }
}
