//! Time-varying link model: piecewise-constant channel traces.
//!
//! A [`LinkTrace`] describes how a link's physical parameters (bandwidth,
//! latency, loss, jitter) evolve over simulated time as an ordered list of
//! [`TraceSegment`]s — the piecewise-constant abstraction every packet-level
//! channel emulator (mahimahi, tc-netem schedules) converges on. The
//! [`super::link::Link`] samples the active segment at send time and costs a
//! packet that straddles a boundary piecewise, so a transfer spanning a
//! Wi-Fi → congested handoff pays the degraded rate for exactly the bits
//! that cross it.
//!
//! A *constant* (single-segment) trace is byte-identical to running the
//! plain [`NetworkConfig`] fields: the piecewise integration collapses to
//! the same floating-point expression the static path evaluates, the RNG
//! draw order is unchanged, and no boundary events exist to perturb event
//! sequence numbers (pinned by `tests/trace_semantics.rs`).
//!
//! Trace construction:
//!   * [`LinkTrace::parse_chain`] — compact grammar
//!     `<state0>[><state>@<time>...]` where each state is a channel spec
//!     understood by [`NetworkConfig::parse`] (minus protocol/seed, which
//!     belong to the channel, not the link) or a trace-only preset
//!     (`congested`, `degraded`), and times accept `s`/`ms`/`us`/`ns`
//!     suffixes. Example: `wifi>congested@2s>wifi@4s`.
//!   * [`LinkTrace::fade`] — smooth multiplicative rate fades (piecewise
//!     approximation of a fading cycle).
//!   * [`LinkTrace::congestion_bursts`] — seeded alternation between the
//!     base channel and a congested state with exponential dwell times.

use anyhow::{anyhow, bail, Result};

use super::event::{SimTime, NS_PER_SEC};
use super::link::LossModel;
use super::transfer::{NetworkConfig, Protocol};
use crate::util::json::Json;
use crate::util::rng::Rng;

/// One piecewise-constant span of link behavior, active from `start_ns`
/// until the next segment's start (the last segment extends forever).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TraceSegment {
    /// Absolute sim time this segment becomes active, ns.
    pub start_ns: SimTime,
    /// Channel capacity, bits/s.
    pub capacity_bps: f64,
    /// Interface (NIC) speed, bits/s.
    pub interface_bps: f64,
    /// Propagation delay, ns.
    pub latency_ns: SimTime,
    /// Saboteur loss rate in [0, 1).
    pub loss_rate: f64,
    /// Loss distribution in time.
    pub loss_model: LossModel,
    /// Per-packet propagation jitter bound, ns.
    pub jitter_ns: SimTime,
}

impl TraceSegment {
    /// Snapshot the link-level fields of a channel spec as a segment.
    pub fn from_net(net: &NetworkConfig, start_ns: SimTime) -> TraceSegment {
        TraceSegment {
            start_ns,
            capacity_bps: net.capacity_bps,
            interface_bps: net.interface_bps,
            latency_ns: net.latency_ns,
            loss_rate: net.loss_rate,
            loss_model: net.loss_model,
            jitter_ns: net.jitter_ns,
        }
    }

    /// Effective serialization rate while this segment is active.
    pub fn rate_bps(&self) -> f64 {
        self.capacity_bps.min(self.interface_bps)
    }
}

/// A piecewise-constant link schedule over sim time.
#[derive(Clone, Debug, PartialEq)]
pub struct LinkTrace {
    /// Human-readable label carried into reports (`wifi>congested@2s`,
    /// `fade`, ...).
    pub name: String,
    segments: Vec<TraceSegment>,
}

impl LinkTrace {
    /// Build a trace from explicit segments. The first segment must start
    /// at t = 0 and starts must strictly increase; every segment needs a
    /// positive finite rate.
    pub fn new(name: &str, segments: Vec<TraceSegment>) -> Result<LinkTrace> {
        if segments.is_empty() {
            bail!("trace '{name}': needs at least one segment");
        }
        if segments[0].start_ns != 0 {
            bail!(
                "trace '{name}': first segment must start at t=0, got {}",
                segments[0].start_ns
            );
        }
        for w in segments.windows(2) {
            if w[1].start_ns <= w[0].start_ns {
                bail!(
                    "trace '{name}': segment starts must strictly increase \
                     ({} then {})",
                    w[0].start_ns,
                    w[1].start_ns
                );
            }
        }
        for s in &segments {
            let r = s.rate_bps();
            if !r.is_finite() || r <= 0.0 {
                bail!(
                    "trace '{name}': segment at {} ns has non-positive \
                     rate {r}",
                    s.start_ns
                );
            }
        }
        Ok(LinkTrace { name: name.to_string(), segments })
    }

    /// A single-segment trace equal to `net`'s own link parameters — the
    /// identity trace (byte-identical to no trace at all).
    pub fn constant(net: &NetworkConfig) -> LinkTrace {
        LinkTrace {
            name: "constant".to_string(),
            segments: vec![TraceSegment::from_net(net, 0)],
        }
    }

    pub fn segments(&self) -> &[TraceSegment] {
        &self.segments
    }

    /// The segment active at absolute time `t`.
    pub fn segment_at(&self, t: SimTime) -> &TraceSegment {
        self.segments
            .iter()
            .rev()
            .find(|s| s.start_ns <= t)
            .expect("first segment starts at 0")
    }

    /// The first segment boundary strictly after `t`, if any.
    pub fn next_boundary_after(&self, t: SimTime) -> Option<SimTime> {
        self.segments
            .iter()
            .map(|s| s.start_ns)
            .find(|&b| b > t)
    }

    /// All interior boundaries (every segment start except t = 0) — the
    /// times the streaming engine schedules `TraceBoundary` calendar
    /// events at.
    pub fn boundaries(&self) -> Vec<SimTime> {
        self.segments[1..].iter().map(|s| s.start_ns).collect()
    }

    /// A constant trace has no boundaries and degenerates to the static
    /// channel model.
    pub fn is_constant(&self) -> bool {
        self.segments.len() == 1
    }

    /// Best-case serialization rate over all segments: the bound
    /// placement/admission stays admissible under (an optimistic estimate
    /// can only over-admit, never wrongly reject, and the paper's
    /// admission contract is "rejected ⇒ provably unservable").
    pub fn best_rate_bps(&self) -> f64 {
        self.segments
            .iter()
            .map(|s| s.rate_bps())
            .fold(f64::NEG_INFINITY, f64::max)
    }

    /// Worst-case serialization rate over all segments (reporting).
    pub fn worst_rate_bps(&self) -> f64 {
        self.segments
            .iter()
            .map(|s| s.rate_bps())
            .fold(f64::INFINITY, f64::min)
    }

    /// Parse the compact chain grammar: `<state0>[><state>@<time>...]`.
    /// Each state is a trace preset (`congested` | `degraded`) or a
    /// channel spec accepted by [`NetworkConfig::parse`] *without*
    /// protocol/seed segments; `<time>` takes `s`/`ms`/`us`/`ns` suffixes
    /// or raw integer ns. The chain itself becomes the trace name.
    pub fn parse_chain(spec: &str) -> Result<LinkTrace> {
        let mut toks = spec.split('>');
        let first = toks.next().unwrap_or("");
        if first.is_empty() {
            bail!("trace '{spec}': empty initial state");
        }
        let mut segments =
            vec![TraceSegment::from_net(&state_config(first)?, 0)];
        for tok in toks {
            let Some((state, at)) = tok.rsplit_once('@') else {
                bail!(
                    "trace '{spec}': state '{tok}' needs a switch time \
                     (<state>@<time>)"
                );
            };
            let t = parse_sim_time(at)
                .map_err(|e| anyhow!("trace '{spec}': {e}"))?;
            segments.push(TraceSegment::from_net(&state_config(state)?, t));
        }
        LinkTrace::new(spec, segments)
    }

    /// Piecewise approximation of `cycles` raised-cosine rate fades on top
    /// of `base`: within each `period_ns` the serialization rate dips
    /// smoothly from the base rate down to `floor * rate` and back, in
    /// `steps` constant segments per period. Latency/loss/jitter follow
    /// the base channel throughout.
    pub fn fade(
        base: &NetworkConfig,
        floor: f64,
        period_ns: SimTime,
        cycles: usize,
        steps: usize,
    ) -> Result<LinkTrace> {
        if !(0.0..=1.0).contains(&floor) || floor == 0.0 {
            bail!("fade: floor must be in (0, 1], got {floor}");
        }
        if period_ns == 0 || cycles == 0 || steps < 2 {
            bail!("fade: needs period > 0, cycles > 0, steps >= 2");
        }
        let mut segments = Vec::with_capacity(cycles * steps + 1);
        for c in 0..cycles {
            for i in 0..steps {
                let t = c as u64 * period_ns
                    + (i as u64 * period_ns) / steps as u64;
                let phase =
                    2.0 * std::f64::consts::PI * i as f64 / steps as f64;
                let depth = 0.5 * (1.0 - phase.cos()); // 0 → 1 → 0
                let factor = 1.0 - (1.0 - floor) * depth;
                let mut seg = TraceSegment::from_net(base, t);
                seg.capacity_bps *= factor;
                seg.interface_bps *= factor;
                if segments
                    .last()
                    .map(|p: &TraceSegment| p.start_ns)
                    != Some(t)
                {
                    segments.push(seg);
                }
            }
        }
        // Recover the base channel after the last cycle.
        segments.push(TraceSegment::from_net(
            base,
            cycles as u64 * period_ns,
        ));
        LinkTrace::new("fade", segments)
    }

    /// Seeded alternation between `base` and `congested` with
    /// exponentially distributed dwell times (`mean_gap_ns` in the base
    /// state, `mean_burst_ns` congested), out to `total_ns`; the trace
    /// ends in the base state. Deterministic in `seed`.
    pub fn congestion_bursts(
        base: &NetworkConfig,
        congested: &NetworkConfig,
        total_ns: SimTime,
        mean_gap_ns: SimTime,
        mean_burst_ns: SimTime,
        seed: u64,
    ) -> Result<LinkTrace> {
        if total_ns == 0 || mean_gap_ns == 0 || mean_burst_ns == 0 {
            bail!("congestion_bursts: all durations must be > 0");
        }
        let mut rng = Rng::new(seed);
        let mut segments = vec![TraceSegment::from_net(base, 0)];
        let mut t: SimTime = 0;
        loop {
            let gap = (rng.exp(mean_gap_ns as f64).round() as SimTime).max(1);
            t += gap;
            if t >= total_ns {
                break;
            }
            segments.push(TraceSegment::from_net(congested, t));
            let burst =
                (rng.exp(mean_burst_ns as f64).round() as SimTime).max(1);
            t += burst;
            segments.push(TraceSegment::from_net(base, t.min(total_ns)));
            if t >= total_ns {
                break;
            }
        }
        LinkTrace::new("congestion-bursts", segments)
    }
}

/// Resolve one trace-state token: a trace-only preset or a channel spec
/// restricted to link parameters (protocol/seed belong to the channel the
/// trace rides on, not to a point-in-time link state).
fn state_config(tok: &str) -> Result<NetworkConfig> {
    match tok {
        // A heavily congested last-mile: 20 Mb/s, 20 ms, bursty 5% loss.
        "congested" => {
            let mut c = NetworkConfig::gigabit(Protocol::Tcp, 0.05, 0);
            c.capacity_bps = 2e7;
            c.interface_bps = 2e7;
            c.latency_ns = 20_000_000;
            c.loss_model = LossModel::bursty(0.05, 8.0);
            Ok(c)
        }
        // A degraded but usable link: 50 Mb/s, 10 ms, 2% i.i.d. loss.
        "degraded" => {
            let mut c = NetworkConfig::gigabit(Protocol::Tcp, 0.02, 0);
            c.capacity_bps = 5e7;
            c.interface_bps = 5e7;
            c.latency_ns = 10_000_000;
            Ok(c)
        }
        _ => {
            for part in tok.split(':').skip(1) {
                let p = part.to_ascii_lowercase();
                if p == "tcp" || p == "udp" || p.starts_with("seed=") {
                    bail!(
                        "trace state '{tok}': '{part}' is not a link \
                         parameter (protocol and seed belong to the \
                         channel spec, not a trace state)"
                    );
                }
            }
            NetworkConfig::parse(tok)
        }
    }
}

/// Parse a simulated-time literal: a number with an `s`/`ms`/`us`/`ns`
/// suffix, or raw integer nanoseconds.
pub fn parse_sim_time(s: &str) -> Result<SimTime> {
    let s = s.trim();
    let (num, mult) = if let Some(v) = s.strip_suffix("ns") {
        (v, 1.0)
    } else if let Some(v) = s.strip_suffix("us") {
        (v, 1e3)
    } else if let Some(v) = s.strip_suffix("ms") {
        (v, 1e6)
    } else if let Some(v) = s.strip_suffix('s') {
        (v, 1e9)
    } else {
        (s, 1.0)
    };
    let val: f64 = num
        .parse()
        .map_err(|_| anyhow!("bad time '{s}' (number + s|ms|us|ns)"))?;
    if !val.is_finite() || val < 0.0 {
        bail!("bad time '{s}': must be finite and non-negative");
    }
    Ok((val * mult).round() as SimTime)
}

/// Parse a per-hop trace assignment: `hop<N>=<chain>[,hop<M>=<chain>...]`.
/// Commas *inside* a chain (e.g. `burst=0.1,0.9` channel-spec segments)
/// are re-joined onto the preceding group: a new group only starts at a
/// `hop<N>=` token.
pub fn parse_hop_traces(spec: &str) -> Result<Vec<(usize, LinkTrace)>> {
    let mut groups: Vec<String> = Vec::new();
    for tok in spec.split(',') {
        let is_new = tok.starts_with("hop") && tok.contains('=');
        match groups.last_mut() {
            Some(last) if !is_new => {
                last.push(',');
                last.push_str(tok);
            }
            _ => groups.push(tok.to_string()),
        }
    }
    let mut out = Vec::new();
    for g in &groups {
        let Some((hop, chain)) = g.split_once('=') else {
            bail!("trace assignment '{g}': expected hop<N>=<chain>");
        };
        let Some(idx) = hop.strip_prefix("hop") else {
            bail!("trace assignment '{g}': expected hop<N>=<chain>");
        };
        let hop: usize = idx.parse().map_err(|_| {
            anyhow!("trace assignment '{g}': bad hop index '{idx}'")
        })?;
        if out.iter().any(|(h, _)| *h == hop) {
            bail!("trace assignment '{spec}': duplicate hop{hop}");
        }
        out.push((hop, LinkTrace::parse_chain(chain)?));
    }
    if out.is_empty() {
        bail!("empty trace assignment");
    }
    Ok(out)
}

/// Parse a JSON hop-map object (`{"hop0": "<chain>", ...}`) into per-hop
/// traces — the document format of a trace file and of each entry in a
/// trace suite.
pub fn hop_traces_from_json(json: &Json) -> Result<Vec<(usize, LinkTrace)>> {
    let Json::Obj(map) = json else {
        bail!("trace document must be an object mapping hop<N> to a chain");
    };
    let mut out = Vec::new();
    for (k, v) in map {
        let Some(idx) = k.strip_prefix("hop") else {
            bail!("trace document: key '{k}' is not hop<N>");
        };
        let hop: usize = idx
            .parse()
            .map_err(|_| anyhow!("trace document: bad hop index '{k}'"))?;
        out.push((hop, LinkTrace::parse_chain(v.str()?)?));
    }
    if out.is_empty() {
        bail!("trace document assigns no hops");
    }
    out.sort_by_key(|(h, _)| *h);
    Ok(out)
}

/// Resolve a `--trace` argument: either the compact per-hop grammar
/// (`hop0=wifi>congested@2s,...`), a JSON trace file (`file.json`, a
/// hop-map object), or one entry of a trace suite (`file.json#entry`,
/// where the file maps entry names to hop-map objects).
pub fn parse_trace_arg(arg: &str) -> Result<Vec<(usize, LinkTrace)>> {
    let (path, entry) = match arg.split_once('#') {
        Some((p, e)) => (p, Some(e)),
        None => (arg, None),
    };
    if path.ends_with(".json") {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("trace file '{path}': {e}"))?;
        let json = Json::parse(&text)
            .map_err(|e| anyhow!("trace file '{path}': {e}"))?;
        let doc = match entry {
            Some(name) => json.get(name).map_err(|_| {
                anyhow!("trace file '{path}' has no entry '{name}'")
            })?,
            None => &json,
        };
        hop_traces_from_json(doc)
    } else if entry.is_some() {
        bail!("trace '{arg}': #entry selectors only apply to .json files");
    } else {
        parse_hop_traces(arg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chain_parses_states_and_times() {
        let tr = LinkTrace::parse_chain("wifi>congested@2s>wifi@4s").unwrap();
        assert_eq!(tr.segments().len(), 3);
        assert_eq!(tr.segments()[0].rate_bps(), 16e7);
        assert_eq!(tr.segments()[1].start_ns, 2_000_000_000);
        assert_eq!(tr.segments()[1].rate_bps(), 2e7);
        assert_eq!(tr.segments()[2].start_ns, 4_000_000_000);
        assert_eq!(tr.boundaries(), vec![2_000_000_000, 4_000_000_000]);
        assert!(!tr.is_constant());
        assert_eq!(tr.best_rate_bps(), 16e7);
        assert_eq!(tr.worst_rate_bps(), 2e7);
    }

    #[test]
    fn chain_accepts_custom_states_with_at_signs() {
        // The switch time splits at the *last* '@'.
        let tr =
            LinkTrace::parse_chain("gigabit>edge@5e7+100000@1500ms").unwrap();
        assert_eq!(tr.segments()[1].start_ns, 1_500_000_000);
        assert_eq!(tr.segments()[1].rate_bps(), 5e7);
    }

    #[test]
    fn segment_lookup_and_boundaries() {
        let tr = LinkTrace::parse_chain("gigabit>wifi@1000>gigabit@3000")
            .unwrap();
        assert_eq!(tr.segment_at(0).rate_bps(), 1e9);
        assert_eq!(tr.segment_at(999).rate_bps(), 1e9);
        assert_eq!(tr.segment_at(1000).rate_bps(), 16e7);
        assert_eq!(tr.segment_at(2999).rate_bps(), 16e7);
        assert_eq!(tr.segment_at(u64::MAX).rate_bps(), 1e9);
        assert_eq!(tr.next_boundary_after(0), Some(1000));
        assert_eq!(tr.next_boundary_after(1000), Some(3000));
        assert_eq!(tr.next_boundary_after(3000), None);
    }

    #[test]
    fn constant_trace_is_the_identity() {
        let net = NetworkConfig::wifi(Protocol::Udp, 0.01, 7);
        let tr = LinkTrace::constant(&net);
        assert!(tr.is_constant());
        assert!(tr.boundaries().is_empty());
        let s = tr.segment_at(123_456);
        assert_eq!(s.latency_ns, net.latency_ns);
        assert_eq!(s.rate_bps(), 16e7);
        assert_eq!(s.loss_rate, 0.01);
    }

    #[test]
    fn chain_rejects_protocol_seed_and_malformed_times() {
        assert!(LinkTrace::parse_chain("wifi:udp>congested@1s").is_err());
        assert!(LinkTrace::parse_chain("wifi:seed=3").is_err());
        assert!(LinkTrace::parse_chain("wifi>congested").is_err());
        assert!(LinkTrace::parse_chain("wifi>congested@-1s").is_err());
        assert!(LinkTrace::parse_chain("wifi>congested@fast").is_err());
        assert!(LinkTrace::parse_chain("").is_err());
        // Same-time or out-of-order switches are rejected.
        assert!(
            LinkTrace::parse_chain("wifi>congested@1s>wifi@1s").is_err()
        );
        assert!(
            LinkTrace::parse_chain("wifi>congested@2s>wifi@1s").is_err()
        );
        // Link parameters (loss, jitter, burst) are allowed in states.
        assert!(
            LinkTrace::parse_chain("wifi:loss=0.1:jitter=5000").is_ok()
        );
    }

    #[test]
    fn sim_time_suffixes() {
        assert_eq!(parse_sim_time("2s").unwrap(), 2_000_000_000);
        assert_eq!(parse_sim_time("1500ms").unwrap(), 1_500_000_000);
        assert_eq!(parse_sim_time("250us").unwrap(), 250_000);
        assert_eq!(parse_sim_time("42ns").unwrap(), 42);
        assert_eq!(parse_sim_time("1000").unwrap(), 1000);
        assert_eq!(parse_sim_time("0.5s").unwrap(), 500_000_000);
        assert!(parse_sim_time("x").is_err());
        assert!(parse_sim_time("-1s").is_err());
    }

    #[test]
    fn hop_traces_regroup_commas_inside_chains() {
        let got = parse_hop_traces(
            "hop0=wifi:burst=0.1,0.9>congested@2s,hop1=gigabit",
        )
        .unwrap();
        assert_eq!(got.len(), 2);
        assert_eq!(got[0].0, 0);
        assert_eq!(got[0].1.segments().len(), 2);
        assert!(matches!(
            got[0].1.segments()[0].loss_model,
            LossModel::GilbertElliott { .. }
        ));
        assert_eq!(got[1].0, 1);
        assert!(got[1].1.is_constant());
        assert!(parse_hop_traces("hop0=wifi,hop0=gigabit").is_err());
        assert!(parse_hop_traces("wifi").is_err());
        assert!(parse_hop_traces("").is_err());
    }

    #[test]
    fn json_hop_map_parses_and_sorts() {
        let j = Json::parse(
            r#"{"hop1": "gigabit", "hop0": "wifi>congested@2s"}"#,
        )
        .unwrap();
        let got = hop_traces_from_json(&j).unwrap();
        assert_eq!(got[0].0, 0);
        assert_eq!(got[0].1.segments().len(), 2);
        assert_eq!(got[1].0, 1);
        assert!(hop_traces_from_json(&Json::parse("{}").unwrap()).is_err());
        assert!(hop_traces_from_json(
            &Json::parse(r#"{"link0": "wifi"}"#).unwrap()
        )
        .is_err());
    }

    #[test]
    fn fade_dips_and_recovers() {
        let base = NetworkConfig::gigabit(Protocol::Tcp, 0.0, 0);
        let tr = LinkTrace::fade(&base, 0.2, 1_000_000, 2, 8).unwrap();
        assert!(tr.segments().len() > 8);
        assert_eq!(tr.segments()[0].rate_bps(), 1e9);
        let worst = tr.worst_rate_bps();
        assert!(
            worst < 0.25 * 1e9 && worst > 0.199 * 1e9,
            "fade floor missed: {worst}"
        );
        // Ends back at the base rate.
        assert_eq!(tr.segments().last().unwrap().rate_bps(), 1e9);
        assert_eq!(tr.best_rate_bps(), 1e9);
        assert!(LinkTrace::fade(&base, 0.0, 1, 1, 8).is_err());
        assert!(LinkTrace::fade(&base, 0.5, 0, 1, 8).is_err());
    }

    #[test]
    fn congestion_bursts_alternate_deterministically() {
        let base = NetworkConfig::gigabit(Protocol::Tcp, 0.0, 0);
        let bad = state_config("congested").unwrap();
        let a = LinkTrace::congestion_bursts(
            &base, &bad, 10_000_000, 1_000_000, 300_000, 11,
        )
        .unwrap();
        let b = LinkTrace::congestion_bursts(
            &base, &bad, 10_000_000, 1_000_000, 300_000, 11,
        )
        .unwrap();
        assert_eq!(a, b, "same seed must give the same trace");
        assert!(a.segments().len() >= 3);
        assert_eq!(a.segments()[0].rate_bps(), 1e9);
        assert!(a.worst_rate_bps() < 1e9);
        let c = LinkTrace::congestion_bursts(
            &base, &bad, 10_000_000, 1_000_000, 300_000, 12,
        )
        .unwrap();
        assert_ne!(a, c, "different seeds diverge");
    }
}
