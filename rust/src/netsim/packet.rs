//! Packetization: MTU, protocol header overheads, packet descriptors.

/// Standard Ethernet MTU (bytes of IP payload).
pub const MTU: u32 = 1500;
/// IPv4 (20) + TCP (20) header bytes.
pub const TCP_HEADER: u32 = 40;
/// IPv4 (20) + UDP (8) header bytes.
pub const UDP_HEADER: u32 = 28;
/// TCP maximum segment size under the default MTU.
pub const TCP_MSS: u32 = MTU - TCP_HEADER;
/// UDP maximum datagram payload under the default MTU.
pub const UDP_MAX_PAYLOAD: u32 = MTU - UDP_HEADER;

/// Direction over the full-duplex channel.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dir {
    /// Edge device -> server (uplink).
    Up,
    /// Server -> edge device (downlink).
    Down,
}

impl Dir {
    pub fn flip(self) -> Dir {
        match self {
            Dir::Up => Dir::Down,
            Dir::Down => Dir::Up,
        }
    }
}

/// One simulated packet (data segment, datagram or ACK).
#[derive(Clone, Debug)]
pub struct Packet {
    /// First payload byte offset within the application message.
    pub offset: u64,
    /// Payload bytes (0 for a pure ACK).
    pub payload: u32,
    /// Header bytes on the wire.
    pub header: u32,
    /// Cumulative acknowledgement number (TCP ACKs).
    pub ack_no: u64,
    /// True when this is a retransmission (Karn: no RTT sample).
    pub retransmit: bool,
    /// Send timestamp for RTT sampling.
    pub sent_at: super::event::SimTime,
}

impl Packet {
    pub fn wire_bytes(&self) -> u32 {
        self.payload + self.header
    }

    pub fn data(offset: u64, payload: u32, now: super::event::SimTime) -> Self {
        Packet {
            offset,
            payload,
            header: TCP_HEADER,
            ack_no: 0,
            retransmit: false,
            sent_at: now,
        }
    }

    pub fn ack(ack_no: u64, now: super::event::SimTime) -> Self {
        Packet {
            offset: 0,
            payload: 0,
            header: TCP_HEADER,
            ack_no,
            retransmit: false,
            sent_at: now,
        }
    }

    pub fn datagram(offset: u64, payload: u32,
                    now: super::event::SimTime) -> Self {
        Packet {
            offset,
            payload,
            header: UDP_HEADER,
            ack_no: 0,
            retransmit: false,
            sent_at: now,
        }
    }
}

/// Split a message of `len` bytes into (offset, payload) segments of at
/// most `max_payload` each, lazily. The UDP fast path walks this
/// directly so the steady-state serve loop stays allocation-free; TCP
/// collects it (retransmission needs random access).
#[inline]
pub fn segment_iter(
    len: u64,
    max_payload: u32,
) -> impl Iterator<Item = (u64, u32)> {
    assert!(max_payload > 0);
    let mut off = 0u64;
    std::iter::from_fn(move || {
        if off >= len {
            return None;
        }
        let p = (len - off).min(max_payload as u64) as u32;
        let seg = (off, p);
        off += p as u64;
        Some(seg)
    })
}

/// [`segment_iter`], collected.
pub fn segment(len: u64, max_payload: u32) -> Vec<(u64, u32)> {
    let mut out =
        Vec::with_capacity(len.div_ceil(max_payload as u64) as usize);
    out.extend(segment_iter(len, max_payload));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn header_sizes() {
        assert_eq!(TCP_MSS, 1460);
        assert_eq!(UDP_MAX_PAYLOAD, 1472);
    }

    #[test]
    fn segment_exact_multiple() {
        let segs = segment(2920, TCP_MSS);
        assert_eq!(segs, vec![(0, 1460), (1460, 1460)]);
    }

    #[test]
    fn segment_remainder() {
        let segs = segment(3000, TCP_MSS);
        assert_eq!(segs, vec![(0, 1460), (1460, 1460), (2920, 80)]);
    }

    #[test]
    fn segment_small_message() {
        assert_eq!(segment(1, TCP_MSS), vec![(0, 1)]);
        assert_eq!(segment(0, TCP_MSS), vec![]);
    }

    #[test]
    fn segment_covers_every_byte_once() {
        for len in [1u64, 7, 1460, 1461, 99_999] {
            let segs = segment(len, TCP_MSS);
            let total: u64 = segs.iter().map(|(_, p)| *p as u64).sum();
            assert_eq!(total, len);
            let mut expect = 0u64;
            for (off, p) in segs {
                assert_eq!(off, expect);
                expect += p as u64;
            }
        }
    }

    #[test]
    fn segment_iter_matches_collected_segment() {
        for len in [0u64, 1, 7, 1460, 1461, 2920, 99_999] {
            let lazy: Vec<(u64, u32)> =
                segment_iter(len, TCP_MSS).collect();
            assert_eq!(lazy, segment(len, TCP_MSS), "len {len}");
        }
    }

    #[test]
    fn dir_flip() {
        assert_eq!(Dir::Up.flip(), Dir::Down);
        assert_eq!(Dir::Down.flip(), Dir::Up);
    }

    #[test]
    fn wire_bytes() {
        let p = Packet::data(0, 100, 0);
        assert_eq!(p.wire_bytes(), 140);
        let a = Packet::ack(5, 0);
        assert_eq!(a.wire_bytes(), 40);
    }
}
