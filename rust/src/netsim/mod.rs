//! Communication-aware discrete-event network simulator (the paper's
//! *netsim* layer; an SCNSL-analogue built from scratch in Rust).
//!
//! Layering:
//!   [`event`]    — virtual clock + time-ordered event queue;
//!   [`packet`]   — MTU/header/segmentation;
//!   [`link`]     — one direction: serialization, propagation, saboteur;
//!   [`tcp`]      — reliable transport (Reno: slow start, AIMD, fast
//!                  retransmit, RTO + backoff);
//!   [`udp`]      — unreliable datagrams with loss reporting;
//!   [`trace`]    — [`trace::LinkTrace`]: piecewise time-varying link
//!                  schedules (fading, congestion bursts, handoffs);
//!   [`transfer`] — [`transfer::Channel`]: the full-duplex message API the
//!                  scenario engine drives.

pub mod event;
pub mod link;
pub mod packet;
pub mod tcp;
pub mod trace;
pub mod transfer;
pub mod udp;

pub use event::{from_secs, secs, QueueKind, SimTime};
pub use packet::Dir;
pub use trace::{LinkTrace, TraceSegment};
pub use transfer::{Channel, NetworkConfig, Protocol, TransferResult};
