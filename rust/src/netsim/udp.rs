//! UDP transport model: fire-and-forget datagrams.
//!
//! No error checking or recovery (paper Sec. V-C): latency is loss-rate
//! independent, but lost datagrams leave holes in the received message —
//! the coordinator maps those holes onto tensor corruption and measures the
//! accuracy impact (Fig. 4-left).

use super::event::SimTime;
use super::link::Link;
use super::packet::{segment_iter, Packet, UDP_MAX_PAYLOAD};

#[derive(Clone, Debug)]
pub struct UdpConfig {
    pub max_payload: u32,
}

impl Default for UdpConfig {
    fn default() -> Self {
        UdpConfig { max_payload: UDP_MAX_PAYLOAD }
    }
}

#[derive(Clone, Copy, Debug, Default)]
pub struct UdpMessageStats {
    pub datagrams_sent: u64,
    pub datagrams_lost: u64,
    pub wire_bytes: u64,
}

#[derive(Clone, Debug)]
pub struct UdpMessageResult {
    /// Time from hand-off until the last datagram's nominal arrival slot:
    /// the receiver's frame deadline. Independent of the saboteur.
    pub latency_ns: SimTime,
    /// Sender-side occupancy: time from hand-off until the last datagram
    /// clears the interface (serialization only — datagrams of the next
    /// message can pipeline over this one's propagation delay).
    pub tx_end_ns: SimTime,
    /// Byte ranges (offset, len) of the message that never arrived.
    pub lost_ranges: Vec<(u64, u32)>,
    pub stats: UdpMessageStats,
}

impl UdpMessageResult {
    pub fn lost_bytes(&self) -> u64 {
        self.lost_ranges.iter().map(|(_, l)| *l as u64).sum()
    }

    pub fn delivered_fraction(&self, len: u64) -> f64 {
        1.0 - self.lost_bytes() as f64 / len as f64
    }
}

/// Send one message as a burst of datagrams at absolute time `start`.
pub fn send_message(
    cfg: &UdpConfig,
    link: &mut Link,
    len: u64,
    start: SimTime,
) -> UdpMessageResult {
    assert!(len > 0, "empty message");
    let mut stats = UdpMessageStats::default();
    let mut lost = Vec::new();
    let mut last_arrival = start;
    let mut last_tx = start;
    // Lazy segmentation: a lossless send performs zero heap allocations
    // (`lost` stays an unallocated empty Vec), which the steady-state
    // serve loop's `alloc-count` smoke depends on.
    for (offset, payload) in segment_iter(len, cfg.max_payload) {
        let pkt = Packet::datagram(offset, payload, start);
        let out = link.send(start, pkt.wire_bytes());
        stats.datagrams_sent += 1;
        stats.wire_bytes += pkt.wire_bytes() as u64;
        last_arrival = last_arrival.max(out.arrival);
        last_tx = last_tx.max(out.tx_done);
        if out.dropped {
            stats.datagrams_lost += 1;
            lost.push((offset, payload));
        }
    }
    UdpMessageResult {
        latency_ns: last_arrival - start,
        tx_end_ns: last_tx - start,
        lost_ranges: lost,
        stats,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::link::LinkConfig;
    use crate::util::rng::Rng;

    fn link(loss: f64, seed: u64) -> Link {
        Link::new(
            LinkConfig::basic(100_000, 1e9, loss),
            Rng::new(seed),
        )
    }

    #[test]
    fn lossless_delivers_everything() {
        let r = send_message(&UdpConfig::default(), &mut link(0.0, 0),
                             50_000, 0);
        assert!(r.lost_ranges.is_empty());
        assert_eq!(r.delivered_fraction(50_000), 1.0);
        assert_eq!(r.stats.datagrams_sent, 34);
    }

    #[test]
    fn latency_is_serialization_plus_propagation() {
        // one datagram: 1028 B wire @ 1 Gb/s = 8.224 µs + 100 µs
        let r = send_message(&UdpConfig::default(), &mut link(0.0, 0),
                             1000, 0);
        assert_eq!(r.latency_ns, 108_224);
    }

    #[test]
    fn latency_independent_of_loss() {
        let l0 = send_message(&UdpConfig::default(), &mut link(0.0, 1),
                              100_000, 0).latency_ns;
        let l30 = send_message(&UdpConfig::default(), &mut link(0.3, 1),
                               100_000, 0).latency_ns;
        assert_eq!(l0, l30);
    }

    #[test]
    fn loss_fraction_tracks_saboteur() {
        let len = 2_000_000u64;
        let r = send_message(&UdpConfig::default(), &mut link(0.1, 2),
                             len, 0);
        let f = r.delivered_fraction(len);
        assert!((f - 0.9).abs() < 0.03, "{f}");
    }

    #[test]
    fn lost_ranges_are_within_message() {
        let len = 500_000u64;
        let r = send_message(&UdpConfig::default(), &mut link(0.5, 3),
                             len, 0);
        for (off, l) in &r.lost_ranges {
            assert!(off + *l as u64 <= len);
        }
        assert_eq!(
            r.lost_bytes(),
            r.lost_ranges.iter().map(|(_, l)| *l as u64).sum::<u64>()
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let a = send_message(&UdpConfig::default(), &mut link(0.2, 4),
                             300_000, 0);
        let b = send_message(&UdpConfig::default(), &mut link(0.2, 4),
                             300_000, 0);
        assert_eq!(a.lost_ranges, b.lost_ranges);
    }
}
