//! Channel: the full-duplex link pair + persistent transport state, with a
//! message-level API the scenario engine drives (XMTR/RCVR in the paper's
//! architecture).

use anyhow::{anyhow, bail, Result};

use super::event::SimTime;
use super::link::{Link, LinkConfig, LinkStats, LossModel};
use super::packet::Dir;
use super::trace::LinkTrace;
use super::tcp::{self, TcpConfig, TcpMessageResult, TcpState};
use super::udp::{self, UdpConfig, UdpMessageResult};
use crate::util::rng::Rng;

/// Transport layer protocol (paper Sec. IV, input 1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Protocol {
    Tcp,
    Udp,
}

impl Protocol {
    pub fn parse(s: &str) -> Result<Protocol> {
        match s.to_ascii_lowercase().as_str() {
            "tcp" => Ok(Protocol::Tcp),
            "udp" => Ok(Protocol::Udp),
            _ => Err(anyhow!("unknown protocol '{s}' (tcp|udp)")),
        }
    }
}

impl std::fmt::Display for Protocol {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            Protocol::Tcp => "TCP",
            Protocol::Udp => "UDP",
        })
    }
}

/// The five network-modeling inputs of the paper's simulator (Sec. IV).
#[derive(Clone, Debug)]
pub struct NetworkConfig {
    pub protocol: Protocol,
    /// Channel latency (propagation), ns. Paper example: 100 µs.
    pub latency_ns: SimTime,
    /// Channel capacity, bits/s.
    pub capacity_bps: f64,
    /// Interface speed, bits/s (1000 Mb/s GbE, 100 Mb/s FE, 160 Mb/s Wi-Fi).
    pub interface_bps: f64,
    /// Saboteur loss rate in [0, 1).
    pub loss_rate: f64,
    /// Loss distribution (i.i.d. saboteur or Gilbert-Elliott bursts).
    pub loss_model: LossModel,
    /// Per-packet propagation jitter bound, ns.
    pub jitter_ns: SimTime,
    pub tcp: TcpConfig,
    pub udp: UdpConfig,
    pub seed: u64,
    /// Optional time-varying schedule for both link directions. `None`
    /// (and any constant trace) reproduces the static fields above
    /// byte-identically; a multi-segment trace overrides the link-level
    /// fields per [`super::trace::TraceSegment`] at send time.
    pub trace: Option<LinkTrace>,
}

impl NetworkConfig {
    /// The paper's evaluation channel: 1 Gigabit full-duplex, 100 µs.
    pub fn gigabit(protocol: Protocol, loss_rate: f64, seed: u64) -> Self {
        NetworkConfig {
            protocol,
            latency_ns: 100_000,
            capacity_bps: 1e9,
            interface_bps: 1e9,
            loss_rate,
            loss_model: LossModel::Iid,
            jitter_ns: 0,
            tcp: TcpConfig::default(),
            udp: UdpConfig::default(),
            seed,
            trace: None,
        }
    }

    pub fn fast_ethernet(protocol: Protocol, loss_rate: f64, seed: u64) -> Self {
        let mut c = Self::gigabit(protocol, loss_rate, seed);
        c.capacity_bps = 1e8;
        c.interface_bps = 1e8;
        c
    }

    pub fn wifi(protocol: Protocol, loss_rate: f64, seed: u64) -> Self {
        let mut c = Self::gigabit(protocol, loss_rate, seed);
        c.capacity_bps = 16e7;
        c.interface_bps = 16e7;
        c.latency_ns = 2_000_000; // 2 ms
        c
    }

    /// Parse a channel spec string:
    /// `<base>[:tcp|udp][:loss=<f>][:seed=<u64>][:jitter=<ns>][:burst=<p_enter>,<p_exit>]`
    /// where `<base>` is a built-in preset name (`gigabit | fast-ethernet |
    /// wifi`) or a custom `name@<bw_bps>+<lat_ns>` pair (bandwidth accepts
    /// scientific notation and sets both capacity and interface speed;
    /// latency is integer nanoseconds, split at the *last* `+` so
    /// explicit-plus exponents like `radio@5e+7+3000000` work). The
    /// trailing segments may appear in any order; defaults are TCP,
    /// loss 0, seed 0, jitter 0, i.i.d. loss. `jitter=<ns>` bounds the
    /// per-packet propagation jitter; `burst=<p_enter>,<p_exit>` switches
    /// the saboteur to a Gilbert-Elliott burst model with the given
    /// per-packet state-transition probabilities (bad-state loss 1).
    /// Examples: `wifi:udp:loss=0.01:seed=7`, `gigabit:tcp`,
    /// `radio@5e7+3000000:udp`, `wifi:jitter=200000:burst=0.02,0.25`.
    ///
    /// This is the one parse path behind CLI `--net` / `--hop-nets`, the
    /// sweep spec's `hop_nets` axis, and `FleetSpec` links — the channel
    /// twin of [`crate::model::DeviceProfile::parse`].
    pub fn parse(spec: &str) -> Result<NetworkConfig> {
        let mut parts = spec.split(':');
        let base = parts.next().unwrap_or("");
        let mut cfg = match base {
            "gigabit" => Self::gigabit(Protocol::Tcp, 0.0, 0),
            "fast-ethernet" => Self::fast_ethernet(Protocol::Tcp, 0.0, 0),
            "wifi" => Self::wifi(Protocol::Tcp, 0.0, 0),
            _ => {
                let Some((name, rest)) = base.split_once('@') else {
                    bail!(
                        "unknown channel '{base}' in '{spec}' (built-ins: \
                         gigabit | fast-ethernet | wifi; custom: \
                         name@<bw_bps>+<lat_ns>)"
                    );
                };
                if name.is_empty() {
                    bail!("custom channel '{spec}' has an empty name");
                }
                let Some((bw, lat)) = rest.rsplit_once('+') else {
                    bail!(
                        "custom channel '{spec}' must be \
                         name@<bw_bps>+<lat_ns>"
                    );
                };
                let bw_bps: f64 = bw.parse().map_err(|_| {
                    anyhow!("custom channel '{spec}': bad bandwidth '{bw}'")
                })?;
                if !bw_bps.is_finite() || bw_bps <= 0.0 {
                    bail!("custom channel '{spec}': bandwidth must be positive");
                }
                let lat_ns: SimTime = lat.parse().map_err(|_| {
                    anyhow!(
                        "custom channel '{spec}': bad latency '{lat}' \
                         (integer ns)"
                    )
                })?;
                let mut c = Self::gigabit(Protocol::Tcp, 0.0, 0);
                c.capacity_bps = bw_bps;
                c.interface_bps = bw_bps;
                c.latency_ns = lat_ns;
                c
            }
        };
        let (mut saw_proto, mut saw_loss, mut saw_seed) =
            (false, false, false);
        let (mut saw_jitter, mut saw_burst) = (false, false);
        for part in parts {
            if let Some(v) = part.strip_prefix("loss=") {
                if saw_loss {
                    bail!("channel '{spec}': duplicate loss= segment");
                }
                saw_loss = true;
                let loss: f64 = v.parse().map_err(|_| {
                    anyhow!("channel '{spec}': bad loss '{v}'")
                })?;
                if !(0.0..1.0).contains(&loss) {
                    bail!("channel '{spec}': loss must be in [0, 1)");
                }
                cfg.loss_rate = loss;
            } else if let Some(v) = part.strip_prefix("seed=") {
                if saw_seed {
                    bail!("channel '{spec}': duplicate seed= segment");
                }
                saw_seed = true;
                cfg.seed = v.parse().map_err(|_| {
                    anyhow!("channel '{spec}': bad seed '{v}' (integer)")
                })?;
            } else if let Some(v) = part.strip_prefix("jitter=") {
                if saw_jitter {
                    bail!("channel '{spec}': duplicate jitter= segment");
                }
                saw_jitter = true;
                cfg.jitter_ns = v.parse().map_err(|_| {
                    anyhow!(
                        "channel '{spec}': bad jitter '{v}' (integer ns)"
                    )
                })?;
            } else if let Some(v) = part.strip_prefix("burst=") {
                if saw_burst {
                    bail!("channel '{spec}': duplicate burst= segment");
                }
                saw_burst = true;
                let Some((enter, exit)) = v.split_once(',') else {
                    bail!(
                        "channel '{spec}': burst needs \
                         <p_enter>,<p_exit>, got '{v}'"
                    );
                };
                let p_gb: f64 = enter.parse().map_err(|_| {
                    anyhow!("channel '{spec}': bad burst p_enter '{enter}'")
                })?;
                let p_bg: f64 = exit.parse().map_err(|_| {
                    anyhow!("channel '{spec}': bad burst p_exit '{exit}'")
                })?;
                if !(p_gb > 0.0 && p_gb < 1.0) {
                    bail!(
                        "channel '{spec}': burst p_enter must be in (0, 1)"
                    );
                }
                if !(p_bg > 0.0 && p_bg <= 1.0) {
                    bail!(
                        "channel '{spec}': burst p_exit must be in (0, 1]"
                    );
                }
                cfg.loss_model =
                    LossModel::GilbertElliott { p_gb, p_bg, bad_loss: 1.0 };
            } else {
                if saw_proto {
                    bail!("channel '{spec}': duplicate protocol segment");
                }
                saw_proto = true;
                cfg.protocol = Protocol::parse(part).map_err(|_| {
                    anyhow!(
                        "channel '{spec}': unknown segment '{part}' \
                         (expected tcp | udp | loss=<f> | seed=<u64> | \
                         jitter=<ns> | burst=<p_enter>,<p_exit>)"
                    )
                })?;
            }
        }
        Ok(cfg)
    }

    /// Attach a time-varying schedule (builder form).
    pub fn with_trace(mut self, trace: LinkTrace) -> Self {
        self.trace = Some(trace);
        self
    }

    /// The best-case serialization rate this channel can ever offer: the
    /// maximum over the attached trace's segments, or the plain
    /// capacity/interface bound without one. Admission and placement
    /// bounds use this so a stream rejected under a time-varying channel
    /// is provably unservable even in the trace's best segment.
    pub fn best_rate_bps(&self) -> f64 {
        match &self.trace {
            Some(tr) => tr.best_rate_bps(),
            None => {
                let mut rate = self.capacity_bps;
                if self.interface_bps > 0.0 {
                    rate = rate.min(self.interface_bps);
                }
                rate
            }
        }
    }

    fn link_config(&self) -> LinkConfig {
        LinkConfig {
            latency_ns: self.latency_ns,
            capacity_bps: self.capacity_bps,
            interface_bps: self.interface_bps,
            loss_rate: self.loss_rate,
            loss_model: self.loss_model,
            jitter_ns: self.jitter_ns,
        }
    }
}

impl std::fmt::Display for NetworkConfig {
    /// Canonical channel spec string, re-parseable by
    /// [`NetworkConfig::parse`]: a built-in preset name when bandwidth and
    /// latency match one (interface speed equal to capacity), else
    /// `custom@<bw_bps>+<lat_ns>`, always followed by the protocol, loss
    /// and seed segments; non-zero jitter renders as `:jitter=<ns>` and a
    /// bursty saboteur (Gilbert-Elliott with bad-state loss 1) as
    /// `:burst=<p_enter>,<p_exit>`. Fields the spec grammar cannot express
    /// (a Gilbert-Elliott bad-state loss below 1, transport tuning,
    /// attached traces) are not rendered.
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let symmetric = self.interface_bps == self.capacity_bps;
        if symmetric && self.capacity_bps == 1e9 && self.latency_ns == 100_000
        {
            f.write_str("gigabit")?;
        } else if symmetric
            && self.capacity_bps == 1e8
            && self.latency_ns == 100_000
        {
            f.write_str("fast-ethernet")?;
        } else if symmetric
            && self.capacity_bps == 16e7
            && self.latency_ns == 2_000_000
        {
            f.write_str("wifi")?;
        } else {
            write!(f, "custom@{}+{}", self.capacity_bps, self.latency_ns)?;
        }
        let proto = match self.protocol {
            Protocol::Tcp => "tcp",
            Protocol::Udp => "udp",
        };
        write!(f, ":{proto}:loss={}:seed={}", self.loss_rate, self.seed)?;
        if self.jitter_ns != 0 {
            write!(f, ":jitter={}", self.jitter_ns)?;
        }
        if let LossModel::GilbertElliott { p_gb, p_bg, bad_loss } =
            self.loss_model
        {
            if bad_loss == 1.0 {
                write!(f, ":burst={p_gb},{p_bg}")?;
            }
        }
        Ok(())
    }
}

/// Result of one application-message transfer.
#[derive(Clone, Debug)]
pub enum TransferResult {
    Tcp(TcpMessageResult),
    Udp(UdpMessageResult),
}

impl TransferResult {
    /// Latency until the receiver considers the message complete.
    pub fn latency_ns(&self) -> SimTime {
        match self {
            TransferResult::Tcp(r) => r.delivery_latency_ns,
            TransferResult::Udp(r) => r.latency_ns,
        }
    }

    /// Byte ranges lost in flight (empty for TCP — reliable delivery).
    pub fn lost_ranges(&self) -> &[(u64, u32)] {
        match self {
            TransferResult::Tcp(_) => &[],
            TransferResult::Udp(r) => &r.lost_ranges,
        }
    }

    pub fn wire_bytes(&self) -> u64 {
        match self {
            TransferResult::Tcp(r) => r.stats.wire_bytes,
            TransferResult::Udp(r) => r.stats.wire_bytes,
        }
    }

    pub fn retransmits(&self) -> u64 {
        match self {
            TransferResult::Tcp(r) => r.stats.retransmits,
            TransferResult::Udp(_) => 0,
        }
    }

    /// The legacy clock convention of [`Channel::send`]: until the last
    /// byte is acknowledged for TCP, until the last datagram's arrival
    /// slot for UDP.
    pub fn busy_ns(&self) -> SimTime {
        match self {
            TransferResult::Tcp(r) => r.ack_latency_ns,
            TransferResult::Udp(r) => r.latency_ns,
        }
    }

    /// Sender-side occupancy: how long this message ties up its sending
    /// endpoint — until the last byte is acknowledged for TCP (the stream
    /// cannot pipeline a second application message into an unacked one
    /// in this model), but only until the last datagram clears the
    /// interface for UDP (fire-and-forget datagrams of the next message
    /// pipeline over this one's propagation delay). This is the queueing
    /// discipline [`Channel::send_no_earlier`] gates on.
    pub fn sender_busy_ns(&self) -> SimTime {
        match self {
            TransferResult::Tcp(r) => r.ack_latency_ns,
            TransferResult::Udp(r) => r.tx_end_ns,
        }
    }
}

/// Full-duplex channel with persistent per-direction transport state.
pub struct Channel {
    pub cfg: NetworkConfig,
    up: Link,
    down: Link,
    tcp_up: TcpState,
    tcp_down: TcpState,
    now: SimTime,
    /// Per-direction message-level occupancy, maintained by
    /// [`Channel::send_no_earlier`]: a direction carries one application
    /// message at a time, so a new message queues behind the previous
    /// one's completion in *its* direction only (full-duplex: an uplink
    /// transfer does not block a concurrent downlink one).
    busy_up: SimTime,
    busy_down: SimTime,
    transfers: u64,
}

impl Channel {
    pub fn new(cfg: NetworkConfig) -> Self {
        let mut rng = Rng::new(cfg.seed);
        let lcfg = cfg.link_config();
        let mut up = Link::new(lcfg.clone(), rng.fork());
        let mut down = Link::new(lcfg, rng.fork());
        if let Some(tr) = &cfg.trace {
            up.set_trace(Some(tr.clone()));
            down.set_trace(Some(tr.clone()));
        }
        Channel {
            tcp_up: TcpState::new(&cfg.tcp),
            tcp_down: TcpState::new(&cfg.tcp),
            cfg,
            up,
            down,
            now: 0,
            busy_up: 0,
            busy_down: 0,
            transfers: 0,
        }
    }

    /// The attached time-varying schedule, if any.
    pub fn trace(&self) -> Option<&LinkTrace> {
        self.cfg.trace.as_ref()
    }

    pub fn now(&self) -> SimTime {
        self.now
    }

    pub fn transfers(&self) -> u64 {
        self.transfers
    }

    /// Advance the channel clock to absolute time `t` (inter-frame gaps).
    pub fn advance_to(&mut self, t: SimTime) {
        self.now = self.now.max(t);
    }

    /// Send `len` bytes in `dir` starting no earlier than the channel's
    /// current time; advances the channel clock past the transfer.
    pub fn send(&mut self, dir: Dir, len: u64) -> Result<TransferResult> {
        let start = self.now;
        let r = self.transfer_at(dir, len, start)?;
        self.now = start + r.busy_ns();
        Ok(r)
    }

    /// Send `len` bytes in `dir` starting at `earliest` — or as soon as
    /// the channel can take the message, whichever is later: the
    /// message-level FIFO queueing discipline the closed-loop streaming
    /// engine models. Returns the actual start time with the transfer
    /// result.
    ///
    /// **UDP** is fire-and-forget with no reverse traffic, so the two
    /// directions are fully independent (true full duplex): an uplink
    /// message never delays a downlink one. **TCP** messages, by
    /// contrast, serialize across the *whole* channel: a TCP message's
    /// ACK stream rides the opposite-direction link, entangling the two
    /// directions — starting a downlink message while an uplink one is
    /// still collecting ACKs would interleave with wire traffic this
    /// message-level model computes atomically (and the legacy engine
    /// serialized through its single clock in exactly the same way).
    pub fn send_no_earlier(
        &mut self,
        dir: Dir,
        len: u64,
        earliest: SimTime,
    ) -> Result<(SimTime, TransferResult)> {
        let gate = match self.cfg.protocol {
            Protocol::Tcp => self.busy_up.max(self.busy_down),
            Protocol::Udp => match dir {
                Dir::Up => self.busy_up,
                Dir::Down => self.busy_down,
            },
        };
        let start = earliest.max(gate);
        let r = self.transfer_at(dir, len, start)?;
        self.now = self.now.max(start + r.sender_busy_ns());
        Ok((start, r))
    }

    /// When `dir` is free for the next message (message-level occupancy;
    /// for TCP both directions advance together, see
    /// [`Channel::send_no_earlier`]).
    pub fn busy_until(&self, dir: Dir) -> SimTime {
        match dir {
            Dir::Up => self.busy_up,
            Dir::Down => self.busy_down,
        }
    }

    fn transfer_at(
        &mut self,
        dir: Dir,
        len: u64,
        start: SimTime,
    ) -> Result<TransferResult> {
        self.transfers += 1;
        let r = match self.cfg.protocol {
            Protocol::Tcp => {
                let (data, ack, state) = match dir {
                    Dir::Up => {
                        (&mut self.up, &mut self.down, &mut self.tcp_up)
                    }
                    Dir::Down => {
                        (&mut self.down, &mut self.up, &mut self.tcp_down)
                    }
                };
                let res = tcp::send_message(
                    &self.cfg.tcp, state, data, ack, len, start,
                )
                .map_err(|e| anyhow!(e))?;
                TransferResult::Tcp(res)
            }
            Protocol::Udp => {
                let link = match dir {
                    Dir::Up => &mut self.up,
                    Dir::Down => &mut self.down,
                };
                let res = udp::send_message(&self.cfg.udp, link, len, start);
                TransferResult::Udp(res)
            }
        };
        let busy = start + r.sender_busy_ns();
        match self.cfg.protocol {
            // TCP: the ACK stream occupied both links — the channel frees
            // as a whole.
            Protocol::Tcp => {
                self.busy_up = self.busy_up.max(busy);
                self.busy_down = self.busy_down.max(busy);
            }
            Protocol::Udp => match dir {
                Dir::Up => self.busy_up = self.busy_up.max(busy),
                Dir::Down => self.busy_down = self.busy_down.max(busy),
            },
        }
        Ok(r)
    }

    pub fn link_stats(&self, dir: Dir) -> LinkStats {
        match dir {
            Dir::Up => self.up.stats,
            Dir::Down => self.down.stats,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn protocol_parse() {
        assert_eq!(Protocol::parse("tcp").unwrap(), Protocol::Tcp);
        assert_eq!(Protocol::parse("UDP").unwrap(), Protocol::Udp);
        assert!(Protocol::parse("sctp").is_err());
    }

    #[test]
    fn tcp_channel_sends_reliably() {
        let mut ch = Channel::new(NetworkConfig::gigabit(
            Protocol::Tcp, 0.05, 42,
        ));
        let r = ch.send(Dir::Up, 100_000).unwrap();
        assert!(r.lost_ranges().is_empty());
        assert!(r.latency_ns() > 0);
        assert!(ch.now() > 0);
    }

    #[test]
    fn udp_channel_reports_losses() {
        let mut ch = Channel::new(NetworkConfig::gigabit(
            Protocol::Udp, 0.3, 42,
        ));
        let r = ch.send(Dir::Up, 1_000_000).unwrap();
        assert!(!r.lost_ranges().is_empty());
    }

    #[test]
    fn directions_have_independent_streams() {
        let mut ch = Channel::new(NetworkConfig::gigabit(
            Protocol::Udp, 0.2, 7,
        ));
        let up = ch.send(Dir::Up, 500_000).unwrap();
        let down = ch.send(Dir::Down, 500_000).unwrap();
        assert_ne!(up.lost_ranges(), down.lost_ranges());
    }

    #[test]
    fn clock_advances_across_transfers() {
        let mut ch = Channel::new(NetworkConfig::gigabit(
            Protocol::Tcp, 0.0, 1,
        ));
        ch.send(Dir::Up, 10_000).unwrap();
        let t1 = ch.now();
        ch.advance_to(t1 + 1_000_000);
        ch.send(Dir::Up, 10_000).unwrap();
        assert!(ch.now() >= t1 + 1_000_000);
    }

    #[test]
    fn send_no_earlier_udp_directions_are_independent() {
        let mut ch = Channel::new(NetworkConfig::gigabit(
            Protocol::Udp, 0.0, 1,
        ));
        let (s1, r1) = ch.send_no_earlier(Dir::Up, 10_000, 0).unwrap();
        assert_eq!(s1, 0);
        // A second uplink message requested at t=0 queues behind the
        // first message's last datagram clearing the interface (not its
        // arrival: UDP pipelines over the propagation delay)…
        let (s2, _) = ch.send_no_earlier(Dir::Up, 10_000, 0).unwrap();
        assert_eq!(s2, r1.sender_busy_ns());
        assert!(r1.sender_busy_ns() < r1.busy_ns(), "tx ends before arrival");
        assert!(ch.busy_until(Dir::Up) > s2);
        // …and the downlink direction is independent (full duplex: UDP
        // has no reverse traffic).
        let (s3, _) = ch.send_no_earlier(Dir::Down, 10_000, 0).unwrap();
        assert_eq!(s3, 0);
    }

    #[test]
    fn send_no_earlier_tcp_serializes_the_channel() {
        // A TCP message's ACKs ride the opposite link, so messages
        // serialize across the whole channel regardless of direction.
        let mut ch = Channel::new(NetworkConfig::gigabit(
            Protocol::Tcp, 0.0, 1,
        ));
        let (s1, r1) = ch.send_no_earlier(Dir::Up, 10_000, 0).unwrap();
        assert_eq!(s1, 0);
        let (s2, _) = ch.send_no_earlier(Dir::Down, 10_000, 0).unwrap();
        assert_eq!(s2, r1.sender_busy_ns());
    }

    #[test]
    fn send_no_earlier_respects_idle_gaps() {
        let mut ch = Channel::new(NetworkConfig::gigabit(
            Protocol::Udp, 0.0, 2,
        ));
        ch.send_no_earlier(Dir::Up, 10_000, 0).unwrap();
        let (s, _) = ch.send_no_earlier(Dir::Up, 10_000, 5_000_000).unwrap();
        assert_eq!(s, 5_000_000, "idle direction starts at the request");
    }

    #[test]
    fn presets_differ() {
        let g = NetworkConfig::gigabit(Protocol::Tcp, 0.0, 0);
        let f = NetworkConfig::fast_ethernet(Protocol::Tcp, 0.0, 0);
        let w = NetworkConfig::wifi(Protocol::Tcp, 0.0, 0);
        assert!(g.capacity_bps > f.capacity_bps);
        assert!(w.latency_ns > g.latency_ns);
    }

    #[test]
    fn parse_accepts_presets_and_custom_specs() {
        let w = NetworkConfig::parse("wifi:udp:loss=0.01:seed=7").unwrap();
        assert_eq!(w.protocol, Protocol::Udp);
        assert_eq!(w.capacity_bps, 16e7);
        assert_eq!(w.latency_ns, 2_000_000);
        assert_eq!(w.loss_rate, 0.01);
        assert_eq!(w.seed, 7);
        let g = NetworkConfig::parse("gigabit:tcp").unwrap();
        assert_eq!(g.protocol, Protocol::Tcp);
        assert_eq!(g.loss_rate, 0.0);
        assert_eq!(g.seed, 0);
        // Bare preset: TCP, loss 0, seed 0.
        let b = NetworkConfig::parse("fast-ethernet").unwrap();
        assert_eq!(b.capacity_bps, 1e8);
        assert_eq!(b.protocol, Protocol::Tcp);
        // Custom bandwidth+latency; explicit-plus exponents split at the
        // last '+'. Segments compose in any order.
        let c = NetworkConfig::parse("radio@5e+7+3000000:seed=3:udp").unwrap();
        assert_eq!(c.capacity_bps, 5e7);
        assert_eq!(c.interface_bps, 5e7);
        assert_eq!(c.latency_ns, 3_000_000);
        assert_eq!(c.protocol, Protocol::Udp);
        assert_eq!(c.seed, 3);
        // jitter= and burst= reach the struct fields the old grammar
        // could not express.
        let j = NetworkConfig::parse(
            "wifi:udp:jitter=200000:burst=0.02,0.25",
        )
        .unwrap();
        assert_eq!(j.jitter_ns, 200_000);
        assert_eq!(
            j.loss_model,
            LossModel::GilbertElliott {
                p_gb: 0.02,
                p_bg: 0.25,
                bad_loss: 1.0
            }
        );
    }

    #[test]
    fn parse_rejects_malformed_specs() {
        for bad in [
            "lan",                       // unknown preset
            "radio@5e7",                 // no latency
            "radio@fast+1",              // bad bandwidth
            "radio@-5e7+1",              // negative bandwidth
            "radio@5e7+1.5",             // fractional latency
            "@5e7+1",                    // empty name
            "gigabit:sctp",              // unknown protocol
            "gigabit:loss=1.5",          // loss out of range
            "gigabit:loss=x",            // bad loss
            "gigabit:seed=-1",           // bad seed
            "gigabit:tcp:udp",           // duplicate protocol
            "gigabit:loss=0:loss=0.1",   // duplicate loss
            "gigabit:seed=1:seed=2",     // duplicate seed
            "gigabit:jitter=x",          // bad jitter
            "gigabit:jitter=-5",         // negative jitter
            "gigabit:jitter=1:jitter=2", // duplicate jitter
            "gigabit:burst=0.5",         // burst missing p_exit
            "gigabit:burst=1.5,0.5",     // p_enter out of range
            "gigabit:burst=0.1,0",       // p_exit out of range
            "gigabit:burst=0.1,0.5:burst=0.1,0.5", // duplicate burst
        ] {
            assert!(NetworkConfig::parse(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn display_is_a_canonical_reparseable_spec() {
        let w = NetworkConfig::wifi(Protocol::Udp, 0.08, 42);
        assert_eq!(w.to_string(), "wifi:udp:loss=0.08:seed=42");
        let c = NetworkConfig::parse("radio@5e7+3000000:udp:loss=0.1").unwrap();
        assert_eq!(c.to_string(), "custom@50000000+3000000:udp:loss=0.1:seed=0");
        // jitter/burst render and re-parse.
        let b = NetworkConfig::parse(
            "wifi:udp:jitter=150000:burst=0.02,0.25",
        )
        .unwrap();
        assert_eq!(
            b.to_string(),
            "wifi:udp:loss=0:seed=0:jitter=150000:burst=0.02,0.25"
        );
        let rt = NetworkConfig::parse(&b.to_string()).unwrap();
        assert_eq!(rt.jitter_ns, 150_000);
        assert_eq!(rt.loss_model, b.loss_model);
    }

    #[test]
    fn prop_channel_spec_roundtrips_display() {
        use crate::util::propcheck::{check, Config};
        check("channel spec round-trip", Config::default(), |c| {
            let base = *c.choice(&[
                "gigabit",
                "fast-ethernet",
                "wifi",
                "custom",
            ]);
            let spec = if base == "custom" {
                let bw = (c.f64(1e6, 1e10) / 1e3).round() * 1e3;
                let lat: SimTime = c.sized_range(1, 100_000_000);
                format!("edge-link@{bw}+{lat}")
            } else {
                base.to_string()
            };
            let proto = if c.bool() { "tcp" } else { "udp" };
            let loss = (c.f64(0.0, 0.5) * 1e4).round() / 1e4;
            let seed = c.sized_range(0, 1_000_000_000);
            let mut spec = format!("{spec}:{proto}:loss={loss}:seed={seed}");
            if c.bool() {
                let jitter: SimTime = c.sized_range(1, 10_000_000);
                spec.push_str(&format!(":jitter={jitter}"));
            }
            if c.bool() {
                let p_gb =
                    ((c.f64(0.0001, 0.5) * 1e4).round() / 1e4).max(0.0001);
                let p_bg =
                    ((c.f64(0.0001, 1.0) * 1e4).round() / 1e4).max(0.0001);
                spec.push_str(&format!(":burst={p_gb},{p_bg}"));
            }
            let cfg = NetworkConfig::parse(&spec)
                .map_err(|e| format!("parse({spec}): {e}"))?;
            let rt = NetworkConfig::parse(&cfg.to_string())
                .map_err(|e| format!("reparse({cfg}): {e}"))?;
            if rt.protocol != cfg.protocol
                || rt.latency_ns != cfg.latency_ns
                || rt.capacity_bps != cfg.capacity_bps
                || rt.interface_bps != cfg.interface_bps
                || rt.loss_rate != cfg.loss_rate
                || rt.seed != cfg.seed
                || rt.jitter_ns != cfg.jitter_ns
                || rt.loss_model != cfg.loss_model
            {
                return Err(format!(
                    "display '{cfg}' did not round-trip '{spec}'"
                ));
            }
            if rt.to_string() != cfg.to_string() {
                return Err(format!(
                    "display not a fixpoint: '{cfg}' vs '{rt}'"
                ));
            }
            Ok(())
        });
    }
}
