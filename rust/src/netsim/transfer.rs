//! Channel: the full-duplex link pair + persistent transport state, with a
//! message-level API the scenario engine drives (XMTR/RCVR in the paper's
//! architecture).

use anyhow::{anyhow, Result};

use super::event::SimTime;
use super::link::{Link, LinkConfig, LinkStats, LossModel};
use super::packet::Dir;
use super::tcp::{self, TcpConfig, TcpMessageResult, TcpState};
use super::udp::{self, UdpConfig, UdpMessageResult};
use crate::util::rng::Rng;

/// Transport layer protocol (paper Sec. IV, input 1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Protocol {
    Tcp,
    Udp,
}

impl Protocol {
    pub fn parse(s: &str) -> Result<Protocol> {
        match s.to_ascii_lowercase().as_str() {
            "tcp" => Ok(Protocol::Tcp),
            "udp" => Ok(Protocol::Udp),
            _ => Err(anyhow!("unknown protocol '{s}' (tcp|udp)")),
        }
    }
}

impl std::fmt::Display for Protocol {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            Protocol::Tcp => "TCP",
            Protocol::Udp => "UDP",
        })
    }
}

/// The five network-modeling inputs of the paper's simulator (Sec. IV).
#[derive(Clone, Debug)]
pub struct NetworkConfig {
    pub protocol: Protocol,
    /// Channel latency (propagation), ns. Paper example: 100 µs.
    pub latency_ns: SimTime,
    /// Channel capacity, bits/s.
    pub capacity_bps: f64,
    /// Interface speed, bits/s (1000 Mb/s GbE, 100 Mb/s FE, 160 Mb/s Wi-Fi).
    pub interface_bps: f64,
    /// Saboteur loss rate in [0, 1).
    pub loss_rate: f64,
    /// Loss distribution (i.i.d. saboteur or Gilbert-Elliott bursts).
    pub loss_model: LossModel,
    /// Per-packet propagation jitter bound, ns.
    pub jitter_ns: SimTime,
    pub tcp: TcpConfig,
    pub udp: UdpConfig,
    pub seed: u64,
}

impl NetworkConfig {
    /// The paper's evaluation channel: 1 Gigabit full-duplex, 100 µs.
    pub fn gigabit(protocol: Protocol, loss_rate: f64, seed: u64) -> Self {
        NetworkConfig {
            protocol,
            latency_ns: 100_000,
            capacity_bps: 1e9,
            interface_bps: 1e9,
            loss_rate,
            loss_model: LossModel::Iid,
            jitter_ns: 0,
            tcp: TcpConfig::default(),
            udp: UdpConfig::default(),
            seed,
        }
    }

    pub fn fast_ethernet(protocol: Protocol, loss_rate: f64, seed: u64) -> Self {
        let mut c = Self::gigabit(protocol, loss_rate, seed);
        c.capacity_bps = 1e8;
        c.interface_bps = 1e8;
        c
    }

    pub fn wifi(protocol: Protocol, loss_rate: f64, seed: u64) -> Self {
        let mut c = Self::gigabit(protocol, loss_rate, seed);
        c.capacity_bps = 16e7;
        c.interface_bps = 16e7;
        c.latency_ns = 2_000_000; // 2 ms
        c
    }

    fn link_config(&self) -> LinkConfig {
        LinkConfig {
            latency_ns: self.latency_ns,
            capacity_bps: self.capacity_bps,
            interface_bps: self.interface_bps,
            loss_rate: self.loss_rate,
            loss_model: self.loss_model,
            jitter_ns: self.jitter_ns,
        }
    }
}

/// Result of one application-message transfer.
#[derive(Clone, Debug)]
pub enum TransferResult {
    Tcp(TcpMessageResult),
    Udp(UdpMessageResult),
}

impl TransferResult {
    /// Latency until the receiver considers the message complete.
    pub fn latency_ns(&self) -> SimTime {
        match self {
            TransferResult::Tcp(r) => r.delivery_latency_ns,
            TransferResult::Udp(r) => r.latency_ns,
        }
    }

    /// Byte ranges lost in flight (empty for TCP — reliable delivery).
    pub fn lost_ranges(&self) -> &[(u64, u32)] {
        match self {
            TransferResult::Tcp(_) => &[],
            TransferResult::Udp(r) => &r.lost_ranges,
        }
    }

    pub fn wire_bytes(&self) -> u64 {
        match self {
            TransferResult::Tcp(r) => r.stats.wire_bytes,
            TransferResult::Udp(r) => r.stats.wire_bytes,
        }
    }

    pub fn retransmits(&self) -> u64 {
        match self {
            TransferResult::Tcp(r) => r.stats.retransmits,
            TransferResult::Udp(_) => 0,
        }
    }
}

/// Full-duplex channel with persistent per-direction transport state.
pub struct Channel {
    pub cfg: NetworkConfig,
    up: Link,
    down: Link,
    tcp_up: TcpState,
    tcp_down: TcpState,
    now: SimTime,
    transfers: u64,
}

impl Channel {
    pub fn new(cfg: NetworkConfig) -> Self {
        let mut rng = Rng::new(cfg.seed);
        let lcfg = cfg.link_config();
        Channel {
            tcp_up: TcpState::new(&cfg.tcp),
            tcp_down: TcpState::new(&cfg.tcp),
            cfg,
            up: Link::new(lcfg.clone(), rng.fork()),
            down: Link::new(lcfg, rng.fork()),
            now: 0,
            transfers: 0,
        }
    }

    pub fn now(&self) -> SimTime {
        self.now
    }

    pub fn transfers(&self) -> u64 {
        self.transfers
    }

    /// Advance the channel clock to absolute time `t` (inter-frame gaps).
    pub fn advance_to(&mut self, t: SimTime) {
        self.now = self.now.max(t);
    }

    /// Send `len` bytes in `dir` starting no earlier than the channel's
    /// current time; advances the channel clock past the transfer.
    pub fn send(&mut self, dir: Dir, len: u64) -> Result<TransferResult> {
        let start = self.now;
        self.transfers += 1;
        let r = match self.cfg.protocol {
            Protocol::Tcp => {
                let (data, ack, state) = match dir {
                    Dir::Up => {
                        (&mut self.up, &mut self.down, &mut self.tcp_up)
                    }
                    Dir::Down => {
                        (&mut self.down, &mut self.up, &mut self.tcp_down)
                    }
                };
                let res = tcp::send_message(
                    &self.cfg.tcp, state, data, ack, len, start,
                )
                .map_err(|e| anyhow!(e))?;
                self.now = start + res.ack_latency_ns;
                TransferResult::Tcp(res)
            }
            Protocol::Udp => {
                let link = match dir {
                    Dir::Up => &mut self.up,
                    Dir::Down => &mut self.down,
                };
                let res = udp::send_message(&self.cfg.udp, link, len, start);
                self.now = start + res.latency_ns;
                TransferResult::Udp(res)
            }
        };
        Ok(r)
    }

    pub fn link_stats(&self, dir: Dir) -> LinkStats {
        match dir {
            Dir::Up => self.up.stats,
            Dir::Down => self.down.stats,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn protocol_parse() {
        assert_eq!(Protocol::parse("tcp").unwrap(), Protocol::Tcp);
        assert_eq!(Protocol::parse("UDP").unwrap(), Protocol::Udp);
        assert!(Protocol::parse("sctp").is_err());
    }

    #[test]
    fn tcp_channel_sends_reliably() {
        let mut ch = Channel::new(NetworkConfig::gigabit(
            Protocol::Tcp, 0.05, 42,
        ));
        let r = ch.send(Dir::Up, 100_000).unwrap();
        assert!(r.lost_ranges().is_empty());
        assert!(r.latency_ns() > 0);
        assert!(ch.now() > 0);
    }

    #[test]
    fn udp_channel_reports_losses() {
        let mut ch = Channel::new(NetworkConfig::gigabit(
            Protocol::Udp, 0.3, 42,
        ));
        let r = ch.send(Dir::Up, 1_000_000).unwrap();
        assert!(!r.lost_ranges().is_empty());
    }

    #[test]
    fn directions_have_independent_streams() {
        let mut ch = Channel::new(NetworkConfig::gigabit(
            Protocol::Udp, 0.2, 7,
        ));
        let up = ch.send(Dir::Up, 500_000).unwrap();
        let down = ch.send(Dir::Down, 500_000).unwrap();
        assert_ne!(up.lost_ranges(), down.lost_ranges());
    }

    #[test]
    fn clock_advances_across_transfers() {
        let mut ch = Channel::new(NetworkConfig::gigabit(
            Protocol::Tcp, 0.0, 1,
        ));
        ch.send(Dir::Up, 10_000).unwrap();
        let t1 = ch.now();
        ch.advance_to(t1 + 1_000_000);
        ch.send(Dir::Up, 10_000).unwrap();
        assert!(ch.now() >= t1 + 1_000_000);
    }

    #[test]
    fn presets_differ() {
        let g = NetworkConfig::gigabit(Protocol::Tcp, 0.0, 0);
        let f = NetworkConfig::fast_ethernet(Protocol::Tcp, 0.0, 0);
        let w = NetworkConfig::wifi(Protocol::Tcp, 0.0, 0);
        assert!(g.capacity_bps > f.capacity_bps);
        assert!(w.latency_ns > g.latency_ns);
    }
}
