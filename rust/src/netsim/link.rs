//! Channel model: one direction of the full-duplex link.
//!
//! Models the four physical knobs the paper's simulator exposes
//! (Sec. IV "communication network modeling"):
//!   * channel latency  — propagation delay per packet;
//!   * channel capacity — available link bandwidth;
//!   * interface speed  — NIC serialization rate (1000 Mb/s GbE, 100 Mb/s
//!     Fast-Ethernet, 160 Mb/s Wi-Fi, ...);
//!   * saboteur         — i.i.d. packet loss rate.
//!
//! Serialization is FIFO: a packet starts on the wire only when the
//! previous one finished (`busy_until`), at rate min(interface, capacity).

use super::event::{SimTime, NS_PER_SEC};
use super::trace::LinkTrace;
use crate::util::rng::Rng;

/// Saboteur model: how packet losses are distributed in time.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum LossModel {
    /// Independent per-packet Bernoulli loss (the paper's saboteur).
    Iid,
    /// Gilbert-Elliott two-state burst model: the channel alternates
    /// between a Good state (lossless) and a Bad state (loss with
    /// probability `bad_loss`); `p_gb` / `p_bg` are the per-packet
    /// transition probabilities. The *stationary* loss rate is
    /// `bad_loss * p_gb / (p_gb + p_bg)`. Bursty loss is what real
    /// wireless links exhibit, and is an ablation of the paper's i.i.d.
    /// assumption (see the ablation_loss_model bench).
    GilbertElliott { p_gb: f64, p_bg: f64, bad_loss: f64 },
}

impl LossModel {
    /// A Gilbert-Elliott parameterization with the given stationary loss
    /// rate and a mean bad-burst length of `burst_len` packets.
    pub fn bursty(stationary_loss: f64, burst_len: f64) -> LossModel {
        let bad_loss = 1.0;
        let p_bg = 1.0 / burst_len.max(1.0);
        // pi_bad = p_gb / (p_gb + p_bg) = stationary_loss / bad_loss
        let pi_bad = (stationary_loss / bad_loss).min(0.999);
        let p_gb = p_bg * pi_bad / (1.0 - pi_bad);
        LossModel::GilbertElliott { p_gb, p_bg, bad_loss }
    }

    pub fn stationary_loss(&self, iid_rate: f64) -> f64 {
        match *self {
            LossModel::Iid => iid_rate,
            LossModel::GilbertElliott { p_gb, p_bg, bad_loss } => {
                bad_loss * p_gb / (p_gb + p_bg)
            }
        }
    }
}

#[derive(Clone, Debug)]
pub struct LinkConfig {
    /// Propagation delay (channel latency), ns.
    pub latency_ns: SimTime,
    /// Channel capacity, bits/s.
    pub capacity_bps: f64,
    /// Interface (NIC) speed, bits/s.
    pub interface_bps: f64,
    /// Saboteur: probability each packet is lost (under `Iid`).
    pub loss_rate: f64,
    /// Loss distribution in time.
    pub loss_model: LossModel,
    /// Random per-packet propagation jitter, ns (uniform in [0, jitter]).
    pub jitter_ns: SimTime,
}

impl LinkConfig {
    pub fn basic(latency_ns: SimTime, rate_bps: f64, loss_rate: f64)
        -> LinkConfig
    {
        LinkConfig {
            latency_ns,
            capacity_bps: rate_bps,
            interface_bps: rate_bps,
            loss_rate,
            loss_model: LossModel::Iid,
            jitter_ns: 0,
        }
    }

    /// Effective serialization rate.
    #[inline]
    pub fn rate_bps(&self) -> f64 {
        self.capacity_bps.min(self.interface_bps)
    }

    // Inlined: `send` is called once per packet on the untraced fast
    // path, which at fleet scale is the single hottest call site of the
    // whole simulator.
    #[inline]
    pub fn serialization_ns(&self, bytes: u32) -> SimTime {
        ((bytes as f64 * 8.0 / self.rate_bps()) * NS_PER_SEC).round() as SimTime
    }
}

/// Outcome of handing a packet to the link.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SendOutcome {
    /// When the packet fully arrives at the far end (even if dropped, for
    /// accounting: drops are decided at the receiving end of the wire).
    pub arrival: SimTime,
    /// When the sender's interface is free again.
    pub tx_done: SimTime,
    /// Saboteur verdict.
    pub dropped: bool,
}

#[derive(Clone, Copy, Debug, Default)]
pub struct LinkStats {
    pub packets_sent: u64,
    pub packets_dropped: u64,
    pub bytes_sent: u64,
    /// Total time the interface spent serializing, ns (utilization).
    pub busy_ns: u64,
}

/// One direction of the channel.
pub struct Link {
    pub cfg: LinkConfig,
    busy_until: SimTime,
    rng: Rng,
    /// Gilbert-Elliott state: true = Bad. Persists across trace segments
    /// (a handoff does not reset the channel's burst phase).
    ge_bad: bool,
    /// Optional time-varying schedule. When attached, `send` samples the
    /// active [`super::trace::TraceSegment`] instead of `cfg`, costing
    /// boundary-straddling packets piecewise.
    trace: Option<LinkTrace>,
    pub stats: LinkStats,
}

impl Link {
    pub fn new(cfg: LinkConfig, rng: Rng) -> Self {
        Link {
            cfg,
            busy_until: 0,
            rng,
            ge_bad: false,
            trace: None,
            stats: LinkStats::default(),
        }
    }

    /// Attach (or detach) a time-varying schedule. A constant trace is
    /// byte-identical to `None`.
    pub fn set_trace(&mut self, trace: Option<LinkTrace>) {
        self.trace = trace;
    }

    pub fn trace(&self) -> Option<&LinkTrace> {
        self.trace.as_ref()
    }

    /// The saboteur with explicit parameters, so a trace segment can
    /// swap the loss law per packet while the Gilbert-Elliott state and
    /// the RNG stream persist.
    fn saboteur_at(&mut self, loss_rate: f64, loss_model: LossModel) -> bool {
        match loss_model {
            LossModel::Iid => self.rng.chance(loss_rate),
            LossModel::GilbertElliott { p_gb, p_bg, bad_loss } => {
                // Transition first, then sample in the new state.
                if self.ge_bad {
                    if self.rng.chance(p_bg) {
                        self.ge_bad = false;
                    }
                } else if self.rng.chance(p_gb) {
                    self.ge_bad = true;
                }
                self.ge_bad && self.rng.chance(bad_loss)
            }
        }
    }

    /// Enqueue `bytes` at `now`; returns serialization/arrival times and the
    /// saboteur's verdict. Deterministic given the link's RNG stream.
    ///
    /// With a trace attached, serialization integrates the packet's bits
    /// across every segment it straddles (each span of bits pays its own
    /// segment's rate), while latency/jitter/loss come from the segment
    /// active when serialization *starts* — the packet committed to the
    /// wire under that segment's propagation conditions.
    pub fn send(&mut self, now: SimTime, bytes: u32) -> SendOutcome {
        let start = now.max(self.busy_until);
        let (seg0, tx_done) = if let Some(tr) = &self.trace {
            let seg0 = *tr.segment_at(start);
            let mut cur = start;
            let mut rem_bits = bytes as f64 * 8.0;
            let tx_done = loop {
                let rate = tr.segment_at(cur).rate_bps();
                // First iteration of a constant trace evaluates the
                // identical expression tree to `serialization_ns`, so a
                // single-segment trace is byte-identical to no trace.
                let fin =
                    cur + ((rem_bits / rate) * NS_PER_SEC).round() as SimTime;
                match tr.next_boundary_after(cur) {
                    Some(b) if fin > b => {
                        rem_bits -= rate * ((b - cur) as f64) / NS_PER_SEC;
                        cur = b;
                        if rem_bits <= 0.0 {
                            break b;
                        }
                    }
                    _ => break fin,
                }
            };
            (Some(seg0), tx_done)
        } else {
            (None, start + self.cfg.serialization_ns(bytes))
        };
        let ser = tx_done - start;
        self.busy_until = tx_done;
        let (latency_ns, jitter_ns, loss_rate, loss_model) = match &seg0 {
            Some(s) => (s.latency_ns, s.jitter_ns, s.loss_rate, s.loss_model),
            None => (
                self.cfg.latency_ns,
                self.cfg.jitter_ns,
                self.cfg.loss_rate,
                self.cfg.loss_model,
            ),
        };
        let jitter = if jitter_ns > 0 {
            self.rng.range_u64(0, jitter_ns)
        } else {
            0
        };
        let arrival = tx_done + latency_ns + jitter;
        let dropped = self.saboteur_at(loss_rate, loss_model);
        self.stats.packets_sent += 1;
        self.stats.bytes_sent += bytes as u64;
        self.stats.busy_ns += ser;
        if dropped {
            self.stats.packets_dropped += 1;
        }
        SendOutcome { arrival, tx_done, dropped }
    }

    /// Sender-side queueing + serialization delay if a packet were sent now.
    #[inline]
    pub fn backlog_ns(&self, now: SimTime) -> SimTime {
        self.busy_until.saturating_sub(now)
    }

    pub fn reset_clock(&mut self) {
        self.busy_until = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gbe() -> LinkConfig {
        LinkConfig::basic(100_000, 1e9, 0.0)
    }

    #[test]
    fn serialization_time_math() {
        // 1500 B at 1 Gb/s = 12 µs.
        assert_eq!(gbe().serialization_ns(1500), 12_000);
    }

    #[test]
    fn rate_is_min_of_interface_and_capacity() {
        let mut c = gbe();
        c.interface_bps = 1e8;
        assert_eq!(c.rate_bps(), 1e8);
        c.interface_bps = 1e9;
        c.capacity_bps = 16e7;
        assert_eq!(c.rate_bps(), 16e7);
    }

    #[test]
    fn arrival_includes_propagation() {
        let mut l = Link::new(gbe(), Rng::new(0));
        let o = l.send(0, 1500);
        assert_eq!(o.tx_done, 12_000);
        assert_eq!(o.arrival, 112_000);
        assert!(!o.dropped);
    }

    #[test]
    fn fifo_serialization() {
        let mut l = Link::new(gbe(), Rng::new(0));
        let a = l.send(0, 1500);
        let b = l.send(0, 1500); // queued behind a
        assert_eq!(b.tx_done, a.tx_done + 12_000);
        assert_eq!(l.backlog_ns(0), 24_000);
    }

    #[test]
    fn idle_gap_no_queueing() {
        let mut l = Link::new(gbe(), Rng::new(0));
        l.send(0, 1500);
        let b = l.send(1_000_000, 1500);
        assert_eq!(b.tx_done, 1_012_000);
    }

    #[test]
    fn saboteur_rate() {
        let mut cfg = gbe();
        cfg.loss_rate = 0.1;
        let mut l = Link::new(cfg, Rng::new(7));
        let drops = (0..20_000).filter(|_| l.send(u64::MAX / 2, 100).dropped)
            .count();
        let rate = drops as f64 / 20_000.0;
        assert!((rate - 0.1).abs() < 0.01, "{rate}");
    }

    #[test]
    fn zero_loss_never_drops() {
        let mut l = Link::new(gbe(), Rng::new(1));
        assert!((0..1000).all(|i| !l.send(i * 100_000, 1500).dropped));
    }

    #[test]
    fn gilbert_elliott_matches_stationary_loss() {
        let mut cfg = gbe();
        cfg.loss_model = LossModel::bursty(0.1, 8.0);
        assert!((cfg.loss_model.stationary_loss(0.0) - 0.1).abs() < 1e-9);
        let mut l = Link::new(cfg, Rng::new(3));
        let n = 200_000;
        let drops = (0..n).filter(|_| l.send(u64::MAX / 2, 100).dropped)
            .count();
        let rate = drops as f64 / n as f64;
        assert!((rate - 0.1).abs() < 0.01, "{rate}");
    }

    #[test]
    fn gilbert_elliott_losses_are_bursty() {
        // Mean run length of consecutive drops must exceed the i.i.d. one
        // at the same stationary rate.
        let run_len = |model: LossModel| -> f64 {
            let mut cfg = gbe();
            cfg.loss_rate = 0.1;
            cfg.loss_model = model;
            let mut l = Link::new(cfg, Rng::new(5));
            let (mut runs, mut drops, mut in_run) = (0u64, 0u64, false);
            for _ in 0..100_000 {
                let d = l.send(u64::MAX / 2, 100).dropped;
                if d {
                    drops += 1;
                    if !in_run {
                        runs += 1;
                        in_run = true;
                    }
                } else {
                    in_run = false;
                }
            }
            drops as f64 / runs.max(1) as f64
        };
        let iid = run_len(LossModel::Iid);
        let ge = run_len(LossModel::bursty(0.1, 8.0));
        assert!(ge > 2.0 * iid, "iid {iid:.2} vs GE {ge:.2}");
    }

    #[test]
    fn jitter_spreads_arrivals() {
        let mut cfg = gbe();
        cfg.jitter_ns = 50_000;
        let mut l = Link::new(cfg, Rng::new(1));
        let arrivals: Vec<u64> = (0..200)
            .map(|i| l.send(i * 1_000_000, 100).arrival
                 - (i * 1_000_000))
            .collect();
        let min = *arrivals.iter().min().unwrap();
        let max = *arrivals.iter().max().unwrap();
        assert!(max - min > 20_000, "jitter not applied: {min}..{max}");
        assert!(max <= 100_000 + 800 + 50_000);
    }

    #[test]
    fn stats_accumulate() {
        let mut l = Link::new(gbe(), Rng::new(0));
        l.send(0, 1000);
        l.send(0, 500);
        assert_eq!(l.stats.packets_sent, 2);
        assert_eq!(l.stats.bytes_sent, 1500);
        assert_eq!(l.stats.busy_ns, 12_000);
    }

    #[test]
    fn constant_trace_is_byte_identical_to_no_trace() {
        let mut cfg = gbe();
        cfg.loss_rate = 0.1;
        cfg.jitter_ns = 30_000;
        let mut plain = Link::new(cfg.clone(), Rng::new(9));
        let mut traced = Link::new(cfg.clone(), Rng::new(9));
        let mut net =
            crate::netsim::transfer::NetworkConfig::gigabit(
                crate::netsim::transfer::Protocol::Udp,
                cfg.loss_rate,
                0,
            );
        net.jitter_ns = cfg.jitter_ns;
        traced.set_trace(Some(LinkTrace::constant(&net)));
        for i in 0..500u64 {
            let a = plain.send(i * 37_000, 100 + (i as u32 % 1400));
            let b = traced.send(i * 37_000, 100 + (i as u32 % 1400));
            assert_eq!(a, b, "packet {i}");
        }
        assert_eq!(plain.stats.packets_sent, traced.stats.packets_sent);
        assert_eq!(plain.stats.packets_dropped, traced.stats.packets_dropped);
        assert_eq!(plain.stats.bytes_sent, traced.stats.bytes_sent);
        assert_eq!(plain.stats.busy_ns, traced.stats.busy_ns);
    }

    #[test]
    fn boundary_straddling_packet_matches_two_segment_closed_form() {
        // 1500 B starting at t=0 on a 1 Gb/s -> 100 Mb/s trace switching
        // at 6 µs: 6000 of the 12000 bits clear at 1 Gb/s by the boundary,
        // the remaining 6000 bits pay 100 Mb/s (60 µs) => tx_done 66 µs.
        let mut l = Link::new(gbe(), Rng::new(0));
        l.set_trace(Some(
            LinkTrace::parse_chain("gigabit>custom@1e8+100000@6000ns")
                .unwrap(),
        ));
        let o = l.send(0, 1500);
        assert_eq!(o.tx_done, 66_000);
        // Latency comes from the segment active at send time (100 µs).
        assert_eq!(o.arrival, 166_000);
        assert_eq!(l.stats.busy_ns, 66_000);
        // A packet sent entirely inside the second segment pays its rate.
        let o2 = l.send(1_000_000, 1500);
        assert_eq!(o2.tx_done, 1_000_000 + 120_000);
    }

    #[test]
    fn trace_switches_loss_and_jitter_at_boundaries() {
        // Lossless and jitter-free until 1 ms, then loss 1.0: every packet
        // sent after the boundary drops, none before.
        let mut l = Link::new(gbe(), Rng::new(4));
        l.set_trace(Some(
            LinkTrace::parse_chain("gigabit>gigabit:loss=0.999@1ms")
                .unwrap(),
        ));
        for i in 0..50 {
            assert!(!l.send(i * 10_000, 100).dropped, "pre-boundary {i}");
        }
        let drops = (0..200)
            .filter(|i| l.send(1_000_000 + i * 10_000, 100).dropped)
            .count();
        assert!(drops > 150, "post-boundary drops: {drops}");
    }
}
