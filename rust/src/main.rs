//! `sei` — the Split-Et-Impera command-line launcher.
//!
//! Subcommands:
//!   summary    Tables I/II for VGG16 (or the trained slim model)
//!   cs-curve   compute the Grad-CAM CS curve in Rust via the backend
//!   suggest    rank + simulate configurations against QoS requirements
//!   simulate   run one LC/RC/SC/MC scenario over the simulated channel(s)
//!   sweep      run a declarative design-space grid on a worker pool
//!   search     budgeted successive-halving arch x split co-design search
//!   serve      stream the ICE-Lab workload through a configuration
//!
//! Every command works without built artifacts or XLA: the default build
//! loads the hermetic analytic backend (see `runtime::analytic`), while
//! the `xla` cargo feature serves the real AOT artifacts when present.

use std::path::{Path, PathBuf};
use std::process::ExitCode;

use anyhow::{bail, Context, Result};

use sei::coordinator::{
    self, ModelScale, QosRequirements, ScenarioConfig, ScenarioKind,
    SearchSpec, SweepSpec,
};
use sei::model::{Arch, DeviceProfile};
use sei::netsim::transfer::{NetworkConfig, Protocol};
use sei::runtime::{load_backend_for, Executable, InferenceBackend};
use sei::util::cli::Command;

/// Open the backend for the parsed `--arch` value (every command routes
/// model-name strings through the one [`Arch::parse`]).
fn backend_from(m: &sei::util::cli::Matches)
    -> anyhow::Result<Box<dyn InferenceBackend>>
{
    let arch = Arch::parse(m.str("arch"))?;
    load_backend_for(Path::new(m.str("artifacts")), arch)
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let (cmd, rest) = match args.split_first() {
        Some((c, r)) => (c.as_str(), r.to_vec()),
        None => {
            eprintln!("{}", usage());
            return ExitCode::FAILURE;
        }
    };
    let result = match cmd {
        "summary" => cmd_summary(&rest),
        "cs-curve" => cmd_cs_curve(&rest),
        "suggest" => cmd_suggest(&rest),
        "place" => cmd_place(&rest),
        "simulate" => cmd_simulate(&rest),
        "sweep" => cmd_sweep(&rest),
        "search" => cmd_search(&rest),
        "serve" => cmd_serve(&rest),
        "hil-worker" => cmd_hil_worker(&rest),
        "hil-serve" => cmd_hil_serve(&rest),
        "--help" | "-h" | "help" => {
            println!("{}", usage());
            Ok(())
        }
        other => {
            eprintln!("unknown command '{other}'\n{}", usage());
            return ExitCode::FAILURE;
        }
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("{e:#}");
            ExitCode::FAILURE
        }
    }
}

fn usage() -> String {
    "sei — Split-Et-Impera: design of distributed deep learning applications

commands:
  summary    print the neural network summary and statistics (Tables I/II)
  cs-curve   compute the Cumulative Saliency curve via the backend
  suggest    rank candidate configurations and simulate them against QoS
  place      search a fleet inventory for the best placement plan
  simulate   run one LC/RC/SC/MC scenario over the simulated channel(s)
  sweep      run a design-space grid in parallel, with a Pareto report
  search     successive-halving co-design search under a simulation budget
  serve      stream the ICE-Lab conveyor workload through a configuration
  hil-worker hardware-in-the-loop: serve a tail/full artifact on a socket
  hil-serve  run split serving against a real worker over localhost TCP

most commands accept --arch vgg16 | resnet18 | mobilenetv2 to pick the
model architecture (split ids are per-arch graph-cut indices), and
--tiers <sensor,...,cloud> to place a pipeline across a device chain
(mc@<k cuts> partitions the network over k+1 tiers, one channel per hop);
simulate/serve take --trace hop0=<chain> for time-varying channels and
simulate --adaptive on compares mid-stream re-splitting to static cuts

run `sei <command> --help` for options"
        .to_string()
}

fn network_from(m: &sei::util::cli::Matches) -> Result<NetworkConfig> {
    // `--net <spec>` is the one-string spelling (NetworkConfig::parse);
    // a spec without an explicit `seed=` segment takes `--seed`.
    if let Some(spec) = m.opt_str("net").filter(|s| !s.is_empty()) {
        let mut net = NetworkConfig::parse(spec)?;
        if !spec.contains("seed=") {
            net.seed = m.u64("seed")?;
        }
        return Ok(net);
    }
    let protocol = Protocol::parse(m.str("protocol"))?;
    let mut net = match m.str("channel") {
        "gigabit" => NetworkConfig::gigabit(protocol, 0.0, m.u64("seed")?),
        "fast-ethernet" => {
            NetworkConfig::fast_ethernet(protocol, 0.0, m.u64("seed")?)
        }
        "wifi" => NetworkConfig::wifi(protocol, 0.0, m.u64("seed")?),
        other => bail!("unknown channel preset '{other}'"),
    };
    net.loss_rate = m.f64("loss")?;
    if let Some(lat) = m.opt_str("latency-us") {
        net.latency_ns = (lat.parse::<f64>()? * 1000.0) as u64;
    }
    Ok(net)
}

/// Per-hop channel chain: `--hop-nets a,b,...` (sensor side first) wins;
/// otherwise the single `--net`/`--channel` template is replicated by the
/// scenario engine with derived per-hop seeds. When no `--hop-nets` entry
/// pins a `seed=`, the whole chain is reseeded from `--seed` (hop 0
/// exact, later hops derived) so CLI runs stay reproducible.
fn hop_nets_from(m: &sei::util::cli::Matches) -> Result<Vec<NetworkConfig>> {
    let list = m.str("hop-nets");
    if list.is_empty() {
        return Ok(vec![network_from(m)?]);
    }
    let mut nets = Vec::new();
    for part in list.split(',') {
        if part.is_empty() {
            bail!("--hop-nets has an empty element in '{list}'");
        }
        nets.push(
            NetworkConfig::parse(part)
                .with_context(|| format!("--hop-nets entry '{part}'"))?,
        );
    }
    Ok(nets)
}

/// Apply the CLI seed policy after the scenario config is assembled (see
/// [`hop_nets_from`]).
fn reseed_from_cli(
    cfg: &mut ScenarioConfig,
    m: &sei::util::cli::Matches,
) -> Result<()> {
    let list = m.str("hop-nets");
    if !list.is_empty() && !list.contains("seed=") {
        cfg.set_base_seed(m.u64("seed")?);
    }
    Ok(())
}

/// Resolve the device tier chain: `--tiers a,b,c` wins; otherwise the
/// classic `[--edge, --server]` pair. Every spec goes through the shared
/// [`DeviceProfile::parse`] path (built-in names or
/// `name@<macs_per_sec>+<overhead_ns>`).
fn tiers_from(m: &sei::util::cli::Matches) -> Result<Vec<DeviceProfile>> {
    let list = m.str("tiers");
    if !list.is_empty() {
        let tiers = DeviceProfile::parse_tiers(list)?;
        if tiers.len() < 2 {
            bail!("--tiers needs at least 2 devices (sensor-side first)");
        }
        return Ok(tiers);
    }
    Ok(vec![
        DeviceProfile::parse(m.str("edge"))?,
        DeviceProfile::parse(m.str("server"))?,
    ])
}

fn cmd_summary(args: &[String]) -> Result<()> {
    let m = Command::new("summary", "Tables I/II model statistics")
        .opt("arch", "vgg16", "vgg16 | resnet18 | mobilenetv2")
        .opt("scale", "full",
             "full | slim (the arch's trained slim geometry)")
        .opt("model", "",
             "deprecated alias: an arch name, or 'slim' for the trained \
              VGG slim model")
        .opt("batch", "16", "batch size for the summary")
        .opt("artifacts", "artifacts", "artifacts directory (for slim)")
        .parse(args)?;
    let batch = m.usize("batch")?;
    let sel = if m.str("model").is_empty() {
        m.str("arch")
    } else {
        m.str("model")
    };
    let mut scale = ModelScale::parse(m.str("scale"))?;
    // Legacy spelling: `--model slim` means the trained VGG slim model.
    let arch = if sel == "slim" {
        scale = ModelScale::Slim;
        Arch::Vgg16
    } else {
        Arch::parse(sel)?
    };
    let net = match scale {
        ModelScale::Full => arch.full_network(),
        ModelScale::Slim => {
            // Slim knobs (image size, width, classes) come from the
            // arch's backend manifest, exactly as the scenario engine
            // resolves them.
            let eng =
                load_backend_for(Path::new(m.str("artifacts")), arch)?;
            let mi = &eng.manifest().model;
            arch.slim_network(mi.img_size, mi.width_mult, mi.hidden,
                              mi.num_classes)
        }
    };
    println!("TABLE I — neural network summary ({})\n", net.name);
    println!("{}", sei::model::render_table1(&net, batch));
    println!("TABLE II — neural network statistics\n");
    println!("{}", sei::model::render_table2(&net, batch));
    Ok(())
}

fn cmd_cs_curve(args: &[String]) -> Result<()> {
    let m = Command::new("cs-curve", "Grad-CAM CS curve via the backend")
        .opt("artifacts", "artifacts", "artifacts directory")
        .opt("arch", "vgg16", "vgg16 | resnet18 | mobilenetv2")
        .opt("images", "128", "number of test images")
        .opt("min-layer", "2", "earliest admissible split layer")
        .parse(args)?;
    let engine = backend_from(&m)?;
    let test = engine.dataset("test")?;
    let curve = coordinator::saliency::compute_cs_curve(
        &*engine, &test, m.usize("images")?,
    )?;
    let norm = curve.normalized();
    let names = &engine.manifest().model.layer_names;
    println!(
        "Cumulative Saliency curve (computed in Rust, {} backend):\n",
        engine.name()
    );
    for (i, &li) in curve.layers.iter().enumerate() {
        let bar = "#".repeat((norm[i] * 50.0) as usize);
        println!("L{li:>2} {:<14} {:>7.4} {bar}", names[li], norm[i]);
    }
    let cands = curve.candidates(m.usize("min-layer")?);
    println!("\ncandidate split points (local CS maxima): {cands:?}");
    println!(
        "build-time candidates (manifest):         {:?}",
        engine.manifest().cs_curve.candidates
    );
    Ok(())
}

fn cmd_suggest(args: &[String]) -> Result<()> {
    let m = Command::new("suggest", "QoS-driven configuration suggestion")
        .opt("artifacts", "artifacts", "artifacts directory")
        .opt("arch", "vgg16", "vgg16 | resnet18 | mobilenetv2")
        .opt("protocol", "tcp", "tcp | udp")
        .opt("channel", "gigabit", "gigabit | fast-ethernet | wifi")
        .opt("loss", "0.0", "packet loss rate")
        .opt("latency-us", "100", "channel latency, µs")
        .opt("net", "",
             "one-string channel spec, e.g. wifi:udp:loss=0.01 or \
              radio@5e7+3000000 (overrides --channel/--protocol/--loss/\
              --latency-us)")
        .opt("fleet", "",
             "FleetSpec JSON: also run the fleet placement search and \
              print the winning plan (see `sei place`)")
        .opt("threads", "1", "worker threads for the --fleet search")
        .opt("fps", "20", "required frames per second")
        .opt("min-accuracy", "0", "required accuracy in [0,1]")
        .opt("frames", "128", "frames to simulate per configuration")
        .opt("edge", "edge-gpu", "edge device profile")
        .opt("server", "server-gpu", "server device profile")
        .opt("tiers", "",
             "device tier chain, sensor first (e.g. \
              sensor-npu,edge-gpu,server-gpu); >= 3 tiers adds multi-tier \
              MC candidates to the ranking")
        .opt("min-layer", "2", "earliest admissible split layer")
        .opt("seed", "42", "simulation seed")
        .parse(args)?;
    let engine = backend_from(&m)?;
    let net = network_from(&m)?;
    let tiers = tiers_from(&m)?;
    let mut qos = QosRequirements::with_fps(m.f64("fps")?)?;
    let min_acc = m.f64("min-accuracy")?;
    if min_acc > 0.0 {
        qos = qos.and_accuracy(min_acc);
    }
    let test = engine.dataset("test")?;
    println!("arch: {}", engine.manifest().model.arch);
    println!("QoS: {}", qos.describe());
    println!(
        "tiers: {}",
        tiers.iter().map(|t| t.name.as_str()).collect::<Vec<_>>()
            .join(" -> ")
    );
    println!("network: {net}\n");
    let suggestions = coordinator::suggest(
        &*engine, &net, &tiers, &qos, &test, m.usize("frames")?,
        m.usize("min-layer")?,
    )?;
    println!(
        "{:<8} {:<16} {:>9} {:>9} {:>12} {:>10} {:>8}",
        "config", "cut", "pred.acc", "sim.acc", "mean lat", "p95 lat",
        "QoS"
    );
    for s in &suggestions {
        println!(
            "{:<8} {:<16} {:>8.1}% {:>8.1}% {:>9.2} ms {:>7.2} ms {:>8}",
            s.rank.kind.to_string(),
            s.rank.cut_name.as_deref().unwrap_or("—"),
            s.rank.predicted_accuracy * 100.0,
            s.report.accuracy * 100.0,
            s.report.mean_latency_ns / 1e6,
            s.report.p95_latency_ns as f64 / 1e6,
            if s.satisfies { "ok" } else { "violated" }
        );
    }
    if let Some(b) = coordinator::best(&suggestions) {
        println!("\nsuggested configuration: {}", b.rank.kind);
    }
    // Fleet integration: with `--fleet <spec>` the suggestion table is
    // followed by the auto-placement search's winning plan.
    if !m.str("fleet").is_empty() {
        let outcome = run_placement(
            m.str("fleet"),
            m.str("artifacts"),
            m.usize("threads")?.max(1),
        )?;
        println!("\nfleet placement ({}):", m.str("fleet"));
        print!("{}", outcome.plan.render());
    }
    Ok(())
}

/// Shared `sei place` / `sei suggest --fleet` driver: load the fleet
/// spec, build per-worker backends, run the search.
fn run_placement(
    spec_path: &str,
    artifacts: &str,
    threads: usize,
) -> Result<coordinator::PlacementOutcome> {
    let text = std::fs::read_to_string(spec_path)
        .with_context(|| format!("reading fleet spec '{spec_path}'"))?;
    let fleet = coordinator::FleetSpec::from_json(&text)?;
    let dir = PathBuf::from(artifacts);
    let factory = move |arch| load_backend_for(&dir, arch);
    coordinator::place(&fleet, threads, &factory)
}

fn cmd_place(args: &[String]) -> Result<()> {
    let m = Command::new(
        "place",
        "fleet-scale auto-placement: search tier chains x cut chains x \
         per-hop channels for the plan satisfying the most streams",
    )
    .opt("artifacts", "artifacts", "artifacts directory")
    .required("fleet", "FleetSpec JSON file (schema: ARCHITECTURE.md)")
    .opt("threads", "1", "worker threads (plan is identical at any count)")
    .opt("out", "", "write the winning PlacementPlan as JSON")
    .parse(args)?;
    let threads = m.usize("threads")?.max(1);
    let t0 = std::time::Instant::now();
    let outcome =
        run_placement(m.str("fleet"), m.str("artifacts"), threads)?;
    print!("{}", outcome.plan.render());
    println!(
        "search             {} candidates, {} simulated, {} pruned \
         ({:.2}s on {threads} thread(s))",
        outcome.candidates,
        outcome.evaluated,
        outcome.pruned,
        t0.elapsed().as_secs_f64()
    );
    if !m.str("out").is_empty() {
        let p = Path::new(m.str("out"));
        if let Some(parent) = p.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        std::fs::write(p, outcome.plan.to_json().to_string())?;
        println!("wrote {}", m.str("out"));
    }
    Ok(())
}

fn cmd_sweep(args: &[String]) -> Result<()> {
    let m = Command::new(
        "sweep",
        "parallel design-space sweep with Pareto reporting",
    )
    .opt("artifacts", "artifacts", "artifacts directory")
    .required("spec", "SweepSpec JSON file (schema: README / sweep docs)")
    .opt("arch", "",
         "override the spec's arch axis with one architecture")
    .opt("threads", "0", "worker threads (0 = all available cores)")
    .opt("out", "", "comma-separated report paths (.json and/or .csv)")
    .parse(args)?;
    let spec_path = m.str("spec");
    let text = std::fs::read_to_string(spec_path)
        .with_context(|| format!("reading sweep spec '{spec_path}'"))?;
    let mut spec = SweepSpec::from_json(&text)?;
    if !m.str("arch").is_empty() {
        spec.archs = vec![Arch::parse(m.str("arch"))?];
    }
    let threads = match m.usize("threads")? {
        0 => std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1),
        n => n,
    };
    // Validate every output path up front — a bad suffix must not cost a
    // full sweep run.
    let out_paths: Vec<&str> =
        m.str("out").split(',').filter(|s| !s.is_empty()).collect();
    for path in &out_paths {
        if !path.ends_with(".json") && !path.ends_with(".csv") {
            bail!("--out path '{path}' must end in .json or .csv");
        }
    }
    let dir = PathBuf::from(m.str("artifacts"));
    let factory = move |arch| load_backend_for(&dir, arch);
    let jobs = spec.expand()?.len();
    println!(
        "sweep '{}': {jobs} grid points x {} frames x {} seed(s) on \
         {threads} thread(s)\n",
        spec.name, spec.frames, spec.seeds_per_point
    );
    let t0 = std::time::Instant::now();
    let report = coordinator::run_sweep(&spec, threads, &factory)?;
    print!("{}", report.render());
    println!("\nswept {jobs} points in {:.2}s", t0.elapsed().as_secs_f64());
    for path in &out_paths {
        let p = Path::new(path);
        if let Some(parent) = p.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        if path.ends_with(".json") {
            std::fs::write(p, report.to_json().to_string())?;
        } else {
            report.to_csv().write(p)?;
        }
        println!("wrote {path}");
    }
    Ok(())
}

fn cmd_search(args: &[String]) -> Result<()> {
    let m = Command::new(
        "search",
        "successive-halving arch x split co-design search: sweep axes \
         plus budget / eta / rung_frames (schema: ARCHITECTURE.md)",
    )
    .opt("artifacts", "artifacts", "artifacts directory")
    .required("spec", "SearchSpec JSON file (SweepSpec + search keys)")
    .opt("threads", "0", "worker threads (0 = all available cores; the \
         report is identical at any count)")
    .opt("out", "", "write the SearchReport as JSON")
    .parse(args)?;
    let spec_path = m.str("spec");
    let text = std::fs::read_to_string(spec_path)
        .with_context(|| format!("reading search spec '{spec_path}'"))?;
    let spec = SearchSpec::from_json(&text)?;
    let threads = match m.usize("threads")? {
        0 => std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1),
        n => n,
    };
    let dir = PathBuf::from(m.str("artifacts"));
    let factory = move |arch| load_backend_for(&dir, arch);
    let candidates = spec.sweep.expand()?.len();
    println!(
        "search '{}': {candidates} candidates x {} rung(s) on {threads} \
         thread(s)\n",
        spec.sweep.name,
        spec.rung_frames.len(),
    );
    let t0 = std::time::Instant::now();
    let report = coordinator::run_search(&spec, threads, &factory)?;
    print!("{}", report.render());
    println!("\nsearched in {:.2}s", t0.elapsed().as_secs_f64());
    if !m.str("out").is_empty() {
        let p = Path::new(m.str("out"));
        if let Some(parent) = p.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        std::fs::write(p, report.to_json().to_string())?;
        println!("wrote {}", m.str("out"));
    }
    Ok(())
}

/// Parse the shared `--queue` flag (event-queue backend selection).
fn queue_kind_from(
    m: &sei::util::cli::Matches,
) -> Result<sei::netsim::QueueKind> {
    let s = m.str("queue");
    sei::netsim::QueueKind::parse(s).ok_or_else(|| {
        anyhow::anyhow!("unknown queue backend '{s}' (wheel | calendar | linear)")
    })
}

fn cmd_simulate(args: &[String]) -> Result<()> {
    let m = Command::new("simulate", "run one scenario")
        .opt("artifacts", "artifacts", "artifacts directory")
        .opt("arch", "vgg16", "vgg16 | resnet18 | mobilenetv2")
        .opt("scenario", "rc", "lc | rc | sc@<cut> | mc@<c1>,<c2>,...")
        .opt("protocol", "tcp", "tcp | udp")
        .opt("channel", "gigabit", "gigabit | fast-ethernet | wifi")
        .opt("loss", "0.0", "packet loss rate")
        .opt("latency-us", "100", "channel latency, µs")
        .opt("net", "",
             "one-string channel spec, e.g. wifi:udp:loss=0.01 \
              (overrides --channel/--protocol/--loss/--latency-us)")
        .opt("hop-nets", "",
             "per-hop channel specs, comma-separated, sensor side first \
              (mc@<k cuts> needs k specs; overrides --net)")
        .opt("frames", "256", "number of frames")
        .opt("fps", "20", "frame rate of the source (and QoS bound)")
        .opt("edge", "edge-gpu", "edge device profile")
        .opt("server", "server-gpu", "server device profile")
        .opt("tiers", "",
             "device tier chain, sensor first (mc@<k cuts> needs k+1 \
              tiers, e.g. sensor-npu,edge-gpu,server-gpu)")
        .opt("scale", "slim", "slim | full (paper-scale volumetrics)")
        .opt("dataset", "test", "train | test | ice")
        .opt("trace", "",
             "time-varying channel schedule: hop0=<chain>[,hop1=...] with \
              chain = state[>state@t...] (states: congested | degraded | \
              a channel spec), a .json hop-map file, or file.json#entry \
              of a trace suite")
        .opt("adaptive", "off",
             "on | off: run the adaptive re-split comparison (static-best \
              vs drain/drop controllers vs zero-cost oracle) over the \
              traced channels instead of one fixed configuration")
        .opt("queue", "calendar",
             "wheel | calendar | linear: event-queue backend (identical \
              results; wheel is the O(1) fleet-scale path)")
        .opt("seed", "42", "simulation seed")
        .parse(args)?;
    let hop_nets = hop_nets_from(&m)?;
    let tiers = tiers_from(&m)?;
    let qos = QosRequirements::with_fps(m.f64("fps")?)?;
    let mut cfg = ScenarioConfig {
        kind: ScenarioKind::parse(m.str("scenario"))?,
        hop_nets,
        tiers,
        scale: ModelScale::parse(m.str("scale"))?,
        frame_period_ns: (1e9 / m.f64("fps")?) as u64,
    };
    reseed_from_cli(&mut cfg, &m)?;
    if let Some(t) = m.opt_str("trace").filter(|s| !s.is_empty()) {
        cfg.apply_traces(&sei::netsim::trace::parse_trace_arg(t)?)?;
    }
    match m.str("adaptive") {
        "off" => {}
        "on" => {
            // A pure timing study — no inference backend needed: compare
            // the best static cut chain against the mid-stream re-split
            // controller (both switch policies) and the zero-cost oracle.
            let acfg = sei::coordinator::AdaptiveConfig {
                arch: Arch::parse(m.str("arch"))?,
                scale: cfg.scale,
                tiers: cfg.tiers.clone(),
                hop_nets: cfg.hop_nets.clone(),
                frames: m.usize("frames")?,
                frame_period_ns: cfg.frame_period_ns,
                deadline_ns: qos
                    .max_latency_ns
                    .unwrap_or(cfg.frame_period_ns * 2),
                controller: Default::default(),
                queue: queue_kind_from(&m)?,
            };
            let report = sei::coordinator::run_adaptive_comparison(&acfg)?;
            print!("{}", report.render());
            return Ok(());
        }
        other => bail!("unknown adaptive mode '{other}' (on | off)"),
    }
    let engine = backend_from(&m)?;
    let ds = engine.dataset(m.str("dataset"))?;
    let report = coordinator::serve_with_queue(
        &*engine, &cfg, &ds, m.usize("frames")?, &qos,
        queue_kind_from(&m)?,
    )?;
    print!("{}", report.render(&qos));
    Ok(())
}

fn cmd_serve(args: &[String]) -> Result<()> {
    let m = Command::new(
        "serve",
        "stream the ICE-Lab conveyor workload (closed-loop, queueing, \
         optionally multi-client)",
    )
        .opt("artifacts", "artifacts", "artifacts directory")
        .opt("arch", "vgg16", "vgg16 | resnet18 | mobilenetv2")
        .opt("scenario", "rc", "lc | rc | sc@<cut> | mc@<c1>,<c2>,...")
        .opt("protocol", "tcp", "tcp | udp")
        .opt("channel", "gigabit", "gigabit | fast-ethernet | wifi")
        .opt("loss", "0.0", "packet loss rate")
        .opt("latency-us", "100", "channel latency, µs")
        .opt("net", "",
             "one-string channel spec, e.g. wifi:udp:loss=0.01 \
              (overrides --channel/--protocol/--loss/--latency-us)")
        .opt("hop-nets", "",
             "per-hop channel specs, comma-separated, sensor side first \
              (mc@<k cuts> needs k specs; overrides --net)")
        .opt("frames", "512", "frames per client")
        .opt("fps", "20", "per-client offered frame rate (and QoS bound)")
        .opt("clients", "1", "concurrent client streams")
        .opt("max-batch", "1", "server dynamic batching: max batch size")
        .opt("batch-wait-us", "0",
             "server dynamic batching: partial-batch deadline, µs")
        .opt("edge", "edge-gpu", "edge device profile")
        .opt("server", "server-gpu", "server device profile")
        .opt("tiers", "",
             "device tier chain, sensor first (mc@<k cuts> needs k+1 \
              tiers)")
        .opt("clients-spec", "",
             "JSON file of heterogeneous client entries (per-client \
              scenario/arch/scale/rate/weight/QoS; overrides \
              --scenario/--clients/--frames/--fps)")
        .opt("fairness", "drr",
             "drr | fifo service at shared resources (clients-spec mode)")
        .opt("admission", "on",
             "on | off: reject provably unservable streams \
              (clients-spec mode)")
        .opt("trace", "",
             "time-varying channel schedule (hop0=<chain>[,hop1=...], a \
              .json hop map, or file.json#entry — see `simulate --help`)")
        .opt("queue", "calendar",
             "wheel | calendar | linear: event-queue backend (identical \
              results; wheel is the O(1) fleet-scale path)")
        .opt("mode", "full",
             "full | latency: latency skips per-frame inference — pure \
              queueing/timing, the 10^6-tenant path (clients-spec mode)")
        .opt("seed", "42", "simulation seed")
        .parse(args)?;
    if let Some(path) =
        m.opt_str("clients-spec").filter(|s| !s.is_empty())
    {
        return serve_clients_from_spec(&m, path);
    }
    let engine = backend_from(&m)?;
    let tiers = tiers_from(&m)?;
    let qos = QosRequirements::with_fps(m.f64("fps")?)?;
    let clients = m.usize("clients")?;
    if clients == 0 {
        bail!("--clients must be >= 1");
    }
    let batch = sei::coordinator::batcher::BatchPolicy::from_micros(
        m.usize("max-batch")?,
        m.f64("batch-wait-us")?,
    )?;
    let mut cfg = ScenarioConfig {
        kind: ScenarioKind::parse(m.str("scenario"))?,
        hop_nets: hop_nets_from(&m)?,
        tiers,
        scale: ModelScale::Slim,
        frame_period_ns: (1e9 / m.f64("fps")?) as u64,
    };
    reseed_from_cli(&mut cfg, &m)?;
    if let Some(t) = m.opt_str("trace").filter(|s| !s.is_empty()) {
        cfg.apply_traces(&sei::netsim::trace::parse_trace_arg(t)?)?;
    }
    let ice = engine.dataset("ice")?;
    println!("ICE-Lab conveyor serving — platform {}", engine.platform());
    if clients > 1 || batch.max_batch > 1 {
        // Multi-client / batched serving: the closed-loop streaming
        // simulator with per-resource queues and a batched server.
        let stream_cfg = sei::coordinator::StreamConfig {
            scenario: cfg,
            clients,
            frames_per_client: m.usize("frames")?,
            batch,
        };
        let t0 = std::time::Instant::now();
        let report = sei::coordinator::run_stream_with_queue(
            &*engine, &stream_cfg, Some(&ice), &qos, queue_kind_from(&m)?,
        )?;
        print!("{}", report.render(&qos));
        println!(
            "serving wall time  {:.2} s",
            t0.elapsed().as_secs_f64()
        );
    } else {
        let report = coordinator::serve_with_queue(
            &*engine, &cfg, &ice, m.usize("frames")?, &qos,
            queue_kind_from(&m)?,
        )?;
        print!("{}", report.render(&qos));
    }
    Ok(())
}

/// The `serve --clients-spec` path: heterogeneous multi-tenant serving
/// with per-client QoS, admission control and DRR fairness.
fn serve_clients_from_spec(
    m: &sei::util::cli::Matches,
    path: &str,
) -> Result<()> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading clients spec '{path}'"))?;
    let clients = coordinator::parse_clients_spec(&text)
        .with_context(|| format!("in clients spec '{path}'"))?;
    let batch = sei::coordinator::batcher::BatchPolicy::from_micros(
        m.usize("max-batch")?,
        m.f64("batch-wait-us")?,
    )?;
    let fairness = match m.str("fairness") {
        "drr" => coordinator::Fairness::Drr,
        "fifo" => coordinator::Fairness::Fifo,
        other => bail!("unknown fairness '{other}' (drr | fifo)"),
    };
    let admission = match m.str("admission") {
        "on" => true,
        "off" => false,
        other => bail!("unknown admission mode '{other}' (on | off)"),
    };
    let mut cfg = coordinator::MultiStreamConfig {
        clients,
        hop_nets: hop_nets_from(m)?,
        tiers: tiers_from(m)?,
        batch,
        fairness,
        admission,
        queue: queue_kind_from(m)?,
    };
    let list = m.str("hop-nets");
    if list.is_empty() || !list.contains("seed=") {
        cfg.set_base_seed(m.u64("seed")?);
    }
    if let Some(t) = m.opt_str("trace").filter(|s| !s.is_empty()) {
        cfg.apply_traces(&sei::netsim::trace::parse_trace_arg(t)?)?;
    }
    // One backend per distinct architecture in the mix.
    let mut archs: Vec<Arch> = Vec::new();
    for s in &cfg.clients {
        if !archs.contains(&s.arch) {
            archs.push(s.arch);
        }
    }
    let backends: Vec<(Arch, Box<dyn InferenceBackend>)> = archs
        .into_iter()
        .map(|a| {
            Ok((a, load_backend_for(Path::new(m.str("artifacts")), a)?))
        })
        .collect::<Result<_>>()?;
    let engines: Vec<(Arch, &dyn InferenceBackend)> =
        backends.iter().map(|(a, b)| (*a, &**b)).collect();
    let qos = QosRequirements::with_fps(m.f64("fps")?)?;
    println!(
        "ICE-Lab multi-tenant serving — platform {}",
        backends[0].1.platform()
    );
    let report = match m.str("mode") {
        "full" => {
            let ice = backends[0].1.dataset("ice")?;
            coordinator::serve_clients(&engines, &cfg, &ice, &qos)?
        }
        "latency" => {
            coordinator::serve_clients_latency(&engines, &cfg, &qos)?
        }
        other => bail!("unknown serve mode '{other}' (full | latency)"),
    };
    print!("{}", report.render(&qos));
    Ok(())
}

fn cmd_hil_worker(args: &[String]) -> Result<()> {
    let m = Command::new("hil-worker", "serve one artifact over TCP")
        .opt("artifacts", "artifacts", "artifacts directory")
        .opt("addr", "127.0.0.1:7117", "bind address")
        .required("exec", "artifact name, e.g. tail_L13_b1")
        .parse(args)?;
    println!("hil-worker: serving {} on {}", m.str("exec"), m.str("addr"));
    let served = sei::coordinator::hil::run_worker(
        Path::new(m.str("artifacts")),
        m.str("addr"),
        m.str("exec"),
    )?;
    println!("hil-worker: served {served} requests, shutting down");
    Ok(())
}

fn cmd_hil_serve(args: &[String]) -> Result<()> {
    let m = Command::new(
        "hil-serve",
        "split serving against a real worker over localhost TCP \
         (hardware-in-the-loop, paper Sec. IV)",
    )
    .opt("artifacts", "artifacts", "artifacts directory")
    .opt("split", "13", "split layer (must have exported artifacts)")
    .opt("frames", "128", "number of frames")
    .opt("addr", "127.0.0.1:0", "worker address (0 = auto port)")
    .parse(args)?;
    let artifacts = m.str("artifacts").to_string();
    let split = m.usize("split")?;
    let frames = m.usize("frames")?;

    // Pick a free port up front so leader and worker agree.
    let addr = {
        let probe = std::net::TcpListener::bind(m.str("addr"))?;
        probe.local_addr()?.to_string()
    };
    let worker_addr = addr.clone();
    let worker_artifacts = artifacts.clone();
    let worker = std::thread::spawn(move || {
        sei::coordinator::hil::run_worker(
            Path::new(&worker_artifacts),
            &worker_addr,
            &format!("tail_L{split}_b1"),
        )
    });

    let engine = load_backend_for(Path::new(&artifacts), Arch::Vgg16)?;
    let ice = engine.dataset("ice")?;
    let head = engine.executable(&format!("head_L{split}_b1"))?;
    let num_classes = engine.manifest().model.num_classes;
    let mut client = sei::coordinator::hil::HilClient::connect(&addr)?;
    let mut correct = 0usize;
    let t0 = std::time::Instant::now();
    for i in 0..frames {
        let idx = i % ice.len();
        let x = ice.batch(idx, 1)?;
        let z = head.run(&[sei::runtime::RtInput::F32(&x)])?;
        let logits = client.infer(&z, vec![1, num_classes])?;
        if logits.argmax_last()[0] == ice.labels[idx] as usize {
            correct += 1;
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let mean_rtt_ms = client.mean_rtt_ns() / 1e6;
    client.shutdown()?;
    let served = worker.join().expect("worker thread")?;
    println!("=== HIL split serving (real localhost TCP) ===");
    println!("split              L{split}");
    println!("frames             {frames} (worker served {served})");
    println!("accuracy           {:.2}%", correct as f64 / frames as f64 * 100.0);
    println!("real tail RTT      mean {mean_rtt_ms:.3} ms (wire + backend)");
    println!("end-to-end         {:.1} frames/s wall", frames as f64 / wall);
    Ok(())
}
