//! Host-side f32 tensor: the interchange type between the dataset loader,
//! the corruption model and the PJRT runtime.

use anyhow::{bail, Result};

#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("shape {:?} wants {n} elements, got {}", shape, data.len());
        }
        Ok(Tensor { shape, data })
    }

    pub fn zeros(shape: Vec<usize>) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape, data: vec![0.0; n] }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    pub fn byte_len(&self) -> u64 {
        (self.data.len() * 4) as u64
    }

    /// Leading-axis slice: rows [start, start+count) of axis 0.
    pub fn slice_rows(&self, start: usize, count: usize) -> Result<Tensor> {
        if self.shape.is_empty() {
            bail!("cannot row-slice a scalar");
        }
        let rows = self.shape[0];
        if start + count > rows {
            bail!("slice {start}+{count} out of {rows} rows");
        }
        let stride: usize = self.shape[1..].iter().product();
        let mut shape = self.shape.clone();
        shape[0] = count;
        Ok(Tensor {
            shape,
            data: self.data[start * stride..(start + count) * stride].to_vec(),
        })
    }

    /// Row-major argmax over the last axis; returns one index per row of
    /// the flattened leading axes (logits -> class predictions).
    pub fn argmax_last(&self) -> Vec<usize> {
        let last = *self.shape.last().expect("scalar");
        self.data
            .chunks_exact(last)
            .map(|row| {
                row.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(i, _)| i)
                    .unwrap()
            })
            .collect()
    }

    /// Zero the byte range [off, off+len) of this tensor's raw f32 buffer
    /// (UDP loss corruption: a lost datagram blanks the bytes it carried).
    /// Partially covered f32 values are zeroed whole — a partially
    /// transmitted float is garbage either way; zero is the deterministic
    /// choice.
    pub fn zero_byte_range(&mut self, off: u64, len: u32) {
        let total = self.byte_len();
        if off >= total || len == 0 {
            return;
        }
        let end = (off + len as u64).min(total);
        let first = (off / 4) as usize;
        let last = (end.div_ceil(4) as usize).min(self.data.len());
        for v in &mut self.data[first..last] {
            *v = 0.0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn new_validates_shape() {
        assert!(Tensor::new(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::new(vec![2, 3], vec![0.0; 5]).is_err());
    }

    #[test]
    fn slice_rows_basic() {
        let t = Tensor::new(vec![3, 2], (0..6).map(|v| v as f32).collect())
            .unwrap();
        let s = t.slice_rows(1, 2).unwrap();
        assert_eq!(s.shape(), &[2, 2]);
        assert_eq!(s.data(), &[2.0, 3.0, 4.0, 5.0]);
        assert!(t.slice_rows(2, 2).is_err());
    }

    #[test]
    fn argmax_rows() {
        let t = Tensor::new(vec![2, 3], vec![0.1, 0.9, 0.3, 2.0, -1.0, 0.0])
            .unwrap();
        assert_eq!(t.argmax_last(), vec![1, 0]);
    }

    #[test]
    fn zero_byte_range_aligned() {
        let mut t = Tensor::new(vec![4], vec![1.0; 4]).unwrap();
        t.zero_byte_range(4, 8); // floats 1..3
        assert_eq!(t.data(), &[1.0, 0.0, 0.0, 1.0]);
    }

    #[test]
    fn zero_byte_range_unaligned_rounds_outward() {
        let mut t = Tensor::new(vec![4], vec![1.0; 4]).unwrap();
        t.zero_byte_range(5, 4); // touches floats 1 and 2
        assert_eq!(t.data(), &[1.0, 0.0, 0.0, 1.0]);
    }

    #[test]
    fn zero_byte_range_clamps_to_buffer() {
        let mut t = Tensor::new(vec![2], vec![1.0; 2]).unwrap();
        t.zero_byte_range(4, 1000);
        assert_eq!(t.data(), &[1.0, 0.0]);
        t.zero_byte_range(100, 4); // past the end: no-op
        assert_eq!(t.data(), &[1.0, 0.0]);
    }

    #[test]
    fn zero_len_is_noop() {
        let mut t = Tensor::new(vec![2], vec![1.0; 2]).unwrap();
        t.zero_byte_range(0, 0);
        assert_eq!(t.data(), &[1.0, 1.0]);
    }
}
