//! ResNet-18 model definitions: the torchvision ImageNet variant (Table-II
//! style golden: 11,689,512 params) and a slim CIFAR-geometry variant, both
//! expressed on the DAG IR — every residual block is a
//! `branch`/`merge_add` subgraph, so split enumeration automatically
//! excludes cuts whose frontier a skip edge would cross.
//!
//! Split-point candidates (10 per network, stable ids `0..=9`): the stem
//! conv (+ maxpool for the ImageNet variant), then each BasicBlock's
//! closing ReLU — the block boundaries where exactly one tensor crosses.

use super::layer::{Network, NetworkBuilder, Shape};

/// (stage, blocks, channels) of ResNet-18's four stages.
pub const RESNET18_STAGES: [(usize, usize, usize); 4] =
    [(1, 2, 64), (2, 2, 128), (3, 2, 256), (4, 2, 512)];

/// One BasicBlock: conv3x3(s)-BN-ReLU-conv3x3-BN, residual add (identity
/// shortcut, or 1x1-conv + BN projection when the shape changes), ReLU.
fn basic_block(
    mut b: NetworkBuilder,
    name: &str,
    out_ch: usize,
    stride: usize,
    in_ch: usize,
) -> NetworkBuilder {
    let skip = b.branch();
    b = b
        .conv(&format!("{name}.conv1"), out_ch, 3, stride, 1, 1, false)
        .bn(&format!("{name}.bn1"))
        .relu(&format!("{name}.relu1"))
        .conv(&format!("{name}.conv2"), out_ch, 3, 1, 1, 1, false)
        .bn(&format!("{name}.bn2"));
    let main = b.branch();
    let shortcut = if stride != 1 || in_ch != out_ch {
        b = b
            .rewind(skip)
            .conv1x1(&format!("{name}.downsample.0"), out_ch, stride)
            .bn(&format!("{name}.downsample.1"));
        b.branch()
    } else {
        skip
    };
    b.rewind(main)
        .merge_add(&format!("{name}.add"), shortcut)
        .relu(&format!("{name}.relu2"))
        .cut_here(name)
}

fn stages(mut b: NetworkBuilder, mut in_ch: usize) -> NetworkBuilder {
    for (stage, blocks, ch) in RESNET18_STAGES {
        for blk in 0..blocks {
            let stride = if stage > 1 && blk == 0 { 2 } else { 1 };
            let name = format!("layer{stage}.{blk}");
            b = basic_block(b, &name, ch, stride, in_ch);
            in_ch = ch;
        }
    }
    b
}

/// Torchvision ResNet-18 at 224x224 / 1000 classes: 7x7-s2 stem, 3x3-s2
/// maxpool, 4 stages of 2 BasicBlocks, global average pool, fc.
pub fn resnet18() -> Network {
    let mut b = NetworkBuilder::new("ResNet18", Shape::Chw(3, 224, 224))
        .conv("conv1", 64, 7, 2, 3, 1, false)
        .bn("bn1")
        .relu("relu1")
        .cut_here("conv1")
        .maxpool("maxpool", 3, 2, 1)
        .cut_here("maxpool");
    b = stages(b, 64);
    b.adaptive_avgpool("avgpool", 1)
        .flatten("flatten")
        .linear("fc", 1000)
        .build()
}

/// CIFAR-geometry slim variant: 3x3-s1 stem (no downsampling maxpool —
/// at 32x32 the ImageNet stem would collapse the map to 8x8 before the
/// first block), same 4-stage BasicBlock plan. To keep the split-point
/// count (and ids) aligned with [`resnet18`], the identity position of
/// the removed maxpool is still marked as candidate 1.
pub fn resnet18_cifar(num_classes: usize) -> Network {
    let mut b = NetworkBuilder::new("ResNet18-cifar", Shape::Chw(3, 32, 32))
        .conv("conv1", 64, 3, 1, 1, 1, false)
        .bn("bn1")
        .relu("relu1")
        .cut_here("conv1")
        .cut_here("maxpool");
    b = stages(b, 64);
    b.adaptive_avgpool("avgpool", 1)
        .flatten("flatten")
        .linear("fc", num_classes)
        .build()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::cut::{split_points, valid_cuts};

    #[test]
    fn resnet18_torchvision_total_params() {
        // Torchvision golden: conv weights (bias-free) + BN affine pairs
        // + fc = 11,689,512.
        assert_eq!(resnet18().total_params(), 11_689_512);
    }

    #[test]
    fn resnet18_stage_shapes() {
        let net = resnet18();
        let shape_of = |name: &str| {
            net.layers().find(|l| l.name == name).unwrap().out
        };
        assert_eq!(shape_of("conv1"), Shape::Chw(64, 112, 112));
        assert_eq!(shape_of("maxpool"), Shape::Chw(64, 56, 56));
        assert_eq!(shape_of("layer2.0.add"), Shape::Chw(128, 28, 28));
        assert_eq!(shape_of("layer4.1.add"), Shape::Chw(512, 7, 7));
        assert_eq!(net.output(), Shape::Flat(1000));
    }

    #[test]
    fn resnet18_has_ten_split_points_at_block_boundaries() {
        for net in [resnet18(), resnet18_cifar(10)] {
            let pts = split_points(&net);
            assert_eq!(pts.len(), 10, "{}", net.name);
            assert_eq!(pts[0].name, "conv1");
            assert_eq!(pts[1].name, "maxpool");
            assert_eq!(pts[2].name, "layer1.0");
            assert_eq!(pts[9].name, "layer4.1");
            for p in &pts {
                assert_eq!(
                    p.head_mult_adds + p.tail_mult_adds,
                    net.mult_adds(),
                    "{} cut {}",
                    net.name,
                    p.name
                );
            }
        }
    }

    #[test]
    fn residual_interiors_are_not_valid_cuts() {
        let net = resnet18();
        let cuts = valid_cuts(&net);
        // No valid frontier may sit strictly between a block's first conv
        // and its merge: the skip edge would cross alongside the main
        // path. Check layer1.0 (identity shortcut) explicitly.
        let first = net
            .nodes
            .iter()
            .position(|n| n.layer.name == "layer1.0.conv1")
            .unwrap();
        let add = net
            .nodes
            .iter()
            .position(|n| n.layer.name == "layer1.0.add")
            .unwrap();
        for c in &cuts {
            assert!(
                c.pos < first || c.pos >= add,
                "cut at node {} ({}) crosses the layer1.0 skip edge",
                c.pos,
                c.name
            );
        }
    }

    #[test]
    fn projection_blocks_have_downsample_params() {
        let net = resnet18();
        assert!(net
            .layers()
            .any(|l| l.name == "layer2.0.downsample.0" && l.params() == 8192));
        // Identity blocks have none.
        assert!(!net.layers().any(|l| l.name == "layer1.0.downsample.0"));
    }

    #[test]
    fn cifar_variant_keeps_split_ids_but_shrinks_compute() {
        let full = resnet18();
        let slim = resnet18_cifar(10);
        let fp = split_points(&full);
        let sp = split_points(&slim);
        assert_eq!(fp.len(), sp.len());
        for (f, s) in fp.iter().zip(&sp) {
            assert_eq!(f.name, s.name);
        }
        assert!(slim.mult_adds() < full.mult_adds());
        assert_eq!(slim.output(), Shape::Flat(10));
        // Pinned regression values (verified against the transliterated
        // reference): CIFAR variant params and ImageNet mult-adds.
        assert_eq!(slim.total_params(), 11_173_962);
        assert_eq!(full.mult_adds(), 1_814_074_344);
    }
}
