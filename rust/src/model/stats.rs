//! Table I / Table II generators: torchinfo-style per-layer summary and
//! aggregate statistics (paper Sec. V-D).

use super::layer::Network;
use crate::util::table;

/// One row of the Table-I style summary.
#[derive(Clone, Debug)]
pub struct SummaryRow {
    pub name: String,
    pub type_name: &'static str,
    pub depth_idx: String,
    pub output_shape: String,
    pub params: Option<u64>,
}

pub fn summary_rows(net: &Network, batch: usize) -> Vec<SummaryRow> {
    net.layers()
        .enumerate()
        .map(|(i, l)| SummaryRow {
            name: l.name.clone(),
            type_name: l.type_name(),
            depth_idx: format!("2-{}", i + 1),
            output_shape: l.out.render(batch),
            params: if l.is_parameterized() {
                Some(l.params())
            } else {
                None
            },
        })
        .collect()
}

/// Render Table I ("The neural network summary provided for the VGG16").
pub fn render_table1(net: &Network, batch: usize) -> String {
    let rows: Vec<Vec<String>> = summary_rows(net, batch)
        .into_iter()
        .map(|r| {
            vec![
                format!("{}: {}", r.type_name, r.depth_idx),
                r.output_shape,
                r.params
                    .map(|p| table::group_digits(p))
                    .unwrap_or_else(|| "—".to_string()),
            ]
        })
        .collect();
    table::render(&["Layer (type:depth-idx)", "Output Shape", "Param (#)"],
                  &rows)
}

/// Aggregate statistics (Table II), torchinfo conventions — see
/// `model::layer` module docs. Sizes in decimal MB as the paper prints.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ModelStats {
    pub total_params: u64,
    pub trainable_params: u64,
    pub mult_adds_g: f64,
    pub input_mb: f64,
    pub fwd_bwd_mb: f64,
    pub params_mb: f64,
    pub total_mb: f64,
}

pub fn model_stats(net: &Network, batch: usize) -> ModelStats {
    let p = net.total_params();
    let input_mb = (batch * net.input.bytes_f32()) as f64 / 1e6;
    let fwd_bwd_mb =
        (2 * 4 * batch as u64 * net.param_layer_out_elements()) as f64 / 1e6;
    let params_mb = (p * 4) as f64 / 1e6;
    ModelStats {
        total_params: p,
        trainable_params: p,
        mult_adds_g: (net.mult_adds() * batch as u64) as f64 / 1e9,
        input_mb,
        fwd_bwd_mb,
        params_mb,
        total_mb: input_mb + fwd_bwd_mb + params_mb,
    }
}

/// Render Table II ("The neural network statistics provided for the VGG16").
pub fn render_table2(net: &Network, batch: usize) -> String {
    let s = model_stats(net, batch);
    let rows = vec![
        vec!["Total params".to_string(), table::group_digits(s.total_params)],
        vec![
            "Trainable params".to_string(),
            table::group_digits(s.trainable_params),
        ],
        vec![
            "Total mult-adds (G)".to_string(),
            format!("{:.2}", s.mult_adds_g),
        ],
        vec![
            "Input size (MB)".to_string(),
            format!("{:.2}", s.input_mb),
        ],
        vec![
            "Forward/backward pass size (MB)".to_string(),
            format!("{:.2}", s.fwd_bwd_mb),
        ],
        vec![
            "Params size (MB)".to_string(),
            format!("{:.2}", s.params_mb),
        ],
        vec![
            "Estimated Total Size (MB)".to_string(),
            format!("{:.2}", s.total_mb),
        ],
    ];
    table::render(&["Statistic", "Value"], &rows)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::vgg::vgg16_full;

    #[test]
    fn table2_reproduces_paper_exactly() {
        let s = model_stats(&vgg16_full(), 16);
        assert_eq!(s.total_params, 138_357_544);
        assert_eq!(s.trainable_params, 138_357_544);
        assert!((s.mult_adds_g - 247.74).abs() < 0.005, "{}", s.mult_adds_g);
        assert!((s.fwd_bwd_mb - 1735.26).abs() < 0.01, "{}", s.fwd_bwd_mb);
        assert!((s.total_mb - 2298.32).abs() < 0.01, "{}", s.total_mb);
    }

    #[test]
    fn table1_contains_paper_rows() {
        let t = render_table1(&vgg16_full(), 16);
        assert!(t.contains("[16, 64, 224, 224]"));
        assert!(t.contains("1.792"));
        assert!(t.contains("102.764.544"));
        assert!(t.contains("4.097.000"));
        assert!(t.contains("[16, 1000]"));
    }

    #[test]
    fn table2_renders_all_rows() {
        let t = render_table2(&vgg16_full(), 16);
        assert!(t.contains("138.357.544"));
        assert!(t.contains("247.74"));
        assert!(t.contains("1735.26"));
        assert!(t.contains("2298.32"));
    }

    #[test]
    fn unparameterized_rows_have_no_params() {
        let rows = summary_rows(&vgg16_full(), 16);
        let relu = rows.iter().find(|r| r.type_name == "ReLU").unwrap();
        assert!(relu.params.is_none());
    }

    #[test]
    fn stats_scale_with_batch() {
        let net = vgg16_full();
        let s1 = model_stats(&net, 1);
        let s16 = model_stats(&net, 16);
        assert!((s16.mult_adds_g / s1.mult_adds_g - 16.0).abs() < 1e-9);
        assert_eq!(s1.total_params, s16.total_params);
    }
}
