//! Device profiles: the *computation platform* axis of the paper's
//! three-dimensional design space (Sec. I). Compute time is modelled as
//! mult-adds / effective-throughput, the same first-order model the paper's
//! simulator uses for the timing of the computation phases.
//!
//! With multi-tier placement the platform axis is a *chain* of devices
//! (sensor -> edge -> cloud); [`DeviceProfile::parse`] is the single parse
//! path shared by the CLI (`--edge`, `--server`, `--tiers`) and sweep-spec
//! JSON: it accepts the built-in profile names plus custom
//! `name@<macs_per_sec>+<overhead_ns>` specs (e.g. `tpu@2e12+100000`).

use anyhow::{bail, Result};

use crate::netsim::event::SimTime;

#[derive(Clone, Debug)]
pub struct DeviceProfile {
    pub name: String,
    /// Effective throughput in mult-adds per second (MACs/s), i.e. already
    /// discounted for achievable utilization, not peak datasheet FLOPs.
    pub macs_per_sec: f64,
    /// Fixed per-inference overhead (kernel launch, DMA, runtime), ns.
    pub overhead_ns: SimTime,
}

impl DeviceProfile {
    fn named(name: &str, macs_per_sec: f64, overhead_ns: SimTime) -> Self {
        DeviceProfile { name: name.to_string(), macs_per_sec, overhead_ns }
    }

    /// Microcontroller-class sensing device (Cortex-M with CMSIS-NN):
    /// suitable only for the first few layers of a slim head.
    pub fn sensor_mcu() -> Self {
        Self::named("sensor-mcu", 2e8, 500_000)
    }

    /// Camera-attached NPU (Coral/Ethos-class, int8): runs a shallow head
    /// in real time but cannot hold a full backbone.
    pub fn sensor_npu() -> Self {
        Self::named("sensor-npu", 5e10, 400_000)
    }

    /// Embedded CPU-class sensing device (Cortex-A with NEON).
    pub fn edge_cpu() -> Self {
        Self::named("edge-cpu", 4e9, 200_000)
    }

    /// Embedded GPU/NPU-class sensing device (Jetson-class, fp16).
    /// 1e12 MACs/s ≈ a Xavier-class NX at realistic utilization — head@L11
    /// of VGG16@224 (~11 GMAC) in ~11 ms, inside the ICE-Lab 50 ms budget.
    pub fn edge_gpu() -> Self {
        Self::named("edge-gpu", 1e12, 300_000)
    }

    /// Server-class accelerator.
    pub fn server_gpu() -> Self {
        Self::named("server-gpu", 1e13, 150_000)
    }

    pub fn by_name(name: &str) -> Option<DeviceProfile> {
        match name {
            "sensor-mcu" => Some(Self::sensor_mcu()),
            "sensor-npu" => Some(Self::sensor_npu()),
            "edge-cpu" => Some(Self::edge_cpu()),
            "edge-gpu" => Some(Self::edge_gpu()),
            "server-gpu" => Some(Self::server_gpu()),
            _ => None,
        }
    }

    /// Parse a device spec: a built-in profile name, or a custom
    /// `name@<macs_per_sec>+<overhead_ns>` triple (throughput accepts
    /// scientific notation, overhead is integer nanoseconds). The one
    /// parse path behind CLI `--tiers`/`--edge`/`--server` and the sweep
    /// spec's `tiers` axis.
    pub fn parse(spec: &str) -> Result<DeviceProfile> {
        if let Some(p) = Self::by_name(spec) {
            return Ok(p);
        }
        let Some((name, rest)) = spec.split_once('@') else {
            bail!(
                "unknown device profile '{spec}' (built-ins: sensor-mcu | \
                 sensor-npu | edge-cpu | edge-gpu | server-gpu; custom: \
                 name@<macs_per_sec>+<overhead_ns>)"
            );
        };
        // Split at the *last* '+': the overhead is an integer (never
        // signed), so MACs/s may use an explicit-plus exponent
        // ("tpu@2e+12+100000").
        let Some((macs, overhead)) = rest.rsplit_once('+') else {
            bail!(
                "custom device '{spec}' must be \
                 name@<macs_per_sec>+<overhead_ns>"
            );
        };
        if name.is_empty() {
            bail!("custom device '{spec}' has an empty name");
        }
        let macs_per_sec: f64 = macs.parse().map_err(|_| {
            anyhow::anyhow!("custom device '{spec}': bad MACs/s '{macs}'")
        })?;
        if !macs_per_sec.is_finite() || macs_per_sec <= 0.0 {
            bail!("custom device '{spec}': MACs/s must be positive");
        }
        let overhead_ns: SimTime = overhead.parse().map_err(|_| {
            anyhow::anyhow!(
                "custom device '{spec}': bad overhead '{overhead}' \
                 (integer ns)"
            )
        })?;
        Ok(DeviceProfile::named(name, macs_per_sec, overhead_ns))
    }

    /// Parse a comma-separated tier chain (`sensor-npu,edge-gpu,server-gpu`),
    /// sensor-side first. Every element goes through [`DeviceProfile::parse`];
    /// empty elements (stray commas) are an error, not silently dropped —
    /// a typo must not shorten the chain.
    pub fn parse_tiers(list: &str) -> Result<Vec<DeviceProfile>> {
        if list.split(',').any(|s| s.trim().is_empty()) {
            bail!(
                "tier chain '{list}' has an empty element (expected a \
                 comma-separated device list, sensor side first)"
            );
        }
        list.split(',').map(|s| Self::parse(s.trim())).collect()
    }

    /// Simulated wall time to execute `mult_adds` MACs on this device.
    pub fn compute_ns(&self, mult_adds: u64) -> SimTime {
        self.overhead_ns
            + ((mult_adds as f64 / self.macs_per_sec) * 1e9).round() as SimTime
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compute_time_scales_linearly() {
        let d = DeviceProfile::edge_gpu();
        let t1 = d.compute_ns(1_000_000_000) - d.overhead_ns;
        let t2 = d.compute_ns(2_000_000_000) - d.overhead_ns;
        assert_eq!(t2, 2 * t1);
    }

    #[test]
    fn zero_work_costs_overhead_only() {
        let d = DeviceProfile::server_gpu();
        assert_eq!(d.compute_ns(0), d.overhead_ns);
    }

    #[test]
    fn server_faster_than_edge() {
        let ma = 15_470_264_320u64; // one VGG16 image
        assert!(
            DeviceProfile::server_gpu().compute_ns(ma)
                < DeviceProfile::edge_gpu().compute_ns(ma)
        );
        assert!(
            DeviceProfile::edge_gpu().compute_ns(ma)
                < DeviceProfile::edge_cpu().compute_ns(ma)
        );
        // The sensor tiers sit below the edge devices in throughput.
        assert!(
            DeviceProfile::sensor_npu().macs_per_sec
                < DeviceProfile::edge_gpu().macs_per_sec
        );
        assert!(
            DeviceProfile::sensor_mcu().macs_per_sec
                < DeviceProfile::sensor_npu().macs_per_sec
        );
    }

    #[test]
    fn by_name_roundtrip() {
        for n in ["sensor-mcu", "sensor-npu", "edge-cpu", "edge-gpu",
                  "server-gpu"] {
            assert_eq!(DeviceProfile::by_name(n).unwrap().name, n);
        }
        assert!(DeviceProfile::by_name("tpu-v9").is_none());
    }

    #[test]
    fn parse_accepts_builtins_and_custom_specs() {
        assert_eq!(DeviceProfile::parse("edge-gpu").unwrap().name, "edge-gpu");
        let c = DeviceProfile::parse("tpu@2e12+100000").unwrap();
        assert_eq!(c.name, "tpu");
        assert_eq!(c.macs_per_sec, 2e12);
        assert_eq!(c.overhead_ns, 100_000);
        // Explicit-plus exponents split at the *last* '+'.
        let e = DeviceProfile::parse("tpu@2e+12+100000").unwrap();
        assert_eq!(e.macs_per_sec, 2e12);
        assert_eq!(e.overhead_ns, 100_000);
        assert_eq!(c.compute_ns(2_000_000_000_000), 100_000 + 1_000_000_000);
        // Malformed custom specs fail with a clear error.
        assert!(DeviceProfile::parse("tpu-v9").is_err());
        assert!(DeviceProfile::parse("tpu@fast+1").is_err());
        assert!(DeviceProfile::parse("tpu@1e12").is_err());
        assert!(DeviceProfile::parse("tpu@-1e12+5").is_err());
        assert!(DeviceProfile::parse("tpu@1e12+5.5").is_err());
        assert!(DeviceProfile::parse("@1e12+5").is_err());
    }

    #[test]
    fn parse_tiers_builds_the_chain() {
        let t = DeviceProfile::parse_tiers(
            "sensor-npu, edge-gpu, server-gpu",
        )
        .unwrap();
        assert_eq!(t.len(), 3);
        assert_eq!(t[0].name, "sensor-npu");
        assert_eq!(t[2].name, "server-gpu");
        assert!(DeviceProfile::parse_tiers("edge-gpu,nope").is_err());
        assert!(DeviceProfile::parse_tiers(" , ").is_err());
        // Stray commas must not silently shorten the chain.
        assert!(DeviceProfile::parse_tiers("edge-gpu,,server-gpu").is_err());
        assert!(DeviceProfile::parse_tiers("edge-gpu,server-gpu,").is_err());
    }

    #[test]
    fn edge_gpu_runs_vgg16_head_in_tens_of_ms() {
        // Sanity for the Fig. 3 scenario: head@L11 of VGG16@224 ≈ 11 GMAC
        // on the edge GPU ≈ 22 ms — inside a 50 ms frame budget.
        let d = DeviceProfile::edge_gpu();
        let t = d.compute_ns(11_000_000_000);
        assert!(t > 5_000_000 && t < 50_000_000, "{t}");
    }
}
