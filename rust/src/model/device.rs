//! Device profiles: the *computation platform* axis of the paper's
//! three-dimensional design space (Sec. I). Compute time is modelled as
//! mult-adds / effective-throughput, the same first-order model the paper's
//! simulator uses for the timing of the computation phases.

use crate::netsim::event::SimTime;

#[derive(Clone, Debug)]
pub struct DeviceProfile {
    pub name: &'static str,
    /// Effective throughput in mult-adds per second (MACs/s), i.e. already
    /// discounted for achievable utilization, not peak datasheet FLOPs.
    pub macs_per_sec: f64,
    /// Fixed per-inference overhead (kernel launch, DMA, runtime), ns.
    pub overhead_ns: SimTime,
}

impl DeviceProfile {
    /// Embedded CPU-class sensing device (Cortex-A with NEON).
    pub fn edge_cpu() -> Self {
        DeviceProfile {
            name: "edge-cpu",
            macs_per_sec: 4e9,
            overhead_ns: 200_000,
        }
    }

    /// Embedded GPU/NPU-class sensing device (Jetson-class, fp16).
    /// 1e12 MACs/s ≈ a Xavier-class NX at realistic utilization — head@L11
    /// of VGG16@224 (~11 GMAC) in ~11 ms, inside the ICE-Lab 50 ms budget.
    pub fn edge_gpu() -> Self {
        DeviceProfile {
            name: "edge-gpu",
            macs_per_sec: 1e12,
            overhead_ns: 300_000,
        }
    }

    /// Server-class accelerator.
    pub fn server_gpu() -> Self {
        DeviceProfile {
            name: "server-gpu",
            macs_per_sec: 1e13,
            overhead_ns: 150_000,
        }
    }

    pub fn by_name(name: &str) -> Option<DeviceProfile> {
        match name {
            "edge-cpu" => Some(Self::edge_cpu()),
            "edge-gpu" => Some(Self::edge_gpu()),
            "server-gpu" => Some(Self::server_gpu()),
            _ => None,
        }
    }

    /// Simulated wall time to execute `mult_adds` MACs on this device.
    pub fn compute_ns(&self, mult_adds: u64) -> SimTime {
        self.overhead_ns
            + ((mult_adds as f64 / self.macs_per_sec) * 1e9).round() as SimTime
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compute_time_scales_linearly() {
        let d = DeviceProfile::edge_gpu();
        let t1 = d.compute_ns(1_000_000_000) - d.overhead_ns;
        let t2 = d.compute_ns(2_000_000_000) - d.overhead_ns;
        assert_eq!(t2, 2 * t1);
    }

    #[test]
    fn zero_work_costs_overhead_only() {
        let d = DeviceProfile::server_gpu();
        assert_eq!(d.compute_ns(0), d.overhead_ns);
    }

    #[test]
    fn server_faster_than_edge() {
        let ma = 15_470_264_320u64; // one VGG16 image
        assert!(
            DeviceProfile::server_gpu().compute_ns(ma)
                < DeviceProfile::edge_gpu().compute_ns(ma)
        );
        assert!(
            DeviceProfile::edge_gpu().compute_ns(ma)
                < DeviceProfile::edge_cpu().compute_ns(ma)
        );
    }

    #[test]
    fn by_name_roundtrip() {
        for n in ["edge-cpu", "edge-gpu", "server-gpu"] {
            assert_eq!(DeviceProfile::by_name(n).unwrap().name, n);
        }
        assert!(DeviceProfile::by_name("tpu-v9").is_none());
    }

    #[test]
    fn edge_gpu_runs_vgg16_head_in_tens_of_ms() {
        // Sanity for the Fig. 3 scenario: head@L11 of VGG16@224 ≈ 11 GMAC
        // on the edge GPU ≈ 22 ms — inside a 50 ms frame budget.
        let d = DeviceProfile::edge_gpu();
        let t = d.compute_ns(11_000_000_000);
        assert!(t > 5_000_000 && t < 50_000_000, "{t}");
    }
}
