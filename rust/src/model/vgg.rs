//! VGG16 model definitions: the paper's full torchvision VGG16 (Tables I/II,
//! Fig. 3/4 transmission volumetrics at 224x224) and the slim variant that
//! matches the trained JAX model in `python/compile/model.py`.

use super::layer::{Network, NetworkBuilder, Shape};

/// VGG16 conv plan: (block, convs, out channels).
pub const VGG16_BLOCKS: [(usize, usize, usize); 5] =
    [(1, 2, 64), (2, 2, 128), (3, 3, 256), (4, 3, 512), (5, 3, 512)];

/// Keras-style names of the 18 feature layers (13 conv + 5 pool), matching
/// `python/compile/model.py::VGG16_LAYER_NAMES` and the paper's Fig. 2.
pub fn feature_layer_names() -> Vec<String> {
    let mut names = Vec::with_capacity(18);
    for (b, convs, _) in VGG16_BLOCKS {
        for c in 1..=convs {
            names.push(format!("block{b}_conv{c}"));
        }
        names.push(format!("block{b}_pool"));
    }
    names
}

pub const NUM_FEATURE_LAYERS: usize = 18;

fn scaled(ch: usize, width_mult: f64) -> usize {
    ((ch as f64 * width_mult) as usize).max(4)
}

/// Torchvision VGG16 exactly as summarized in the paper's Table I:
/// 224x224x3 input, avgpool to 7x7, classifier 4096/4096/1000 with ReLU and
/// Dropout rows.
pub fn vgg16_full() -> Network {
    let mut b = NetworkBuilder::new("VGG16", Shape::Chw(3, 224, 224));
    for (blk, convs, ch) in VGG16_BLOCKS {
        for c in 1..=convs {
            b = b
                .conv3x3(&format!("block{blk}_conv{c}"), ch)
                .relu(&format!("block{blk}_relu{c}"));
        }
        b = b.maxpool2(&format!("block{blk}_pool"));
    }
    b.adaptive_avgpool("avgpool", 7)
        .flatten("flatten")
        .linear("fc1", 4096)
        .relu("fc1_relu")
        .dropout("fc1_drop")
        .linear("fc2", 4096)
        .relu("fc2_relu")
        .dropout("fc2_drop")
        .linear("fc3", 1000)
        .build()
}

/// The slim trained model: VGG16 topology at `img_size` with channel widths
/// scaled by `width_mult`, flatten straight into a small classifier. Must
/// stay in lockstep with `python/compile/model.py`.
pub fn vgg16_slim(img_size: usize, width_mult: f64, hidden: usize,
                  num_classes: usize) -> Network {
    let mut b = NetworkBuilder::new(
        "VGG16-slim",
        Shape::Chw(3, img_size, img_size),
    );
    for (blk, convs, ch) in VGG16_BLOCKS {
        let oc = scaled(ch, width_mult);
        for c in 1..=convs {
            b = b
                .conv3x3(&format!("block{blk}_conv{c}"), oc)
                .relu(&format!("block{blk}_relu{c}"));
        }
        b = b.maxpool2(&format!("block{blk}_pool"));
    }
    b.flatten("flatten")
        .linear("fc0", hidden)
        .relu("fc0_relu")
        .linear("fc1", num_classes)
        .build()
}

/// Metadata of one of the 18 feature layers (ReLU folded into its conv),
/// indexed 0..17 as in the paper's Fig. 2 and the python model.
#[derive(Clone, Debug)]
pub struct FeatureLayer {
    pub index: usize,
    pub name: String,
    pub is_pool: bool,
    pub out: Shape,
    pub params: u64,
    /// Mult-adds per image for this layer alone.
    pub mult_adds: u64,
}

impl FeatureLayer {
    /// Bytes of the raw activation at this layer (f32, per image).
    pub fn activation_bytes(&self) -> u64 {
        self.out.bytes_f32() as u64
    }

    /// Bytes of the 50%-compressed bottleneck latent transmitted when
    /// splitting here (channel dimension halved, per the paper's AEs).
    pub fn latent_bytes(&self) -> u64 {
        let Shape::Chw(c, h, w) = self.out else { unreachable!() };
        ((c / 2).max(1) * h * w * 4) as u64
    }
}

/// Extract the 18 feature layers of a (full or slim) VGG16 network built by
/// this module, with cumulative-friendly per-layer costs.
pub fn feature_layers(net: &Network) -> Vec<FeatureLayer> {
    let mut out = Vec::with_capacity(NUM_FEATURE_LAYERS);
    for l in &net.layers {
        match l.kind {
            super::layer::LayerKind::Conv2d { .. }
                if l.name.starts_with("block") =>
            {
                out.push(FeatureLayer {
                    index: out.len(),
                    name: l.name.clone(),
                    is_pool: false,
                    out: l.out,
                    params: l.params(),
                    mult_adds: l.mult_adds(),
                });
            }
            super::layer::LayerKind::MaxPool2 => {
                out.push(FeatureLayer {
                    index: out.len(),
                    name: l.name.clone(),
                    is_pool: true,
                    out: l.out,
                    params: 0,
                    mult_adds: 0,
                });
            }
            _ => {}
        }
    }
    assert_eq!(out.len(), NUM_FEATURE_LAYERS);
    out
}

/// Mult-adds per image of the head (feature layers 0..=split, plus the
/// bottleneck encoder conv) and of the tail (decoder conv + remaining
/// feature layers + classifier).
pub fn split_compute(net: &Network, split: usize) -> (u64, u64) {
    let feats = feature_layers(net);
    assert!(split < NUM_FEATURE_LAYERS - 1, "split {split} out of range");
    let head_feat: u64 = feats[..=split].iter().map(|f| f.mult_adds).sum();
    let tail_feat: u64 = feats[split + 1..].iter().map(|f| f.mult_adds).sum();
    let classifier: u64 = net
        .layers
        .iter()
        .filter(|l| matches!(l.kind, super::layer::LayerKind::Linear { .. }))
        .map(|l| l.mult_adds())
        .sum();
    // Bottleneck convs: encoder C->C/2 3x3 at the split's spatial size,
    // decoder C/2->C (mirrors python/compile/bottleneck.py).
    let Shape::Chw(c, h, w) = feats[split].out else { unreachable!() };
    let zc = (c / 2).max(1);
    let enc = (zc * h * w) as u64 * (c * 9) as u64 + (zc * h * w) as u64;
    let dec = (c * h * w) as u64 * (zc * 9) as u64 + (c * h * w) as u64;
    (head_feat + enc, dec + tail_feat + classifier)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vgg16_paper_total_params() {
        // Paper Table II: 138,357,544.
        assert_eq!(vgg16_full().total_params(), 138_357_544);
    }

    #[test]
    fn vgg16_paper_mult_adds_batch16() {
        // Paper Table II: 247.74 G mult-adds at batch 16.
        let g = vgg16_full().mult_adds() as f64 * 16.0 / 1e9;
        assert!((g - 247.74).abs() < 0.005, "{g}");
    }

    #[test]
    fn vgg16_table1_spot_rows() {
        let net = vgg16_full();
        let c1 = net.layers.iter().find(|l| l.name == "block1_conv1").unwrap();
        assert_eq!(c1.params(), 1_792);
        assert_eq!(c1.out, Shape::Chw(64, 224, 224));
        let fc1 = net.layers.iter().find(|l| l.name == "fc1").unwrap();
        assert_eq!(fc1.params(), 102_764_544);
        let fc3 = net.layers.iter().find(|l| l.name == "fc3").unwrap();
        assert_eq!(fc3.params(), 4_097_000);
    }

    #[test]
    fn feature_layer_names_match_paper_candidates() {
        let names = feature_layer_names();
        assert_eq!(names.len(), 18);
        // Paper Fig. 2 (0-based feature indexing):
        assert_eq!(names[5], "block2_pool");
        assert_eq!(names[9], "block3_pool");
        assert_eq!(names[11], "block4_conv2");
        assert_eq!(names[13], "block4_pool");
        assert_eq!(names[15], "block5_conv2");
    }

    #[test]
    fn feature_layers_of_full_vgg16() {
        let f = feature_layers(&vgg16_full());
        assert_eq!(f.len(), 18);
        assert_eq!(f[11].name, "block4_conv2");
        assert_eq!(f[11].out, Shape::Chw(512, 28, 28));
        // latent at 50% compression: 256x28x28 f32
        assert_eq!(f[11].latent_bytes(), 256 * 28 * 28 * 4);
        assert_eq!(f[15].out, Shape::Chw(512, 14, 14));
        assert_eq!(f[15].latent_bytes(), 256 * 14 * 14 * 4);
    }

    #[test]
    fn slim_matches_python_total_params() {
        // python: compile.model.total_params(ModelConfig(0.125)) == 235378
        let net = vgg16_slim(32, 0.125, 64, 10);
        assert_eq!(net.total_params(), 235_378);
    }

    #[test]
    fn slim_feature_shapes() {
        let f = feature_layers(&vgg16_slim(32, 0.125, 64, 10));
        assert_eq!(f[0].out, Shape::Chw(8, 32, 32));
        assert_eq!(f[17].out, Shape::Chw(64, 1, 1));
        assert_eq!(f[11].out, Shape::Chw(64, 4, 4));
    }

    #[test]
    fn split_compute_sums_to_more_than_full() {
        // head+tail >= full (bottleneck adds compute)
        let net = vgg16_full();
        let full = net.mult_adds();
        for s in [5usize, 9, 11, 13, 15] {
            let (h, t) = split_compute(&net, s);
            assert!(h + t > full, "split {s}");
            assert!(h < h + t);
        }
    }

    #[test]
    fn split_head_grows_with_split_point() {
        let net = vgg16_full();
        let mut prev = 0;
        for s in [5usize, 9, 11, 13, 15] {
            let (h, _) = split_compute(&net, s);
            assert!(h > prev);
            prev = h;
        }
    }
}
