//! VGG16 model definitions: the paper's full torchvision VGG16 (Tables I/II,
//! Fig. 3/4 transmission volumetrics at 224x224) and the slim variant that
//! matches the trained JAX model in `python/compile/model.py`.
//!
//! Both builders mark the 18 feature layers (13 conv+ReLU pairs, named
//! after the conv, plus 5 pools) as split-point candidates, so
//! [`super::cut::split_points`] reproduces the paper's Fig. 2 indexing
//! `0..=17` exactly.

use super::cut::{split_points, Cut};
use super::layer::{LayerKind, Network, NetworkBuilder, Shape};

/// VGG16 conv plan: (block, convs, out channels).
pub const VGG16_BLOCKS: [(usize, usize, usize); 5] =
    [(1, 2, 64), (2, 2, 128), (3, 3, 256), (4, 3, 512), (5, 3, 512)];

/// Keras-style names of the 18 feature layers (13 conv + 5 pool), matching
/// `python/compile/model.py::VGG16_LAYER_NAMES` and the paper's Fig. 2.
pub fn feature_layer_names() -> Vec<String> {
    let mut names = Vec::with_capacity(NUM_FEATURE_LAYERS);
    for (b, convs, _) in VGG16_BLOCKS {
        for c in 1..=convs {
            names.push(format!("block{b}_conv{c}"));
        }
        names.push(format!("block{b}_pool"));
    }
    names
}

/// Number of feature layers, derived from the conv plan (one candidate
/// per conv plus one per block pool) instead of a free-standing literal.
pub const NUM_FEATURE_LAYERS: usize = num_feature_layers();

const fn num_feature_layers() -> usize {
    let mut n = 0;
    let mut i = 0;
    while i < VGG16_BLOCKS.len() {
        n += VGG16_BLOCKS[i].1 + 1;
        i += 1;
    }
    n
}

/// Channel width scaled by `width_mult`, rounded half-up (the old
/// implementation silently truncated, so e.g. `scaled(30, 0.15)` lost
/// almost half a channel), floored at 4 channels.
fn scaled(ch: usize, width_mult: f64) -> usize {
    ((ch as f64 * width_mult + 0.5).floor() as usize).max(4)
}

fn features(mut b: NetworkBuilder, width_mult: Option<f64>) -> NetworkBuilder {
    for (blk, convs, ch) in VGG16_BLOCKS {
        let oc = width_mult.map(|m| scaled(ch, m)).unwrap_or(ch);
        for c in 1..=convs {
            b = b
                .conv3x3(&format!("block{blk}_conv{c}"), oc)
                .relu(&format!("block{blk}_relu{c}"))
                .cut_here(&format!("block{blk}_conv{c}"));
        }
        b = b
            .maxpool2(&format!("block{blk}_pool"))
            .cut_here(&format!("block{blk}_pool"));
    }
    b
}

/// Torchvision VGG16 exactly as summarized in the paper's Table I:
/// 224x224x3 input, avgpool to 7x7, classifier 4096/4096/1000 with ReLU and
/// Dropout rows.
pub fn vgg16_full() -> Network {
    let b = features(
        NetworkBuilder::new("VGG16", Shape::Chw(3, 224, 224)),
        None,
    );
    b.adaptive_avgpool("avgpool", 7)
        .flatten("flatten")
        .linear("fc1", 4096)
        .relu("fc1_relu")
        .dropout("fc1_drop")
        .linear("fc2", 4096)
        .relu("fc2_relu")
        .dropout("fc2_drop")
        .linear("fc3", 1000)
        .build()
}

/// The slim trained model: VGG16 topology at `img_size` with channel widths
/// scaled by `width_mult`, flatten straight into a small classifier. Must
/// stay in lockstep with `python/compile/model.py`.
pub fn vgg16_slim(img_size: usize, width_mult: f64, hidden: usize,
                  num_classes: usize) -> Network {
    let b = features(
        NetworkBuilder::new("VGG16-slim", Shape::Chw(3, img_size, img_size)),
        Some(width_mult),
    );
    b.flatten("flatten")
        .linear("fc0", hidden)
        .relu("fc0_relu")
        .linear("fc1", num_classes)
        .build()
}

/// Metadata of one of the 18 feature layers (ReLU folded into its conv),
/// indexed 0..17 as in the paper's Fig. 2 and the python model. Kept as
/// the VGG-specific view of [`split_points`]; new code should use the
/// arch-agnostic [`Cut`]s directly.
#[derive(Clone, Debug)]
pub struct FeatureLayer {
    pub index: usize,
    pub name: String,
    pub is_pool: bool,
    pub out: Shape,
    pub params: u64,
    /// Mult-adds per image for this layer alone.
    pub mult_adds: u64,
}

impl FeatureLayer {
    /// Bytes of the raw activation at this layer (f32, per image).
    pub fn activation_bytes(&self) -> u64 {
        self.out.bytes_f32() as u64
    }

    /// Bytes of the 50%-compressed bottleneck latent transmitted when
    /// splitting here (channel dimension halved, per the paper's AEs).
    pub fn latent_bytes(&self) -> u64 {
        let Shape::Chw(c, h, w) = self.out else { unreachable!() };
        ((c / 2).max(1) * h * w * 4) as u64
    }
}

/// Extract the 18 feature layers of a (full or slim) VGG16 network built by
/// this module, as per-layer deltas of the marked split points.
pub fn feature_layers(net: &Network) -> Vec<FeatureLayer> {
    let pts: Vec<Cut> = split_points(net);
    assert_eq!(pts.len(), NUM_FEATURE_LAYERS);
    // Cumulative params up to each node, to attribute each cut segment's
    // params to its candidate (the conv between two consecutive cuts).
    let mut cum_params = vec![0u64; net.len()];
    let mut acc = 0u64;
    for (i, c) in cum_params.iter_mut().enumerate() {
        acc += net.layer(i).params();
        *c = acc;
    }
    let mut out = Vec::with_capacity(pts.len());
    let mut prev_ma = 0u64;
    let mut prev_p = 0u64;
    for cut in &pts {
        let is_pool = matches!(
            net.layer(cut.source).kind,
            LayerKind::MaxPool2 | LayerKind::MaxPool { .. }
        );
        let p = cum_params[cut.pos];
        out.push(FeatureLayer {
            index: cut.index,
            name: cut.name.clone(),
            is_pool,
            out: cut.out,
            params: p - prev_p,
            mult_adds: cut.head_mult_adds - prev_ma,
        });
        prev_ma = cut.head_mult_adds;
        prev_p = p;
    }
    out
}

/// Mult-adds per image of the head (feature layers 0..=split, plus the
/// bottleneck encoder conv) and of the tail (decoder conv + remaining
/// feature layers + classifier). VGG-indexed wrapper over
/// [`Cut::split_compute`].
pub fn split_compute(net: &Network, split: usize) -> (u64, u64) {
    let pts = split_points(net);
    assert!(split < pts.len() - 1, "split {split} out of range");
    pts[split].split_compute()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vgg16_paper_total_params() {
        // Paper Table II: 138,357,544.
        assert_eq!(vgg16_full().total_params(), 138_357_544);
    }

    #[test]
    fn vgg16_paper_mult_adds_batch16() {
        // Paper Table II: 247.74 G mult-adds at batch 16.
        let g = vgg16_full().mult_adds() as f64 * 16.0 / 1e9;
        assert!((g - 247.74).abs() < 0.005, "{g}");
    }

    #[test]
    fn vgg16_table1_spot_rows() {
        let net = vgg16_full();
        let c1 = net.layers().find(|l| l.name == "block1_conv1").unwrap();
        assert_eq!(c1.params(), 1_792);
        assert_eq!(c1.out, Shape::Chw(64, 224, 224));
        let fc1 = net.layers().find(|l| l.name == "fc1").unwrap();
        assert_eq!(fc1.params(), 102_764_544);
        let fc3 = net.layers().find(|l| l.name == "fc3").unwrap();
        assert_eq!(fc3.params(), 4_097_000);
    }

    #[test]
    fn feature_layer_names_match_paper_candidates() {
        let names = feature_layer_names();
        assert_eq!(names.len(), 18);
        // Paper Fig. 2 (0-based feature indexing):
        assert_eq!(names[5], "block2_pool");
        assert_eq!(names[9], "block3_pool");
        assert_eq!(names[11], "block4_conv2");
        assert_eq!(names[13], "block4_pool");
        assert_eq!(names[15], "block5_conv2");
    }

    #[test]
    fn num_feature_layers_is_derived_from_the_conv_plan() {
        assert_eq!(NUM_FEATURE_LAYERS, feature_layer_names().len());
        assert_eq!(NUM_FEATURE_LAYERS, 18);
    }

    #[test]
    fn feature_layers_of_full_vgg16() {
        let f = feature_layers(&vgg16_full());
        assert_eq!(f.len(), 18);
        assert_eq!(f[11].name, "block4_conv2");
        assert_eq!(f[11].out, Shape::Chw(512, 28, 28));
        // latent at 50% compression: 256x28x28 f32
        assert_eq!(f[11].latent_bytes(), 256 * 28 * 28 * 4);
        assert_eq!(f[15].out, Shape::Chw(512, 14, 14));
        assert_eq!(f[15].latent_bytes(), 256 * 14 * 14 * 4);
    }

    #[test]
    fn feature_layers_match_the_layer_table() {
        // The cut-based view must attribute params/MACs to the same rows
        // the old linear scan did: conv candidates own their conv's
        // params+MACs, pools own nothing.
        let net = vgg16_full();
        let f = feature_layers(&net);
        let c1 = net.layers().find(|l| l.name == "block1_conv1").unwrap();
        assert_eq!(f[0].params, c1.params());
        assert_eq!(f[0].mult_adds, c1.mult_adds());
        let c42 = net.layers().find(|l| l.name == "block4_conv2").unwrap();
        assert_eq!(f[11].params, c42.params());
        assert_eq!(f[11].mult_adds, c42.mult_adds());
        for pool in [2usize, 5, 9, 13, 17] {
            assert!(f[pool].is_pool);
            assert_eq!(f[pool].params, 0);
            assert_eq!(f[pool].mult_adds, 0);
        }
    }

    #[test]
    fn slim_matches_python_total_params() {
        // python: compile.model.total_params(ModelConfig(0.125)) == 235378
        let net = vgg16_slim(32, 0.125, 64, 10);
        assert_eq!(net.total_params(), 235_378);
    }

    #[test]
    fn slim_feature_shapes() {
        let f = feature_layers(&vgg16_slim(32, 0.125, 64, 10));
        assert_eq!(f[0].out, Shape::Chw(8, 32, 32));
        assert_eq!(f[17].out, Shape::Chw(64, 1, 1));
        assert_eq!(f[11].out, Shape::Chw(64, 4, 4));
    }

    #[test]
    fn scaled_widths_regression() {
        // The trained slim widths (width_mult 0.125) are exact halvings —
        // the rounding change must not move them.
        let f = feature_layers(&vgg16_slim(32, 0.125, 64, 10));
        let widths: Vec<usize> = [0usize, 3, 7, 11, 15]
            .iter()
            .map(|&i| {
                let Shape::Chw(c, _, _) = f[i].out else { unreachable!() };
                c
            })
            .collect();
        assert_eq!(widths, vec![8, 16, 32, 64, 64]);
        // ...and the lite-model widths (0.0625) are pinned too.
        let lite = feature_layers(&vgg16_slim(32, 0.0625, 48, 10));
        let Shape::Chw(c0, _, _) = lite[0].out else { unreachable!() };
        assert_eq!(c0, 4);
    }

    #[test]
    fn scaled_rounds_half_up_instead_of_truncating() {
        // 64 * 0.15 = 9.6 -> 10 (the old truncation said 9);
        // 30 * 0.15 = 4.5 -> 5 (exactly half rounds up);
        // the 4-channel floor still applies.
        assert_eq!(scaled(64, 0.15), 10);
        assert_eq!(scaled(30, 0.15), 5);
        assert_eq!(scaled(8, 0.125), 4);
        assert_eq!(scaled(64, 0.125), 8);
    }

    #[test]
    fn split_compute_sums_to_more_than_full() {
        // head+tail >= full (bottleneck adds compute)
        let net = vgg16_full();
        let full = net.mult_adds();
        for s in [5usize, 9, 11, 13, 15] {
            let (h, t) = split_compute(&net, s);
            assert!(h + t > full, "split {s}");
            assert!(h < h + t);
        }
    }

    #[test]
    fn split_head_grows_with_split_point() {
        let net = vgg16_full();
        let mut prev = 0;
        for s in [5usize, 9, 11, 13, 15] {
            let (h, _) = split_compute(&net, s);
            assert!(h > prev);
            prev = h;
        }
    }

    #[test]
    fn split_points_match_feature_indexing() {
        // The DAG cut enumeration reproduces the paper's 0..=17 indexing.
        let pts = super::super::cut::split_points(&vgg16_full());
        assert_eq!(pts.len(), NUM_FEATURE_LAYERS);
        let names = feature_layer_names();
        for (p, n) in pts.iter().zip(&names) {
            assert_eq!(&p.name, n);
        }
        assert_eq!(pts[11].out, Shape::Chw(512, 28, 28));
    }
}
