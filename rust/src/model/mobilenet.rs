//! MobileNetV2 model definitions: the torchvision ImageNet variant with a
//! width multiplier (golden at 1.0: 3,504,872 params) and a slim CIFAR
//! geometry, on the DAG IR — inverted-residual blocks whose stride-1
//! same-width instances carry an `Add` skip, so split enumeration excludes
//! their interiors automatically.
//!
//! Split-point candidates (19 per network, stable ids `0..=18`): the stem
//! conv, each of the 17 inverted-residual blocks, and the 1x1 head conv.

use super::layer::{Network, NetworkBuilder, Shape};

/// Inverted-residual plan: (expansion t, channels c, repeats n, stride s).
pub const MOBILENETV2_CFG: [(usize, usize, usize, usize); 7] = [
    (1, 16, 1, 1),
    (6, 24, 2, 2),
    (6, 32, 3, 2),
    (6, 64, 4, 2),
    (6, 96, 3, 1),
    (6, 160, 3, 2),
    (6, 320, 1, 1),
];

/// Round a scaled channel count to the nearest multiple of 8, never
/// dropping below 90% of the requested width (torchvision's
/// `_make_divisible`).
pub fn make_divisible(v: f64, divisor: usize) -> usize {
    let new_v = ((v + divisor as f64 / 2.0) as usize / divisor * divisor)
        .max(divisor);
    if (new_v as f64) < 0.9 * v {
        new_v + divisor
    } else {
        new_v
    }
}

/// One inverted residual: optional 1x1 expand (+BN+ReLU6), 3x3 depthwise
/// (+BN+ReLU6), 1x1 linear project (+BN), with an `Add` skip when stride
/// is 1 and the width is unchanged.
fn inverted_residual(
    mut b: NetworkBuilder,
    name: &str,
    in_ch: usize,
    out_ch: usize,
    stride: usize,
    expand: usize,
) -> NetworkBuilder {
    let hidden = in_ch * expand;
    let skip = b.branch();
    if expand != 1 {
        b = b
            .conv1x1(&format!("{name}.expand"), hidden, 1)
            .bn(&format!("{name}.expand_bn"))
            .relu6(&format!("{name}.expand_relu"));
    }
    b = b
        .dwconv3x3(&format!("{name}.dw"), stride)
        .bn(&format!("{name}.dw_bn"))
        .relu6(&format!("{name}.dw_relu"))
        .conv1x1(&format!("{name}.project"), out_ch, 1)
        .bn(&format!("{name}.project_bn"));
    if stride == 1 && in_ch == out_ch {
        b = b.merge_add(&format!("{name}.add"), skip);
    }
    b.cut_here(name)
}

fn build(
    name: &str,
    img_size: usize,
    stem_stride: usize,
    width_mult: f64,
    last_channel: usize,
    num_classes: usize,
) -> Network {
    let stem_ch = make_divisible(32.0 * width_mult, 8);
    let mut b = NetworkBuilder::new(name, Shape::Chw(3, img_size, img_size))
        .conv("stem", stem_ch, 3, stem_stride, 1, 1, false)
        .bn("stem_bn")
        .relu6("stem_relu")
        .cut_here("stem");
    let mut in_ch = stem_ch;
    let mut idx = 0;
    for (t, c, n, s) in MOBILENETV2_CFG {
        let out_ch = make_divisible(c as f64 * width_mult, 8);
        for i in 0..n {
            idx += 1;
            let stride = if i == 0 { s } else { 1 };
            b = inverted_residual(
                b,
                &format!("block{idx}"),
                in_ch,
                out_ch,
                stride,
                t,
            );
            in_ch = out_ch;
        }
    }
    b.conv1x1("head", last_channel, 1)
        .bn("head_bn")
        .relu6("head_relu")
        .cut_here("head")
        .adaptive_avgpool("avgpool", 1)
        .flatten("flatten")
        .dropout("dropout")
        .linear("classifier", num_classes)
        .build()
}

/// Torchvision MobileNetV2 at 224x224 / 1000 classes with a width
/// multiplier. The head channel count never shrinks below 1280
/// (`_make_divisible(1280 * max(1, width))`), matching torchvision.
pub fn mobilenetv2(width_mult: f64) -> Network {
    let last = make_divisible(1280.0 * width_mult.max(1.0), 8);
    build("MobileNetV2", 224, 2, width_mult, last, 1000)
}

/// Slim CIFAR geometry: 32x32 input, stride-1 stem (the ImageNet stem
/// would halve the map before the first block), head channels scaled by
/// the width multiplier (no 1280 floor). Split-point ids match
/// [`mobilenetv2`].
pub fn mobilenetv2_cifar(width_mult: f64, num_classes: usize) -> Network {
    let last = make_divisible(1280.0 * width_mult, 8);
    build("MobileNetV2-cifar", 32, 1, width_mult, last, num_classes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::cut::{split_points, valid_cuts};

    #[test]
    fn mobilenetv2_torchvision_total_params() {
        // Torchvision golden at width 1.0.
        assert_eq!(mobilenetv2(1.0).total_params(), 3_504_872);
    }

    #[test]
    fn make_divisible_matches_torchvision() {
        assert_eq!(make_divisible(32.0, 8), 32);
        assert_eq!(make_divisible(32.0 * 0.5, 8), 16);
        assert_eq!(make_divisible(24.0 * 0.5, 8), 16); // 12 -> 16 (90% rule)
        assert_eq!(make_divisible(4.0, 8), 8); // divisor floor
        assert_eq!(make_divisible(96.0 * 0.5, 8), 48);
    }

    #[test]
    fn imagenet_shapes_follow_the_stride_plan() {
        let net = mobilenetv2(1.0);
        let shape_of = |name: &str| {
            net.layers().find(|l| l.name == name).unwrap().out
        };
        assert_eq!(shape_of("stem"), Shape::Chw(32, 112, 112));
        assert_eq!(shape_of("block1.project_bn"), Shape::Chw(16, 112, 112));
        assert_eq!(shape_of("block3.add"), Shape::Chw(24, 56, 56));
        assert_eq!(shape_of("block17.project_bn"), Shape::Chw(320, 7, 7));
        assert_eq!(shape_of("head"), Shape::Chw(1280, 7, 7));
        assert_eq!(net.output(), Shape::Flat(1000));
    }

    #[test]
    fn nineteen_split_points_with_conserved_macs() {
        for net in [mobilenetv2(1.0), mobilenetv2_cifar(0.5, 10)] {
            let pts = split_points(&net);
            assert_eq!(pts.len(), 19, "{}", net.name);
            assert_eq!(pts[0].name, "stem");
            assert_eq!(pts[1].name, "block1");
            assert_eq!(pts[17].name, "block17");
            assert_eq!(pts[18].name, "head");
            for p in &pts {
                assert_eq!(
                    p.head_mult_adds + p.tail_mult_adds,
                    net.mult_adds(),
                    "{} cut {}",
                    net.name,
                    p.name
                );
            }
        }
    }

    #[test]
    fn residual_block_interiors_are_excluded() {
        // block5 (32ch, stride 1) carries a skip; no valid cut may sit
        // strictly inside it.
        let net = mobilenetv2(1.0);
        let cuts = valid_cuts(&net);
        let first = net
            .nodes
            .iter()
            .position(|n| n.layer.name == "block5.expand")
            .unwrap();
        let add = net
            .nodes
            .iter()
            .position(|n| n.layer.name == "block5.add")
            .unwrap();
        for c in &cuts {
            assert!(
                c.pos < first || c.pos >= add,
                "cut at node {} ({}) crosses block5's skip edge",
                c.pos,
                c.name
            );
        }
        // Non-residual blocks (stride 2 or width change) cut anywhere.
        assert!(net.layers().all(|l| l.name != "block2.add"));
    }

    #[test]
    fn depthwise_blocks_are_cheaper_than_dense() {
        // Depthwise 3x3 + pointwise 1x1 must undercut a dense 3x3 at the
        // same shape — the whole point of the architecture.
        let net = mobilenetv2(1.0);
        let dw = net.layers().find(|l| l.name == "block4.dw").unwrap();
        // block4 expands 24 -> 144 hidden channels before the depthwise.
        let out_el = dw.out.elements() as u64;
        let dense_equivalent = out_el * (144 * 9) as u64;
        assert!(dw.mult_adds() * 10 < dense_equivalent);
    }

    #[test]
    fn width_multiplier_scales_params_down() {
        let full = mobilenetv2(1.0).total_params();
        let half = mobilenetv2_cifar(0.5, 10).total_params();
        assert!(half * 2 < full, "half {half} vs full {full}");
        // Pinned regression values (verified against the transliterated
        // reference).
        assert_eq!(half, 590_410);
        assert_eq!(mobilenetv2(1.0).mult_adds(), 300_775_272);
    }
}
