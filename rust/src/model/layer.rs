//! Layer-graph IR: shapes, parameter counts, mult-adds — now over an
//! explicit DAG instead of an implicit linear chain.
//!
//! This is the "neural network statistics" subsystem behind the paper's
//! Tables I and II (torchinfo-style summaries), and the source of the
//! per-layer activation/latent sizes and compute costs the scenario engine
//! uses for transmission volumetrics and compute-time modelling.
//!
//! A [`Network`] is a list of [`Node`]s in topological order; every node
//! carries a [`Layer`] (name + kind + output shape) plus the indices of
//! its predecessor nodes. A node with no predecessors reads the network
//! input. Chains (VGG) are the degenerate single-predecessor case; skip
//! connections (ResNet's residual `Add`, concat merges) are nodes with two
//! predecessors. The [`NetworkBuilder`] keeps the fluent chain API as
//! sugar and adds [`NetworkBuilder::branch`] / [`NetworkBuilder::rewind`] /
//! [`NetworkBuilder::merge_add`] for residual blocks, plus
//! [`NetworkBuilder::cut_here`] to mark the paper-style split-point
//! candidates consumed by [`super::cut`].
//!
//! Conventions (matching the numbers printed in the paper):
//!   * params include biases (convs may opt out — ResNet/MobileNet convs
//!     carry `bias: false` because BatchNorm follows);
//!   * mult-adds of a conv/linear = output_elements x fan_in + bias adds
//!     (exactly reproduces Table II's 247.74 G for VGG16 @ batch 16);
//!   * BatchNorm contributes 2·C trainable params and no mult-adds
//!     (torchinfo convention); merges (`Add`/`Concat`) are free;
//!   * forward/backward pass size counts the outputs of *parameterized*
//!     layers only, twice (activations + gradients), in f32.

/// Activation shape flowing along a graph edge.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Shape {
    /// Channels-first feature map.
    Chw(usize, usize, usize),
    /// Flattened vector.
    Flat(usize),
}

impl Shape {
    pub fn elements(&self) -> usize {
        match *self {
            Shape::Chw(c, h, w) => c * h * w,
            Shape::Flat(n) => n,
        }
    }

    pub fn bytes_f32(&self) -> usize {
        self.elements() * 4
    }

    pub fn render(&self, batch: usize) -> String {
        match *self {
            Shape::Chw(c, h, w) => format!("[{batch}, {c}, {h}, {w}]"),
            Shape::Flat(n) => format!("[{batch}, {n}]"),
        }
    }
}

#[derive(Clone, Debug, PartialEq, Eq)]
pub enum LayerKind {
    /// 2-D convolution. `groups == in_ch` models a depthwise conv;
    /// `bias: false` models the conv+BatchNorm idiom. The VGG builder's
    /// 3x3 "same" convs are the `stride 1, padding k/2, groups 1, bias`
    /// special case.
    Conv2d {
        in_ch: usize,
        out_ch: usize,
        kernel: usize,
        stride: usize,
        padding: usize,
        groups: usize,
        bias: bool,
    },
    /// Batch normalization over `ch` channels (2·ch trainable params,
    /// no mult-adds under the torchinfo convention).
    BatchNorm { ch: usize },
    ReLU,
    /// Clipped ReLU (MobileNet family).
    ReLU6,
    /// 2x2 max pooling, stride 2 (the only pool VGG uses).
    MaxPool2,
    /// General max pooling (ResNet stem: 3x3, stride 2, padding 1).
    MaxPool { kernel: usize, stride: usize, padding: usize },
    /// Adaptive average pool to a fixed spatial size.
    AdaptiveAvgPool { out_hw: usize },
    Flatten,
    Linear { in_f: usize, out_f: usize },
    Dropout,
    /// Elementwise sum of two equal-shape inputs (residual merge).
    Add,
    /// Channel concatenation of two feature maps.
    Concat,
}

#[derive(Clone, Debug)]
pub struct Layer {
    pub name: String,
    pub kind: LayerKind,
    pub out: Shape,
}

impl Layer {
    pub fn params(&self) -> u64 {
        match self.kind {
            LayerKind::Conv2d { in_ch, out_ch, kernel, groups, bias, .. } => {
                (out_ch * (in_ch / groups) * kernel * kernel
                    + if bias { out_ch } else { 0 }) as u64
            }
            LayerKind::BatchNorm { ch } => (2 * ch) as u64,
            LayerKind::Linear { in_f, out_f } => (in_f * out_f + out_f) as u64,
            _ => 0,
        }
    }

    /// Mult-adds per image (torchinfo convention: MACs + bias adds).
    pub fn mult_adds(&self) -> u64 {
        let out_el = self.out.elements() as u64;
        match self.kind {
            LayerKind::Conv2d { in_ch, kernel, groups, bias, .. } => {
                out_el * ((in_ch / groups) * kernel * kernel) as u64
                    + if bias { out_el } else { 0 }
            }
            LayerKind::Linear { in_f, .. } => out_el * in_f as u64 + out_el,
            _ => 0,
        }
    }

    pub fn is_parameterized(&self) -> bool {
        self.params() > 0
    }

    pub fn type_name(&self) -> &'static str {
        match self.kind {
            LayerKind::Conv2d { .. } => "Conv2d",
            LayerKind::BatchNorm { .. } => "BatchNorm2d",
            LayerKind::ReLU => "ReLU",
            LayerKind::ReLU6 => "ReLU6",
            LayerKind::MaxPool2 | LayerKind::MaxPool { .. } => "MaxPool2d",
            LayerKind::AdaptiveAvgPool { .. } => "AdaptiveAvgPool2d",
            LayerKind::Flatten => "Flatten",
            LayerKind::Linear { .. } => "Linear",
            LayerKind::Dropout => "Dropout",
            LayerKind::Add => "Add",
            LayerKind::Concat => "Concat",
        }
    }
}

/// One node of the network DAG: a layer plus its predecessor node
/// indices. `inputs` is empty for nodes reading the network input and
/// holds two indices for merges (`Add`/`Concat`).
#[derive(Clone, Debug)]
pub struct Node {
    pub layer: Layer,
    pub inputs: Vec<usize>,
}

/// A full network: input shape + DAG nodes in topological order (every
/// node's inputs have smaller indices — guaranteed by the builder).
#[derive(Clone, Debug)]
pub struct Network {
    pub name: String,
    pub input: Shape,
    pub nodes: Vec<Node>,
    /// Marked split-point candidates: `(node index, candidate name)` in
    /// topological order — the paper-style cut positions enumerated by
    /// [`super::cut::split_points`].
    pub cut_marks: Vec<(usize, String)>,
}

/// Opaque handle to a node, returned by [`NetworkBuilder::branch`]: the
/// point a skip connection forks from (and can be merged back into).
#[derive(Clone, Copy, Debug)]
pub struct BranchPoint(usize);

pub struct NetworkBuilder {
    name: String,
    input: Shape,
    cur: Shape,
    /// Index of the node whose output is the current chain tip; `None`
    /// before the first node (the network input).
    tip: Option<usize>,
    nodes: Vec<Node>,
    cut_marks: Vec<(usize, String)>,
}

fn conv_out_hw(hw: usize, kernel: usize, stride: usize, padding: usize)
    -> usize
{
    (hw + 2 * padding - kernel) / stride + 1
}

impl NetworkBuilder {
    pub fn new(name: &str, input: Shape) -> Self {
        NetworkBuilder {
            name: name.to_string(),
            input,
            cur: input,
            tip: None,
            nodes: Vec::new(),
            cut_marks: Vec::new(),
        }
    }

    fn push(&mut self, name: String, kind: LayerKind, out: Shape) {
        let inputs = self.tip.map(|t| vec![t]).unwrap_or_default();
        self.push_node(name, kind, out, inputs);
    }

    fn push_node(
        &mut self,
        name: String,
        kind: LayerKind,
        out: Shape,
        inputs: Vec<usize>,
    ) {
        self.nodes.push(Node { layer: Layer { name, kind, out }, inputs });
        self.tip = Some(self.nodes.len() - 1);
        self.cur = out;
    }

    /// General 2-D conv (see [`LayerKind::Conv2d`]).
    #[allow(clippy::too_many_arguments)]
    pub fn conv(
        mut self,
        name: &str,
        out_ch: usize,
        kernel: usize,
        stride: usize,
        padding: usize,
        groups: usize,
        bias: bool,
    ) -> Self {
        let Shape::Chw(c, h, w) = self.cur else {
            panic!("conv on flat input")
        };
        assert!(groups >= 1 && c % groups == 0 && out_ch % groups == 0,
                "conv '{name}': groups {groups} must divide {c} and {out_ch}");
        self.push(
            name.into(),
            LayerKind::Conv2d {
                in_ch: c,
                out_ch,
                kernel,
                stride,
                padding,
                groups,
                bias,
            },
            Shape::Chw(
                out_ch,
                conv_out_hw(h, kernel, stride, padding),
                conv_out_hw(w, kernel, stride, padding),
            ),
        );
        self
    }

    /// 3x3 "same" conv with bias (the only conv VGG uses).
    pub fn conv3x3(self, name: &str, out_ch: usize) -> Self {
        self.conv(name, out_ch, 3, 1, 1, 1, true)
    }

    /// 1x1 pointwise conv without bias (projection shortcuts, MobileNet
    /// expand/project convs).
    pub fn conv1x1(self, name: &str, out_ch: usize, stride: usize) -> Self {
        self.conv(name, out_ch, 1, stride, 0, 1, false)
    }

    /// 3x3 depthwise conv without bias (`groups == channels`).
    pub fn dwconv3x3(mut self, name: &str, stride: usize) -> Self {
        let Shape::Chw(c, _, _) = self.cur else {
            panic!("dwconv on flat input")
        };
        self = self.conv(name, c, 3, stride, 1, c, false);
        self
    }

    pub fn bn(mut self, name: &str) -> Self {
        let Shape::Chw(c, _, _) = self.cur else {
            panic!("batchnorm on flat input")
        };
        let out = self.cur;
        self.push(name.into(), LayerKind::BatchNorm { ch: c }, out);
        self
    }

    pub fn relu(mut self, name: &str) -> Self {
        let out = self.cur;
        self.push(name.into(), LayerKind::ReLU, out);
        self
    }

    pub fn relu6(mut self, name: &str) -> Self {
        let out = self.cur;
        self.push(name.into(), LayerKind::ReLU6, out);
        self
    }

    pub fn maxpool2(mut self, name: &str) -> Self {
        let Shape::Chw(c, h, w) = self.cur else {
            panic!("pool on flat input")
        };
        self.push(name.into(), LayerKind::MaxPool2, Shape::Chw(c, h / 2, w / 2));
        self
    }

    /// General max pool (ResNet stem: `maxpool(name, 3, 2, 1)`).
    pub fn maxpool(
        mut self,
        name: &str,
        kernel: usize,
        stride: usize,
        padding: usize,
    ) -> Self {
        let Shape::Chw(c, h, w) = self.cur else {
            panic!("pool on flat input")
        };
        self.push(
            name.into(),
            LayerKind::MaxPool { kernel, stride, padding },
            Shape::Chw(
                c,
                conv_out_hw(h, kernel, stride, padding),
                conv_out_hw(w, kernel, stride, padding),
            ),
        );
        self
    }

    pub fn adaptive_avgpool(mut self, name: &str, out_hw: usize) -> Self {
        let Shape::Chw(c, _, _) = self.cur else {
            panic!("pool on flat input")
        };
        self.push(
            name.into(),
            LayerKind::AdaptiveAvgPool { out_hw },
            Shape::Chw(c, out_hw, out_hw),
        );
        self
    }

    pub fn flatten(mut self, name: &str) -> Self {
        let n = self.cur.elements();
        self.push(name.into(), LayerKind::Flatten, Shape::Flat(n));
        self
    }

    pub fn linear(mut self, name: &str, out_f: usize) -> Self {
        let in_f = self.cur.elements();
        self.push(
            name.into(),
            LayerKind::Linear { in_f, out_f },
            Shape::Flat(out_f),
        );
        self
    }

    pub fn dropout(mut self, name: &str) -> Self {
        let out = self.cur;
        self.push(name.into(), LayerKind::Dropout, out);
        self
    }

    // -- DAG construction ---------------------------------------------------

    /// Handle to the current chain tip: the point a skip connection forks
    /// from. Panics before the first layer (branching from the raw network
    /// input is not needed by any zoo architecture).
    pub fn branch(&self) -> BranchPoint {
        BranchPoint(self.tip.expect("branch() before any layer"))
    }

    /// Rewind the chain tip to a previous [`branch`](Self::branch) point,
    /// so subsequent fluent calls build a side path (e.g. a projection
    /// shortcut) off that node.
    pub fn rewind(mut self, at: BranchPoint) -> Self {
        self.tip = Some(at.0);
        self.cur = self.nodes[at.0].layer.out;
        self
    }

    /// Merge the current tip with `other` by elementwise addition (the
    /// residual merge). Shapes must match.
    pub fn merge_add(mut self, name: &str, other: BranchPoint) -> Self {
        let tip = self.tip.expect("merge_add() before any layer");
        let a = self.nodes[tip].layer.out;
        let b = self.nodes[other.0].layer.out;
        assert_eq!(a, b, "merge_add '{name}': shape mismatch {a:?} vs {b:?}");
        self.push_node(name.into(), LayerKind::Add, a, vec![tip, other.0]);
        self
    }

    /// Merge the current tip with `other` by channel concatenation.
    pub fn merge_concat(mut self, name: &str, other: BranchPoint) -> Self {
        let tip = self.tip.expect("merge_concat() before any layer");
        let (Shape::Chw(ca, h, w), Shape::Chw(cb, hb, wb)) =
            (self.nodes[tip].layer.out, self.nodes[other.0].layer.out)
        else {
            panic!("merge_concat '{name}': both inputs must be CHW")
        };
        assert_eq!((h, w), (hb, wb),
                   "merge_concat '{name}': spatial mismatch");
        self.push_node(
            name.into(),
            LayerKind::Concat,
            Shape::Chw(ca + cb, h, w),
            vec![tip, other.0],
        );
        self
    }

    /// Mark the current tip as a split-point candidate named `name` (the
    /// paper's "cut after layer i" positions — see [`super::cut`]).
    pub fn cut_here(mut self, name: &str) -> Self {
        let tip = self.tip.expect("cut_here() before any layer");
        self.cut_marks.push((tip, name.to_string()));
        self
    }

    pub fn build(self) -> Network {
        Network {
            name: self.name,
            input: self.input,
            nodes: self.nodes,
            cut_marks: self.cut_marks,
        }
    }
}

impl Network {
    /// The layers in topological order (DAG-agnostic view for summaries).
    pub fn layers(&self) -> impl Iterator<Item = &Layer> + '_ {
        self.nodes.iter().map(|n| &n.layer)
    }

    pub fn layer(&self, i: usize) -> &Layer {
        &self.nodes[i].layer
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    pub fn total_params(&self) -> u64 {
        self.layers().map(|l| l.params()).sum()
    }

    /// Mult-adds per image.
    pub fn mult_adds(&self) -> u64 {
        self.layers().map(|l| l.mult_adds()).sum()
    }

    /// Sum of output elements of parameterized layers (per image).
    pub fn param_layer_out_elements(&self) -> u64 {
        self.layers()
            .filter(|l| l.is_parameterized())
            .map(|l| l.out.elements() as u64)
            .sum()
    }

    pub fn output(&self) -> Shape {
        self.nodes.last().map(|n| n.layer.out).unwrap_or(self.input)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Network {
        NetworkBuilder::new("t", Shape::Chw(3, 8, 8))
            .conv3x3("c1", 4)
            .relu("r1")
            .maxpool2("p1")
            .flatten("f")
            .linear("fc", 10)
            .build()
    }

    #[test]
    fn shape_propagation() {
        let n = tiny();
        assert_eq!(n.layer(0).out, Shape::Chw(4, 8, 8));
        assert_eq!(n.layer(2).out, Shape::Chw(4, 4, 4));
        assert_eq!(n.layer(3).out, Shape::Flat(64));
        assert_eq!(n.output(), Shape::Flat(10));
    }

    #[test]
    fn param_counts() {
        let n = tiny();
        assert_eq!(n.layer(0).params(), 4 * 3 * 9 + 4);
        assert_eq!(n.layer(4).params(), 64 * 10 + 10);
        assert_eq!(n.total_params(), 112 + 650);
    }

    #[test]
    fn mult_adds_include_bias() {
        let n = tiny();
        // conv: 256 out el x 27 + 256; linear: 10 x 64 + 10
        assert_eq!(n.layer(0).mult_adds(), 256 * 27 + 256);
        assert_eq!(n.layer(4).mult_adds(), 650);
    }

    #[test]
    fn relu_and_pool_are_free() {
        let n = tiny();
        assert_eq!(n.layer(1).params() + n.layer(2).params(), 0);
        assert_eq!(n.layer(1).mult_adds() + n.layer(2).mult_adds(), 0);
    }

    #[test]
    fn shape_render() {
        assert_eq!(Shape::Chw(64, 224, 224).render(16), "[16, 64, 224, 224]");
        assert_eq!(Shape::Flat(1000).render(16), "[16, 1000]");
    }

    #[test]
    fn bytes_f32() {
        assert_eq!(Shape::Chw(2, 3, 4).bytes_f32(), 96);
    }

    #[test]
    fn chain_edges_are_sequential() {
        let n = tiny();
        assert!(n.nodes[0].inputs.is_empty());
        for i in 1..n.len() {
            assert_eq!(n.nodes[i].inputs, vec![i - 1]);
        }
    }

    #[test]
    fn strided_and_padded_conv_shapes() {
        // ResNet stem: 7x7 s2 p3 on 224 -> 112; maxpool 3x3 s2 p1 -> 56.
        let n = NetworkBuilder::new("s", Shape::Chw(3, 224, 224))
            .conv("conv1", 64, 7, 2, 3, 1, false)
            .maxpool("pool", 3, 2, 1)
            .build();
        assert_eq!(n.layer(0).out, Shape::Chw(64, 112, 112));
        assert_eq!(n.layer(1).out, Shape::Chw(64, 56, 56));
        // bias=false: no bias params, no bias adds.
        assert_eq!(n.layer(0).params(), 64 * 3 * 49);
        assert_eq!(
            n.layer(0).mult_adds(),
            (64 * 112 * 112) as u64 * (3 * 49) as u64
        );
    }

    #[test]
    fn depthwise_conv_divides_fan_in_by_groups() {
        let n = NetworkBuilder::new("d", Shape::Chw(8, 4, 4))
            .dwconv3x3("dw", 1)
            .build();
        // groups == in_ch == 8: params 8 * 1 * 9, macs 128 out el * 9.
        assert_eq!(n.layer(0).params(), 72);
        assert_eq!(n.layer(0).mult_adds(), 128 * 9);
    }

    #[test]
    fn batchnorm_params_no_macs() {
        let n = NetworkBuilder::new("b", Shape::Chw(8, 4, 4))
            .bn("bn")
            .build();
        assert_eq!(n.layer(0).params(), 16);
        assert_eq!(n.layer(0).mult_adds(), 0);
        assert!(n.layer(0).is_parameterized());
    }

    #[test]
    fn residual_block_merges_and_records_edges() {
        let mut b = NetworkBuilder::new("r", Shape::Chw(4, 8, 8))
            .conv3x3("pre", 4);
        let skip = b.branch();
        b = b
            .conv3x3("c1", 4)
            .relu("r1")
            .conv3x3("c2", 4)
            .merge_add("add", skip)
            .relu("r2");
        let n = b.build();
        let add = n.nodes.iter().position(|x| x.layer.name == "add").unwrap();
        assert_eq!(n.nodes[add].inputs, vec![add - 1, 0]);
        assert_eq!(n.layer(add).out, Shape::Chw(4, 8, 8));
        assert_eq!(n.layer(add).mult_adds(), 0);
    }

    #[test]
    fn rewind_builds_a_projection_side_path() {
        let mut b = NetworkBuilder::new("p", Shape::Chw(4, 8, 8))
            .conv3x3("pre", 4);
        let fork = b.branch();
        b = b.conv("main", 8, 3, 2, 1, 1, false);
        let main = b.branch();
        b = b.rewind(fork).conv1x1("proj", 8, 2);
        b = b.merge_add("add", main);
        let n = b.build();
        let proj =
            n.nodes.iter().position(|x| x.layer.name == "proj").unwrap();
        assert_eq!(n.nodes[proj].inputs, vec![0]);
        assert_eq!(n.layer(proj).out, Shape::Chw(8, 4, 4));
        let add = n.len() - 1;
        assert_eq!(n.layer(add).out, Shape::Chw(8, 4, 4));
    }

    #[test]
    fn concat_sums_channels() {
        let mut b = NetworkBuilder::new("c", Shape::Chw(4, 8, 8))
            .conv3x3("pre", 4);
        let fork = b.branch();
        b = b.conv3x3("left", 6);
        let left = b.branch();
        b = b.rewind(fork).conv3x3("right", 2);
        b = b.merge_concat("cat", left);
        let n = b.build();
        assert_eq!(n.output(), Shape::Chw(8, 8, 8));
    }

    #[test]
    #[should_panic(expected = "shape mismatch")]
    fn mismatched_add_panics() {
        let mut b = NetworkBuilder::new("x", Shape::Chw(4, 8, 8))
            .conv3x3("pre", 4);
        let fork = b.branch();
        b = b.conv3x3("widen", 8);
        let _ = b.merge_add("bad", fork);
    }

    #[test]
    fn cut_marks_record_positions_in_order() {
        let n = NetworkBuilder::new("m", Shape::Chw(3, 8, 8))
            .conv3x3("c1", 4)
            .relu("r1")
            .cut_here("c1")
            .maxpool2("p1")
            .cut_here("p1")
            .flatten("f")
            .linear("fc", 10)
            .build();
        assert_eq!(
            n.cut_marks,
            vec![(1, "c1".to_string()), (3, "p1".to_string())]
        );
    }
}
