//! Layer graph metadata: shapes, parameter counts, mult-adds.
//!
//! This is the "neural network statistics" subsystem behind the paper's
//! Tables I and II (torchinfo-style summaries), and the source of the
//! per-layer activation/latent sizes and compute costs the scenario engine
//! uses for transmission volumetrics and compute-time modelling.
//!
//! Conventions (matching the numbers printed in the paper):
//!   * params include biases;
//!   * mult-adds of a conv/linear = output_elements x fan_in + bias adds
//!     (exactly reproduces Table II's 247.74 G for VGG16 @ batch 16);
//!   * forward/backward pass size counts the outputs of *parameterized*
//!     layers only, twice (activations + gradients), in f32.

/// Activation shape flowing between layers.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Shape {
    /// Channels-first feature map.
    Chw(usize, usize, usize),
    /// Flattened vector.
    Flat(usize),
}

impl Shape {
    pub fn elements(&self) -> usize {
        match *self {
            Shape::Chw(c, h, w) => c * h * w,
            Shape::Flat(n) => n,
        }
    }

    pub fn bytes_f32(&self) -> usize {
        self.elements() * 4
    }

    pub fn render(&self, batch: usize) -> String {
        match *self {
            Shape::Chw(c, h, w) => format!("[{batch}, {c}, {h}, {w}]"),
            Shape::Flat(n) => format!("[{batch}, {n}]"),
        }
    }
}

#[derive(Clone, Debug, PartialEq, Eq)]
pub enum LayerKind {
    /// 3x3 "same" convolution (the only conv VGG uses).
    Conv2d { in_ch: usize, out_ch: usize, kernel: usize },
    ReLU,
    /// 2x2 max pooling, stride 2.
    MaxPool2,
    /// Adaptive average pool to a fixed spatial size.
    AdaptiveAvgPool { out_hw: usize },
    Flatten,
    Linear { in_f: usize, out_f: usize },
    Dropout,
}

#[derive(Clone, Debug)]
pub struct Layer {
    pub name: String,
    pub kind: LayerKind,
    pub out: Shape,
}

impl Layer {
    pub fn params(&self) -> u64 {
        match self.kind {
            LayerKind::Conv2d { in_ch, out_ch, kernel } => {
                (out_ch * in_ch * kernel * kernel + out_ch) as u64
            }
            LayerKind::Linear { in_f, out_f } => (in_f * out_f + out_f) as u64,
            _ => 0,
        }
    }

    /// Mult-adds per image (torchinfo convention: MACs + bias adds).
    pub fn mult_adds(&self) -> u64 {
        let out_el = self.out.elements() as u64;
        match self.kind {
            LayerKind::Conv2d { in_ch, kernel, .. } => {
                out_el * (in_ch * kernel * kernel) as u64 + out_el
            }
            LayerKind::Linear { in_f, .. } => out_el * in_f as u64 + out_el,
            _ => 0,
        }
    }

    pub fn is_parameterized(&self) -> bool {
        self.params() > 0
    }

    pub fn type_name(&self) -> &'static str {
        match self.kind {
            LayerKind::Conv2d { .. } => "Conv2d",
            LayerKind::ReLU => "ReLU",
            LayerKind::MaxPool2 => "MaxPool2d",
            LayerKind::AdaptiveAvgPool { .. } => "AdaptiveAvgPool2d",
            LayerKind::Flatten => "Flatten",
            LayerKind::Linear { .. } => "Linear",
            LayerKind::Dropout => "Dropout",
        }
    }
}

/// A full network: input shape + ordered layers with propagated shapes.
#[derive(Clone, Debug)]
pub struct Network {
    pub name: String,
    pub input: Shape,
    pub layers: Vec<Layer>,
}

pub struct NetworkBuilder {
    name: String,
    input: Shape,
    cur: Shape,
    layers: Vec<Layer>,
}

impl NetworkBuilder {
    pub fn new(name: &str, input: Shape) -> Self {
        NetworkBuilder {
            name: name.to_string(),
            input,
            cur: input,
            layers: Vec::new(),
        }
    }

    fn push(&mut self, name: String, kind: LayerKind, out: Shape) {
        self.layers.push(Layer { name, kind, out });
        self.cur = out;
    }

    pub fn conv3x3(mut self, name: &str, out_ch: usize) -> Self {
        let Shape::Chw(c, h, w) = self.cur else {
            panic!("conv on flat input")
        };
        self.push(
            name.into(),
            LayerKind::Conv2d { in_ch: c, out_ch, kernel: 3 },
            Shape::Chw(out_ch, h, w),
        );
        self
    }

    pub fn relu(mut self, name: &str) -> Self {
        let out = self.cur;
        self.push(name.into(), LayerKind::ReLU, out);
        self
    }

    pub fn maxpool2(mut self, name: &str) -> Self {
        let Shape::Chw(c, h, w) = self.cur else {
            panic!("pool on flat input")
        };
        self.push(name.into(), LayerKind::MaxPool2, Shape::Chw(c, h / 2, w / 2));
        self
    }

    pub fn adaptive_avgpool(mut self, name: &str, out_hw: usize) -> Self {
        let Shape::Chw(c, _, _) = self.cur else {
            panic!("pool on flat input")
        };
        self.push(
            name.into(),
            LayerKind::AdaptiveAvgPool { out_hw },
            Shape::Chw(c, out_hw, out_hw),
        );
        self
    }

    pub fn flatten(mut self, name: &str) -> Self {
        let n = self.cur.elements();
        self.push(name.into(), LayerKind::Flatten, Shape::Flat(n));
        self
    }

    pub fn linear(mut self, name: &str, out_f: usize) -> Self {
        let in_f = self.cur.elements();
        self.push(
            name.into(),
            LayerKind::Linear { in_f, out_f },
            Shape::Flat(out_f),
        );
        self
    }

    pub fn dropout(mut self, name: &str) -> Self {
        let out = self.cur;
        self.push(name.into(), LayerKind::Dropout, out);
        self
    }

    pub fn build(self) -> Network {
        Network { name: self.name, input: self.input, layers: self.layers }
    }
}

impl Network {
    pub fn total_params(&self) -> u64 {
        self.layers.iter().map(|l| l.params()).sum()
    }

    /// Mult-adds per image.
    pub fn mult_adds(&self) -> u64 {
        self.layers.iter().map(|l| l.mult_adds()).sum()
    }

    /// Sum of output elements of parameterized layers (per image).
    pub fn param_layer_out_elements(&self) -> u64 {
        self.layers
            .iter()
            .filter(|l| l.is_parameterized())
            .map(|l| l.out.elements() as u64)
            .sum()
    }

    pub fn output(&self) -> Shape {
        self.layers.last().map(|l| l.out).unwrap_or(self.input)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Network {
        NetworkBuilder::new("t", Shape::Chw(3, 8, 8))
            .conv3x3("c1", 4)
            .relu("r1")
            .maxpool2("p1")
            .flatten("f")
            .linear("fc", 10)
            .build()
    }

    #[test]
    fn shape_propagation() {
        let n = tiny();
        assert_eq!(n.layers[0].out, Shape::Chw(4, 8, 8));
        assert_eq!(n.layers[2].out, Shape::Chw(4, 4, 4));
        assert_eq!(n.layers[3].out, Shape::Flat(64));
        assert_eq!(n.output(), Shape::Flat(10));
    }

    #[test]
    fn param_counts() {
        let n = tiny();
        assert_eq!(n.layers[0].params(), 4 * 3 * 9 + 4);
        assert_eq!(n.layers[4].params(), 64 * 10 + 10);
        assert_eq!(n.total_params(), 112 + 650);
    }

    #[test]
    fn mult_adds_include_bias() {
        let n = tiny();
        // conv: 256 out el x 27 + 256; linear: 10 x 64 + 10
        assert_eq!(n.layers[0].mult_adds(), 256 * 27 + 256);
        assert_eq!(n.layers[4].mult_adds(), 650);
    }

    #[test]
    fn relu_and_pool_are_free() {
        let n = tiny();
        assert_eq!(n.layers[1].params() + n.layers[2].params(), 0);
        assert_eq!(n.layers[1].mult_adds() + n.layers[2].mult_adds(), 0);
    }

    #[test]
    fn shape_render() {
        assert_eq!(Shape::Chw(64, 224, 224).render(16), "[16, 64, 224, 224]");
        assert_eq!(Shape::Flat(1000).render(16), "[16, 1000]");
    }

    #[test]
    fn bytes_f32() {
        assert_eq!(Shape::Chw(2, 3, 4).bytes_f32(), 96);
    }
}
