//! Model metadata subsystem: the DAG layer-graph IR ([`layer`]), graph-cut
//! split enumeration ([`cut`]), the architecture zoo (VGG16, ResNet-18,
//! MobileNetV2), parameter/mult-add accounting (Tables I/II), per-cut
//! activation/latent volumetrics, and device compute-time profiles.

pub mod cut;
pub mod device;
pub mod layer;
pub mod mobilenet;
pub mod resnet;
pub mod stats;
pub mod vgg;

use anyhow::{bail, Result};

pub use cut::{
    chain_costs, is_ordered_chain, ordered_chains, split_points,
    valid_cut_chains, valid_cuts, ChainCache, ChainCosts, Cut,
};
pub use device::DeviceProfile;
pub use layer::{Layer, LayerKind, Network, NetworkBuilder, Node, Shape};
pub use mobilenet::{mobilenetv2, mobilenetv2_cifar};
pub use resnet::{resnet18, resnet18_cifar};
pub use stats::{model_stats, render_table1, render_table2, ModelStats};
pub use vgg::{
    feature_layers, split_compute, vgg16_full, vgg16_slim, FeatureLayer,
    NUM_FEATURE_LAYERS,
};

/// Architecture axis of the design space: which network geometry drives
/// volumetrics, compute costs and split-point enumeration. This is the
/// single model-string parser — the CLI (`--arch`), sweep-spec JSON
/// (`"archs"`) and examples all go through [`Arch::parse`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Arch {
    /// The paper's VGG16 (18 chain split points).
    #[default]
    Vgg16,
    /// ResNet-18 (10 block-boundary split points; residual interiors are
    /// invalid cuts).
    ResNet18,
    /// MobileNetV2 (19 block-boundary split points).
    MobileNetV2,
}

impl Arch {
    pub const ALL: [Arch; 3] =
        [Arch::Vgg16, Arch::ResNet18, Arch::MobileNetV2];

    /// Parse an architecture name (case-insensitive; common dashed and
    /// underscored spellings accepted).
    pub fn parse(s: &str) -> Result<Arch> {
        match s.to_ascii_lowercase().replace('-', "").replace('_', "")
            .as_str()
        {
            "vgg16" => Ok(Arch::Vgg16),
            "resnet18" => Ok(Arch::ResNet18),
            "mobilenetv2" | "mobilenet" => Ok(Arch::MobileNetV2),
            _ => bail!(
                "unknown architecture '{s}' (valid: vgg16 | resnet18 | \
                 mobilenetv2)"
            ),
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            Arch::Vgg16 => "vgg16",
            Arch::ResNet18 => "resnet18",
            Arch::MobileNetV2 => "mobilenetv2",
        }
    }

    /// Infer the architecture from a manifest `model.arch` string (e.g.
    /// `"vgg16-slim-analytic"`, `"resnet18-analytic"`); unrecognized
    /// strings default to VGG16, the original backend geometry.
    pub fn infer(manifest_arch: &str) -> Arch {
        let a = manifest_arch.to_ascii_lowercase();
        if a.contains("resnet18") {
            Arch::ResNet18
        } else if a.contains("mobilenet") {
            Arch::MobileNetV2
        } else {
            Arch::Vgg16
        }
    }

    /// The paper-scale (224x224, 1000-class) network of this architecture.
    pub fn full_network(&self) -> Network {
        match self {
            Arch::Vgg16 => vgg16_full(),
            Arch::ResNet18 => resnet18(),
            Arch::MobileNetV2 => mobilenetv2(1.0),
        }
    }

    /// The slim (32x32-class, trained-artifact geometry) network. VGG uses
    /// every manifest knob; ResNet-18 has no width knob (its CIFAR variant
    /// is the standard 64-channel plan); MobileNetV2 honours the width
    /// multiplier.
    pub fn slim_network(
        &self,
        img_size: usize,
        width_mult: f64,
        hidden: usize,
        num_classes: usize,
    ) -> Network {
        match self {
            Arch::Vgg16 => {
                vgg16_slim(img_size, width_mult, hidden, num_classes)
            }
            Arch::ResNet18 => resnet18_cifar(num_classes),
            Arch::MobileNetV2 => mobilenetv2_cifar(width_mult, num_classes),
        }
    }
}

impl std::fmt::Display for Arch {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Which model scale's volumetrics/compute drive a simulation. The
/// *architecture* is a separate axis ([`Arch`]); the scale picks between
/// that arch's trained slim geometry and its paper-scale (224x224,
/// 1000-class) network. It lives in the model layer because it is half of
/// the (arch, scale) pair that resolves to a concrete [`Network`] — the
/// key every crate-wide memo cache ([`ChainCache`]) is indexed by.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ModelScale {
    /// The actual trained slim model (end-to-end serving).
    Slim,
    /// The arch's paper-scale network at 224x224 (Fig. 3/4 transfer sizes
    /// and compute); accuracy is still measured on the slim artifacts with
    /// the same loss fraction (corruption is scaled proportionally).
    Full,
}

impl ModelScale {
    /// Parse `"slim" | "full"` (case-insensitive; the historical
    /// `"vgg16"` / `"vgg16-full"` spellings are accepted as aliases for
    /// `full`).
    pub fn parse(s: &str) -> Result<ModelScale> {
        match s.to_ascii_lowercase().as_str() {
            "slim" => Ok(ModelScale::Slim),
            "full" | "vgg16" | "vgg16-full" => Ok(ModelScale::Full),
            other => bail!(
                "unknown model scale '{other}' (slim | full; 'vgg16' and \
                 'vgg16-full' are accepted as aliases for full)"
            ),
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            ModelScale::Slim => "slim",
            ModelScale::Full => "full",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arch_parse_roundtrips_and_aliases() {
        for a in Arch::ALL {
            assert_eq!(Arch::parse(a.as_str()).unwrap(), a);
        }
        assert_eq!(Arch::parse("ResNet-18").unwrap(), Arch::ResNet18);
        assert_eq!(Arch::parse("mobilenet_v2").unwrap(), Arch::MobileNetV2);
        assert_eq!(Arch::parse("VGG16").unwrap(), Arch::Vgg16);
        let err = Arch::parse("alexnet").unwrap_err().to_string();
        assert!(err.contains("vgg16") && err.contains("resnet18")
                && err.contains("mobilenetv2"), "{err}");
    }

    #[test]
    fn arch_infer_from_manifest_strings() {
        assert_eq!(Arch::infer("vgg16-slim-analytic"), Arch::Vgg16);
        assert_eq!(Arch::infer("resnet18-analytic"), Arch::ResNet18);
        assert_eq!(Arch::infer("mobilenetv2-analytic"), Arch::MobileNetV2);
        assert_eq!(Arch::infer("something-else"), Arch::Vgg16);
    }

    #[test]
    fn full_networks_have_distinct_sizes() {
        let vgg = Arch::Vgg16.full_network().mult_adds();
        let res = Arch::ResNet18.full_network().mult_adds();
        let mob = Arch::MobileNetV2.full_network().mult_adds();
        // The zoo spans ~2 orders of magnitude of compute — that is what
        // makes architecture a meaningful sweep axis.
        assert!(mob < res && res < vgg, "{mob} {res} {vgg}");
        assert!(vgg > 5 * res && res > 5 * mob);
    }

    #[test]
    fn slim_networks_classify_into_n_classes() {
        for a in Arch::ALL {
            let n = a.slim_network(32, 0.5, 64, 10);
            assert_eq!(n.output(), Shape::Flat(10), "{}", a.as_str());
            assert!(!split_points(&n).is_empty());
        }
    }
}
