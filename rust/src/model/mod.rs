//! Model metadata subsystem: layer graphs, shapes, parameter/mult-add
//! accounting (Tables I/II), per-layer activation/latent volumetrics, and
//! device compute-time profiles.

pub mod device;
pub mod layer;
pub mod stats;
pub mod vgg;

pub use device::DeviceProfile;
pub use layer::{Layer, LayerKind, Network, Shape};
pub use stats::{model_stats, render_table1, render_table2, ModelStats};
pub use vgg::{
    feature_layers, split_compute, vgg16_full, vgg16_slim, FeatureLayer,
    NUM_FEATURE_LAYERS,
};
