//! Graph cuts of a [`Network`] DAG — the generalization of "split after
//! layer i" that stays meaningful for architectures with skip connections.
//!
//! A *cut* partitions the topological node order into a head `[0..=pos]`
//! and a tail `[pos+1..]`. The cut is **valid** when every edge crossing
//! the frontier originates from one single node: exactly one tensor then
//! crosses the network boundary, which is the quantity the netsim
//! transfers. Cutting inside a residual block is invalid — the skip edge
//! and the main-path edge cross from *different* sources, so the frontier
//! would have to ship two tensors ([`valid_cuts`] excludes it).
//!
//! [`split_points`] narrows the valid cuts down to the positions each
//! architecture marks via [`super::layer::NetworkBuilder::cut_here`] —
//! the paper-style candidates (conv+ReLU boundaries and pools for VGG,
//! block boundaries for ResNet/MobileNet), indexed `0..n` per arch. For
//! VGG16 these coincide exactly with the 18 feature layers of Fig. 2.

use std::collections::HashMap;

use anyhow::{bail, Result};

use super::layer::{Network, Shape};
use super::{Arch, ModelScale};

/// One valid cut: the head/tail partition after topological position
/// `pos`, with the single crossing tensor and cumulative compute costs.
#[derive(Clone, Debug)]
pub struct Cut {
    /// Index of this cut within its enumeration (`split_points` ids are
    /// the arch's stable split indices).
    pub index: usize,
    /// Candidate name (mark name for split points; source-node name for
    /// raw valid cuts).
    pub name: String,
    /// Topological position: head = nodes `[0..=pos]`.
    pub pos: usize,
    /// Node whose output is the single crossing tensor.
    pub source: usize,
    /// The crossing tensor's shape.
    pub out: Shape,
    /// Mult-adds per image of the head nodes (no bottleneck).
    pub head_mult_adds: u64,
    /// Mult-adds per image of the tail nodes (no bottleneck).
    pub tail_mult_adds: u64,
}

impl Cut {
    /// Bytes of the raw crossing activation (f32, per image).
    pub fn crossing_bytes(&self) -> u64 {
        self.out.bytes_f32() as u64
    }

    /// Bytes of the 50%-compressed bottleneck latent transmitted when
    /// splitting here (channel/feature dimension halved, per the paper's
    /// AEs).
    pub fn latent_bytes(&self) -> u64 {
        match self.out {
            Shape::Chw(c, h, w) => ((c / 2).max(1) * h * w * 4) as u64,
            Shape::Flat(n) => ((n / 2).max(1) * 4) as u64,
        }
    }

    /// Mult-adds of the bottleneck (encoder, decoder) convs wrapped
    /// around this cut: encoder C -> C/2 3x3 at the crossing spatial
    /// size, decoder C/2 -> C (mirrors `python/compile/bottleneck.py`);
    /// for flat crossings a linear N -> N/2 -> N pair.
    pub fn bottleneck_mult_adds(&self) -> (u64, u64) {
        match self.out {
            Shape::Chw(c, h, w) => {
                let zc = (c / 2).max(1);
                let enc = (zc * h * w) as u64 * (c * 9) as u64
                    + (zc * h * w) as u64;
                let dec = (c * h * w) as u64 * (zc * 9) as u64
                    + (c * h * w) as u64;
                (enc, dec)
            }
            Shape::Flat(n) => {
                let z = (n / 2).max(1);
                let enc = (z * n + z) as u64;
                let dec = (n * z + n) as u64;
                (enc, dec)
            }
        }
    }

    /// Mult-adds per image of the head (plus bottleneck encoder) and of
    /// the tail (plus bottleneck decoder) when splitting here.
    pub fn split_compute(&self) -> (u64, u64) {
        let (enc, dec) = self.bottleneck_mult_adds();
        (self.head_mult_adds + enc, dec + self.tail_mult_adds)
    }
}

/// The single crossing source of the frontier after position `pos`, or
/// `None` when the cut is invalid (multiple sources, or a tail node reads
/// the raw network input).
fn crossing_source(net: &Network, pos: usize) -> Option<usize> {
    let mut source: Option<usize> = None;
    for (v, node) in net.nodes.iter().enumerate().skip(pos + 1) {
        if node.inputs.is_empty() {
            // Reads the raw network input from inside the tail: the input
            // would have to cross alongside the activation.
            return None;
        }
        for &u in &node.inputs {
            if u <= pos {
                match source {
                    None => source = Some(u),
                    Some(s) if s == u => {}
                    Some(_) => return None,
                }
            }
        }
    }
    source
}

/// Enumerate every structurally valid cut of `net`, in topological order.
/// Head and tail are both non-empty (`pos` ranges over `0..len-1`).
pub fn valid_cuts(net: &Network) -> Vec<Cut> {
    let total: u64 = net.mult_adds();
    let mut head = 0u64;
    let mut out = Vec::new();
    for pos in 0..net.len().saturating_sub(1) {
        head += net.layer(pos).mult_adds();
        if let Some(source) = crossing_source(net, pos) {
            out.push(Cut {
                index: out.len(),
                name: net.layer(source).name.clone(),
                pos,
                source,
                out: net.layer(source).out,
                head_mult_adds: head,
                tail_mult_adds: total - head,
            });
        }
    }
    out
}

/// The architecture's canonical split-point candidates: the cuts at the
/// positions marked with `cut_here`, indexed `0..n` in topological order.
/// Panics if a mark sits at an invalid position (a residual interior) —
/// that is a zoo-authoring bug, not a runtime condition.
pub fn split_points(net: &Network) -> Vec<Cut> {
    let total: u64 = net.mult_adds();
    let mut cum = vec![0u64; net.len()];
    let mut acc = 0u64;
    for (i, c) in cum.iter_mut().enumerate() {
        acc += net.layer(i).mult_adds();
        *c = acc;
    }
    net.cut_marks
        .iter()
        .enumerate()
        .map(|(index, (pos, name))| {
            let source = crossing_source(net, *pos).unwrap_or_else(|| {
                panic!(
                    "{}: cut mark '{name}' at node {pos} is not a valid \
                     single-tensor frontier (residual interior?)",
                    net.name
                )
            });
            Cut {
                index,
                name: name.clone(),
                pos: *pos,
                source,
                out: net.layer(source).out,
                head_mult_adds: cum[*pos],
                tail_mult_adds: total - cum[*pos],
            }
        })
        .collect()
}

/// Per-segment costs of a k-cut chain over `points` (the output of
/// [`split_points`]): the network is partitioned into `chain.len() + 1`
/// segments executed on a chain of tiers, each consecutive pair of
/// segments linked by the bottleneck codec of the cut between them.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ChainCosts {
    /// Mult-adds per image of each segment, *including* the bottleneck
    /// decoder of the incoming cut and the encoder of the outgoing cut
    /// (`len == chain.len() + 1`). A single-cut chain reproduces
    /// [`Cut::split_compute`] exactly.
    pub seg_mult_adds: Vec<u64>,
    /// Compressed latent bytes crossing each inter-tier hop
    /// (`len == chain.len()`), i.e. [`Cut::latent_bytes`] per cut.
    pub hop_bytes: Vec<u64>,
}

/// Is `cuts` a well-ordered cut chain: non-empty and strictly increasing
/// (k ordered cuts over one topological order)? The single validity
/// predicate shared by the scenario parser, the sweep spec, the analytic
/// backend's on-demand executables and [`chain_costs`].
pub fn is_ordered_chain(cuts: &[usize]) -> bool {
    !cuts.is_empty() && cuts.windows(2).all(|w| w[0] < w[1])
}

/// Resolve the per-segment accounting of an ordered cut chain. `chain`
/// holds strictly increasing indices into `points`; the last split point
/// is excluded (its tail is degenerate), mirroring the single-cut bound.
pub fn chain_costs(points: &[Cut], chain: &[usize]) -> Result<ChainCosts> {
    if !is_ordered_chain(chain) {
        bail!(
            "cut chain {chain:?} must be non-empty and strictly \
             increasing (one topological order, k ordered cuts)"
        );
    }
    let last_valid = points.len().saturating_sub(1);
    for &c in chain {
        if c >= last_valid {
            bail!(
                "cut {c} out of range: {} cut points (valid: 0..={})",
                points.len(),
                last_valid.saturating_sub(1)
            );
        }
    }
    let mut seg = Vec::with_capacity(chain.len() + 1);
    let mut hop = Vec::with_capacity(chain.len());
    let mut prev_head = 0u64; // cumulative head MACs up to the previous cut
    let mut prev_dec = 0u64; // decoder of the incoming bottleneck
    for &c in chain {
        let cut = &points[c];
        let (enc, dec) = cut.bottleneck_mult_adds();
        seg.push(cut.head_mult_adds - prev_head + prev_dec + enc);
        hop.push(cut.latent_bytes());
        prev_head = cut.head_mult_adds;
        prev_dec = dec;
    }
    let last = &points[*chain.last().unwrap()];
    seg.push(last.tail_mult_adds + prev_dec);
    Ok(ChainCosts { seg_mult_adds: seg, hop_bytes: hop })
}

/// All strictly increasing chains of `k` ids over the ascending list
/// `ids` — the shared k-subset enumerator behind [`valid_cut_chains`]
/// and the suggest engine's multi-tier candidate generation.
pub fn ordered_chains(ids: &[usize], k: usize) -> Vec<Vec<usize>> {
    fn rec(
        ids: &[usize],
        start: usize,
        k: usize,
        cur: &mut Vec<usize>,
        out: &mut Vec<Vec<usize>>,
    ) {
        if cur.len() == k {
            out.push(cur.clone());
            return;
        }
        for i in start..ids.len() {
            cur.push(ids[i]);
            rec(ids, i + 1, k, cur, out);
            cur.pop();
        }
    }
    let mut out = Vec::new();
    if k > 0 && k <= ids.len() {
        rec(ids, 0, k, &mut Vec::with_capacity(k), &mut out);
    }
    out
}

/// Enumerate every valid ordered chain of `k` cuts over the network's
/// marked split points: all strictly increasing k-subsets of the split
/// ids admissible for [`chain_costs`]. The single topological order makes
/// validity purely combinatorial — the frontier machinery already
/// guarantees each individual id is a single-tensor cut.
pub fn valid_cut_chains(net: &Network, k: usize) -> Vec<Vec<usize>> {
    let ids: Vec<usize> =
        (0..split_points(net).len().saturating_sub(1)).collect();
    ordered_chains(&ids, k)
}

/// Crate-wide memoization of [`valid_cut_chains`] per (arch × scale × k):
/// the adaptive controller re-evaluates the candidate set on every Check,
/// the placement search re-enumerates it per tier chain, and the budgeted
/// co-design search per rung — re-enumerating the k-subset lattice each
/// time would make every decision O(enumeration) instead of
/// O(candidates). The counters are observable so regression tests can pin
/// "one enumeration, many lookups".
pub struct ChainCache {
    map: HashMap<(Arch, ModelScale, usize), Vec<Vec<usize>>>,
    enumerations: u64,
    lookups: u64,
}

impl Default for ChainCache {
    fn default() -> Self {
        Self::new()
    }
}

impl ChainCache {
    pub fn new() -> Self {
        ChainCache { map: HashMap::new(), enumerations: 0, lookups: 0 }
    }

    /// The candidate cut chains of `net` for `k` cuts, enumerating at
    /// most once per (arch, scale, k).
    pub fn chains(
        &mut self,
        arch: Arch,
        scale: ModelScale,
        k: usize,
        net: &Network,
    ) -> &[Vec<usize>] {
        self.lookups += 1;
        let key = (arch, scale, k);
        if !self.map.contains_key(&key) {
            self.enumerations += 1;
            self.map.insert(key, valid_cut_chains(net, k));
        }
        self.map.get(&key).expect("just inserted")
    }

    /// How many times the k-subset lattice was actually enumerated.
    pub fn enumerations(&self) -> u64 {
        self.enumerations
    }

    /// How many candidate-set requests were served (cache hits + misses).
    pub fn lookups(&self) -> u64 {
        self.lookups
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::layer::NetworkBuilder;

    fn chain() -> Network {
        NetworkBuilder::new("chain", Shape::Chw(3, 8, 8))
            .conv3x3("c1", 4)
            .relu("r1")
            .cut_here("c1")
            .maxpool2("p1")
            .cut_here("p1")
            .flatten("f")
            .linear("fc", 10)
            .build()
    }

    fn residual() -> Network {
        let mut b = NetworkBuilder::new("res", Shape::Chw(3, 8, 8))
            .conv3x3("pre", 4)
            .relu("pre_relu")
            .cut_here("pre");
        let skip = b.branch();
        b = b
            .conv3x3("c1", 4)
            .relu("r1")
            .conv3x3("c2", 4)
            .merge_add("add", skip)
            .relu("r2")
            .cut_here("block");
        b.flatten("f").linear("fc", 10).build()
    }

    #[test]
    fn every_chain_position_is_a_valid_cut() {
        let net = chain();
        let cuts = valid_cuts(&net);
        // A pure chain: every non-final position is a valid cut.
        assert_eq!(cuts.len(), net.len() - 1);
        for (i, c) in cuts.iter().enumerate() {
            assert_eq!(c.pos, i);
            assert_eq!(c.source, i);
            assert_eq!(
                c.head_mult_adds + c.tail_mult_adds,
                net.mult_adds()
            );
        }
    }

    #[test]
    fn residual_interior_cuts_are_excluded() {
        let net = residual();
        let cuts = valid_cuts(&net);
        let add =
            net.nodes.iter().position(|n| n.layer.name == "add").unwrap();
        // The pre_relu node both paths read.
        let skip_src = net
            .nodes
            .iter()
            .position(|n| n.layer.name == "pre_relu")
            .unwrap();
        // No valid cut strictly inside the block: positions between the
        // fork source and the merge have two crossing sources.
        for c in &cuts {
            assert!(
                c.pos < skip_src + 1 || c.pos >= add,
                "cut at {} is inside the residual block",
                c.pos
            );
        }
        // The frontier right at the fork is valid (single source: the
        // forked tensor feeds both paths).
        assert!(cuts.iter().any(|c| c.pos == skip_src));
        // And so is the frontier after the merge.
        assert!(cuts.iter().any(|c| c.pos == add));
    }

    #[test]
    fn split_points_follow_marks() {
        let net = chain();
        let pts = split_points(&net);
        assert_eq!(pts.len(), 2);
        assert_eq!(pts[0].name, "c1");
        assert_eq!(pts[0].index, 0);
        assert_eq!(pts[0].out, Shape::Chw(4, 8, 8));
        assert_eq!(pts[1].name, "p1");
        assert_eq!(pts[1].out, Shape::Chw(4, 4, 4));
        // Conservation at every split point.
        for p in &pts {
            assert_eq!(p.head_mult_adds + p.tail_mult_adds, net.mult_adds());
        }
    }

    #[test]
    fn residual_marks_resolve_to_single_tensors() {
        let net = residual();
        let pts = split_points(&net);
        assert_eq!(pts.len(), 2);
        assert_eq!(pts[1].name, "block");
        assert_eq!(pts[1].out, Shape::Chw(4, 8, 8));
        assert_eq!(
            pts[1].head_mult_adds + pts[1].tail_mult_adds,
            net.mult_adds()
        );
    }

    #[test]
    fn latent_and_bottleneck_math() {
        let c = Cut {
            index: 0,
            name: "x".into(),
            pos: 0,
            source: 0,
            out: Shape::Chw(512, 28, 28),
            head_mult_adds: 10,
            tail_mult_adds: 20,
        };
        assert_eq!(c.crossing_bytes(), 512 * 28 * 28 * 4);
        assert_eq!(c.latent_bytes(), 256 * 28 * 28 * 4);
        let (enc, dec) = c.bottleneck_mult_adds();
        assert_eq!(enc, (256 * 28 * 28) as u64 * (512 * 9) as u64
                        + (256 * 28 * 28) as u64);
        assert_eq!(dec, (512 * 28 * 28) as u64 * (256 * 9) as u64
                        + (512 * 28 * 28) as u64);
        let (h, t) = c.split_compute();
        assert_eq!(h, 10 + enc);
        assert_eq!(t, dec + 20);
    }

    #[test]
    fn single_cut_chain_reproduces_split_compute() {
        // The degenerate-equivalence anchor at the accounting level: a
        // one-cut chain's two segments are exactly (head+enc, dec+tail).
        let net = chain();
        let pts = split_points(&net);
        for c in 0..pts.len() - 1 {
            let costs = chain_costs(&pts, &[c]).unwrap();
            let (head, tail) = pts[c].split_compute();
            assert_eq!(costs.seg_mult_adds, vec![head, tail]);
            assert_eq!(costs.hop_bytes, vec![pts[c].latent_bytes()]);
        }
    }

    fn chain3() -> Network {
        // Three marked points, so 2-cut chains exist over this toy net.
        NetworkBuilder::new("chain3", Shape::Chw(3, 8, 8))
            .conv3x3("c1", 4)
            .relu("r1")
            .cut_here("c1")
            .maxpool2("p1")
            .cut_here("p1")
            .conv3x3("c2", 8)
            .relu("r2")
            .cut_here("c2")
            .flatten("f")
            .linear("fc", 10)
            .build()
    }

    #[test]
    fn chain_segments_conserve_macs_plus_codecs() {
        let net = chain3();
        let pts = split_points(&net);
        let chains = [vec![0usize], vec![1], vec![0, 1]];
        for ch in &chains {
            let costs = chain_costs(&pts, ch).unwrap();
            assert_eq!(costs.seg_mult_adds.len(), ch.len() + 1);
            assert_eq!(costs.hop_bytes.len(), ch.len());
            let codec: u64 = ch
                .iter()
                .map(|&c| {
                    let (e, d) = pts[c].bottleneck_mult_adds();
                    e + d
                })
                .sum();
            assert_eq!(
                costs.seg_mult_adds.iter().sum::<u64>(),
                net.mult_adds() + codec,
                "chain {ch:?}: segment MACs must telescope to \
                 total + codecs"
            );
        }
    }

    #[test]
    fn chain_costs_rejects_bad_chains() {
        let net = chain();
        let pts = split_points(&net);
        assert!(chain_costs(&pts, &[]).is_err());
        assert!(chain_costs(&pts, &[1, 1]).is_err());
        assert!(chain_costs(&pts, &[1, 0]).is_err());
        // The last split point is excluded, as for single cuts.
        assert!(chain_costs(&pts, &[pts.len() - 1]).is_err());
    }

    #[test]
    fn valid_cut_chains_enumerates_increasing_subsets() {
        let net = chain3();
        let n = split_points(&net).len() - 1; // admissible ids: 0..n
        let one = valid_cut_chains(&net, 1);
        assert_eq!(one.len(), n);
        let two = valid_cut_chains(&net, 2);
        assert_eq!(two.len(), n * (n - 1) / 2);
        for ch in one.iter().chain(&two) {
            assert!(chain_costs(&split_points(&net), ch).is_ok());
            assert!(ch.windows(2).all(|w| w[0] < w[1]));
        }
        assert!(valid_cut_chains(&net, 0).is_empty());
        assert!(valid_cut_chains(&net, n + 1).is_empty());
    }

    #[test]
    fn flat_crossing_uses_linear_bottleneck() {
        let c = Cut {
            index: 0,
            name: "x".into(),
            pos: 0,
            source: 0,
            out: Shape::Flat(64),
            head_mult_adds: 0,
            tail_mult_adds: 0,
        };
        assert_eq!(c.latent_bytes(), 32 * 4);
        assert_eq!(c.bottleneck_mult_adds(), (32 * 64 + 32, 64 * 32 + 64));
    }
}
