//! Criterion-style measurement harness (no criterion in the offline image).
//!
//! Used by the `cargo bench` targets (`harness = false`): warmup, repeated
//! timed iterations, mean / median / p99 / std-dev, throughput, and a
//! stable one-line report format the bench binaries print.

use std::time::{Duration, Instant};

#[derive(Clone, Debug)]
pub struct Stats {
    pub iters: usize,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub p99_ns: f64,
    pub min_ns: f64,
    pub max_ns: f64,
    pub stddev_ns: f64,
}

impl Stats {
    pub fn from_samples(mut ns: Vec<f64>) -> Stats {
        assert!(!ns.is_empty());
        ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = ns.len();
        let mean = ns.iter().sum::<f64>() / n as f64;
        let var =
            ns.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / n as f64;
        Stats {
            iters: n,
            mean_ns: mean,
            median_ns: ns[n / 2],
            p99_ns: ns[((n as f64) * 0.99) as usize % n.max(1)],
            min_ns: ns[0],
            max_ns: ns[n - 1],
            stddev_ns: var.sqrt(),
        }
    }

    pub fn mean(&self) -> Duration {
        Duration::from_nanos(self.mean_ns as u64)
    }
}

/// Human-friendly time formatting (ns/µs/ms/s).
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

pub struct Bencher {
    /// Minimum wall time to spend measuring each benchmark.
    pub measure_time: Duration,
    pub warmup_time: Duration,
    pub max_iters: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            measure_time: Duration::from_millis(800),
            warmup_time: Duration::from_millis(200),
            max_iters: 100_000,
        }
    }
}

impl Bencher {
    pub fn quick() -> Self {
        Bencher {
            measure_time: Duration::from_millis(200),
            warmup_time: Duration::from_millis(50),
            max_iters: 10_000,
        }
    }

    /// Measure `f`, printing a criterion-like line. Returns the stats.
    pub fn bench<F: FnMut()>(&self, name: &str, mut f: F) -> Stats {
        // Warmup.
        let wstart = Instant::now();
        let mut warm_iters = 0usize;
        while wstart.elapsed() < self.warmup_time && warm_iters < 1000 {
            f();
            warm_iters += 1;
        }
        // Measure.
        let mut samples = Vec::new();
        let mstart = Instant::now();
        while mstart.elapsed() < self.measure_time
            && samples.len() < self.max_iters
        {
            let t = Instant::now();
            f();
            samples.push(t.elapsed().as_nanos() as f64);
        }
        let st = Stats::from_samples(samples);
        println!(
            "bench {name:<44} mean {:>12}  median {:>12}  p99 {:>12}  ({} iters)",
            fmt_ns(st.mean_ns),
            fmt_ns(st.median_ns),
            fmt_ns(st.p99_ns),
            st.iters
        );
        st
    }

    /// Like `bench` but also reports items/second throughput.
    pub fn bench_throughput<F: FnMut()>(
        &self,
        name: &str,
        items_per_iter: f64,
        f: F,
    ) -> Stats {
        let st = self.bench(name, f);
        let per_sec = items_per_iter / (st.mean_ns / 1e9);
        println!("      {name:<44} throughput {:.0} items/s", per_sec);
        st
    }
}

/// Prevent the optimizer from eliding a computation (std::hint based).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_of_constant_samples() {
        let s = Stats::from_samples(vec![100.0; 50]);
        assert_eq!(s.mean_ns, 100.0);
        assert_eq!(s.median_ns, 100.0);
        assert_eq!(s.stddev_ns, 0.0);
    }

    #[test]
    fn stats_ordering() {
        let s = Stats::from_samples(vec![3.0, 1.0, 2.0]);
        assert_eq!(s.min_ns, 1.0);
        assert_eq!(s.max_ns, 3.0);
        assert_eq!(s.median_ns, 2.0);
    }

    #[test]
    fn fmt_ranges() {
        assert!(fmt_ns(500.0).contains("ns"));
        assert!(fmt_ns(5_000.0).contains("µs"));
        assert!(fmt_ns(5_000_000.0).contains("ms"));
        assert!(fmt_ns(5e9).ends_with("s"));
    }

    #[test]
    fn bench_runs_function() {
        let mut count = 0usize;
        let b = Bencher {
            measure_time: Duration::from_millis(5),
            warmup_time: Duration::from_millis(1),
            max_iters: 100,
        };
        b.bench("noop", || count += 1);
        assert!(count > 0);
    }
}
