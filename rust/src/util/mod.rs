//! Infrastructure substrates built in-repo (the offline image vendors only
//! the `xla` crate's closure — no clap/serde/rand/criterion/proptest).

pub mod bench;
pub mod cli;
pub mod json;
pub mod propcheck;
pub mod rng;
pub mod table;
