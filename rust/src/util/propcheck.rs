//! Property-based testing mini-framework (no proptest in the offline image).
//!
//! A property is a function over a seeded case generator; the runner drives
//! many random cases and, on failure, retries with "shrunken" variants of
//! the failing case's scale parameter to report the smallest failure it can
//! find. Used heavily for the netsim invariants (see
//! rust/tests/netsim_properties.rs).

use crate::util::rng::Rng;

/// Budget for one property run.
#[derive(Clone, Copy)]
pub struct Config {
    pub cases: usize,
    pub base_seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Config { cases: 64, base_seed: 0x5E1_5E1 }
    }
}

/// A generated case: an RNG stream plus a size hint in [0, 1] that
/// generators should use to scale structures (bigger later cases).
pub struct Case<'a> {
    pub rng: &'a mut Rng,
    pub size: f64,
}

impl<'a> Case<'a> {
    /// Integer in [lo, hi] biased by the case size (ramps up coverage).
    pub fn sized_range(&mut self, lo: u64, hi: u64) -> u64 {
        let span = ((hi - lo) as f64 * self.size).ceil() as u64;
        self.rng.range_u64(lo, lo + span.min(hi - lo))
    }

    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.range_f64(lo, hi)
    }

    pub fn choice<'b, T>(&mut self, items: &'b [T]) -> &'b T {
        &items[self.rng.below(items.len() as u64) as usize]
    }

    pub fn bool(&mut self) -> bool {
        self.rng.chance(0.5)
    }
}

/// Run `prop` over `cfg.cases` random cases. `prop` returns Err(msg) on
/// violation. Panics with a reproduction seed on failure.
pub fn check<F>(name: &str, cfg: Config, mut prop: F)
where
    F: FnMut(&mut Case) -> Result<(), String>,
{
    for i in 0..cfg.cases {
        let seed = cfg.base_seed.wrapping_add(i as u64);
        let mut rng = Rng::new(seed);
        let size = (i + 1) as f64 / cfg.cases as f64;
        let mut case = Case { rng: &mut rng, size };
        if let Err(msg) = prop(&mut case) {
            // Shrink: retry with progressively smaller size at same seed to
            // find a smaller counterexample for the report.
            let mut smallest: Option<(f64, String)> = None;
            for k in 1..=8 {
                let s = size * (1.0 - k as f64 / 10.0);
                if s <= 0.0 {
                    break;
                }
                let mut rng2 = Rng::new(seed);
                let mut c2 = Case { rng: &mut rng2, size: s };
                if let Err(m) = prop(&mut c2) {
                    smallest = Some((s, m));
                }
            }
            let detail = match smallest {
                Some((s, m)) => format!(
                    "{msg}\n  shrunk: size={s:.2} still fails: {m}"
                ),
                None => msg,
            };
            panic!(
                "property '{name}' failed (seed={seed}, case {i}, \
                 size={size:.2}):\n  {detail}"
            );
        }
    }
}

/// Like `check`, but the property itself is passed the seed (for cases
/// where internals need to derive several independent streams).
pub fn check_seeded<F>(name: &str, cfg: Config, mut prop: F)
where
    F: FnMut(u64, f64) -> Result<(), String>,
{
    for i in 0..cfg.cases {
        let seed = cfg.base_seed.wrapping_add((i as u64) << 8);
        let size = (i + 1) as f64 / cfg.cases as f64;
        if let Err(msg) = prop(seed, size) {
            panic!(
                "property '{name}' failed (seed={seed}, size={size:.2}):\n  {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("tautology", Config::default(), |c| {
            let v = c.rng.below(10);
            if v < 10 {
                Ok(())
            } else {
                Err("impossible".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'falsum' failed")]
    fn failing_property_panics_with_seed() {
        check("falsum", Config { cases: 8, base_seed: 1 }, |c| {
            if c.rng.below(4) == 0 {
                Err("hit zero".into())
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn sized_range_within_bounds() {
        check("sized_range", Config::default(), |c| {
            let v = c.sized_range(3, 10);
            if (3..=10).contains(&v) {
                Ok(())
            } else {
                Err(format!("{v} out of range"))
            }
        });
    }

    #[test]
    fn seeded_variant_runs_all_cases() {
        let mut n = 0;
        check_seeded("count", Config { cases: 5, base_seed: 0 }, |_, _| {
            n += 1;
            Ok(())
        });
        assert_eq!(n, 5);
    }
}
