//! Minimal JSON parser/writer (no serde in the offline image).
//!
//! Parses the artifact manifest written by `python/compile/aot.py` and
//! writes the report/result files the benches emit. Supports the full JSON
//! value grammar needed for those files: objects, arrays, strings (with
//! escapes), numbers, booleans, null.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing data at byte {}", p.i);
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m
                .get(key)
                .ok_or_else(|| anyhow!("missing key '{key}'")),
            _ => bail!("not an object (key '{key}')"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("not an array"),
        }
    }

    pub fn str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string"),
        }
    }

    pub fn f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("not a number"),
        }
    }

    pub fn usize(&self) -> Result<usize> {
        Ok(self.u64()? as usize)
    }

    pub fn i64(&self) -> Result<i64> {
        let n = self.f64()?;
        if !n.is_finite() || n.fract() != 0.0 {
            bail!("expected an integer, got {n}");
        }
        Ok(n as i64)
    }

    /// Non-negative integer accessor. All numbers flow through the `f64`
    /// representation, so integers above 2^53 lose precision upstream of
    /// this call; fractional and negative values are rejected rather than
    /// silently truncated.
    pub fn u64(&self) -> Result<u64> {
        let n = self.f64()?;
        if !n.is_finite() || n < 0.0 || n.fract() != 0.0 {
            bail!("expected a non-negative integer, got {n}");
        }
        Ok(n as u64)
    }

    pub fn bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("not a bool"),
        }
    }

    /// `[1, 2, 3]` -> Vec<usize>, etc.
    pub fn usize_vec(&self) -> Result<Vec<usize>> {
        self.arr()?.iter().map(|v| v.usize()).collect()
    }

    pub fn f64_vec(&self) -> Result<Vec<f64>> {
        self.arr()?.iter().map(|v| v.f64()).collect()
    }

    /// `["a", "b"]` -> Vec<String> (used by the sweep-spec deserializer).
    pub fn str_vec(&self) -> Result<Vec<String>> {
        self.arr()?
            .iter()
            .map(|v| Ok(v.str()?.to_string()))
            .collect()
    }

    // -- writer ------------------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, e) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    e.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience constructors for report writers.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

pub fn arr(v: Vec<Json>) -> Json {
    Json::Arr(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected '{}' at byte {}, found '{}'",
                  c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}', found '{}'", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected ',' or ']', found '{}'", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("bad \\u escape");
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(
                                char::from_u32(cp)
                                    .ok_or_else(|| anyhow!("bad codepoint"))?,
                            );
                        }
                        _ => bail!("bad escape '\\{}'", e as char),
                    }
                }
                c => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = if c >= 0xF0 {
                            4
                        } else if c >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        let end = start + len;
                        if end > self.b.len() {
                            bail!("truncated UTF-8");
                        }
                        s.push_str(std::str::from_utf8(&self.b[start..end])?);
                        self.i = end;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i],
                        b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let txt = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(txt.parse::<f64>().map_err(|e| {
            anyhow!("bad number '{txt}' at byte {start}: {e}")
        })?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(j.get("a").unwrap().arr().unwrap().len(), 3);
        assert_eq!(
            j.get("a").unwrap().arr().unwrap()[2]
                .get("b")
                .unwrap()
                .str()
                .unwrap(),
            "c"
        );
    }

    #[test]
    fn parse_escapes() {
        let j = Json::parse(r#""a\nb\t\"q\" A""#).unwrap();
        assert_eq!(j.str().unwrap(), "a\nb\t\"q\" A");
    }

    #[test]
    fn parse_unicode_passthrough() {
        let j = Json::parse("\"caffè ☕\"").unwrap();
        assert_eq!(j.str().unwrap(), "caffè ☕");
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"b":[1,2.5,true,null,"x"],"a":{"k":-3}}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("{} x").is_err());
    }

    #[test]
    fn rejects_truncation() {
        assert!(Json::parse(r#"{"a": [1, 2"#).is_err());
        assert!(Json::parse(r#""unterminated"#).is_err());
    }

    #[test]
    fn typed_accessors() {
        let j = Json::parse(r#"{"v": [3, 4], "f": 1.5}"#).unwrap();
        assert_eq!(j.get("v").unwrap().usize_vec().unwrap(), vec![3, 4]);
        assert_eq!(j.get("f").unwrap().f64().unwrap(), 1.5);
        assert!(j.get("missing").is_err());
        assert!(j.opt("missing").is_none());
    }

    #[test]
    fn string_and_u64_accessors() {
        let j = Json::parse(r#"{"s": ["tcp", "udp"], "n": 42}"#).unwrap();
        assert_eq!(
            j.get("s").unwrap().str_vec().unwrap(),
            vec!["tcp".to_string(), "udp".to_string()]
        );
        assert_eq!(j.get("n").unwrap().u64().unwrap(), 42);
        assert!(j.get("n").unwrap().str_vec().is_err());
        assert!(j.get("s").unwrap().arr().unwrap()[0].u64().is_err());
        assert!(Json::Num(-1.0).u64().is_err());
        assert!(Json::Num(1.9).u64().is_err());
        assert!(Json::Num(f64::NAN).u64().is_err());
    }

    #[test]
    fn writer_escapes() {
        let j = Json::Str("a\"b\n".into());
        assert_eq!(j.to_string(), r#""a\"b\n""#);
    }

    #[test]
    fn integers_written_without_fraction() {
        assert_eq!(Json::Num(5.0).to_string(), "5");
        assert_eq!(Json::Num(5.25).to_string(), "5.25");
    }
}
