//! Aligned text tables for the report generators (Tables I/II, bench rows).

/// Build an aligned, boxed text table from a header row and data rows.
pub fn render(headers: &[&str], rows: &[Vec<String>]) -> String {
    let ncols = headers.len();
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        assert_eq!(row.len(), ncols, "row width mismatch");
        for (i, cell) in row.iter().enumerate() {
            widths[i] = widths[i].max(cell.chars().count());
        }
    }
    let sep: String = {
        let mut s = String::from("+");
        for w in &widths {
            s.push_str(&"-".repeat(w + 2));
            s.push('+');
        }
        s.push('\n');
        s
    };
    let fmt_row = |cells: &[String]| -> String {
        let mut s = String::from("|");
        for (i, c) in cells.iter().enumerate() {
            let pad = widths[i] - c.chars().count();
            s.push(' ');
            s.push_str(c);
            s.push_str(&" ".repeat(pad + 1));
            s.push('|');
        }
        s.push('\n');
        s
    };
    let mut out = String::new();
    out.push_str(&sep);
    out.push_str(&fmt_row(
        &headers.iter().map(|h| h.to_string()).collect::<Vec<_>>(),
    ));
    out.push_str(&sep);
    for r in rows {
        out.push_str(&fmt_row(r));
    }
    out.push_str(&sep);
    out
}

/// Thousands separators in the paper's European style: 138.357.544.
pub fn group_digits(n: u64) -> String {
    let s = n.to_string();
    let bytes = s.as_bytes();
    let mut out = String::new();
    for (i, b) in bytes.iter().enumerate() {
        if i > 0 && (bytes.len() - i) % 3 == 0 {
            out.push('.');
        }
        out.push(*b as char);
    }
    out
}

/// Simple ASCII line plot: one series per label, y normalized per chart.
pub fn ascii_plot(
    title: &str,
    xlabel: &str,
    xs: &[f64],
    series: &[(&str, Vec<f64>)],
    height: usize,
) -> String {
    let glyphs = ['*', 'o', '+', 'x', '#', '@'];
    let mut ymin = f64::INFINITY;
    let mut ymax = f64::NEG_INFINITY;
    for (_, ys) in series {
        for &y in ys {
            ymin = ymin.min(y);
            ymax = ymax.max(y);
        }
    }
    if !ymin.is_finite() || ymax <= ymin {
        ymax = ymin + 1.0;
    }
    let width = xs.len().max(2);
    let mut grid = vec![vec![' '; width]; height];
    for (si, (_, ys)) in series.iter().enumerate() {
        for (xi, &y) in ys.iter().enumerate() {
            let fy = (y - ymin) / (ymax - ymin);
            let row = ((1.0 - fy) * (height - 1) as f64).round() as usize;
            grid[row.min(height - 1)][xi] = glyphs[si % glyphs.len()];
        }
    }
    let mut out = format!("{title}\n");
    for (ri, row) in grid.iter().enumerate() {
        let yv = ymax - (ri as f64 / (height - 1) as f64) * (ymax - ymin);
        out.push_str(&format!("{yv:>10.4} | "));
        out.extend(row.iter());
        out.push('\n');
    }
    out.push_str(&format!("{:>10} +-{}\n", "", "-".repeat(width)));
    out.push_str(&format!("{:>13}{xlabel}\n", ""));
    for (si, (label, _)) in series.iter().enumerate() {
        out.push_str(&format!(
            "{:>13}{} = {label}\n",
            "",
            glyphs[si % glyphs.len()]
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_is_aligned() {
        let t = render(
            &["a", "long header"],
            &[
                vec!["1".into(), "2".into()],
                vec!["333".into(), "4".into()],
            ],
        );
        let lines: Vec<&str> = t.lines().collect();
        let w = lines[0].len();
        assert!(lines.iter().all(|l| l.len() == w), "{t}");
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn table_rejects_ragged_rows() {
        render(&["a", "b"], &[vec!["1".into()]]);
    }

    #[test]
    fn digit_grouping_paper_style() {
        assert_eq!(group_digits(138_357_544), "138.357.544");
        assert_eq!(group_digits(1_792), "1.792");
        assert_eq!(group_digits(999), "999");
        assert_eq!(group_digits(0), "0");
    }

    #[test]
    fn plot_contains_series_glyphs_and_labels() {
        let p = ascii_plot(
            "t",
            "x",
            &[0.0, 1.0, 2.0],
            &[("up", vec![0.0, 1.0, 2.0]), ("down", vec![2.0, 1.0, 0.0])],
            8,
        );
        assert!(p.contains('*') && p.contains('o'));
        assert!(p.contains("up") && p.contains("down"));
    }

    #[test]
    fn plot_handles_flat_series() {
        let p = ascii_plot("t", "x", &[0.0, 1.0], &[("f", vec![1.0, 1.0])], 4);
        assert!(p.contains('*'));
    }
}
