//! Declarative command-line parser (no clap in the offline image).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional arguments,
//! subcommands, defaults, and generated `--help`.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

#[derive(Clone, Debug)]
pub struct ArgSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<String>,
    pub is_flag: bool,
    pub required: bool,
}

/// One (sub)command: a list of argument specs and the parsed values.
pub struct Command {
    pub name: &'static str,
    pub about: &'static str,
    specs: Vec<ArgSpec>,
}

impl Command {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Command { name, about, specs: Vec::new() }
    }

    pub fn opt(mut self, name: &'static str, default: &str,
               help: &'static str) -> Self {
        self.specs.push(ArgSpec {
            name,
            help,
            default: Some(default.to_string()),
            is_flag: false,
            required: false,
        });
        self
    }

    pub fn required(mut self, name: &'static str, help: &'static str) -> Self {
        self.specs.push(ArgSpec {
            name,
            help,
            default: None,
            is_flag: false,
            required: true,
        });
        self
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.specs.push(ArgSpec {
            name,
            help,
            default: None,
            is_flag: true,
            required: false,
        });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\noptions:\n", self.name, self.about);
        for a in &self.specs {
            let d = match (&a.default, a.is_flag) {
                (_, true) => String::from("(flag)"),
                (Some(d), _) => format!("(default: {d})"),
                (None, _) => String::from("(required)"),
            };
            s.push_str(&format!("  --{:<22} {} {}\n", a.name, a.help, d));
        }
        s
    }

    /// Parse `args` (without argv[0] / subcommand name).
    pub fn parse(&self, args: &[String]) -> Result<Matches> {
        let mut values: BTreeMap<String, String> = BTreeMap::new();
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            if a == "--help" || a == "-h" {
                bail!("{}", self.usage());
            }
            let Some(stripped) = a.strip_prefix("--") else {
                bail!("unexpected positional argument '{a}'\n{}", self.usage());
            };
            let (key, inline) = match stripped.split_once('=') {
                Some((k, v)) => (k, Some(v.to_string())),
                None => (stripped, None),
            };
            let spec = self
                .specs
                .iter()
                .find(|s| s.name == key)
                .ok_or_else(|| anyhow!("unknown option '--{key}'\n{}",
                                       self.usage()))?;
            let val = if spec.is_flag {
                if inline.is_some() {
                    bail!("flag '--{key}' takes no value");
                }
                "true".to_string()
            } else if let Some(v) = inline {
                v
            } else {
                i += 1;
                args.get(i)
                    .cloned()
                    .ok_or_else(|| anyhow!("option '--{key}' needs a value"))?
            };
            values.insert(key.to_string(), val);
            i += 1;
        }
        for spec in &self.specs {
            if !values.contains_key(spec.name) {
                if spec.required {
                    bail!("missing required option '--{}'\n{}",
                          spec.name, self.usage());
                }
                if let Some(d) = &spec.default {
                    values.insert(spec.name.to_string(), d.clone());
                }
            }
        }
        Ok(Matches { values })
    }
}

#[derive(Debug)]
pub struct Matches {
    values: BTreeMap<String, String>,
}

impl Matches {
    pub fn str(&self, key: &str) -> &str {
        self.values
            .get(key)
            .map(|s| s.as_str())
            .unwrap_or_else(|| panic!("option '{key}' not declared"))
    }

    pub fn opt_str(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    pub fn f64(&self, key: &str) -> Result<f64> {
        self.str(key)
            .parse()
            .map_err(|e| anyhow!("--{key}: bad float: {e}"))
    }

    pub fn usize(&self, key: &str) -> Result<usize> {
        self.str(key)
            .parse()
            .map_err(|e| anyhow!("--{key}: bad integer: {e}"))
    }

    pub fn u64(&self, key: &str) -> Result<u64> {
        self.str(key)
            .parse()
            .map_err(|e| anyhow!("--{key}: bad integer: {e}"))
    }

    pub fn flag(&self, key: &str) -> bool {
        self.values.get(key).map(|v| v == "true").unwrap_or(false)
    }

    /// Comma-separated list of floats, e.g. `--loss 0,0.01,0.03`.
    pub fn f64_list(&self, key: &str) -> Result<Vec<f64>> {
        self.str(key)
            .split(',')
            .filter(|s| !s.is_empty())
            .map(|s| s.trim().parse().map_err(|e| anyhow!("--{key}: {e}")))
            .collect()
    }

    pub fn usize_list(&self, key: &str) -> Result<Vec<usize>> {
        self.str(key)
            .split(',')
            .filter(|s| !s.is_empty())
            .map(|s| s.trim().parse().map_err(|e| anyhow!("--{key}: {e}")))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cmd() -> Command {
        Command::new("test", "a test command")
            .opt("alpha", "1.5", "alpha value")
            .required("name", "the name")
            .flag("verbose", "print more")
            .opt("list", "1,2", "a list")
    }

    fn parse(args: &[&str]) -> Result<Matches> {
        cmd().parse(&args.iter().map(|s| s.to_string()).collect::<Vec<_>>())
    }

    #[test]
    fn defaults_and_required() {
        let m = parse(&["--name", "x"]).unwrap();
        assert_eq!(m.f64("alpha").unwrap(), 1.5);
        assert_eq!(m.str("name"), "x");
        assert!(!m.flag("verbose"));
    }

    #[test]
    fn equals_syntax() {
        let m = parse(&["--name=y", "--alpha=2"]).unwrap();
        assert_eq!(m.str("name"), "y");
        assert_eq!(m.f64("alpha").unwrap(), 2.0);
    }

    #[test]
    fn flags() {
        let m = parse(&["--name", "x", "--verbose"]).unwrap();
        assert!(m.flag("verbose"));
    }

    #[test]
    fn missing_required_errors() {
        assert!(parse(&[]).is_err());
    }

    #[test]
    fn unknown_option_errors() {
        assert!(parse(&["--name", "x", "--bogus", "1"]).is_err());
    }

    #[test]
    fn lists() {
        let m = parse(&["--name", "x", "--list", "0,0.5,1"]).unwrap();
        assert_eq!(m.f64_list("list").unwrap(), vec![0.0, 0.5, 1.0]);
    }

    #[test]
    fn missing_value_errors() {
        assert!(parse(&["--name"]).is_err());
    }

    #[test]
    fn help_is_error_with_usage() {
        let err = parse(&["--help"]).unwrap_err().to_string();
        assert!(err.contains("--alpha"));
    }
}
