//! Deterministic, seedable PRNG (xoshiro256**).
//!
//! The offline build has no `rand` crate; the simulator needs a fast,
//! high-quality, *reproducible* generator (the saboteur's loss pattern must
//! be identical across runs for a given seed — paper experiments are
//! averaged over seeds). xoshiro256** is the standard small-state generator
//! with excellent statistical properties.

/// SplitMix64 (the reference seed-expansion generator): one add and two
/// multiply-xorshift rounds per output, with the property that *any* seed
/// — including 0 and consecutive integers — yields a decorrelated stream.
///
/// It seeds [`Rng`]'s xoshiro state, and it is the batched per-stream
/// derivation pass for fleet-scale populations (the 10^6-stream builder
/// in `benches/streaming_saturation`): deriving `n` per-entity values
/// costs one `SplitMix64` walked `n` times ([`SplitMix64::fill`])
/// instead of constructing `n` full generators.
#[derive(Clone, Debug)]
pub struct SplitMix64(pub u64);

impl SplitMix64 {
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.0 = self.0.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.0;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Fill `out` with one derived value per slot — the one-pass batched
    /// seeding used for 10^6-stream populations.
    pub fn fill(&mut self, out: &mut [u64]) {
        for slot in out.iter_mut() {
            *slot = self.next_u64();
        }
    }
}

/// xoshiro256** by Blackman & Vigna (public domain reference algorithm).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via [`SplitMix64`] so that similar seeds give unrelated
    /// streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64(seed);
        let s =
            [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()];
        Rng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n). Unbiased via rejection.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Uniform integer in [lo, hi].
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Uniform float in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Bernoulli trial.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Exponentially distributed with the given mean (packet inter-arrival).
    pub fn exp(&mut self, mean: f64) -> f64 {
        let u = loop {
            let u = self.f64();
            if u > 0.0 {
                break u;
            }
        };
        -mean * u.ln()
    }

    /// Derive an independent stream (e.g. per-link saboteur).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_matches_reference_vectors() {
        // Published SplitMix64 test vector (seed 0) — pins the extracted
        // generator to the exact sequence the inline seeding always
        // produced, so every seeded artifact stays byte-identical.
        let mut sm = SplitMix64(0);
        assert_eq!(sm.next_u64(), 0xE220_A839_7B1D_CDAF);
        assert_eq!(sm.next_u64(), 0x6E78_9E6A_A1B9_65F4);
        assert_eq!(sm.next_u64(), 0x06C4_5D18_8009_454F);
    }

    #[test]
    fn splitmix_fill_matches_sequential_draws() {
        let mut a = SplitMix64(1234567);
        let mut batch = [0u64; 8];
        a.fill(&mut batch);
        let mut b = SplitMix64(1234567);
        for (i, &v) in batch.iter().enumerate() {
            assert_eq!(v, b.next_u64(), "slot {i}");
        }
        assert_eq!(batch[0], 0x599E_D017_FB08_FC85);
    }

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_decorrelated() {
        let (mut a, mut b) = (Rng::new(1), Rng::new(2));
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn f64_mean_near_half() {
        let mut r = Rng::new(9);
        let m: f64 = (0..100_000).map(|_| r.f64()).sum::<f64>() / 100_000.0;
        assert!((m - 0.5).abs() < 0.01, "{m}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10) as usize;
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn chance_extremes() {
        let mut r = Rng::new(4);
        for _ in 0..100 {
            assert!(!r.chance(0.0));
            assert!(r.chance(1.0));
        }
    }

    #[test]
    fn chance_rate_matches_p() {
        let mut r = Rng::new(5);
        let hits = (0..100_000).filter(|_| r.chance(0.03)).count();
        let rate = hits as f64 / 100_000.0;
        assert!((rate - 0.03).abs() < 0.005, "{rate}");
    }

    #[test]
    fn exp_mean() {
        let mut r = Rng::new(6);
        let m: f64 = (0..100_000).map(|_| r.exp(2.0)).sum::<f64>() / 100_000.0;
        assert!((m - 2.0).abs() < 0.05, "{m}");
    }

    #[test]
    fn fork_streams_independent() {
        let mut a = Rng::new(10);
        let mut f1 = a.fork();
        let mut f2 = a.fork();
        assert_ne!(f1.next_u64(), f2.next_u64());
    }
}
