//! Deterministic, seedable PRNG (xoshiro256**).
//!
//! The offline build has no `rand` crate; the simulator needs a fast,
//! high-quality, *reproducible* generator (the saboteur's loss pattern must
//! be identical across runs for a given seed — paper experiments are
//! averaged over seeds). xoshiro256** is the standard small-state generator
//! with excellent statistical properties.

/// xoshiro256** by Blackman & Vigna (public domain reference algorithm).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 so that similar seeds give unrelated streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        let s = [next(), next(), next(), next()];
        Rng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n). Unbiased via rejection.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Uniform integer in [lo, hi].
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Uniform float in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Bernoulli trial.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Exponentially distributed with the given mean (packet inter-arrival).
    pub fn exp(&mut self, mean: f64) -> f64 {
        let u = loop {
            let u = self.f64();
            if u > 0.0 {
                break u;
            }
        };
        -mean * u.ln()
    }

    /// Derive an independent stream (e.g. per-link saboteur).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_decorrelated() {
        let (mut a, mut b) = (Rng::new(1), Rng::new(2));
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn f64_mean_near_half() {
        let mut r = Rng::new(9);
        let m: f64 = (0..100_000).map(|_| r.f64()).sum::<f64>() / 100_000.0;
        assert!((m - 0.5).abs() < 0.01, "{m}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10) as usize;
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn chance_extremes() {
        let mut r = Rng::new(4);
        for _ in 0..100 {
            assert!(!r.chance(0.0));
            assert!(r.chance(1.0));
        }
    }

    #[test]
    fn chance_rate_matches_p() {
        let mut r = Rng::new(5);
        let hits = (0..100_000).filter(|_| r.chance(0.03)).count();
        let rate = hits as f64 / 100_000.0;
        assert!((rate - 0.03).abs() < 0.005, "{rate}");
    }

    #[test]
    fn exp_mean() {
        let mut r = Rng::new(6);
        let m: f64 = (0..100_000).map(|_| r.exp(2.0)).sum::<f64>() / 100_000.0;
        assert!((m - 2.0).abs() < 0.05, "{m}");
    }

    #[test]
    fn fork_streams_independent() {
        let mut a = Rng::new(10);
        let mut f1 = a.fork();
        let mut f2 = a.fork();
        assert_ne!(f1.next_u64(), f2.next_u64());
    }
}
