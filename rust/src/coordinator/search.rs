//! Successive-halving arch × split co-design search (`sei search`) —
//! the payoff of the bound-guided evaluation core: instead of simulating
//! every grid point at full fidelity, the search spends a declared
//! budget over *rungs* of increasing simulated-frame counts, halving the
//! candidate set between rungs by the same (satisfied, latency,
//! accuracy) order the placement search optimizes.
//!
//! A [`SearchSpec`] is a [`SweepSpec`] (all its axes: scenarios,
//! architectures, protocols, channels, tier chains, client mixes, …)
//! plus three search keys:
//!
//! ```json
//! { "...all SweepSpec keys...",
//!   "budget": 4096, "eta": 2, "rung_frames": [8, 24, 96] }
//! ```
//!
//! - `rung_frames`: simulated frames per client at each rung, strictly
//!   increasing; the last entry is the search's full fidelity.
//! - `eta`: halving factor — `ceil(n / eta)` candidates survive a rung.
//! - `budget`: total simulation allowance in frame-units (`frames ×
//!   seeds_per_point × clients` per candidate per rung), consumed
//!   greedily rung-by-rung in priority order with deterministic
//!   truncation. `0` means unlimited — *every* candidate runs *every*
//!   rung and no halving is applied, which makes the unlimited search an
//!   exhaustive-sweep oracle: its winner equals the best point of a
//!   plain [`run_sweep`](super::sweep::run_sweep) at final-rung
//!   fidelity (a property the integration tests pin).
//!
//! Determinism: rung 0 is seeded by the ascending analytic bound
//! ([`job_bound_ns`], unbounded points last, ties by grid index); later
//! rungs inherit the previous rung's ranking; every evaluation runs on
//! the deterministic work-stealing pool. The whole [`SearchReport`] —
//! winner, rungs, costs — is byte-identical at any `--threads` value.

use std::cmp::Ordering as CmpOrdering;

use anyhow::{bail, Context, Result};

use super::bound::job_bound_ns;
use super::sweep::{
    job_archs, point_json, run_jobs, BackendFactory, EngineCache, SweepJob,
    SweepPoint, SweepScheduler, SweepSpec,
};
use crate::netsim::event::SimTime;
use crate::util::json::{self, Json};

/// The declarative input of `sei search`: a full sweep grid plus the
/// successive-halving schedule (see the module docs for the JSON form).
#[derive(Clone, Debug)]
pub struct SearchSpec {
    /// The design-space grid — every [`SweepSpec`] axis and QoS key.
    pub sweep: SweepSpec,
    /// Total frame-unit allowance; `0` = unlimited (exhaustive oracle).
    pub budget: usize,
    /// Halving factor (>= 2): `ceil(n / eta)` survive each rung.
    pub eta: usize,
    /// Frames per client at each rung, strictly increasing.
    pub rung_frames: Vec<usize>,
}

impl SearchSpec {
    /// A search over `sweep` with the default schedule: one rung at the
    /// sweep's own frame count, `eta = 2`, unlimited budget.
    pub fn new(sweep: SweepSpec) -> SearchSpec {
        let rung_frames = vec![sweep.frames];
        SearchSpec { sweep, budget: 0, eta: 2, rung_frames }
    }

    /// Parse the JSON form: the three search keys are split off and the
    /// remainder must be a valid [`SweepSpec`] (unknown keys rejected
    /// there, so typos still fail loudly).
    pub fn from_json(text: &str) -> Result<SearchSpec> {
        let j = Json::parse(text).context("search spec")?;
        let Json::Obj(map) = &j else {
            bail!("search spec must be a JSON object");
        };
        let mut grid = map.clone();
        let budget = grid.remove("budget");
        let eta = grid.remove("eta");
        let rung_frames = grid.remove("rung_frames");
        let sweep = SweepSpec::from_json(&Json::Obj(grid).to_string())?;
        let mut spec = SearchSpec::new(sweep);
        if let Some(v) = budget {
            spec.budget = v.usize()?;
        }
        if let Some(v) = eta {
            spec.eta = v.usize()?;
        }
        if let Some(v) = rung_frames {
            spec.rung_frames = v
                .arr()?
                .iter()
                .map(|f| f.usize())
                .collect::<Result<_>>()?;
        }
        spec.validate()?;
        Ok(spec)
    }

    pub fn validate(&self) -> Result<()> {
        if self.eta < 2 {
            bail!(
                "search spec '{}': eta must be >= 2, got {}",
                self.sweep.name,
                self.eta
            );
        }
        if self.rung_frames.is_empty() {
            bail!(
                "search spec '{}': rung_frames must name at least one rung",
                self.sweep.name
            );
        }
        if self.rung_frames[0] == 0 {
            bail!(
                "search spec '{}': rung_frames must be >= 1",
                self.sweep.name
            );
        }
        if self.rung_frames.windows(2).any(|w| w[1] <= w[0]) {
            bail!(
                "search spec '{}': rung_frames must be strictly \
                 increasing, got {:?}",
                self.sweep.name,
                self.rung_frames
            );
        }
        Ok(())
    }

    /// The spec back as JSON (the sweep keys plus the three search keys;
    /// key order is the object's sorted order, so this is deterministic).
    pub fn to_json(&self) -> Json {
        let Json::Obj(mut map) = self.sweep.to_json() else {
            unreachable!("SweepSpec::to_json returns an object");
        };
        map.insert("budget".into(), json::num(self.budget as f64));
        map.insert("eta".into(), json::num(self.eta as f64));
        map.insert(
            "rung_frames".into(),
            json::arr(
                self.rung_frames
                    .iter()
                    .map(|&f| json::num(f as f64))
                    .collect(),
            ),
        );
        Json::Obj(map)
    }
}

/// What one rung of the search did.
#[derive(Clone, Debug)]
pub struct RungOutcome {
    /// Frames per client simulated at this rung.
    pub frames: usize,
    /// Candidates that entered (fit the budget) at this rung.
    pub entrants: usize,
    /// Entrants the bound-guided prefilter skipped (no simulation).
    pub skipped: usize,
    /// Frame-units this rung consumed.
    pub cost: usize,
    /// Grid indices surviving into the next rung, best first.
    pub survivors: Vec<usize>,
}

/// The result of [`run_search`]: the per-rung trace and the winning
/// grid point at the highest fidelity it reached.
#[derive(Clone, Debug)]
pub struct SearchReport {
    pub spec: SearchSpec,
    pub rungs: Vec<RungOutcome>,
    /// The winner's evaluation at the last rung it ran.
    pub winner: SweepPoint,
    /// Total frame-units consumed across all rungs.
    pub total_cost: usize,
    /// Candidates the budget never admitted to rung 0.
    pub never_evaluated: usize,
}

impl SearchReport {
    /// Machine-readable report (deterministic key order and formatting;
    /// byte-identical at any thread count).
    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("spec", self.spec.to_json()),
            (
                "rungs",
                json::arr(
                    self.rungs
                        .iter()
                        .map(|r| {
                            json::obj(vec![
                                ("frames", json::num(r.frames as f64)),
                                ("entrants", json::num(r.entrants as f64)),
                                ("skipped", json::num(r.skipped as f64)),
                                ("cost", json::num(r.cost as f64)),
                                (
                                    "survivors",
                                    json::arr(
                                        r.survivors
                                            .iter()
                                            .map(|&i| json::num(i as f64))
                                            .collect(),
                                    ),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("winner", point_json(&self.winner)),
            ("total_cost", json::num(self.total_cost as f64)),
            (
                "never_evaluated",
                json::num(self.never_evaluated as f64),
            ),
        ])
    }

    /// Human-readable rung trace and winner line.
    pub fn render(&self) -> String {
        let mut out = format!(
            "Search '{}' — {} rung(s), eta {}, budget {}\n\n",
            self.spec.sweep.name,
            self.spec.rung_frames.len(),
            self.spec.eta,
            if self.spec.budget == 0 {
                "unlimited".to_string()
            } else {
                format!("{} frame-units", self.spec.budget)
            },
        );
        for (r, rung) in self.rungs.iter().enumerate() {
            out.push_str(&format!(
                "rung {r}: {} frames x {} entrant(s) ({} prefilter-skipped) \
                 -> {} survivor(s), cost {}\n",
                rung.frames,
                rung.entrants,
                rung.skipped,
                rung.survivors.len(),
                rung.cost,
            ));
        }
        if self.never_evaluated > 0 {
            out.push_str(&format!(
                "budget truncation: {} candidate(s) never admitted\n",
                self.never_evaluated,
            ));
        }
        let w = &self.winner;
        out.push_str(&format!(
            "\nwinner: #{} {} {} {} loss {:.1}% {} {}t — mean {:.2} ms, \
             accuracy {}, QoS {} (total cost {})\n",
            w.index,
            w.kind,
            w.arch.as_str(),
            w.protocol,
            w.loss * 100.0,
            w.channel,
            w.tiers.len(),
            w.mean_latency_ns / 1e6,
            w.accuracy
                .map(|a| format!("{:.1}%", a * 100.0))
                .unwrap_or_else(|| "—".to_string()),
            match w.satisfies {
                Some(true) => "ok",
                Some(false) => "violated",
                None => "—",
            },
            self.total_cost,
        ));
        out
    }
}

/// QoS-first rank of an evaluated point: satisfied beats unknown beats
/// violated — the same order the placement search optimizes.
fn sat_rank(p: &SweepPoint) -> u8 {
    match p.satisfies {
        Some(true) => 2,
        None => 1,
        Some(false) => 0,
    }
}

/// The search's strict total order over evaluated points: satisfaction
/// rank, then lower mean latency, then higher accuracy (unmeasured
/// worst), then lower grid index — the deterministic tie-break that
/// makes every rung's ranking (hence the winner) independent of
/// evaluation order and thread count.
fn rank(a: &SweepPoint, b: &SweepPoint) -> CmpOrdering {
    sat_rank(b)
        .cmp(&sat_rank(a))
        .then(
            a.mean_latency_ns
                .partial_cmp(&b.mean_latency_ns)
                .unwrap_or(CmpOrdering::Equal),
        )
        .then(
            b.accuracy
                .unwrap_or(f64::NEG_INFINITY)
                .partial_cmp(&a.accuracy.unwrap_or(f64::NEG_INFINITY))
                .unwrap_or(CmpOrdering::Equal),
        )
        .then(a.index.cmp(&b.index))
}

/// Frame-units one candidate costs at a `frames`-fidelity rung.
fn rung_cost(spec: &SweepSpec, job: &SweepJob, frames: usize) -> usize {
    frames * spec.seeds_per_point * job.clients.max(1)
}

/// Run the successive-halving co-design search (see the module docs).
/// Deterministic in `(spec, backend artifacts)` alone — `threads` only
/// changes wall-clock time.
pub fn run_search(
    spec: &SearchSpec,
    threads: usize,
    factory: &BackendFactory<'_>,
) -> Result<SearchReport> {
    spec.validate()?;
    let jobs = spec.sweep.expand()?;

    // Rung-0 priority: ascending admissible bound — the candidates that
    // could be fastest get first claim on the budget. Unbounded points
    // (mixes, traces) sort last; ties and unbounded points order by grid
    // index. The bound is analytic, so this costs no simulation budget.
    let mut engines = EngineCache::new();
    let mut bounds: Vec<SimTime> = Vec::with_capacity(jobs.len());
    for job in &jobs {
        engines.ensure(&job_archs(&spec.sweep, job), factory)?;
        let b = job_bound_ns(engines.get(job.arch)?, &spec.sweep, job)?;
        bounds.push(b.unwrap_or(SimTime::MAX));
    }
    let mut alive: Vec<usize> = (0..jobs.len()).collect();
    alive.sort_by_key(|&i| (bounds[i], i));

    let mut rungs: Vec<RungOutcome> = Vec::new();
    let mut total_cost = 0usize;
    let mut best: Option<SweepPoint> = None;
    for (r, &rf) in spec.rung_frames.iter().enumerate() {
        // Greedy budget admission in priority order: a candidate that
        // does not fit stops the rung (deterministic truncation — no
        // peeking past it, or the entrant set would depend on job sizes
        // in fragile ways).
        let mut entrants: Vec<usize> = Vec::new();
        let mut cost = 0usize;
        for &ci in &alive {
            let c = rung_cost(&spec.sweep, &jobs[ci], rf);
            if spec.budget > 0 && total_cost + cost + c > spec.budget {
                break;
            }
            entrants.push(ci);
            cost += c;
        }
        if entrants.is_empty() {
            if r == 0 {
                bail!(
                    "search spec '{}': budget {} cannot afford a single \
                     rung-0 evaluation (cheapest candidate costs {})",
                    spec.sweep.name,
                    spec.budget,
                    alive
                        .iter()
                        .map(|&i| rung_cost(&spec.sweep, &jobs[i], rf))
                        .min()
                        .unwrap_or(0),
                );
            }
            break;
        }
        let mut rspec = spec.sweep.clone();
        rspec.frames = rf;
        let entrant_jobs: Vec<SweepJob> =
            entrants.iter().map(|&ci| jobs[ci].clone()).collect();
        let mut points = run_jobs(
            &rspec,
            &entrant_jobs,
            threads,
            SweepScheduler::Stealing,
            factory,
        )?;
        total_cost += cost;
        points.sort_by(rank);
        let skipped = points.iter().filter(|p| p.skipped).count();
        // Unlimited budget disables halving: every rung re-measures the
        // full candidate set at higher fidelity, so the final rung *is*
        // an exhaustive sweep (the oracle property).
        let keep = if spec.budget == 0 {
            points.len()
        } else {
            points.len().div_ceil(spec.eta).max(1)
        };
        let survivors: Vec<usize> =
            points.iter().take(keep).map(|p| p.index).collect();
        best = Some(points[0].clone());
        rungs.push(RungOutcome {
            frames: rf,
            entrants: entrants.len(),
            skipped,
            cost,
            survivors: survivors.clone(),
        });
        alive = survivors;
    }
    let winner = best.expect("rung 0 evaluated at least one candidate");
    // Rung 0 is the only admission gate (later rungs only shrink the
    // set), so whatever its budget truncation left out was never seen.
    let never_evaluated = jobs.len() - rungs[0].entrants;
    Ok(SearchReport {
        spec: spec.clone(),
        rungs,
        winner,
        total_cost,
        never_evaluated,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::load_backend_for;
    use std::path::Path;

    fn factory(
        arch: crate::model::Arch,
    ) -> Result<Box<dyn crate::runtime::InferenceBackend>> {
        load_backend_for(Path::new("artifacts"), arch)
    }

    #[test]
    fn search_spec_json_round_trip_and_validation() {
        let text = r#"{"name": "s", "frames": 32,
            "loss_rates": [0.0, 0.05],
            "budget": 512, "eta": 3, "rung_frames": [4, 32]}"#;
        let spec = SearchSpec::from_json(text).unwrap();
        assert_eq!(spec.budget, 512);
        assert_eq!(spec.eta, 3);
        assert_eq!(spec.rung_frames, vec![4, 32]);
        assert_eq!(spec.sweep.frames, 32);
        // The search keys must not leak into the sweep grid...
        let back = spec.to_json().to_string();
        assert!(back.contains("\"rung_frames\""));
        // ...and schedule mistakes fail loudly.
        for bad in [
            r#"{"name": "s", "rung_frames": [8, 8]}"#,
            r#"{"name": "s", "eta": 1}"#,
            r#"{"name": "s", "rung_frames": []}"#,
            r#"{"name": "s", "not_a_key": 1}"#,
        ] {
            assert!(SearchSpec::from_json(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn unlimited_budget_keeps_every_candidate_every_rung() {
        let mut sweep = SweepSpec::new("oracle");
        sweep.loss_rates = vec![0.0, 0.04];
        sweep.frames = 8;
        let mut spec = SearchSpec::new(sweep);
        spec.rung_frames = vec![2, 8];
        let report = run_search(&spec, 1, &factory).unwrap();
        let n = spec.sweep.expand().unwrap().len();
        assert_eq!(report.rungs.len(), 2);
        for rung in &report.rungs {
            assert_eq!(rung.entrants, n);
            assert_eq!(rung.survivors.len(), n);
        }
        assert_eq!(report.never_evaluated, 0);
    }

    #[test]
    fn budget_truncation_is_deterministic_and_reported() {
        let mut sweep = SweepSpec::new("tight");
        sweep.loss_rates = vec![0.0, 0.02, 0.04, 0.08];
        sweep.frames = 8;
        let mut spec = SearchSpec::new(sweep);
        spec.rung_frames = vec![2, 8];
        // Room for exactly three rung-0 entrants (2 frames x 1 seed x
        // 1 client each) and nothing more.
        spec.budget = 6;
        let a = run_search(&spec, 1, &factory).unwrap();
        let b = run_search(&spec, 4, &factory).unwrap();
        assert_eq!(a.to_json().to_string(), b.to_json().to_string());
        assert_eq!(a.rungs[0].entrants, 3);
        assert_eq!(a.never_evaluated, 1);
        assert_eq!(a.total_cost, 6);
        // The second rung could not afford anyone: winner comes from
        // rung 0.
        assert_eq!(a.rungs.len(), 1);
    }
}
