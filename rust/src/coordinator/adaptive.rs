//! Adaptive mid-stream re-splitting over time-varying channels (the
//! payoff scenario on top of the [`crate::netsim::trace`] layer).
//!
//! A static cut chain is chosen once and survives whatever the link does;
//! this module closes the loop: a controller monitors the *observed*
//! per-hop goodput over a sliding window of completed uplink transfers
//! and re-selects the cut chain mid-stream when the link degrades,
//! paying an explicit switchover cost. The engine is a self-contained
//! single-client discrete-event pipeline simulator — its own event
//! calendar (same [`EventQueue`] backends as the streaming engine, so
//! backend determinism is pinned the same way), real [`Channel`]s per hop
//! (with [`LinkTrace`]s attached via the hop's `NetworkConfig`), per-tier
//! busy clocks, and analytic per-candidate costs from [`chain_costs`].
//!
//! Controller state machine:
//!
//!   Stable --(Check: best < cur·(1-margin), dwell elapsed)--> Switching
//!   Switching --(resync transfer delivered: ResyncDone)-----> Stable
//!
//! In `Switching` further switch decisions are suppressed and the two
//! policies part ways. `Drain` is make-before-break: the old chain keeps
//! serving (in-flight *and* queued frames drain through it) while the
//! resync transfer rides the downlink, and the cutover happens the
//! instant the resync lands. `Drop` is break-before-make: tier 0 stops
//! after its current frame, frames queued at tier 0 are discarded
//! (counted as deadline misses), and the pipeline restarts fresh on the
//! new chain when the resync lands. Frames already past tier 0 always
//! finish under the chain they were stamped with, in both policies.
//!
//! Switchover cost model: candidate heads/tails are assumed pre-staged
//! on every tier at session setup (the candidate set is enumerable and
//! known), so what must cross the wire at switch time is the *boundary
//! state* that cannot be pre-staged — each changed hop drains the old
//! cut's latent and primes the new cut's decoder (one old-latent plus
//! one new-latent transfer worth of bytes) on top of a fixed control
//! handshake. The resync rides the real (possibly degraded) channel as
//! an ordinary transfer, which is exactly why the adaptive run is
//! strictly worse than the zero-cost oracle.
//!
//! [`run_adaptive_comparison`] runs every static candidate, the adaptive
//! controller under both switch policies, and the zero-switchover-cost
//! oracle over the *same* traced channels, and reports them side by
//! side. Everything is deterministic in the config alone: no wall clock,
//! no threads, event ties broken by sequence number identically across
//! queue backends.

use std::collections::VecDeque;

use anyhow::{bail, Result};

use crate::model::{
    chain_costs, split_points, Arch, Cut, DeviceProfile,
    Network,
};
use crate::netsim::event::{EventQueue, QueueKind, SimTime};
use crate::netsim::transfer::{Channel, NetworkConfig};
use crate::netsim::Dir;
use crate::util::json::Json;

use super::scenario::{derive_hop_net, ModelScale};

/// Fixed control-plane handshake bytes of any re-split, under the
/// boundary-state resync model (one MTU-ish message each way).
pub const RESYNC_CONTROL_BYTES: u64 = 1500;

/// What happens to frames queued at tier 0 when a switch begins.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SwitchPolicy {
    /// Make-before-break: the old chain keeps serving every frame while
    /// the resync is in flight; the cutover is instant when it lands.
    Drain,
    /// Break-before-make: tier 0 blocks for the resync and frames queued
    /// there are discarded (counted as deadline misses), so the new
    /// chain starts from an empty pipeline.
    Drop,
}

impl SwitchPolicy {
    pub fn as_str(&self) -> &'static str {
        match self {
            SwitchPolicy::Drain => "drain",
            SwitchPolicy::Drop => "drop",
        }
    }
}

/// Hysteresis + observation parameters of the re-split controller.
#[derive(Clone, Debug)]
pub struct ControllerConfig {
    /// Sliding window (completed uplink transfers per hop) the observed
    /// goodput is estimated over.
    pub window: usize,
    /// Period of the controller's Check events.
    pub check_period_ns: SimTime,
    /// Minimum simulated time between switches (dwell-time hysteresis).
    pub min_dwell_ns: SimTime,
    /// Relative-improvement hysteresis: switch only when the best
    /// candidate's predicted cost is below `current * (1 - margin)`.
    pub switch_margin: f64,
}

impl Default for ControllerConfig {
    fn default() -> Self {
        ControllerConfig {
            window: 4,
            check_period_ns: 5_000_000,
            min_dwell_ns: 50_000_000,
            switch_margin: 0.1,
        }
    }
}

/// Full configuration of one adaptive-vs-static comparison.
#[derive(Clone, Debug)]
pub struct AdaptiveConfig {
    pub arch: Arch,
    pub scale: ModelScale,
    /// Device tier chain, sensor side first (k = tiers - 1 cuts).
    pub tiers: Vec<DeviceProfile>,
    /// Per-hop channels (traces attached); a single entry is a template
    /// replicated with derived seeds, like [`super::scenario`].
    pub hop_nets: Vec<NetworkConfig>,
    pub frames: usize,
    pub frame_period_ns: SimTime,
    /// Per-frame latency deadline the hit-rate is measured against.
    pub deadline_ns: SimTime,
    pub controller: ControllerConfig,
    pub queue: QueueKind,
}

// ---------------------------------------------------------------------------
// Candidate enumeration cache.
// ---------------------------------------------------------------------------

/// The memoized [`crate::model::valid_cut_chains`] cache was generalized
/// out of this module into the model layer ([`crate::model::ChainCache`]) so the
/// placement and co-design searches share it; the historical
/// `coordinator::adaptive::ChainCache` path keeps working.
pub use crate::model::ChainCache;

/// The geometry/scale pair resolved to a concrete network, mirroring the
/// scenario engine's resolution but without an [`InferenceBackend`]
/// (adaptive comparisons are pure timing studies): `Full` is the
/// paper-scale network, `Slim` the standard trained-artifact geometry
/// (32x32, width 0.5, hidden 64, 10 classes).
///
/// [`InferenceBackend`]: crate::runtime::InferenceBackend
fn network_for(arch: Arch, scale: ModelScale) -> Network {
    match scale {
        ModelScale::Full => arch.full_network(),
        ModelScale::Slim => arch.slim_network(32, 0.5, 64, 10),
    }
}

// ---------------------------------------------------------------------------
// Per-candidate cost tables.
// ---------------------------------------------------------------------------

/// One candidate chain with everything the engine and the controller
/// need precomputed: per-tier compute times and per-hop latent bytes.
#[derive(Clone, Debug)]
struct Cand {
    chain: Vec<usize>,
    /// Compute time of segment `t` on tier `t` (overhead included).
    seg_ns: Vec<SimTime>,
    /// Latent bytes crossing hop `h`.
    hop_bytes: Vec<u64>,
}

fn build_cands(
    points: &[Cut],
    chains: &[Vec<usize>],
    tiers: &[DeviceProfile],
) -> Result<Vec<Cand>> {
    chains
        .iter()
        .map(|chain| {
            let costs = chain_costs(points, chain)?;
            let seg_ns = costs
                .seg_mult_adds
                .iter()
                .zip(tiers)
                .map(|(&ma, d)| d.compute_ns(ma))
                .collect();
            Ok(Cand {
                chain: chain.clone(),
                seg_ns,
                hop_bytes: costs.hop_bytes,
            })
        })
        .collect()
}

/// Boundary-state bytes a switch from `old` to `new` must move: per
/// changed hop, one old-latent drain plus one new-latent prime, plus the
/// fixed control handshake. Identical chains cost nothing (no switch).
fn resync_bytes(old: &Cand, new: &Cand) -> u64 {
    let mut bytes = 0u64;
    for h in 0..old.hop_bytes.len().max(new.hop_bytes.len()) {
        let ob = old.hop_bytes.get(h).copied().unwrap_or(0);
        let nb = new.hop_bytes.get(h).copied().unwrap_or(0);
        let changed = old.chain.get(h) != new.chain.get(h);
        if changed {
            bytes += ob + nb;
        }
    }
    if bytes == 0 {
        0
    } else {
        bytes + RESYNC_CONTROL_BYTES
    }
}

// ---------------------------------------------------------------------------
// The single-client event engine.
// ---------------------------------------------------------------------------

enum AdEv {
    /// Frame `f` is emitted by the source.
    Emit { f: usize },
    /// Tier `tier` finished computing frame `f`'s segment.
    TierDone { f: usize, tier: usize },
    /// Frame `f`'s uplink latent fully arrived at tier `hop + 1`.
    UpDelivered { f: usize, hop: usize },
    /// Frame `f`'s result arrived back at tier `hop` (0 = done).
    DownDelivered { f: usize, hop: usize },
    /// Controller observation/decision point.
    Check,
    /// The switchover resync transfer landed; the new chain is live.
    ResyncDone,
}

/// One per-hop goodput observation: committed (visible to the
/// controller) from `at_ns` on — the transfer's arrival time, so the
/// controller never sees into the future of the calendar.
#[derive(Clone, Copy)]
struct Obs {
    at_ns: SimTime,
    bytes: u64,
    dur_ns: SimTime,
}

struct Engine<'a> {
    cands: &'a [Cand],
    ctl: Option<&'a ControllerConfig>,
    policy: SwitchPolicy,
    /// Oracle mode: switches are free and instantaneous.
    zero_cost: bool,
    period: SimTime,
    frames: usize,
    result_bytes: u64,

    q: EventQueue<AdEv>,
    channels: Vec<Channel>,

    emitted: Vec<SimTime>,
    completed: Vec<Option<SimTime>>,
    dropped: Vec<bool>,
    cand_of: Vec<usize>,

    edge_q: VecDeque<usize>,
    edge_busy: bool,
    /// Busy-until clock of each non-edge tier (index 0 unused).
    tier_free: Vec<SimTime>,

    window: Vec<VecDeque<Obs>>,
    active: usize,
    pending: Option<usize>,
    last_switch: SimTime,
    switches: usize,
    settled: usize,

    // Cache instrumentation: the controller consults the memoized
    // candidate enumeration on every decision.
    cache: &'a mut ChainCache,
    arch: Arch,
    scale: ModelScale,
    net: &'a Network,
}

/// Aggregate outcome of one run (one static candidate or one policy).
#[derive(Clone, Debug)]
pub struct PolicyOutcome {
    pub label: String,
    pub frames: usize,
    pub completed: usize,
    pub dropped: usize,
    pub switches: usize,
    /// Frames meeting the deadline over *all* frames (drops are misses).
    pub deadline_hit_rate: f64,
    /// Mean latency over completed frames.
    pub mean_latency_ns: f64,
    pub p95_latency_ns: SimTime,
}

impl<'a> Engine<'a> {
    fn start_edge(&mut self, f: usize, t: SimTime) {
        self.cand_of[f] = self.active;
        self.edge_busy = true;
        let dt = self.cands[self.active].seg_ns[0];
        self.q.schedule(t + dt, AdEv::TierDone { f, tier: 0 });
    }

    fn start_resync(&mut self, t: SimTime) -> Result<()> {
        let to = self.pending.expect("resync without a pending chain");
        let bytes = resync_bytes(&self.cands[self.active], &self.cands[to])
            .max(RESYNC_CONTROL_BYTES);
        if self.policy == SwitchPolicy::Drop {
            // Break-before-make: tier 0 is held until the resync lands.
            self.edge_busy = true;
        }
        let (start, r) =
            self.channels[0].send_no_earlier(Dir::Down, bytes, t)?;
        self.q.schedule(start + r.latency_ns(), AdEv::ResyncDone);
        Ok(())
    }

    fn emit(&mut self, f: usize, t: SimTime) {
        self.emitted[f] = t;
        if f + 1 < self.frames {
            self.q.schedule(t + self.period, AdEv::Emit { f: f + 1 });
        }
        if self.edge_busy {
            self.edge_q.push_back(f);
        } else {
            self.start_edge(f, t);
        }
    }

    fn send_up(&mut self, f: usize, hop: usize, t: SimTime) -> Result<()> {
        let bytes = self.cands[self.cand_of[f]].hop_bytes[hop];
        let (start, r) =
            self.channels[hop].send_no_earlier(Dir::Up, bytes, t)?;
        let arrival = start + r.latency_ns();
        // Commit the goodput observation at arrival time; the window is
        // filled in channel-FIFO order, so arrival stamps are monotone
        // per hop and the controller filter below stays a prefix.
        self.window[hop].push_back(Obs {
            at_ns: arrival,
            bytes,
            dur_ns: (arrival - start).max(1),
        });
        let cap = self.ctl.map(|c| c.window.max(1)).unwrap_or(1);
        while self.window[hop].len() > cap {
            self.window[hop].pop_front();
        }
        self.q.schedule(arrival, AdEv::UpDelivered { f, hop });
        Ok(())
    }

    fn send_down(&mut self, f: usize, hop: usize, t: SimTime) -> Result<()> {
        let (start, r) = self.channels[hop].send_no_earlier(
            Dir::Down,
            self.result_bytes,
            t,
        )?;
        self.q
            .schedule(start + r.latency_ns(), AdEv::DownDelivered { f, hop });
        Ok(())
    }

    fn tier_done(&mut self, f: usize, tier: usize, t: SimTime) -> Result<()> {
        if tier == 0 {
            self.edge_busy = false;
            if self.pending.is_some() && self.policy == SwitchPolicy::Drop {
                // Deferred break-before-make: the in-flight head frame
                // finished, now hold tier 0 for the resync.
                self.start_resync(t)?;
            } else if let Some(g) = self.edge_q.pop_front() {
                self.start_edge(g, t);
            }
        }
        let k = self.cands[self.cand_of[f]].hop_bytes.len();
        if tier < k {
            self.send_up(f, tier, t)?;
        } else {
            // Last tier: the result returns hop by hop.
            self.send_down(f, k - 1, t)?;
        }
        Ok(())
    }

    fn up_delivered(&mut self, f: usize, hop: usize, t: SimTime) {
        let tier = hop + 1;
        let start = t.max(self.tier_free[tier]);
        let dt = self.cands[self.cand_of[f]].seg_ns[tier];
        self.tier_free[tier] = start + dt;
        self.q.schedule(start + dt, AdEv::TierDone { f, tier });
    }

    fn down_delivered(&mut self, f: usize, hop: usize, t: SimTime)
        -> Result<()>
    {
        if hop == 0 {
            self.completed[f] = Some(t);
            self.settled += 1;
            Ok(())
        } else {
            self.send_down(f, hop - 1, t)
        }
    }

    /// Observed goodput of hop `h` at time `t` (bps), from window
    /// entries already delivered; before any observation, the channel's
    /// best-case rate (the same optimistic prior admission uses).
    fn observed_rate(&self, h: usize, t: SimTime) -> f64 {
        let mut bytes = 0u64;
        let mut dur = 0u64;
        for o in &self.window[h] {
            if o.at_ns <= t {
                bytes += o.bytes;
                dur += o.dur_ns;
            }
        }
        if dur == 0 {
            self.channels[h].cfg.best_rate_bps()
        } else {
            bytes as f64 * 8.0 / dur as f64 * 1e9
        }
    }

    /// Predicted per-frame cost of candidate `ci` under the currently
    /// observed rates: pipelined end-to-end latency plus a queue-growth
    /// penalty when any stage's service time exceeds the frame period
    /// (a sustained-overload chain is bad even if one frame would fit).
    fn predict(&self, ci: usize, t: SimTime) -> f64 {
        let c = &self.cands[ci];
        let mut lat = 0.0f64;
        let mut stage_max = 0.0f64;
        for &ns in &c.seg_ns {
            lat += ns as f64;
            stage_max = stage_max.max(ns as f64);
        }
        for (h, &bytes) in c.hop_bytes.iter().enumerate() {
            let rate = self.observed_rate(h, t);
            if rate <= 0.0 {
                return f64::INFINITY;
            }
            let up = bytes as f64 * 8.0 / rate * 1e9;
            let down = self.result_bytes as f64 * 8.0 / rate * 1e9;
            let prop = self.channels[h].cfg.latency_ns as f64;
            lat += up + down + 2.0 * prop;
            stage_max = stage_max.max(up);
        }
        lat + 10.0 * (stage_max - self.period as f64).max(0.0)
    }

    fn check(&mut self, t: SimTime) -> Result<()> {
        let Some(ctl) = self.ctl else { return Ok(()) };
        if self.settled < self.frames {
            self.q.schedule(t + ctl.check_period_ns.max(1), AdEv::Check);
        }
        if self.pending.is_some() {
            return Ok(());
        }
        if t < self.last_switch + ctl.min_dwell_ns {
            return Ok(());
        }
        // The memoized enumeration is the controller's candidate set —
        // a cache hit per decision, never a re-enumeration.
        let k = self.cands[0].hop_bytes.len();
        let n = self
            .cache
            .chains(self.arch, self.scale, k, self.net)
            .len();
        debug_assert_eq!(n, self.cands.len());
        let cur = self.predict(self.active, t);
        let (mut best_i, mut best) = (self.active, cur);
        for ci in 0..self.cands.len() {
            let p = self.predict(ci, t);
            if p < best {
                best = p;
                best_i = ci;
            }
        }
        if best_i != self.active && best < cur * (1.0 - ctl.switch_margin) {
            self.begin_switch(best_i, t)?;
        }
        Ok(())
    }

    fn begin_switch(&mut self, to: usize, t: SimTime) -> Result<()> {
        self.switches += 1;
        self.last_switch = t;
        if self.zero_cost {
            // Oracle: free, instantaneous switchover.
            self.active = to;
            return Ok(());
        }
        self.pending = Some(to);
        match self.policy {
            // Make-before-break: resync rides the downlink immediately,
            // the old chain keeps serving in the meantime.
            SwitchPolicy::Drain => self.start_resync(t)?,
            SwitchPolicy::Drop => {
                for f in self.edge_q.drain(..) {
                    self.dropped[f] = true;
                    self.settled += 1;
                }
                if !self.edge_busy {
                    self.start_resync(t)?;
                }
                // else: deferred to the in-flight frame's TierDone.
            }
        }
        Ok(())
    }

    fn resync_done(&mut self, t: SimTime) {
        self.active = self.pending.take().expect("ResyncDone without switch");
        if self.policy == SwitchPolicy::Drop {
            // Frames that arrived while tier 0 was held are stale at
            // cutover — break-before-make restarts from an empty
            // pipeline.
            for f in self.edge_q.drain(..) {
                self.dropped[f] = true;
                self.settled += 1;
            }
            self.edge_busy = false;
        }
    }

    fn handle(&mut self, ev: AdEv, t: SimTime) -> Result<()> {
        match ev {
            AdEv::Emit { f } => {
                self.emit(f, t);
                Ok(())
            }
            AdEv::TierDone { f, tier } => self.tier_done(f, tier, t),
            AdEv::UpDelivered { f, hop } => {
                self.up_delivered(f, hop, t);
                Ok(())
            }
            AdEv::DownDelivered { f, hop } => self.down_delivered(f, hop, t),
            AdEv::Check => self.check(t),
            AdEv::ResyncDone => {
                self.resync_done(t);
                Ok(())
            }
        }
    }
}

struct RunParams<'a> {
    cands: &'a [Cand],
    hop_nets: &'a [NetworkConfig],
    frames: usize,
    period: SimTime,
    deadline: SimTime,
    result_bytes: u64,
    queue: QueueKind,
}

#[allow(clippy::too_many_arguments)]
fn run_once(
    p: &RunParams<'_>,
    initial: usize,
    ctl: Option<&ControllerConfig>,
    policy: SwitchPolicy,
    zero_cost: bool,
    label: String,
    cache: &mut ChainCache,
    arch: Arch,
    scale: ModelScale,
    net: &Network,
) -> Result<PolicyOutcome> {
    let n_hops = p.hop_nets.len();
    let channels: Vec<Channel> =
        p.hop_nets.iter().map(|n| Channel::new(n.clone())).collect();
    let mut eng = Engine {
        cands: p.cands,
        ctl,
        policy,
        zero_cost,
        period: p.period,
        frames: p.frames,
        result_bytes: p.result_bytes,
        q: EventQueue::with_kind(p.queue),
        channels,
        emitted: vec![0; p.frames],
        completed: vec![None; p.frames],
        dropped: vec![false; p.frames],
        cand_of: vec![0; p.frames],
        edge_q: VecDeque::new(),
        edge_busy: false,
        tier_free: vec![0; n_hops + 1],
        window: vec![VecDeque::new(); n_hops],
        active: initial,
        pending: None,
        last_switch: 0,
        switches: 0,
        settled: 0,
        cache,
        arch,
        scale,
        net,
    };
    eng.q.schedule(0, AdEv::Emit { f: 0 });
    if let Some(c) = ctl {
        eng.q.schedule(c.check_period_ns.max(1), AdEv::Check);
    }
    while eng.settled < eng.frames {
        let Some((t, ev)) = eng.q.pop() else {
            bail!(
                "adaptive deadlock: {}/{} frames settled ({label})",
                eng.settled,
                eng.frames
            );
        };
        eng.handle(ev, t)?;
    }

    let mut latencies: Vec<SimTime> = Vec::new();
    let mut hits = 0usize;
    let mut dropped = 0usize;
    for f in 0..p.frames {
        if eng.dropped[f] {
            dropped += 1;
            continue;
        }
        let done = eng.completed[f].expect("settled frame incomplete");
        let lat = done - eng.emitted[f];
        if lat <= p.deadline {
            hits += 1;
        }
        latencies.push(lat);
    }
    latencies.sort_unstable();
    let completed = latencies.len();
    let mean = if completed > 0 {
        latencies.iter().map(|&l| l as f64).sum::<f64>() / completed as f64
    } else {
        0.0
    };
    let p95 = if completed > 0 {
        latencies[((completed as f64 * 0.95).ceil() as usize)
            .saturating_sub(1)
            .min(completed - 1)]
    } else {
        0
    };
    Ok(PolicyOutcome {
        label,
        frames: p.frames,
        completed,
        dropped,
        switches: eng.switches,
        deadline_hit_rate: hits as f64 / p.frames as f64,
        mean_latency_ns: mean,
        p95_latency_ns: p95,
    })
}

// ---------------------------------------------------------------------------
// The comparison report.
// ---------------------------------------------------------------------------

/// Side-by-side outcome of every static candidate, the adaptive
/// controller under both switch policies, and the zero-cost oracle, over
/// one traced channel configuration.
#[derive(Clone, Debug)]
pub struct AdaptiveReport {
    /// One static (never-switching) run per candidate chain.
    pub candidates: Vec<(Vec<usize>, PolicyOutcome)>,
    /// Index into `candidates` of the best static run (highest hit-rate,
    /// ties broken by lower mean latency, then lower index).
    pub static_best: usize,
    pub adaptive_drain: PolicyOutcome,
    pub adaptive_drop: PolicyOutcome,
    pub oracle: PolicyOutcome,
    /// How many times the candidate lattice was enumerated (memoized:
    /// stays 1 however many decisions the controllers make).
    pub chain_enumerations: u64,
    /// How many candidate-set requests the cache served.
    pub chain_lookups: u64,
}

impl AdaptiveReport {
    pub fn static_best_outcome(&self) -> &PolicyOutcome {
        &self.candidates[self.static_best].1
    }

    fn chain_label(chain: &[usize]) -> String {
        let mut s = String::from("mc@");
        for (i, c) in chain.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            s.push_str(&format!("L{c}"));
        }
        s
    }

    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(
            "policy                 hit-rate   mean-lat(ms)  p95(ms)  \
             switches  dropped\n",
        );
        let mut row = |label: &str, o: &PolicyOutcome| {
            out.push_str(&format!(
                "{label:<22} {:>8.3} {:>13.3} {:>8.3} {:>9} {:>8}\n",
                o.deadline_hit_rate,
                o.mean_latency_ns / 1e6,
                o.p95_latency_ns as f64 / 1e6,
                o.switches,
                o.dropped,
            ));
        };
        let (chain, best) = &self.candidates[self.static_best];
        row(
            &format!("static-best {}", Self::chain_label(chain)),
            best,
        );
        row("adaptive (drain)", &self.adaptive_drain);
        row("adaptive (drop)", &self.adaptive_drop);
        row("oracle (free switch)", &self.oracle);
        out.push_str(&format!(
            "\n{} static candidates evaluated; chain cache: {} \
             enumeration(s), {} lookups\n",
            self.candidates.len(),
            self.chain_enumerations,
            self.chain_lookups,
        ));
        out
    }

    pub fn to_json(&self) -> Json {
        let outcome = |o: &PolicyOutcome| {
            Json::obj(vec![
                ("label", Json::s(&o.label)),
                ("frames", Json::num(o.frames as f64)),
                ("completed", Json::num(o.completed as f64)),
                ("dropped", Json::num(o.dropped as f64)),
                ("switches", Json::num(o.switches as f64)),
                ("deadline_hit_rate", Json::num(o.deadline_hit_rate)),
                ("mean_latency_ns", Json::num(o.mean_latency_ns)),
                ("p95_latency_ns", Json::num(o.p95_latency_ns as f64)),
            ])
        };
        Json::obj(vec![
            (
                "static_best",
                Json::obj(vec![
                    (
                        "chain",
                        Json::arr(
                            self.candidates[self.static_best]
                                .0
                                .iter()
                                .map(|&c| Json::num(c as f64))
                                .collect(),
                        ),
                    ),
                    ("outcome", outcome(self.static_best_outcome())),
                ]),
            ),
            ("adaptive_drain", outcome(&self.adaptive_drain)),
            ("adaptive_drop", outcome(&self.adaptive_drop)),
            ("oracle", outcome(&self.oracle)),
            ("candidates", Json::num(self.candidates.len() as f64)),
            (
                "chain_enumerations",
                Json::num(self.chain_enumerations as f64),
            ),
            ("chain_lookups", Json::num(self.chain_lookups as f64)),
        ])
    }
}

/// Run the full static-vs-adaptive comparison for `cfg`: every candidate
/// chain statically, the adaptive controller under Drain and Drop, and
/// the zero-switchover-cost oracle, all over identical traced channels.
/// Deterministic in `cfg` alone — across queue backends by the shared
/// `(time, seq)` tiebreak, across thread counts trivially (the engine is
/// single-threaded by construction).
pub fn run_adaptive_comparison(cfg: &AdaptiveConfig)
    -> Result<AdaptiveReport>
{
    if cfg.tiers.len() < 2 {
        bail!("adaptive re-splitting needs at least 2 tiers (edge + server)");
    }
    if cfg.frames == 0 {
        bail!("adaptive comparison needs at least one frame");
    }
    if cfg.frame_period_ns == 0 {
        bail!("adaptive comparison needs a positive frame period");
    }
    if cfg.deadline_ns == 0 {
        bail!("adaptive comparison needs a positive deadline");
    }
    let k = cfg.tiers.len() - 1;
    if cfg.hop_nets.is_empty() {
        bail!("adaptive comparison needs at least one hop net");
    }
    if cfg.hop_nets.len() != 1 && cfg.hop_nets.len() != k {
        bail!(
            "{} hop nets for {} hops (one per inter-tier hop, or a single \
             template)",
            cfg.hop_nets.len(),
            k
        );
    }
    let hop_nets: Vec<NetworkConfig> =
        (0..k).map(|h| derive_hop_net(&cfg.hop_nets, h)).collect();

    let net = network_for(cfg.arch, cfg.scale);
    let points = split_points(&net);
    let mut cache = ChainCache::new();
    let chains =
        cache.chains(cfg.arch, cfg.scale, k, &net).to_vec();
    if chains.is_empty() {
        bail!(
            "{} has no valid {k}-cut chains ({} split points)",
            cfg.arch,
            points.len()
        );
    }
    let cands = build_cands(&points, &chains, &cfg.tiers)?;
    let result_bytes = net.output().bytes_f32() as u64;
    let p = RunParams {
        cands: &cands,
        hop_nets: &hop_nets,
        frames: cfg.frames,
        period: cfg.frame_period_ns,
        deadline: cfg.deadline_ns,
        result_bytes,
        queue: cfg.queue,
    };

    // Static runs: every candidate, no controller.
    let mut candidates = Vec::with_capacity(cands.len());
    for (ci, cand) in cands.iter().enumerate() {
        let o = run_once(
            &p,
            ci,
            None,
            SwitchPolicy::Drain,
            false,
            format!("static {}", AdaptiveReport::chain_label(&cand.chain)),
            &mut cache,
            cfg.arch,
            cfg.scale,
            &net,
        )?;
        candidates.push((cand.chain.clone(), o));
    }
    let mut static_best = 0usize;
    for i in 1..candidates.len() {
        let (b, c) = (&candidates[static_best].1, &candidates[i].1);
        if c.deadline_hit_rate > b.deadline_hit_rate
            || (c.deadline_hit_rate == b.deadline_hit_rate
                && c.mean_latency_ns < b.mean_latency_ns)
        {
            static_best = i;
        }
    }

    // The adaptive runs all start from the candidate the controller
    // would pick blind (best-case rates, no observations) — the same
    // first decision a fresh deployment would make.
    let initial = run_params_initial(&p);

    let adaptive_drain = run_once(
        &p,
        initial,
        Some(&cfg.controller),
        SwitchPolicy::Drain,
        false,
        "adaptive-drain".to_string(),
        &mut cache,
        cfg.arch,
        cfg.scale,
        &net,
    )?;
    let adaptive_drop = run_once(
        &p,
        initial,
        Some(&cfg.controller),
        SwitchPolicy::Drop,
        false,
        "adaptive-drop".to_string(),
        &mut cache,
        cfg.arch,
        cfg.scale,
        &net,
    )?;
    let oracle = run_once(
        &p,
        initial,
        Some(&cfg.controller),
        SwitchPolicy::Drain,
        true,
        "oracle".to_string(),
        &mut cache,
        cfg.arch,
        cfg.scale,
        &net,
    )?;

    Ok(AdaptiveReport {
        candidates,
        static_best,
        adaptive_drain,
        adaptive_drop,
        oracle,
        chain_enumerations: cache.enumerations(),
        chain_lookups: cache.lookups(),
    })
}

/// The controller's blind first pick: argmin predicted cost under each
/// channel's best-case rate (no observations yet) — computed without an
/// engine instance so every policy run starts identically.
fn run_params_initial(p: &RunParams<'_>) -> usize {
    let rates: Vec<f64> =
        p.hop_nets.iter().map(|n| n.best_rate_bps()).collect();
    let mut best_i = 0usize;
    let mut best = f64::INFINITY;
    for (ci, c) in p.cands.iter().enumerate() {
        let mut lat = 0.0f64;
        let mut stage_max = 0.0f64;
        for &ns in &c.seg_ns {
            lat += ns as f64;
            stage_max = stage_max.max(ns as f64);
        }
        let mut feasible = true;
        for (h, &bytes) in c.hop_bytes.iter().enumerate() {
            if rates[h] <= 0.0 {
                feasible = false;
                break;
            }
            let up = bytes as f64 * 8.0 / rates[h] * 1e9;
            let down =
                p.result_bytes as f64 * 8.0 / rates[h] * 1e9;
            lat += up + down + 2.0 * p.hop_nets[h].latency_ns as f64;
            stage_max = stage_max.max(up);
        }
        if !feasible {
            continue;
        }
        let score = lat + 10.0 * (stage_max - p.period as f64).max(0.0);
        if score < best {
            best = score;
            best_i = ci;
        }
    }
    best_i
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::trace::LinkTrace;
    use crate::netsim::transfer::Protocol;

    fn cmp_outcome(a: &PolicyOutcome, b: &PolicyOutcome) -> bool {
        a.deadline_hit_rate == b.deadline_hit_rate
            && a.mean_latency_ns == b.mean_latency_ns
            && a.p95_latency_ns == b.p95_latency_ns
            && a.switches == b.switches
            && a.dropped == b.dropped
            && a.completed == b.completed
    }

    fn base_cfg() -> AdaptiveConfig {
        // Short propagation delay so the observed goodput stays close
        // to the configured rate — on a steady link the blind first
        // pick must remain inside the hysteresis margin.
        let mut net = NetworkConfig::parse("gigabit:udp:loss=0").unwrap();
        net.latency_ns = 10_000;
        AdaptiveConfig {
            arch: Arch::Vgg16,
            scale: ModelScale::Full,
            tiers: vec![
                DeviceProfile::parse("edge@2e12+10000").unwrap(),
                DeviceProfile::parse("srv@1e15+1000").unwrap(),
            ],
            hop_nets: vec![net],
            frames: 20,
            frame_period_ns: 10_000_000,
            deadline_ns: 18_000_000,
            controller: ControllerConfig::default(),
            queue: QueueKind::Calendar,
        }
    }

    #[test]
    fn chain_cache_memoizes_per_key() {
        let net = Arch::Vgg16.full_network();
        let mut cache = ChainCache::new();
        let n1 = cache
            .chains(Arch::Vgg16, ModelScale::Full, 1, &net)
            .len();
        for _ in 0..10 {
            let n = cache
                .chains(Arch::Vgg16, ModelScale::Full, 1, &net)
                .len();
            assert_eq!(n, n1);
        }
        assert_eq!(cache.enumerations(), 1);
        assert_eq!(cache.lookups(), 11);
        // A different k is a different key — exactly one more enumeration.
        cache.chains(Arch::Vgg16, ModelScale::Full, 2, &net);
        assert_eq!(cache.enumerations(), 2);
    }

    #[test]
    fn resync_bytes_counts_changed_hops_only() {
        let points = split_points(&Arch::Vgg16.full_network());
        let tiers = vec![
            DeviceProfile::edge_gpu(),
            DeviceProfile::server_gpu(),
        ];
        let cands = build_cands(
            &points,
            &[vec![5], vec![13], vec![5]],
            &tiers,
        )
        .unwrap();
        let b = resync_bytes(&cands[0], &cands[1]);
        assert_eq!(
            b,
            cands[0].hop_bytes[0]
                + cands[1].hop_bytes[0]
                + RESYNC_CONTROL_BYTES
        );
        // Identical chains: nothing changes, nothing moves.
        assert_eq!(resync_bytes(&cands[0], &cands[2]), 0);
    }

    #[test]
    fn constant_channel_comparison_is_deterministic_across_backends() {
        let mut cfg = base_cfg();
        let a = run_adaptive_comparison(&cfg).unwrap();
        cfg.queue = QueueKind::LinearScan;
        let b = run_adaptive_comparison(&cfg).unwrap();
        assert_eq!(a.static_best, b.static_best);
        assert!(cmp_outcome(&a.adaptive_drain, &b.adaptive_drain));
        assert!(cmp_outcome(&a.adaptive_drop, &b.adaptive_drop));
        assert!(cmp_outcome(&a.oracle, &b.oracle));
        for ((ca, oa), (cb, ob)) in
            a.candidates.iter().zip(b.candidates.iter())
        {
            assert_eq!(ca, cb);
            assert!(cmp_outcome(oa, ob));
        }
    }

    #[test]
    fn constant_channel_adaptive_never_switches() {
        let r = run_adaptive_comparison(&base_cfg()).unwrap();
        // A steady link gives the controller nothing to react to: the
        // blind first pick stays best within the hysteresis margin.
        assert_eq!(r.adaptive_drain.switches, 0);
        assert_eq!(r.adaptive_drop.switches, 0);
        assert_eq!(r.chain_enumerations, 1);
        assert!(r.chain_lookups > 1, "{}", r.chain_lookups);
    }

    #[test]
    fn validation_rejects_degenerate_configs() {
        let mut c = base_cfg();
        c.tiers.truncate(1);
        assert!(run_adaptive_comparison(&c).is_err());
        let mut c = base_cfg();
        c.frames = 0;
        assert!(run_adaptive_comparison(&c).is_err());
        let mut c = base_cfg();
        c.frame_period_ns = 0;
        assert!(run_adaptive_comparison(&c).is_err());
        let mut c = base_cfg();
        c.hop_nets = vec![
            NetworkConfig::parse("gigabit").unwrap(),
            NetworkConfig::parse("gigabit").unwrap(),
        ];
        assert!(run_adaptive_comparison(&c).is_err());
    }

    #[test]
    fn degrading_trace_adaptive_beats_static_and_loses_to_oracle() {
        // Self-calibrating handoff scenario (good -> bad -> good) built
        // from the arch's own volumetrics; see tests/trace_semantics.rs
        // for the committed-suite version.
        let period: SimTime = 10_000_000; // 10 ms
        let frames = 60usize;
        let net = Arch::Vgg16.full_network();
        let points = split_points(&net);
        // d: the shallowest candidate in the smallest-latent group
        // (VGG: pool4); a: the best shallow candidate (pool3 group).
        let n_cand = points.len() - 1;
        let min_bytes = (0..n_cand)
            .map(|i| points[i].latent_bytes())
            .min()
            .unwrap();
        let d = (0..n_cand)
            .find(|&i| points[i].latent_bytes() == min_bytes)
            .unwrap();
        let shallow_min_bytes = (0..d)
            .map(|i| points[i].latent_bytes())
            .min()
            .unwrap();
        assert!(
            shallow_min_bytes >= 2 * min_bytes,
            "need byte separation: {shallow_min_bytes} vs {min_bytes}"
        );
        // Edge tuned so d's head runs at 1.02 x period: a slow drift that
        // makes the deep chain infeasible as a *static* choice (its edge
        // queue grows all run) while a mid-stream visit stays affordable.
        let (head_d, _) = points[d].split_compute();
        let overhead = 10_000u64;
        let macs = head_d as f64
            / ((1.02 * period as f64 - overhead as f64) / 1e9);
        let tiers = vec![
            DeviceProfile::parse(&format!("edge@{macs:e}+{overhead}"))
                .unwrap(),
            DeviceProfile::parse("srv@1e15+1000").unwrap(),
        ];
        // Good rate: the shallow latent crosses in period/2. Bad rate: it
        // needs 1.35 periods — the shallow uplink outruns the frame period
        // (its queue grows without bound) while the deep latent still
        // crosses in ~0.68 periods and keeps meeting the deadline.
        let rg = shallow_min_bytes as f64 * 8.0
            / (0.5 * period as f64 / 1e9);
        let rb = shallow_min_bytes as f64 * 8.0
            / (1.35 * period as f64 / 1e9);
        let mk = |rate: f64| {
            let mut n =
                NetworkConfig::parse("gigabit:udp:loss=0").unwrap();
            n.capacity_bps = rate;
            n.interface_bps = rate;
            n.latency_ns = 200_000;
            n
        };
        let (good, bad) = (mk(rg), mk(rb));
        let t1 = (frames as u64 * period) * 2 / 5; // 40%: bad begins
        let t2 = (frames as u64 * period) * 7 / 10; // 70%: recovery
        let trace = LinkTrace::new(
            "handoff".into(),
            vec![
                crate::netsim::trace::TraceSegment::from_net(&good, 0),
                crate::netsim::trace::TraceSegment::from_net(&bad, t1),
                crate::netsim::trace::TraceSegment::from_net(&good, t2),
            ],
        )
        .unwrap();
        let cfg = AdaptiveConfig {
            arch: Arch::Vgg16,
            scale: ModelScale::Full,
            tiers,
            hop_nets: vec![good.clone().with_trace(trace)],
            frames,
            frame_period_ns: period,
            deadline_ns: period * 2,
            controller: ControllerConfig {
                window: 4,
                check_period_ns: period / 2,
                min_dwell_ns: 5 * period,
                switch_margin: 0.1,
            },
            queue: QueueKind::Calendar,
        };
        let r = run_adaptive_comparison(&cfg).unwrap();
        let sb = r.static_best_outcome();
        assert!(
            r.adaptive_drain.deadline_hit_rate > sb.deadline_hit_rate,
            "drain {} vs static-best {} ({})",
            r.adaptive_drain.deadline_hit_rate,
            sb.deadline_hit_rate,
            sb.label,
        );
        assert!(
            r.adaptive_drop.deadline_hit_rate > sb.deadline_hit_rate,
            "drop {} vs static-best {}",
            r.adaptive_drop.deadline_hit_rate,
            sb.deadline_hit_rate,
        );
        assert!(
            r.oracle.deadline_hit_rate
                > r.adaptive_drain.deadline_hit_rate,
            "oracle {} vs drain {}",
            r.oracle.deadline_hit_rate,
            r.adaptive_drain.deadline_hit_rate,
        );
        assert!(r.adaptive_drain.switches >= 1);
        assert!(r.oracle.switches >= 1);
        assert_eq!(r.chain_enumerations, 1);
        assert!(r.chain_lookups as usize > r.candidates.len());
        // The report renders and serializes.
        assert!(r.render().contains("adaptive (drain)"));
        assert!(r.to_json().to_string().contains("oracle"));
    }
}
