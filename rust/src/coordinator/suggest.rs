//! QoS suggestion engine (paper Fig. 1, step iii and Sec. IV "output"):
//! rank the candidate configurations by the accuracy the network is
//! expected to achieve, simulate each, and report which designs satisfy the
//! application's constraints — "the engineer may then decide to simulate
//! all or only a subset of them".

use anyhow::Result;

use super::qos::QosRequirements;
use super::saliency::CsCurve;
use super::scenario::{
    scenario_network, ModelScale, ScenarioConfig, ScenarioKind,
    ScenarioReport,
};
use super::sweep;
use crate::data::Dataset;
use crate::model::{ChainCache, DeviceProfile};
use crate::netsim::transfer::NetworkConfig;
use crate::runtime::InferenceBackend;

/// One ranked configuration, pre-simulation.
#[derive(Clone, Debug)]
pub struct RankedConfig {
    pub kind: ScenarioKind,
    /// For SC candidates: the name of the graph cut the split id denotes
    /// (e.g. `block4_conv2` for VGG16, `layer2.1` for ResNet-18) — split
    /// ids are arch-relative, the name is what the engineer reads.
    pub cut_name: Option<String>,
    /// Accuracy predictor: measured split-eval accuracy from the manifest
    /// for SC; base/lite accuracy for RC/LC.
    pub predicted_accuracy: f64,
    /// Uplink payload per frame, bytes (0 for LC).
    pub up_bytes: u64,
    pub cs_value: Option<f64>,
}

/// Final suggestion row after simulation.
#[derive(Clone, Debug)]
pub struct Suggestion {
    pub rank: RankedConfig,
    pub report: ScenarioReport,
    pub satisfies: bool,
}

/// Step 1+2: candidate split points from the CS curve, ranked by predicted
/// accuracy, plus the LC and RC baselines. With a tier chain deeper than
/// two devices (`n_tiers >= 3`), every ordered chain of `n_tiers - 1`
/// exported cuts whose first element is a CS candidate joins the ranking
/// as a multi-tier (MC) configuration.
pub fn rank_configurations(
    engine: &dyn InferenceBackend,
    min_layer: usize,
    n_tiers: usize,
) -> Vec<RankedConfig> {
    rank_configurations_cached(
        engine,
        min_layer,
        n_tiers,
        &mut ChainCache::new(),
    )
}

/// [`rank_configurations`] against a caller-owned [`ChainCache`], so
/// repeated rankings (one per tier chain, as the placement and co-design
/// searches issue them) enumerate the k-subset lattice at most once per
/// (arch, scale, k).
pub fn rank_configurations_cached(
    engine: &dyn InferenceBackend,
    min_layer: usize,
    n_tiers: usize,
    cache: &mut ChainCache,
) -> Vec<RankedConfig> {
    let m = engine.manifest();
    let curve = CsCurve::from_manifest(m);
    let norm = curve.normalized();
    let available = m.available_splits();
    let mut out = Vec::new();

    // SC candidates: CS local maxima (cut ids of the manifest's arch)
    // that have exported artifacts.
    let cands = curve.candidates(min_layer);
    for &cand in &cands {
        if !available.contains(&cand) {
            continue;
        }
        let acc = m
            .split_eval_for(cand)
            .map(|r| r.accuracy)
            .unwrap_or(m.model.base_test_accuracy);
        let up = m
            .split_eval_for(cand)
            .map(|r| r.latent_bytes_per_image)
            .unwrap_or(0);
        out.push(RankedConfig {
            kind: ScenarioKind::Sc { split: cand },
            cut_name: m.model.layer_names.get(cand).cloned(),
            predicted_accuracy: acc,
            up_bytes: up,
            cs_value: norm.get(cand).copied(),
        });
    }
    // MC candidates: ordered chains of exported cuts matching the tier
    // chain's hop count. Predicted accuracy is the most pessimistic cut's
    // split-eval accuracy; the reported uplink volume is the sensor-side
    // hop (the constrained one).
    if n_tiers >= 3 {
        let k = n_tiers - 1;
        // The memoized lattice covers every split id; restricting it to
        // the manifest's exported ids reproduces
        // `ordered_chains(&available, k)` element-for-element (same
        // lexicographic order), while repeated rankings reuse one
        // enumeration per (arch, scale, k).
        let net = scenario_network(engine, ModelScale::Slim);
        let chains: Vec<Vec<usize>> = cache
            .chains(m.arch(), ModelScale::Slim, k, &net)
            .iter()
            .filter(|chain| {
                chain.iter().all(|c| available.contains(c))
            })
            .cloned()
            .collect();
        for chain in chains {
            if !cands.contains(&chain[0]) {
                continue;
            }
            let acc = chain
                .iter()
                .filter_map(|&c| m.split_eval_for(c).map(|r| r.accuracy))
                .fold(m.model.base_test_accuracy, f64::min);
            let up = m
                .split_eval_for(chain[0])
                .map(|r| r.latent_bytes_per_image)
                .unwrap_or(0);
            let name = chain
                .iter()
                .map(|&c| {
                    m.model
                        .layer_names
                        .get(c)
                        .cloned()
                        .unwrap_or_else(|| format!("L{c}"))
                })
                .collect::<Vec<_>>()
                .join(">");
            out.push(RankedConfig {
                cs_value: norm.get(chain[0]).copied(),
                kind: ScenarioKind::Mc { cuts: chain },
                cut_name: Some(name),
                predicted_accuracy: acc,
                up_bytes: up,
            });
        }
    }
    // Baselines. The RC uplink volume is the manifest's input tensor
    // description (shape × dtype), not a dense-RGB-f32 assumption.
    out.push(RankedConfig {
        kind: ScenarioKind::Rc,
        cut_name: None,
        predicted_accuracy: m.model.base_test_accuracy,
        up_bytes: m.input_bytes_per_frame(),
        cs_value: None,
    });
    out.push(RankedConfig {
        kind: ScenarioKind::Lc,
        cut_name: None,
        predicted_accuracy: lite_accuracy(engine),
        up_bytes: 0,
        cs_value: None,
    });
    out.sort_by(|a, b| {
        b.predicted_accuracy
            .partial_cmp(&a.predicted_accuracy)
            .unwrap()
            .then(a.up_bytes.cmp(&b.up_bytes))
    });
    out
}

fn lite_accuracy(engine: &dyn InferenceBackend) -> f64 {
    engine.manifest().lite_accuracy.unwrap_or(0.0)
}

/// Step 3: simulate each ranked configuration and check QoS.
/// `n_frames` frames of `dataset` per configuration.
///
/// `tiers` is the device chain (sensor side first): with the classic two
/// tiers the candidates are LC/RC/SC; a deeper chain adds every matching
/// multi-tier (MC) cut chain to the ranking, and the two-tier baselines
/// run on the chain's first and last devices.
///
/// Each configuration is one point of the design space; execution rides the
/// sweep engine's point runner ([`sweep::pooled_scenario`]) so the suggest
/// loop and batch sweeps share a single scenario-execution path.
pub fn suggest(
    engine: &dyn InferenceBackend,
    net: &NetworkConfig,
    tiers: &[DeviceProfile],
    qos: &QosRequirements,
    dataset: &Dataset,
    n_frames: usize,
    min_layer: usize,
) -> Result<Vec<Suggestion>> {
    if tiers.len() < 2 {
        anyhow::bail!("suggest needs a chain of at least 2 device tiers");
    }
    let ranked = rank_configurations(engine, min_layer, tiers.len());
    let mut out = Vec::with_capacity(ranked.len());
    for rank in ranked {
        let cfg = ScenarioConfig {
            kind: rank.kind.clone(),
            hop_nets: vec![net.clone()],
            tiers: match rank.kind {
                // MC occupies the whole chain; the two-tier baselines run
                // on its endpoints.
                ScenarioKind::Mc { .. } => tiers.to_vec(),
                _ => vec![
                    tiers[0].clone(),
                    tiers.last().unwrap().clone(),
                ],
            },
            scale: ModelScale::Slim,
            frame_period_ns: qos.max_latency_ns.unwrap_or(0),
        };
        // Capability probe: a backend without per-segment chain
        // executables (real AOT artifacts export single-split
        // heads/tails only; on-demand synthesis is an analytic-backend
        // capability) cannot serve an MC candidate — drop the chain from
        // the table rather than failing the LC/RC/SC baselines with it.
        // Genuine simulation failures below still propagate.
        if let ScenarioKind::Mc { cuts } = &rank.kind {
            if !super::streaming::chain_servable(engine, cuts) {
                continue;
            }
        }
        let report = sweep::pooled_scenario(
            engine, &cfg, dataset, n_frames, &[net.seed], qos,
        )?;
        // Per-frame verdict: the deadline hit-rate (not the mean) decides.
        let satisfies =
            qos.satisfied_by(report.deadline_hit_rate, report.accuracy);
        out.push(Suggestion { rank, report, satisfies });
    }
    Ok(out)
}

/// The best suggestion: satisfying configs first (highest accuracy, then
/// lowest latency), otherwise the closest to satisfying.
pub fn best(suggestions: &[Suggestion]) -> Option<&Suggestion> {
    suggestions
        .iter()
        .filter(|s| s.satisfies)
        .max_by(|a, b| {
            a.report
                .accuracy
                .partial_cmp(&b.report.accuracy)
                .unwrap()
                .then(
                    b.report
                        .mean_latency_ns
                        .partial_cmp(&a.report.mean_latency_ns)
                        .unwrap(),
                )
        })
        .or_else(|| {
            suggestions.iter().max_by(|a, b| {
                a.report.accuracy.partial_cmp(&b.report.accuracy).unwrap()
            })
        })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ordered_chains;
    use crate::netsim::transfer::Protocol;

    fn fake_report(kind: ScenarioKind, acc: f64, lat: f64) -> ScenarioReport {
        ScenarioReport {
            kind,
            protocol: Protocol::Tcp,
            loss_rate: 0.0,
            frames: 1,
            accuracy: acc,
            mean_latency_ns: lat,
            p95_latency_ns: lat as u64,
            p99_latency_ns: lat as u64,
            max_latency_ns: lat as u64,
            mean_wire_bytes: 0.0,
            total_retransmits: 0,
            deadline_hit_rate: None,
            qos_satisfied: None,
            records: vec![],
        }
    }

    fn fake_suggestion(acc: f64, lat: f64, ok: bool) -> Suggestion {
        Suggestion {
            rank: RankedConfig {
                kind: ScenarioKind::Rc,
                cut_name: None,
                predicted_accuracy: acc,
                up_bytes: 0,
                cs_value: None,
            },
            report: fake_report(ScenarioKind::Rc, acc, lat),
            satisfies: ok,
        }
    }

    #[test]
    fn best_prefers_satisfying() {
        let s = vec![
            fake_suggestion(0.99, 100.0, false),
            fake_suggestion(0.90, 10.0, true),
        ];
        assert!((best(&s).unwrap().report.accuracy - 0.90).abs() < 1e-9);
    }

    #[test]
    fn best_among_satisfying_takes_highest_accuracy() {
        let s = vec![
            fake_suggestion(0.90, 10.0, true),
            fake_suggestion(0.95, 20.0, true),
        ];
        assert!((best(&s).unwrap().report.accuracy - 0.95).abs() < 1e-9);
    }

    #[test]
    fn best_falls_back_to_highest_accuracy() {
        let s = vec![
            fake_suggestion(0.80, 10.0, false),
            fake_suggestion(0.85, 20.0, false),
        ];
        assert!((best(&s).unwrap().report.accuracy - 0.85).abs() < 1e-9);
    }

    #[test]
    fn best_of_empty_is_none() {
        assert!(best(&[]).is_none());
    }

    #[test]
    fn ordered_chains_enumerate_increasing_subsets() {
        let ids = [5usize, 9, 11, 13, 15];
        assert_eq!(ordered_chains(&ids, 1).len(), 5);
        assert_eq!(ordered_chains(&ids, 2).len(), 10);
        assert_eq!(ordered_chains(&ids, 5).len(), 1);
        assert!(ordered_chains(&ids, 6).is_empty());
        assert!(ordered_chains(&ids, 0).is_empty());
        for ch in ordered_chains(&ids, 3) {
            assert!(ch.windows(2).all(|w| w[0] < w[1]));
        }
    }
}
