//! Server-side dynamic batcher.
//!
//! The paper's tail runs on a server shared by "one or more DNNs" /
//! multiple sensing devices; a production deployment amortizes inference by
//! batching concurrent requests (the b16 artifacts exist exactly for this).
//! This module implements the classic size-or-deadline policy: a batch is
//! released when it reaches `max_batch` requests or when the oldest queued
//! request has waited `max_wait_ns`, whichever comes first.
//!
//! The batcher is a pure (simulated-time) policy object so it can be driven
//! both by the discrete-event scenario engine and by the real-socket HIL
//! worker; `ablation_batching` measures the throughput/latency trade-off.

use anyhow::{bail, Result};

use crate::netsim::event::SimTime;

#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_wait_ns: SimTime,
}

impl BatchPolicy {
    pub fn immediate() -> Self {
        BatchPolicy { max_batch: 1, max_wait_ns: 0 }
    }

    pub fn new(max_batch: usize, max_wait_ns: SimTime) -> Self {
        assert!(max_batch >= 1);
        BatchPolicy { max_batch, max_wait_ns }
    }

    /// Build a policy from user-facing units (CLI flags, sweep specs): a
    /// maximum batch size and a partial-batch deadline in microseconds.
    /// The single validating µs→ns conversion shared by `sei serve` and
    /// [`crate::coordinator::sweep::SweepSpec`].
    pub fn from_micros(max_batch: usize, wait_us: f64) -> Result<Self> {
        if max_batch == 0 {
            bail!("max batch size must be >= 1");
        }
        if !wait_us.is_finite() || wait_us < 0.0 {
            bail!(
                "batch wait must be a non-negative number of µs, \
                 got {wait_us}"
            );
        }
        Ok(BatchPolicy::new(max_batch, (wait_us * 1000.0) as SimTime))
    }
}

/// A queued inference request.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Request {
    pub id: u64,
    pub arrival_ns: SimTime,
}

/// A released batch.
#[derive(Clone, Debug)]
pub struct Batch {
    pub requests: Vec<Request>,
    pub released_ns: SimTime,
}

impl Batch {
    pub fn len(&self) -> usize {
        self.requests.len()
    }

    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }

    /// Mean queueing delay the batched requests paid.
    pub fn mean_wait_ns(&self) -> f64 {
        if self.requests.is_empty() {
            return 0.0;
        }
        self.requests
            .iter()
            .map(|r| (self.released_ns - r.arrival_ns) as f64)
            .sum::<f64>()
            / self.requests.len() as f64
    }
}

/// Size-or-deadline dynamic batcher over simulated time.
pub struct Batcher {
    policy: BatchPolicy,
    queue: Vec<Request>,
    /// Pooled storage for the next release (see [`Batcher::recycle`]).
    spare: Vec<Request>,
    next_id: u64,
    pub batches_released: u64,
    pub requests_seen: u64,
}

impl Batcher {
    pub fn new(policy: BatchPolicy) -> Self {
        Batcher {
            policy,
            queue: Vec::new(),
            spare: Vec::new(),
            next_id: 0,
            batches_released: 0,
            requests_seen: 0,
        }
    }

    /// Return a served batch's request storage to the pool: the next
    /// release re-arms the pending queue with this capacity, so a
    /// steady-state serve loop circulates a fixed set of `Vec`s instead
    /// of allocating one per batch.
    pub fn recycle(&mut self, mut spent: Vec<Request>) {
        spent.clear();
        self.spare = spent;
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Offer a request at simulated time `now`; returns a batch if the
    /// size trigger fires.
    pub fn offer(&mut self, now: SimTime) -> Option<Batch> {
        let id = self.next_id;
        self.next_id += 1;
        self.requests_seen += 1;
        self.queue.push(Request { id, arrival_ns: now });
        if self.queue.len() >= self.policy.max_batch {
            return Some(self.release(now));
        }
        None
    }

    /// The absolute time at which the deadline trigger fires for the
    /// currently queued requests (None when the queue is empty).
    pub fn deadline(&self) -> Option<SimTime> {
        self.queue
            .first()
            .map(|r| r.arrival_ns + self.policy.max_wait_ns)
    }

    /// Called when simulated time passes the deadline: release whatever is
    /// queued.
    pub fn poll(&mut self, now: SimTime) -> Option<Batch> {
        match self.deadline() {
            Some(d) if now >= d && !self.queue.is_empty() => {
                Some(self.release(now))
            }
            _ => None,
        }
    }

    /// Force-release the current queue (shutdown / drain).
    pub fn flush(&mut self, now: SimTime) -> Option<Batch> {
        if self.queue.is_empty() {
            None
        } else {
            Some(self.release(now))
        }
    }

    fn release(&mut self, now: SimTime) -> Batch {
        self.batches_released += 1;
        let requests = std::mem::replace(
            &mut self.queue,
            std::mem::take(&mut self.spare),
        );
        Batch { requests, released_ns: now }
    }
}

/// A fairness-aware variant of [`Batcher`] for multi-tenant serving: same
/// size-or-deadline release triggers, same counters, same id assignment
/// (ids are dense in offer order — the streaming engine maps them back to
/// frames), but each released batch orders its requests by deficit round
/// robin over the offering clients instead of pure arrival order.
///
/// Because `offer` releases the moment the queue reaches `max_batch`, the
/// pending set never exceeds one batch and every release drains it — so
/// the *membership* of each batch matches [`Batcher`] exactly; DRR only
/// decides the within-batch service order (which drives the order the
/// server's results re-enter the shared downlink lanes).
pub struct DrrBatcher {
    policy: BatchPolicy,
    /// Pending requests in offer order, tagged with the offering client.
    queue: Vec<(usize, Request)>,
    /// Persistent DRR scheduler reused across releases. A fully drained
    /// [`super::drr::DrrQueue`] is back in its pristine state (ring
    /// empty, deficits zeroed on departure), so one instance serves every
    /// batch — a release costs O(batch), not O(clients): rebuilding the
    /// per-client queue table per release is what made 10^6-tenant DRR
    /// serving quadratic.
    scratch: super::drr::DrrQueue<Request>,
    /// Pooled storage for the next release (see [`DrrBatcher::recycle`]).
    spare: Vec<Request>,
    next_id: u64,
    pub batches_released: u64,
    pub requests_seen: u64,
}

impl DrrBatcher {
    /// `weights[c]` scales client `c`'s share of each batch's head
    /// positions (minimum 1 enforced by the scheduler).
    pub fn new(policy: BatchPolicy, weights: Vec<u64>) -> Self {
        DrrBatcher {
            policy,
            queue: Vec::new(),
            scratch: super::drr::DrrQueue::new(&weights, 1),
            spare: Vec::new(),
            next_id: 0,
            batches_released: 0,
            requests_seen: 0,
        }
    }

    /// Return a served batch's request storage to the pool (same contract
    /// as [`Batcher::recycle`]).
    pub fn recycle(&mut self, mut spent: Vec<Request>) {
        spent.clear();
        self.spare = spent;
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Offer a request from `client` at simulated time `now`.
    pub fn offer(&mut self, client: usize, now: SimTime) -> Option<Batch> {
        let id = self.next_id;
        self.next_id += 1;
        self.requests_seen += 1;
        self.queue.push((client, Request { id, arrival_ns: now }));
        if self.queue.len() >= self.policy.max_batch {
            return Some(self.release(now));
        }
        None
    }

    /// Deadline of the oldest pending request (offer order = arrival
    /// order, so the first entry is the oldest).
    pub fn deadline(&self) -> Option<SimTime> {
        self.queue
            .first()
            .map(|(_, r)| r.arrival_ns + self.policy.max_wait_ns)
    }

    pub fn poll(&mut self, now: SimTime) -> Option<Batch> {
        match self.deadline() {
            Some(d) if now >= d && !self.queue.is_empty() => {
                Some(self.release(now))
            }
            _ => None,
        }
    }

    pub fn flush(&mut self, now: SimTime) -> Option<Batch> {
        if self.queue.is_empty() {
            None
        } else {
            Some(self.release(now))
        }
    }

    fn release(&mut self, now: SimTime) -> Batch {
        self.batches_released += 1;
        // Unit cost + quantum 1 turns DRR into weighted round robin over
        // the offering clients; ring order follows first appearance in the
        // batch, so the ordering is deterministic — and identical whether
        // the scheduler is freshly built or reused after a full drain.
        debug_assert!(self.scratch.is_empty());
        for (client, req) in self.queue.drain(..) {
            self.scratch.push(client, 1, req);
        }
        let mut requests = std::mem::take(&mut self.spare);
        requests.reserve(self.scratch.len());
        while let Some(req) = self.scratch.pop() {
            requests.push(req);
        }
        Batch { requests, released_ns: now }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn size_trigger_releases_full_batch() {
        let mut b = Batcher::new(BatchPolicy::new(4, 1_000_000));
        assert!(b.offer(0).is_none());
        assert!(b.offer(10).is_none());
        assert!(b.offer(20).is_none());
        let batch = b.offer(30).expect("size trigger");
        assert_eq!(batch.len(), 4);
        assert_eq!(b.pending(), 0);
        assert_eq!(batch.released_ns, 30);
    }

    #[test]
    fn deadline_trigger_releases_partial_batch() {
        let mut b = Batcher::new(BatchPolicy::new(16, 1_000_000));
        b.offer(0);
        b.offer(500);
        assert!(b.poll(999_999).is_none());
        let batch = b.poll(1_000_000).expect("deadline trigger");
        assert_eq!(batch.len(), 2);
    }

    #[test]
    fn deadline_tracks_oldest_request() {
        let mut b = Batcher::new(BatchPolicy::new(16, 100));
        assert!(b.deadline().is_none());
        b.offer(50);
        b.offer(120);
        assert_eq!(b.deadline(), Some(150));
    }

    #[test]
    fn from_micros_validates_and_converts() {
        let p = BatchPolicy::from_micros(8, 500.0).unwrap();
        assert_eq!(p.max_batch, 8);
        assert_eq!(p.max_wait_ns, 500_000);
        assert!(BatchPolicy::from_micros(0, 1.0).is_err());
        assert!(BatchPolicy::from_micros(1, -1.0).is_err());
        assert!(BatchPolicy::from_micros(1, f64::NAN).is_err());
    }

    #[test]
    fn immediate_policy_is_batchless() {
        let mut b = Batcher::new(BatchPolicy::immediate());
        let batch = b.offer(7).expect("immediate");
        assert_eq!(batch.len(), 1);
    }

    #[test]
    fn mean_wait_accounts_queueing() {
        let mut b = Batcher::new(BatchPolicy::new(2, 1_000));
        b.offer(0);
        let batch = b.offer(100).unwrap();
        assert_eq!(batch.mean_wait_ns(), 50.0);
    }

    #[test]
    fn flush_drains() {
        let mut b = Batcher::new(BatchPolicy::new(8, 1_000));
        b.offer(1);
        b.offer(2);
        let batch = b.flush(10).unwrap();
        assert_eq!(batch.len(), 2);
        assert!(b.flush(11).is_none());
    }

    #[test]
    fn counters() {
        let mut b = Batcher::new(BatchPolicy::new(2, 1_000));
        for t in 0..6 {
            b.offer(t);
        }
        assert_eq!(b.requests_seen, 6);
        assert_eq!(b.batches_released, 3);
    }

    #[test]
    fn drr_batcher_matches_fifo_membership_and_triggers() {
        // Same offer sequence into both batchers: identical release
        // points, identical batch membership (as id sets), identical
        // counters — only the within-batch order may differ.
        let policy = BatchPolicy::new(4, 1_000);
        let mut fifo = Batcher::new(policy);
        let mut drr = DrrBatcher::new(policy, vec![1, 1, 1]);
        for (i, t) in [0u64, 5, 10, 15, 100, 105, 110, 115].iter()
            .enumerate()
        {
            let a = fifo.offer(*t);
            let b = drr.offer(i % 3, *t);
            assert_eq!(a.is_some(), b.is_some());
            if let (Some(a), Some(b)) = (a, b) {
                let mut ia: Vec<u64> =
                    a.requests.iter().map(|r| r.id).collect();
                let mut ib: Vec<u64> =
                    b.requests.iter().map(|r| r.id).collect();
                ia.sort_unstable();
                ib.sort_unstable();
                assert_eq!(ia, ib);
                assert_eq!(a.released_ns, b.released_ns);
            }
        }
        assert_eq!(fifo.requests_seen, drr.requests_seen);
        assert_eq!(fifo.batches_released, drr.batches_released);
        assert_eq!(fifo.deadline(), drr.deadline());
    }

    #[test]
    fn drr_batcher_interleaves_clients_within_a_batch() {
        // Client 0 offers three requests, client 1 one: DRR puts client
        // 1's request second, not last.
        let mut b = DrrBatcher::new(BatchPolicy::new(4, 1_000), vec![1, 1]);
        assert!(b.offer(0, 0).is_none());
        assert!(b.offer(0, 1).is_none());
        assert!(b.offer(0, 2).is_none());
        let batch = b.offer(1, 3).expect("size trigger");
        let ids: Vec<u64> = batch.requests.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![0, 3, 1, 2]);
    }

    #[test]
    fn recycled_storage_does_not_change_releases() {
        // A batcher fed recycled request Vecs must release byte-identical
        // batches to one that allocates fresh storage every time.
        let policy = BatchPolicy::new(2, 1_000);
        let mut plain = Batcher::new(policy);
        let mut pooled = Batcher::new(policy);
        let mut spent: Option<Vec<Request>> = None;
        for t in 0..20u64 {
            let a = plain.offer(t);
            let b = pooled.offer(t);
            match (a, b) {
                (Some(a), Some(b)) => {
                    assert_eq!(a.requests, b.requests);
                    assert_eq!(a.released_ns, b.released_ns);
                    if let Some(v) = spent.take() {
                        pooled.recycle(v);
                    }
                    spent = Some(b.requests);
                }
                (None, None) => {}
                _ => panic!("release points diverged at t={t}"),
            }
        }
        assert_eq!(plain.batches_released, pooled.batches_released);
    }

    #[test]
    fn drr_scratch_reuse_is_identical_across_releases() {
        // Two releases through the persistent scheduler: a fully drained
        // DrrQueue is pristine, so the second batch must interleave
        // exactly like the first.
        let mut b = DrrBatcher::new(BatchPolicy::new(4, 1_000), vec![1, 1]);
        let mut orders = Vec::new();
        for round in 0..2u64 {
            assert!(b.offer(0, round).is_none());
            assert!(b.offer(0, round).is_none());
            assert!(b.offer(0, round).is_none());
            let batch = b.offer(1, round).expect("size trigger");
            let pos: Vec<u64> =
                batch.requests.iter().map(|r| r.id % 4).collect();
            orders.push(pos);
            b.recycle(batch.requests);
        }
        assert_eq!(orders[0], vec![0, 3, 1, 2]);
        assert_eq!(orders[0], orders[1]);
    }

    #[test]
    fn drr_batcher_deadline_release() {
        let mut b =
            DrrBatcher::new(BatchPolicy::new(16, 1_000), vec![1, 1]);
        b.offer(0, 0);
        b.offer(1, 500);
        assert_eq!(b.pending(), 2);
        assert!(b.poll(999).is_none());
        let batch = b.poll(1_000).expect("deadline trigger");
        assert_eq!(batch.len(), 2);
        assert_eq!(b.pending(), 0);
        assert!(b.flush(2_000).is_none());
    }

    /// Property: no released request ever waits longer than max_wait (when
    /// poll is called at the deadline) and no batch exceeds max_batch.
    #[test]
    fn prop_batcher_invariants() {
        use crate::util::propcheck::{check, Config};
        check("batcher_invariants", Config::default(), |c| {
            let max_batch = c.rng.range_u64(1, 16) as usize;
            let max_wait = c.rng.range_u64(1, 10_000);
            let mut b = Batcher::new(BatchPolicy::new(max_batch, max_wait));
            let mut now = 0u64;
            let mut released = 0u64;
            for _ in 0..c.sized_range(1, 300) {
                now += c.rng.below(max_wait);
                // fire deadline first, as a real event loop would
                if let Some(d) = b.deadline() {
                    if d <= now {
                        let batch = b.poll(d).ok_or("deadline missed")?;
                        released += batch.len() as u64;
                        for r in &batch.requests {
                            if d - r.arrival_ns > max_wait {
                                return Err("overwaited".into());
                            }
                        }
                    }
                }
                if let Some(batch) = b.offer(now) {
                    released += batch.len() as u64;
                    if batch.len() > max_batch {
                        return Err("oversized batch".into());
                    }
                }
            }
            if let Some(batch) = b.flush(now) {
                released += batch.len() as u64;
            }
            if released != b.requests_seen {
                return Err(format!(
                    "lost requests: {released} of {}",
                    b.requests_seen
                ));
            }
            Ok(())
        });
    }
}
