//! The Split-Et-Impera coordinator (paper Fig. 1): saliency-driven split
//! search, communication-aware scenario simulation, QoS suggestion, the
//! closed-loop multi-client streaming engine ([`streaming`]) and the
//! serving driver. This is the L3 system contribution; it owns the event
//! loop and drives the netsim plus whichever [`crate::runtime`] inference
//! backend is loaded (PJRT artifacts or the hermetic analytic reference).

pub mod batcher;
pub mod corruption;
pub mod hil;
pub mod placement;
pub mod qos;
pub mod saliency;
pub mod scenario;
pub mod serve;
pub mod streaming;
pub mod suggest;
pub mod sweep;
pub mod workload;

pub use placement::{
    place, FleetDevice, FleetSpec, FleetStream, PlacementOutcome,
    PlacementPlan, StreamVerdict,
};
pub use qos::QosRequirements;
pub use saliency::CsCurve;
pub use scenario::{
    run_scenario, simulate_latency, ModelScale, ScenarioConfig, ScenarioKind,
    ScenarioReport,
};
pub use serve::{serve, ServeReport};
pub use streaming::{
    pooled_stream, run_stream, StreamConfig, StreamReport,
};
pub use suggest::{best, rank_configurations, suggest, Suggestion};
pub use sweep::{
    pooled_scenario, run_sweep, SweepJob, SweepMode, SweepPoint, SweepReport,
    SweepSpec,
};
