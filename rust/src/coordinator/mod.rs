//! The Split-Et-Impera coordinator (paper Fig. 1): saliency-driven split
//! search, communication-aware scenario simulation, QoS suggestion, the
//! closed-loop multi-client streaming engine ([`streaming`]) and the
//! serving driver. This is the L3 system contribution; it owns the event
//! loop and drives the netsim plus whichever [`crate::runtime`] inference
//! backend is loaded (PJRT artifacts or the hermetic analytic reference).

pub mod adaptive;
pub mod batcher;
pub mod bound;
pub mod corruption;
pub mod drr;
pub mod hil;
pub mod placement;
pub mod qos;
pub mod saliency;
pub mod scenario;
pub mod search;
pub mod serve;
pub mod streaming;
pub mod suggest;
pub mod sweep;
pub mod workload;

pub use adaptive::{
    run_adaptive_comparison, AdaptiveConfig, AdaptiveReport, ChainCache,
    ControllerConfig, PolicyOutcome, SwitchPolicy,
};
pub use bound::{job_bound_ns, latency_bound_ns};
pub use placement::{
    place, FleetDevice, FleetSpec, FleetStream, PlacementOutcome,
    PlacementPlan, StreamVerdict,
};
pub use qos::QosRequirements;
pub use saliency::CsCurve;
pub use scenario::{
    run_scenario, run_scenario_with_queue, simulate_latency, ModelScale,
    ScenarioConfig, ScenarioKind, ScenarioReport,
};
pub use serve::{
    serve, serve_clients, serve_clients_latency, serve_with_queue,
    HeteroServeReport, ServeReport,
};
pub use streaming::{
    parse_clients_spec, pooled_hetero_stream, pooled_stream,
    pooled_stream_with_queue, run_hetero_stream, run_stream,
    run_stream_with_queue, ClientOutcome, ClientSpec, Fairness,
    HeteroStreamReport, MultiStreamConfig, StreamConfig, StreamFrameRecord,
    StreamReport,
};
pub use search::{run_search, SearchReport, SearchSpec};
pub use suggest::{
    best, rank_configurations, rank_configurations_cached, suggest,
    Suggestion,
};
pub use sweep::{
    pooled_scenario, run_sweep, run_sweep_with, ClientMix, EngineCache,
    SweepJob, SweepMode, SweepPoint, SweepReport, SweepScheduler, SweepSpec,
};
