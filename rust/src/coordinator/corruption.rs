//! UDP-loss corruption model: map the byte ranges the netsim reports as
//! lost onto the transmitted tensor (paper Fig. 4-left: accuracy vs loss
//! rate under UDP, "no error checking and recovery services are provided").
//!
//! Lost bytes are zeroed — the receiver materialises the frame buffer
//! zero-initialised and copies in the datagrams that did arrive.

use crate::netsim::transfer::TransferResult;
use crate::tensor::Tensor;

/// Zero the byte ranges of row `row` of `batch` (shape [B, ...]) that were
/// lost transferring that row's payload.
pub fn corrupt_row(batch: &mut Tensor, row: usize, lost: &[(u64, u32)]) {
    let rows = batch.shape()[0];
    assert!(row < rows, "row {row} out of {rows}");
    let row_bytes = batch.byte_len() / rows as u64;
    for &(off, len) in lost {
        let clipped = (off + len as u64).min(row_bytes);
        if off >= row_bytes || clipped <= off {
            continue;
        }
        batch.zero_byte_range(
            row as u64 * row_bytes + off,
            (clipped - off) as u32,
        );
    }
}

/// Corrupt a whole single-payload tensor (batch of 1 / latent transfer).
pub fn corrupt(t: &mut Tensor, result: &TransferResult) {
    for &(off, len) in result.lost_ranges() {
        t.zero_byte_range(off, len);
    }
}

/// When the simulated wire payload is larger than the actual tensor (the
/// paper-scale VGG16@224 volumetrics vs our slim tensors), map lost ranges
/// proportionally onto the tensor so the *fraction* of corrupted bytes is
/// preserved.
pub fn corrupt_scaled(t: &mut Tensor, lost: &[(u64, u32)], wire_len: u64) {
    let t_len = t.byte_len();
    if wire_len == 0 || t_len == 0 {
        return;
    }
    let scale = t_len as f64 / wire_len as f64;
    for &(off, len) in lost {
        let s = (off as f64 * scale).floor() as u64;
        let e = ((off + len as u64) as f64 * scale).ceil() as u64;
        let e = e.min(t_len);
        if e > s {
            t.zero_byte_range(s, (e - s) as u32);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ones(shape: Vec<usize>) -> Tensor {
        let n = shape.iter().product();
        Tensor::new(shape, vec![1.0; n]).unwrap()
    }

    #[test]
    fn corrupt_row_only_touches_that_row() {
        let mut b = ones(vec![2, 4]); // rows of 16 bytes
        corrupt_row(&mut b, 1, &[(0, 8)]);
        assert_eq!(b.data(), &[1., 1., 1., 1., 0., 0., 1., 1.]);
    }

    #[test]
    fn corrupt_row_clips_to_row() {
        let mut b = ones(vec![2, 2]); // rows of 8 bytes
        corrupt_row(&mut b, 0, &[(4, 1000)]);
        assert_eq!(b.data(), &[1., 0., 1., 1.]);
    }

    #[test]
    fn corrupt_row_ignores_ranges_past_row() {
        let mut b = ones(vec![2, 2]);
        corrupt_row(&mut b, 0, &[(8, 4)]);
        assert_eq!(b.data(), &[1., 1., 1., 1.]);
    }

    #[test]
    fn scaled_preserves_fraction() {
        let mut t = ones(vec![1000]); // 4000 bytes
        // wire is 40000 bytes; lose 10% of it in one range
        corrupt_scaled(&mut t, &[(0, 4000)], 40_000);
        let zeros = t.data().iter().filter(|v| **v == 0.0).count();
        assert!((zeros as f64 / 1000.0 - 0.1).abs() < 0.01, "{zeros}");
    }

    #[test]
    fn scaled_handles_tail_range() {
        let mut t = ones(vec![10]);
        corrupt_scaled(&mut t, &[(39_000, 1000)], 40_000);
        assert_eq!(t.data()[9], 0.0);
        assert_eq!(t.data()[0], 1.0);
    }

    #[test]
    fn scaled_zero_wire_is_noop() {
        let mut t = ones(vec![4]);
        corrupt_scaled(&mut t, &[(0, 4)], 0);
        assert_eq!(t.data(), &[1.0; 4]);
    }
}
