//! Design-space sweep engine (paper Sec. IV: "rapid evaluation of different
//! neural network rearrangements" under QoS constraints).
//!
//! A [`SweepSpec`] declares a cartesian grid over the paper's design axes —
//! network condition (channel preset, propagation latency, loss rate),
//! transport protocol (TCP/UDP), scenario kind (LC / RC / SC×split /
//! MC×cut-chain via `cut_chains`), model scale, the serving-load axes
//! (concurrent `clients`, per-client `offered_fps`), and the device
//! tier-chain axis (`tiers`: sensor → edge → cloud placements) — plus the
//! fixed evaluation parameters (frames, seeds, batching policy, QoS
//! bounds). Named heterogeneous tenant mixes (`client_mixes`) add
//! multi-tenant grid points that run on
//! [`super::streaming::run_hetero_stream`] (per-client arch/placement/
//! rate, DRR fairness, admission control).
//! Every grid point executes on the closed-loop streaming engine
//! ([`super::streaming`]), so overloaded points report queueing latency
//! and saturated throughput instead of an open-loop fiction.
//! [`SweepSpec::expand`] turns the grid into
//! an ordered job list and [`run_sweep`] executes it on a deterministic
//! worker pool: jobs are pulled from a shared counter, every job derives
//! its simulation seeds from the spec alone, and results are keyed by job
//! index — so the resulting [`SweepReport`] is **byte-identical regardless
//! of thread count**. The reduction computes the accuracy-vs-latency Pareto
//! frontier ([`crate::report::pareto`]) and per-constraint satisfaction
//! counts, and serializes to JSON/CSV via [`crate::util::json`] and
//! [`crate::report::csv`].
//!
//! Inference backends are not `Send` (executables are `Rc`-cached), so each
//! worker thread opens its own backend through the caller's factory; the
//! hermetic analytic backend makes that cheap and bit-reproducible.
//!
//! # Example: declare and expand a grid
//!
//! ```
//! use sei::coordinator::sweep::SweepSpec;
//!
//! let spec = SweepSpec::from_json(r#"{
//!     "name": "doc-grid",
//!     "scenarios": ["rc", "sc@13"],
//!     "protocols": ["tcp", "udp"],
//!     "loss_rates": [0.0, 0.05],
//!     "frames": 8,
//!     "fps": 20
//! }"#).unwrap();
//! let jobs = spec.expand().unwrap();
//! // 2 scenarios x 2 protocols x 2 loss rates on the default gigabit
//! // channel at slim scale:
//! assert_eq!(jobs.len(), 8);
//! assert_eq!(jobs[0].index, 0);
//! ```

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::Sender;
use std::sync::Mutex;

use anyhow::{anyhow, bail, Context, Result};

use super::batcher::BatchPolicy;
use super::qos::QosRequirements;
use super::scenario::{
    run_scenario, ModelScale, ScenarioConfig, ScenarioKind, ScenarioReport,
};
use super::streaming::{
    parse_client_entries, pooled_hetero_stream, pooled_stream_with_queue,
    ClientSpec, Fairness, MultiStreamConfig, StreamConfig,
};
use crate::data::Dataset;
use crate::model::{Arch, DeviceProfile};
use crate::netsim::event::{QueueKind, SimTime};
use crate::netsim::transfer::{NetworkConfig, Protocol};
use crate::report::csv::Csv;
use crate::report::pareto::pareto_frontier;
use crate::runtime::InferenceBackend;
use crate::util::json::{self, Json};
use crate::util::table;

/// What each grid point measures.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SweepMode {
    /// Full pipeline per frame: real inference + channel simulation, so
    /// every point reports measured accuracy *and* latency.
    Full,
    /// Pure channel + compute-time simulation (no model execution) — the
    /// paper-scale Fig. 3 style sweep where accuracy is not re-measured.
    LatencyOnly,
}

impl SweepMode {
    pub fn parse(s: &str) -> Result<SweepMode> {
        match s.to_ascii_lowercase().as_str() {
            "full" => Ok(SweepMode::Full),
            "latency" | "latency-only" => Ok(SweepMode::LatencyOnly),
            other => bail!("unknown sweep mode '{other}' (full | latency)"),
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            SweepMode::Full => "full",
            SweepMode::LatencyOnly => "latency",
        }
    }
}

/// Declarative description of a design-space sweep: the cartesian grid
/// axes plus the fixed evaluation parameters shared by every point.
///
/// The JSON schema accepted by [`SweepSpec::from_json`] (and emitted by
/// [`SweepSpec::to_json`]) mirrors the field names; only `scenarios`,
/// `protocols` and `loss_rates` are required, everything else defaults as
/// in [`SweepSpec::new`]. A `fps` key is accepted as sugar that sets both
/// `frame_period_ns` and `max_latency_ms` from the frame rate.
#[derive(Clone, Debug)]
pub struct SweepSpec {
    pub name: String,
    pub mode: SweepMode,
    // -- grid axes --------------------------------------------------------
    pub scenarios: Vec<ScenarioKind>,
    pub protocols: Vec<Protocol>,
    /// Channel presets: `"gigabit" | "fast-ethernet" | "wifi"`.
    pub channels: Vec<String>,
    /// Propagation-latency overrides, µs; empty = each preset's default.
    pub latencies_us: Vec<f64>,
    pub loss_rates: Vec<f64>,
    pub scales: Vec<ModelScale>,
    /// Architectures under test; each grid point runs against a backend
    /// serving that arch (split ids are arch-relative cut indices).
    pub archs: Vec<Arch>,
    /// Concurrent client streams sharing channel + server per point.
    pub clients: Vec<usize>,
    /// Per-client offered frame rates; empty = one point driven by
    /// `frame_period_ns` instead. Rates must be finite and > 0.
    pub offered_fps: Vec<f64>,
    /// Device tier chains (sensor side first), each a list of
    /// [`DeviceProfile::parse`] specs; empty = the single `[edge, server]`
    /// chain. MC scenarios pair only with chains of matching length
    /// (`cuts + 1`); LC/RC/SC run on any chain (first + last tier).
    pub tiers: Vec<Vec<String>>,
    /// Ordered cut chains added to the scenario axis as
    /// [`ScenarioKind::Mc`] entries (strictly increasing split ids).
    pub cut_chains: Vec<Vec<usize>>,
    /// Named heterogeneous tenant mixes. Each mix adds one grid point per
    /// channel × tier combination, executed on the multi-tenant engine
    /// ([`super::streaming::run_hetero_stream`]: DRR fairness, admission
    /// control, indexed event calendar) instead of the homogeneous
    /// clients × offered_fps axes — the mix pins every tenant's scenario,
    /// arch, scale, rate, frame count and per-tenant QoS itself, so the
    /// homogeneous scenario / scale / arch / load axes do not multiply it.
    pub client_mixes: Vec<ClientMix>,
    /// Explicit per-hop channel specs (sensor side first), each a
    /// [`NetworkConfig::parse`] string (`wifi:udp:loss=0.01`,
    /// `gigabit:tcp`, `radio@5e7+3000000`). Empty = the channel chain is
    /// derived from the `protocols` × `channels` × `latencies_us` ×
    /// `loss_rates` axes as usual. Non-empty, those four axes must be
    /// single-valued (the hop list replaces them); a multi-entry list must
    /// match every swept scenario's hop count. Any `seed=` segments are
    /// overridden by the sweep's own seed schedule.
    pub hop_nets: Vec<String>,
    /// Time-varying channel schedules swept as a grid axis. Each entry is
    /// a hop-trace spec (`"hop0=wifi>congested@2s"`, see
    /// [`crate::netsim::trace::parse_hop_traces`]) attached on top of the
    /// point's channel chain; empty = one untraced value. Traces multiply
    /// the grid as the innermost axis, so untraced specs keep their
    /// stride, and a constant trace reproduces the untraced point
    /// byte-identically.
    pub traces: Vec<String>,
    // -- fixed parameters -------------------------------------------------
    pub edge: String,
    pub server: String,
    /// Dataset split driving full-mode points (`"train" | "test" | "ice"`).
    pub dataset: String,
    /// Frames simulated per (point, seed).
    pub frames: usize,
    /// Independent simulation repetitions pooled into each point.
    pub seeds_per_point: usize,
    /// Base seed; repetition `s` of every point runs at `seed + s`.
    pub seed: u64,
    /// Frame inter-arrival time (conveyor speed); 0 = back-to-back.
    pub frame_period_ns: SimTime,
    /// QoS latency bound, ms (0 = unconstrained).
    pub max_latency_ms: f64,
    /// QoS accuracy bound in [0, 1] (0 = unconstrained).
    pub min_accuracy: f64,
    /// Fraction of frames that must meet the latency bound, in (0, 1].
    pub min_hit_rate: f64,
    /// Server-side dynamic batching: maximum batch size (1 = unbatched).
    pub max_batch: usize,
    /// Server-side dynamic batching: deadline for a partial batch, µs.
    pub batch_wait_us: f64,
    /// Bound-guided two-phase evaluation (default off): when the spec
    /// sets a latency deadline, skip the full discrete-event run for
    /// points whose admissible analytic lower bound
    /// ([`super::bound::job_bound_ns`]) already exceeds it — such points
    /// are *provably* QoS-infeasible (every frame would miss). Skipped
    /// points stay in the report (flagged, latency columns carrying the
    /// bound, no accuracy) and are counted in [`SweepReport::skipped`].
    pub prefilter: bool,
    /// Event-queue backend every point simulates on (`"queue"` key:
    /// `"wheel" | "calendar" | "linear"`). Purely a performance choice —
    /// all backends pop events in the identical deterministic order.
    pub queue: QueueKind,
}

/// One expanded grid point, in deterministic expansion order.
#[derive(Clone, Debug)]
pub struct SweepJob {
    pub index: usize,
    pub kind: ScenarioKind,
    pub protocol: Protocol,
    pub channel: String,
    pub latency_us: Option<f64>,
    pub loss: f64,
    pub scale: ModelScale,
    pub arch: Arch,
    pub clients: usize,
    /// Per-client offered rate; `None` = use the spec's `frame_period_ns`.
    pub offered_fps: Option<f64>,
    /// Device tier chain of this point (sensor side first).
    pub tiers: Vec<String>,
    /// Explicit per-hop channel specs (empty = derived from the
    /// protocol/channel/latency/loss fields above).
    pub hop_nets: Vec<String>,
    /// Hop-trace spec attached to this point's channels (`None` =
    /// untraced constant channels).
    pub trace: Option<String>,
    /// `Some(i)` = this point runs `spec.client_mixes[i]` on the
    /// multi-tenant engine; the scenario / arch / scale columns then label
    /// the mix's first tenant and `clients` counts the whole mix.
    pub mix: Option<usize>,
}

/// A named heterogeneous tenant mix swept as one grid point per channel ×
/// tier combination (see [`SweepSpec::client_mixes`]).
#[derive(Clone, Debug)]
pub struct ClientMix {
    pub name: String,
    pub clients: Vec<ClientSpec>,
}

/// Resolve a channel-preset name into its [`NetworkConfig`].
pub fn channel_preset(
    name: &str,
    protocol: Protocol,
    loss: f64,
    seed: u64,
) -> Result<NetworkConfig> {
    Ok(match name {
        "gigabit" => NetworkConfig::gigabit(protocol, loss, seed),
        "fast-ethernet" => NetworkConfig::fast_ethernet(protocol, loss, seed),
        "wifi" => NetworkConfig::wifi(protocol, loss, seed),
        other => bail!(
            "unknown channel preset '{other}' (gigabit | fast-ethernet | wifi)"
        ),
    })
}

impl SweepSpec {
    /// A single-point RC/TCP/gigabit spec with the default evaluation
    /// parameters; callers widen the axes they want to explore.
    pub fn new(name: &str) -> SweepSpec {
        SweepSpec {
            name: name.to_string(),
            mode: SweepMode::Full,
            scenarios: vec![ScenarioKind::Rc],
            protocols: vec![Protocol::Tcp],
            channels: vec!["gigabit".to_string()],
            latencies_us: Vec::new(),
            loss_rates: vec![0.0],
            scales: vec![ModelScale::Slim],
            archs: vec![Arch::Vgg16],
            clients: vec![1],
            offered_fps: Vec::new(),
            tiers: Vec::new(),
            cut_chains: Vec::new(),
            client_mixes: Vec::new(),
            hop_nets: Vec::new(),
            traces: Vec::new(),
            edge: "edge-gpu".to_string(),
            server: "server-gpu".to_string(),
            dataset: "test".to_string(),
            frames: 64,
            seeds_per_point: 1,
            seed: 42,
            frame_period_ns: 0,
            max_latency_ms: 0.0,
            min_accuracy: 0.0,
            min_hit_rate: 1.0,
            max_batch: 1,
            batch_wait_us: 0.0,
            prefilter: false,
            queue: QueueKind::Calendar,
        }
    }

    /// The QoS requirements every point is checked against.
    pub fn qos(&self) -> QosRequirements {
        let mut q = QosRequirements::none();
        if self.max_latency_ms > 0.0 {
            q.max_latency_ns = Some((self.max_latency_ms * 1e6) as SimTime);
        }
        if self.min_accuracy > 0.0 {
            q = q.and_accuracy(self.min_accuracy);
        }
        q.min_hit_rate = self.min_hit_rate;
        q
    }

    /// The server-side batching policy every point serves under.
    pub fn batch_policy(&self) -> BatchPolicy {
        BatchPolicy::from_micros(self.max_batch, self.batch_wait_us)
            .expect("batching parameters validated by SweepSpec::expand")
    }

    /// Expand the grid into its ordered job list. Axis order (outermost
    /// first): scenario (declared kinds, then one MC entry per
    /// `cut_chains` element), protocol, channel, latency, loss, scale,
    /// arch, clients, offered_fps, tiers, traces — so a caller can index
    /// `jobs` arithmetically; newer inner axes (arch, load, tiers,
    /// traces) default to a single value, preserving the stride of older
    /// specs. The only
    /// non-cartesian rule: an MC scenario pairs exclusively with tier
    /// chains of matching length (`cuts + 1`), and it is an error for an
    /// MC scenario to match none of them.
    pub fn expand(&self) -> Result<Vec<SweepJob>> {
        if self.scenarios.is_empty()
            && self.cut_chains.is_empty()
            && self.client_mixes.is_empty()
        {
            bail!("sweep spec '{}' has no scenarios", self.name);
        }
        if self.protocols.is_empty() {
            bail!("sweep spec '{}' has no protocols", self.name);
        }
        if self.channels.is_empty() {
            bail!("sweep spec '{}' has no channels", self.name);
        }
        if self.loss_rates.is_empty() {
            bail!("sweep spec '{}' has no loss_rates", self.name);
        }
        if self.scales.is_empty() {
            bail!("sweep spec '{}' has no scales", self.name);
        }
        if self.archs.is_empty() {
            bail!("sweep spec '{}' has no archs", self.name);
        }
        if self.frames == 0 {
            bail!("sweep spec '{}' needs frames >= 1", self.name);
        }
        if self.seeds_per_point == 0 {
            bail!("sweep spec '{}' needs seeds_per_point >= 1", self.name);
        }
        for &l in &self.loss_rates {
            if !(0.0..1.0).contains(&l) {
                bail!(
                    "sweep spec '{}': loss rate {l} outside [0, 1)",
                    self.name
                );
            }
        }
        for &us in &self.latencies_us {
            if !us.is_finite() || us < 0.0 {
                bail!(
                    "sweep spec '{}': latency {us} µs must be a \
                     non-negative number",
                    self.name
                );
            }
        }
        if self.clients.is_empty() {
            bail!("sweep spec '{}' has no clients", self.name);
        }
        for &c in &self.clients {
            if c == 0 {
                bail!("sweep spec '{}': clients must be >= 1", self.name);
            }
        }
        for &fps in &self.offered_fps {
            // The 1e9 cap matches QosRequirements::with_fps: a rate above
            // 1 GHz truncates to a 0 ns frame period, silently flipping
            // the point to closed-loop source semantics.
            if !fps.is_finite() || fps <= 0.0 || fps > 1e9 {
                bail!(
                    "sweep spec '{}': offered_fps must be a positive \
                     number <= 1e9, got {fps}",
                    self.name
                );
            }
        }
        if self.max_batch == 0 {
            bail!("sweep spec '{}': max_batch must be >= 1", self.name);
        }
        if !self.batch_wait_us.is_finite() || self.batch_wait_us < 0.0 {
            bail!(
                "sweep spec '{}': batch_wait_us must be a non-negative \
                 number, got {}",
                self.name,
                self.batch_wait_us
            );
        }
        if !self.min_hit_rate.is_finite()
            || self.min_hit_rate <= 0.0
            || self.min_hit_rate > 1.0
        {
            bail!(
                "sweep spec '{}': min_hit_rate must be in (0, 1], got {}",
                self.name,
                self.min_hit_rate
            );
        }
        for c in &self.channels {
            channel_preset(c, Protocol::Tcp, 0.0, 0)?;
        }
        // Every device spec — the two-tier defaults and every chain
        // element — goes through the one shared parse path.
        for name in [&self.edge, &self.server] {
            DeviceProfile::parse(name)?;
        }
        for chain in &self.tiers {
            if chain.len() < 2 {
                bail!(
                    "sweep spec '{}': tier chain {chain:?} needs at least \
                     2 devices",
                    self.name
                );
            }
            for name in chain {
                DeviceProfile::parse(name)?;
            }
        }
        for cuts in &self.cut_chains {
            if !crate::model::is_ordered_chain(cuts) {
                bail!(
                    "sweep spec '{}': cut chain {cuts:?} must be non-empty \
                     and strictly increasing",
                    self.name
                );
            }
        }
        // Tenant mixes are validated eagerly with the same rigor as the
        // homogeneous axes: an unservable mix fails here, not inside a
        // worker thread mid-sweep.
        for (mi, mix) in self.client_mixes.iter().enumerate() {
            if mix.clients.is_empty() {
                bail!(
                    "sweep spec '{}': client_mixes[{mi}] ('{}') has no \
                     clients",
                    self.name,
                    mix.name
                );
            }
            for (ci, c) in mix.clients.iter().enumerate() {
                if c.frames == 0 || c.weight == 0 {
                    bail!(
                        "sweep spec '{}': client_mixes[{mi}] ('{}') client \
                         {ci} needs frames >= 1 and weight >= 1",
                        self.name,
                        mix.name
                    );
                }
                if let ScenarioKind::Mc { cuts } = &c.kind {
                    if !crate::model::is_ordered_chain(cuts) {
                        bail!(
                            "sweep spec '{}': client_mixes[{mi}] ('{}') \
                             client {ci}: cut chain {cuts:?} must be \
                             non-empty and strictly increasing",
                            self.name,
                            mix.name
                        );
                    }
                    let n = crate::model::split_points(&c.arch.full_network())
                        .len();
                    if cuts.iter().any(|&x| x + 1 >= n) {
                        bail!(
                            "sweep spec '{}': client_mixes[{mi}] ('{}') \
                             client {ci}: cut chain {cuts:?} out of range \
                             for {} ({} cut points, valid 0..={})",
                            self.name,
                            mix.name,
                            c.arch.as_str(),
                            n,
                            n.saturating_sub(2),
                        );
                    }
                }
            }
        }
        // Explicit per-hop channels go through the shared spec grammar and
        // replace the four channel-derivation axes, which must then be
        // single-valued (the grid would otherwise silently ignore them).
        let hop0 = match self.hop_nets.first() {
            Some(first) => {
                for s in &self.hop_nets {
                    NetworkConfig::parse(s).with_context(|| {
                        format!("sweep spec '{}': hop_nets entry", self.name)
                    })?;
                }
                if self.protocols.len() > 1
                    || self.channels.len() > 1
                    || self.loss_rates.len() > 1
                    || self.latencies_us.len() > 1
                {
                    bail!(
                        "sweep spec '{}': hop_nets pins every hop's channel \
                         — drop the multi-valued protocols / channels / \
                         loss_rates / latencies_us axes",
                        self.name
                    );
                }
                Some((first.clone(), NetworkConfig::parse(first)?))
            }
            None => None,
        };
        let scenarios = self.effective_scenarios();
        if self.hop_nets.len() > 1 {
            for kind in &scenarios {
                let hops = kind.tiers_needed().saturating_sub(1);
                if hops != self.hop_nets.len() {
                    bail!(
                        "sweep spec '{}': scenario {kind} has {hops} \
                         inter-tier hops but hop_nets lists {} channels \
                         (give one per hop, or a single template)",
                        self.name,
                        self.hop_nets.len()
                    );
                }
            }
        }
        // Trace specs parse eagerly and must target hops every swept
        // scenario actually has (mix points are checked against their
        // tier chains inside the mix loop below).
        let mut trace_max_hop: Option<usize> = None;
        for t in &self.traces {
            let entries = crate::netsim::trace::parse_hop_traces(t)
                .with_context(|| {
                    format!("sweep spec '{}': traces entry", self.name)
                })?;
            let max_hop =
                entries.iter().map(|(h, _)| *h).max().unwrap_or(0);
            trace_max_hop =
                Some(trace_max_hop.unwrap_or(0).max(max_hop));
            for kind in &scenarios {
                let hops = kind.tiers_needed().saturating_sub(1).max(1);
                if max_hop >= hops {
                    bail!(
                        "sweep spec '{}': trace '{t}' targets hop{max_hop} \
                         but scenario {kind} has only {hops} inter-tier \
                         hop(s)",
                        self.name
                    );
                }
            }
        }
        // MC cut ids must be in range for every arch on the grid — an
        // invalid spec fails here, not inside a worker thread mid-sweep.
        // (Per-arch cut-mark counts are scale-independent: the slim and
        // paper-scale networks mark the same split points.)
        if scenarios.iter().any(|k| matches!(k, ScenarioKind::Mc { .. })) {
            let cut_counts: Vec<(Arch, usize)> = self
                .archs
                .iter()
                .map(|&a| {
                    (a, crate::model::split_points(&a.full_network()).len())
                })
                .collect();
            for kind in &scenarios {
                let ScenarioKind::Mc { cuts } = kind else { continue };
                for &(arch, n) in &cut_counts {
                    if cuts.iter().any(|&c| c + 1 >= n) {
                        bail!(
                            "sweep spec '{}': cut chain {cuts:?} out of \
                             range for {} ({} cut points, valid 0..={})",
                            self.name,
                            arch.as_str(),
                            n,
                            n.saturating_sub(2),
                        );
                    }
                }
            }
        }
        let tier_chains = self.effective_tiers();
        let lats: Vec<Option<f64>> = if self.latencies_us.is_empty() {
            vec![None]
        } else {
            self.latencies_us.iter().map(|&l| Some(l)).collect()
        };
        let rates: Vec<Option<f64>> = if self.offered_fps.is_empty() {
            vec![None]
        } else {
            self.offered_fps.iter().map(|&f| Some(f)).collect()
        };
        let mut jobs = Vec::new();
        for kind in &scenarios {
            let before = jobs.len();
            for &protocol in &self.protocols {
                for channel in &self.channels {
                    for &latency_us in &lats {
                        for &loss in &self.loss_rates {
                            for &scale in &self.scales {
                                for &arch in &self.archs {
                                    for &clients in &self.clients {
                                        for &offered_fps in &rates {
                                            for chain in &tier_chains {
                                                // MC pairs only with tier
                                                // chains of matching
                                                // length; other kinds run
                                                // on any chain.
                                                if let ScenarioKind::Mc {
                                                    cuts,
                                                } = kind
                                                {
                                                    if chain.len()
                                                        != cuts.len() + 1
                                                    {
                                                        continue;
                                                    }
                                                }
                                                // With explicit hop_nets,
                                                // the labelling columns
                                                // come from hop 0 (the
                                                // sensor uplink).
                                                jobs.push(match &hop0 {
                                                    Some((spec0, net0)) => {
                                                        SweepJob {
                                                            index: jobs.len(),
                                                            kind: kind.clone(),
                                                            protocol:
                                                                net0.protocol,
                                                            channel: spec0
                                                                .clone(),
                                                            latency_us: None,
                                                            loss: net0
                                                                .loss_rate,
                                                            scale,
                                                            arch,
                                                            clients,
                                                            offered_fps,
                                                            tiers: chain
                                                                .clone(),
                                                            hop_nets: self
                                                                .hop_nets
                                                                .clone(),
                                                            trace: None,
                                                            mix: None,
                                                        }
                                                    }
                                                    None => SweepJob {
                                                        index: jobs.len(),
                                                        kind: kind.clone(),
                                                        protocol,
                                                        channel: channel
                                                            .clone(),
                                                        latency_us,
                                                        loss,
                                                        scale,
                                                        arch,
                                                        clients,
                                                        offered_fps,
                                                        tiers: chain.clone(),
                                                        hop_nets: Vec::new(),
                                                        trace: None,
                                                        mix: None,
                                                    },
                                                });
                                            }
                                        }
                                    }
                                }
                            }
                        }
                    }
                }
            }
            if jobs.len() == before {
                bail!(
                    "sweep spec '{}': scenario {kind} has no compatible \
                     tier chain (MC with k cuts needs a {}-tier chain)",
                    self.name,
                    kind.tiers_needed(),
                );
            }
        }
        // Tenant-mix points ride only the channel and tier axes: the mix
        // itself pins each tenant's scenario / arch / scale / rate, so the
        // homogeneous scenario × scale × arch × load axes do not multiply
        // it. The labelling columns come from the mix's first tenant.
        for (mi, mix) in self.client_mixes.iter().enumerate() {
            let before = jobs.len();
            for &protocol in &self.protocols {
                for channel in &self.channels {
                    for &latency_us in &lats {
                        for &loss in &self.loss_rates {
                            for chain in &tier_chains {
                                // An MC tenant pairs only with chains of
                                // matching length, exactly like the
                                // homogeneous MC rule.
                                let mc_mismatch =
                                    mix.clients.iter().any(|c| match &c.kind {
                                        ScenarioKind::Mc { cuts } => {
                                            chain.len() != cuts.len() + 1
                                        }
                                        _ => false,
                                    });
                                if mc_mismatch {
                                    continue;
                                }
                                if let Some(mh) = trace_max_hop {
                                    if mh + 1 >= chain.len() {
                                        bail!(
                                            "sweep spec '{}': \
                                             client_mixes[{mi}] ('{}') \
                                             pairs with a {}-tier chain \
                                             but a traces entry targets \
                                             hop{mh}",
                                            self.name,
                                            mix.name,
                                            chain.len()
                                        );
                                    }
                                }
                                if self.hop_nets.len() > 1
                                    && self.hop_nets.len() != chain.len() - 1
                                {
                                    bail!(
                                        "sweep spec '{}': client_mixes[{mi}] \
                                         ('{}') pairs with a {}-tier chain \
                                         but hop_nets lists {} channels \
                                         (the multi-tenant engine needs one \
                                         per physical hop)",
                                        self.name,
                                        mix.name,
                                        chain.len(),
                                        self.hop_nets.len()
                                    );
                                }
                                let lead = &mix.clients[0];
                                jobs.push(match &hop0 {
                                    Some((spec0, net0)) => SweepJob {
                                        index: jobs.len(),
                                        kind: lead.kind.clone(),
                                        protocol: net0.protocol,
                                        channel: spec0.clone(),
                                        latency_us: None,
                                        loss: net0.loss_rate,
                                        scale: lead.scale,
                                        arch: lead.arch,
                                        clients: mix.clients.len(),
                                        offered_fps: None,
                                        tiers: chain.clone(),
                                        hop_nets: self.hop_nets.clone(),
                                        trace: None,
                                        mix: Some(mi),
                                    },
                                    None => SweepJob {
                                        index: jobs.len(),
                                        kind: lead.kind.clone(),
                                        protocol,
                                        channel: channel.clone(),
                                        latency_us,
                                        loss,
                                        scale: lead.scale,
                                        arch: lead.arch,
                                        clients: mix.clients.len(),
                                        offered_fps: None,
                                        tiers: chain.clone(),
                                        hop_nets: Vec::new(),
                                        trace: None,
                                        mix: Some(mi),
                                    },
                                });
                            }
                        }
                    }
                }
            }
            if jobs.len() == before {
                bail!(
                    "sweep spec '{}': client_mixes[{mi}] ('{}') has no \
                     compatible tier chain (an MC tenant with k cuts needs \
                     a (k+1)-tier chain)",
                    self.name,
                    mix.name
                );
            }
        }
        // The trace axis multiplies the grid as the innermost axis (trace
        // values vary fastest), so untraced specs keep their stride.
        if !self.traces.is_empty() {
            let base = std::mem::take(&mut jobs);
            for job in base {
                for t in &self.traces {
                    let mut j = job.clone();
                    j.index = jobs.len();
                    j.trace = Some(t.clone());
                    jobs.push(j);
                }
            }
        }
        Ok(jobs)
    }

    /// The scenario axis actually swept: the declared `scenarios` plus one
    /// [`ScenarioKind::Mc`] entry per `cut_chains` element, in order.
    fn effective_scenarios(&self) -> Vec<ScenarioKind> {
        let mut out = self.scenarios.clone();
        out.extend(
            self.cut_chains
                .iter()
                .map(|cuts| ScenarioKind::Mc { cuts: cuts.clone() }),
        );
        out
    }

    /// The tier-chain axis actually swept: `tiers`, or the single
    /// `[edge, server]` chain when none are declared.
    fn effective_tiers(&self) -> Vec<Vec<String>> {
        if self.tiers.is_empty() {
            vec![vec![self.edge.clone(), self.server.clone()]]
        } else {
            self.tiers.clone()
        }
    }

    /// Parse a spec from its JSON document (see the type-level docs for
    /// the schema). The grid is validated eagerly, so an invalid spec
    /// fails here rather than inside a worker thread.
    pub fn from_json(text: &str) -> Result<SweepSpec> {
        const KEYS: [&str; 31] = [
            "name", "mode", "scenarios", "protocols", "channels",
            "latencies_us", "loss_rates", "scales", "archs", "clients",
            "offered_fps", "tiers", "cut_chains", "client_mixes", "hop_nets",
            "traces", "edge", "server", "dataset", "frames",
            "seeds_per_point", "seed", "fps", "frame_period_ns",
            "max_latency_ms", "min_accuracy", "min_hit_rate", "max_batch",
            "batch_wait_us", "prefilter", "queue",
        ];
        let j = Json::parse(text).context("parsing sweep spec")?;
        // A misspelled optional key must not silently fall back to its
        // default (e.g. "max_latency" running the sweep unconstrained).
        if let Json::Obj(map) = &j {
            for k in map.keys() {
                if !KEYS.contains(&k.as_str()) {
                    bail!("unknown sweep spec key '{k}'");
                }
            }
        }
        let mut spec = SweepSpec::new(
            j.opt("name").map(|v| v.str()).transpose()?.unwrap_or("sweep"),
        );
        // `scenarios` may be omitted when `cut_chains` supplies the MC
        // scenario axis on its own (an empty union still fails in
        // `expand`).
        spec.scenarios = match j.opt("scenarios") {
            Some(v) => v
                .str_vec()?
                .iter()
                .map(|s| ScenarioKind::parse(s))
                .collect::<Result<_>>()?,
            None => Vec::new(),
        };
        spec.protocols = j
            .get("protocols")?
            .str_vec()?
            .iter()
            .map(|s| Protocol::parse(s))
            .collect::<Result<_>>()?;
        spec.loss_rates = j.get("loss_rates")?.f64_vec()?;
        if let Some(v) = j.opt("channels") {
            spec.channels = v.str_vec()?;
        }
        if let Some(v) = j.opt("latencies_us") {
            spec.latencies_us = v.f64_vec()?;
        }
        if let Some(v) = j.opt("scales") {
            spec.scales = v
                .str_vec()?
                .iter()
                .map(|s| ModelScale::parse(s))
                .collect::<Result<_>>()?;
        }
        if let Some(v) = j.opt("archs") {
            spec.archs = v
                .str_vec()?
                .iter()
                .map(|s| Arch::parse(s))
                .collect::<Result<_>>()?;
        }
        if let Some(v) = j.opt("clients") {
            spec.clients = v.usize_vec()?;
        }
        if let Some(v) = j.opt("offered_fps") {
            spec.offered_fps = v.f64_vec()?;
        }
        if let Some(v) = j.opt("tiers") {
            spec.tiers = v
                .arr()?
                .iter()
                .map(|chain| chain.str_vec())
                .collect::<Result<_>>()?;
        }
        if let Some(v) = j.opt("cut_chains") {
            spec.cut_chains = v
                .arr()?
                .iter()
                .map(|chain| chain.usize_vec())
                .collect::<Result<_>>()?;
        }
        if let Some(v) = j.opt("client_mixes") {
            spec.client_mixes = v
                .arr()?
                .iter()
                .enumerate()
                .map(|(i, m)| -> Result<ClientMix> {
                    let name = match m.opt("name") {
                        Some(n) => n.str()?.to_string(),
                        None => format!("mix{i}"),
                    };
                    let clients = m
                        .get("clients")
                        .and_then(|c| parse_client_entries(c))
                        .with_context(|| {
                            format!("client_mixes[{i}] ('{name}')")
                        })?;
                    Ok(ClientMix { name, clients })
                })
                .collect::<Result<_>>()?;
        }
        if let Some(v) = j.opt("hop_nets") {
            spec.hop_nets = v.str_vec()?;
        }
        if let Some(v) = j.opt("traces") {
            spec.traces = v.str_vec()?;
        }
        if let Some(v) = j.opt("max_batch") {
            spec.max_batch = v.u64()? as usize;
        }
        if let Some(v) = j.opt("batch_wait_us") {
            spec.batch_wait_us = v.f64()?;
        }
        if let Some(v) = j.opt("min_hit_rate") {
            spec.min_hit_rate = v.f64()?;
        }
        if let Some(v) = j.opt("edge") {
            spec.edge = v.str()?.to_string();
        }
        if let Some(v) = j.opt("server") {
            spec.server = v.str()?.to_string();
        }
        if let Some(v) = j.opt("dataset") {
            spec.dataset = v.str()?.to_string();
        }
        if let Some(v) = j.opt("frames") {
            spec.frames = v.u64()? as usize;
        }
        if let Some(v) = j.opt("seeds_per_point") {
            spec.seeds_per_point = v.u64()? as usize;
        }
        if let Some(v) = j.opt("seed") {
            spec.seed = v.u64()?;
        }
        if let Some(v) = j.opt("fps") {
            let fps = v.f64()?;
            if !fps.is_finite() || fps <= 0.0 || fps > 1e9 {
                bail!(
                    "sweep spec 'fps' must be a positive number <= 1e9, \
                     got {fps}"
                );
            }
            spec.frame_period_ns = (1e9 / fps) as SimTime;
            spec.max_latency_ms = 1e3 / fps;
        }
        if let Some(v) = j.opt("frame_period_ns") {
            spec.frame_period_ns = v.u64()?;
        }
        if let Some(v) = j.opt("max_latency_ms") {
            let ms = v.f64()?;
            if !ms.is_finite() || ms < 0.0 {
                bail!(
                    "sweep spec 'max_latency_ms' must be a non-negative \
                     number, got {ms}"
                );
            }
            spec.max_latency_ms = ms;
        }
        if let Some(v) = j.opt("min_accuracy") {
            let acc = v.f64()?;
            if !acc.is_finite() || !(0.0..=1.0).contains(&acc) {
                bail!(
                    "sweep spec 'min_accuracy' must be in [0, 1], got {acc}"
                );
            }
            spec.min_accuracy = acc;
        }
        if let Some(v) = j.opt("mode") {
            spec.mode = SweepMode::parse(v.str()?)?;
        }
        if let Some(v) = j.opt("prefilter") {
            spec.prefilter = v.bool()?;
        }
        if let Some(v) = j.opt("queue") {
            let s = v.str()?;
            spec.queue = QueueKind::parse(s).ok_or_else(|| {
                anyhow!(
                    "unknown queue backend '{s}' (wheel | calendar | linear)"
                )
            })?;
        }
        spec.expand()?;
        Ok(spec)
    }

    /// Serialize back to the JSON schema [`SweepSpec::from_json`] accepts.
    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("name", json::s(&self.name)),
            ("mode", json::s(self.mode.as_str())),
            (
                "scenarios",
                json::arr(
                    self.scenarios
                        .iter()
                        .map(|k| json::s(&k.to_string()))
                        .collect(),
                ),
            ),
            (
                "protocols",
                json::arr(
                    self.protocols
                        .iter()
                        .map(|p| json::s(&p.to_string()))
                        .collect(),
                ),
            ),
            (
                "channels",
                json::arr(self.channels.iter().map(|c| json::s(c)).collect()),
            ),
            (
                "latencies_us",
                json::arr(
                    self.latencies_us.iter().map(|&l| json::num(l)).collect(),
                ),
            ),
            (
                "loss_rates",
                json::arr(
                    self.loss_rates.iter().map(|&l| json::num(l)).collect(),
                ),
            ),
            (
                "scales",
                json::arr(
                    self.scales.iter().map(|s| json::s(s.as_str())).collect(),
                ),
            ),
            (
                "archs",
                json::arr(
                    self.archs.iter().map(|a| json::s(a.as_str())).collect(),
                ),
            ),
            (
                "clients",
                json::arr(
                    self.clients
                        .iter()
                        .map(|&c| json::num(c as f64))
                        .collect(),
                ),
            ),
            (
                "offered_fps",
                json::arr(
                    self.offered_fps.iter().map(|&f| json::num(f)).collect(),
                ),
            ),
            (
                "tiers",
                json::arr(
                    self.tiers
                        .iter()
                        .map(|chain| {
                            json::arr(
                                chain.iter().map(|d| json::s(d)).collect(),
                            )
                        })
                        .collect(),
                ),
            ),
            (
                "cut_chains",
                json::arr(
                    self.cut_chains
                        .iter()
                        .map(|chain| {
                            json::arr(
                                chain
                                    .iter()
                                    .map(|&c| json::num(c as f64))
                                    .collect(),
                            )
                        })
                        .collect(),
                ),
            ),
            (
                "client_mixes",
                json::arr(
                    self.client_mixes
                        .iter()
                        .map(|m| {
                            json::obj(vec![
                                ("name", json::s(&m.name)),
                                (
                                    "clients",
                                    json::arr(
                                        m.clients
                                            .iter()
                                            .map(client_spec_json)
                                            .collect(),
                                    ),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "hop_nets",
                json::arr(
                    self.hop_nets.iter().map(|h| json::s(h)).collect(),
                ),
            ),
            (
                "traces",
                json::arr(self.traces.iter().map(|t| json::s(t)).collect()),
            ),
            ("edge", json::s(&self.edge)),
            ("server", json::s(&self.server)),
            ("dataset", json::s(&self.dataset)),
            ("frames", json::num(self.frames as f64)),
            ("seeds_per_point", json::num(self.seeds_per_point as f64)),
            ("seed", json::num(self.seed as f64)),
            ("frame_period_ns", json::num(self.frame_period_ns as f64)),
            ("max_latency_ms", json::num(self.max_latency_ms)),
            ("min_accuracy", json::num(self.min_accuracy)),
            ("min_hit_rate", json::num(self.min_hit_rate)),
            ("max_batch", json::num(self.max_batch as f64)),
            ("batch_wait_us", json::num(self.batch_wait_us)),
            ("prefilter", Json::Bool(self.prefilter)),
        ])
    }
}

/// Serialize one tenant back to the client-entry schema accepted by
/// [`parse_client_entries`], so a spec with mixes round-trips through
/// [`SweepSpec::to_json`] / [`SweepSpec::from_json`] losslessly.
fn client_spec_json(c: &ClientSpec) -> Json {
    let mut fields = vec![
        ("scenario", json::s(&c.kind.to_string())),
        ("arch", json::s(c.arch.as_str())),
        ("scale", json::s(c.scale.as_str())),
        ("frame_period_ns", json::num(c.frame_period_ns as f64)),
        ("frames", json::num(c.frames as f64)),
        ("weight", json::num(c.weight as f64)),
    ];
    if let Some(ns) = c.qos.max_latency_ns {
        fields.push(("max_latency_ms", json::num(ns as f64 / 1e6)));
    }
    if let Some(acc) = c.qos.min_accuracy {
        fields.push(("min_accuracy", json::num(acc)));
    }
    if c.qos.min_hit_rate != 1.0 {
        fields.push(("min_hit_rate", json::num(c.qos.min_hit_rate)));
    }
    json::obj(fields)
}

/// Aggregated metrics of one grid point (pooled over its seeds).
#[derive(Clone, Debug)]
pub struct SweepPoint {
    pub index: usize,
    pub kind: ScenarioKind,
    pub protocol: Protocol,
    pub channel: String,
    pub latency_us: Option<f64>,
    pub loss: f64,
    pub scale: ModelScale,
    /// Architecture under test at this point.
    pub arch: Arch,
    /// Concurrent client streams at this point.
    pub clients: usize,
    /// Per-client offered rate; `None` = spec `frame_period_ns` drove it.
    pub offered_fps: Option<f64>,
    /// Device tier chain of this point (sensor side first).
    pub tiers: Vec<String>,
    /// Explicit per-hop channel specs (empty = single derived channel).
    pub hop_nets: Vec<String>,
    /// Hop-trace spec this point ran under (`None` = constant channels).
    pub trace: Option<String>,
    /// Name of the tenant mix this point ran (`None` = homogeneous).
    pub mix: Option<String>,
    /// Total frames pooled into this point (clients × frames × seeds).
    pub frames: usize,
    /// Measured accuracy; `None` in latency-only sweeps.
    pub accuracy: Option<f64>,
    pub mean_latency_ns: f64,
    pub p95_latency_ns: SimTime,
    pub p99_latency_ns: SimTime,
    pub max_latency_ns: SimTime,
    /// Achieved throughput (frames/s, averaged over seeds) — plateaus at
    /// the bottleneck resource under overload.
    pub throughput_fps: f64,
    /// Time-averaged / peak number of frames waiting in queues.
    pub mean_queue_depth: f64,
    pub max_queue_depth: usize,
    pub mean_wire_bytes: f64,
    pub total_retransmits: u64,
    /// Fraction of frames meeting the latency bound (if one is set).
    pub deadline_hit_rate: Option<f64>,
    /// QoS verdict; `None` when the spec sets no checkable constraint.
    pub satisfies: Option<bool>,
    /// True when the bound-guided prefilter proved the point infeasible
    /// and skipped its simulation: the latency columns then carry the
    /// analytic lower bound (the simulation could only be slower),
    /// `frames` is 0 and `accuracy` is `None`.
    pub skipped: bool,
}

/// Run `cfg` once per seed and pool the frame records into one report —
/// the single scenario-execution path shared by the sweep worker pool and
/// the [`crate::coordinator::suggest`] engine.
pub fn pooled_scenario(
    engine: &dyn InferenceBackend,
    cfg: &ScenarioConfig,
    dataset: &Dataset,
    frames: usize,
    seeds: &[u64],
    qos: &QosRequirements,
) -> Result<ScenarioReport> {
    if seeds.is_empty() || frames == 0 {
        bail!("pooled_scenario needs at least one seed and one frame");
    }
    let mut records = Vec::with_capacity(frames * seeds.len());
    for &seed in seeds {
        let mut c = cfg.clone();
        c.set_base_seed(seed);
        records.extend(run_scenario(engine, &c, dataset, frames, qos)?.records);
    }
    ScenarioReport::from_records(cfg, records, qos)
}

/// The architectures a job touches: its own axis value, plus (for a
/// tenant-mix point) every tenant's. Callers preload one backend per
/// entry before dispatching the job.
pub(crate) fn job_archs(spec: &SweepSpec, job: &SweepJob) -> Vec<Arch> {
    let mut archs = vec![job.arch];
    if let Some(m) = job.mix {
        for c in &spec.client_mixes[m].clients {
            if !archs.contains(&c.arch) {
                archs.push(c.arch);
            }
        }
    }
    archs
}

/// Per-worker, per-architecture backend cache: backends are not `Send`
/// (executables are `Rc`-cached), so every worker owns one of these and
/// loads each architecture at most once, however many jobs it steals.
/// Shared by the sweep pool, the placement search and the co-design
/// search — the manifest/engine construction cost is paid `archs ×
/// workers` times per run, never per job.
pub struct EngineCache {
    map: HashMap<Arch, Box<dyn InferenceBackend>>,
}

impl Default for EngineCache {
    fn default() -> Self {
        Self::new()
    }
}

impl EngineCache {
    pub fn new() -> Self {
        EngineCache { map: HashMap::new() }
    }

    /// Load (through `factory`) every architecture in `archs` that is
    /// not cached yet.
    pub fn ensure(
        &mut self,
        archs: &[Arch],
        factory: &BackendFactory<'_>,
    ) -> Result<()> {
        for &arch in archs {
            if !self.map.contains_key(&arch) {
                self.map.insert(arch, factory(arch)?);
            }
        }
        Ok(())
    }

    /// The cached backend for `arch`; an error names the architecture if
    /// [`EngineCache::ensure`] was never called for it.
    pub fn get(&self, arch: Arch) -> Result<&dyn InferenceBackend> {
        self.map
            .get(&arch)
            .map(|e| &**e)
            .ok_or_else(|| anyhow!("no backend loaded for {}", arch.as_str()))
    }
}

/// Execute one expanded job against `engines` — which must hold a backend
/// for every arch in [`job_archs`] (the caller's per-arch cache
/// guarantees it). Deterministic in `(spec, job)` alone: the channel
/// seeds are `spec.seed + s`, never thread state. Both modes ride the
/// closed-loop streaming engine; latency-only mode simply skips model
/// execution (`dataset: None`). Homogeneous points run [`pooled_stream`];
/// tenant-mix points run the multi-tenant engine
/// ([`pooled_hetero_stream`]: DRR fairness, admission control, indexed
/// event calendar).
fn run_job(
    engines: &EngineCache,
    dataset: Option<&Dataset>,
    spec: &SweepSpec,
    job: &SweepJob,
) -> Result<SweepPoint> {
    let qos = spec.qos();
    let mut hop_nets: Vec<NetworkConfig> = if job.hop_nets.is_empty() {
        let mut net =
            channel_preset(&job.channel, job.protocol, job.loss, spec.seed)?;
        if let Some(us) = job.latency_us {
            net.latency_ns = (us * 1000.0) as SimTime;
        }
        vec![net]
    } else {
        // Explicit per-hop channels; their seeds are re-derived from the
        // spec seed by pooled_stream, keeping the point deterministic in
        // (spec, job) alone.
        job.hop_nets
            .iter()
            .map(|s| NetworkConfig::parse(s))
            .collect::<Result<_>>()?
    };
    let tiers = job
        .tiers
        .iter()
        .map(|d| DeviceProfile::parse(d))
        .collect::<Result<Vec<_>>>()?;
    if let Some(t) = &job.trace {
        // Attach the point's time-varying schedule before the engines
        // replicate / reseed the hop chain: a mix point spans the full
        // tier chain, a homogeneous point only the hops its kind uses.
        let hops = match job.mix {
            None => job.kind.tiers_needed().saturating_sub(1).max(1),
            Some(_) => tiers.len().saturating_sub(1).max(1),
        };
        let entries = crate::netsim::trace::parse_hop_traces(t)?;
        super::scenario::apply_hop_traces(&mut hop_nets, hops, &entries)?;
    }
    let seeds: Vec<u64> = (0..spec.seeds_per_point as u64)
        .map(|s| spec.seed.wrapping_add(s))
        .collect();
    let ds = match spec.mode {
        SweepMode::Full => Some(
            dataset
                .ok_or_else(|| anyhow!("full-mode sweep needs a dataset"))?,
        ),
        SweepMode::LatencyOnly => None,
    };
    let (r, mix_name) = match job.mix {
        None => {
            let frame_period_ns = match job.offered_fps {
                Some(fps) => (1e9 / fps) as SimTime,
                None => spec.frame_period_ns,
            };
            let cfg = StreamConfig {
                scenario: ScenarioConfig {
                    kind: job.kind.clone(),
                    hop_nets,
                    tiers,
                    scale: job.scale,
                    frame_period_ns,
                },
                clients: job.clients,
                frames_per_client: spec.frames,
                batch: spec.batch_policy(),
            };
            let r = pooled_stream_with_queue(
                engines.get(job.arch)?,
                &cfg,
                ds,
                &seeds,
                &qos,
                spec.queue,
            )?;
            (r, None)
        }
        Some(m) => {
            let mix = &spec.client_mixes[m];
            let cfg = MultiStreamConfig {
                clients: mix.clients.clone(),
                hop_nets,
                tiers,
                batch: spec.batch_policy(),
                fairness: Fairness::Drr,
                admission: true,
                queue: spec.queue,
            };
            let refs: Vec<(Arch, &dyn InferenceBackend)> =
                job_archs(spec, job)
                    .into_iter()
                    .map(|a| Ok((a, engines.get(a)?)))
                    .collect::<Result<_>>()?;
            let r = pooled_hetero_stream(&refs, &cfg, ds, &seeds, &qos)?;
            (r, Some(mix.name.clone()))
        }
    };
    Ok(SweepPoint {
        index: job.index,
        kind: job.kind.clone(),
        protocol: job.protocol,
        channel: job.channel.clone(),
        latency_us: job.latency_us,
        loss: job.loss,
        scale: job.scale,
        arch: job.arch,
        clients: job.clients,
        offered_fps: job.offered_fps,
        tiers: job.tiers.clone(),
        hop_nets: job.hop_nets.clone(),
        trace: job.trace.clone(),
        mix: mix_name,
        frames: r.frames,
        accuracy: r.accuracy,
        mean_latency_ns: r.mean_latency_ns,
        p95_latency_ns: r.p95_latency_ns,
        p99_latency_ns: r.p99_latency_ns,
        max_latency_ns: r.max_latency_ns,
        throughput_fps: r.stats.throughput_fps,
        mean_queue_depth: r.stats.mean_queue_depth,
        max_queue_depth: r.stats.max_queue_depth,
        mean_wire_bytes: r.mean_wire_bytes,
        total_retransmits: r.total_retransmits,
        deadline_hit_rate: r.deadline_hit_rate,
        satisfies: r.qos_satisfied,
        skipped: false,
    })
}

/// The report entry of a prefilter-skipped point: the latency columns
/// carry the admissible bound (every simulated frame would be at least
/// this late), the deadline hit-rate is the proven 0, and the QoS
/// verdict is the proven violation. No frames were simulated, so the
/// throughput/queue/accuracy columns stay empty.
fn skipped_point(job: &SweepJob, bound_ns: SimTime) -> SweepPoint {
    SweepPoint {
        index: job.index,
        kind: job.kind.clone(),
        protocol: job.protocol,
        channel: job.channel.clone(),
        latency_us: job.latency_us,
        loss: job.loss,
        scale: job.scale,
        arch: job.arch,
        clients: job.clients,
        offered_fps: job.offered_fps,
        tiers: job.tiers.clone(),
        hop_nets: job.hop_nets.clone(),
        trace: job.trace.clone(),
        mix: None,
        frames: 0,
        accuracy: None,
        mean_latency_ns: bound_ns as f64,
        p95_latency_ns: bound_ns,
        p99_latency_ns: bound_ns,
        max_latency_ns: bound_ns,
        throughput_fps: 0.0,
        mean_queue_depth: 0.0,
        max_queue_depth: 0,
        mean_wire_bytes: 0.0,
        total_retransmits: 0,
        deadline_hit_rate: Some(0.0),
        satisfies: Some(false),
        skipped: true,
    }
}

/// Bound-guided phase 1 of a two-phase evaluation: when the spec opts in
/// and sets a deadline, return the skipped-point record for a job whose
/// admissible analytic bound already proves the deadline unreachable
/// (`None` = no proof, run the full simulation). The engine for
/// `job.arch` must already be loaded in `engines`.
fn prefiltered(
    engines: &EngineCache,
    spec: &SweepSpec,
    job: &SweepJob,
) -> Result<Option<SweepPoint>> {
    if !spec.prefilter {
        return Ok(None);
    }
    let Some(deadline) = spec.qos().max_latency_ns else {
        return Ok(None);
    };
    let bound = super::bound::job_bound_ns(engines.get(job.arch)?, spec, job)?;
    Ok(match bound {
        Some(b) if b > deadline => Some(skipped_point(job, b)),
        _ => None,
    })
}

/// The reduced result of a sweep: every point plus the Pareto frontier
/// and per-constraint satisfaction counts.
#[derive(Clone, Debug)]
pub struct SweepReport {
    pub spec: SweepSpec,
    /// One entry per grid point, in expansion (index) order.
    pub points: Vec<SweepPoint>,
    /// Indices into `points` of the accuracy-vs-mean-latency Pareto
    /// frontier, latency ascending (empty for latency-only sweeps).
    pub pareto: Vec<usize>,
    /// Points meeting the latency bound (all, when unconstrained).
    pub satisfied_latency: usize,
    /// Points meeting the accuracy bound (all, when unconstrained).
    pub satisfied_accuracy: usize,
    /// Points meeting every stated constraint.
    pub satisfied_both: usize,
    /// Points that ran the full discrete-event simulation.
    pub evaluated: usize,
    /// Points skipped by the bound-guided prefilter (their analytic
    /// latency lower bound already proved the deadline unreachable).
    pub skipped: usize,
}

impl SweepReport {
    pub fn from_points(
        spec: &SweepSpec,
        points: Vec<SweepPoint>,
    ) -> SweepReport {
        let qos = spec.qos();
        let skipped = points.iter().filter(|p| p.skipped).count();
        let coords: Vec<(f64, f64)> = points
            .iter()
            .map(|p| (p.accuracy.unwrap_or(f64::NAN), p.mean_latency_ns))
            .collect();
        // Per-frame semantics: a point meets the latency constraint when
        // its deadline hit-rate reaches the threshold, not when its mean
        // sneaks under the bound.
        let lat_ok = |p: &SweepPoint| qos.latency_ok(p.deadline_hit_rate);
        let acc_ok = |p: &SweepPoint| match (qos.min_accuracy, p.accuracy) {
            (None, _) => true,
            (Some(m), Some(a)) => a >= m,
            (Some(_), None) => false,
        };
        SweepReport {
            pareto: pareto_frontier(&coords),
            satisfied_latency: points.iter().filter(|p| lat_ok(p)).count(),
            satisfied_accuracy: points.iter().filter(|p| acc_ok(p)).count(),
            satisfied_both: points
                .iter()
                .filter(|p| lat_ok(p) && acc_ok(p))
                .count(),
            evaluated: points.len() - skipped,
            skipped,
            spec: spec.clone(),
            points,
        }
    }

    /// Machine-readable report (deterministic key order and formatting).
    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("spec", self.spec.to_json()),
            (
                "points",
                json::arr(self.points.iter().map(point_json).collect()),
            ),
            (
                "pareto",
                json::arr(
                    self.pareto.iter().map(|&i| json::num(i as f64)).collect(),
                ),
            ),
            ("satisfied_latency", json::num(self.satisfied_latency as f64)),
            (
                "satisfied_accuracy",
                json::num(self.satisfied_accuracy as f64),
            ),
            ("satisfied_both", json::num(self.satisfied_both as f64)),
            ("evaluated", json::num(self.evaluated as f64)),
            ("skipped", json::num(self.skipped as f64)),
            ("total_points", json::num(self.points.len() as f64)),
        ])
    }

    /// Plot-ready CSV, one row per grid point.
    pub fn to_csv(&self) -> Csv {
        let mut csv = Csv::new(&[
            "index",
            "scenario",
            "protocol",
            "channel",
            "latency_us",
            "loss",
            "scale",
            "arch",
            "clients",
            "offered_fps",
            "tiers",
            "hop_nets",
            "trace",
            "mix",
            "frames",
            "accuracy",
            "mean_latency_ms",
            "p95_latency_ms",
            "p99_latency_ms",
            "max_latency_ms",
            "throughput_fps",
            "mean_queue_depth",
            "max_queue_depth",
            "deadline_hit_rate",
            "qos_satisfied",
            "skipped",
            "pareto",
        ]);
        for (pos, p) in self.points.iter().enumerate() {
            csv.row(vec![
                p.index.to_string(),
                p.kind.to_string(),
                p.protocol.to_string(),
                p.channel.clone(),
                p.latency_us.map(|v| format!("{v}")).unwrap_or_default(),
                format!("{}", p.loss),
                p.scale.as_str().to_string(),
                p.arch.as_str().to_string(),
                p.clients.to_string(),
                p.offered_fps.map(|v| format!("{v}")).unwrap_or_default(),
                p.tiers.join(">"),
                p.hop_nets.join(">"),
                p.trace.clone().unwrap_or_default(),
                p.mix.clone().unwrap_or_default(),
                p.frames.to_string(),
                p.accuracy.map(|a| format!("{a:.4}")).unwrap_or_default(),
                format!("{:.4}", p.mean_latency_ns / 1e6),
                format!("{:.4}", p.p95_latency_ns as f64 / 1e6),
                format!("{:.4}", p.p99_latency_ns as f64 / 1e6),
                format!("{:.4}", p.max_latency_ns as f64 / 1e6),
                format!("{:.2}", p.throughput_fps),
                format!("{:.2}", p.mean_queue_depth),
                p.max_queue_depth.to_string(),
                p.deadline_hit_rate
                    .map(|r| format!("{r:.4}"))
                    .unwrap_or_default(),
                p.satisfies.map(|s| s.to_string()).unwrap_or_default(),
                p.skipped.to_string(),
                // The frontier holds *positions* into `points` (== index
                // for reports built by run_sweep, but not necessarily for
                // caller-assembled ones).
                self.pareto.contains(&pos).to_string(),
            ]);
        }
        csv
    }

    /// Human-readable table + frontier + satisfaction summary.
    pub fn render(&self) -> String {
        let qos = self.spec.qos();
        let n = self.points.len();
        let mut out = format!(
            "Sweep '{}' — {} points ({} mode), QoS: {}\n\n",
            self.spec.name,
            n,
            self.spec.mode.as_str(),
            qos.describe()
        );
        let rows: Vec<Vec<String>> = self
            .points
            .iter()
            .enumerate()
            .map(|(pos, p)| {
                vec![
                    p.index.to_string(),
                    match &p.mix {
                        Some(name) => format!("mix:{name}"),
                        None => p.kind.to_string(),
                    },
                    format!("{} {}", p.protocol, p.channel),
                    format!("{:.1}%", p.loss * 100.0),
                    p.scale.as_str().to_string(),
                    p.arch.as_str().to_string(),
                    match p.offered_fps {
                        Some(f) => format!("{}x{:.0}", p.clients, f),
                        None => format!("{}x—", p.clients),
                    },
                    if p.tiers.len() <= 2 {
                        format!("{}t", p.tiers.len())
                    } else {
                        format!("{}t:{}", p.tiers.len(), p.tiers.join(">"))
                    },
                    p.accuracy
                        .map(|a| format!("{:.1}%", a * 100.0))
                        .unwrap_or_else(|| "—".to_string()),
                    format!("{:.2} ms", p.mean_latency_ns / 1e6),
                    format!("{:.2} ms", p.p99_latency_ns as f64 / 1e6),
                    format!("{:.1}", p.throughput_fps),
                    match p.satisfies {
                        Some(true) => "ok",
                        Some(false) => "violated",
                        None => "—",
                    }
                    .to_string(),
                    if self.pareto.contains(&pos) { "*" } else { "" }
                        .to_string(),
                ]
            })
            .collect();
        out.push_str(&table::render(
            &[
                "#", "scenario", "transport", "loss", "scale", "arch",
                "load", "tiers", "accuracy", "mean lat", "p99 lat", "thru",
                "QoS", "Pareto",
            ],
            &rows,
        ));
        if !self.pareto.is_empty() {
            out.push_str(
                "\naccuracy-vs-latency Pareto frontier (latency ascending):\n",
            );
            for &i in &self.pareto {
                let p = &self.points[i];
                out.push_str(&format!(
                    "  #{:<3} {:<8} {:<11} {:<4} loss {:>4.1}%  \
                     acc {:>5.1}%  mean {:>8.2} ms\n",
                    p.index,
                    p.kind.to_string(),
                    p.arch.as_str(),
                    p.protocol.to_string(),
                    p.loss * 100.0,
                    p.accuracy.unwrap_or(f64::NAN) * 100.0,
                    p.mean_latency_ns / 1e6,
                ));
            }
        }
        out.push_str(&format!(
            "\nconstraint satisfaction: latency {}/{n} · accuracy {}/{n} · \
             both {}/{n}\n",
            self.satisfied_latency, self.satisfied_accuracy,
            self.satisfied_both,
        ));
        if self.spec.prefilter {
            out.push_str(&format!(
                "prefilter: {} simulated · {} skipped (analytic bound \
                 above the deadline — provably infeasible)\n",
                self.evaluated, self.skipped,
            ));
        }
        out
    }
}

pub(crate) fn point_json(p: &SweepPoint) -> Json {
    json::obj(vec![
        ("index", json::num(p.index as f64)),
        ("scenario", json::s(&p.kind.to_string())),
        ("protocol", json::s(&p.protocol.to_string())),
        ("channel", json::s(&p.channel)),
        (
            "latency_us",
            p.latency_us.map(json::num).unwrap_or(Json::Null),
        ),
        ("loss", json::num(p.loss)),
        ("scale", json::s(p.scale.as_str())),
        ("arch", json::s(p.arch.as_str())),
        ("clients", json::num(p.clients as f64)),
        (
            "offered_fps",
            p.offered_fps.map(json::num).unwrap_or(Json::Null),
        ),
        (
            "tiers",
            json::arr(p.tiers.iter().map(|d| json::s(d)).collect()),
        ),
        (
            "hop_nets",
            json::arr(p.hop_nets.iter().map(|h| json::s(h)).collect()),
        ),
        (
            "trace",
            p.trace.as_deref().map(json::s).unwrap_or(Json::Null),
        ),
        (
            "mix",
            p.mix.as_deref().map(json::s).unwrap_or(Json::Null),
        ),
        ("frames", json::num(p.frames as f64)),
        ("accuracy", p.accuracy.map(json::num).unwrap_or(Json::Null)),
        ("mean_latency_ns", json::num(p.mean_latency_ns)),
        ("p95_latency_ns", json::num(p.p95_latency_ns as f64)),
        ("p99_latency_ns", json::num(p.p99_latency_ns as f64)),
        ("max_latency_ns", json::num(p.max_latency_ns as f64)),
        ("throughput_fps", json::num(p.throughput_fps)),
        ("mean_queue_depth", json::num(p.mean_queue_depth)),
        ("max_queue_depth", json::num(p.max_queue_depth as f64)),
        ("mean_wire_bytes", json::num(p.mean_wire_bytes)),
        ("total_retransmits", json::num(p.total_retransmits as f64)),
        (
            "deadline_hit_rate",
            p.deadline_hit_rate.map(json::num).unwrap_or(Json::Null),
        ),
        (
            "qos_satisfied",
            p.satisfies.map(Json::Bool).unwrap_or(Json::Null),
        ),
        ("skipped", Json::Bool(p.skipped)),
    ])
}

/// A thread-safe constructor for per-worker, per-architecture inference
/// backends. Backends themselves are deliberately *not* shared across
/// threads (their caches are `Rc`-based); each worker opens its own, one
/// per architecture its jobs touch (workers cache them, so a factory is
/// called at most `archs × workers` times per sweep).
pub type BackendFactory<'a> =
    dyn Fn(Arch) -> Result<Box<dyn InferenceBackend>> + Sync + 'a;

fn load_dataset(
    engine: &dyn InferenceBackend,
    spec: &SweepSpec,
) -> Result<Option<Dataset>> {
    match spec.mode {
        SweepMode::Full => Ok(Some(engine.dataset(&spec.dataset)?)),
        SweepMode::LatencyOnly => Ok(None),
    }
}

pub(crate) fn record_failure(
    flag: &AtomicBool,
    slot: &Mutex<Option<anyhow::Error>>,
    e: anyhow::Error,
) {
    flag.store(true, Ordering::Relaxed);
    let mut s = slot.lock().unwrap();
    if s.is_none() {
        *s = Some(e);
    }
}

/// How a parallel evaluation pool hands jobs to workers. Either way the
/// results are keyed by job position, so the report is byte-identical;
/// only wall-clock time differs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SweepScheduler {
    /// Deterministic work stealing (the default): every worker claims
    /// the next unclaimed job off a shared atomic counter the moment it
    /// goes idle, and keeps its backend cache for the whole run. No
    /// barrier — a skewed job mix never strands idle workers behind one
    /// heavy job.
    Stealing,
    /// The pre-stealing fixed-wave pool, retained as the benchmark
    /// baseline: jobs run in waves of `threads`, one per worker, with a
    /// full barrier between waves and backends rebuilt each wave. Every
    /// wave lasts as long as its slowest job.
    Waves,
}

/// Execute `jobs` (already expanded from `spec`) on a pool of `threads`
/// workers and return one [`SweepPoint`] per job, in slice order —
/// whatever order workers finish in, results are keyed by position.
/// `threads <= 1` runs inline with no pool at all.
pub(crate) fn run_jobs(
    spec: &SweepSpec,
    jobs: &[SweepJob],
    threads: usize,
    scheduler: SweepScheduler,
    factory: &BackendFactory<'_>,
) -> Result<Vec<SweepPoint>> {
    let threads = threads.clamp(1, jobs.len().max(1));
    if threads <= 1 {
        let mut engines = EngineCache::new();
        // The synthetic datasets are arch-independent (asserted by the
        // analytic backend's tests), so the first engine's dataset serves
        // every grid point.
        let mut dataset: Option<Dataset> = None;
        let mut points = Vec::with_capacity(jobs.len());
        for job in jobs {
            engines.ensure(&job_archs(spec, job), factory)?;
            if dataset.is_none() && spec.mode == SweepMode::Full {
                dataset = load_dataset(engines.get(job.arch)?, spec)?;
            }
            points.push(match prefiltered(&engines, spec, job)? {
                Some(p) => p,
                None => run_job(&engines, dataset.as_ref(), spec, job)?,
            });
        }
        return Ok(points);
    }

    // The dataset is plain shareable data — load it once and hand every
    // worker a reference; only the backends are per-worker (`Rc`-cached).
    // Latency-only sweeps need no dataset, so skip the throwaway backend.
    let dataset = match spec.mode {
        SweepMode::Full => {
            let engine = factory(spec.archs[0])?;
            load_dataset(&*engine, spec)?
        }
        SweepMode::LatencyOnly => None,
    };
    let failed = AtomicBool::new(false);
    let error: Mutex<Option<anyhow::Error>> = Mutex::new(None);
    let (tx, rx) = std::sync::mpsc::channel::<(usize, SweepPoint)>();
    {
        let dataset = dataset.as_ref();
        let (failed, error) = (&failed, &error);
        // One worker's turn of duty: bound-check, then simulate. Each
        // worker brings its own `Sender` clone and backend cache; only
        // shared read-only state crosses threads by reference.
        let work = |engines: &EngineCache,
                    tx: &Sender<(usize, SweepPoint)>,
                    i: usize| {
            let point = prefiltered(engines, spec, &jobs[i])
                .and_then(|skip| match skip {
                    Some(p) => Ok(p),
                    None => run_job(engines, dataset, spec, &jobs[i]),
                });
            match point {
                // The receiver outlives the scope; send cannot fail.
                Ok(p) => tx.send((i, p)).expect("sweep result receiver"),
                Err(e) => record_failure(failed, error, e),
            }
        };
        let work = &work;
        match scheduler {
            SweepScheduler::Stealing => {
                let next = AtomicUsize::new(0);
                let next = &next;
                std::thread::scope(|s| {
                    for _ in 0..threads {
                        let tx = tx.clone();
                        s.spawn(move || {
                            let mut engines = EngineCache::new();
                            loop {
                                if failed.load(Ordering::Relaxed) {
                                    return;
                                }
                                let i = next.fetch_add(1, Ordering::Relaxed);
                                if i >= jobs.len() {
                                    return;
                                }
                                match engines
                                    .ensure(&job_archs(spec, &jobs[i]), factory)
                                {
                                    Ok(()) => work(&engines, &tx, i),
                                    Err(e) => {
                                        return record_failure(
                                            failed, error, e,
                                        )
                                    }
                                }
                            }
                        });
                    }
                });
            }
            SweepScheduler::Waves => {
                for (w, wave) in jobs.chunks(threads).enumerate() {
                    if failed.load(Ordering::Relaxed) {
                        break;
                    }
                    std::thread::scope(|s| {
                        for o in 0..wave.len() {
                            let tx = tx.clone();
                            s.spawn(move || {
                                let i = w * threads + o;
                                let mut engines = EngineCache::new();
                                match engines
                                    .ensure(&job_archs(spec, &jobs[i]), factory)
                                {
                                    Ok(()) => work(&engines, &tx, i),
                                    Err(e) => record_failure(failed, error, e),
                                }
                            });
                        }
                    });
                }
            }
        }
    }
    drop(tx);
    if let Some(e) = error.into_inner().unwrap() {
        return Err(e);
    }
    let mut slots: Vec<Option<SweepPoint>> = vec![None; jobs.len()];
    for (i, p) in rx {
        slots[i] = Some(p);
    }
    slots
        .into_iter()
        .enumerate()
        .map(|(i, p)| p.ok_or_else(|| anyhow!("sweep point {i} missing")))
        .collect()
}

/// [`run_sweep`] with an explicit scheduler — the wave scheduler exists
/// for benchmark comparison; everything else should take the default.
pub fn run_sweep_with(
    spec: &SweepSpec,
    threads: usize,
    scheduler: SweepScheduler,
    factory: &BackendFactory<'_>,
) -> Result<SweepReport> {
    let jobs = spec.expand()?;
    let points = run_jobs(spec, &jobs, threads, scheduler, factory)?;
    Ok(SweepReport::from_points(spec, points))
}

/// Expand `spec` and execute every grid point on a deterministic
/// work-stealing pool of `threads` workers (clamped to the job count;
/// `<= 1` runs inline). Workers claim jobs off a shared counter, open
/// one backend per architecture they encounter (cached for the whole
/// run), and results are keyed by job index — so the returned
/// [`SweepReport`] is identical — byte-for-byte in its JSON/CSV forms —
/// for every thread count.
///
/// ```
/// use std::path::Path;
/// use sei::coordinator::sweep::{run_sweep, SweepSpec};
/// use sei::runtime::load_backend_for;
///
/// let mut spec = SweepSpec::new("doc-run");
/// spec.loss_rates = vec![0.0, 0.08];
/// spec.frames = 4;
/// let factory =
///     |arch| load_backend_for(Path::new("artifacts"), arch);
/// let one = run_sweep(&spec, 1, &factory).unwrap();
/// let many = run_sweep(&spec, 2, &factory).unwrap();
/// assert_eq!(one.to_json().to_string(), many.to_json().to_string());
/// ```
pub fn run_sweep(
    spec: &SweepSpec,
    threads: usize,
    factory: &BackendFactory<'_>,
) -> Result<SweepReport> {
    run_sweep_with(spec, threads, SweepScheduler::Stealing, factory)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::load_backend_for;
    use std::path::Path;

    fn factory(arch: Arch) -> Result<Box<dyn InferenceBackend>> {
        // No artifacts directory in tests: loads the analytic backend.
        load_backend_for(Path::new("artifacts"), arch)
    }

    fn small_spec() -> SweepSpec {
        let mut spec = SweepSpec::new("unit");
        spec.scenarios =
            vec![ScenarioKind::Lc, ScenarioKind::Sc { split: 13 }];
        spec.protocols = vec![Protocol::Tcp, Protocol::Udp];
        spec.loss_rates = vec![0.0, 0.08];
        spec.frames = 8;
        spec.max_latency_ms = 50.0;
        spec.min_accuracy = 0.5;
        spec
    }

    #[test]
    fn expand_is_cartesian_and_ordered() {
        let spec = small_spec();
        let jobs = spec.expand().unwrap();
        assert_eq!(jobs.len(), 2 * 2 * 2);
        for (i, j) in jobs.iter().enumerate() {
            assert_eq!(j.index, i);
        }
        // Scenario-major, then protocol, then loss.
        assert_eq!(jobs[0].kind, ScenarioKind::Lc);
        assert_eq!(jobs[0].protocol, Protocol::Tcp);
        assert_eq!(jobs[0].loss, 0.0);
        assert_eq!(jobs[1].loss, 0.08);
        assert_eq!(jobs[2].protocol, Protocol::Udp);
        assert_eq!(jobs[4].kind, ScenarioKind::Sc { split: 13 });
    }

    #[test]
    fn expand_rejects_bad_specs() {
        let mut spec = small_spec();
        spec.scenarios.clear();
        assert!(spec.expand().is_err());
        let mut spec = small_spec();
        spec.channels = vec!["carrier-pigeon".to_string()];
        assert!(spec.expand().is_err());
        let mut spec = small_spec();
        spec.edge = "tpu-v9".to_string();
        assert!(spec.expand().is_err());
        let mut spec = small_spec();
        spec.frames = 0;
        assert!(spec.expand().is_err());
        let mut spec = small_spec();
        spec.loss_rates = vec![1.0];
        assert!(spec.expand().is_err());
        let mut spec = small_spec();
        spec.loss_rates = vec![-0.1];
        assert!(spec.expand().is_err());
        let mut spec = small_spec();
        spec.latencies_us = vec![-100.0];
        assert!(spec.expand().is_err());
    }

    #[test]
    fn load_axes_expand_and_validate() {
        let mut spec = small_spec();
        spec.scenarios = vec![ScenarioKind::Rc];
        spec.protocols = vec![Protocol::Udp];
        spec.loss_rates = vec![0.0];
        spec.clients = vec![1, 4];
        spec.offered_fps = vec![100.0, 1000.0];
        let jobs = spec.expand().unwrap();
        assert_eq!(jobs.len(), 4);
        assert_eq!(jobs[0].clients, 1);
        assert_eq!(jobs[0].offered_fps, Some(100.0));
        assert_eq!(jobs[1].offered_fps, Some(1000.0));
        assert_eq!(jobs[2].clients, 4);
        // offered_fps <= 0 is rejected the same way as a QoS fps of 0,
        // and rates past 1 GHz (0 ns period) are rejected too.
        spec.offered_fps = vec![0.0];
        assert!(spec.expand().is_err());
        spec.offered_fps = vec![-5.0];
        assert!(spec.expand().is_err());
        spec.offered_fps = vec![2e9];
        assert!(spec.expand().is_err());
        let mut spec = small_spec();
        spec.clients = vec![0];
        assert!(spec.expand().is_err());
        let mut spec = small_spec();
        spec.max_batch = 0;
        assert!(spec.expand().is_err());
        let mut spec = small_spec();
        spec.min_hit_rate = 0.0;
        assert!(spec.expand().is_err());
    }

    #[test]
    fn arch_axis_expands_validates_and_runs() {
        let mut spec = small_spec();
        spec.scenarios = vec![ScenarioKind::Rc, ScenarioKind::Sc { split: 5 }];
        spec.protocols = vec![Protocol::Tcp];
        spec.loss_rates = vec![0.0];
        spec.archs = vec![Arch::Vgg16, Arch::ResNet18, Arch::MobileNetV2];
        spec.frames = 6;
        let jobs = spec.expand().unwrap();
        assert_eq!(jobs.len(), 2 * 3);
        assert_eq!(jobs[0].arch, Arch::Vgg16);
        assert_eq!(jobs[1].arch, Arch::ResNet18);
        assert_eq!(jobs[2].arch, Arch::MobileNetV2);
        // Split 5 is exported by every arch's analytic backend, so the
        // full-mode sweep runs end-to-end across the zoo.
        let report = run_sweep(&spec, 2, &factory).unwrap();
        assert_eq!(report.points.len(), 6);
        for p in &report.points {
            assert!(p.accuracy.is_some());
            assert!(p.mean_latency_ns > 0.0);
        }
        // An empty arch axis is rejected eagerly.
        spec.archs.clear();
        assert!(spec.expand().is_err());
    }

    #[test]
    fn tier_and_cut_chain_axes_expand_with_the_compat_rule() {
        let mut spec = small_spec();
        spec.scenarios = vec![ScenarioKind::Rc];
        spec.protocols = vec![Protocol::Tcp];
        spec.loss_rates = vec![0.0];
        spec.tiers = vec![
            vec!["edge-gpu".into(), "server-gpu".into()],
            vec![
                "sensor-npu".into(),
                "edge-gpu".into(),
                "server-gpu".into(),
            ],
        ];
        spec.cut_chains = vec![vec![5, 9]];
        let jobs = spec.expand().unwrap();
        // RC runs on both chains; MC@5,9 pairs only with the 3-tier one.
        assert_eq!(jobs.len(), 3);
        assert_eq!(jobs[0].kind, ScenarioKind::Rc);
        assert_eq!(jobs[0].tiers.len(), 2);
        assert_eq!(jobs[1].tiers.len(), 3);
        assert_eq!(jobs[2].kind, ScenarioKind::Mc { cuts: vec![5, 9] });
        assert_eq!(jobs[2].tiers[0], "sensor-npu");
        // An MC scenario with no matching chain is an eager error.
        spec.tiers.remove(1);
        assert!(spec.expand().is_err());
        // Malformed chains are rejected.
        let mut spec = small_spec();
        spec.cut_chains = vec![vec![9, 5]];
        assert!(spec.expand().is_err());
        // Out-of-range cuts fail eagerly, not inside a worker thread.
        let mut spec = small_spec();
        spec.tiers = vec![vec![
            "sensor-npu".into(),
            "edge-gpu".into(),
            "server-gpu".into(),
        ]];
        spec.cut_chains = vec![vec![5, 40]];
        assert!(spec.expand().is_err());
        let mut spec = small_spec();
        spec.cut_chains = vec![vec![]];
        assert!(spec.expand().is_err());
        let mut spec = small_spec();
        spec.tiers = vec![vec!["edge-gpu".into()]];
        assert!(spec.expand().is_err());
        let mut spec = small_spec();
        spec.tiers = vec![vec!["edge-gpu".into(), "warp-drive".into()]];
        assert!(spec.expand().is_err());
        // Custom device specs ride the shared parse path.
        let mut spec = small_spec();
        spec.tiers =
            vec![vec!["npu@5e10+400000".into(), "server-gpu".into()]];
        assert!(spec.expand().is_ok());
    }

    #[test]
    fn from_json_parses_tiers_and_cut_chains() {
        let spec = SweepSpec::from_json(
            r#"{"protocols": ["tcp"], "loss_rates": [0.0],
                "cut_chains": [[5, 9], [5, 13]],
                "tiers": [["sensor-npu", "edge-gpu", "server-gpu"]]}"#,
        )
        .unwrap();
        assert!(spec.scenarios.is_empty());
        assert_eq!(spec.cut_chains, vec![vec![5, 9], vec![5, 13]]);
        assert_eq!(spec.tiers.len(), 1);
        assert_eq!(spec.expand().unwrap().len(), 2);
        // The grid round-trips through JSON with the new axes intact.
        let back = SweepSpec::from_json(&spec.to_json().to_string()).unwrap();
        assert_eq!(back.tiers, spec.tiers);
        assert_eq!(back.cut_chains, spec.cut_chains);
        assert_eq!(back.to_json().to_string(), spec.to_json().to_string());
        // Non-increasing chains fail at parse time.
        assert!(SweepSpec::from_json(
            r#"{"protocols": ["tcp"], "loss_rates": [0.0],
                "cut_chains": [[9, 5]],
                "tiers": [["sensor-npu", "edge-gpu", "server-gpu"]]}"#,
        )
        .is_err());
    }

    #[test]
    fn hop_nets_replace_the_channel_axes_and_label_from_hop_zero() {
        let mut spec = small_spec();
        spec.scenarios = vec![ScenarioKind::Rc];
        spec.protocols = vec![Protocol::Tcp];
        spec.loss_rates = vec![0.0];
        spec.hop_nets = vec!["wifi:udp:loss=0.02".into()];
        let jobs = spec.expand().unwrap();
        assert_eq!(jobs.len(), 1);
        // The labelling columns come from hop 0, not the (single-valued)
        // channel-derivation axes.
        assert_eq!(jobs[0].protocol, Protocol::Udp);
        assert_eq!(jobs[0].channel, "wifi:udp:loss=0.02");
        assert!((jobs[0].loss - 0.02).abs() < 1e-12);
        assert_eq!(jobs[0].hop_nets.len(), 1);
        // A multi-entry chain must match every scenario's hop count.
        spec.scenarios = Vec::new();
        spec.cut_chains = vec![vec![5, 13]];
        spec.tiers = vec![vec![
            "sensor-npu".into(),
            "edge-gpu".into(),
            "server-gpu".into(),
        ]];
        spec.hop_nets = vec!["wifi:udp".into(), "gigabit:udp".into()];
        assert!(spec.expand().is_ok());
        spec.scenarios = vec![ScenarioKind::Rc]; // 1 hop, 2 entries
        let err = spec.expand().unwrap_err().to_string();
        assert!(err.contains("1 inter-tier hops"), "{err}");
        assert!(err.contains("2 channels"), "{err}");
        // hop_nets replaces the channel axes: multi-valued axes error.
        let mut spec = small_spec();
        spec.hop_nets = vec!["gigabit:tcp".into()];
        assert!(spec.expand().is_err());
        // Malformed channel specs fail eagerly.
        let mut spec = small_spec();
        spec.protocols = vec![Protocol::Tcp];
        spec.loss_rates = vec![0.0];
        spec.hop_nets = vec!["carrier-pigeon".into()];
        assert!(spec.expand().is_err());
    }

    #[test]
    fn from_json_parses_hop_nets() {
        let spec = SweepSpec::from_json(
            r#"{"protocols": ["udp"], "loss_rates": [0.0],
                "cut_chains": [[5, 13]],
                "tiers": [["sensor-npu", "edge-gpu", "server-gpu"]],
                "hop_nets": ["wifi:udp:loss=0.05", "gigabit:tcp"]}"#,
        )
        .unwrap();
        assert_eq!(spec.hop_nets.len(), 2);
        let jobs = spec.expand().unwrap();
        assert_eq!(jobs.len(), 1);
        assert_eq!(jobs[0].hop_nets.len(), 2);
        let back = SweepSpec::from_json(&spec.to_json().to_string()).unwrap();
        assert_eq!(back.hop_nets, spec.hop_nets);
        assert_eq!(back.to_json().to_string(), spec.to_json().to_string());
    }

    #[test]
    fn traces_axis_multiplies_innermost_and_validates() {
        let mut spec = small_spec();
        spec.scenarios = vec![ScenarioKind::Sc { split: 13 }];
        spec.protocols = vec![Protocol::Tcp];
        spec.loss_rates = vec![0.0, 0.08];
        spec.frames = 4;
        spec.traces = vec![
            "hop0=gigabit".to_string(),
            "hop0=gigabit>degraded@2ms".to_string(),
        ];
        let jobs = spec.expand().unwrap();
        // 2 loss values × 2 traces, the trace varying fastest.
        assert_eq!(jobs.len(), 4);
        for (i, j) in jobs.iter().enumerate() {
            assert_eq!(j.index, i);
        }
        assert_eq!(jobs[0].trace.as_deref(), Some("hop0=gigabit"));
        assert_eq!(
            jobs[1].trace.as_deref(),
            Some("hop0=gigabit>degraded@2ms")
        );
        assert_eq!(jobs[1].loss, 0.0);
        assert_eq!(jobs[2].loss, 0.08);
        // The axis survives the JSON round-trip.
        let back = SweepSpec::from_json(&spec.to_json().to_string()).unwrap();
        assert_eq!(back.traces, spec.traces);
        assert_eq!(back.to_json().to_string(), spec.to_json().to_string());
        // A constant trace restating the point's own channel reproduces
        // the untraced metrics; the degraded trace visibly hurts.
        let report = run_sweep(&spec, 2, &factory).unwrap();
        let mut untraced = spec.clone();
        untraced.traces.clear();
        let base = run_sweep(&untraced, 1, &factory).unwrap();
        let p = &report.points[0];
        let b = &base.points[0];
        assert_eq!(p.mean_latency_ns, b.mean_latency_ns);
        assert_eq!(p.p99_latency_ns, b.p99_latency_ns);
        assert_eq!(p.throughput_fps, b.throughput_fps);
        assert!(
            report.points[1].mean_latency_ns > p.mean_latency_ns,
            "degraded trace should slow the stream"
        );
        // CSV and JSON carry the trace column, deterministically across
        // thread counts.
        assert!(report.to_csv().to_string().contains("degraded@2ms"));
        assert!(report.to_json().to_string().contains("\"trace\""));
        let solo = run_sweep(&spec, 1, &factory).unwrap();
        assert_eq!(
            solo.to_json().to_string(),
            report.to_json().to_string()
        );
        // Malformed chains and out-of-range hops fail eagerly.
        let mut bad = spec.clone();
        bad.traces = vec!["hop0=carrier-pigeon".to_string()];
        assert!(bad.expand().is_err());
        let mut bad = spec.clone();
        bad.traces = vec!["hop1=gigabit".to_string()];
        let err = bad.expand().unwrap_err().to_string();
        assert!(err.contains("hop1"), "{err}");
    }

    #[test]
    fn from_json_parses_archs() {
        let spec = SweepSpec::from_json(
            r#"{"scenarios": ["rc"], "protocols": ["tcp"],
                "loss_rates": [0.0],
                "archs": ["vgg16", "resnet18", "mobilenetv2"]}"#,
        )
        .unwrap();
        assert_eq!(
            spec.archs,
            vec![Arch::Vgg16, Arch::ResNet18, Arch::MobileNetV2]
        );
        assert_eq!(spec.expand().unwrap().len(), 3);
        assert!(SweepSpec::from_json(
            r#"{"scenarios": ["rc"], "protocols": ["tcp"],
                "loss_rates": [0.0], "archs": ["alexnet"]}"#,
        )
        .is_err());
    }

    #[test]
    fn from_json_parses_load_axes() {
        let spec = SweepSpec::from_json(
            r#"{"scenarios": ["rc"], "protocols": ["udp"],
                "loss_rates": [0.0], "clients": [1, 8],
                "offered_fps": [50, 400], "max_batch": 8,
                "batch_wait_us": 500, "min_hit_rate": 0.95}"#,
        )
        .unwrap();
        assert_eq!(spec.clients, vec![1, 8]);
        assert_eq!(spec.offered_fps, vec![50.0, 400.0]);
        assert_eq!(spec.max_batch, 8);
        assert!((spec.qos().min_hit_rate - 0.95).abs() < 1e-12);
        assert_eq!(spec.expand().unwrap().len(), 4);
        assert!(SweepSpec::from_json(
            r#"{"scenarios": ["rc"], "protocols": ["udp"],
                "loss_rates": [0.0], "offered_fps": [0]}"#,
        )
        .is_err());
    }

    #[test]
    fn from_json_applies_defaults_and_fps_sugar() {
        let spec = SweepSpec::from_json(
            r#"{"scenarios": ["rc"], "protocols": ["tcp"],
                "loss_rates": [0.0], "fps": 20}"#,
        )
        .unwrap();
        assert_eq!(spec.channels, vec!["gigabit".to_string()]);
        assert_eq!(spec.scales, vec![ModelScale::Slim]);
        assert_eq!(spec.frame_period_ns, 50_000_000);
        assert!((spec.max_latency_ms - 50.0).abs() < 1e-9);
        assert_eq!(spec.qos().max_latency_ns, Some(50_000_000));
        assert!(SweepSpec::from_json(r#"{"protocols": ["tcp"]}"#).is_err());
        // Misspelled keys are rejected, not silently defaulted.
        assert!(SweepSpec::from_json(
            r#"{"scenarios": ["rc"], "protocols": ["tcp"],
                "loss_rates": [0.0], "max_latency": 50}"#,
        )
        .is_err());
        // Fractional counts are rejected, not truncated.
        assert!(SweepSpec::from_json(
            r#"{"scenarios": ["rc"], "protocols": ["tcp"],
                "loss_rates": [0.0], "frames": 96.5}"#,
        )
        .is_err());
    }

    #[test]
    fn spec_json_roundtrip_preserves_the_grid() {
        let spec = small_spec();
        let back = SweepSpec::from_json(&spec.to_json().to_string()).unwrap();
        assert_eq!(back.expand().unwrap().len(), spec.expand().unwrap().len());
        assert_eq!(back.name, spec.name);
        assert_eq!(back.scenarios, spec.scenarios);
        assert_eq!(back.protocols, spec.protocols);
        assert_eq!(back.seed, spec.seed);
        assert_eq!(back.mode, spec.mode);
        assert_eq!(back.to_json().to_string(), spec.to_json().to_string());
    }

    #[test]
    fn sequential_sweep_reports_every_point() {
        let spec = small_spec();
        let report = run_sweep(&spec, 1, &factory).unwrap();
        assert_eq!(report.points.len(), 8);
        for (i, p) in report.points.iter().enumerate() {
            assert_eq!(p.index, i);
            assert!(p.accuracy.is_some());
            assert!(p.mean_latency_ns > 0.0);
            assert!(p.satisfies.is_some());
        }
        assert!(!report.pareto.is_empty());
        assert!(report.satisfied_both <= report.satisfied_latency);
        // The report serializes to valid JSON.
        let j = Json::parse(&report.to_json().to_string()).unwrap();
        assert_eq!(j.get("total_points").unwrap().usize().unwrap(), 8);
    }

    #[test]
    fn client_mix_axis_expands_and_runs() {
        let mut spec = small_spec();
        spec.scenarios = vec![ScenarioKind::Rc];
        spec.protocols = vec![Protocol::Tcp];
        spec.loss_rates = vec![0.0, 0.08];
        spec.frames = 4;
        let mut a = ClientSpec::new(ScenarioKind::Rc);
        a.frame_period_ns = 2_000_000;
        a.frames = 4;
        let mut b = ClientSpec::new(ScenarioKind::Sc { split: 5 });
        b.arch = Arch::ResNet18;
        b.frame_period_ns = 3_000_000;
        b.frames = 4;
        spec.client_mixes = vec![ClientMix {
            name: "duo".to_string(),
            clients: vec![a, b],
        }];
        let jobs = spec.expand().unwrap();
        // 2 homogeneous points (loss axis) + 2 mix points: the mix rides
        // the channel axes but not scenario/scale/arch/load.
        assert_eq!(jobs.len(), 4);
        assert_eq!(jobs[0].mix, None);
        assert_eq!(jobs[2].mix, Some(0));
        assert_eq!(jobs[2].clients, 2);
        assert_eq!(jobs[3].loss, 0.08);
        // The mix point runs end-to-end on the multi-tenant engine, with
        // a per-arch backend per worker (vgg16 + resnet18 here).
        let report = run_sweep(&spec, 2, &factory).unwrap();
        assert_eq!(report.points.len(), 4);
        let p = &report.points[2];
        assert_eq!(p.mix.as_deref(), Some("duo"));
        assert_eq!(p.clients, 2);
        assert_eq!(p.frames, 8);
        assert!(p.accuracy.is_some());
        assert!(p.mean_latency_ns > 0.0);
        // JSON and CSV carry the mix column.
        let j = report.to_json().to_string();
        assert!(j.contains("\"mix\""), "{j}");
        assert!(report.to_csv().to_string().contains("duo"));
        // Mixed heterogeneous points stay thread-count deterministic.
        let solo = run_sweep(&spec, 1, &factory).unwrap();
        assert_eq!(solo.to_json().to_string(), j);
        // An empty mix is rejected eagerly.
        spec.client_mixes.push(ClientMix {
            name: "empty".to_string(),
            clients: Vec::new(),
        });
        let err = spec.expand().unwrap_err().to_string();
        assert!(err.contains("client_mixes[1]"), "{err}");
        // A zero-frame tenant is rejected eagerly.
        let mut spec2 = small_spec();
        let mut c = ClientSpec::new(ScenarioKind::Rc);
        c.frames = 0;
        spec2.client_mixes = vec![ClientMix {
            name: "zero".to_string(),
            clients: vec![c],
        }];
        assert!(spec2.expand().is_err());
        // An MC tenant pairs only with tier chains of matching length.
        let mut spec3 = small_spec();
        spec3.scenarios = vec![ScenarioKind::Rc];
        spec3.protocols = vec![Protocol::Tcp];
        spec3.loss_rates = vec![0.0];
        spec3.client_mixes = vec![ClientMix {
            name: "mc".to_string(),
            clients: vec![ClientSpec::new(ScenarioKind::Mc {
                cuts: vec![5, 9],
            })],
        }];
        let err = spec3.expand().unwrap_err().to_string();
        assert!(err.contains("no compatible tier chain"), "{err}");
        spec3.tiers = vec![vec![
            "sensor-npu".into(),
            "edge-gpu".into(),
            "server-gpu".into(),
        ]];
        let jobs = spec3.expand().unwrap();
        // RC homogeneous point + the MC mix point, both on the 3-tier
        // chain.
        assert_eq!(jobs.len(), 2);
        assert_eq!(jobs[1].mix, Some(0));
        assert_eq!(jobs[1].tiers.len(), 3);
    }

    #[test]
    fn from_json_parses_client_mixes() {
        let spec = SweepSpec::from_json(
            r#"{"scenarios": ["rc"], "protocols": ["tcp"],
                "loss_rates": [0.0],
                "client_mixes": [{"name": "duo", "clients": [
                    {"scenario": "rc", "fps": 200, "frames": 4},
                    {"scenario": "sc@5", "arch": "resnet18", "fps": 100,
                     "frames": 4, "max_latency_ms": 25}
                ]}]}"#,
        )
        .unwrap();
        assert_eq!(spec.client_mixes.len(), 1);
        assert_eq!(spec.client_mixes[0].name, "duo");
        assert_eq!(spec.client_mixes[0].clients.len(), 2);
        assert_eq!(spec.client_mixes[0].clients[0].frame_period_ns, 5_000_000);
        assert_eq!(spec.client_mixes[0].clients[1].arch, Arch::ResNet18);
        assert_eq!(spec.expand().unwrap().len(), 2);
        // The grid round-trips through JSON with the mixes intact.
        let back = SweepSpec::from_json(&spec.to_json().to_string()).unwrap();
        assert_eq!(back.client_mixes[0].name, "duo");
        assert_eq!(back.client_mixes[0].clients.len(), 2);
        assert_eq!(
            back.client_mixes[0].clients[1].qos.max_latency_ns,
            Some(25_000_000)
        );
        assert_eq!(back.to_json().to_string(), spec.to_json().to_string());
        // A malformed tenant entry names the offending mix.
        let err = SweepSpec::from_json(
            r#"{"scenarios": ["rc"], "protocols": ["tcp"],
                "loss_rates": [0.0],
                "client_mixes": [{"name": "bad",
                                  "clients": [{"fps": 5}]}]}"#,
        )
        .unwrap_err();
        assert!(format!("{err:#}").contains("client_mixes[0]"), "{err:#}");
        // A mix-only spec (no homogeneous scenarios) is valid.
        let solo = SweepSpec::from_json(
            r#"{"protocols": ["tcp"], "loss_rates": [0.0],
                "client_mixes": [{"clients": [
                    {"scenario": "rc", "frames": 2}]}]}"#,
        )
        .unwrap();
        assert_eq!(solo.client_mixes[0].name, "mix0");
        assert_eq!(solo.expand().unwrap().len(), 1);
    }

    #[test]
    fn latency_only_mode_skips_inference() {
        let mut spec = small_spec();
        spec.mode = SweepMode::LatencyOnly;
        spec.seeds_per_point = 2;
        let report = run_sweep(&spec, 1, &factory).unwrap();
        for p in &report.points {
            assert!(p.accuracy.is_none());
            assert_eq!(p.frames, spec.frames * 2);
            assert!(p.mean_latency_ns > 0.0);
        }
        // No measurable accuracy: the Pareto frontier is empty and the
        // accuracy constraint cannot be met.
        assert!(report.pareto.is_empty());
        assert_eq!(report.satisfied_accuracy, 0);
    }
}
