//! Saliency-driven split-point search (paper Sec. III, step i of Fig. 1).
//!
//! The CS curve can come from two places:
//!   * the manifest (computed by python at build time, or synthesised by
//!     the analytic backend), or
//!   * [`compute_cs_curve`] — recomputed **in Rust** by running the
//!     per-layer Grad-CAM executables (`gradcam_L{i}_b16`; under the `xla`
//!     feature these embed the forward pass, the backward pass to the
//!     target layer and the Pallas saliency reduction) over a test batch
//!     stream. This is the framework's "no python on the request path"
//!     claim applied to the design phase as well.

use anyhow::Result;

use crate::data::Dataset;
use crate::runtime::{Executable, InferenceBackend, Manifest, RtInput};

/// A cumulative-saliency curve over an architecture's split-point
/// candidates (the 18 VGG feature layers of the paper's Fig. 2, block
/// boundaries for ResNet/MobileNet — see `model::cut::split_points`).
#[derive(Clone, Debug)]
pub struct CsCurve {
    /// Raw CS^i values (layer-normalized, see python/compile/saliency.py).
    pub raw: Vec<f64>,
    /// Which split-point (cut id) each entry corresponds to.
    pub layers: Vec<usize>,
}

impl CsCurve {
    pub fn from_manifest(manifest: &Manifest) -> CsCurve {
        let cs = &manifest.cs_curve;
        CsCurve {
            raw: cs.raw.clone(),
            layers: (0..cs.raw.len()).collect(),
        }
    }

    /// Min-max normalized values (the paper plots normalized saliency).
    pub fn normalized(&self) -> Vec<f64> {
        let lo = self.raw.iter().cloned().fold(f64::INFINITY, f64::min);
        let hi = self.raw.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        if hi <= lo {
            return vec![0.0; self.raw.len()];
        }
        self.raw.iter().map(|v| (v - lo) / (hi - lo)).collect()
    }

    /// Candidate split points: local maxima of the curve, excluding
    /// endpoints and the earliest layers (paper Sec. III: "the candidate
    /// split points can be identified by the layers that give local CS
    /// maxima").
    pub fn candidates(&self, min_layer: usize) -> Vec<usize> {
        let v = self.normalized();
        let n = v.len();
        let mut out = Vec::new();
        for i in 1..n.saturating_sub(1) {
            if self.layers[i] < min_layer {
                continue;
            }
            if v[i] > v[i - 1] && v[i] >= v[i + 1] {
                out.push(self.layers[i]);
            }
        }
        out
    }
}

/// Recompute the CS curve by executing the Grad-CAM executables on
/// `n_images` of `dataset` (must be a multiple of the artifact batch, 16).
pub fn compute_cs_curve(
    engine: &dyn InferenceBackend,
    dataset: &Dataset,
    n_images: usize,
) -> Result<CsCurve> {
    let layers = engine.manifest().gradcam_layers();
    let mut raw = Vec::with_capacity(layers.len());
    for &li in &layers {
        let exec = engine.executable(&format!("gradcam_L{li}_b16"))?;
        let batch = exec.spec().batch;
        let n = n_images.min(dataset.len()) / batch * batch;
        let mut acc = 0.0f64;
        let mut count = 0usize;
        let mut start = 0;
        while start + batch <= n {
            let x = dataset.batch(start, batch)?;
            let y = dataset.batch_labels(start, batch);
            let out = exec.run(&[RtInput::F32(&x), RtInput::I32(y)])?;
            acc += out.data().iter().map(|v| *v as f64).sum::<f64>();
            count += batch;
            start += batch;
        }
        raw.push(acc / count.max(1) as f64);
    }
    Ok(CsCurve { raw, layers })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn curve(vals: &[f64]) -> CsCurve {
        CsCurve { raw: vals.to_vec(), layers: (0..vals.len()).collect() }
    }

    #[test]
    fn normalization() {
        let c = curve(&[1.0, 3.0, 2.0]);
        assert_eq!(c.normalized(), vec![0.0, 1.0, 0.5]);
    }

    #[test]
    fn flat_curve_normalizes_to_zero() {
        let c = curve(&[2.0, 2.0]);
        assert_eq!(c.normalized(), vec![0.0, 0.0]);
    }

    #[test]
    fn candidates_are_local_maxima() {
        let c = curve(&[0.0, 0.5, 0.2, 0.8, 0.3, 0.9, 0.1]);
        assert_eq!(c.candidates(0), vec![1, 3, 5]);
        assert_eq!(c.candidates(2), vec![3, 5]);
    }

    #[test]
    fn endpoints_excluded() {
        let c = curve(&[1.0, 0.5, 0.9]);
        assert!(c.candidates(0).is_empty());
    }

    #[test]
    fn plateau_takes_first() {
        let c = curve(&[0.0, 0.7, 0.7, 0.1]);
        assert_eq!(c.candidates(0), vec![1]);
    }

    #[test]
    fn sparse_layer_indices_respected() {
        let c = CsCurve {
            raw: vec![0.1, 0.9, 0.2],
            layers: vec![2, 6, 10],
        };
        assert_eq!(c.candidates(0), vec![6]);
        assert_eq!(c.candidates(7), Vec::<usize>::new());
    }
}
