//! Workload generators: frame arrival processes for the scenario engine.
//!
//! The paper's ICE-Lab conveyor produces strictly periodic frames (belt
//! speed -> 20 FPS); real sensing deployments also see Poisson arrivals
//! (event cameras, motion triggers) and on/off bursts. The arrival process
//! changes the queueing behaviour of the shared channel and the batcher's
//! efficiency, so it is a first-class experiment axis.

use crate::netsim::event::SimTime;
use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ArrivalProcess {
    /// Strictly periodic (conveyor belt) at the given FPS.
    Periodic { fps: f64 },
    /// Poisson with the given mean rate.
    Poisson { fps: f64 },
    /// On/off bursts: `burst` back-to-back frames at `fps`, then idle for
    /// `idle_s` seconds.
    Bursty { fps: f64, burst: usize, idle_s: f64 },
}

impl ArrivalProcess {
    pub fn mean_rate(&self) -> f64 {
        match *self {
            ArrivalProcess::Periodic { fps } | ArrivalProcess::Poisson { fps } => {
                fps
            }
            ArrivalProcess::Bursty { fps, burst, idle_s } => {
                let burst_span = burst as f64 / fps;
                burst as f64 / (burst_span + idle_s)
            }
        }
    }
}

/// Iterator of frame arrival timestamps.
pub struct Workload {
    process: ArrivalProcess,
    rng: Rng,
    next: SimTime,
    emitted: usize,
}

impl Workload {
    pub fn new(process: ArrivalProcess, seed: u64) -> Workload {
        Workload { process, rng: Rng::new(seed), next: 0, emitted: 0 }
    }

    /// Timestamp of the next frame arrival.
    pub fn next_arrival(&mut self) -> SimTime {
        let t = self.next;
        self.emitted += 1;
        let dt_s = match self.process {
            ArrivalProcess::Periodic { fps } => 1.0 / fps,
            ArrivalProcess::Poisson { fps } => self.rng.exp(1.0 / fps),
            ArrivalProcess::Bursty { fps, burst, idle_s } => {
                if self.emitted % burst == 0 {
                    idle_s
                } else {
                    1.0 / fps
                }
            }
        };
        self.next = t + (dt_s * 1e9).round() as SimTime;
        t
    }

    /// Materialize the first `n` arrivals.
    pub fn take_arrivals(&mut self, n: usize) -> Vec<SimTime> {
        (0..n).map(|_| self.next_arrival()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn periodic_is_exact() {
        let mut w = Workload::new(ArrivalProcess::Periodic { fps: 20.0 }, 0);
        let a = w.take_arrivals(4);
        assert_eq!(a, vec![0, 50_000_000, 100_000_000, 150_000_000]);
    }

    #[test]
    fn poisson_mean_rate_converges() {
        let mut w = Workload::new(ArrivalProcess::Poisson { fps: 100.0 }, 7);
        let a = w.take_arrivals(20_000);
        let span_s = *a.last().unwrap() as f64 / 1e9;
        let rate = a.len() as f64 / span_s;
        assert!((rate - 100.0).abs() < 3.0, "{rate}");
    }

    #[test]
    fn poisson_is_irregular() {
        let mut w = Workload::new(ArrivalProcess::Poisson { fps: 20.0 }, 1);
        let a = w.take_arrivals(10);
        let gaps: Vec<u64> = a.windows(2).map(|p| p[1] - p[0]).collect();
        assert!(gaps.iter().any(|&g| g != gaps[0]));
    }

    #[test]
    fn bursty_has_gaps() {
        let mut w = Workload::new(
            ArrivalProcess::Bursty { fps: 100.0, burst: 3, idle_s: 1.0 },
            0,
        );
        let a = w.take_arrivals(7);
        // frames 0,1,2 back-to-back, then a 1 s gap
        assert_eq!(a[1] - a[0], 10_000_000);
        assert_eq!(a[3] - a[2], 1_000_000_000);
    }

    #[test]
    fn mean_rates() {
        assert_eq!(ArrivalProcess::Periodic { fps: 20.0 }.mean_rate(), 20.0);
        let b = ArrivalProcess::Bursty { fps: 100.0, burst: 10, idle_s: 0.9 };
        assert!((b.mean_rate() - 10.0).abs() < 1e-9);
    }

    #[test]
    fn deterministic_per_seed() {
        let a = Workload::new(ArrivalProcess::Poisson { fps: 20.0 }, 5)
            .take_arrivals(10);
        let b = Workload::new(ArrivalProcess::Poisson { fps: 20.0 }, 5)
            .take_arrivals(10);
        assert_eq!(a, b);
    }
}
