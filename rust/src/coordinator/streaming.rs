//! Closed-loop, queueing, multi-client streaming simulator — the serving
//! path of the framework (paper Sec. IV-V, scaled to many sensing devices
//! and, since the multi-tier refactor, to pipelines spanning a chain of
//! device tiers).
//!
//! The original scenario engine was *open-loop*: frame `i` started at
//! `i * frame_period_ns` even when the edge device, the channel or the
//! server was still busy with frame `i-1`, so overload never showed up as
//! queueing delay and the latency judged against the QoS bound was wrong
//! exactly in the regime the framework exists to detect. This module is
//! the fix: a discrete-event, closed-loop simulator in which `N` client
//! streams emit frames into per-resource FIFO queues —
//!
//! ```text
//!   client c ─► [tier 0 compute c] ─► [hop 0 uplink] ─► [tier 1 compute]
//!                 ─► [hop 1 uplink] ─► … ─► [last tier: batcher+compute]
//!                                                            │
//!   client c ◄─ [hop 0 downlink] ◄─ … ◄─ [hop H-1 downlink] ◄┘
//! ```
//!
//! — so a frame's latency includes the time spent waiting behind earlier
//! frames and behind *other clients* on the shared resources, and
//! throughput saturates at the bottleneck resource instead of latency
//! staying flat under overload.
//!
//! Semantics:
//!
//! * **Sources.** Each client emits `frames_per_client` frames at a fixed
//!   period (`ScenarioConfig::frame_period_ns`). A period of 0 selects a
//!   *closed-loop source*: the next frame is emitted the instant the
//!   previous one completes (the "back-to-back" mode of the old engine,
//!   now with well-defined queueing semantics).
//! * **Tier 0.** Each client owns its sensing device; LC, SC and MC frames
//!   pay the first segment's compute there (FIFO per client). RC frames
//!   skip the stage, as in the per-frame pipeline.
//! * **Hops.** Every inter-tier hop is its own [`Channel`] (seeded via
//!   [`ScenarioConfig::hop_net`]), shared by all clients. Messages queue
//!   at message level ([`Channel::send_no_earlier`]): under UDP the two
//!   directions of a hop are independent FIFO resources (true full
//!   duplex, no reverse traffic); under TCP every message's ACK stream
//!   rides the opposite link of *its* hop, so TCP messages serialize
//!   across that hop — the same coupling the legacy engine expressed
//!   through its single clock. A slow mid-chain hop therefore saturates
//!   exactly like any other bottleneck resource.
//! * **Mid tiers.** MC's intermediate tiers are shared single-server FIFO
//!   resources: a frame pays `tiers[t].compute_ns(segment MACs)` and
//!   forwards its re-encoded latent up the next hop.
//! * **Last tier.** Requests arriving off the final uplink hop are fronted
//!   by the size-or-deadline [`Batcher`]; a released batch of `n` requests
//!   costs `server.compute_ns(n × segment MACs)`, amortizing the per-call
//!   overhead — with [`BatchPolicy::immediate`] this degenerates to the
//!   old per-frame cost exactly. Results return hop by hop in reverse
//!   over each hop's downlink.
//! * **Inference.** In full mode the per-frame tensors flow through the
//!   same executables and UDP corruption path as `run_scenario` always
//!   used (batching affects *timing* only; accuracy is measured with the
//!   per-frame `b1` executables). MC chains run `head → mid… → tail`
//!   segment executables, synthesized on demand by the analytic backend.
//!
//! With one client, batch size 1 and a period longer than the pipeline
//! latency, the closed-loop engine reproduces the open-loop per-frame
//! latencies *exactly* for UDP (any loss rate) and lossless TCP, and
//! drives byte-identical transfers in every case (asserted by
//! `rust/tests/streaming_properties.rs` against the retained
//! [`super::scenario::run_scenario_open_loop`] reference). Likewise,
//! `mc@[i]` over two tiers reproduces `sc@i` byte-identically — the
//! degenerate-equivalence anchor of the multi-tier refactor (pinned by
//! `rust/tests/multi_tier.rs`). Under lossy TCP the closed loop
//! additionally counts the time a result waits for the channel to drain
//! the upstream ACK tail — time the open-loop accounting silently
//! dropped — so those latencies are `>=` the legacy ones frame-by-frame.
//! Under overload the two engines deliberately diverge; that divergence
//! is the bug this engine fixes.

use std::collections::VecDeque;
use std::rc::Rc;

use anyhow::{anyhow, bail, Result};

use super::batcher::{Batch, BatchPolicy, Batcher};
use super::corruption;
use super::qos::QosRequirements;
use super::scenario::{costs, Costs, FrameRecord, ScenarioConfig, ScenarioKind};
use crate::data::Dataset;
use crate::model::DeviceProfile;
use crate::netsim::event::{secs, EventQueue, SimTime};
use crate::netsim::transfer::{Channel, Protocol};
use crate::netsim::Dir;
use crate::report::stats::percentile;
use crate::runtime::{Executable, InferenceBackend, RtInput};
use crate::tensor::Tensor;

/// Configuration of one streaming run.
#[derive(Clone, Debug)]
pub struct StreamConfig {
    /// Scenario under test. `scenario.frame_period_ns` is the per-client
    /// source period (0 = closed-loop back-to-back).
    pub scenario: ScenarioConfig,
    /// Number of concurrent client streams sharing the channels + server.
    pub clients: usize,
    /// Frames each client emits.
    pub frames_per_client: usize,
    /// Server-side dynamic batching policy ([`BatchPolicy::immediate`]
    /// reproduces unbatched per-frame serving).
    pub batch: BatchPolicy,
}

impl StreamConfig {
    /// The single-client, unbatched configuration `run_scenario` rides.
    pub fn single(scenario: &ScenarioConfig, n_frames: usize) -> StreamConfig {
        StreamConfig {
            scenario: scenario.clone(),
            clients: 1,
            frames_per_client: n_frames,
            batch: BatchPolicy::immediate(),
        }
    }

    /// Aggregate offered load over all clients, frames/s (0 when the
    /// sources are closed-loop).
    pub fn offered_fps(&self) -> f64 {
        if self.scenario.frame_period_ns == 0 {
            0.0
        } else {
            self.clients as f64 * 1e9 / self.scenario.frame_period_ns as f64
        }
    }
}

/// One served frame.
#[derive(Clone, Debug)]
pub struct StreamFrameRecord {
    pub client: usize,
    /// Per-client frame number.
    pub frame: usize,
    pub emitted_ns: SimTime,
    pub completed_ns: SimTime,
    /// End-to-end latency including all queue waits.
    pub latency_ns: SimTime,
    /// Time spent waiting in queues (tiers, hop lanes, batcher+server),
    /// i.e. the part of `latency_ns` the open-loop model lost.
    pub queue_wait_ns: SimTime,
    /// `None` in latency-only runs.
    pub correct: Option<bool>,
    pub wire_bytes: u64,
    pub retransmits: u64,
    pub corrupted: bool,
}

/// Resource-level aggregates of one run (or the merge of several seeds).
#[derive(Clone, Copy, Debug, Default)]
pub struct ResourceStats {
    /// Simulated time from the first emission (t = 0) to the last
    /// completion.
    pub duration_ns: SimTime,
    /// Achieved throughput: completed frames / duration.
    pub throughput_fps: f64,
    /// Time-averaged number of frames waiting in queues.
    pub mean_queue_depth: f64,
    /// Peak number of frames waiting in queues.
    pub max_queue_depth: usize,
    pub batches_released: u64,
    /// Requests that went through the batcher (frames with an uplink leg).
    pub batched_requests: u64,
}

impl ResourceStats {
    pub fn mean_batch_size(&self) -> f64 {
        if self.batches_released == 0 {
            0.0
        } else {
            self.batched_requests as f64 / self.batches_released as f64
        }
    }
}

/// The reduced result of a streaming run.
#[derive(Clone, Debug)]
pub struct StreamReport {
    pub clients: usize,
    /// Aggregate offered load, frames/s (0 = closed-loop sources).
    pub offered_fps: f64,
    pub frames: usize,
    /// `None` in latency-only runs.
    pub accuracy: Option<f64>,
    pub mean_latency_ns: f64,
    pub p50_latency_ns: SimTime,
    pub p95_latency_ns: SimTime,
    pub p99_latency_ns: SimTime,
    pub max_latency_ns: SimTime,
    pub mean_queue_wait_ns: f64,
    pub mean_wire_bytes: f64,
    pub total_retransmits: u64,
    /// Fraction of frames meeting the latency bound (if one is set).
    pub deadline_hit_rate: Option<f64>,
    /// Hit-rate-based QoS verdict; `None` without checkable constraints.
    pub qos_satisfied: Option<bool>,
    pub stats: ResourceStats,
    pub records: Vec<StreamFrameRecord>,
}

impl StreamReport {
    fn from_parts(
        clients: usize,
        offered_fps: f64,
        records: Vec<StreamFrameRecord>,
        stats: ResourceStats,
        qos: &QosRequirements,
    ) -> StreamReport {
        let n = records.len().max(1);
        let mut lat: Vec<SimTime> =
            records.iter().map(|r| r.latency_ns).collect();
        lat.sort_unstable();
        let mean_latency_ns =
            lat.iter().map(|&v| v as f64).sum::<f64>() / n as f64;
        let measured = records.iter().all(|r| r.correct.is_some())
            && !records.is_empty();
        let accuracy = if measured {
            Some(
                records.iter().filter(|r| r.correct == Some(true)).count()
                    as f64
                    / n as f64,
            )
        } else {
            None
        };
        let deadline_hit_rate = qos.max_latency_ns.map(|m| {
            records.iter().filter(|r| r.latency_ns <= m).count() as f64
                / n as f64
        });
        // A measured latency violation is a definite verdict even when an
        // accuracy bound exists but accuracy was not measured; only a
        // *passing* latency check with an uncheckable accuracy bound
        // leaves the verdict open.
        let latency_ok = qos.latency_ok(deadline_hit_rate);
        let qos_satisfied =
            match (qos.max_latency_ns, qos.min_accuracy, accuracy) {
                (None, None, _) => None,
                _ if !latency_ok => Some(false),
                // Latency passes; an accuracy bound is uncheckable
                // without inference, so leave the verdict open rather
                // than claiming "ok".
                (_, Some(_), None) => None,
                (_, _, acc) => Some(
                    qos.satisfied_by(deadline_hit_rate, acc.unwrap_or(1.0)),
                ),
            };
        StreamReport {
            clients,
            offered_fps,
            frames: records.len(),
            accuracy,
            mean_latency_ns,
            p50_latency_ns: percentile(&lat, 0.50),
            p95_latency_ns: percentile(&lat, 0.95),
            p99_latency_ns: percentile(&lat, 0.99),
            max_latency_ns: lat.last().copied().unwrap_or(0),
            mean_queue_wait_ns: records
                .iter()
                .map(|r| r.queue_wait_ns as f64)
                .sum::<f64>()
                / n as f64,
            mean_wire_bytes: records
                .iter()
                .map(|r| r.wire_bytes as f64)
                .sum::<f64>()
                / n as f64,
            total_retransmits: records.iter().map(|r| r.retransmits).sum(),
            deadline_hit_rate,
            qos_satisfied,
            stats,
            records,
        }
    }

    /// View the per-frame records as scenario-engine [`FrameRecord`]s (in
    /// deterministic (client, frame) order).
    pub fn to_frame_records(&self) -> Vec<FrameRecord> {
        self.records
            .iter()
            .map(|r| FrameRecord {
                latency_ns: r.latency_ns,
                completed_ns: r.completed_ns,
                correct: r.correct.unwrap_or(false),
                wire_bytes: r.wire_bytes,
                retransmits: r.retransmits,
                corrupted: r.corrupted,
            })
            .collect()
    }

    /// Human-readable serving summary.
    pub fn render(&self, qos: &QosRequirements) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "clients            {} ({} frames total)",
            self.clients, self.frames
        ));
        if self.offered_fps > 0.0 {
            out.push_str(&format!(
                " @ {:.1} FPS offered (aggregate)",
                self.offered_fps
            ));
        } else {
            out.push_str(" (closed-loop sources)");
        }
        out.push('\n');
        out.push_str(&format!(
            "throughput         {:.1} FPS over {:.2} s simulated\n",
            self.stats.throughput_fps,
            secs(self.stats.duration_ns)
        ));
        if let Some(acc) = self.accuracy {
            out.push_str(&format!(
                "accuracy           {:.2}%\n",
                acc * 100.0
            ));
        }
        out.push_str(&format!(
            "latency            mean {:.2} ms | p50 {:.2} ms | p95 {:.2} ms \
             | p99 {:.2} ms | max {:.2} ms\n",
            self.mean_latency_ns / 1e6,
            self.p50_latency_ns as f64 / 1e6,
            self.p95_latency_ns as f64 / 1e6,
            self.p99_latency_ns as f64 / 1e6,
            self.max_latency_ns as f64 / 1e6,
        ));
        out.push_str(&format!(
            "queueing           mean wait {:.2} ms/frame | depth mean \
             {:.1} / max {}\n",
            self.mean_queue_wait_ns / 1e6,
            self.stats.mean_queue_depth,
            self.stats.max_queue_depth,
        ));
        if self.stats.batches_released > 0 {
            out.push_str(&format!(
                "batching           {} batches, mean size {:.2}\n",
                self.stats.batches_released,
                self.stats.mean_batch_size(),
            ));
        }
        out.push_str(&format!(
            "wire traffic       {:.0} B/frame, {} retransmits total\n",
            self.mean_wire_bytes, self.total_retransmits
        ));
        if let Some(hit) = self.deadline_hit_rate {
            out.push_str(&format!(
                "deadline hit-rate  {:.1}% of frames\n",
                hit * 100.0
            ));
        }
        out.push_str(&format!("QoS ({})\n", qos.describe()));
        let has_constraints =
            qos.max_latency_ns.is_some() || qos.min_accuracy.is_some();
        out.push_str(&format!(
            "VERDICT            {}\n",
            match self.qos_satisfied {
                Some(true) => "SATISFIED",
                Some(false) => "VIOLATED",
                // Constraints exist but the accuracy bound was not
                // measurable in this run (latency-only): the verdict is
                // deliberately open, not absent.
                None if has_constraints => "OPEN (accuracy not measured)",
                None => "no constraints",
            }
        ));
        out
    }
}

/// Run `cfg` once per seed (via [`ScenarioConfig::set_base_seed`], which
/// re-derives every hop's channel seed) and merge the results into one
/// pooled report — the streaming analogue of
/// [`super::sweep::pooled_scenario`].
pub fn pooled_stream(
    engine: &dyn InferenceBackend,
    cfg: &StreamConfig,
    dataset: Option<&Dataset>,
    seeds: &[u64],
    qos: &QosRequirements,
) -> Result<StreamReport> {
    if seeds.is_empty() {
        bail!("pooled_stream needs at least one seed");
    }
    let mut reports = Vec::with_capacity(seeds.len());
    for &seed in seeds {
        let mut c = cfg.clone();
        c.scenario.set_base_seed(seed);
        reports.push(run_stream(engine, &c, dataset, qos)?);
    }
    let k = reports.len();
    let stats = ResourceStats {
        duration_ns: reports
            .iter()
            .map(|r| r.stats.duration_ns)
            .max()
            .unwrap_or(0),
        throughput_fps: reports
            .iter()
            .map(|r| r.stats.throughput_fps)
            .sum::<f64>()
            / k as f64,
        mean_queue_depth: reports
            .iter()
            .map(|r| r.stats.mean_queue_depth)
            .sum::<f64>()
            / k as f64,
        max_queue_depth: reports
            .iter()
            .map(|r| r.stats.max_queue_depth)
            .max()
            .unwrap_or(0),
        batches_released: reports
            .iter()
            .map(|r| r.stats.batches_released)
            .sum(),
        batched_requests: reports
            .iter()
            .map(|r| r.stats.batched_requests)
            .sum(),
    };
    let clients = cfg.clients;
    let offered = cfg.offered_fps();
    let records: Vec<StreamFrameRecord> =
        reports.into_iter().flat_map(|r| r.records).collect();
    Ok(StreamReport::from_parts(clients, offered, records, stats, qos))
}

// ---------------------------------------------------------------------------
// The discrete-event simulator.
// ---------------------------------------------------------------------------

enum Ev {
    /// Client `c` emits its next frame.
    Emit { c: usize },
    /// Client `c`'s tier-0 device finished its current frame.
    EdgeDone { c: usize },
    /// Transfer lane `lane` (hop `lane / 2`) is free for the next message.
    NetFree { lane: usize },
    /// Frame `g`'s uplink payload fully arrived at tier `hop + 1`.
    UpDelivered { g: usize, hop: usize },
    /// Shared mid-chain tier `tier` finished its current frame.
    MidDone { tier: usize },
    /// Size-or-deadline batcher poll point.
    BatchTimer,
    /// The server finished computing `batch`.
    ServerDone { batch: Batch },
    /// Frame `g`'s result arrived back at tier `hop` (0 = the client).
    DownDelivered { g: usize, hop: usize },
}

#[derive(Clone, Debug, Default)]
struct Frame {
    emitted_ns: SimTime,
    completed_ns: SimTime,
    queue_wait_ns: SimTime,
    /// When the frame entered its current queue (reused per stage).
    ready_at: SimTime,
    wire_bytes: u64,
    retransmits: u64,
    corrupted: bool,
    /// In-flight tensor (input for RC, latent for SC/MC) in full mode.
    payload: Option<Tensor>,
    pred: Option<usize>,
    label: usize,
}

struct Sim<'a> {
    cfg: &'a StreamConfig,
    costs: Costs,
    dataset: Option<&'a Dataset>,
    full_exec: Option<Rc<dyn Executable>>,
    head_exec: Option<Rc<dyn Executable>>,
    /// MC mid-segment executables (`mid_execs[t - 1]` runs on tier `t`).
    mid_execs: Vec<Rc<dyn Executable>>,
    tail_exec: Option<Rc<dyn Executable>>,
    /// `argmax` of an all-zero logits tensor — the prediction a frame is
    /// left with when its UDP result datagram is fully lost.
    zero_pred: usize,
    /// One channel per inter-tier hop (hop 0 keeps the configured seed).
    channels: Vec<Channel>,
    q: EventQueue<Ev>,
    frames: Vec<Frame>,
    /// Per-client next frame index to emit.
    next_frame: Vec<usize>,
    edge_q: Vec<VecDeque<usize>>,
    edge_busy: Vec<bool>,
    edge_cur: Vec<usize>,
    /// Shared mid-chain tier resources, indexed by tier (0 and the last
    /// tier are unused — they have their own machinery).
    mid_q: Vec<VecDeque<usize>>,
    mid_busy: Vec<bool>,
    mid_cur: Vec<usize>,
    /// Transfer lanes, two per hop: lane `2h` is hop `h`'s shared lane for
    /// TCP (the ACK stream couples the directions) and its uplink lane for
    /// UDP; lane `2h + 1` is hop `h`'s UDP downlink lane (full duplex).
    lane_q: Vec<VecDeque<(Dir, usize)>>,
    lane_busy: Vec<bool>,
    batcher: Batcher,
    /// Batcher request id -> global frame index (ids are sequential).
    offered: Vec<usize>,
    srv_q: VecDeque<Batch>,
    srv_busy: bool,
    // Queue-depth accounting (time-weighted over the event timeline).
    queued: usize,
    max_queued: usize,
    depth_area: f64,
    last_t: SimTime,
    completed: usize,
}

impl<'a> Sim<'a> {
    fn full_mode(&self) -> bool {
        self.dataset.is_some()
    }

    fn period(&self) -> SimTime {
        self.cfg.scenario.frame_period_ns
    }

    fn fpc(&self) -> usize {
        self.cfg.frames_per_client
    }

    fn client_of(&self, g: usize) -> usize {
        g / self.fpc()
    }

    /// Number of inter-tier hops in this pipeline.
    fn hops(&self) -> usize {
        self.costs.hops()
    }

    /// The device executing pipeline segment `seg` (RC/SC on a longer
    /// chain bypass the middle tiers: first and last device only).
    fn device(&self, seg: usize) -> &DeviceProfile {
        let tiers = &self.cfg.scenario.tiers;
        if seg == 0 {
            &tiers[0]
        } else if seg + 1 == self.costs.seg_mult_adds.len() {
            tiers.last().expect("validated by costs()")
        } else {
            &tiers[seg]
        }
    }

    fn input(&self, g: usize) -> Result<Tensor> {
        let ds = self.dataset.ok_or_else(|| anyhow!("no dataset"))?;
        let f = g % self.fpc();
        ds.batch(f % ds.len(), 1)
    }

    // -- queue-depth bookkeeping -------------------------------------------

    fn inc_queued(&mut self, by: usize) {
        self.queued += by;
        self.max_queued = self.max_queued.max(self.queued);
    }

    fn dec_queued(&mut self, by: usize) {
        debug_assert!(self.queued >= by);
        self.queued -= by;
    }

    // -- sources -----------------------------------------------------------

    fn emit(&mut self, c: usize, t: SimTime) -> Result<()> {
        let f = self.next_frame[c];
        debug_assert!(f < self.fpc());
        self.next_frame[c] = f + 1;
        let g = c * self.fpc() + f;
        self.frames[g].emitted_ns = t;
        let period = self.period();
        if period > 0 && f + 1 < self.fpc() {
            self.q.schedule(t + period, Ev::Emit { c });
        }
        if self.full_mode() {
            let ds = self.dataset.unwrap();
            self.frames[g].label = ds.labels[f % ds.len()] as usize;
            if self.cfg.scenario.kind == ScenarioKind::Rc {
                // The RC uplink payload is the raw input frame.
                let x = self.input(g)?;
                self.frames[g].payload = Some(x);
            }
        }
        match self.cfg.scenario.kind {
            ScenarioKind::Rc => self.enqueue_xfer(Dir::Up, 0, g, t),
            ScenarioKind::Lc
            | ScenarioKind::Sc { .. }
            | ScenarioKind::Mc { .. } => self.enqueue_edge(c, g, t),
        }
    }

    // -- tier-0 compute (one device per client) ----------------------------

    fn enqueue_edge(&mut self, c: usize, g: usize, t: SimTime) -> Result<()> {
        self.frames[g].ready_at = t;
        if self.edge_busy[c] {
            self.edge_q[c].push_back(g);
            self.inc_queued(1);
            Ok(())
        } else {
            self.start_edge(c, g, t)
        }
    }

    fn start_edge(&mut self, c: usize, g: usize, t: SimTime) -> Result<()> {
        self.edge_busy[c] = true;
        self.edge_cur[c] = g;
        let wait = t - self.frames[g].ready_at;
        self.frames[g].queue_wait_ns += wait;
        let dur = self.device(0).compute_ns(self.costs.seg_mult_adds[0]);
        self.q.schedule(t + dur, Ev::EdgeDone { c });
        Ok(())
    }

    fn edge_done(&mut self, c: usize, t: SimTime) -> Result<()> {
        let g = self.edge_cur[c];
        self.edge_busy[c] = false;
        if self.full_mode() {
            match &self.cfg.scenario.kind {
                ScenarioKind::Lc => {
                    let x = self.input(g)?;
                    let logits = self
                        .full_exec
                        .as_ref()
                        .unwrap()
                        .run(&[RtInput::F32(&x)])?;
                    self.frames[g].pred = Some(logits.argmax_last()[0]);
                }
                ScenarioKind::Sc { .. } | ScenarioKind::Mc { .. } => {
                    let x = self.input(g)?;
                    let latent = self
                        .head_exec
                        .as_ref()
                        .unwrap()
                        .run(&[RtInput::F32(&x)])?;
                    self.frames[g].payload = Some(latent);
                }
                ScenarioKind::Rc => unreachable!("RC has no tier-0 stage"),
            }
        }
        if self.hops() == 0 {
            self.complete(g, t); // LC: done at the edge
        } else {
            self.enqueue_xfer(Dir::Up, 0, g, t)?;
        }
        if let Some(g2) = self.edge_q[c].pop_front() {
            self.dec_queued(1);
            self.start_edge(c, g2, t)?;
        }
        Ok(())
    }

    // -- shared per-hop channel lanes --------------------------------------

    /// Which transfer lane a (hop, direction) pair uses: a TCP hop shares
    /// one lane (ACK entanglement serializes the hop), a UDP hop gets one
    /// lane per direction (full duplex). With heterogeneous `hop_nets`
    /// each hop follows *its own* channel's transport.
    fn lane_of(&self, hop: usize, dir: Dir) -> usize {
        let local = match (self.channels[hop].cfg.protocol, dir) {
            (Protocol::Tcp, _) => 0,
            (Protocol::Udp, Dir::Up) => 0,
            (Protocol::Udp, Dir::Down) => 1,
        };
        hop * 2 + local
    }

    fn enqueue_xfer(
        &mut self,
        dir: Dir,
        hop: usize,
        g: usize,
        t: SimTime,
    ) -> Result<()> {
        self.frames[g].ready_at = t;
        let lane = self.lane_of(hop, dir);
        if self.lane_busy[lane] {
            self.lane_q[lane].push_back((dir, g));
            self.inc_queued(1);
            Ok(())
        } else {
            self.start_xfer(lane, dir, g, t)
        }
    }

    fn start_xfer(
        &mut self,
        lane: usize,
        dir: Dir,
        g: usize,
        t: SimTime,
    ) -> Result<()> {
        self.lane_busy[lane] = true;
        let hop = lane / 2;
        let wait = t - self.frames[g].ready_at;
        self.frames[g].queue_wait_ns += wait;
        let bytes = match dir {
            Dir::Up => self.costs.up_bytes[hop],
            Dir::Down => self.costs.down_bytes,
        };
        let (start, res) =
            self.channels[hop].send_no_earlier(dir, bytes, t)?;
        debug_assert_eq!(start, t, "channel lane discipline violated");
        self.frames[g].wire_bytes += res.wire_bytes();
        self.frames[g].retransmits += res.retransmits();
        match dir {
            Dir::Up => {
                if self.channels[hop].cfg.protocol == Protocol::Udp
                    && !res.lost_ranges().is_empty()
                {
                    self.frames[g].corrupted = true;
                    if let Some(p) = self.frames[g].payload.as_mut() {
                        corruption::corrupt_scaled(
                            p,
                            res.lost_ranges(),
                            self.costs.up_bytes[hop],
                        );
                    }
                }
                self.q.schedule(
                    start + res.latency_ns(),
                    Ev::UpDelivered { g, hop },
                );
            }
            Dir::Down => {
                let lost: u64 =
                    res.lost_ranges().iter().map(|(_, l)| *l as u64).sum();
                if lost >= self.costs.down_bytes {
                    // A fully lost UDP result datagram voids the frame.
                    self.frames[g].corrupted = true;
                    if self.full_mode() {
                        self.frames[g].pred = Some(self.zero_pred);
                    }
                }
                self.q.schedule(
                    start + res.latency_ns(),
                    Ev::DownDelivered { g, hop },
                );
            }
        }
        self.q.schedule(start + res.sender_busy_ns(), Ev::NetFree { lane });
        Ok(())
    }

    fn net_free(&mut self, lane: usize, t: SimTime) -> Result<()> {
        self.lane_busy[lane] = false;
        if let Some((dir, g)) = self.lane_q[lane].pop_front() {
            self.dec_queued(1);
            self.start_xfer(lane, dir, g, t)?;
        }
        Ok(())
    }

    // -- mid-chain tiers (shared FIFO compute) -----------------------------

    fn enqueue_mid(&mut self, tier: usize, g: usize, t: SimTime)
        -> Result<()>
    {
        self.frames[g].ready_at = t;
        if self.mid_busy[tier] {
            self.mid_q[tier].push_back(g);
            self.inc_queued(1);
            Ok(())
        } else {
            self.start_mid(tier, g, t)
        }
    }

    fn start_mid(&mut self, tier: usize, g: usize, t: SimTime) -> Result<()> {
        self.mid_busy[tier] = true;
        self.mid_cur[tier] = g;
        let wait = t - self.frames[g].ready_at;
        self.frames[g].queue_wait_ns += wait;
        let dur =
            self.device(tier).compute_ns(self.costs.seg_mult_adds[tier]);
        self.q.schedule(t + dur, Ev::MidDone { tier });
        Ok(())
    }

    fn mid_done(&mut self, tier: usize, t: SimTime) -> Result<()> {
        let g = self.mid_cur[tier];
        self.mid_busy[tier] = false;
        if self.full_mode() {
            let payload = self.frames[g]
                .payload
                .take()
                .ok_or_else(|| anyhow!("frame {g} lost its payload"))?;
            let exec = &self.mid_execs[tier - 1];
            let latent = exec.run(&[RtInput::F32(&payload)])?;
            self.frames[g].payload = Some(latent);
        }
        self.enqueue_xfer(Dir::Up, tier, g, t)?;
        if let Some(g2) = self.mid_q[tier].pop_front() {
            self.dec_queued(1);
            self.start_mid(tier, g2, t)?;
        }
        Ok(())
    }

    // -- server (batcher + compute) ----------------------------------------

    fn up_delivered(&mut self, g: usize, hop: usize, t: SimTime)
        -> Result<()>
    {
        let tier = hop + 1;
        if tier < self.hops() {
            // A mid-chain tier: pay its segment compute, then forward.
            return self.enqueue_mid(tier, g, t);
        }
        self.frames[g].ready_at = t;
        self.offered.push(g);
        if let Some(batch) = self.batcher.offer(t) {
            // The size trigger fired: the batch holds batch.len()-1
            // previously queued requests plus this one, which was served
            // immediately and never counted as waiting.
            self.dec_queued(batch.len() - 1);
            self.enqueue_srv(batch, t)?;
        } else {
            self.inc_queued(1);
            if self.batcher.pending() == 1 {
                // The deadline is set by the oldest pending request; only
                // the request that *opens* a batch needs to arm the timer.
                if let Some(d) = self.batcher.deadline() {
                    self.q.schedule(d, Ev::BatchTimer);
                }
            }
        }
        Ok(())
    }

    fn batch_timer(&mut self, t: SimTime) -> Result<()> {
        if let Some(batch) = self.batcher.poll(t) {
            self.dec_queued(batch.len());
            self.enqueue_srv(batch, t)?;
        }
        Ok(())
    }

    fn enqueue_srv(&mut self, batch: Batch, t: SimTime) -> Result<()> {
        if self.srv_busy {
            self.inc_queued(batch.len());
            self.srv_q.push_back(batch);
            Ok(())
        } else {
            self.start_srv(batch, t)
        }
    }

    fn start_srv(&mut self, batch: Batch, t: SimTime) -> Result<()> {
        self.srv_busy = true;
        for req in &batch.requests {
            let g = self.offered[req.id as usize];
            let wait = t - self.frames[g].ready_at;
            self.frames[g].queue_wait_ns += wait;
        }
        let last = self.costs.seg_mult_adds.len() - 1;
        let dur = self
            .device(last)
            .compute_ns(batch.len() as u64 * self.costs.seg_mult_adds[last]);
        self.q.schedule(t + dur, Ev::ServerDone { batch });
        Ok(())
    }

    fn server_done(&mut self, batch: Batch, t: SimTime) -> Result<()> {
        self.srv_busy = false;
        let last_hop = self.hops() - 1;
        for req in &batch.requests {
            let g = self.offered[req.id as usize];
            if self.full_mode() {
                let payload = self.frames[g]
                    .payload
                    .take()
                    .ok_or_else(|| anyhow!("frame {g} lost its payload"))?;
                let exec = match &self.cfg.scenario.kind {
                    ScenarioKind::Rc => self.full_exec.as_ref().unwrap(),
                    ScenarioKind::Sc { .. } | ScenarioKind::Mc { .. } => {
                        self.tail_exec.as_ref().unwrap()
                    }
                    ScenarioKind::Lc => {
                        unreachable!("LC never reaches the server")
                    }
                };
                let logits = exec.run(&[RtInput::F32(&payload)])?;
                self.frames[g].pred = Some(logits.argmax_last()[0]);
            }
            self.enqueue_xfer(Dir::Down, last_hop, g, t)?;
        }
        if let Some(next) = self.srv_q.pop_front() {
            self.dec_queued(next.len());
            self.start_srv(next, t)?;
        }
        Ok(())
    }

    fn down_delivered(&mut self, g: usize, hop: usize, t: SimTime)
        -> Result<()>
    {
        if hop == 0 {
            self.complete(g, t);
            Ok(())
        } else {
            // Relay the result down the next hop toward the client.
            self.enqueue_xfer(Dir::Down, hop - 1, g, t)
        }
    }

    // -- completion --------------------------------------------------------

    fn complete(&mut self, g: usize, t: SimTime) {
        let fr = &mut self.frames[g];
        fr.completed_ns = t;
        fr.payload = None;
        self.completed += 1;
        let c = self.client_of(g);
        // Closed-loop source: emit the next frame on completion.
        if self.period() == 0 && self.next_frame[c] < self.fpc() {
            self.q.schedule(t, Ev::Emit { c });
        }
    }

    fn handle(&mut self, ev: Ev, t: SimTime) -> Result<()> {
        match ev {
            Ev::Emit { c } => self.emit(c, t),
            Ev::EdgeDone { c } => self.edge_done(c, t),
            Ev::NetFree { lane } => self.net_free(lane, t),
            Ev::UpDelivered { g, hop } => self.up_delivered(g, hop, t),
            Ev::MidDone { tier } => self.mid_done(tier, t),
            Ev::BatchTimer => self.batch_timer(t),
            Ev::ServerDone { batch } => self.server_done(batch, t),
            Ev::DownDelivered { g, hop } => self.down_delivered(g, hop, t),
        }
    }
}

/// The executable name serving the final segment of a cut chain: the
/// plain split tail for a single cut, the composed chain tail otherwise
/// (synthesized on demand by the analytic backend).
pub fn chain_tail_name(cuts: &[usize], batch: usize) -> String {
    if cuts.len() == 1 {
        format!("tail_L{}_b{batch}", cuts[0])
    } else {
        let mut name = "tail_chain".to_string();
        for c in cuts {
            name.push_str(&format!("_L{c}"));
        }
        name.push_str(&format!("_b{batch}"));
        name
    }
}

/// The executable name re-encoding the latent of cut `from` into the
/// latent of cut `to` on a mid-chain tier.
pub fn mid_exec_name(from: usize, to: usize, batch: usize) -> String {
    format!("mid_L{from}_L{to}_b{batch}")
}

/// Run the closed-loop streaming simulation.
///
/// `dataset: Some(_)` selects *full* mode (per-frame inference and
/// accuracy, the `run_scenario` path); `None` selects *latency-only* mode
/// (pure timing, the `simulate_latency` / Fig. 3 path). Deterministic in
/// `(cfg, engine seed)` alone.
pub fn run_stream(
    engine: &dyn InferenceBackend,
    cfg: &StreamConfig,
    dataset: Option<&Dataset>,
    qos: &QosRequirements,
) -> Result<StreamReport> {
    if cfg.clients == 0 {
        bail!("streaming needs at least one client");
    }
    if cfg.frames_per_client == 0 {
        bail!("streaming needs at least one frame per client");
    }
    if let Some(ds) = dataset {
        if ds.len() == 0 {
            bail!("streaming needs a non-empty dataset in full mode");
        }
    }
    let costs = costs(engine, &cfg.scenario)?;
    let num_classes = engine.manifest().model.num_classes;

    // Pre-load the executables used by this scenario (full mode only).
    let mut mid_execs: Vec<Rc<dyn Executable>> = Vec::new();
    let (full_exec, head_exec, tail_exec) = if dataset.is_some() {
        match &cfg.scenario.kind {
            ScenarioKind::Lc => {
                let name = if engine
                    .manifest()
                    .executables
                    .contains_key("full_fwd_lite_b1")
                {
                    "full_fwd_lite_b1"
                } else {
                    "full_fwd_b1"
                };
                (Some(engine.executable(name)?), None, None)
            }
            ScenarioKind::Rc => {
                (Some(engine.executable("full_fwd_b1")?), None, None)
            }
            ScenarioKind::Sc { split } => (
                None,
                Some(engine.executable(&format!("head_L{split}_b1"))?),
                Some(engine.executable(&format!("tail_L{split}_b1"))?),
            ),
            ScenarioKind::Mc { cuts } => {
                for w in cuts.windows(2) {
                    mid_execs.push(
                        engine.executable(&mid_exec_name(w[0], w[1], 1))?,
                    );
                }
                (
                    None,
                    Some(
                        engine
                            .executable(&format!("head_L{}_b1", cuts[0]))?,
                    ),
                    Some(engine.executable(&chain_tail_name(cuts, 1))?),
                )
            }
        }
    } else {
        (None, None, None)
    };

    let hops = costs.hops();
    let total = cfg.clients * cfg.frames_per_client;
    let n_tiers = costs.seg_mult_adds.len();
    let mut sim = Sim {
        cfg,
        dataset,
        full_exec,
        head_exec,
        mid_execs,
        tail_exec,
        zero_pred: Tensor::zeros(vec![1, num_classes]).argmax_last()[0],
        channels: (0..hops.max(1))
            .map(|h| Channel::new(cfg.scenario.hop_net(h)))
            .collect(),
        q: EventQueue::new(),
        frames: vec![Frame::default(); total],
        next_frame: vec![0; cfg.clients],
        edge_q: vec![VecDeque::new(); cfg.clients],
        edge_busy: vec![false; cfg.clients],
        edge_cur: vec![0; cfg.clients],
        mid_q: vec![VecDeque::new(); n_tiers],
        mid_busy: vec![false; n_tiers],
        mid_cur: vec![0; n_tiers],
        lane_q: vec![VecDeque::new(); 2 * hops.max(1)],
        lane_busy: vec![false; 2 * hops.max(1)],
        batcher: Batcher::new(cfg.batch),
        offered: Vec::new(),
        srv_q: VecDeque::new(),
        srv_busy: false,
        queued: 0,
        max_queued: 0,
        depth_area: 0.0,
        last_t: 0,
        completed: 0,
        costs,
    };

    for c in 0..cfg.clients {
        sim.q.schedule(0, Ev::Emit { c });
    }
    while sim.completed < total {
        let Some((t, ev)) = sim.q.pop() else {
            bail!(
                "streaming deadlock: {}/{} frames completed",
                sim.completed,
                total
            );
        };
        sim.depth_area += sim.queued as f64 * (t - sim.last_t) as f64;
        sim.last_t = t;
        sim.handle(ev, t)?;
    }

    let duration_ns = sim
        .frames
        .iter()
        .map(|f| f.completed_ns)
        .max()
        .unwrap_or(0);
    let stats = ResourceStats {
        duration_ns,
        throughput_fps: if duration_ns > 0 {
            total as f64 / secs(duration_ns)
        } else {
            0.0
        },
        mean_queue_depth: if duration_ns > 0 {
            sim.depth_area / duration_ns as f64
        } else {
            0.0
        },
        max_queue_depth: sim.max_queued,
        batches_released: sim.batcher.batches_released,
        batched_requests: sim.batcher.requests_seen,
    };
    let fpc = cfg.frames_per_client;
    let records: Vec<StreamFrameRecord> = sim
        .frames
        .iter()
        .enumerate()
        .map(|(g, f)| StreamFrameRecord {
            client: g / fpc.max(1),
            frame: g % fpc.max(1),
            emitted_ns: f.emitted_ns,
            completed_ns: f.completed_ns,
            latency_ns: f.completed_ns - f.emitted_ns,
            queue_wait_ns: f.queue_wait_ns,
            correct: if dataset.is_some() {
                Some(f.pred == Some(f.label))
            } else {
                None
            },
            wire_bytes: f.wire_bytes,
            retransmits: f.retransmits,
            corrupted: f.corrupted,
        })
        .collect();
    Ok(StreamReport::from_parts(
        cfg.clients,
        cfg.offered_fps(),
        records,
        stats,
        qos,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::scenario::ModelScale;
    use crate::model::DeviceProfile;
    use crate::netsim::transfer::NetworkConfig;
    use crate::runtime::load_backend;
    use std::path::Path;

    fn engine() -> Box<dyn InferenceBackend> {
        load_backend(Path::new("artifacts")).expect("backend")
    }

    fn scenario(period_ns: SimTime) -> ScenarioConfig {
        ScenarioConfig::two_tier(
            ScenarioKind::Rc,
            NetworkConfig::gigabit(Protocol::Udp, 0.0, 9),
            DeviceProfile::edge_gpu(),
            DeviceProfile::server_gpu(),
            ModelScale::Slim,
            period_ns,
        )
    }

    #[test]
    fn conserves_frames_across_clients() {
        let eng = engine();
        let cfg = StreamConfig {
            scenario: scenario(1_000_000),
            clients: 3,
            frames_per_client: 8,
            batch: BatchPolicy::new(4, 2_000_000),
        };
        let r = run_stream(&*eng, &cfg, None, &QosRequirements::none())
            .unwrap();
        assert_eq!(r.frames, 24);
        assert_eq!(r.stats.batched_requests, 24);
        assert!(r.records.iter().all(|f| f.completed_ns >= f.emitted_ns));
        // Every client stream is complete and ordered.
        for c in 0..3 {
            let mine: Vec<_> =
                r.records.iter().filter(|f| f.client == c).collect();
            assert_eq!(mine.len(), 8);
            for w in mine.windows(2) {
                assert!(w[1].frame == w[0].frame + 1);
                assert!(w[1].emitted_ns >= w[0].emitted_ns);
            }
        }
    }

    #[test]
    fn closed_loop_source_emits_on_completion() {
        let eng = engine();
        let cfg = StreamConfig {
            scenario: scenario(0),
            clients: 1,
            frames_per_client: 6,
            batch: BatchPolicy::immediate(),
        };
        let r = run_stream(&*eng, &cfg, None, &QosRequirements::none())
            .unwrap();
        assert_eq!(r.offered_fps, 0.0);
        for w in r.records.windows(2) {
            assert_eq!(
                w[1].emitted_ns, w[0].completed_ns,
                "closed-loop emission must follow completion"
            );
        }
        // No queueing in a closed loop with one client.
        assert!(r.records.iter().all(|f| f.queue_wait_ns == 0));
    }

    #[test]
    fn overload_builds_queues_low_load_does_not() {
        let eng = engine();
        // Service time per frame is bounded below by the server overhead
        // (150 µs) -> a 10 µs period is far past saturation.
        let slow = run_stream(
            &*eng,
            &StreamConfig {
                scenario: scenario(50_000_000),
                clients: 1,
                frames_per_client: 16,
                batch: BatchPolicy::immediate(),
            },
            None,
            &QosRequirements::none(),
        )
        .unwrap();
        let fast = run_stream(
            &*eng,
            &StreamConfig {
                scenario: scenario(10_000),
                clients: 1,
                frames_per_client: 16,
                batch: BatchPolicy::immediate(),
            },
            None,
            &QosRequirements::none(),
        )
        .unwrap();
        assert!(slow.records.iter().all(|f| f.queue_wait_ns == 0));
        // A contention-free run must report an empty peak queue.
        assert_eq!(slow.stats.max_queue_depth, 0);
        assert!(fast.mean_queue_wait_ns > 0.0);
        assert!(fast.mean_latency_ns > slow.mean_latency_ns);
        assert!(fast.stats.max_queue_depth > 0);
        // Throughput saturates below the offered rate.
        assert!(fast.stats.throughput_fps < 1e9 / 10_000.0);
    }

    #[test]
    fn latency_violation_is_definite_even_without_accuracy() {
        let eng = engine();
        // A 1 ns deadline nobody can meet plus an accuracy bound a
        // latency-only run cannot measure: the verdict must still be a
        // definite violation, not an open "no constraints".
        let qos = QosRequirements {
            max_latency_ns: Some(1),
            min_accuracy: Some(0.9),
            min_hit_rate: 1.0,
        };
        let cfg = StreamConfig {
            scenario: scenario(50_000_000),
            clients: 1,
            frames_per_client: 4,
            batch: BatchPolicy::immediate(),
        };
        let r = run_stream(&*eng, &cfg, None, &qos).unwrap();
        assert_eq!(r.deadline_hit_rate, Some(0.0));
        assert_eq!(r.qos_satisfied, Some(false));
        // With an achievable deadline the accuracy bound stays open.
        let loose = QosRequirements {
            max_latency_ns: Some(10_000_000_000),
            min_accuracy: Some(0.9),
            min_hit_rate: 1.0,
        };
        let r = run_stream(&*eng, &cfg, None, &loose).unwrap();
        assert_eq!(r.qos_satisfied, None);
    }

    #[test]
    fn zero_sized_runs_are_rejected() {
        let eng = engine();
        let mut cfg = StreamConfig {
            scenario: scenario(0),
            clients: 0,
            frames_per_client: 4,
            batch: BatchPolicy::immediate(),
        };
        assert!(run_stream(&*eng, &cfg, None, &QosRequirements::none())
            .is_err());
        cfg.clients = 1;
        cfg.frames_per_client = 0;
        assert!(run_stream(&*eng, &cfg, None, &QosRequirements::none())
            .is_err());
    }

    #[test]
    fn mc_needs_matching_tier_chain() {
        let eng = engine();
        let mut sc = scenario(0);
        sc.kind = ScenarioKind::Mc { cuts: vec![5, 9] };
        // 2 cuts over 2 tiers: rejected (needs 3).
        let cfg = StreamConfig {
            scenario: sc,
            clients: 1,
            frames_per_client: 2,
            batch: BatchPolicy::immediate(),
        };
        assert!(run_stream(&*eng, &cfg, None, &QosRequirements::none())
            .is_err());
    }

    #[test]
    fn three_tier_chain_runs_and_charges_every_hop() {
        let eng = engine();
        let mut sc = scenario(50_000_000);
        sc.kind = ScenarioKind::Mc { cuts: vec![5, 9] };
        sc.tiers = vec![
            DeviceProfile::sensor_npu(),
            DeviceProfile::edge_gpu(),
            DeviceProfile::server_gpu(),
        ];
        let cfg = StreamConfig {
            scenario: sc,
            clients: 1,
            frames_per_client: 4,
            batch: BatchPolicy::immediate(),
        };
        let r = run_stream(&*eng, &cfg, None, &QosRequirements::none())
            .unwrap();
        assert_eq!(r.frames, 4);
        // Two uplink hops + two downlink hops of wire traffic per frame:
        // strictly more than the single-hop SC equivalent at the deeper
        // cut alone.
        let mut sc1 = scenario(50_000_000);
        sc1.kind = ScenarioKind::Sc { split: 9 };
        let one = run_stream(
            &*eng,
            &StreamConfig {
                scenario: sc1,
                clients: 1,
                frames_per_client: 4,
                batch: BatchPolicy::immediate(),
            },
            None,
            &QosRequirements::none(),
        )
        .unwrap();
        assert!(r.mean_wire_bytes > one.mean_wire_bytes);
        assert!(r.mean_latency_ns > 0.0);
    }

    #[test]
    fn batching_amortizes_server_overhead() {
        let eng = engine();
        let mk = |batch: BatchPolicy| StreamConfig {
            scenario: scenario(200_000), // 5000 FPS offered
            clients: 4,
            frames_per_client: 12,
            batch,
        };
        let unbatched = run_stream(
            &*eng,
            &mk(BatchPolicy::immediate()),
            None,
            &QosRequirements::none(),
        )
        .unwrap();
        let batched = run_stream(
            &*eng,
            &mk(BatchPolicy::new(8, 1_000_000)),
            None,
            &QosRequirements::none(),
        )
        .unwrap();
        assert_eq!(unbatched.stats.mean_batch_size(), 1.0);
        assert!(batched.stats.mean_batch_size() > 1.0);
        assert_eq!(batched.frames, unbatched.frames);
    }
}
