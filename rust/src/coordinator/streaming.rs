//! Closed-loop, queueing, multi-client streaming simulator — the serving
//! path of the framework (paper Sec. IV-V, scaled to many sensing devices
//! and, since the multi-tier refactor, to pipelines spanning a chain of
//! device tiers).
//!
//! The original scenario engine was *open-loop*: frame `i` started at
//! `i * frame_period_ns` even when the edge device, the channel or the
//! server was still busy with frame `i-1`, so overload never showed up as
//! queueing delay and the latency judged against the QoS bound was wrong
//! exactly in the regime the framework exists to detect. This module is
//! the fix: a discrete-event, closed-loop simulator in which `N` client
//! streams emit frames into per-resource FIFO queues —
//!
//! ```text
//!   client c ─► [tier 0 compute c] ─► [hop 0 uplink] ─► [tier 1 compute]
//!                 ─► [hop 1 uplink] ─► … ─► [last tier: batcher+compute]
//!                                                            │
//!   client c ◄─ [hop 0 downlink] ◄─ … ◄─ [hop H-1 downlink] ◄┘
//! ```
//!
//! — so a frame's latency includes the time spent waiting behind earlier
//! frames and behind *other clients* on the shared resources, and
//! throughput saturates at the bottleneck resource instead of latency
//! staying flat under overload.
//!
//! Semantics:
//!
//! * **Sources.** Each client emits `frames_per_client` frames at a fixed
//!   period (`ScenarioConfig::frame_period_ns`). A period of 0 selects a
//!   *closed-loop source*: the next frame is emitted the instant the
//!   previous one completes (the "back-to-back" mode of the old engine,
//!   now with well-defined queueing semantics).
//! * **Tier 0.** Each client owns its sensing device; LC, SC and MC frames
//!   pay the first segment's compute there (FIFO per client). RC frames
//!   skip the stage, as in the per-frame pipeline.
//! * **Hops.** Every inter-tier hop is its own [`Channel`] (seeded via
//!   [`ScenarioConfig::hop_net`]), shared by all clients. Messages queue
//!   at message level ([`Channel::send_no_earlier`]): under UDP the two
//!   directions of a hop are independent FIFO resources (true full
//!   duplex, no reverse traffic); under TCP every message's ACK stream
//!   rides the opposite link of *its* hop, so TCP messages serialize
//!   across that hop — the same coupling the legacy engine expressed
//!   through its single clock. A slow mid-chain hop therefore saturates
//!   exactly like any other bottleneck resource.
//! * **Mid tiers.** MC's intermediate tiers are shared single-server FIFO
//!   resources: a frame pays `tiers[t].compute_ns(segment MACs)` and
//!   forwards its re-encoded latent up the next hop.
//! * **Last tier.** Requests arriving off the final uplink hop are fronted
//!   by the size-or-deadline [`Batcher`]; a released batch of `n` requests
//!   costs `server.compute_ns(n × segment MACs)`, amortizing the per-call
//!   overhead — with [`BatchPolicy::immediate`] this degenerates to the
//!   old per-frame cost exactly. Results return hop by hop in reverse
//!   over each hop's downlink.
//! * **Inference.** In full mode the per-frame tensors flow through the
//!   same executables and UDP corruption path as `run_scenario` always
//!   used (batching affects *timing* only; accuracy is measured with the
//!   per-frame `b1` executables). MC chains run `head → mid… → tail`
//!   segment executables, synthesized on demand by the analytic backend.
//!
//! With one client, batch size 1 and a period longer than the pipeline
//! latency, the closed-loop engine reproduces the open-loop per-frame
//! latencies *exactly* for UDP (any loss rate) and lossless TCP, and
//! drives byte-identical transfers in every case (asserted by
//! `rust/tests/streaming_properties.rs` against the retained
//! [`super::scenario::run_scenario_open_loop`] reference). Likewise,
//! `mc@[i]` over two tiers reproduces `sc@i` byte-identically — the
//! degenerate-equivalence anchor of the multi-tier refactor (pinned by
//! `rust/tests/multi_tier.rs`). Under lossy TCP the closed loop
//! additionally counts the time a result waits for the channel to drain
//! the upstream ACK tail — time the open-loop accounting silently
//! dropped — so those latencies are `>=` the legacy ones frame-by-frame.
//! Under overload the two engines deliberately diverge; that divergence
//! is the bug this engine fixes.
//!
//! **Scale & multi-tenancy.** The event core runs on a pluggable
//! [`EventQueue`] keyed by `(time, seq)`: a hierarchical timing wheel
//! ([`QueueKind::Wheel`], O(1) amortized, the 10^6-stream default for
//! benchmarks), an indexed binary-heap calendar, and the retained
//! [`QueueKind::LinearScan`] — all three extract the globally minimal
//! key, so the differential harness (`rust/tests/calendar_equivalence.rs`)
//! pins them byte-identical. Frame state lives in a struct-of-arrays
//! [`FrameArena`], seeded in one batched pass, with the model lanes
//! (payload / prediction / label) committed only in full mode; the
//! steady-state serve loop recycles batch request `Vec`s through the
//! batcher pool ([`Batcher::recycle`]) and runs allocation-free after
//! warm-up (asserted by the `alloc-count` smoke in
//! `benches/streaming_saturation.rs`). On top of the same core,
//! [`run_hetero_stream`] serves *heterogeneous* tenants — per-client
//! architecture, placement, scale, rate, DRR weight and QoS — through
//! one shared tier chain, with utilization-based admission control
//! (rejected streams emit nothing, leaving admitted streams bit-exact)
//! and optional deficit-round-robin fairness at every shared resource.

use std::collections::VecDeque;
use std::rc::Rc;

use anyhow::{anyhow, bail, Result};

use super::batcher::{Batch, BatchPolicy, Batcher, DrrBatcher, Request};
use super::corruption;
use super::drr::DrrQueue;
use super::qos::QosRequirements;
use super::scenario::{
    costs, derive_hop_net, kind_costs, reseed_hop_nets, Costs, FrameRecord,
    ModelScale, ScenarioConfig, ScenarioKind,
};
use crate::data::Dataset;
use crate::model::{Arch, DeviceProfile};
use crate::netsim::event::{secs, EventQueue, QueueKind, SimTime};
use crate::netsim::transfer::{Channel, NetworkConfig, Protocol};
use crate::netsim::Dir;
use crate::report::stats::{percentile, percentile_mut};
use crate::runtime::{Executable, InferenceBackend, RtInput};
use crate::tensor::Tensor;
use crate::util::json::Json;

/// Configuration of one streaming run.
#[derive(Clone, Debug)]
pub struct StreamConfig {
    /// Scenario under test. `scenario.frame_period_ns` is the per-client
    /// source period (0 = closed-loop back-to-back).
    pub scenario: ScenarioConfig,
    /// Number of concurrent client streams sharing the channels + server.
    pub clients: usize,
    /// Frames each client emits.
    pub frames_per_client: usize,
    /// Server-side dynamic batching policy ([`BatchPolicy::immediate`]
    /// reproduces unbatched per-frame serving).
    pub batch: BatchPolicy,
}

impl StreamConfig {
    /// The single-client, unbatched configuration `run_scenario` rides.
    pub fn single(scenario: &ScenarioConfig, n_frames: usize) -> StreamConfig {
        StreamConfig {
            scenario: scenario.clone(),
            clients: 1,
            frames_per_client: n_frames,
            batch: BatchPolicy::immediate(),
        }
    }

    /// Aggregate offered load over all clients, frames/s (0 when the
    /// sources are closed-loop).
    pub fn offered_fps(&self) -> f64 {
        if self.scenario.frame_period_ns == 0 {
            0.0
        } else {
            self.clients as f64 * 1e9 / self.scenario.frame_period_ns as f64
        }
    }
}

/// One served frame. `PartialEq`/`Eq` make byte-identity pins (the
/// calendar-vs-linear-scan and admission-isolation differential tests)
/// one-line assertions.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct StreamFrameRecord {
    pub client: usize,
    /// Per-client frame number.
    pub frame: usize,
    pub emitted_ns: SimTime,
    pub completed_ns: SimTime,
    /// End-to-end latency including all queue waits.
    pub latency_ns: SimTime,
    /// Time spent waiting in queues (tiers, hop lanes, batcher+server),
    /// i.e. the part of `latency_ns` the open-loop model lost.
    pub queue_wait_ns: SimTime,
    /// `None` in latency-only runs.
    pub correct: Option<bool>,
    pub wire_bytes: u64,
    pub retransmits: u64,
    pub corrupted: bool,
}

/// Resource-level aggregates of one run (or the merge of several seeds).
#[derive(Clone, Copy, Debug, Default)]
pub struct ResourceStats {
    /// Simulated time from the first emission (t = 0) to the last
    /// completion.
    pub duration_ns: SimTime,
    /// Achieved throughput: completed frames / duration.
    pub throughput_fps: f64,
    /// Time-averaged number of frames waiting in queues.
    pub mean_queue_depth: f64,
    /// Peak number of frames waiting in queues.
    pub max_queue_depth: usize,
    pub batches_released: u64,
    /// Requests that went through the batcher (frames with an uplink leg).
    pub batched_requests: u64,
    /// Discrete events the simulator processed (the numerator of the
    /// events/sec engine-throughput metric in `benches/streaming_saturation`).
    pub events_processed: u64,
}

impl ResourceStats {
    pub fn mean_batch_size(&self) -> f64 {
        if self.batches_released == 0 {
            0.0
        } else {
            self.batched_requests as f64 / self.batches_released as f64
        }
    }
}

/// The reduced result of a streaming run.
#[derive(Clone, Debug)]
pub struct StreamReport {
    pub clients: usize,
    /// Aggregate offered load, frames/s (0 = closed-loop sources).
    pub offered_fps: f64,
    pub frames: usize,
    /// `None` in latency-only runs.
    pub accuracy: Option<f64>,
    pub mean_latency_ns: f64,
    pub p50_latency_ns: SimTime,
    pub p95_latency_ns: SimTime,
    pub p99_latency_ns: SimTime,
    pub max_latency_ns: SimTime,
    pub mean_queue_wait_ns: f64,
    pub mean_wire_bytes: f64,
    pub total_retransmits: u64,
    /// Fraction of frames meeting the latency bound (if one is set).
    pub deadline_hit_rate: Option<f64>,
    /// Hit-rate-based QoS verdict; `None` without checkable constraints.
    pub qos_satisfied: Option<bool>,
    pub stats: ResourceStats,
    pub records: Vec<StreamFrameRecord>,
}

impl StreamReport {
    fn from_parts(
        clients: usize,
        offered_fps: f64,
        records: Vec<StreamFrameRecord>,
        stats: ResourceStats,
        qos: &QosRequirements,
    ) -> StreamReport {
        let n = records.len().max(1);
        let mut lat: Vec<SimTime> =
            records.iter().map(|r| r.latency_ns).collect();
        lat.sort_unstable();
        let mean_latency_ns =
            lat.iter().map(|&v| v as f64).sum::<f64>() / n as f64;
        let measured = records.iter().all(|r| r.correct.is_some())
            && !records.is_empty();
        let accuracy = if measured {
            Some(
                records.iter().filter(|r| r.correct == Some(true)).count()
                    as f64
                    / n as f64,
            )
        } else {
            None
        };
        let deadline_hit_rate = qos.max_latency_ns.map(|m| {
            records.iter().filter(|r| r.latency_ns <= m).count() as f64
                / n as f64
        });
        // A measured latency violation is a definite verdict even when an
        // accuracy bound exists but accuracy was not measured; only a
        // *passing* latency check with an uncheckable accuracy bound
        // leaves the verdict open.
        let latency_ok = qos.latency_ok(deadline_hit_rate);
        let qos_satisfied =
            match (qos.max_latency_ns, qos.min_accuracy, accuracy) {
                (None, None, _) => None,
                _ if !latency_ok => Some(false),
                // Latency passes; an accuracy bound is uncheckable
                // without inference, so leave the verdict open rather
                // than claiming "ok".
                (_, Some(_), None) => None,
                (_, _, acc) => Some(
                    qos.satisfied_by(deadline_hit_rate, acc.unwrap_or(1.0)),
                ),
            };
        StreamReport {
            clients,
            offered_fps,
            frames: records.len(),
            accuracy,
            mean_latency_ns,
            p50_latency_ns: percentile(&lat, 0.50),
            p95_latency_ns: percentile(&lat, 0.95),
            p99_latency_ns: percentile(&lat, 0.99),
            max_latency_ns: lat.last().copied().unwrap_or(0),
            mean_queue_wait_ns: records
                .iter()
                .map(|r| r.queue_wait_ns as f64)
                .sum::<f64>()
                / n as f64,
            mean_wire_bytes: records
                .iter()
                .map(|r| r.wire_bytes as f64)
                .sum::<f64>()
                / n as f64,
            total_retransmits: records.iter().map(|r| r.retransmits).sum(),
            deadline_hit_rate,
            qos_satisfied,
            stats,
            records,
        }
    }

    /// View the per-frame records as scenario-engine [`FrameRecord`]s (in
    /// deterministic (client, frame) order).
    pub fn to_frame_records(&self) -> Vec<FrameRecord> {
        self.records
            .iter()
            .map(|r| FrameRecord {
                latency_ns: r.latency_ns,
                completed_ns: r.completed_ns,
                correct: r.correct.unwrap_or(false),
                wire_bytes: r.wire_bytes,
                retransmits: r.retransmits,
                corrupted: r.corrupted,
            })
            .collect()
    }

    /// Human-readable serving summary.
    pub fn render(&self, qos: &QosRequirements) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "clients            {} ({} frames total)",
            self.clients, self.frames
        ));
        if self.offered_fps > 0.0 {
            out.push_str(&format!(
                " @ {:.1} FPS offered (aggregate)",
                self.offered_fps
            ));
        } else {
            out.push_str(" (closed-loop sources)");
        }
        out.push('\n');
        out.push_str(&format!(
            "throughput         {:.1} FPS over {:.2} s simulated\n",
            self.stats.throughput_fps,
            secs(self.stats.duration_ns)
        ));
        if let Some(acc) = self.accuracy {
            out.push_str(&format!(
                "accuracy           {:.2}%\n",
                acc * 100.0
            ));
        }
        out.push_str(&format!(
            "latency            mean {:.2} ms | p50 {:.2} ms | p95 {:.2} ms \
             | p99 {:.2} ms | max {:.2} ms\n",
            self.mean_latency_ns / 1e6,
            self.p50_latency_ns as f64 / 1e6,
            self.p95_latency_ns as f64 / 1e6,
            self.p99_latency_ns as f64 / 1e6,
            self.max_latency_ns as f64 / 1e6,
        ));
        out.push_str(&format!(
            "queueing           mean wait {:.2} ms/frame | depth mean \
             {:.1} / max {}\n",
            self.mean_queue_wait_ns / 1e6,
            self.stats.mean_queue_depth,
            self.stats.max_queue_depth,
        ));
        if self.stats.batches_released > 0 {
            out.push_str(&format!(
                "batching           {} batches, mean size {:.2}\n",
                self.stats.batches_released,
                self.stats.mean_batch_size(),
            ));
        }
        out.push_str(&format!(
            "wire traffic       {:.0} B/frame, {} retransmits total\n",
            self.mean_wire_bytes, self.total_retransmits
        ));
        if let Some(hit) = self.deadline_hit_rate {
            out.push_str(&format!(
                "deadline hit-rate  {:.1}% of frames\n",
                hit * 100.0
            ));
        }
        out.push_str(&format!("QoS ({})\n", qos.describe()));
        let has_constraints =
            qos.max_latency_ns.is_some() || qos.min_accuracy.is_some();
        out.push_str(&format!(
            "VERDICT            {}\n",
            match self.qos_satisfied {
                Some(true) => "SATISFIED",
                Some(false) => "VIOLATED",
                // Constraints exist but the accuracy bound was not
                // measurable in this run (latency-only): the verdict is
                // deliberately open, not absent.
                None if has_constraints => "OPEN (accuracy not measured)",
                None => "no constraints",
            }
        ));
        out
    }
}

/// Run `cfg` once per seed (via [`ScenarioConfig::set_base_seed`], which
/// re-derives every hop's channel seed) and merge the results into one
/// pooled report — the streaming analogue of
/// [`super::sweep::pooled_scenario`].
pub fn pooled_stream(
    engine: &dyn InferenceBackend,
    cfg: &StreamConfig,
    dataset: Option<&Dataset>,
    seeds: &[u64],
    qos: &QosRequirements,
) -> Result<StreamReport> {
    pooled_stream_with_queue(engine, cfg, dataset, seeds, qos,
                             QueueKind::Calendar)
}

/// [`pooled_stream`] with an explicit event-queue backend (the sweep
/// spec's `"queue"` key). Backend choice never changes results.
pub fn pooled_stream_with_queue(
    engine: &dyn InferenceBackend,
    cfg: &StreamConfig,
    dataset: Option<&Dataset>,
    seeds: &[u64],
    qos: &QosRequirements,
    queue: QueueKind,
) -> Result<StreamReport> {
    if seeds.is_empty() {
        bail!("pooled_stream needs at least one seed");
    }
    let mut reports = Vec::with_capacity(seeds.len());
    let mut c = cfg.clone();
    for &seed in seeds {
        c.scenario.set_base_seed(seed);
        reports.push(run_stream_with_queue(engine, &c, dataset, qos, queue)?);
    }
    Ok(merge_stream_reports(
        cfg.clients,
        cfg.offered_fps(),
        reports,
        qos,
    ))
}

/// Merge per-seed reports into one pooled report: duration and peak depth
/// take the max, rates average, counters sum, records concatenate.
fn merge_stream_reports(
    clients: usize,
    offered_fps: f64,
    reports: Vec<StreamReport>,
    qos: &QosRequirements,
) -> StreamReport {
    let k = reports.len().max(1);
    let stats = ResourceStats {
        duration_ns: reports
            .iter()
            .map(|r| r.stats.duration_ns)
            .max()
            .unwrap_or(0),
        throughput_fps: reports
            .iter()
            .map(|r| r.stats.throughput_fps)
            .sum::<f64>()
            / k as f64,
        mean_queue_depth: reports
            .iter()
            .map(|r| r.stats.mean_queue_depth)
            .sum::<f64>()
            / k as f64,
        max_queue_depth: reports
            .iter()
            .map(|r| r.stats.max_queue_depth)
            .max()
            .unwrap_or(0),
        // Saturating folds: at fleet scale (10^6 streams x many seeds) a
        // wrapping `sum()` would silently produce a tiny bogus count in
        // release builds; a pinned ceiling is at least visibly wrong.
        batches_released: reports
            .iter()
            .fold(0u64, |a, r| a.saturating_add(r.stats.batches_released)),
        batched_requests: reports
            .iter()
            .fold(0u64, |a, r| a.saturating_add(r.stats.batched_requests)),
        events_processed: reports
            .iter()
            .fold(0u64, |a, r| a.saturating_add(r.stats.events_processed)),
    };
    let records: Vec<StreamFrameRecord> =
        reports.into_iter().flat_map(|r| r.records).collect();
    StreamReport::from_parts(clients, offered_fps, records, stats, qos)
}

// ---------------------------------------------------------------------------
// Heterogeneous multi-tenant serving.
// ---------------------------------------------------------------------------

/// Queue-service discipline at the shared resources (hop lanes, mid-chain
/// tiers and the server-side batcher).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Fairness {
    /// Plain arrival order — one aggressive tenant can starve the rest.
    Fifo,
    /// Deficit round robin over clients ([`super::drr::DrrQueue`]):
    /// byte-costed at the lanes, MAC-costed at the mid tiers, per-request
    /// at the batcher. Bounds any tenant's wait behind another tenant's
    /// burst to ~one weighted round.
    Drr,
}

/// One tenant stream of a heterogeneous serving run.
#[derive(Clone, Debug)]
pub struct ClientSpec {
    /// Placement of this client's pipeline (LC / SC / RC / MC).
    pub kind: ScenarioKind,
    /// Model family this client runs (must have a loaded backend).
    pub arch: Arch,
    pub scale: ModelScale,
    /// Source period; 0 = closed-loop (emit on completion).
    pub frame_period_ns: SimTime,
    /// Frames this client emits.
    pub frames: usize,
    /// DRR weight (service share relative to other clients; min 1).
    pub weight: u64,
    /// Per-tenant QoS, judged per client in the report.
    pub qos: QosRequirements,
}

impl ClientSpec {
    /// A single open-loop slim-VGG16 client of the given kind; adjust
    /// fields as needed.
    pub fn new(kind: ScenarioKind) -> ClientSpec {
        ClientSpec {
            kind,
            arch: Arch::Vgg16,
            scale: ModelScale::Slim,
            frame_period_ns: 0,
            frames: 1,
            weight: 1,
            qos: QosRequirements::none(),
        }
    }
}

/// Configuration of a heterogeneous multi-tenant streaming run: every
/// client brings its own architecture, placement, scale, rate and QoS;
/// the physical tier chain, per-hop channels and batcher are shared.
#[derive(Clone, Debug)]
pub struct MultiStreamConfig {
    pub clients: Vec<ClientSpec>,
    /// One [`NetworkConfig`] per inter-tier hop, or a single template
    /// replicated with per-hop derived seeds (see
    /// [`ScenarioConfig::hop_net`] for the same rule on the homogeneous
    /// path).
    pub hop_nets: Vec<NetworkConfig>,
    /// The shared physical device chain (tier 0 is per-client hardware of
    /// this profile; the last tier hosts the batcher).
    pub tiers: Vec<DeviceProfile>,
    pub batch: BatchPolicy,
    pub fairness: Fairness,
    /// Reject streams the bottleneck resource provably cannot serve
    /// (utilization > 1 under lower-bound service times). Rejected
    /// streams emit nothing, so admitted streams behave exactly as if
    /// the rejected ones were never offered.
    pub admission: bool,
    /// Event-queue backend (the calendar unless a differential test asks
    /// for the retained linear scan).
    pub queue: QueueKind,
}

impl MultiStreamConfig {
    /// Re-derive every hop's channel seed from `seed` (same derivation as
    /// [`ScenarioConfig::set_base_seed`]).
    pub fn set_base_seed(&mut self, seed: u64) {
        reseed_hop_nets(&mut self.hop_nets, seed);
    }

    /// Attach time-varying [`crate::netsim::LinkTrace`]s to this mix's
    /// hops, materializing a single-entry template first (same contract
    /// as [`ScenarioConfig::apply_traces`], but the hop count comes from
    /// the shared tier chain).
    pub fn apply_traces(
        &mut self,
        traces: &[(usize, crate::netsim::LinkTrace)],
    ) -> Result<()> {
        let hops = self.tiers.len().saturating_sub(1).max(1);
        super::scenario::apply_hop_traces(&mut self.hop_nets, hops, traces)
    }

    /// Aggregate offered load over the open-loop clients, frames/s.
    pub fn offered_fps(&self) -> f64 {
        self.clients
            .iter()
            .filter(|s| s.frame_period_ns > 0)
            .map(|s| 1e9 / s.frame_period_ns as f64)
            .sum()
    }
}

/// Per-tenant verdict of a heterogeneous run.
#[derive(Clone, Debug)]
pub struct ClientOutcome {
    pub client: usize,
    /// "kind arch scale" tag for rendering.
    pub label: String,
    pub admitted: bool,
    pub reject_reason: Option<String>,
    pub frames: usize,
    pub accuracy: Option<f64>,
    pub mean_latency_ns: f64,
    pub p95_latency_ns: SimTime,
    pub max_latency_ns: SimTime,
    pub deadline_hit_rate: Option<f64>,
    /// Judged against this client's own [`ClientSpec::qos`].
    pub qos_satisfied: Option<bool>,
}

/// Result of [`run_hetero_stream`]: the shared-infrastructure aggregate
/// plus one outcome per offered client (admitted or not).
#[derive(Clone, Debug)]
pub struct HeteroStreamReport {
    pub outcomes: Vec<ClientOutcome>,
    pub aggregate: StreamReport,
}

impl HeteroStreamReport {
    pub fn admitted(&self) -> usize {
        self.outcomes.iter().filter(|o| o.admitted).count()
    }

    /// Human-readable multi-tenant summary (aggregate + per-client).
    pub fn render(&self, qos: &QosRequirements) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "tenants            {} offered, {} admitted\n",
            self.outcomes.len(),
            self.admitted()
        ));
        out.push_str(&self.aggregate.render(qos));
        out.push_str("per-client\n");
        const SHOWN: usize = 32;
        for o in self.outcomes.iter().take(SHOWN) {
            match &o.reject_reason {
                Some(r) => out.push_str(&format!(
                    "  [{}] {:<22} {}\n",
                    o.client, o.label, r
                )),
                None => {
                    out.push_str(&format!(
                        "  [{}] {:<22} {} frames | mean {:.2} ms | p95 \
                         {:.2} ms | max {:.2} ms",
                        o.client,
                        o.label,
                        o.frames,
                        o.mean_latency_ns / 1e6,
                        o.p95_latency_ns as f64 / 1e6,
                        o.max_latency_ns as f64 / 1e6,
                    ));
                    if let Some(hit) = o.deadline_hit_rate {
                        out.push_str(&format!(" | hit {:.1}%", hit * 100.0));
                    }
                    if let Some(acc) = o.accuracy {
                        out.push_str(&format!(" | acc {:.1}%", acc * 100.0));
                    }
                    out.push_str(match o.qos_satisfied {
                        Some(true) => " | OK",
                        Some(false) => " | VIOLATED",
                        None => "",
                    });
                    out.push('\n');
                }
            }
        }
        if self.outcomes.len() > SHOWN {
            out.push_str(&format!(
                "  ... and {} more clients\n",
                self.outcomes.len() - SHOWN
            ));
        }
        out
    }
}

/// Run a heterogeneous config once per seed and merge the aggregates —
/// the multi-tenant analogue of [`pooled_stream`].
pub fn pooled_hetero_stream(
    engines: &[(Arch, &dyn InferenceBackend)],
    cfg: &MultiStreamConfig,
    dataset: Option<&Dataset>,
    seeds: &[u64],
    qos: &QosRequirements,
) -> Result<StreamReport> {
    if seeds.is_empty() {
        bail!("pooled_hetero_stream needs at least one seed");
    }
    let mut reports = Vec::with_capacity(seeds.len());
    // One working copy, re-seeded per run: `set_base_seed` re-derives
    // every hop from the base seed alone, so reusing the copy is
    // byte-identical to cloning per seed — without duplicating a
    // 10^6-entry client table once per seed.
    let mut c = cfg.clone();
    for &seed in seeds {
        c.set_base_seed(seed);
        reports.push(run_hetero_stream(engines, &c, dataset, qos)?.aggregate);
    }
    Ok(merge_stream_reports(
        cfg.clients.len(),
        cfg.offered_fps(),
        reports,
        qos,
    ))
}

// ---------------------------------------------------------------------------
// The discrete-event simulator.
// ---------------------------------------------------------------------------

enum Ev {
    /// Client `c` emits its next frame.
    Emit { c: usize },
    /// Client `c`'s tier-0 device finished its current frame.
    EdgeDone { c: usize },
    /// Transfer lane `lane` (hop `lane / 2`) is free for the next message.
    NetFree { lane: usize },
    /// Frame `g`'s uplink payload fully arrived at tier `hop + 1`.
    UpDelivered { g: usize, hop: usize },
    /// Shared mid-chain tier `tier` finished its current frame.
    MidDone { tier: usize },
    /// Size-or-deadline batcher poll point.
    BatchTimer,
    /// The server finished computing `batch`.
    ServerDone { batch: Batch },
    /// Frame `g`'s result arrived back at tier `hop` (0 = the client).
    DownDelivered { g: usize, hop: usize },
    /// Hop `hop`'s [`LinkTrace`] enters a new segment. Scheduled upfront
    /// (one event per boundary) only for hops whose trace has more than
    /// one segment, so constant traces leave the event stream — and
    /// therefore `events_processed` and every sequence-number tiebreak —
    /// byte-identical to the untraced engine. The links themselves sample
    /// the trace lazily at send time; this event exists so the calendar
    /// *sees* the boundary (waking the simulation even when idle, and
    /// giving adaptive controllers a deterministic observation point).
    TraceBoundary { hop: usize },
}

/// Frame state in struct-of-arrays layout: one arena entry per frame,
/// indexed by the global frame id `g`. The hot per-event fields
/// (`ready_at`, `queue_wait_ns`, timing counters) live in dense parallel
/// `Vec`s instead of one AoS struct, so a 10^5-stream run touches only
/// the lanes of cache it actually needs per event; `owner`/`fidx` give
/// O(1) frame -> client mapping for heterogeneous (ragged) stream sizes.
struct FrameArena {
    emitted_ns: Vec<SimTime>,
    completed_ns: Vec<SimTime>,
    queue_wait_ns: Vec<SimTime>,
    /// When the frame entered its current queue (reused per stage).
    ready_at: Vec<SimTime>,
    wire_bytes: Vec<u64>,
    retransmits: Vec<u64>,
    corrupted: Vec<bool>,
    /// In-flight tensor (input for RC, latent for SC/MC) in full mode.
    payload: Vec<Option<Tensor>>,
    pred: Vec<Option<usize>>,
    label: Vec<usize>,
    /// Owning client of each frame.
    owner: Vec<u32>,
    /// Per-client frame number of each frame.
    fidx: Vec<u32>,
}

impl FrameArena {
    /// Batched seeding: lay out every client's frames contiguously in
    /// client order (`g = start[c] + f`) in one pass. Latency-only runs
    /// (`full = false`) never read or write `payload`/`pred`/`label`, so
    /// those lanes stay empty instead of committing `total` dead entries
    /// — at 10^6 streams that is the difference between the arena fitting
    /// in cache-friendly timing lanes and dragging an unused model lane
    /// through every miss.
    fn seeded(fpc: &[usize], full: bool) -> FrameArena {
        let total: usize = fpc.iter().sum();
        let model = if full { total } else { 0 };
        let mut owner = Vec::with_capacity(total);
        let mut fidx = Vec::with_capacity(total);
        for (c, &k) in fpc.iter().enumerate() {
            for f in 0..k {
                owner.push(c as u32);
                fidx.push(f as u32);
            }
        }
        FrameArena {
            emitted_ns: vec![0; total],
            completed_ns: vec![0; total],
            queue_wait_ns: vec![0; total],
            ready_at: vec![0; total],
            wire_bytes: vec![0; total],
            retransmits: vec![0; total],
            corrupted: vec![false; total],
            payload: vec![None; model],
            pred: vec![None; model],
            label: vec![0; model],
            owner,
            fidx,
        }
    }
}

/// A shared-resource queue under the configured [`Fairness`] discipline.
enum MultiQueue<T> {
    Fifo(VecDeque<T>),
    Drr(DrrQueue<T>),
}

impl<T> MultiQueue<T> {
    fn push(&mut self, client: usize, cost: u64, item: T) {
        match self {
            MultiQueue::Fifo(q) => q.push_back(item),
            MultiQueue::Drr(q) => q.push(client, cost, item),
        }
    }

    fn pop(&mut self) -> Option<T> {
        match self {
            MultiQueue::Fifo(q) => q.pop_front(),
            MultiQueue::Drr(q) => q.pop(),
        }
    }
}

fn new_multi_queue<T>(
    fairness: Fairness,
    weights: &[u64],
    quantum: u64,
) -> MultiQueue<T> {
    match fairness {
        Fairness::Fifo => MultiQueue::Fifo(VecDeque::new()),
        Fairness::Drr => MultiQueue::Drr(DrrQueue::new(weights, quantum)),
    }
}

/// The server-side batching front under the configured [`Fairness`]:
/// identical release triggers and batch membership, DRR only reorders
/// requests *within* a batch (see [`DrrBatcher`]).
enum Front {
    Fifo(Batcher),
    Drr(DrrBatcher),
}

impl Front {
    fn pending(&self) -> usize {
        match self {
            Front::Fifo(b) => b.pending(),
            Front::Drr(b) => b.pending(),
        }
    }

    fn offer(&mut self, client: usize, now: SimTime) -> Option<Batch> {
        match self {
            Front::Fifo(b) => b.offer(now),
            Front::Drr(b) => b.offer(client, now),
        }
    }

    fn deadline(&self) -> Option<SimTime> {
        match self {
            Front::Fifo(b) => b.deadline(),
            Front::Drr(b) => b.deadline(),
        }
    }

    fn poll(&mut self, now: SimTime) -> Option<Batch> {
        match self {
            Front::Fifo(b) => b.poll(now),
            Front::Drr(b) => b.poll(now),
        }
    }

    /// Return a served batch's spent request storage to the batcher pool
    /// ([`Batcher::recycle`]): the steady-state serve loop then circulates
    /// a fixed set of request `Vec`s between the batcher and the in-flight
    /// batches instead of growing a fresh one per release.
    fn recycle(&mut self, spent: Vec<Request>) {
        match self {
            Front::Fifo(b) => b.recycle(spent),
            Front::Drr(b) => b.recycle(spent),
        }
    }

    fn batches_released(&self) -> u64 {
        match self {
            Front::Fifo(b) => b.batches_released,
            Front::Drr(b) => b.batches_released,
        }
    }

    fn requests_seen(&self) -> u64 {
        match self {
            Front::Fifo(b) => b.requests_seen,
            Front::Drr(b) => b.requests_seen,
        }
    }
}

/// Resolved execution profile of one `(arch, kind, scale)` combination,
/// shared by every client running that combination.
struct Profile {
    kind: ScenarioKind,
    costs: Costs,
    full_exec: Option<Rc<dyn Executable>>,
    head_exec: Option<Rc<dyn Executable>>,
    /// MC mid-segment executables (`mid_execs[t - 1]` runs on tier `t`).
    mid_execs: Vec<Rc<dyn Executable>>,
    tail_exec: Option<Rc<dyn Executable>>,
    /// `argmax` of an all-zero logits tensor — the prediction a frame is
    /// left with when its UDP result datagram is fully lost.
    zero_pred: usize,
}

/// Fully resolved per-client inputs of one simulation, shared between the
/// homogeneous ([`run_stream`]) and heterogeneous ([`run_hetero_stream`])
/// entry points.
struct StreamSetup<'a> {
    profiles: Vec<Profile>,
    /// Per-client profile index.
    prof: Vec<usize>,
    /// Per-client source period (0 = closed loop).
    period: Vec<SimTime>,
    /// Per-client frame count (0 = rejected by admission: emits nothing).
    fpc: Vec<usize>,
    /// Per-client DRR weight.
    weight: Vec<u64>,
    /// The shared physical device chain.
    tiers: Vec<DeviceProfile>,
    batch: BatchPolicy,
    fairness: Fairness,
    queue: QueueKind,
    dataset: Option<&'a Dataset>,
}

/// Which transfer lane a (hop, direction) pair uses: a TCP hop shares
/// one lane (ACK entanglement serializes the hop), a UDP hop gets one
/// lane per direction (full duplex). With heterogeneous `hop_nets`
/// each hop follows *its own* channel's transport.
fn lane_index(channels: &[Channel], hop: usize, dir: Dir) -> usize {
    let local = match (channels[hop].cfg.protocol, dir) {
        (Protocol::Tcp, _) => 0,
        (Protocol::Udp, Dir::Up) => 0,
        (Protocol::Udp, Dir::Down) => 1,
    };
    hop * 2 + local
}

struct Sim<'a> {
    setup: &'a StreamSetup<'a>,
    /// Per-client arena offset (`g = start[c] + f`).
    start: Vec<usize>,
    /// One channel per inter-tier hop (hop 0 keeps the configured seed).
    channels: Vec<Channel>,
    q: EventQueue<Ev>,
    arena: FrameArena,
    /// Per-client next frame index to emit.
    next_frame: Vec<usize>,
    edge_q: Vec<VecDeque<usize>>,
    edge_busy: Vec<bool>,
    edge_cur: Vec<usize>,
    /// Shared mid-chain tier resources, indexed by tier (0 and the last
    /// tier are unused — they have their own machinery).
    mid_q: Vec<MultiQueue<usize>>,
    mid_busy: Vec<bool>,
    mid_cur: Vec<usize>,
    /// Transfer lanes, two per hop (see [`lane_index`]).
    lane_q: Vec<MultiQueue<(Dir, usize)>>,
    lane_busy: Vec<bool>,
    front: Front,
    /// Batcher request id -> global frame index (ids are sequential).
    offered: Vec<usize>,
    srv_q: VecDeque<Batch>,
    srv_busy: bool,
    // Queue-depth accounting (time-weighted over the event timeline).
    queued: usize,
    max_queued: usize,
    depth_area: f64,
    last_t: SimTime,
    completed: usize,
}

impl<'a> Sim<'a> {
    fn full_mode(&self) -> bool {
        self.setup.dataset.is_some()
    }

    fn prof_of(&self, c: usize) -> &Profile {
        &self.setup.profiles[self.setup.prof[c]]
    }

    fn costs_of(&self, c: usize) -> &Costs {
        &self.prof_of(c).costs
    }

    fn fpc(&self, c: usize) -> usize {
        self.setup.fpc[c]
    }

    fn client_of(&self, g: usize) -> usize {
        self.arena.owner[g] as usize
    }

    fn fidx(&self, g: usize) -> usize {
        self.arena.fidx[g] as usize
    }

    /// Number of inter-tier hops in client `c`'s pipeline.
    fn hops_of(&self, c: usize) -> usize {
        self.costs_of(c).hops()
    }

    /// The device executing pipeline segment `seg` of client `c` (RC/SC
    /// on a longer chain bypass the middle tiers: first and last device
    /// only).
    fn device(&self, c: usize, seg: usize) -> &DeviceProfile {
        let tiers = &self.setup.tiers;
        if seg == 0 {
            &tiers[0]
        } else if seg + 1 == self.costs_of(c).seg_mult_adds.len() {
            tiers.last().expect("validated by costs()")
        } else {
            &tiers[seg]
        }
    }

    fn input(&self, g: usize) -> Result<Tensor> {
        let ds = self.setup.dataset.ok_or_else(|| anyhow!("no dataset"))?;
        let f = self.fidx(g);
        ds.batch(f % ds.len(), 1)
    }

    fn lane_of(&self, hop: usize, dir: Dir) -> usize {
        lane_index(&self.channels, hop, dir)
    }

    // -- queue-depth bookkeeping -------------------------------------------

    fn inc_queued(&mut self, by: usize) {
        self.queued += by;
        self.max_queued = self.max_queued.max(self.queued);
    }

    fn dec_queued(&mut self, by: usize) {
        debug_assert!(self.queued >= by);
        self.queued -= by;
    }

    // -- sources -----------------------------------------------------------

    fn emit(&mut self, c: usize, t: SimTime) -> Result<()> {
        let f = self.next_frame[c];
        debug_assert!(f < self.fpc(c));
        self.next_frame[c] = f + 1;
        let g = self.start[c] + f;
        self.arena.emitted_ns[g] = t;
        let period = self.setup.period[c];
        if period > 0 && f + 1 < self.fpc(c) {
            self.q.schedule(t + period, Ev::Emit { c });
        }
        let is_rc = matches!(self.prof_of(c).kind, ScenarioKind::Rc);
        if let Some(ds) = self.setup.dataset {
            self.arena.label[g] = ds.labels[f % ds.len()] as usize;
            if is_rc {
                // The RC uplink payload is the raw input frame.
                let x = self.input(g)?;
                self.arena.payload[g] = Some(x);
            }
        }
        if is_rc {
            self.enqueue_xfer(Dir::Up, 0, g, t)
        } else {
            self.enqueue_edge(c, g, t)
        }
    }

    // -- tier-0 compute (one device per client) ----------------------------

    fn enqueue_edge(&mut self, c: usize, g: usize, t: SimTime) -> Result<()> {
        self.arena.ready_at[g] = t;
        if self.edge_busy[c] {
            self.edge_q[c].push_back(g);
            self.inc_queued(1);
            Ok(())
        } else {
            self.start_edge(c, g, t)
        }
    }

    fn start_edge(&mut self, c: usize, g: usize, t: SimTime) -> Result<()> {
        self.edge_busy[c] = true;
        self.edge_cur[c] = g;
        let wait = t - self.arena.ready_at[g];
        self.arena.queue_wait_ns[g] += wait;
        let ma = self.costs_of(c).seg_mult_adds[0];
        let dur = self.device(c, 0).compute_ns(ma);
        self.q.schedule(t + dur, Ev::EdgeDone { c });
        Ok(())
    }

    fn edge_done(&mut self, c: usize, t: SimTime) -> Result<()> {
        let g = self.edge_cur[c];
        self.edge_busy[c] = false;
        if self.full_mode() {
            let is_lc = matches!(self.prof_of(c).kind, ScenarioKind::Lc);
            let x = self.input(g)?;
            if is_lc {
                let exec = self
                    .prof_of(c)
                    .full_exec
                    .clone()
                    .expect("LC executable preloaded");
                let logits = exec.run(&[RtInput::F32(&x)])?;
                self.arena.pred[g] = Some(logits.argmax_last()[0]);
            } else {
                // SC / MC head; RC never enters the edge stage.
                let exec = self
                    .prof_of(c)
                    .head_exec
                    .clone()
                    .expect("head executable preloaded");
                let latent = exec.run(&[RtInput::F32(&x)])?;
                self.arena.payload[g] = Some(latent);
            }
        }
        if self.hops_of(c) == 0 {
            self.complete(g, t); // LC: done at the edge
        } else {
            self.enqueue_xfer(Dir::Up, 0, g, t)?;
        }
        if let Some(g2) = self.edge_q[c].pop_front() {
            self.dec_queued(1);
            self.start_edge(c, g2, t)?;
        }
        Ok(())
    }

    // -- shared per-hop channel lanes --------------------------------------

    /// Wire cost of frame `g`'s transfer on `hop` in `dir` — also the DRR
    /// service cost at that lane.
    fn xfer_bytes(&self, dir: Dir, hop: usize, g: usize) -> u64 {
        let c = self.client_of(g);
        match dir {
            Dir::Up => self.costs_of(c).up_bytes[hop],
            Dir::Down => self.costs_of(c).down_bytes,
        }
    }

    fn enqueue_xfer(
        &mut self,
        dir: Dir,
        hop: usize,
        g: usize,
        t: SimTime,
    ) -> Result<()> {
        self.arena.ready_at[g] = t;
        let lane = self.lane_of(hop, dir);
        if self.lane_busy[lane] {
            let c = self.client_of(g);
            let cost = self.xfer_bytes(dir, hop, g);
            self.lane_q[lane].push(c, cost, (dir, g));
            self.inc_queued(1);
            Ok(())
        } else {
            self.start_xfer(lane, dir, g, t)
        }
    }

    fn start_xfer(
        &mut self,
        lane: usize,
        dir: Dir,
        g: usize,
        t: SimTime,
    ) -> Result<()> {
        self.lane_busy[lane] = true;
        let hop = lane / 2;
        let c = self.client_of(g);
        let wait = t - self.arena.ready_at[g];
        self.arena.queue_wait_ns[g] += wait;
        let bytes = self.xfer_bytes(dir, hop, g);
        let (start, res) =
            self.channels[hop].send_no_earlier(dir, bytes, t)?;
        debug_assert_eq!(start, t, "channel lane discipline violated");
        self.arena.wire_bytes[g] += res.wire_bytes();
        self.arena.retransmits[g] += res.retransmits();
        match dir {
            Dir::Up => {
                if self.channels[hop].cfg.protocol == Protocol::Udp
                    && !res.lost_ranges().is_empty()
                {
                    self.arena.corrupted[g] = true;
                    if self.full_mode() {
                        if let Some(p) = self.arena.payload[g].as_mut() {
                            corruption::corrupt_scaled(
                                p,
                                res.lost_ranges(),
                                bytes,
                            );
                        }
                    }
                }
                self.q.schedule(
                    start + res.latency_ns(),
                    Ev::UpDelivered { g, hop },
                );
            }
            Dir::Down => {
                let lost: u64 =
                    res.lost_ranges().iter().map(|(_, l)| *l as u64).sum();
                if lost >= bytes {
                    // A fully lost UDP result datagram voids the frame.
                    self.arena.corrupted[g] = true;
                    if self.full_mode() {
                        self.arena.pred[g] = Some(self.prof_of(c).zero_pred);
                    }
                }
                self.q.schedule(
                    start + res.latency_ns(),
                    Ev::DownDelivered { g, hop },
                );
            }
        }
        self.q.schedule(start + res.sender_busy_ns(), Ev::NetFree { lane });
        Ok(())
    }

    fn net_free(&mut self, lane: usize, t: SimTime) -> Result<()> {
        self.lane_busy[lane] = false;
        if let Some((dir, g)) = self.lane_q[lane].pop() {
            self.dec_queued(1);
            self.start_xfer(lane, dir, g, t)?;
        }
        Ok(())
    }

    // -- mid-chain tiers (shared FIFO compute) -----------------------------

    fn enqueue_mid(&mut self, tier: usize, g: usize, t: SimTime)
        -> Result<()>
    {
        self.arena.ready_at[g] = t;
        if self.mid_busy[tier] {
            let c = self.client_of(g);
            let cost = self.costs_of(c).seg_mult_adds[tier];
            self.mid_q[tier].push(c, cost, g);
            self.inc_queued(1);
            Ok(())
        } else {
            self.start_mid(tier, g, t)
        }
    }

    fn start_mid(&mut self, tier: usize, g: usize, t: SimTime) -> Result<()> {
        self.mid_busy[tier] = true;
        self.mid_cur[tier] = g;
        let wait = t - self.arena.ready_at[g];
        self.arena.queue_wait_ns[g] += wait;
        let c = self.client_of(g);
        let ma = self.costs_of(c).seg_mult_adds[tier];
        let dur = self.device(c, tier).compute_ns(ma);
        self.q.schedule(t + dur, Ev::MidDone { tier });
        Ok(())
    }

    fn mid_done(&mut self, tier: usize, t: SimTime) -> Result<()> {
        let g = self.mid_cur[tier];
        self.mid_busy[tier] = false;
        if self.full_mode() {
            let payload = self.arena.payload[g]
                .take()
                .ok_or_else(|| anyhow!("frame {g} lost its payload"))?;
            let c = self.client_of(g);
            let exec = self.prof_of(c).mid_execs[tier - 1].clone();
            let latent = exec.run(&[RtInput::F32(&payload)])?;
            self.arena.payload[g] = Some(latent);
        }
        self.enqueue_xfer(Dir::Up, tier, g, t)?;
        if let Some(g2) = self.mid_q[tier].pop() {
            self.dec_queued(1);
            self.start_mid(tier, g2, t)?;
        }
        Ok(())
    }

    // -- server (batcher + compute) ----------------------------------------

    fn up_delivered(&mut self, g: usize, hop: usize, t: SimTime)
        -> Result<()>
    {
        let c = self.client_of(g);
        let tier = hop + 1;
        if tier < self.hops_of(c) {
            // A mid-chain tier: pay its segment compute, then forward.
            return self.enqueue_mid(tier, g, t);
        }
        self.arena.ready_at[g] = t;
        self.offered.push(g);
        if let Some(batch) = self.front.offer(c, t) {
            // The size trigger fired: the batch holds batch.len()-1
            // previously queued requests plus this one, which was served
            // immediately and never counted as waiting.
            self.dec_queued(batch.len() - 1);
            self.enqueue_srv(batch, t)?;
        } else {
            self.inc_queued(1);
            if self.front.pending() == 1 {
                // The deadline is set by the oldest pending request; only
                // the request that *opens* a batch needs to arm the timer.
                if let Some(d) = self.front.deadline() {
                    self.q.schedule(d, Ev::BatchTimer);
                }
            }
        }
        Ok(())
    }

    fn batch_timer(&mut self, t: SimTime) -> Result<()> {
        if let Some(batch) = self.front.poll(t) {
            self.dec_queued(batch.len());
            self.enqueue_srv(batch, t)?;
        }
        Ok(())
    }

    fn enqueue_srv(&mut self, batch: Batch, t: SimTime) -> Result<()> {
        if self.srv_busy {
            self.inc_queued(batch.len());
            self.srv_q.push_back(batch);
            Ok(())
        } else {
            self.start_srv(batch, t)
        }
    }

    fn start_srv(&mut self, batch: Batch, t: SimTime) -> Result<()> {
        self.srv_busy = true;
        // Heterogeneous batch cost: the sum of each request's own final
        // segment (for a homogeneous batch this reduces to the old
        // `batch.len() * seg_mult_adds[last]` exactly).
        let mut total_ma = 0u64;
        for req in &batch.requests {
            let g = self.offered[req.id as usize];
            let wait = t - self.arena.ready_at[g];
            self.arena.queue_wait_ns[g] += wait;
            let c = self.client_of(g);
            let segs = &self.costs_of(c).seg_mult_adds;
            total_ma += segs[segs.len() - 1];
        }
        let dur = self
            .setup
            .tiers
            .last()
            .expect("validated by costs()")
            .compute_ns(total_ma);
        self.q.schedule(t + dur, Ev::ServerDone { batch });
        Ok(())
    }

    fn server_done(&mut self, batch: Batch, t: SimTime) -> Result<()> {
        self.srv_busy = false;
        for req in &batch.requests {
            let g = self.offered[req.id as usize];
            let c = self.client_of(g);
            if self.full_mode() {
                let payload = self.arena.payload[g]
                    .take()
                    .ok_or_else(|| anyhow!("frame {g} lost its payload"))?;
                let p = self.prof_of(c);
                let exec = match &p.kind {
                    ScenarioKind::Rc => p.full_exec.clone().unwrap(),
                    ScenarioKind::Sc { .. } | ScenarioKind::Mc { .. } => {
                        p.tail_exec.clone().unwrap()
                    }
                    ScenarioKind::Lc => {
                        unreachable!("LC never reaches the server")
                    }
                };
                let logits = exec.run(&[RtInput::F32(&payload)])?;
                self.arena.pred[g] = Some(logits.argmax_last()[0]);
            }
            let last_hop = self.hops_of(c) - 1;
            self.enqueue_xfer(Dir::Down, last_hop, g, t)?;
        }
        // The batch is spent: hand its request storage back to the
        // batcher pool so the next release reuses it instead of growing
        // a fresh Vec (the serve loop's last per-batch allocation).
        self.front.recycle(batch.requests);
        if let Some(next) = self.srv_q.pop_front() {
            self.dec_queued(next.len());
            self.start_srv(next, t)?;
        }
        Ok(())
    }

    fn down_delivered(&mut self, g: usize, hop: usize, t: SimTime)
        -> Result<()>
    {
        if hop == 0 {
            self.complete(g, t);
            Ok(())
        } else {
            // Relay the result down the next hop toward the client.
            self.enqueue_xfer(Dir::Down, hop - 1, g, t)
        }
    }

    // -- completion --------------------------------------------------------

    fn complete(&mut self, g: usize, t: SimTime) {
        self.arena.completed_ns[g] = t;
        if self.full_mode() {
            self.arena.payload[g] = None;
        }
        self.completed += 1;
        let c = self.client_of(g);
        // Closed-loop source: emit the next frame on completion.
        if self.setup.period[c] == 0 && self.next_frame[c] < self.fpc(c) {
            self.q.schedule(t, Ev::Emit { c });
        }
    }

    fn handle(&mut self, ev: Ev, t: SimTime) -> Result<()> {
        match ev {
            Ev::Emit { c } => self.emit(c, t),
            Ev::EdgeDone { c } => self.edge_done(c, t),
            Ev::NetFree { lane } => self.net_free(lane, t),
            Ev::UpDelivered { g, hop } => self.up_delivered(g, hop, t),
            Ev::MidDone { tier } => self.mid_done(tier, t),
            Ev::BatchTimer => self.batch_timer(t),
            Ev::ServerDone { batch } => self.server_done(batch, t),
            Ev::DownDelivered { g, hop } => self.down_delivered(g, hop, t),
            // Segment entry itself is a no-op: links cost transfers
            // piecewise from the trace regardless. The event's job is
            // done the moment it pops (clock advanced, boundary visible
            // in the calendar).
            Ev::TraceBoundary { .. } => Ok(()),
        }
    }
}

/// The executable name serving the final segment of a cut chain: the
/// plain split tail for a single cut, the composed chain tail otherwise
/// (synthesized on demand by the analytic backend).
pub fn chain_tail_name(cuts: &[usize], batch: usize) -> String {
    if cuts.len() == 1 {
        format!("tail_L{}_b{batch}", cuts[0])
    } else {
        let mut name = "tail_chain".to_string();
        for c in cuts {
            name.push_str(&format!("_L{c}"));
        }
        name.push_str(&format!("_b{batch}"));
        name
    }
}

/// The executable name re-encoding the latent of cut `from` into the
/// latent of cut `to` on a mid-chain tier.
pub fn mid_exec_name(from: usize, to: usize, batch: usize) -> String {
    format!("mid_L{from}_L{to}_b{batch}")
}

/// A cut chain is servable when the backend has (or can synthesize) the
/// head, every mid segment and the chain tail at batch 1 — the single
/// capability probe shared by the suggest engine and the placement/search
/// candidate enumerations (real AOT artifacts export single-split
/// heads/tails only; on-demand chain synthesis is an analytic-backend
/// capability).
pub fn chain_servable(
    engine: &dyn crate::runtime::InferenceBackend,
    cuts: &[usize],
) -> bool {
    engine.executable(&format!("head_L{}_b1", cuts[0])).is_ok()
        && cuts.windows(2).all(|w| {
            engine.executable(&mid_exec_name(w[0], w[1], 1)).is_ok()
        })
        && engine.executable(&chain_tail_name(cuts, 1)).is_ok()
}

/// Resolve the execution profile of one `(kind, scale)` on `engine`,
/// given precomputed costs: preload the executables this placement needs
/// (full mode only) and the zero-logits fallback prediction.
fn build_profile_with_costs(
    engine: &dyn InferenceBackend,
    kind: &ScenarioKind,
    costs: Costs,
    full: bool,
) -> Result<Profile> {
    let num_classes = engine.manifest().model.num_classes;
    let mut mid_execs: Vec<Rc<dyn Executable>> = Vec::new();
    let (full_exec, head_exec, tail_exec) = if full {
        match kind {
            ScenarioKind::Lc => {
                let name = if engine
                    .manifest()
                    .executables
                    .contains_key("full_fwd_lite_b1")
                {
                    "full_fwd_lite_b1"
                } else {
                    "full_fwd_b1"
                };
                (Some(engine.executable(name)?), None, None)
            }
            ScenarioKind::Rc => {
                (Some(engine.executable("full_fwd_b1")?), None, None)
            }
            ScenarioKind::Sc { split } => (
                None,
                Some(engine.executable(&format!("head_L{split}_b1"))?),
                Some(engine.executable(&format!("tail_L{split}_b1"))?),
            ),
            ScenarioKind::Mc { cuts } => {
                for w in cuts.windows(2) {
                    mid_execs.push(
                        engine.executable(&mid_exec_name(w[0], w[1], 1))?,
                    );
                }
                (
                    None,
                    Some(
                        engine
                            .executable(&format!("head_L{}_b1", cuts[0]))?,
                    ),
                    Some(engine.executable(&chain_tail_name(cuts, 1))?),
                )
            }
        }
    } else {
        (None, None, None)
    };
    Ok(Profile {
        kind: kind.clone(),
        costs,
        full_exec,
        head_exec,
        mid_execs,
        tail_exec,
        zero_pred: Tensor::zeros(vec![1, num_classes]).argmax_last()[0],
    })
}

fn build_profile(
    engine: &dyn InferenceBackend,
    kind: &ScenarioKind,
    scale: ModelScale,
    n_tiers: usize,
    full: bool,
) -> Result<Profile> {
    let costs = kind_costs(engine, kind, scale, n_tiers)?;
    build_profile_with_costs(engine, kind, costs, full)
}

/// Run one resolved setup to completion and reduce it to records + stats.
fn simulate(
    setup: &StreamSetup<'_>,
    channels: Vec<Channel>,
) -> Result<(Vec<StreamFrameRecord>, ResourceStats)> {
    let n_clients = setup.prof.len();
    let total: usize = setup.fpc.iter().sum();
    let mut start = Vec::with_capacity(n_clients);
    let mut acc = 0usize;
    for &k in &setup.fpc {
        start.push(acc);
        acc += k;
    }
    let n_mid = setup.tiers.len();
    let n_lanes = 2 * channels.len();

    // DRR quanta: at least the maximum single-item cost at each resource
    // over the admitted clients, so every active client is guaranteed at
    // least one item of service per weighted round.
    let mut lane_quantum = vec![1u64; n_lanes];
    let mut mid_quantum = vec![1u64; n_mid];
    for c in 0..n_clients {
        if setup.fpc[c] == 0 {
            continue;
        }
        let costs = &setup.profiles[setup.prof[c]].costs;
        for h in 0..costs.hops() {
            let up = lane_index(&channels, h, Dir::Up);
            lane_quantum[up] = lane_quantum[up].max(costs.up_bytes[h]);
            let down = lane_index(&channels, h, Dir::Down);
            lane_quantum[down] = lane_quantum[down].max(costs.down_bytes);
        }
        for tier in 1..costs.hops() {
            mid_quantum[tier] =
                mid_quantum[tier].max(costs.seg_mult_adds[tier]);
        }
    }

    let front = match setup.fairness {
        Fairness::Fifo => Front::Fifo(Batcher::new(setup.batch)),
        Fairness::Drr => {
            Front::Drr(DrrBatcher::new(setup.batch, setup.weight.clone()))
        }
    };
    let mut sim = Sim {
        setup,
        start,
        channels,
        // Pending events are bounded by in-service items plus one armed
        // source timer per client — O(clients), never O(frames) — so a
        // small multiple of the client count pre-sizes the queue past
        // any reallocation in the loop.
        q: EventQueue::with_kind_and_capacity(
            setup.queue,
            4 * n_clients + 64,
        ),
        arena: FrameArena::seeded(&setup.fpc, setup.dataset.is_some()),
        next_frame: vec![0; n_clients],
        edge_q: vec![VecDeque::new(); n_clients],
        edge_busy: vec![false; n_clients],
        edge_cur: vec![0; n_clients],
        mid_q: (0..n_mid)
            .map(|t| {
                new_multi_queue(setup.fairness, &setup.weight, mid_quantum[t])
            })
            .collect(),
        mid_busy: vec![false; n_mid],
        mid_cur: vec![0; n_mid],
        lane_q: (0..n_lanes)
            .map(|l| {
                new_multi_queue(setup.fairness, &setup.weight, lane_quantum[l])
            })
            .collect(),
        lane_busy: vec![false; n_lanes],
        front,
        // Every frame that reaches the batcher appends exactly one id
        // mapping; reserving the worst case (all frames) keeps the hot
        // loop free of growth reallocations.
        offered: Vec::with_capacity(total),
        srv_q: VecDeque::new(),
        srv_busy: false,
        queued: 0,
        max_queued: 0,
        depth_area: 0.0,
        last_t: 0,
        completed: 0,
    };

    // Batched seeding: run the emit handler directly, in client order,
    // instead of scheduling N seed events. The N `Emit`s would carry the
    // N smallest sequence numbers at t = 0 and therefore pop first, in
    // exactly this order, before any derived event; skipping the queue
    // round-trip shifts every later event's tiebreak down by N
    // *uniformly*, which preserves their relative order — frame-visible
    // behavior is identical (pinned by tests/calendar_equivalence.rs).
    for c in 0..n_clients {
        if setup.fpc[c] > 0 {
            sim.emit(c, 0)?;
        }
    }
    // Trace boundaries enter the calendar as explicit events — one per
    // segment transition per hop. Constant (or absent) traces schedule
    // none, keeping the event stream byte-identical to the untraced
    // engine; multi-segment traces get deterministic boundary wakeups
    // regardless of traffic.
    let boundaries: Vec<(usize, Vec<SimTime>)> = sim
        .channels
        .iter()
        .enumerate()
        .filter_map(|(hop, ch)| {
            ch.trace().filter(|tr| !tr.is_constant()).map(|tr| {
                (hop, tr.boundaries())
            })
        })
        .collect();
    for (hop, bounds) in boundaries {
        for b in bounds {
            sim.q.schedule(b, Ev::TraceBoundary { hop });
        }
    }
    while sim.completed < total {
        let Some((t, ev)) = sim.q.pop() else {
            bail!(
                "streaming deadlock: {}/{} frames completed",
                sim.completed,
                total
            );
        };
        sim.depth_area += sim.queued as f64 * (t - sim.last_t) as f64;
        sim.last_t = t;
        sim.handle(ev, t)?;
    }

    let duration_ns =
        sim.arena.completed_ns.iter().copied().max().unwrap_or(0);
    let stats = ResourceStats {
        duration_ns,
        throughput_fps: if duration_ns > 0 {
            total as f64 / secs(duration_ns)
        } else {
            0.0
        },
        mean_queue_depth: if duration_ns > 0 {
            sim.depth_area / duration_ns as f64
        } else {
            0.0
        },
        max_queue_depth: sim.max_queued,
        batches_released: sim.front.batches_released(),
        batched_requests: sim.front.requests_seen(),
        events_processed: sim.q.processed(),
    };
    let full = setup.dataset.is_some();
    let a = &sim.arena;
    let records: Vec<StreamFrameRecord> = (0..total)
        .map(|g| StreamFrameRecord {
            client: a.owner[g] as usize,
            frame: a.fidx[g] as usize,
            emitted_ns: a.emitted_ns[g],
            completed_ns: a.completed_ns[g],
            latency_ns: a.completed_ns[g] - a.emitted_ns[g],
            queue_wait_ns: a.queue_wait_ns[g],
            correct: if full {
                Some(a.pred[g] == Some(a.label[g]))
            } else {
                None
            },
            wire_bytes: a.wire_bytes[g],
            retransmits: a.retransmits[g],
            corrupted: a.corrupted[g],
        })
        .collect();
    Ok((records, stats))
}

/// Run the closed-loop streaming simulation.
///
/// `dataset: Some(_)` selects *full* mode (per-frame inference and
/// accuracy, the `run_scenario` path); `None` selects *latency-only* mode
/// (pure timing, the `simulate_latency` / Fig. 3 path). Deterministic in
/// `(cfg, engine seed)` alone.
pub fn run_stream(
    engine: &dyn InferenceBackend,
    cfg: &StreamConfig,
    dataset: Option<&Dataset>,
    qos: &QosRequirements,
) -> Result<StreamReport> {
    run_stream_with_queue(engine, cfg, dataset, qos, QueueKind::Calendar)
}

/// [`run_stream`] with an explicit event-queue backend — the hook the
/// differential harness uses to pin the calendar against the retained
/// linear scan. Results are byte-identical across backends by
/// construction (both always extract the event with the globally minimal
/// `(time, seq)` key).
pub fn run_stream_with_queue(
    engine: &dyn InferenceBackend,
    cfg: &StreamConfig,
    dataset: Option<&Dataset>,
    qos: &QosRequirements,
    queue: QueueKind,
) -> Result<StreamReport> {
    if cfg.clients == 0 {
        bail!("streaming needs at least one client");
    }
    if cfg.frames_per_client == 0 {
        bail!("streaming needs at least one frame per client");
    }
    if let Some(ds) = dataset {
        if ds.len() == 0 {
            bail!("streaming needs a non-empty dataset in full mode");
        }
    }
    let costs = costs(engine, &cfg.scenario)?;
    let hops = costs.hops();
    let profile = build_profile_with_costs(
        engine,
        &cfg.scenario.kind,
        costs,
        dataset.is_some(),
    )?;
    let channels: Vec<Channel> = (0..hops.max(1))
        .map(|h| Channel::new(cfg.scenario.hop_net(h)))
        .collect();
    let n = cfg.clients;
    let setup = StreamSetup {
        profiles: vec![profile],
        prof: vec![0; n],
        period: vec![cfg.scenario.frame_period_ns; n],
        fpc: vec![cfg.frames_per_client; n],
        weight: vec![1; n],
        tiers: cfg.scenario.tiers.clone(),
        batch: cfg.batch,
        fairness: Fairness::Fifo,
        queue,
        dataset,
    };
    let (records, stats) = simulate(&setup, channels)?;
    Ok(StreamReport::from_parts(
        cfg.clients,
        cfg.offered_fps(),
        records,
        stats,
        qos,
    ))
}

// ---------------------------------------------------------------------------
// Admission control.
// ---------------------------------------------------------------------------

/// Optimistic (lower-bound) serialization time of `bytes` on `net`'s
/// bottleneck rate, in ns. Ignores protocol headers, losses and ACK
/// coupling — everything that can only make the real channel slower — so
/// a stream rejected on this estimate provably cannot be served. Under a
/// time-varying trace the bound uses the trace's *best-case* segment
/// ([`NetworkConfig::best_rate_bps`]): a stream infeasible even on the
/// link's best segment is infeasible on every segment.
fn lane_service_ns(net: &NetworkConfig, bytes: u64) -> f64 {
    let rate = net.best_rate_bps();
    if rate <= 0.0 {
        return f64::INFINITY;
    }
    bytes as f64 * 8.0 / rate * 1e9
}

/// Greedy admission in client order: each open-loop client adds its
/// lower-bound utilization `lambda * service_time` to every shared
/// resource it visits (lanes by serialization time, mid tiers and the
/// amortized server by compute time); a client that would push any
/// resource past utilization 1 — or whose own tier-0 device cannot keep
/// up with its period — is rejected with a reason naming the bottleneck.
/// Closed-loop clients (period 0) self-clock and are always admitted.
fn admission_reasons(
    specs: &[ClientSpec],
    profiles: &[Profile],
    prof: &[usize],
    tiers: &[DeviceProfile],
    hop_nets: &[NetworkConfig],
    batch: &BatchPolicy,
) -> Vec<Option<String>> {
    const LIMIT: f64 = 1.0 + 1e-9;
    let mut lane_util = vec![0.0f64; 2 * hop_nets.len()];
    let mut mid_util = vec![0.0f64; tiers.len()];
    let mut srv_util = 0.0f64;
    // Per-spec contribution buffers, hoisted out of the loop: a 10^6
    // tenant pass reuses two buffers instead of allocating two fresh
    // Vecs per client.
    let mut lane_add = vec![0.0f64; lane_util.len()];
    let mut mid_add = vec![0.0f64; mid_util.len()];
    // Chunked fast path: `count`-expanded specs arrive as runs of
    // identical consecutive clients, and a rejection leaves every shared
    // utilization untouched — so once one client of a (profile, period)
    // run is rejected, every directly following client of the same run
    // gets the verbatim verdict without re-walking the resources. Any
    // admission in between invalidates the cache (utilizations moved).
    let mut rejected_run: Option<(usize, SimTime, String)> = None;
    let mut out = Vec::with_capacity(specs.len());
    for (c, spec) in specs.iter().enumerate() {
        let p = &profiles[prof[c]];
        let costs = &p.costs;
        let period = spec.frame_period_ns;
        if period == 0 {
            // Closed-loop sources emit only on completion: they cannot
            // push any resource past saturation.
            out.push(None);
            continue;
        }
        if let Some((rp, rper, verdict)) = &rejected_run {
            if *rp == prof[c] && *rper == period {
                out.push(Some(verdict.clone()));
                continue;
            }
        }
        // Tier 0 is the client's own device, not a shared resource: the
        // stream starves itself when one frame's compute exceeds its
        // period.
        if !matches!(p.kind, ScenarioKind::Rc) {
            let s0 = tiers[0].compute_ns(costs.seg_mult_adds[0]);
            if s0 > period {
                let verdict = format!(
                    "rejected by admission control: tier-0 device '{}' \
                     needs {:.3} ms per frame, more than the {:.3} ms \
                     frame period",
                    tiers[0].name,
                    s0 as f64 / 1e6,
                    period as f64 / 1e6
                );
                rejected_run = Some((prof[c], period, verdict.clone()));
                out.push(Some(verdict));
                continue;
            }
        }
        let lam = 1.0 / period as f64; // frames per ns
        lane_add.fill(0.0);
        mid_add.fill(0.0);
        let mut srv_add = 0.0f64;
        for h in 0..costs.hops() {
            let net = &hop_nets[h];
            lane_add[2 * h] +=
                lam * lane_service_ns(net, costs.up_bytes[h]);
            let down_lane = match net.protocol {
                Protocol::Tcp => 2 * h,
                Protocol::Udp => 2 * h + 1,
            };
            lane_add[down_lane] +=
                lam * lane_service_ns(net, costs.down_bytes);
        }
        for tier in 1..costs.hops() {
            mid_add[tier] += lam
                * tiers[tier].compute_ns(costs.seg_mult_adds[tier]) as f64;
        }
        if costs.hops() >= 1 {
            let last_ma = *costs.seg_mult_adds.last().expect("non-empty");
            let b = batch.max_batch.max(1);
            let amortized = tiers
                .last()
                .expect("validated")
                .compute_ns(b as u64 * last_ma) as f64
                / b as f64;
            srv_add += lam * amortized;
        }
        let mut reason: Option<String> = None;
        for (l, add) in lane_add.iter().enumerate() {
            if reason.is_none() && lane_util[l] + add > LIMIT {
                let dir = if l % 2 == 0 { "uplink" } else { "downlink" };
                reason = Some(format!(
                    "hop {} {dir} lane utilization would reach {:.2}",
                    l / 2,
                    lane_util[l] + add
                ));
            }
        }
        for (tier, add) in mid_add.iter().enumerate() {
            if reason.is_none() && mid_util[tier] + add > LIMIT {
                reason = Some(format!(
                    "mid tier {} ('{}') utilization would reach {:.2}",
                    tier,
                    tiers[tier].name,
                    mid_util[tier] + add
                ));
            }
        }
        if reason.is_none() && srv_util + srv_add > LIMIT {
            reason = Some(format!(
                "server tier ('{}') utilization would reach {:.2}",
                tiers.last().expect("validated").name,
                srv_util + srv_add
            ));
        }
        match reason {
            Some(r) => {
                let verdict = format!(
                    "rejected by admission control: {r} (> 1 at the \
                     bottleneck)"
                );
                rejected_run = Some((prof[c], period, verdict.clone()));
                out.push(Some(verdict));
            }
            None => {
                for (l, add) in lane_add.iter().enumerate() {
                    lane_util[l] += add;
                }
                for (tier, add) in mid_add.iter().enumerate() {
                    mid_util[tier] += add;
                }
                srv_util += srv_add;
                rejected_run = None;
                out.push(None);
            }
        }
    }
    out
}

/// Reduce one client's record slice (its contiguous arena span) to a
/// per-tenant outcome judged against its own QoS.
fn client_outcome(
    c: usize,
    spec: &ClientSpec,
    reason: Option<String>,
    recs: &[StreamFrameRecord],
) -> ClientOutcome {
    let label = format!(
        "{} {} {}",
        spec.kind,
        spec.arch.as_str(),
        spec.scale.as_str()
    );
    if let Some(r) = reason {
        let has_constraints = spec.qos.max_latency_ns.is_some()
            || spec.qos.min_accuracy.is_some();
        return ClientOutcome {
            client: c,
            label,
            admitted: false,
            reject_reason: Some(r),
            frames: 0,
            accuracy: None,
            mean_latency_ns: 0.0,
            p95_latency_ns: 0,
            max_latency_ns: 0,
            deadline_hit_rate: None,
            // A rejected stream serves nothing: a constrained QoS is
            // definitively violated, an unconstrained one stays open.
            qos_satisfied: if has_constraints { Some(false) } else { None },
        };
    }
    let n = recs.len().max(1);
    let mut lat: Vec<SimTime> = recs.iter().map(|r| r.latency_ns).collect();
    let mean_latency_ns =
        lat.iter().map(|&v| v as f64).sum::<f64>() / n as f64;
    let max_latency_ns = lat.iter().copied().max().unwrap_or(0);
    let p95_latency_ns = percentile_mut(&mut lat, 0.95);
    let measured =
        !recs.is_empty() && recs.iter().all(|r| r.correct.is_some());
    let accuracy = if measured {
        Some(
            recs.iter().filter(|r| r.correct == Some(true)).count() as f64
                / n as f64,
        )
    } else {
        None
    };
    let deadline_hit_rate = spec.qos.max_latency_ns.map(|m| {
        recs.iter().filter(|r| r.latency_ns <= m).count() as f64 / n as f64
    });
    let latency_ok = spec.qos.latency_ok(deadline_hit_rate);
    let qos_satisfied =
        match (spec.qos.max_latency_ns, spec.qos.min_accuracy, accuracy) {
            (None, None, _) => None,
            _ if !latency_ok => Some(false),
            (_, Some(_), None) => None,
            (_, _, acc) => Some(
                spec.qos.satisfied_by(deadline_hit_rate, acc.unwrap_or(1.0)),
            ),
        };
    ClientOutcome {
        client: c,
        label,
        admitted: true,
        reject_reason: None,
        frames: recs.len(),
        accuracy,
        mean_latency_ns,
        p95_latency_ns,
        max_latency_ns,
        deadline_hit_rate,
        qos_satisfied,
    }
}

/// Run a heterogeneous multi-tenant streaming simulation: per-client
/// architecture / placement / scale / rate / weight / QoS over one shared
/// tier chain, with optional admission control and DRR fairness.
///
/// `engines` maps each architecture to a loaded backend; every distinct
/// `(arch, kind, scale)` combination resolves to one shared [`Profile`].
/// Rejected clients emit nothing — admitted streams produce records
/// byte-identical to a run where the rejected streams were never offered.
/// The aggregate's records keep original client indices, grouped per
/// client in emission order.
pub fn run_hetero_stream(
    engines: &[(Arch, &dyn InferenceBackend)],
    cfg: &MultiStreamConfig,
    dataset: Option<&Dataset>,
    qos: &QosRequirements,
) -> Result<HeteroStreamReport> {
    if cfg.clients.is_empty() {
        bail!("streaming needs at least one client");
    }
    if cfg.tiers.is_empty() {
        bail!("multi-tenant streaming needs at least one device tier");
    }
    if cfg.hop_nets.is_empty() {
        bail!(
            "multi-tenant streaming needs at least one hop_nets entry \
             (a single entry is replicated per hop with derived seeds)"
        );
    }
    let phys_hops = cfg.tiers.len() - 1;
    if cfg.hop_nets.len() > 1 && cfg.hop_nets.len() != phys_hops {
        bail!(
            "tier chain has {} inter-tier hops but {} hop_nets entries \
             (give one per hop, or a single template to replicate)",
            phys_hops,
            cfg.hop_nets.len()
        );
    }
    if let Some(ds) = dataset {
        if ds.len() == 0 {
            bail!("streaming needs a non-empty dataset in full mode");
        }
    }
    for (i, spec) in cfg.clients.iter().enumerate() {
        if spec.frames == 0 {
            bail!("clients[{i}]: needs at least one frame");
        }
        if spec.weight == 0 {
            bail!("clients[{i}]: weight must be >= 1");
        }
    }

    // Resolve one profile per distinct (arch, kind, scale). Chunked fast
    // path: `count`-expanded specs arrive as runs of identical
    // consecutive clients, so the common case reuses the previous
    // client's index without re-scanning the key table (O(clients)
    // total instead of O(clients x distinct profiles)).
    let mut profiles: Vec<Profile> = Vec::new();
    let mut keys: Vec<(Arch, ScenarioKind, ModelScale)> = Vec::new();
    let mut prof: Vec<usize> = Vec::with_capacity(cfg.clients.len());
    for (i, spec) in cfg.clients.iter().enumerate() {
        if let Some(&prev) = prof.last() {
            let k = &keys[prev];
            if k.0 == spec.arch && k.2 == spec.scale && k.1 == spec.kind {
                prof.push(prev);
                continue;
            }
        }
        let key = (spec.arch, spec.kind.clone(), spec.scale);
        let idx = match keys.iter().position(|k| *k == key) {
            Some(idx) => idx,
            None => {
                let engine = engines
                    .iter()
                    .find(|(a, _)| *a == spec.arch)
                    .map(|(_, e)| *e)
                    .ok_or_else(|| {
                        anyhow!(
                            "clients[{i}]: no inference backend loaded \
                             for arch '{}'",
                            spec.arch.as_str()
                        )
                    })?;
                profiles.push(
                    build_profile(
                        engine,
                        &spec.kind,
                        spec.scale,
                        cfg.tiers.len(),
                        dataset.is_some(),
                    )
                    .map_err(|e| anyhow!("clients[{i}]: {e}"))?,
                );
                keys.push(key);
                profiles.len() - 1
            }
        };
        prof.push(idx);
    }

    let hop_nets: Vec<NetworkConfig> = (0..phys_hops.max(1))
        .map(|h| derive_hop_net(&cfg.hop_nets, h))
        .collect();
    let reasons: Vec<Option<String>> = if cfg.admission {
        admission_reasons(
            &cfg.clients,
            &profiles,
            &prof,
            &cfg.tiers,
            &hop_nets,
            &cfg.batch,
        )
    } else {
        vec![None; cfg.clients.len()]
    };
    let fpc: Vec<usize> = cfg
        .clients
        .iter()
        .zip(&reasons)
        .map(|(s, r)| if r.is_none() { s.frames } else { 0 })
        .collect();

    let channels: Vec<Channel> =
        hop_nets.iter().cloned().map(Channel::new).collect();
    let setup = StreamSetup {
        profiles,
        prof,
        period: cfg.clients.iter().map(|s| s.frame_period_ns).collect(),
        fpc: fpc.clone(),
        weight: cfg.clients.iter().map(|s| s.weight).collect(),
        tiers: cfg.tiers.clone(),
        batch: cfg.batch,
        fairness: cfg.fairness,
        queue: cfg.queue,
        dataset,
    };
    let (records, stats) = simulate(&setup, channels)?;
    let aggregate = StreamReport::from_parts(
        cfg.clients.len(),
        cfg.offered_fps(),
        records,
        stats,
        qos,
    );

    let mut outcomes = Vec::with_capacity(cfg.clients.len());
    let mut off = 0usize;
    for ((c, spec), reason) in
        cfg.clients.iter().enumerate().zip(reasons.into_iter())
    {
        let k = fpc[c];
        let recs = &aggregate.records[off..off + k];
        off += k;
        outcomes.push(client_outcome(c, spec, reason, recs));
    }
    Ok(HeteroStreamReport { outcomes, aggregate })
}

// ---------------------------------------------------------------------------
// Clients-spec JSON.
// ---------------------------------------------------------------------------

const CLIENT_KEYS: [&str; 11] = [
    "count",
    "scenario",
    "arch",
    "scale",
    "fps",
    "frame_period_ns",
    "frames",
    "weight",
    "max_latency_ms",
    "min_accuracy",
    "min_hit_rate",
];

/// Parse a clients-spec document (`sei serve --clients-spec`): either a
/// bare JSON array of client entries or `{"clients": [...]}`. Every
/// entry requires `"scenario"`; optional keys are `count` (bulk
/// expansion), `arch`, `scale`, `fps` *or* `frame_period_ns`, `frames`,
/// `weight` and the QoS bounds `max_latency_ms` / `min_accuracy` /
/// `min_hit_rate`. Errors name the offending entry as `clients[i]`.
pub fn parse_clients_spec(text: &str) -> Result<Vec<ClientSpec>> {
    let json = Json::parse(text)?;
    parse_client_entries(&json)
}

/// [`parse_clients_spec`] over an already-parsed [`Json`] value.
pub fn parse_client_entries(json: &Json) -> Result<Vec<ClientSpec>> {
    let entries = match json {
        Json::Arr(items) => items,
        _ => json
            .get("clients")
            .map_err(|_| {
                anyhow!(
                    "clients spec must be a JSON array of client entries \
                     or an object with a 'clients' array"
                )
            })?
            .arr()?,
    };
    let mut out = Vec::new();
    for (i, e) in entries.iter().enumerate() {
        let Json::Obj(map) = e else {
            bail!("clients[{i}]: each entry must be a JSON object");
        };
        if let Some(k) =
            map.keys().find(|k| !CLIENT_KEYS.contains(&k.as_str()))
        {
            bail!(
                "clients[{i}]: unknown key '{k}' (known: {})",
                CLIENT_KEYS.join(", ")
            );
        }
        let ctx = |err: anyhow::Error| anyhow!("clients[{i}]: {err}");
        let kind_s = e
            .get("scenario")
            .map_err(|_| {
                anyhow!("clients[{i}]: missing required key 'scenario'")
            })?
            .str()
            .map_err(ctx)?;
        let kind = ScenarioKind::parse(kind_s).map_err(ctx)?;
        let arch = match e.opt("arch") {
            Some(v) => Arch::parse(v.str().map_err(ctx)?).map_err(ctx)?,
            None => Arch::Vgg16,
        };
        let scale = match e.opt("scale") {
            Some(v) => {
                ModelScale::parse(v.str().map_err(ctx)?).map_err(ctx)?
            }
            None => ModelScale::Slim,
        };
        let frames = match e.opt("frames") {
            Some(v) => v.usize().map_err(ctx)?,
            None => 64,
        };
        if frames == 0 {
            bail!("clients[{i}]: frames must be >= 1");
        }
        let weight = match e.opt("weight") {
            Some(v) => v.u64().map_err(ctx)?,
            None => 1,
        };
        if weight == 0 {
            bail!("clients[{i}]: weight must be >= 1");
        }
        let count = match e.opt("count") {
            Some(v) => v.usize().map_err(ctx)?,
            None => 1,
        };
        if count == 0 {
            bail!("clients[{i}]: count must be >= 1");
        }
        let frame_period_ns = match (e.opt("fps"), e.opt("frame_period_ns"))
        {
            (Some(_), Some(_)) => bail!(
                "clients[{i}]: give 'fps' or 'frame_period_ns', not both"
            ),
            (Some(v), None) => {
                let fps = v.f64().map_err(ctx)?;
                if !fps.is_finite() || fps <= 0.0 || fps > 1e9 {
                    bail!(
                        "clients[{i}]: fps must be a positive number \
                         <= 1e9, got {fps}"
                    );
                }
                (1e9 / fps).round() as SimTime
            }
            (None, Some(v)) => v.u64().map_err(ctx)?,
            (None, None) => 0,
        };
        let bound = |key: &str| -> Result<Option<f64>> {
            e.opt(key)
                .map(|v| v.f64())
                .transpose()
                .map_err(|err| anyhow!("clients[{i}]: {err}"))
        };
        let qos = QosRequirements::from_bounds(
            bound("max_latency_ms")?,
            bound("min_accuracy")?,
            bound("min_hit_rate")?,
        )
        .map_err(ctx)?;
        let spec = ClientSpec {
            kind,
            arch,
            scale,
            frame_period_ns,
            frames,
            weight,
            qos,
        };
        out.extend(std::iter::repeat_with(|| spec.clone()).take(count));
    }
    if out.is_empty() {
        bail!("clients spec contains no client entries");
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::scenario::ModelScale;
    use crate::model::DeviceProfile;
    use crate::netsim::transfer::NetworkConfig;
    use crate::runtime::load_backend;
    use std::path::Path;

    fn engine() -> Box<dyn InferenceBackend> {
        load_backend(Path::new("artifacts")).expect("backend")
    }

    fn scenario(period_ns: SimTime) -> ScenarioConfig {
        ScenarioConfig::two_tier(
            ScenarioKind::Rc,
            NetworkConfig::gigabit(Protocol::Udp, 0.0, 9),
            DeviceProfile::edge_gpu(),
            DeviceProfile::server_gpu(),
            ModelScale::Slim,
            period_ns,
        )
    }

    #[test]
    fn conserves_frames_across_clients() {
        let eng = engine();
        let cfg = StreamConfig {
            scenario: scenario(1_000_000),
            clients: 3,
            frames_per_client: 8,
            batch: BatchPolicy::new(4, 2_000_000),
        };
        let r = run_stream(&*eng, &cfg, None, &QosRequirements::none())
            .unwrap();
        assert_eq!(r.frames, 24);
        assert_eq!(r.stats.batched_requests, 24);
        assert!(r.records.iter().all(|f| f.completed_ns >= f.emitted_ns));
        // Every client stream is complete and ordered.
        for c in 0..3 {
            let mine: Vec<_> =
                r.records.iter().filter(|f| f.client == c).collect();
            assert_eq!(mine.len(), 8);
            for w in mine.windows(2) {
                assert!(w[1].frame == w[0].frame + 1);
                assert!(w[1].emitted_ns >= w[0].emitted_ns);
            }
        }
    }

    #[test]
    fn closed_loop_source_emits_on_completion() {
        let eng = engine();
        let cfg = StreamConfig {
            scenario: scenario(0),
            clients: 1,
            frames_per_client: 6,
            batch: BatchPolicy::immediate(),
        };
        let r = run_stream(&*eng, &cfg, None, &QosRequirements::none())
            .unwrap();
        assert_eq!(r.offered_fps, 0.0);
        for w in r.records.windows(2) {
            assert_eq!(
                w[1].emitted_ns, w[0].completed_ns,
                "closed-loop emission must follow completion"
            );
        }
        // No queueing in a closed loop with one client.
        assert!(r.records.iter().all(|f| f.queue_wait_ns == 0));
    }

    #[test]
    fn overload_builds_queues_low_load_does_not() {
        let eng = engine();
        // Service time per frame is bounded below by the server overhead
        // (150 µs) -> a 10 µs period is far past saturation.
        let slow = run_stream(
            &*eng,
            &StreamConfig {
                scenario: scenario(50_000_000),
                clients: 1,
                frames_per_client: 16,
                batch: BatchPolicy::immediate(),
            },
            None,
            &QosRequirements::none(),
        )
        .unwrap();
        let fast = run_stream(
            &*eng,
            &StreamConfig {
                scenario: scenario(10_000),
                clients: 1,
                frames_per_client: 16,
                batch: BatchPolicy::immediate(),
            },
            None,
            &QosRequirements::none(),
        )
        .unwrap();
        assert!(slow.records.iter().all(|f| f.queue_wait_ns == 0));
        // A contention-free run must report an empty peak queue.
        assert_eq!(slow.stats.max_queue_depth, 0);
        assert!(fast.mean_queue_wait_ns > 0.0);
        assert!(fast.mean_latency_ns > slow.mean_latency_ns);
        assert!(fast.stats.max_queue_depth > 0);
        // Throughput saturates below the offered rate.
        assert!(fast.stats.throughput_fps < 1e9 / 10_000.0);
    }

    #[test]
    fn latency_violation_is_definite_even_without_accuracy() {
        let eng = engine();
        // A 1 ns deadline nobody can meet plus an accuracy bound a
        // latency-only run cannot measure: the verdict must still be a
        // definite violation, not an open "no constraints".
        let qos = QosRequirements {
            max_latency_ns: Some(1),
            min_accuracy: Some(0.9),
            min_hit_rate: 1.0,
        };
        let cfg = StreamConfig {
            scenario: scenario(50_000_000),
            clients: 1,
            frames_per_client: 4,
            batch: BatchPolicy::immediate(),
        };
        let r = run_stream(&*eng, &cfg, None, &qos).unwrap();
        assert_eq!(r.deadline_hit_rate, Some(0.0));
        assert_eq!(r.qos_satisfied, Some(false));
        // With an achievable deadline the accuracy bound stays open.
        let loose = QosRequirements {
            max_latency_ns: Some(10_000_000_000),
            min_accuracy: Some(0.9),
            min_hit_rate: 1.0,
        };
        let r = run_stream(&*eng, &cfg, None, &loose).unwrap();
        assert_eq!(r.qos_satisfied, None);
    }

    #[test]
    fn zero_sized_runs_are_rejected() {
        let eng = engine();
        let mut cfg = StreamConfig {
            scenario: scenario(0),
            clients: 0,
            frames_per_client: 4,
            batch: BatchPolicy::immediate(),
        };
        assert!(run_stream(&*eng, &cfg, None, &QosRequirements::none())
            .is_err());
        cfg.clients = 1;
        cfg.frames_per_client = 0;
        assert!(run_stream(&*eng, &cfg, None, &QosRequirements::none())
            .is_err());
    }

    #[test]
    fn mc_needs_matching_tier_chain() {
        let eng = engine();
        let mut sc = scenario(0);
        sc.kind = ScenarioKind::Mc { cuts: vec![5, 9] };
        // 2 cuts over 2 tiers: rejected (needs 3).
        let cfg = StreamConfig {
            scenario: sc,
            clients: 1,
            frames_per_client: 2,
            batch: BatchPolicy::immediate(),
        };
        assert!(run_stream(&*eng, &cfg, None, &QosRequirements::none())
            .is_err());
    }

    #[test]
    fn three_tier_chain_runs_and_charges_every_hop() {
        let eng = engine();
        let mut sc = scenario(50_000_000);
        sc.kind = ScenarioKind::Mc { cuts: vec![5, 9] };
        sc.tiers = vec![
            DeviceProfile::sensor_npu(),
            DeviceProfile::edge_gpu(),
            DeviceProfile::server_gpu(),
        ];
        let cfg = StreamConfig {
            scenario: sc,
            clients: 1,
            frames_per_client: 4,
            batch: BatchPolicy::immediate(),
        };
        let r = run_stream(&*eng, &cfg, None, &QosRequirements::none())
            .unwrap();
        assert_eq!(r.frames, 4);
        // Two uplink hops + two downlink hops of wire traffic per frame:
        // strictly more than the single-hop SC equivalent at the deeper
        // cut alone.
        let mut sc1 = scenario(50_000_000);
        sc1.kind = ScenarioKind::Sc { split: 9 };
        let one = run_stream(
            &*eng,
            &StreamConfig {
                scenario: sc1,
                clients: 1,
                frames_per_client: 4,
                batch: BatchPolicy::immediate(),
            },
            None,
            &QosRequirements::none(),
        )
        .unwrap();
        assert!(r.mean_wire_bytes > one.mean_wire_bytes);
        assert!(r.mean_latency_ns > 0.0);
    }

    #[test]
    fn batching_amortizes_server_overhead() {
        let eng = engine();
        let mk = |batch: BatchPolicy| StreamConfig {
            scenario: scenario(200_000), // 5000 FPS offered
            clients: 4,
            frames_per_client: 12,
            batch,
        };
        let unbatched = run_stream(
            &*eng,
            &mk(BatchPolicy::immediate()),
            None,
            &QosRequirements::none(),
        )
        .unwrap();
        let batched = run_stream(
            &*eng,
            &mk(BatchPolicy::new(8, 1_000_000)),
            None,
            &QosRequirements::none(),
        )
        .unwrap();
        assert_eq!(unbatched.stats.mean_batch_size(), 1.0);
        assert!(batched.stats.mean_batch_size() > 1.0);
        assert_eq!(batched.frames, unbatched.frames);
    }

    #[test]
    fn linear_scan_backend_matches_calendar_exactly() {
        let eng = engine();
        let cfg = StreamConfig {
            scenario: scenario(150_000),
            clients: 4,
            frames_per_client: 10,
            batch: BatchPolicy::new(4, 1_000_000),
        };
        let qos = QosRequirements::none();
        let cal = run_stream_with_queue(
            &*eng,
            &cfg,
            None,
            &qos,
            QueueKind::Calendar,
        )
        .unwrap();
        let lin = run_stream_with_queue(
            &*eng,
            &cfg,
            None,
            &qos,
            QueueKind::LinearScan,
        )
        .unwrap();
        assert_eq!(cal.records, lin.records);
        assert_eq!(
            cal.stats.events_processed,
            lin.stats.events_processed
        );
        assert!(cal.stats.events_processed > 0);
    }

    #[test]
    fn wheel_backend_matches_calendar_exactly() {
        let eng = engine();
        let cfg = StreamConfig {
            scenario: scenario(150_000),
            clients: 4,
            frames_per_client: 10,
            batch: BatchPolicy::new(4, 1_000_000),
        };
        let qos = QosRequirements::none();
        let cal = run_stream_with_queue(
            &*eng,
            &cfg,
            None,
            &qos,
            QueueKind::Calendar,
        )
        .unwrap();
        let whl = run_stream_with_queue(
            &*eng,
            &cfg,
            None,
            &qos,
            QueueKind::Wheel,
        )
        .unwrap();
        assert_eq!(cal.records, whl.records);
        assert_eq!(
            cal.stats.events_processed,
            whl.stats.events_processed
        );
        assert!(whl.stats.events_processed > 0);
    }

    #[test]
    fn merged_event_counters_saturate_instead_of_wrapping() {
        let eng = engine();
        let cfg = StreamConfig {
            scenario: scenario(150_000),
            clients: 1,
            frames_per_client: 2,
            batch: BatchPolicy::immediate(),
        };
        let qos = QosRequirements::none();
        let a = run_stream(&*eng, &cfg, None, &qos).unwrap();
        let mut b = a.clone();
        let mut c = a.clone();
        b.stats.events_processed = u64::MAX - 5;
        c.stats.events_processed = 100;
        let merged = merge_stream_reports(1, 0.0, vec![b, c], &qos);
        // A wrapping sum would report ~94 events; the saturating fold
        // pins at the ceiling, which is visibly wrong instead of tiny.
        assert_eq!(merged.stats.events_processed, u64::MAX);
    }

    fn hetero_cfg(clients: Vec<ClientSpec>) -> MultiStreamConfig {
        MultiStreamConfig {
            clients,
            hop_nets: vec![NetworkConfig::gigabit(Protocol::Udp, 0.0, 9)],
            tiers: vec![
                DeviceProfile::edge_gpu(),
                DeviceProfile::server_gpu(),
            ],
            batch: BatchPolicy::immediate(),
            fairness: Fairness::Drr,
            admission: true,
            queue: QueueKind::Calendar,
        }
    }

    #[test]
    fn hetero_mixed_kinds_conserve_frames() {
        let eng = engine();
        let engines: Vec<(Arch, &dyn InferenceBackend)> =
            vec![(Arch::Vgg16, &*eng)];
        let mut rc = ClientSpec::new(ScenarioKind::Rc);
        rc.frame_period_ns = 2_000_000;
        rc.frames = 6;
        let mut sc = ClientSpec::new(ScenarioKind::Sc { split: 9 });
        sc.frame_period_ns = 3_000_000;
        sc.frames = 4;
        let cfg = hetero_cfg(vec![rc, sc]);
        let r = run_hetero_stream(
            &engines,
            &cfg,
            None,
            &QosRequirements::none(),
        )
        .unwrap();
        assert_eq!(r.admitted(), 2);
        assert_eq!(r.aggregate.frames, 10);
        // Records are grouped per client, each stream complete and in
        // frame order.
        assert!(r.aggregate.records[..6]
            .iter()
            .enumerate()
            .all(|(f, rec)| rec.client == 0 && rec.frame == f));
        assert!(r.aggregate.records[6..]
            .iter()
            .enumerate()
            .all(|(f, rec)| rec.client == 1 && rec.frame == f));
        assert_eq!(r.outcomes[0].frames, 6);
        assert_eq!(r.outcomes[1].frames, 4);
        assert!(r.outcomes.iter().all(|o| o.reject_reason.is_none()));
    }

    #[test]
    fn admission_rejects_unservable_stream_and_isolates_the_rest() {
        let eng = engine();
        let engines: Vec<(Arch, &dyn InferenceBackend)> =
            vec![(Arch::Vgg16, &*eng)];
        // The light, servable client comes FIRST so its greedy admission
        // decision cannot depend on the hog behind it.
        let mut light = ClientSpec::new(ScenarioKind::Rc);
        light.frame_period_ns = 5_000_000;
        light.frames = 4;
        // A 1 ns frame period is beyond any resource's service rate.
        let mut hog = ClientSpec::new(ScenarioKind::Sc { split: 9 });
        hog.frame_period_ns = 1;
        hog.frames = 4;
        let both = hetero_cfg(vec![light.clone(), hog]);
        let r = run_hetero_stream(
            &engines,
            &both,
            None,
            &QosRequirements::none(),
        )
        .unwrap();
        assert_eq!(r.admitted(), 1);
        assert!(r.outcomes[0].admitted);
        assert!(!r.outcomes[1].admitted);
        let reason = r.outcomes[1].reject_reason.as_deref().unwrap();
        assert!(reason.contains("admission"), "{reason}");
        assert_eq!(r.outcomes[1].frames, 0);
        // The admitted stream's records are byte-identical to a run where
        // the rejected stream was never offered.
        let solo = hetero_cfg(vec![light]);
        let s = run_hetero_stream(
            &engines,
            &solo,
            None,
            &QosRequirements::none(),
        )
        .unwrap();
        assert_eq!(r.aggregate.records, s.aggregate.records);
    }

    #[test]
    fn clients_spec_parses_and_expands_counts() {
        let specs = parse_clients_spec(
            r#"[
                {"scenario": "rc", "count": 2, "fps": 20.0},
                {"scenario": "sc@9", "frames": 5, "weight": 3,
                 "max_latency_ms": 50.0}
            ]"#,
        )
        .unwrap();
        assert_eq!(specs.len(), 3);
        assert!(matches!(specs[0].kind, ScenarioKind::Rc));
        assert!(matches!(specs[1].kind, ScenarioKind::Rc));
        assert_eq!(specs[0].frame_period_ns, 50_000_000);
        assert!(matches!(specs[2].kind, ScenarioKind::Sc { split: 9 }));
        assert_eq!(specs[2].frames, 5);
        assert_eq!(specs[2].weight, 3);
        assert_eq!(specs[2].qos.max_latency_ns, Some(50_000_000));
        // The wrapped-object form parses identically.
        let wrapped = parse_clients_spec(
            r#"{"clients": [{"scenario": "lc"}]}"#,
        )
        .unwrap();
        assert_eq!(wrapped.len(), 1);
        assert!(matches!(wrapped[0].kind, ScenarioKind::Lc));
    }

    #[test]
    fn clients_spec_errors_name_the_offending_entry() {
        let err = parse_clients_spec(
            r#"[{"scenario": "rc"}, {"scenario": "rc", "color": 1}]"#,
        )
        .unwrap_err()
        .to_string();
        assert!(
            err.contains("clients[1]") && err.contains("color"),
            "{err}"
        );
        let err = parse_clients_spec(
            r#"[{"scenario": "rc", "fps": 20, "frame_period_ns": 100}]"#,
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("not both"), "{err}");
        let err = parse_clients_spec(r#"[{"count": 3}]"#)
            .unwrap_err()
            .to_string();
        assert!(
            err.contains("clients[0]") && err.contains("scenario"),
            "{err}"
        );
        let err = parse_clients_spec(
            r#"[{"scenario": "rc", "min_accuracy": 1.5}]"#,
        )
        .unwrap_err()
        .to_string();
        assert!(
            err.contains("clients[0]") && err.contains("min_accuracy"),
            "{err}"
        );
        assert!(parse_clients_spec("[]").is_err());
    }
}
