//! Deficit-round-robin (DRR) scheduling for the shared tiers of the
//! multi-tenant streaming engine.
//!
//! With FIFO service at a shared lane or device, one aggressive stream can
//! starve every other tenant: its backlog sits at the head of the queue and
//! light streams wait behind the full burst. DRR (Shreedhar & Varghese,
//! SIGCOMM '95) bounds that: each active client queue holds a *deficit
//! counter*; a queue at the head of the active ring may serve items while
//! their cost fits its deficit, the deficit grows by `weight × quantum`
//! per round, and unserved queues keep their credit. With `quantum` at
//! least the maximum item cost, every active client is guaranteed service
//! proportional to its weight per round — the classic O(1) fairness bound.
//!
//! Cost units are per-resource: bytes at the network lanes, mult-adds at
//! the compute tiers, and 1 per request at the batcher (pure round-robin).

use std::collections::VecDeque;

/// A multi-client queue served in deficit-round-robin order.
///
/// Items are `(cost, payload)` per client; `pop` returns payloads in DRR
/// order. Deterministic: ring order is a pure function of the push/pop
/// sequence, so simulations stay replayable.
///
/// **Reuse contract:** a fully drained queue is back in its pristine
/// state — the ring is empty, every departing client's deficit is zeroed
/// and `in_ring` cleared — so long-lived holders (the pooled
/// [`super::batcher::DrrBatcher`] scratch, the per-resource lanes of the
/// streaming engine) reuse one instance across rounds; its per-client
/// `VecDeque`s keep their capacity, which is what makes the steady-state
/// serve loop allocation-free.
pub struct DrrQueue<T> {
    queues: Vec<VecDeque<(u64, T)>>,
    deficit: Vec<u64>,
    weight: Vec<u64>,
    quantum: u64,
    /// Active clients in service order; `ring[0]` is being served.
    ring: VecDeque<usize>,
    in_ring: Vec<bool>,
    len: usize,
}

impl<T> DrrQueue<T> {
    /// One queue per client. `weights[c]` scales client `c`'s share
    /// (minimum 1 is enforced); `quantum` should be at least the maximum
    /// single-item cost for the one-item-per-round service guarantee
    /// (minimum 1 is enforced so the scheduler always makes progress).
    pub fn new(weights: &[u64], quantum: u64) -> Self {
        let n = weights.len();
        DrrQueue {
            queues: (0..n).map(|_| VecDeque::new()).collect(),
            deficit: vec![0; n],
            weight: weights.iter().map(|&w| w.max(1)).collect(),
            quantum: quantum.max(1),
            ring: VecDeque::new(),
            in_ring: vec![false; n],
            len: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Enqueue `item` for `client` with the given service cost. A newly
    /// active client joins the back of the ring with zero deficit (credit
    /// never accumulates while idle).
    #[inline]
    pub fn push(&mut self, client: usize, cost: u64, item: T) {
        self.queues[client].push_back((cost, item));
        self.len += 1;
        if !self.in_ring[client] {
            self.in_ring[client] = true;
            self.deficit[client] = 0;
            self.ring.push_back(client);
        }
    }

    /// Dequeue the next item in DRR order.
    #[inline]
    pub fn pop(&mut self) -> Option<T> {
        if self.len == 0 {
            return None;
        }
        loop {
            let c = *self.ring.front().expect("len > 0 with empty ring");
            let head_cost =
                self.queues[c].front().expect("ringed client empty").0;
            if head_cost <= self.deficit[c] {
                let (cost, item) =
                    self.queues[c].pop_front().expect("checked front");
                self.deficit[c] -= cost;
                self.len -= 1;
                if self.queues[c].is_empty() {
                    // Leaving the ring forfeits remaining credit.
                    self.deficit[c] = 0;
                    self.in_ring[c] = false;
                    self.ring.pop_front();
                }
                return Some(item);
            }
            // Head item does not fit: credit one round and move to the
            // back of the ring. Deficit grows monotonically, so any finite
            // cost is eventually served — no livelock.
            self.deficit[c] = self.deficit[c]
                .saturating_add(self.weight[c].saturating_mul(self.quantum));
            self.ring.rotate_left(1);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_pops_none() {
        let mut q: DrrQueue<u32> = DrrQueue::new(&[1, 1], 10);
        assert!(q.is_empty());
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn single_client_is_fifo() {
        let mut q = DrrQueue::new(&[1], 4);
        for i in 0..5u32 {
            q.push(0, 1, i);
        }
        let out: Vec<u32> = std::iter::from_fn(|| q.pop()).collect();
        assert_eq!(out, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn unit_costs_equal_weights_round_robin() {
        // Client 0 has a deep backlog, client 1 a shallow one: with unit
        // costs, equal weights and quantum >= cost, service strictly
        // alternates — the backlog cannot starve the light client.
        let mut q = DrrQueue::new(&[1, 1], 1);
        for i in 0..6u32 {
            q.push(0, 1, 100 + i);
        }
        for i in 0..3u32 {
            q.push(1, 1, 200 + i);
        }
        let out: Vec<u32> = std::iter::from_fn(|| q.pop()).collect();
        assert_eq!(
            out,
            vec![100, 200, 101, 201, 102, 202, 103, 104, 105]
        );
    }

    #[test]
    fn weights_scale_service_share() {
        // Weight 2 vs 1 with unit costs: per round, client 0 serves two
        // items for each one of client 1.
        let mut q = DrrQueue::new(&[2, 1], 1);
        for i in 0..8u32 {
            q.push(0, 1, i);
        }
        for i in 0..4u32 {
            q.push(1, 1, 100 + i);
        }
        let out: Vec<u32> = std::iter::from_fn(|| q.pop()).collect();
        // First 6 services: client 0 gets 4, client 1 gets 2.
        let head = &out[..6];
        let c0 = head.iter().filter(|&&x| x < 100).count();
        assert_eq!(c0, 4, "{out:?}");
    }

    #[test]
    fn starvation_bound_under_heavy_skew() {
        // 100:1 backlog skew with quantum = max cost: the light client is
        // served at least once per round, i.e. its single item departs
        // within 2 services of joining the ring — not after the heavy
        // client's 100-item burst.
        let mut q = DrrQueue::new(&[1, 1], 5);
        for i in 0..100u32 {
            q.push(0, 5, i);
        }
        q.push(1, 5, 9999);
        let mut served_at = None;
        for k in 0..102 {
            let item = q.pop().unwrap();
            if item == 9999 {
                served_at = Some(k);
                break;
            }
        }
        assert!(
            served_at.unwrap() <= 2,
            "light client starved: served at position {served_at:?}"
        );
    }

    #[test]
    fn oversized_items_still_make_progress() {
        // An item costing far more than weight*quantum needs several
        // credit rounds but is eventually served.
        let mut q = DrrQueue::new(&[1, 1], 2);
        q.push(0, 1000, 7u32);
        q.push(1, 1, 8u32);
        let a = q.pop().unwrap();
        let b = q.pop().unwrap();
        assert_eq!({ let mut v = vec![a, b]; v.sort(); v }, vec![7, 8]);
        assert!(q.is_empty());
    }

    #[test]
    fn drained_queue_is_reusable() {
        // The reuse contract the pooled DrrBatcher scratch depends on: a
        // fully drained queue must behave exactly like a fresh one.
        let mut fresh = DrrQueue::new(&[1, 2], 1);
        let mut reused = DrrQueue::new(&[1, 2], 1);
        // Dirty `reused` with an asymmetric round, then drain it.
        for i in 0..5u32 {
            reused.push(0, 1, i);
        }
        reused.push(1, 1, 99);
        while reused.pop().is_some() {}
        // Same workload into both: identical service order.
        for q in [&mut fresh, &mut reused] {
            for i in 0..4u32 {
                q.push(i as usize % 2, 1, 10 + i);
            }
        }
        let a: Vec<u32> = std::iter::from_fn(|| fresh.pop()).collect();
        let b: Vec<u32> = std::iter::from_fn(|| reused.pop()).collect();
        assert_eq!(a, b);
    }

    #[test]
    fn idle_clients_do_not_hoard_credit() {
        let mut q = DrrQueue::new(&[1, 1], 1);
        q.push(0, 1, 1u32);
        assert_eq!(q.pop(), Some(1));
        // Client 0 went idle; re-arrival starts from zero deficit and the
        // back of the ring.
        q.push(1, 1, 2);
        q.push(0, 1, 3);
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), Some(3));
    }
}
