//! Hardware-in-the-loop (HIL) bridge — paper Sec. IV: the simulator "must
//! allow the integration of real-world components, such as a real computing
//! system".
//!
//! This module replaces the *simulated* server with a real worker process
//! (or thread) reached over an actual TCP socket: the leader runs the head
//! locally, ships the latent over the wire with a small length-prefixed
//! frame protocol, and the worker runs the tail on its own inference
//! backend (PJRT under the `xla` feature, analytic otherwise) and returns
//! the logits. Round-trip wall time is measured, giving a real (not
//! simulated) latency sample to calibrate the netsim against.
//!
//! Frame protocol (little-endian):
//!   request:  [magic u32 = 0x5E1F00D] [n_bytes u32] [payload f32 bytes]
//!   response: [magic u32]             [n_bytes u32] [payload f32 bytes]
//! A zero-length request asks the worker to shut down.

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::path::Path;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::runtime::{load_backend, Executable, InferenceBackend, RtInput};
use crate::tensor::Tensor;

const MAGIC: u32 = 0x05E1_F00D;

fn write_frame(stream: &mut TcpStream, payload: &[f32]) -> Result<()> {
    let mut buf = Vec::with_capacity(8 + payload.len() * 4);
    buf.extend_from_slice(&MAGIC.to_le_bytes());
    buf.extend_from_slice(&((payload.len() * 4) as u32).to_le_bytes());
    for v in payload {
        buf.extend_from_slice(&v.to_le_bytes());
    }
    stream.write_all(&buf).context("writing frame")
}

fn read_frame(stream: &mut TcpStream) -> Result<Vec<f32>> {
    let mut header = [0u8; 8];
    stream.read_exact(&mut header).context("reading frame header")?;
    let magic = u32::from_le_bytes(header[0..4].try_into().unwrap());
    if magic != MAGIC {
        bail!("bad frame magic {magic:#x}");
    }
    let n = u32::from_le_bytes(header[4..8].try_into().unwrap()) as usize;
    if n % 4 != 0 {
        bail!("frame length {n} not f32-aligned");
    }
    let mut payload = vec![0u8; n];
    stream.read_exact(&mut payload).context("reading frame payload")?;
    Ok(payload
        .chunks_exact(4)
        .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
        .collect())
}

/// Worker: serve `exec_name` on `addr` until a shutdown frame arrives.
/// Returns the number of requests served.
pub fn run_worker(artifacts: &Path, addr: &str, exec_name: &str)
    -> Result<u64>
{
    let engine = load_backend(artifacts)?;
    let exec = engine.executable(exec_name)?;
    let input_shape = exec.spec().inputs[0].shape.clone();
    let n_in: usize = input_shape.iter().product();
    let listener = TcpListener::bind(addr)
        .with_context(|| format!("binding {addr}"))?;
    let (mut stream, peer) = listener.accept().context("accept")?;
    stream.set_nodelay(true).ok();
    let mut served = 0u64;
    loop {
        let payload = read_frame(&mut stream)?;
        if payload.is_empty() {
            break; // shutdown
        }
        if payload.len() != n_in {
            bail!(
                "worker {exec_name}: got {} floats, artifact wants {n_in} \
                 (peer {peer})",
                payload.len()
            );
        }
        let input = Tensor::new(input_shape.clone(), payload)?;
        let out = exec.run(&[RtInput::F32(&input)])?;
        write_frame(&mut stream, out.data())?;
        served += 1;
    }
    Ok(served)
}

/// Leader-side connection to a HIL worker.
pub struct HilClient {
    stream: TcpStream,
    /// Wall-clock round-trip times, ns.
    pub rtts_ns: Vec<u64>,
}

impl HilClient {
    pub fn connect(addr: &str) -> Result<HilClient> {
        // The worker may still be binding; retry briefly.
        let mut last_err = None;
        for _ in 0..100 {
            match TcpStream::connect(addr) {
                Ok(stream) => {
                    stream.set_nodelay(true).ok();
                    return Ok(HilClient { stream, rtts_ns: Vec::new() });
                }
                Err(e) => {
                    last_err = Some(e);
                    std::thread::sleep(std::time::Duration::from_millis(20));
                }
            }
        }
        bail!("connecting {addr}: {last_err:?}")
    }

    /// Ship a tensor to the worker, get the result, record the RTT.
    pub fn infer(&mut self, input: &Tensor, out_shape: Vec<usize>)
        -> Result<Tensor>
    {
        let t0 = Instant::now();
        write_frame(&mut self.stream, input.data())?;
        let out = read_frame(&mut self.stream)?;
        self.rtts_ns.push(t0.elapsed().as_nanos() as u64);
        Tensor::new(out_shape, out)
    }

    pub fn shutdown(mut self) -> Result<()> {
        write_frame(&mut self.stream, &[])
    }

    pub fn mean_rtt_ns(&self) -> f64 {
        if self.rtts_ns.is_empty() {
            0.0
        } else {
            self.rtts_ns.iter().sum::<u64>() as f64
                / self.rtts_ns.len() as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frame_roundtrip_over_localhost() {
        // Pure protocol test with an echo peer (no artifacts needed).
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let echo = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            loop {
                let p = read_frame(&mut s).unwrap();
                if p.is_empty() {
                    break;
                }
                write_frame(&mut s, &p).unwrap();
            }
        });
        let mut client = HilClient::connect(&addr.to_string()).unwrap();
        let t = Tensor::new(vec![2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0])
            .unwrap();
        let back = client.infer(&t, vec![2, 3]).unwrap();
        assert_eq!(back, t);
        assert_eq!(client.rtts_ns.len(), 1);
        assert!(client.mean_rtt_ns() > 0.0);
        client.shutdown().unwrap();
        echo.join().unwrap();
    }

    #[test]
    fn bad_magic_rejected() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let bad = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            s.write_all(&[0u8; 8]).unwrap();
        });
        let mut s = TcpStream::connect(addr).unwrap();
        assert!(read_frame(&mut s).is_err());
        bad.join().unwrap();
    }
}
