//! Shared admissible analytic latency lower bound — the single place
//! both searches reason about "how fast could this candidate possibly
//! be" before paying for a discrete-event simulation.
//!
//! The bound charges exactly the work no schedule can avoid: each
//! pipeline segment's compute time on its device
//! ([`DeviceProfile::compute_ns`]) plus, per uplink hop, the payload's
//! serialization at the link's bottleneck rate and its propagation
//! latency. Everything else the closed-loop streaming engine models —
//! queueing behind other frames or clients, protocol headers, ACK
//! coupling, retransmits, jitter (uniform in `[0, jitter]`, so strictly
//! additive), batching waits, and the downlink return — can only *add*
//! latency, which is what makes the bound admissible: no simulated frame
//! of the candidate ever finishes faster.
//!
//! Two consumers ride it:
//!
//! - the fleet placement search ([`super::placement`]) orders and prunes
//!   candidates by [`latency_bound_ns`] over their [`ChainCosts`];
//! - the sweep engine ([`super::sweep`]) optionally two-phases its grid
//!   (`"prefilter": true`): [`job_bound_ns`] bounds a whole grid point,
//!   and a point whose bound already exceeds the QoS deadline is
//!   *provably* infeasible — every frame would miss, the deadline
//!   hit-rate would be 0, below any valid `min_hit_rate` — so the full
//!   simulation is skipped and the point reported as such.
//!
//! Points the bound cannot vouch for return `None` instead of a number:
//! heterogeneous tenant mixes (per-tenant costs live inside the
//! multi-tenant engine) and traced channels (a schedule may *improve*
//! mid-run — e.g. a `congested>gigabit` recovery — so the initial
//! channel is not a lower bound for the whole stream).

use anyhow::Result;

use super::scenario::{derive_hop_net, kind_costs};
use super::sweep::{channel_preset, SweepJob, SweepSpec};
use crate::model::{ChainCosts, DeviceProfile};
use crate::netsim::event::SimTime;
use crate::netsim::transfer::NetworkConfig;
use crate::runtime::InferenceBackend;

/// Admissible latency lower bound of one frame through a candidate
/// placement: per-segment compute plus per-hop payload serialization at
/// capacity and propagation latency. The simulator can only add to this
/// (queueing, protocol headers, acks, retransmits, downlink).
pub fn latency_bound_ns(
    tiers: &[&DeviceProfile],
    costs: &ChainCosts,
    hop_nets: &[&NetworkConfig],
) -> SimTime {
    let mut t: SimTime = 0;
    for (d, &ma) in tiers.iter().zip(&costs.seg_mult_adds) {
        t = t.saturating_add(d.compute_ns(ma));
    }
    for (net, &bytes) in hop_nets.iter().zip(&costs.hop_bytes) {
        t = t.saturating_add(hop_bound_ns(net, bytes));
    }
    t
}

/// The unavoidable cost of one payload crossing one hop: serialization
/// at the link's bottleneck rate plus propagation latency (truncation
/// rounds down — still a lower bound).
fn hop_bound_ns(net: &NetworkConfig, bytes: u64) -> SimTime {
    let rate = net.capacity_bps.min(net.interface_bps);
    let wire = (bytes as f64 * 8.0 / rate * 1e9) as SimTime;
    net.latency_ns.saturating_add(wire)
}

/// Admissible latency lower bound of one frame of a sweep grid point, or
/// `None` when no sound bound exists for it (tenant-mix and traced
/// points — see the module docs). Deterministic in `(spec, job)` and the
/// backend manifest alone; channel seeds never enter the bound.
pub fn job_bound_ns(
    engine: &dyn InferenceBackend,
    spec: &SweepSpec,
    job: &SweepJob,
) -> Result<Option<SimTime>> {
    if job.mix.is_some() || job.trace.is_some() {
        return Ok(None);
    }
    let tiers: Vec<DeviceProfile> = job
        .tiers
        .iter()
        .map(|d| DeviceProfile::parse(d))
        .collect::<Result<_>>()?;
    let costs = kind_costs(engine, &job.kind, job.scale, tiers.len())?;
    // The channel chain exactly as `run_job` derives it (the seed only
    // shifts loss/jitter draws, which the bound ignores).
    let nets: Vec<NetworkConfig> = if job.hop_nets.is_empty() {
        let mut net = channel_preset(
            &job.channel,
            job.protocol,
            job.loss,
            spec.seed,
        )?;
        if let Some(us) = job.latency_us {
            net.latency_ns = (us * 1000.0) as SimTime;
        }
        vec![net]
    } else {
        job.hop_nets
            .iter()
            .map(|s| NetworkConfig::parse(s))
            .collect::<Result<_>>()?
    };
    let hop_nets: Vec<NetworkConfig> = (0..costs.hops())
        .map(|h| derive_hop_net(&nets, h))
        .collect();
    // Devices executing each pipeline segment, mirroring the streaming
    // engine's mapping: RC/SC on a longer chain bypass the middle tiers
    // (first and last device only); MC segments are one-to-one.
    let n_seg = costs.seg_mult_adds.len();
    let mut t: SimTime = 0;
    for (s, &ma) in costs.seg_mult_adds.iter().enumerate() {
        let d = if s == 0 {
            &tiers[0]
        } else if s + 1 == n_seg {
            tiers.last().expect("tier count validated by kind_costs")
        } else {
            &tiers[s]
        };
        t = t.saturating_add(d.compute_ns(ma));
    }
    for (net, &bytes) in hop_nets.iter().zip(&costs.up_bytes) {
        t = t.saturating_add(hop_bound_ns(net, bytes));
    }
    Ok(Some(t))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::load_backend_for;
    use std::path::Path;

    #[test]
    fn job_bound_declines_mix_and_traced_points() {
        let engine =
            load_backend_for(Path::new("artifacts"), Default::default())
                .unwrap();
        let spec = SweepSpec::new("bound-unit");
        let jobs = spec.expand().unwrap();
        let mut traced = jobs[0].clone();
        traced.trace = Some("hop0=gigabit>congested@2s".to_string());
        assert!(job_bound_ns(&*engine, &spec, &traced).unwrap().is_none());
    }

    #[test]
    fn job_bound_grows_with_propagation_latency() {
        let engine =
            load_backend_for(Path::new("artifacts"), Default::default())
                .unwrap();
        let spec = SweepSpec::new("bound-unit");
        let jobs = spec.expand().unwrap();
        let base = job_bound_ns(&*engine, &spec, &jobs[0])
            .unwrap()
            .expect("homogeneous untraced point has a bound");
        let mut slow = jobs[0].clone();
        slow.latency_us = Some(200_000.0);
        let far = job_bound_ns(&*engine, &spec, &slow)
            .unwrap()
            .expect("homogeneous untraced point has a bound");
        // 200 ms of one-way propagation must show up in full.
        assert!(far >= base + 200_000_000, "{base} -> {far}");
    }
}
