//! Serving driver: streams frames (the ICE-Lab conveyor belt) through a
//! configured scenario in real time, with actual backend inference per
//! frame, and reports accuracy / latency / throughput / deadline behaviour.
//!
//! This is the end-to-end validation path: every layer composes — dataset
//! loader -> scenario engine -> netsim -> inference backend -> QoS verdict.

use std::time::Instant;

use anyhow::Result;

use super::qos::QosRequirements;
use super::scenario::{run_scenario, ScenarioConfig, ScenarioReport};
use crate::data::Dataset;
use crate::netsim::event::secs;
use crate::runtime::InferenceBackend;

#[derive(Clone, Debug)]
pub struct ServeReport {
    pub scenario: ScenarioReport,
    /// Real wall-clock seconds spent serving (backend + coordinator).
    pub wall_seconds: f64,
    /// Real frames per second achieved by the serving path.
    pub wall_fps: f64,
    /// Simulated frames per second (1 / mean simulated latency).
    pub sim_fps: f64,
    pub frames: usize,
}

impl ServeReport {
    pub fn render(&self, qos: &QosRequirements) -> String {
        let s = &self.scenario;
        let mut out = String::new();
        out.push_str(&format!(
            "scenario           {} over {} (loss {:.1}%)\n",
            s.kind,
            s.protocol,
            s.loss_rate * 100.0
        ));
        out.push_str(&format!("frames             {}\n", self.frames));
        out.push_str(&format!(
            "accuracy           {:.2}%\n",
            s.accuracy * 100.0
        ));
        out.push_str(&format!(
            "sim latency        mean {:.2} ms | p95 {:.2} ms | max {:.2} ms\n",
            s.mean_latency_ns / 1e6,
            s.p95_latency_ns as f64 / 1e6,
            s.max_latency_ns as f64 / 1e6
        ));
        out.push_str(&format!(
            "sim throughput     {:.1} FPS\n",
            self.sim_fps
        ));
        if let Some(hit) = s.deadline_hit_rate {
            out.push_str(&format!(
                "deadline hit-rate  {:.1}% of frames\n",
                hit * 100.0
            ));
        }
        out.push_str(&format!(
            "wire traffic       {:.0} B/frame, {} retransmits total\n",
            s.mean_wire_bytes, s.total_retransmits
        ));
        out.push_str(&format!(
            "serving wall time  {:.2} s ({:.1} frames/s real)\n",
            self.wall_seconds, self.wall_fps
        ));
        out.push_str(&format!("QoS ({})\n", qos.describe()));
        out.push_str(&format!(
            "VERDICT            {}\n",
            match s.qos_satisfied {
                Some(true) => "SATISFIED",
                Some(false) => "VIOLATED",
                None => "no constraints",
            }
        ));
        out
    }
}

/// Serve `n_frames` frames from `dataset` through `cfg`.
pub fn serve(
    engine: &dyn InferenceBackend,
    cfg: &ScenarioConfig,
    dataset: &Dataset,
    n_frames: usize,
    qos: &QosRequirements,
) -> Result<ServeReport> {
    let t0 = Instant::now();
    let scenario = run_scenario(engine, cfg, dataset, n_frames, qos)?;
    let wall = t0.elapsed().as_secs_f64();
    let sim_fps = if scenario.mean_latency_ns > 0.0 {
        1e9 / scenario.mean_latency_ns
    } else {
        f64::INFINITY
    };
    Ok(ServeReport {
        frames: scenario.frames,
        wall_seconds: wall,
        wall_fps: scenario.frames as f64 / wall.max(1e-9),
        sim_fps,
        scenario,
    })
}

/// Total simulated duration of a report's frame stream.
pub fn simulated_duration_secs(report: &ScenarioReport) -> f64 {
    report
        .records
        .iter()
        .map(|r| r.latency_ns)
        .max()
        .map(secs)
        .unwrap_or(0.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::scenario::{ScenarioKind, ScenarioReport};
    use crate::netsim::transfer::Protocol;

    #[test]
    fn render_contains_verdict() {
        let report = ServeReport {
            scenario: ScenarioReport {
                kind: ScenarioKind::Lc,
                protocol: Protocol::Tcp,
                loss_rate: 0.0,
                frames: 1,
                accuracy: 1.0,
                mean_latency_ns: 1e6,
                p95_latency_ns: 1_000_000,
                max_latency_ns: 1_000_000,
                mean_wire_bytes: 0.0,
                total_retransmits: 0,
                deadline_hit_rate: Some(1.0),
                qos_satisfied: Some(true),
                records: vec![],
            },
            wall_seconds: 0.5,
            wall_fps: 2.0,
            sim_fps: 1000.0,
            frames: 1,
        };
        let txt = report.render(&QosRequirements::ice_lab());
        assert!(txt.contains("SATISFIED"));
        assert!(txt.contains("accuracy"));
    }
}
