//! Serving driver: streams frames (the ICE-Lab conveyor belt) through a
//! configured scenario in real time, with actual backend inference per
//! frame, and reports accuracy / latency / throughput / deadline behaviour.
//!
//! This is the end-to-end validation path: every layer composes — dataset
//! loader -> scenario engine -> netsim -> inference backend -> QoS verdict.

use std::time::Instant;

use anyhow::Result;

use super::qos::QosRequirements;
use super::scenario::{
    run_scenario_with_queue, ScenarioConfig, ScenarioReport,
};
use super::streaming::{run_hetero_stream, HeteroStreamReport, MultiStreamConfig};
use crate::data::Dataset;
use crate::model::Arch;
use crate::netsim::event::{secs, QueueKind};
use crate::runtime::InferenceBackend;

#[derive(Clone, Debug)]
pub struct ServeReport {
    pub scenario: ScenarioReport,
    /// Real wall-clock seconds spent serving (backend + coordinator).
    pub wall_seconds: f64,
    /// Real frames per second achieved by the serving path.
    pub wall_fps: f64,
    /// Simulated throughput: frames delivered per simulated second
    /// (frames / stream completion time, *not* 1 / mean latency — with a
    /// non-zero frame period the stream lasts much longer than any single
    /// frame's latency).
    pub sim_fps: f64,
    pub frames: usize,
}

impl ServeReport {
    pub fn render(&self, qos: &QosRequirements) -> String {
        let s = &self.scenario;
        let mut out = String::new();
        out.push_str(&format!(
            "scenario           {} over {} (loss {:.1}%)\n",
            s.kind,
            s.protocol,
            s.loss_rate * 100.0
        ));
        out.push_str(&format!("frames             {}\n", self.frames));
        out.push_str(&format!(
            "accuracy           {:.2}%\n",
            s.accuracy * 100.0
        ));
        out.push_str(&format!(
            "sim latency        mean {:.2} ms | p95 {:.2} ms | max {:.2} ms\n",
            s.mean_latency_ns / 1e6,
            s.p95_latency_ns as f64 / 1e6,
            s.max_latency_ns as f64 / 1e6
        ));
        out.push_str(&format!(
            "sim throughput     {:.1} FPS\n",
            self.sim_fps
        ));
        if let Some(hit) = s.deadline_hit_rate {
            out.push_str(&format!(
                "deadline hit-rate  {:.1}% of frames\n",
                hit * 100.0
            ));
        }
        out.push_str(&format!(
            "wire traffic       {:.0} B/frame, {} retransmits total\n",
            s.mean_wire_bytes, s.total_retransmits
        ));
        out.push_str(&format!(
            "serving wall time  {:.2} s ({:.1} frames/s real)\n",
            self.wall_seconds, self.wall_fps
        ));
        out.push_str(&format!("QoS ({})\n", qos.describe()));
        out.push_str(&format!(
            "VERDICT            {}\n",
            match s.qos_satisfied {
                Some(true) => "SATISFIED",
                Some(false) => "VIOLATED",
                None => "no constraints",
            }
        ));
        out
    }
}

/// Serve `n_frames` frames from `dataset` through `cfg`.
pub fn serve(
    engine: &dyn InferenceBackend,
    cfg: &ScenarioConfig,
    dataset: &Dataset,
    n_frames: usize,
    qos: &QosRequirements,
) -> Result<ServeReport> {
    serve_with_queue(engine, cfg, dataset, n_frames, qos, QueueKind::Calendar)
}

/// [`serve`] with an explicit event-queue backend (`--queue` on the CLI).
pub fn serve_with_queue(
    engine: &dyn InferenceBackend,
    cfg: &ScenarioConfig,
    dataset: &Dataset,
    n_frames: usize,
    qos: &QosRequirements,
    queue: QueueKind,
) -> Result<ServeReport> {
    let t0 = Instant::now();
    let scenario =
        run_scenario_with_queue(engine, cfg, dataset, n_frames, qos, queue)?;
    let wall = t0.elapsed().as_secs_f64();
    let sim_secs = simulated_duration_secs(&scenario);
    let sim_fps = if sim_secs > 0.0 {
        scenario.frames as f64 / sim_secs
    } else {
        f64::INFINITY
    };
    Ok(ServeReport {
        frames: scenario.frames,
        wall_seconds: wall,
        wall_fps: scenario.frames as f64 / wall.max(1e-9),
        sim_fps,
        scenario,
    })
}

/// Result of the multi-tenant serving path (`sei serve --clients-spec`).
#[derive(Clone, Debug)]
pub struct HeteroServeReport {
    pub report: HeteroStreamReport,
    /// Real wall-clock seconds spent serving (backend + coordinator).
    pub wall_seconds: f64,
    /// Real frames per second achieved by the serving path.
    pub wall_fps: f64,
}

impl HeteroServeReport {
    pub fn render(&self, qos: &QosRequirements) -> String {
        let mut out = self.report.render(qos);
        out.push_str(&format!(
            "serving wall time  {:.2} s ({:.1} frames/s real)\n",
            self.wall_seconds, self.wall_fps
        ));
        out
    }
}

/// Serve a heterogeneous tenant mix end-to-end: full-mode
/// [`run_hetero_stream`] (per-frame inference from `dataset`) plus
/// wall-clock accounting.
pub fn serve_clients(
    engines: &[(Arch, &dyn InferenceBackend)],
    cfg: &MultiStreamConfig,
    dataset: &Dataset,
    qos: &QosRequirements,
) -> Result<HeteroServeReport> {
    serve_clients_mode(engines, cfg, Some(dataset), qos)
}

/// [`serve_clients`] in latency-only mode: no dataset and no per-frame
/// inference, pure queueing/timing — the fleet-scale path, where a
/// 10^6-tenant run would otherwise spend its wall time on millions of
/// backend calls that cannot change any timing result.
pub fn serve_clients_latency(
    engines: &[(Arch, &dyn InferenceBackend)],
    cfg: &MultiStreamConfig,
    qos: &QosRequirements,
) -> Result<HeteroServeReport> {
    serve_clients_mode(engines, cfg, None, qos)
}

fn serve_clients_mode(
    engines: &[(Arch, &dyn InferenceBackend)],
    cfg: &MultiStreamConfig,
    dataset: Option<&Dataset>,
    qos: &QosRequirements,
) -> Result<HeteroServeReport> {
    let t0 = Instant::now();
    let report = run_hetero_stream(engines, cfg, dataset, qos)?;
    let wall = t0.elapsed().as_secs_f64();
    let frames = report.aggregate.frames;
    Ok(HeteroServeReport {
        report,
        wall_seconds: wall,
        wall_fps: frames as f64 / wall.max(1e-9),
    })
}

/// Total simulated duration of a report's frame stream: the completion
/// time of the last frame (streams start at t = 0). The old
/// implementation returned the maximum per-frame *latency*, which
/// understates the duration by a factor of ~`frames` whenever
/// `frame_period_ns > 0`.
pub fn simulated_duration_secs(report: &ScenarioReport) -> f64 {
    report
        .records
        .iter()
        .map(|r| r.completed_ns)
        .max()
        .map(secs)
        .unwrap_or(0.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::scenario::{ScenarioKind, ScenarioReport};
    use crate::netsim::transfer::Protocol;

    #[test]
    fn render_contains_verdict() {
        let report = ServeReport {
            scenario: ScenarioReport {
                kind: ScenarioKind::Lc,
                protocol: Protocol::Tcp,
                loss_rate: 0.0,
                frames: 1,
                accuracy: 1.0,
                mean_latency_ns: 1e6,
                p95_latency_ns: 1_000_000,
                p99_latency_ns: 1_000_000,
                max_latency_ns: 1_000_000,
                mean_wire_bytes: 0.0,
                total_retransmits: 0,
                deadline_hit_rate: Some(1.0),
                qos_satisfied: Some(true),
                records: vec![],
            },
            wall_seconds: 0.5,
            wall_fps: 2.0,
            sim_fps: 1000.0,
            frames: 1,
        };
        let txt = report.render(&QosRequirements::ice_lab());
        assert!(txt.contains("SATISFIED"));
        assert!(txt.contains("accuracy"));
    }

    #[test]
    fn duration_comes_from_completions_not_latencies() {
        use crate::coordinator::scenario::FrameRecord;
        use crate::model::DeviceProfile;
        use crate::netsim::transfer::NetworkConfig;
        let cfg = crate::coordinator::scenario::ScenarioConfig::two_tier(
            ScenarioKind::Lc,
            NetworkConfig::gigabit(Protocol::Tcp, 0.0, 0),
            DeviceProfile::edge_gpu(),
            DeviceProfile::server_gpu(),
            crate::coordinator::scenario::ModelScale::Slim,
            1_000_000_000,
        );
        // Two frames, 1 s apart, 2 ms latency each: the stream lasts
        // ~1.002 s — the old max-latency implementation would have said
        // 2 ms.
        let records = vec![
            FrameRecord { latency_ns: 2_000_000, completed_ns: 2_000_000,
                          correct: true, wire_bytes: 0, retransmits: 0,
                          corrupted: false },
            FrameRecord { latency_ns: 2_000_000,
                          completed_ns: 1_002_000_000, correct: true,
                          wire_bytes: 0, retransmits: 0, corrupted: false },
        ];
        let report = crate::coordinator::scenario::ScenarioReport::
            from_records(&cfg, records, &QosRequirements::none()).unwrap();
        let d = simulated_duration_secs(&report);
        assert!((d - 1.002).abs() < 1e-9, "{d}");
    }
}
