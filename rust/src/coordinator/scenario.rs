//! Scenario engine: LC / RC / SC / MC pipelines over the simulated channel
//! with *real* model inference (paper Sec. IV: supervisor / sensing / XMTR /
//! netsim / RCVR).
//!
//! Each frame of the workload runs the full pipeline:
//!
//!   LC: [edge: lite model] -> prediction
//!   RC: [edge: capture] -> XMTR(input) -> netsim -> [server: full model]
//!       -> XMTR(result) -> netsim -> prediction at the edge
//!   SC: [edge: head + AE encoder] -> XMTR(latent) -> netsim ->
//!       [server: AE decoder + tail] -> XMTR(result) -> netsim ->
//!       prediction at the edge
//!   MC: k ordered cuts over one topological order — k+1 segments on a
//!       chain of tiers (sensor -> edge -> cloud), every inter-tier hop a
//!       distinct netsim channel; the result returns hop by hop. `mc@i`
//!       over two tiers reproduces `sc@i` byte-identically.
//!
//! *Latency* is simulated time: device-profile compute + discrete-event
//! transfer. *Accuracy* is measured: the backend's executables run on the
//! (loss-corrupted, for UDP) tensors — real PJRT artifacts under the `xla`
//! feature, the hermetic analytic reference backend otherwise. Volumetrics
//! can be taken from the slim trained model or from the paper's full VGG16
//! @ 224x224 ([`ModelScale`]).
//!
//! Since the closed-loop rework, [`run_scenario`] and [`simulate_latency`]
//! ride the queueing streaming engine ([`super::streaming`]) with a single
//! client and batch size 1: a frame that arrives while the edge, channel
//! or server is still busy with its predecessor now *waits*, and that wait
//! is part of its latency. The old open-loop timing model (frame `i`
//! unconditionally starts at `i * frame_period_ns`) is retained as
//! [`run_scenario_open_loop`] / [`simulate_latency_open_loop`] — a
//! reference implementation used by regression tests to pin the low-load
//! equivalence of the two engines and to demonstrate their divergence
//! under overload. The open-loop reference predates multi-tier placement
//! and deliberately supports only the two-tier kinds.

use anyhow::{bail, Result};

use super::corruption;
use super::qos::QosRequirements;
use crate::data::Dataset;
use crate::model::{self, DeviceProfile, Network};
use crate::netsim::event::SimTime;
use crate::netsim::trace::LinkTrace;
use crate::netsim::transfer::{Channel, NetworkConfig, Protocol};
use crate::netsim::Dir;
use crate::runtime::{Executable, InferenceBackend, RtInput};
use crate::tensor::Tensor;

/// Architecture under test (paper Sec. II-A, extended with the multi-tier
/// placement axis). No longer `Copy`: the multi-cut variant owns its cut
/// chain — clone deliberately where a scenario kind crosses an API.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ScenarioKind {
    /// Local-only computing: lightweight model on the sensing device.
    Lc,
    /// Remote-only computing: raw input to the server.
    Rc,
    /// Split computing at feature layer `split` (two tiers).
    Sc { split: usize },
    /// Multi-tier split computing: `cuts.len()` ordered cuts partition the
    /// network into `cuts.len() + 1` segments over a tier chain; each
    /// inter-tier hop is its own queued channel.
    Mc { cuts: Vec<usize> },
}

impl ScenarioKind {
    /// Parse `"lc" | "rc" | "sc@<layer>" | "mc@<c1>,<c2>,..."`
    /// (case-insensitive; layer ids accept an optional `L` prefix, so
    /// `sc@L13`, `sc@13` and `mc@L4,L11` all work and
    /// [`std::fmt::Display`] round-trips).
    pub fn parse(s: &str) -> Result<ScenarioKind> {
        let t = s.to_ascii_lowercase();
        let layer = |tok: &str| -> Result<usize> {
            let tok = tok.strip_prefix('l').unwrap_or(tok);
            Ok(tok.parse()?)
        };
        match t.as_str() {
            "lc" => Ok(ScenarioKind::Lc),
            "rc" => Ok(ScenarioKind::Rc),
            other => {
                if let Some(rest) = other.strip_prefix("sc@") {
                    Ok(ScenarioKind::Sc { split: layer(rest)? })
                } else if let Some(rest) = other.strip_prefix("mc@") {
                    // An empty (or trailing-comma) cut list would surface
                    // as a bare integer-parse error from the empty token;
                    // catch it here for a useful diagnostic.
                    if rest.split(',').any(|tok| tok.is_empty()) {
                        bail!(
                            "mc@ needs a comma-separated list of cuts \
                             (e.g. mc@4,11), got '{s}'"
                        );
                    }
                    let cuts: Vec<usize> = rest
                        .split(',')
                        .map(layer)
                        .collect::<Result<_>>()?;
                    if !model::is_ordered_chain(&cuts) {
                        bail!(
                            "mc@ cuts must be strictly increasing \
                             (one topological order), got '{s}'"
                        );
                    }
                    Ok(ScenarioKind::Mc { cuts })
                } else {
                    bail!(
                        "scenario must be lc | rc | sc@<layer> | \
                         mc@<c1>,<c2>,..., got '{s}'"
                    )
                }
            }
        }
    }

    /// Number of device tiers this kind occupies: 1 for LC, 2 for RC/SC,
    /// `cuts + 1` for MC.
    pub fn tiers_needed(&self) -> usize {
        match self {
            ScenarioKind::Lc => 1,
            ScenarioKind::Rc | ScenarioKind::Sc { .. } => 2,
            ScenarioKind::Mc { cuts } => cuts.len() + 1,
        }
    }
}

impl std::fmt::Display for ScenarioKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ScenarioKind::Lc => write!(f, "LC"),
            ScenarioKind::Rc => write!(f, "RC"),
            ScenarioKind::Sc { split } => write!(f, "SC@L{split}"),
            ScenarioKind::Mc { cuts } => {
                write!(f, "MC@")?;
                for (i, c) in cuts.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "L{c}")?;
                }
                Ok(())
            }
        }
    }
}

/// Model-scale axis, re-exported from the model layer (it moved there so
/// crate-wide caches like [`crate::model::ChainCache`] can key on it
/// without depending on the coordinator); the historical
/// `coordinator::scenario::ModelScale` path keeps working.
pub use crate::model::ModelScale;

/// Seed stride between the per-hop channels of a *replicated* tier chain:
/// with a single `hop_nets` template, hop `h` simulates on
/// `net.seed + h * HOP_SEED_STRIDE`, so hop 0 keeps the configured seed
/// exactly (the two-tier degenerate-equivalence anchor) while later hops
/// draw decorrelated loss patterns.
pub(crate) const HOP_SEED_STRIDE: u64 = 0x9E37_79B9_7F4A_7C15;

/// Shared per-hop channel derivation, used both by
/// [`ScenarioConfig::hop_net`] and the heterogeneous multi-stream config:
/// a single entry is a template replicated to every hop with derived seeds
/// (hop 0 keeps the configured seed exactly); multiple entries configure
/// each hop explicitly and are returned verbatim.
pub(crate) fn derive_hop_net(
    hop_nets: &[NetworkConfig],
    hop: usize,
) -> NetworkConfig {
    if hop_nets.len() > 1 {
        return hop_nets[hop].clone();
    }
    let base = &hop_nets[0];
    let mut net = base.clone();
    net.seed = base
        .seed
        .wrapping_add((hop as u64).wrapping_mul(HOP_SEED_STRIDE));
    net
}

/// Shared reseeding contract (see [`ScenarioConfig::set_base_seed`]):
/// entry `h` takes `seed + h * HOP_SEED_STRIDE`.
pub(crate) fn reseed_hop_nets(hop_nets: &mut [NetworkConfig], seed: u64) {
    for (h, net) in hop_nets.iter_mut().enumerate() {
        net.seed =
            seed.wrapping_add((h as u64).wrapping_mul(HOP_SEED_STRIDE));
    }
}

/// Attach per-hop [`LinkTrace`]s to a hop-net chain, shared by
/// [`ScenarioConfig::apply_traces`] and the heterogeneous multi-stream
/// config. A trace targets one hop only, so a replicated single-entry
/// template is first materialized to `hops` explicit entries (via
/// [`derive_hop_net`], preserving the per-hop seed derivation
/// byte-identically) whenever the chain has more than one hop — otherwise
/// a trace set on the template would silently replicate to every hop.
pub(crate) fn apply_hop_traces(
    hop_nets: &mut Vec<NetworkConfig>,
    hops: usize,
    traces: &[(usize, LinkTrace)],
) -> Result<()> {
    if traces.is_empty() {
        return Ok(());
    }
    let hops = hops.max(1);
    if hop_nets.len() == 1 && hops > 1 {
        *hop_nets =
            (0..hops).map(|h| derive_hop_net(hop_nets, h)).collect();
    }
    for (hop, trace) in traces {
        if *hop >= hop_nets.len() {
            bail!(
                "trace targets hop{hop} but the scenario has only {} \
                 inter-tier hop(s)",
                hop_nets.len()
            );
        }
        hop_nets[*hop].trace = Some(trace.clone());
    }
    Ok(())
}

#[derive(Clone, Debug)]
pub struct ScenarioConfig {
    pub kind: ScenarioKind,
    /// Per-hop channel settings, sensor side first (each inter-tier hop
    /// gets its own [`Channel`] instance via [`ScenarioConfig::hop_net`]).
    /// A **single entry** is a template replicated to every hop with
    /// derived per-hop seeds (the pre-redesign behaviour, byte-identical);
    /// **multiple entries** configure each hop explicitly — a wifi sensor
    /// uplink can feed a gigabit backbone — and the length must then equal
    /// `tiers − 1` for the scenario kind (checked by the engines).
    pub hop_nets: Vec<NetworkConfig>,
    /// Device tier chain, sensor side first. LC runs on `tiers[0]`; RC and
    /// SC use the first and last tiers (intermediate tiers, if any, are
    /// bypassed — a direct sensor→cloud channel); MC with k cuts needs
    /// exactly k+1 tiers.
    pub tiers: Vec<DeviceProfile>,
    pub scale: ModelScale,
    /// Frame inter-arrival time (conveyor speed); 0 = closed-loop
    /// back-to-back (the source emits the next frame the moment the
    /// previous one completes).
    pub frame_period_ns: SimTime,
}

impl ScenarioConfig {
    /// The classic two-tier configuration (edge + server) over one channel.
    pub fn two_tier(
        kind: ScenarioKind,
        net: NetworkConfig,
        edge: DeviceProfile,
        server: DeviceProfile,
        scale: ModelScale,
        frame_period_ns: SimTime,
    ) -> ScenarioConfig {
        ScenarioConfig {
            kind,
            hop_nets: vec![net],
            tiers: vec![edge, server],
            scale,
            frame_period_ns,
        }
    }

    /// The sensor-side tier (first in the chain).
    pub fn edge(&self) -> &DeviceProfile {
        &self.tiers[0]
    }

    /// The cloud-side tier (last in the chain).
    pub fn server(&self) -> &DeviceProfile {
        self.tiers.last().expect("scenario config with no tiers")
    }

    /// The channel template reports and reseeding derive from: hop 0's
    /// configuration (the only one, when a single entry is replicated).
    pub fn base_net(&self) -> &NetworkConfig {
        self.hop_nets.first().expect("scenario config with no hop nets")
    }

    /// The [`NetworkConfig`] of inter-tier hop `h`.
    ///
    /// Replicated form (one entry): the template with a per-hop derived
    /// seed — **hop 0 keeps the configured seed exactly** (pinned: two-tier
    /// scenarios and `mc@i ≡ sc@i` degenerate equivalence stay
    /// byte-identical with the pre-`hop_nets` engine), later hops add
    /// `h * HOP_SEED_STRIDE`. Heterogeneous form (one entry per hop): each
    /// entry is returned verbatim, seed included — no derivation, what you
    /// configure is what each hop simulates.
    pub fn hop_net(&self, hop: usize) -> NetworkConfig {
        derive_hop_net(&self.hop_nets, hop)
    }

    /// Reseed the whole chain from one base seed, preserving the per-hop
    /// derivation contract: the replicated template takes `seed` directly
    /// (hop `h` then derives `seed + h * HOP_SEED_STRIDE` as before);
    /// explicit heterogeneous entries take `seed + h * HOP_SEED_STRIDE`
    /// verbatim. Used by the pooled multi-seed evaluators so a seed sweep
    /// re-draws every hop's loss pattern deterministically.
    pub fn set_base_seed(&mut self, seed: u64) {
        reseed_hop_nets(&mut self.hop_nets, seed);
    }

    /// Attach time-varying [`LinkTrace`]s to this scenario's hops (parsed
    /// from `--trace hop0=wifi>congested@2s,...` or a JSON trace file).
    /// A single-entry replicated template is materialized to one explicit
    /// entry per inter-tier hop first (byte-identical derivation), so a
    /// trace on hop 0 never leaks onto later hops. Errors if a trace
    /// targets a hop the scenario kind doesn't have.
    pub fn apply_traces(
        &mut self,
        traces: &[(usize, LinkTrace)],
    ) -> Result<()> {
        let hops = self.kind.tiers_needed().saturating_sub(1).max(1);
        apply_hop_traces(&mut self.hop_nets, hops, traces)
    }
}

#[derive(Clone, Copy, Debug)]
pub struct FrameRecord {
    /// End-to-end latency, including time queued behind earlier frames.
    pub latency_ns: SimTime,
    /// Absolute simulated time the frame's result was delivered (the
    /// stream starts at t = 0), so stream duration and throughput derive
    /// from completions, not from per-frame latencies.
    pub completed_ns: SimTime,
    pub correct: bool,
    pub wire_bytes: u64,
    pub retransmits: u64,
    pub corrupted: bool,
}

#[derive(Clone, Debug)]
pub struct ScenarioReport {
    pub kind: ScenarioKind,
    pub protocol: Protocol,
    pub loss_rate: f64,
    pub frames: usize,
    pub accuracy: f64,
    pub mean_latency_ns: f64,
    pub p95_latency_ns: SimTime,
    pub p99_latency_ns: SimTime,
    pub max_latency_ns: SimTime,
    pub mean_wire_bytes: f64,
    pub total_retransmits: u64,
    /// Fraction of frames meeting the latency bound (if any).
    pub deadline_hit_rate: Option<f64>,
    /// Per-frame verdict: the deadline hit-rate must reach
    /// [`QosRequirements::min_hit_rate`] (not the *mean* latency — a
    /// stream whose mean fits the budget can still miss it on half its
    /// frames).
    pub qos_satisfied: Option<bool>,
    pub records: Vec<FrameRecord>,
}

impl ScenarioReport {
    /// Reduce per-frame records to a report. A zero-frame stream is an
    /// error: the old code divided by `n.max(1)` and fabricated accuracy
    /// 0.0 / mean 0.0 for an empty record set, which read as a real (and
    /// catastrophically bad) measurement downstream.
    pub(crate) fn from_records(
        cfg: &ScenarioConfig,
        records: Vec<FrameRecord>,
        qos: &QosRequirements,
    ) -> Result<ScenarioReport> {
        if records.is_empty() {
            bail!(
                "scenario {} produced no frame records; refusing to \
                 report metrics for an empty stream",
                cfg.kind
            );
        }
        let n = records.len();
        let accuracy =
            records.iter().filter(|r| r.correct).count() as f64 / n as f64;
        let mean_latency_ns =
            records.iter().map(|r| r.latency_ns as f64).sum::<f64>() / n as f64;
        let mut lat: Vec<SimTime> =
            records.iter().map(|r| r.latency_ns).collect();
        let max = lat.iter().copied().max().unwrap_or(0);
        let deadline_hit_rate = qos.max_latency_ns.map(|m| {
            records.iter().filter(|r| r.latency_ns <= m).count() as f64
                / n as f64
        });
        let qos_satisfied = if qos.max_latency_ns.is_some()
            || qos.min_accuracy.is_some()
        {
            Some(qos.satisfied_by(deadline_hit_rate, accuracy))
        } else {
            None
        };
        Ok(ScenarioReport {
            kind: cfg.kind.clone(),
            // Heterogeneous chains report hop 0's transport and loss (the
            // sensor uplink — the hop the paper's split decision trades
            // against); per-hop detail lives in the config itself.
            protocol: cfg.base_net().protocol,
            loss_rate: cfg.base_net().loss_rate,
            frames: records.len(),
            accuracy,
            mean_latency_ns,
            p95_latency_ns: crate::report::stats::percentile_mut(
                &mut lat, 0.95,
            ),
            p99_latency_ns: crate::report::stats::percentile_mut(
                &mut lat, 0.99,
            ),
            max_latency_ns: max,
            mean_wire_bytes: records.iter().map(|r| r.wire_bytes as f64)
                .sum::<f64>() / n as f64,
            total_retransmits: records.iter().map(|r| r.retransmits).sum(),
            deadline_hit_rate,
            qos_satisfied,
            records,
        })
    }
}

/// Volumetrics + compute costs resolved for a (kind, scale, tiers) triple:
/// per-tier segment compute and per-hop uplink payloads.
pub(crate) struct Costs {
    /// Bytes on the wire of each inter-tier uplink hop (input for RC,
    /// latents for SC/MC); empty for LC.
    pub(crate) up_bytes: Vec<u64>,
    /// Result payload (class scores), returned hop by hop in reverse.
    pub(crate) down_bytes: u64,
    /// Mult-adds of each pipeline segment, sensor side first
    /// (`len == up_bytes.len() + 1`).
    pub(crate) seg_mult_adds: Vec<u64>,
}

impl Costs {
    pub(crate) fn hops(&self) -> usize {
        self.up_bytes.len()
    }
}

/// The network whose volumetrics/compute drive a scenario: the backend
/// manifest names the architecture, the config picks the scale.
pub(crate) fn scenario_network(
    engine: &dyn InferenceBackend,
    scale: ModelScale,
) -> Network {
    let m = &engine.manifest().model;
    let arch = engine.manifest().arch();
    match scale {
        ModelScale::Slim => arch.slim_network(
            m.img_size,
            m.width_mult,
            m.hidden,
            m.num_classes,
        ),
        ModelScale::Full => arch.full_network(),
    }
}

pub(crate) fn costs(engine: &dyn InferenceBackend, cfg: &ScenarioConfig)
    -> Result<Costs>
{
    if cfg.hop_nets.is_empty() {
        bail!("scenario {} has no hop_nets configured", cfg.kind);
    }
    // A single hop_nets entry is a template replicated to every hop; an
    // explicit heterogeneous list must cover each inter-tier hop exactly.
    let hops_needed = cfg.kind.tiers_needed().saturating_sub(1);
    if cfg.hop_nets.len() > 1 && cfg.hop_nets.len() != hops_needed {
        bail!(
            "scenario {} has {} inter-tier hops but {} hop_nets entries \
             (give one per hop, or a single template to replicate)",
            cfg.kind,
            hops_needed,
            cfg.hop_nets.len()
        );
    }
    kind_costs(engine, &cfg.kind, cfg.scale, cfg.tiers.len())
}

/// Per-(kind, scale) volumetrics against a physical chain of `n_tiers`
/// devices — the tier-count validation plus the cost table, shared by the
/// homogeneous [`costs`] path and the heterogeneous multi-stream engine
/// (where every client resolves its own kind/arch/scale against one
/// physical chain).
pub(crate) fn kind_costs(
    engine: &dyn InferenceBackend,
    kind: &ScenarioKind,
    scale: ModelScale,
    n_tiers: usize,
) -> Result<Costs> {
    let m = &engine.manifest().model;
    if n_tiers < kind.tiers_needed().min(2) {
        bail!(
            "scenario {} needs {} tiers, config has {}",
            kind,
            kind.tiers_needed(),
            n_tiers
        );
    }
    let down_bytes = (m.num_classes * 4) as u64;
    let net = scenario_network(engine, scale);
    let input_bytes: u64 = match scale {
        // Slim-scale input volume comes from the manifest's input tensor
        // description, not a hard-coded dense-RGB-f32 assumption.
        ModelScale::Slim => engine.manifest().input_bytes_per_frame(),
        ModelScale::Full => net.input.bytes_f32() as u64,
    };
    Ok(match kind {
        ScenarioKind::Lc => {
            // Lightweight local model: measured lite model at slim scale;
            // at paper scale, assume a quarter-width VGG16 (MobileNet-class
            // MACs). The lite model is arch-independent — it is the same
            // tiny CNN whatever the server-side architecture.
            let lite_ma = match scale {
                ModelScale::Slim => {
                    model::vgg16_slim(m.img_size, 0.0625, 48, m.num_classes)
                        .mult_adds()
                }
                ModelScale::Full => {
                    model::vgg16_slim(224, 0.25, 4096, 1000).mult_adds()
                }
            };
            Costs {
                up_bytes: Vec::new(),
                down_bytes: 0,
                seg_mult_adds: vec![lite_ma],
            }
        }
        ScenarioKind::Rc => Costs {
            up_bytes: vec![input_bytes],
            down_bytes,
            seg_mult_adds: vec![0, net.mult_adds()],
        },
        ScenarioKind::Sc { split } => {
            // DAG cut semantics: the split id indexes the arch's marked
            // split points; every one is a valid single-tensor frontier
            // (residual interiors never appear), and the crossing
            // tensor's bottleneck latent is what the netsim transfers.
            let cuts = model::split_points(&net);
            if *split >= cuts.len() - 1 {
                bail!(
                    "split {split} out of range: {} has {} cut points \
                     (valid: 0..={})",
                    net.name,
                    cuts.len(),
                    cuts.len() - 2
                );
            }
            let cut = &cuts[*split];
            let (head_ma, tail_ma) = cut.split_compute();
            Costs {
                up_bytes: vec![cut.latent_bytes()],
                down_bytes,
                seg_mult_adds: vec![head_ma, tail_ma],
            }
        }
        ScenarioKind::Mc { cuts } => {
            if n_tiers != cuts.len() + 1 {
                bail!(
                    "MC with {} cuts needs exactly {} tiers, config \
                     has {}",
                    cuts.len(),
                    cuts.len() + 1,
                    n_tiers
                );
            }
            let points = model::split_points(&net);
            let chain = model::chain_costs(&points, cuts).map_err(|e| {
                anyhow::anyhow!("{}: {e}", net.name)
            })?;
            Costs {
                up_bytes: chain.hop_bytes,
                down_bytes,
                seg_mult_adds: chain.seg_mult_adds,
            }
        }
    })
}

/// Run `n_frames` frames of `dataset` through the configured scenario.
///
/// Rides the closed-loop streaming engine ([`super::streaming`]) with a
/// single client and batch size 1: per-frame latency *includes* the time
/// spent queued behind earlier frames on the edge device, the channels and
/// the server. At low load (frame period longer than the pipeline
/// latency) this reproduces the open-loop reference
/// ([`run_scenario_open_loop`]) exactly for UDP and lossless TCP — and is
/// per-frame `>=` it under lossy TCP, where the legacy accounting dropped
/// the wait for the channel's ACK tail; under overload, latency grows
/// with queue depth instead of staying silently flat.
pub fn run_scenario(
    engine: &dyn InferenceBackend,
    cfg: &ScenarioConfig,
    dataset: &Dataset,
    n_frames: usize,
    qos: &QosRequirements,
) -> Result<ScenarioReport> {
    run_scenario_with_queue(
        engine,
        cfg,
        dataset,
        n_frames,
        qos,
        crate::netsim::event::QueueKind::Calendar,
    )
}

/// [`run_scenario`] with an explicit event-queue backend (the `--queue`
/// flag on `sei simulate` / `sei serve`). Results are byte-identical
/// across backends by construction — wheel, calendar and linear scan all
/// extract the event with the globally minimal `(time, seq)` key.
pub fn run_scenario_with_queue(
    engine: &dyn InferenceBackend,
    cfg: &ScenarioConfig,
    dataset: &Dataset,
    n_frames: usize,
    qos: &QosRequirements,
    queue: crate::netsim::event::QueueKind,
) -> Result<ScenarioReport> {
    let stream = super::streaming::run_stream_with_queue(
        engine,
        &super::streaming::StreamConfig::single(cfg, n_frames),
        Some(dataset),
        qos,
        queue,
    )?;
    ScenarioReport::from_records(cfg, stream.to_frame_records(), qos)
}

/// Latency-only variant: no model execution, pure simulation (used by the
/// paper-scale Fig. 3 sweeps where accuracy is not measured per point).
/// Shares the closed-loop event loop with [`run_scenario`], so full-mode
/// and latency-only timings can no longer drift apart.
pub fn simulate_latency(
    engine: &dyn InferenceBackend,
    cfg: &ScenarioConfig,
    n_frames: usize,
) -> Result<Vec<SimTime>> {
    let stream = super::streaming::run_stream(
        engine,
        &super::streaming::StreamConfig::single(cfg, n_frames),
        None,
        &QosRequirements::none(),
    )?;
    Ok(stream.records.iter().map(|r| r.latency_ns).collect())
}

/// The **legacy open-loop** scenario runner, retained as a reference: it
/// starts frame `i` at `i * frame_period_ns` even when the previous frame
/// is still in flight, so waiting time never shows up in latency — the
/// timing bug the closed-loop engine fixes. Used only by regression tests
/// that (a) pin `run_scenario == run_scenario_open_loop` at low load and
/// (b) demonstrate the divergence under overload. Two-tier kinds only
/// (LC / RC / SC); do not build new functionality on this path.
pub fn run_scenario_open_loop(
    engine: &dyn InferenceBackend,
    cfg: &ScenarioConfig,
    dataset: &Dataset,
    n_frames: usize,
    qos: &QosRequirements,
) -> Result<ScenarioReport> {
    if let ScenarioKind::Mc { .. } = cfg.kind {
        bail!("the open-loop reference engine predates multi-tier placement");
    }
    let costs = costs(engine, cfg)?;
    let up_bytes = costs.up_bytes.first().copied().unwrap_or(0);
    let edge_ma = costs.seg_mult_adds[0];
    let server_ma = costs.seg_mult_adds.last().copied().unwrap_or(0);
    let mut channel = Channel::new(cfg.hop_net(0));
    let num_classes = engine.manifest().model.num_classes;

    // Pre-load the executables used by this scenario.
    let (full_exec, head_exec, tail_exec) = match &cfg.kind {
        ScenarioKind::Lc => {
            let name = if engine.manifest().executables
                .contains_key("full_fwd_lite_b1")
            {
                "full_fwd_lite_b1"
            } else {
                "full_fwd_b1"
            };
            (Some(engine.executable(name)?), None, None)
        }
        ScenarioKind::Rc => (Some(engine.executable("full_fwd_b1")?), None,
                             None),
        ScenarioKind::Sc { split } => (
            None,
            Some(engine.executable(&format!("head_L{split}_b1"))?),
            Some(engine.executable(&format!("tail_L{split}_b1"))?),
        ),
        ScenarioKind::Mc { .. } => unreachable!("rejected above"),
    };

    let mut records = Vec::with_capacity(n_frames);
    for i in 0..n_frames {
        let idx = i % dataset.len();
        let x = dataset.batch(idx, 1)?;
        let label = dataset.labels[idx] as usize;
        channel.advance_to(i as SimTime * cfg.frame_period_ns);
        let frame_start = channel.now();

        let mut latency: SimTime = 0;
        let mut wire = 0u64;
        let mut retx = 0u64;
        let mut corrupted = false;

        let logits: Tensor = match &cfg.kind {
            ScenarioKind::Lc => {
                latency += cfg.edge().compute_ns(edge_ma);
                full_exec.as_ref().unwrap().run(&[RtInput::F32(&x)])?
            }
            ScenarioKind::Rc => {
                let up = channel.send(Dir::Up, up_bytes)?;
                latency += up.latency_ns();
                wire += up.wire_bytes();
                retx += up.retransmits();
                let mut input = x.clone();
                if cfg.base_net().protocol == Protocol::Udp
                    && !up.lost_ranges().is_empty()
                {
                    corrupted = true;
                    corruption::corrupt_scaled(
                        &mut input, up.lost_ranges(), up_bytes,
                    );
                }
                latency += cfg.server().compute_ns(server_ma);
                let logits =
                    full_exec.as_ref().unwrap().run(&[RtInput::F32(&input)])?;
                channel.advance_to(frame_start + latency);
                let down = channel.send(Dir::Down, costs.down_bytes)?;
                latency += down.latency_ns();
                wire += down.wire_bytes();
                retx += down.retransmits();
                // A fully lost UDP result datagram voids the frame: treat
                // as incorrect below by corrupting the logits.
                if down.lost_ranges().iter().map(|(_, l)| *l as u64).sum::<u64>()
                    >= costs.down_bytes
                {
                    corrupted = true;
                    Tensor::zeros(vec![1, num_classes])
                } else {
                    logits
                }
            }
            ScenarioKind::Sc { .. } => {
                latency += cfg.edge().compute_ns(edge_ma);
                let mut latent =
                    head_exec.as_ref().unwrap().run(&[RtInput::F32(&x)])?;
                channel.advance_to(frame_start + latency);
                let up = channel.send(Dir::Up, up_bytes)?;
                latency += up.latency_ns();
                wire += up.wire_bytes();
                retx += up.retransmits();
                if cfg.base_net().protocol == Protocol::Udp
                    && !up.lost_ranges().is_empty()
                {
                    corrupted = true;
                    corruption::corrupt_scaled(
                        &mut latent, up.lost_ranges(), up_bytes,
                    );
                }
                latency += cfg.server().compute_ns(server_ma);
                let logits = tail_exec
                    .as_ref()
                    .unwrap()
                    .run(&[RtInput::F32(&latent)])?;
                channel.advance_to(frame_start + latency);
                let down = channel.send(Dir::Down, costs.down_bytes)?;
                latency += down.latency_ns();
                wire += down.wire_bytes();
                retx += down.retransmits();
                if down.lost_ranges().iter().map(|(_, l)| *l as u64).sum::<u64>()
                    >= costs.down_bytes
                {
                    corrupted = true;
                    Tensor::zeros(vec![1, num_classes])
                } else {
                    logits
                }
            }
            ScenarioKind::Mc { .. } => unreachable!("rejected above"),
        };

        let pred = logits.argmax_last()[0];
        records.push(FrameRecord {
            latency_ns: latency,
            completed_ns: frame_start + latency,
            correct: pred == label,
            wire_bytes: wire,
            retransmits: retx,
            corrupted,
        });
    }
    ScenarioReport::from_records(cfg, records, qos)
}

/// The legacy open-loop latency-only runner (see
/// [`run_scenario_open_loop`]): pure simulation, frame `i` pinned to
/// `i * frame_period_ns` regardless of resource state. Reference for
/// regression tests only; two-tier kinds only.
pub fn simulate_latency_open_loop(
    engine: &dyn InferenceBackend,
    cfg: &ScenarioConfig,
    n_frames: usize,
) -> Result<Vec<SimTime>> {
    if let ScenarioKind::Mc { .. } = cfg.kind {
        bail!("the open-loop reference engine predates multi-tier placement");
    }
    let costs = costs(engine, cfg)?;
    let up_bytes = costs.up_bytes.first().copied().unwrap_or(0);
    let edge_ma = costs.seg_mult_adds[0];
    let server_ma = costs.seg_mult_adds.last().copied().unwrap_or(0);
    let mut channel = Channel::new(cfg.hop_net(0));
    let mut out = Vec::with_capacity(n_frames);
    for i in 0..n_frames {
        channel.advance_to(i as SimTime * cfg.frame_period_ns);
        let frame_start = channel.now();
        let mut latency: SimTime = 0;
        latency += cfg.edge().compute_ns(edge_ma);
        if up_bytes > 0 {
            channel.advance_to(frame_start + latency);
            latency += channel.send(Dir::Up, up_bytes)?.latency_ns();
            latency += cfg.server().compute_ns(server_ma);
            channel.advance_to(frame_start + latency);
            latency +=
                channel.send(Dir::Down, costs.down_bytes)?.latency_ns();
        }
        out.push(latency);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    // Engine-dependent paths are covered by rust/tests/; here we test the
    // pure pieces.

    #[test]
    fn kind_display() {
        assert_eq!(ScenarioKind::Lc.to_string(), "LC");
        assert_eq!(ScenarioKind::Sc { split: 11 }.to_string(), "SC@L11");
        assert_eq!(
            ScenarioKind::Mc { cuts: vec![4, 11] }.to_string(),
            "MC@L4,L11"
        );
    }

    #[test]
    fn kind_parse_roundtrips_display() {
        for kind in [
            ScenarioKind::Lc,
            ScenarioKind::Rc,
            ScenarioKind::Sc { split: 13 },
            ScenarioKind::Mc { cuts: vec![5] },
            ScenarioKind::Mc { cuts: vec![4, 11, 15] },
        ] {
            assert_eq!(ScenarioKind::parse(&kind.to_string()).unwrap(), kind);
        }
        assert_eq!(
            ScenarioKind::parse("sc@11").unwrap(),
            ScenarioKind::Sc { split: 11 }
        );
        assert_eq!(
            ScenarioKind::parse("mc@4,11").unwrap(),
            ScenarioKind::Mc { cuts: vec![4, 11] }
        );
        assert_eq!(
            ScenarioKind::parse("MC@L4,11").unwrap(),
            ScenarioKind::Mc { cuts: vec![4, 11] }
        );
        assert!(ScenarioKind::parse("mc").is_err());
        assert!(ScenarioKind::parse("mc@").is_err());
        assert!(ScenarioKind::parse("mc@4,").is_err());
        assert!(ScenarioKind::parse("mc@11,4").is_err());
        assert!(ScenarioKind::parse("mc@4,4").is_err());
        assert!(ScenarioKind::parse("sc@x").is_err());
    }

    #[test]
    fn prop_kind_and_scale_parse_roundtrip() {
        // Property: Display -> parse is the identity for every
        // representable ScenarioKind (including multi-cut chains) and
        // ModelScale, and parsing is case-insensitive.
        use crate::util::propcheck::{check, Config};
        check("scenario_kind_roundtrip", Config::default(), |c| {
            let kind = match c.rng.below(4) {
                0 => ScenarioKind::Lc,
                1 => ScenarioKind::Rc,
                2 => ScenarioKind::Sc {
                    split: c.rng.below(40) as usize,
                },
                _ => {
                    let k = 1 + c.rng.below(4) as usize;
                    let mut cuts = Vec::with_capacity(k);
                    let mut next = c.rng.below(6) as usize;
                    for _ in 0..k {
                        cuts.push(next);
                        next += 1 + c.rng.below(5) as usize;
                    }
                    ScenarioKind::Mc { cuts }
                }
            };
            let shown = kind.to_string();
            let back = ScenarioKind::parse(&shown)
                .map_err(|e| format!("parse('{shown}'): {e}"))?;
            if back != kind {
                return Err(format!("{shown} -> {back:?} != {kind:?}"));
            }
            let lower = ScenarioKind::parse(&shown.to_ascii_lowercase())
                .map_err(|e| e.to_string())?;
            if lower != kind {
                return Err(format!("lowercase '{shown}' != {kind:?}"));
            }
            let scale = if c.bool() {
                ModelScale::Slim
            } else {
                ModelScale::Full
            };
            if ModelScale::parse(scale.as_str()).map_err(|e| e.to_string())?
                != scale
            {
                return Err(format!("scale {scale:?} does not round-trip"));
            }
            Ok(())
        });
    }

    #[test]
    fn scale_parse_roundtrips_as_str() {
        for scale in [ModelScale::Slim, ModelScale::Full] {
            assert_eq!(ModelScale::parse(scale.as_str()).unwrap(), scale);
        }
        // Historical aliases still accepted; arch names are not scales.
        assert_eq!(ModelScale::parse("vgg16").unwrap(), ModelScale::Full);
        assert_eq!(ModelScale::parse("vgg16-full").unwrap(), ModelScale::Full);
        assert!(ModelScale::parse("resnet18").is_err());
        // The error names the silently accepted aliases.
        let err = ModelScale::parse("resnet18").unwrap_err().to_string();
        assert!(
            err.contains("vgg16") && err.contains("vgg16-full"),
            "{err}"
        );
    }

    #[test]
    fn tiers_needed_per_kind() {
        assert_eq!(ScenarioKind::Lc.tiers_needed(), 1);
        assert_eq!(ScenarioKind::Rc.tiers_needed(), 2);
        assert_eq!(ScenarioKind::Sc { split: 5 }.tiers_needed(), 2);
        assert_eq!(
            ScenarioKind::Mc { cuts: vec![4, 11] }.tiers_needed(),
            3
        );
    }

    #[test]
    fn hop_nets_keep_hop_zero_seed_and_decorrelate_the_rest() {
        let cfg = ScenarioConfig::two_tier(
            ScenarioKind::Rc,
            NetworkConfig::gigabit(Protocol::Udp, 0.1, 1234),
            DeviceProfile::edge_gpu(),
            DeviceProfile::server_gpu(),
            ModelScale::Slim,
            0,
        );
        assert_eq!(cfg.hop_net(0).seed, 1234);
        assert_ne!(cfg.hop_net(1).seed, 1234);
        assert_ne!(cfg.hop_net(1).seed, cfg.hop_net(2).seed);
        assert_eq!(cfg.edge().name, "edge-gpu");
        assert_eq!(cfg.server().name, "server-gpu");
    }

    #[test]
    fn heterogeneous_hop_nets_are_used_verbatim() {
        let mut cfg = ScenarioConfig::two_tier(
            ScenarioKind::Mc { cuts: vec![4, 11] },
            NetworkConfig::wifi(Protocol::Udp, 0.05, 7),
            DeviceProfile::sensor_npu(),
            DeviceProfile::server_gpu(),
            ModelScale::Slim,
            0,
        );
        cfg.tiers.insert(1, DeviceProfile::edge_gpu());
        cfg.hop_nets = vec![
            NetworkConfig::wifi(Protocol::Udp, 0.05, 7),
            NetworkConfig::gigabit(Protocol::Tcp, 0.0, 99),
        ];
        // Explicit entries come back verbatim — no seed derivation.
        assert_eq!(cfg.hop_net(0).seed, 7);
        assert_eq!(cfg.hop_net(0).protocol, Protocol::Udp);
        assert_eq!(cfg.hop_net(1).seed, 99);
        assert_eq!(cfg.hop_net(1).protocol, Protocol::Tcp);
        assert_eq!(cfg.hop_net(1).capacity_bps, 1e9);
        assert_eq!(cfg.base_net().protocol, Protocol::Udp);
    }

    #[test]
    fn set_base_seed_reseeds_every_hop_deterministically() {
        // Replicated template: the base takes the seed directly, so
        // hop_net(h) still derives seed + h * stride.
        let mut rep = ScenarioConfig::two_tier(
            ScenarioKind::Rc,
            NetworkConfig::gigabit(Protocol::Udp, 0.1, 1),
            DeviceProfile::edge_gpu(),
            DeviceProfile::server_gpu(),
            ModelScale::Slim,
            0,
        );
        rep.set_base_seed(5000);
        assert_eq!(rep.hop_net(0).seed, 5000);
        // Heterogeneous chain: each hop gets the derived seed verbatim —
        // the same per-hop streams a replicated chain would draw.
        let mut het = rep.clone();
        het.kind = ScenarioKind::Mc { cuts: vec![4, 11] };
        het.tiers.insert(1, DeviceProfile::edge_gpu());
        het.hop_nets = vec![
            NetworkConfig::wifi(Protocol::Udp, 0.1, 0),
            NetworkConfig::gigabit(Protocol::Udp, 0.1, 0),
        ];
        het.set_base_seed(5000);
        assert_eq!(het.hop_net(0).seed, 5000);
        assert_eq!(het.hop_net(1).seed, rep.hop_net(1).seed);
    }

    #[test]
    fn report_aggregates() {
        let cfg = ScenarioConfig::two_tier(
            ScenarioKind::Lc,
            NetworkConfig::gigabit(Protocol::Tcp, 0.0, 0),
            DeviceProfile::edge_gpu(),
            DeviceProfile::server_gpu(),
            ModelScale::Slim,
            0,
        );
        let records = vec![
            FrameRecord { latency_ns: 10, completed_ns: 10, correct: true,
                          wire_bytes: 4, retransmits: 0, corrupted: false },
            FrameRecord { latency_ns: 30, completed_ns: 60, correct: false,
                          wire_bytes: 6, retransmits: 2, corrupted: true },
        ];
        let q = QosRequirements::with_fps(1e9 / 20.0).unwrap();
        let r = ScenarioReport::from_records(&cfg, records, &q).unwrap();
        assert_eq!(r.frames, 2);
        assert!((r.accuracy - 0.5).abs() < 1e-9);
        assert!((r.mean_latency_ns - 20.0).abs() < 1e-9);
        assert_eq!(r.max_latency_ns, 30);
        assert_eq!(r.p95_latency_ns, 30);
        assert_eq!(r.p99_latency_ns, 30);
        assert_eq!(r.total_retransmits, 2);
        assert_eq!(r.deadline_hit_rate, Some(0.5));
        // Per-frame verdict: half the frames missed the 20 ns deadline,
        // so the (strict) QoS is violated even though the mean fits.
        assert_eq!(r.qos_satisfied, Some(false));
    }

    #[test]
    fn empty_record_set_is_an_error_not_fake_metrics() {
        let cfg = ScenarioConfig::two_tier(
            ScenarioKind::Lc,
            NetworkConfig::gigabit(Protocol::Tcp, 0.0, 0),
            DeviceProfile::edge_gpu(),
            DeviceProfile::server_gpu(),
            ModelScale::Slim,
            0,
        );
        let err = ScenarioReport::from_records(
            &cfg, Vec::new(), &QosRequirements::none(),
        );
        assert!(err.is_err(), "empty streams must not report accuracy 0.0");
    }

    #[test]
    fn p95_is_nearest_rank_not_max() {
        // 20 equal-spaced latencies: p95 must be the 19th value, not the
        // max — the old `(n * 0.95) as usize % n` indexed the maximum.
        let cfg = ScenarioConfig::two_tier(
            ScenarioKind::Lc,
            NetworkConfig::gigabit(Protocol::Tcp, 0.0, 0),
            DeviceProfile::edge_gpu(),
            DeviceProfile::server_gpu(),
            ModelScale::Slim,
            0,
        );
        let records: Vec<FrameRecord> = (1..=20)
            .map(|i| FrameRecord {
                latency_ns: i * 100,
                completed_ns: i * 100,
                correct: true,
                wire_bytes: 0,
                retransmits: 0,
                corrupted: false,
            })
            .collect();
        let r = ScenarioReport::from_records(
            &cfg, records, &QosRequirements::none(),
        )
        .unwrap();
        assert_eq!(r.p95_latency_ns, 1900);
        assert_eq!(r.p99_latency_ns, 2000);
        assert_eq!(r.max_latency_ns, 2000);
    }
}
