//! Fleet-scale auto-placement (paper Fig. 1 step iii, lifted from one
//! hand-picked chain to a declared inventory; cf. SplitPlace,
//! arXiv 2110.04841): a [`FleetSpec`] names the devices a deployment
//! owns, the channels between them and the streams it must serve, and
//! [`place`] searches tier chains × cut chains × per-hop channel
//! assignments for the [`PlacementPlan`] that satisfies the most
//! streams' QoS — tie-broken by mean latency, then accuracy.
//!
//! The search is branch-and-bound: candidates are ordered by an
//! *admissible* analytic latency lower bound (segment compute via
//! [`DeviceProfile::compute_ns`] over [`chain_costs`], plus per-hop
//! serialization at link capacity and propagation latency — everything
//! the simulator can only add to: queueing, headers, acks, retransmits),
//! and a candidate is pruned when that bound proves it cannot beat the
//! incumbent even on tie-breaks. Survivors are simulated with the
//! deterministic scenario evaluator ([`sweep::pooled_scenario`]), so the
//! winning plan is byte-identical at any worker-thread count; setting
//! [`FleetSpec::exhaustive`] disables pruning, which the tests use as
//! the enumeration oracle for the bound's admissibility.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Mutex;

use anyhow::{bail, Context, Result};

use super::bound::latency_bound_ns;
use super::qos::QosRequirements;
use super::scenario::{
    scenario_network, ModelScale, ScenarioConfig, ScenarioKind,
};
use super::streaming::chain_servable;
use super::sweep::{self, BackendFactory};
use crate::data::Dataset;
use crate::model::{
    chain_costs, split_points, Arch, ChainCache, ChainCosts, Cut,
    DeviceProfile,
};
use crate::netsim::event::SimTime;
use crate::netsim::transfer::NetworkConfig;
use crate::runtime::InferenceBackend;
use crate::util::json::{self, Json};

/// One entry of the fleet's device inventory: a profile and how many of
/// it the deployment owns. The `devices` list is ordered sensor side
/// first; tier chains are order-preserving selections from it.
#[derive(Clone, Debug)]
pub struct FleetDevice {
    pub profile: DeviceProfile,
    pub count: usize,
}

/// One application stream the placement must serve, with its QoS.
#[derive(Clone, Debug)]
pub struct FleetStream {
    pub name: String,
    /// Offered (and required) frame rate; the per-frame deadline is one
    /// frame period.
    pub fps: f64,
    pub min_accuracy: Option<f64>,
    /// Fraction of frames that must meet the deadline, in (0, 1]
    /// (default 1.0: every frame).
    pub min_hit_rate: Option<f64>,
}

impl FleetStream {
    pub fn qos(&self) -> Result<QosRequirements> {
        let mut q = QosRequirements::with_fps(self.fps)
            .with_context(|| format!("stream '{}'", self.name))?;
        if let Some(a) = self.min_accuracy {
            if !(0.0..=1.0).contains(&a) {
                bail!(
                    "stream '{}': min_accuracy must be in [0, 1], got {a}",
                    self.name
                );
            }
            q = q.and_accuracy(a);
        }
        if let Some(h) = self.min_hit_rate {
            if !(h > 0.0 && h <= 1.0) {
                bail!(
                    "stream '{}': min_hit_rate must be in (0, 1], got {h}",
                    self.name
                );
            }
            q = q.and_hit_rate(h);
        }
        Ok(q)
    }
}

/// The declarative input of the placement search (`sei place --fleet`).
///
/// JSON schema (see `examples/specs/fleet.json` / ARCHITECTURE.md):
/// ```json
/// {
///   "name": "ice-lab",
///   "arch": "vgg16",
///   "devices": [{"profile": "sensor-npu", "count": 1}, ...],
///   "links": {"uplink": "wifi:udp:loss=0.02", "backbone": "gigabit:tcp"},
///   "streams": [{"name": "belt-a", "fps": 20, "min_accuracy": 0.5}],
///   "frames": 64, "seed": 42, "max_tiers": 3, "dataset": "test",
///   "exhaustive": false
/// }
/// ```
/// Link channel specs go through [`NetworkConfig::parse`]; any `seed=`
/// they carry is overridden by the spec's `seed` at evaluation time
/// (via [`ScenarioConfig::set_base_seed`]), keeping plans deterministic
/// in the spec alone.
#[derive(Clone, Debug)]
pub struct FleetSpec {
    pub name: String,
    pub arch: Arch,
    /// Inventory, sensor side first.
    pub devices: Vec<FleetDevice>,
    /// Named channels, name-sorted (JSON object order).
    pub links: Vec<(String, NetworkConfig)>,
    pub streams: Vec<FleetStream>,
    /// Frames simulated per stream per candidate.
    pub frames: usize,
    pub seed: u64,
    /// Longest tier chain considered (>= 2).
    pub max_tiers: usize,
    pub dataset: String,
    /// Disable branch-and-bound pruning and simulate every candidate —
    /// the enumeration oracle for small fleets.
    pub exhaustive: bool,
}

impl FleetSpec {
    pub fn from_json(text: &str) -> Result<FleetSpec> {
        let j = Json::parse(text).context("fleet spec")?;
        const KEYS: [&str; 10] = [
            "name", "arch", "devices", "links", "streams", "frames",
            "seed", "max_tiers", "dataset", "exhaustive",
        ];
        match &j {
            Json::Obj(m) => {
                for k in m.keys() {
                    if !KEYS.contains(&k.as_str()) {
                        bail!(
                            "fleet spec: unknown key '{k}' (known: {})",
                            KEYS.join(", ")
                        );
                    }
                }
            }
            _ => bail!("fleet spec must be a JSON object"),
        }
        let mut devices = Vec::new();
        for d in j.get("devices")?.arr()? {
            let profile = DeviceProfile::parse(d.get("profile")?.str()?)?;
            let count = match d.opt("count") {
                Some(c) => c.usize()?,
                None => 1,
            };
            devices.push(FleetDevice { profile, count });
        }
        let mut links = Vec::new();
        match j.get("links")? {
            Json::Obj(m) => {
                for (k, v) in m {
                    let net = NetworkConfig::parse(v.str()?)
                        .with_context(|| format!("fleet link '{k}'"))?;
                    links.push((k.clone(), net));
                }
            }
            _ => bail!(
                "fleet spec: 'links' must be an object of \
                 name -> channel spec"
            ),
        }
        let mut streams = Vec::new();
        for s in j.get("streams")?.arr()? {
            streams.push(FleetStream {
                name: s.get("name")?.str()?.to_string(),
                fps: s.get("fps")?.f64()?,
                min_accuracy: s
                    .opt("min_accuracy")
                    .map(|v| v.f64())
                    .transpose()?,
                min_hit_rate: s
                    .opt("min_hit_rate")
                    .map(|v| v.f64())
                    .transpose()?,
            });
        }
        let spec = FleetSpec {
            name: j.get("name")?.str()?.to_string(),
            arch: Arch::parse(j.get("arch")?.str()?)?,
            devices,
            links,
            streams,
            frames: match j.opt("frames") {
                Some(v) => v.usize()?,
                None => 64,
            },
            seed: match j.opt("seed") {
                Some(v) => v.u64()?,
                None => 42,
            },
            max_tiers: match j.opt("max_tiers") {
                Some(v) => v.usize()?,
                None => 3,
            },
            dataset: match j.opt("dataset") {
                Some(v) => v.str()?.to_string(),
                None => "test".to_string(),
            },
            exhaustive: match j.opt("exhaustive") {
                Some(v) => v.bool()?,
                None => false,
            },
        };
        spec.validate()?;
        Ok(spec)
    }

    pub fn validate(&self) -> Result<()> {
        if self.devices.iter().any(|d| d.count == 0) {
            bail!("fleet '{}': every device needs count >= 1", self.name);
        }
        let owned: usize = self.devices.iter().map(|d| d.count).sum();
        if owned < 2 {
            bail!(
                "fleet '{}' owns {owned} device(s); placement needs a \
                 chain of at least 2",
                self.name
            );
        }
        if self.links.is_empty() {
            bail!("fleet '{}' declares no links", self.name);
        }
        if self.streams.is_empty() {
            bail!("fleet '{}' declares no streams", self.name);
        }
        let mut names: Vec<&str> =
            self.streams.iter().map(|s| s.name.as_str()).collect();
        names.sort_unstable();
        if names.windows(2).any(|w| w[0] == w[1]) {
            bail!("fleet '{}': duplicate stream names", self.name);
        }
        for s in &self.streams {
            s.qos()?; // surfaces bad fps / accuracy / hit-rate early
        }
        if self.frames == 0 {
            bail!("fleet '{}': frames must be >= 1", self.name);
        }
        if self.max_tiers < 2 {
            bail!(
                "fleet '{}': max_tiers must be >= 2, got {}",
                self.name,
                self.max_tiers
            );
        }
        Ok(())
    }
}

/// Per-stream verdict of the winning plan.
#[derive(Clone, Debug)]
pub struct StreamVerdict {
    pub stream: String,
    pub satisfied: bool,
    pub mean_latency_ns: f64,
    pub accuracy: f64,
    pub deadline_hit_rate: Option<f64>,
}

/// The search's output: where to place which segments over which
/// channels, plus the measured evidence.
#[derive(Clone, Debug)]
pub struct PlacementPlan {
    pub fleet: String,
    pub arch: Arch,
    /// Chosen tier chain, sensor side first.
    pub tiers: Vec<DeviceProfile>,
    /// Chosen cut chain (`tiers.len() - 1` ordered split ids).
    pub cuts: Vec<usize>,
    /// Human-readable names of the chosen cuts.
    pub cut_names: Vec<String>,
    /// Chosen link name per inter-tier hop.
    pub hop_links: Vec<String>,
    /// The channels those names resolve to.
    pub hop_nets: Vec<NetworkConfig>,
    /// Streams satisfied out of [`PlacementPlan::streams`].
    pub satisfied: usize,
    pub streams: Vec<StreamVerdict>,
    /// Mean of the per-stream mean latencies.
    pub mean_latency_ns: f64,
    /// Mean of the per-stream accuracies.
    pub accuracy: f64,
    /// The candidate's analytic latency lower bound.
    pub bound_ns: SimTime,
}

impl PlacementPlan {
    pub fn kind(&self) -> ScenarioKind {
        ScenarioKind::Mc { cuts: self.cuts.clone() }
    }

    /// Stable JSON form — the CI determinism check compares these bytes
    /// across thread counts.
    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("fleet", json::s(&self.fleet)),
            ("arch", json::s(self.arch.as_str())),
            (
                "tiers",
                json::arr(
                    self.tiers
                        .iter()
                        .map(|t| json::s(&t.name))
                        .collect(),
                ),
            ),
            (
                "cuts",
                json::arr(
                    self.cuts
                        .iter()
                        .map(|&c| json::num(c as f64))
                        .collect(),
                ),
            ),
            (
                "cut_names",
                json::arr(
                    self.cut_names.iter().map(|n| json::s(n)).collect(),
                ),
            ),
            (
                "hop_links",
                json::arr(
                    self.hop_links.iter().map(|l| json::s(l)).collect(),
                ),
            ),
            (
                "hop_nets",
                json::arr(
                    self.hop_nets
                        .iter()
                        .map(|n| json::s(&n.to_string()))
                        .collect(),
                ),
            ),
            ("bound_ns", json::num(self.bound_ns as f64)),
            ("satisfied", json::num(self.satisfied as f64)),
            ("total_streams", json::num(self.streams.len() as f64)),
            ("mean_latency_ns", json::num(self.mean_latency_ns)),
            ("accuracy", json::num(self.accuracy)),
            (
                "streams",
                json::arr(
                    self.streams
                        .iter()
                        .map(|v| {
                            json::obj(vec![
                                ("name", json::s(&v.stream)),
                                ("satisfied", Json::Bool(v.satisfied)),
                                (
                                    "mean_latency_ns",
                                    json::num(v.mean_latency_ns),
                                ),
                                ("accuracy", json::num(v.accuracy)),
                                (
                                    "deadline_hit_rate",
                                    v.deadline_hit_rate
                                        .map(json::num)
                                        .unwrap_or(Json::Null),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Human-readable plan summary for the CLI.
    pub fn render(&self) -> String {
        let mut s = format!(
            "=== placement plan — fleet '{}' ({}) ===\n",
            self.fleet, self.arch
        );
        s.push_str(&format!(
            "tiers              {}\n",
            self.tiers
                .iter()
                .map(|t| t.name.as_str())
                .collect::<Vec<_>>()
                .join(" -> ")
        ));
        s.push_str(&format!(
            "cuts               {} ({})\n",
            self.kind(),
            self.cut_names.join(" > ")
        ));
        for (h, (link, net)) in
            self.hop_links.iter().zip(&self.hop_nets).enumerate()
        {
            s.push_str(&format!("hop {h} channel      {link}: {net}\n"));
        }
        s.push_str(&format!(
            "QoS                {}/{} streams satisfied\n",
            self.satisfied,
            self.streams.len()
        ));
        for v in &self.streams {
            s.push_str(&format!(
                "  {:<16} {:<9} mean {:>8.2} ms   acc {:>5.1}%\n",
                v.stream,
                if v.satisfied { "ok" } else { "violated" },
                v.mean_latency_ns / 1e6,
                v.accuracy * 100.0
            ));
        }
        s.push_str(&format!(
            "analytic bound     {:.2} ms (mean measured {:.2} ms)\n",
            self.bound_ns as f64 / 1e6,
            self.mean_latency_ns / 1e6
        ));
        s
    }
}

/// [`place`]'s result: the winning plan plus search accounting. Only the
/// plan is thread-count invariant — evaluated/pruned counts depend on
/// wave boundaries (see module docs).
#[derive(Clone, Debug)]
pub struct PlacementOutcome {
    pub plan: PlacementPlan,
    pub candidates: usize,
    pub evaluated: usize,
    pub pruned: usize,
}

/// One point of the search space before simulation.
#[derive(Clone, Debug)]
struct Candidate {
    /// Indices into `fleet.devices` (repeats allowed up to `count`).
    tiers: Vec<usize>,
    cuts: Vec<usize>,
    /// Index into `fleet.links` per hop.
    links: Vec<usize>,
    bound_ns: SimTime,
}

/// The measured value of a candidate.
#[derive(Clone, Debug)]
struct Eval {
    cand: usize,
    satisfied: usize,
    mean_latency_ns: f64,
    accuracy: f64,
    verdicts: Vec<StreamVerdict>,
}

/// Strict total order of the search: more satisfied streams, then lower
/// mean latency, then higher accuracy, then lower candidate index (the
/// deterministic tie-break that makes the winner independent of
/// evaluation order, hence of thread count).
fn better(a: &Eval, b: &Eval) -> bool {
    if a.satisfied != b.satisfied {
        return a.satisfied > b.satisfied;
    }
    if a.mean_latency_ns != b.mean_latency_ns {
        return a.mean_latency_ns < b.mean_latency_ns;
    }
    if a.accuracy != b.accuracy {
        return a.accuracy > b.accuracy;
    }
    a.cand < b.cand
}

/// Order-preserving multisubset chains over the inventory: each device
/// contributes `0..=count` tiers, totals in `2..=max_tiers`, declared
/// order kept (sensor side first).
fn tier_chains(devices: &[FleetDevice], max_tiers: usize) -> Vec<Vec<usize>> {
    fn rec(
        devices: &[FleetDevice],
        i: usize,
        max_tiers: usize,
        cur: &mut Vec<usize>,
        out: &mut Vec<Vec<usize>>,
    ) {
        if i == devices.len() {
            if cur.len() >= 2 {
                out.push(cur.clone());
            }
            return;
        }
        let budget = max_tiers - cur.len();
        for m in 0..=devices[i].count.min(budget) {
            for _ in 0..m {
                cur.push(i);
            }
            rec(devices, i + 1, max_tiers, cur, out);
            for _ in 0..m {
                cur.pop();
            }
        }
        // `m = 0` was the first iteration, so every selection count is
        // covered exactly once.
    }
    let mut out = Vec::new();
    rec(devices, 0, max_tiers, &mut Vec::new(), &mut out);
    out
}

/// Can a plan with per-frame latency >= `bound_ns` still satisfy the
/// stream? (Latency only — accuracy is sampled, so no analytic bound on
/// it is admissible.)
fn stream_reachable(stream: &FleetStream, bound_ns: SimTime) -> bool {
    match QosRequirements::with_fps(stream.fps)
        .ok()
        .and_then(|q| q.max_latency_ns)
    {
        Some(deadline) => bound_ns <= deadline,
        None => true,
    }
}

/// Upper bound on the number of streams a candidate can satisfy.
fn ub_satisfied(fleet: &FleetSpec, bound_ns: SimTime) -> usize {
    fleet
        .streams
        .iter()
        .filter(|s| stream_reachable(s, bound_ns))
        .count()
}

/// Prune when the candidate provably cannot beat the incumbent, even on
/// the latency tie-break: its satisfiable-stream upper bound is below
/// the incumbent's count, or ties it while the latency bound already
/// exceeds the incumbent's *measured* mean latency.
fn prunable(fleet: &FleetSpec, cand: &Candidate, inc: &Eval) -> bool {
    let ub = ub_satisfied(fleet, cand.bound_ns);
    ub < inc.satisfied
        || (ub == inc.satisfied
            && (cand.bound_ns as f64) > inc.mean_latency_ns)
}

/// Enumerate the full candidate space (tier chains × servable cut chains
/// × per-hop link assignments) with analytic bounds, in one fixed,
/// thread-independent order. Chain *enumeration* comes from the shared
/// [`ChainCache`] (reusable across placement runs and the co-design
/// search); the fleet-specific servability probe and cost layer are
/// memoized locally per hop count.
fn enumerate(
    fleet: &FleetSpec,
    engine: &dyn InferenceBackend,
    points: &[Cut],
    cache: &mut ChainCache,
) -> Result<Vec<Candidate>> {
    let network = scenario_network(engine, ModelScale::Slim);
    let available = engine.manifest().available_splits();
    // Servable cut chains and their costs per hop count, probed once.
    let mut chains_for: HashMap<usize, Vec<(Vec<usize>, ChainCosts)>> =
        HashMap::new();
    let mut cands = Vec::new();
    for chain in tier_chains(&fleet.devices, fleet.max_tiers) {
        let k = chain.len() - 1;
        let cut_chains =
            match chains_for.entry(k) {
                std::collections::hash_map::Entry::Occupied(e) => {
                    e.into_mut()
                }
                std::collections::hash_map::Entry::Vacant(e) => {
                    let mut v = Vec::new();
                    for cuts in
                        cache.chains(fleet.arch, ModelScale::Slim, k, &network)
                    {
                        if !cuts.iter().all(|c| available.contains(c)) {
                            continue;
                        }
                        if !chain_servable(engine, cuts) {
                            continue;
                        }
                        let costs = chain_costs(points, cuts)?;
                        v.push((cuts.clone(), costs));
                    }
                    e.insert(v)
                }
            };
        let tiers: Vec<&DeviceProfile> = chain
            .iter()
            .map(|&d| &fleet.devices[d].profile)
            .collect();
        for (cuts, costs) in cut_chains.iter() {
            // Odometer over per-hop link assignments, hop 0 most
            // significant.
            let mut assign = vec![0usize; k];
            loop {
                let hop_nets: Vec<&NetworkConfig> =
                    assign.iter().map(|&l| &fleet.links[l].1).collect();
                cands.push(Candidate {
                    tiers: chain.clone(),
                    cuts: cuts.clone(),
                    links: assign.clone(),
                    bound_ns: latency_bound_ns(&tiers, costs, &hop_nets),
                });
                let mut h = k;
                loop {
                    if h == 0 {
                        break;
                    }
                    h -= 1;
                    assign[h] += 1;
                    if assign[h] < fleet.links.len() {
                        break;
                    }
                    assign[h] = 0;
                }
                if assign.iter().all(|&l| l == 0) {
                    break;
                }
            }
        }
    }
    if cands.len() > 100_000 {
        bail!(
            "fleet '{}': search space has {} candidates — lower \
             max_tiers, device counts or the link set",
            fleet.name,
            cands.len()
        );
    }
    Ok(cands)
}

/// Simulate every stream of the fleet on one candidate.
fn evaluate(
    engine: &dyn InferenceBackend,
    dataset: &Dataset,
    fleet: &FleetSpec,
    cands: &[Candidate],
    ci: usize,
) -> Result<Eval> {
    let c = &cands[ci];
    let tiers: Vec<DeviceProfile> = c
        .tiers
        .iter()
        .map(|&d| fleet.devices[d].profile.clone())
        .collect();
    let hop_nets: Vec<NetworkConfig> =
        c.links.iter().map(|&l| fleet.links[l].1.clone()).collect();
    let mut verdicts = Vec::with_capacity(fleet.streams.len());
    for stream in &fleet.streams {
        let qos = stream.qos()?;
        let cfg = ScenarioConfig {
            kind: ScenarioKind::Mc { cuts: c.cuts.clone() },
            hop_nets: hop_nets.clone(),
            tiers: tiers.clone(),
            scale: ModelScale::Slim,
            frame_period_ns: (1e9 / stream.fps) as SimTime,
        };
        let r = sweep::pooled_scenario(
            engine,
            &cfg,
            dataset,
            fleet.frames,
            &[fleet.seed],
            &qos,
        )?;
        verdicts.push(StreamVerdict {
            stream: stream.name.clone(),
            satisfied: qos.satisfied_by(r.deadline_hit_rate, r.accuracy),
            mean_latency_ns: r.mean_latency_ns,
            accuracy: r.accuracy,
            deadline_hit_rate: r.deadline_hit_rate,
        });
    }
    let n = verdicts.len() as f64;
    Ok(Eval {
        cand: ci,
        satisfied: verdicts.iter().filter(|v| v.satisfied).count(),
        mean_latency_ns: verdicts
            .iter()
            .map(|v| v.mean_latency_ns)
            .sum::<f64>()
            / n,
        accuracy: verdicts.iter().map(|v| v.accuracy).sum::<f64>() / n,
        verdicts,
    })
}

/// Fold one evaluation into the incumbent under [`better`].
fn absorb(incumbent: &mut Option<Eval>, ev: Eval) {
    if incumbent.as_ref().map_or(true, |inc| better(&ev, inc)) {
        *incumbent = Some(ev);
    }
}

/// Run the branch-and-bound over `order` (candidate indices, ascending
/// analytic bound) on a work-stealing pool: every worker claims the next
/// candidate off a shared counter, prunes it at claim time against the
/// shared incumbent, else simulates it and merges the result back.
/// There is no wave barrier — while one worker simulates a heavy
/// candidate, the others keep claiming, and every merged evaluation
/// tightens the incumbent *immediately* for all subsequent claims.
///
/// Each claimed candidate is counted exactly once (pruned or evaluated),
/// so `evaluated + pruned == order.len()` on success. Which candidates
/// get pruned depends on evaluation timing, but the *winner* does not:
/// the bound is admissible, so a pruned candidate provably loses to the
/// incumbent that pruned it — and, [`better`] being a strict total
/// order, to the final winner too.
fn branch_and_bound(
    engine: &dyn InferenceBackend,
    dataset: &Dataset,
    fleet: &FleetSpec,
    cands: &[Candidate],
    order: &[usize],
    threads: usize,
    factory: &BackendFactory<'_>,
) -> Result<(Option<Eval>, usize, usize)> {
    let threads = threads.clamp(1, order.len().max(1));
    if threads <= 1 {
        let mut incumbent: Option<Eval> = None;
        let (mut evaluated, mut pruned) = (0usize, 0usize);
        for &ci in order {
            if !fleet.exhaustive {
                if let Some(inc) = &incumbent {
                    if prunable(fleet, &cands[ci], inc) {
                        pruned += 1;
                        continue;
                    }
                }
            }
            absorb(
                &mut incumbent,
                evaluate(engine, dataset, fleet, cands, ci)?,
            );
            evaluated += 1;
        }
        return Ok((incumbent, evaluated, pruned));
    }
    let incumbent: Mutex<Option<Eval>> = Mutex::new(None);
    let next = AtomicUsize::new(0);
    let evaluated = AtomicUsize::new(0);
    let pruned = AtomicUsize::new(0);
    let failed = AtomicBool::new(false);
    let error: Mutex<Option<anyhow::Error>> = Mutex::new(None);
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| {
                let mut engine: Option<Box<dyn InferenceBackend>> = None;
                loop {
                    if failed.load(Ordering::Relaxed) {
                        return;
                    }
                    let w = next.fetch_add(1, Ordering::Relaxed);
                    if w >= order.len() {
                        return;
                    }
                    let ci = order[w];
                    if !fleet.exhaustive {
                        let inc = incumbent.lock().unwrap();
                        if inc
                            .as_ref()
                            .is_some_and(|i| prunable(fleet, &cands[ci], i))
                        {
                            pruned.fetch_add(1, Ordering::Relaxed);
                            continue;
                        }
                    }
                    if engine.is_none() {
                        match factory(fleet.arch) {
                            Ok(e) => engine = Some(e),
                            Err(e) => {
                                return sweep::record_failure(
                                    &failed, &error, e,
                                )
                            }
                        }
                    }
                    let eng = engine.as_deref().unwrap();
                    match evaluate(eng, dataset, fleet, cands, ci) {
                        Ok(ev) => {
                            evaluated.fetch_add(1, Ordering::Relaxed);
                            absorb(&mut incumbent.lock().unwrap(), ev);
                        }
                        Err(e) => {
                            return sweep::record_failure(&failed, &error, e)
                        }
                    }
                }
            });
        }
    });
    if let Some(e) = error.into_inner().unwrap() {
        return Err(e);
    }
    Ok((
        incumbent.into_inner().unwrap(),
        evaluated.into_inner(),
        pruned.into_inner(),
    ))
}

/// Search the fleet for the best placement plan.
///
/// Candidates are visited in ascending analytic-bound order by a
/// work-stealing pool of `threads` workers; every finished evaluation
/// tightens the shared incumbent immediately, and later claims it
/// provably dominates are pruned. Because the bound is admissible and
/// ties are broken by candidate index, the returned plan is identical
/// for every `threads` value — and identical to exhaustive enumeration
/// ([`FleetSpec::exhaustive`]).
pub fn place(
    fleet: &FleetSpec,
    threads: usize,
    factory: &BackendFactory<'_>,
) -> Result<PlacementOutcome> {
    let mut cache = ChainCache::new();
    place_cached(fleet, threads, factory, &mut cache)
}

/// [`place`] against a caller-owned [`ChainCache`], so repeated
/// placements (and the co-design search) enumerate each
/// arch × scale × hop-count chain set once.
pub fn place_cached(
    fleet: &FleetSpec,
    threads: usize,
    factory: &BackendFactory<'_>,
    cache: &mut ChainCache,
) -> Result<PlacementOutcome> {
    fleet.validate()?;
    let engine = factory(fleet.arch)?;
    let dataset = engine.dataset(&fleet.dataset)?;
    let network = scenario_network(&*engine, ModelScale::Slim);
    let points = split_points(&network);
    let cands = enumerate(fleet, &*engine, &points, cache)?;
    if cands.is_empty() {
        bail!(
            "fleet '{}': no placement candidates (no servable cut chain \
             fits any tier chain up to {} tiers)",
            fleet.name,
            fleet.max_tiers
        );
    }
    let mut order: Vec<usize> = (0..cands.len()).collect();
    order.sort_by_key(|&i| (cands[i].bound_ns, i));

    let (incumbent, evaluated, pruned) = branch_and_bound(
        &*engine, &dataset, fleet, &cands, &order, threads, factory,
    )?;
    let winner = incumbent.expect("non-empty candidate set was evaluated");
    let c = &cands[winner.cand];
    let names = &engine.manifest().model.layer_names;
    let plan = PlacementPlan {
        fleet: fleet.name.clone(),
        arch: fleet.arch,
        tiers: c
            .tiers
            .iter()
            .map(|&d| fleet.devices[d].profile.clone())
            .collect(),
        cuts: c.cuts.clone(),
        cut_names: c
            .cuts
            .iter()
            .map(|&cut| {
                names
                    .get(cut)
                    .cloned()
                    .unwrap_or_else(|| format!("L{cut}"))
            })
            .collect(),
        hop_links: c
            .links
            .iter()
            .map(|&l| fleet.links[l].0.clone())
            .collect(),
        hop_nets: c
            .links
            .iter()
            .map(|&l| fleet.links[l].1.clone())
            .collect(),
        satisfied: winner.satisfied,
        streams: winner.verdicts,
        mean_latency_ns: winner.mean_latency_ns,
        accuracy: winner.accuracy,
        bound_ns: c.bound_ns,
    };
    Ok(PlacementOutcome {
        plan,
        candidates: cands.len(),
        evaluated,
        pruned,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::transfer::Protocol;
    use crate::runtime::load_backend_for;
    use std::path::Path;

    fn factory(arch: Arch) -> Result<Box<dyn InferenceBackend>> {
        // No artifacts directory in tests: loads the analytic backend.
        load_backend_for(Path::new("artifacts"), arch)
    }

    fn small_fleet() -> FleetSpec {
        FleetSpec {
            name: "unit".into(),
            arch: Arch::Vgg16,
            devices: vec![
                FleetDevice {
                    profile: DeviceProfile::edge_gpu(),
                    count: 1,
                },
                FleetDevice {
                    profile: DeviceProfile::server_gpu(),
                    count: 1,
                },
            ],
            links: vec![
                (
                    "backbone".into(),
                    NetworkConfig::gigabit(Protocol::Tcp, 0.0, 0),
                ),
                (
                    "uplink".into(),
                    NetworkConfig::wifi(Protocol::Udp, 0.05, 0),
                ),
            ],
            streams: vec![
                FleetStream {
                    name: "belt-a".into(),
                    fps: 20.0,
                    min_accuracy: None,
                    min_hit_rate: None,
                },
                FleetStream {
                    name: "belt-b".into(),
                    fps: 50.0,
                    min_accuracy: Some(0.5),
                    min_hit_rate: None,
                },
            ],
            frames: 6,
            seed: 42,
            max_tiers: 2,
            dataset: "test".into(),
            exhaustive: false,
        }
    }

    #[test]
    fn tier_chains_respect_counts_and_order() {
        let devices = vec![
            FleetDevice {
                profile: DeviceProfile::sensor_npu(),
                count: 2,
            },
            FleetDevice { profile: DeviceProfile::edge_gpu(), count: 1 },
        ];
        let chains = tier_chains(&devices, 3);
        // ss, se, sse, e alone is too short; s alone too short.
        assert!(chains.contains(&vec![0, 0]));
        assert!(chains.contains(&vec![0, 1]));
        assert!(chains.contains(&vec![0, 0, 1]));
        assert!(!chains.iter().any(|c| c.len() < 2 || c.len() > 3));
        // Counts are a hard budget: no chain uses three sensors or two
        // edges.
        assert!(!chains.iter().any(|c| {
            c.iter().filter(|&&d| d == 0).count() > 2
                || c.iter().filter(|&&d| d == 1).count() > 1
        }));
        // Declared order is preserved (non-decreasing indices).
        assert!(chains
            .iter()
            .all(|c| c.windows(2).all(|w| w[0] <= w[1])));
    }

    #[test]
    fn branch_and_bound_matches_exhaustive_enumeration() {
        // The acceptance oracle: pruning must never change the winner.
        let mut fleet = small_fleet();
        let bb = place(&fleet, 1, &factory).unwrap();
        fleet.exhaustive = true;
        let oracle = place(&fleet, 1, &factory).unwrap();
        assert_eq!(
            bb.plan.to_json().to_string(),
            oracle.plan.to_json().to_string()
        );
        assert_eq!(oracle.evaluated, oracle.candidates);
        assert_eq!(oracle.pruned, 0);
        assert!(bb.evaluated <= oracle.evaluated);
    }

    #[test]
    fn winning_plan_is_thread_count_invariant() {
        let fleet = small_fleet();
        let one = place(&fleet, 1, &factory).unwrap();
        let many = place(&fleet, 8, &factory).unwrap();
        assert_eq!(
            one.plan.to_json().to_string(),
            many.plan.to_json().to_string()
        );
    }

    #[test]
    fn bound_is_admissible_for_every_evaluated_candidate() {
        // Every stream's measured mean latency must dominate the
        // analytic bound — otherwise pruning could discard true winners.
        let mut fleet = small_fleet();
        fleet.exhaustive = true;
        let engine = factory(fleet.arch).unwrap();
        let dataset = engine.dataset(&fleet.dataset).unwrap();
        let network = scenario_network(&*engine, ModelScale::Slim);
        let points = split_points(&network);
        let cands = enumerate(&fleet, &*engine, &points).unwrap();
        assert!(!cands.is_empty());
        for ci in 0..cands.len() {
            let ev = evaluate(&*engine, &dataset, &fleet, &cands, ci)
                .unwrap();
            for v in &ev.verdicts {
                assert!(
                    v.mean_latency_ns >= cands[ci].bound_ns as f64,
                    "candidate {ci} ({:?} cuts {:?}): bound {} ns \
                     exceeds measured {} ns for stream {}",
                    cands[ci].tiers,
                    cands[ci].cuts,
                    cands[ci].bound_ns,
                    v.mean_latency_ns,
                    v.stream
                );
            }
        }
    }

    #[test]
    fn fleet_spec_parses_and_validates() {
        let text = r#"{
            "name": "demo", "arch": "vgg16",
            "devices": [
                {"profile": "sensor-npu", "count": 1},
                {"profile": "server-gpu"}
            ],
            "links": {"up": "wifi:udp:loss=0.02", "bb": "gigabit:tcp"},
            "streams": [{"name": "a", "fps": 20, "min_accuracy": 0.4}],
            "frames": 8, "seed": 7, "max_tiers": 2
        }"#;
        let spec = FleetSpec::from_json(text).unwrap();
        assert_eq!(spec.name, "demo");
        assert_eq!(spec.devices.len(), 2);
        assert_eq!(spec.devices[1].count, 1);
        // JSON objects are name-sorted: "bb" precedes "up".
        assert_eq!(spec.links[0].0, "bb");
        assert_eq!(spec.links[1].1.protocol, Protocol::Udp);
        assert!((spec.links[1].1.loss_rate - 0.02).abs() < 1e-12);
        assert_eq!(spec.streams[0].min_accuracy, Some(0.4));
        assert_eq!(spec.max_tiers, 2);
        assert!(!spec.exhaustive);

        for bad in [
            r#"{"name": "x"}"#,
            // unknown key
            r#"{"name": "x", "arch": "vgg16", "devices": [],
                "links": {"l": "gigabit"}, "streams": [], "bogus": 1}"#,
            // no devices at all
            r#"{"name": "x", "arch": "vgg16", "devices": [],
                "links": {"l": "gigabit"},
                "streams": [{"name": "a", "fps": 20}]}"#,
            // one device cannot form a chain
            r#"{"name": "x", "arch": "vgg16",
                "devices": [{"profile": "edge-gpu"}],
                "links": {"l": "gigabit"},
                "streams": [{"name": "a", "fps": 20}]}"#,
            // duplicate stream names
            r#"{"name": "x", "arch": "vgg16",
                "devices": [{"profile": "edge-gpu"},
                            {"profile": "server-gpu"}],
                "links": {"l": "gigabit"},
                "streams": [{"name": "a", "fps": 20},
                            {"name": "a", "fps": 10}]}"#,
            // bad link spec
            r#"{"name": "x", "arch": "vgg16",
                "devices": [{"profile": "edge-gpu"},
                            {"profile": "server-gpu"}],
                "links": {"l": "carrier-pigeon"},
                "streams": [{"name": "a", "fps": 20}]}"#,
        ] {
            assert!(FleetSpec::from_json(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn plan_prefers_satisfying_fast_links() {
        // With a gigabit backbone available, the winner must not route
        // its hop over the lossy wifi uplink: same cut chain over the
        // faster link strictly dominates on satisfied streams (or mean
        // latency at equal satisfaction).
        let fleet = small_fleet();
        let out = place(&fleet, 1, &factory).unwrap();
        assert_eq!(out.plan.hop_links, vec!["backbone".to_string()]);
        assert_eq!(out.plan.tiers.len(), 2);
        assert_eq!(out.plan.cuts.len(), 1);
        assert_eq!(out.plan.streams.len(), 2);
        assert!(out.plan.satisfied >= 1);
        // The search did real pruning work on this fleet, and the
        // accounting is consistent.
        assert_eq!(out.evaluated + out.pruned, out.candidates);
    }
}
