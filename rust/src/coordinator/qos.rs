//! Application QoS requirements (paper Sec. I/V: e.g. "maximum frame
//! latency of 0.05 s (20 FPS), given by the velocity of the conveyor belt").

use crate::netsim::event::{from_secs, SimTime};

#[derive(Clone, Copy, Debug)]
pub struct QosRequirements {
    /// Maximum acceptable per-frame latency.
    pub max_latency_ns: Option<SimTime>,
    /// Minimum acceptable classification accuracy in [0, 1].
    pub min_accuracy: Option<f64>,
}

impl QosRequirements {
    pub fn none() -> Self {
        QosRequirements { max_latency_ns: None, min_accuracy: None }
    }

    /// The ICE-Lab conveyor-belt requirement from the paper: 20 FPS.
    pub fn ice_lab() -> Self {
        QosRequirements {
            max_latency_ns: Some(from_secs(0.05)),
            min_accuracy: None,
        }
    }

    pub fn with_fps(fps: f64) -> Self {
        QosRequirements {
            max_latency_ns: Some(from_secs(1.0 / fps)),
            min_accuracy: None,
        }
    }

    pub fn and_accuracy(mut self, min: f64) -> Self {
        self.min_accuracy = Some(min);
        self
    }

    /// Does a measured (latency, accuracy) pair satisfy the requirements?
    pub fn satisfied_by(&self, latency_ns: SimTime, accuracy: f64) -> bool {
        self.max_latency_ns.map_or(true, |m| latency_ns <= m)
            && self.min_accuracy.map_or(true, |m| accuracy >= m)
    }

    pub fn describe(&self) -> String {
        let mut parts = Vec::new();
        if let Some(l) = self.max_latency_ns {
            parts.push(format!(
                "latency <= {:.1} ms ({:.0} FPS)",
                l as f64 / 1e6,
                1e9 / l as f64
            ));
        }
        if let Some(a) = self.min_accuracy {
            parts.push(format!("accuracy >= {:.1}%", a * 100.0));
        }
        if parts.is_empty() {
            "no constraints".to_string()
        } else {
            parts.join(", ")
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ice_lab_is_20fps() {
        let q = QosRequirements::ice_lab();
        assert_eq!(q.max_latency_ns, Some(50_000_000));
    }

    #[test]
    fn satisfaction_logic() {
        let q = QosRequirements::with_fps(20.0).and_accuracy(0.9);
        assert!(q.satisfied_by(49_000_000, 0.95));
        assert!(!q.satisfied_by(51_000_000, 0.95));
        assert!(!q.satisfied_by(49_000_000, 0.85));
    }

    #[test]
    fn no_constraints_always_satisfied() {
        assert!(QosRequirements::none().satisfied_by(u64::MAX, 0.0));
    }

    #[test]
    fn describe_mentions_both() {
        let d = QosRequirements::with_fps(20.0).and_accuracy(0.9).describe();
        assert!(d.contains("50.0 ms") && d.contains("90.0%"), "{d}");
    }
}
