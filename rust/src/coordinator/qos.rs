//! Application QoS requirements (paper Sec. I/V: e.g. "maximum frame
//! latency of 0.05 s (20 FPS), given by the velocity of the conveyor belt").
//!
//! The paper's latency bound is *per frame*: a conveyor item that misses
//! its deadline is a miss even if the stream's mean latency looks fine.
//! The verdict therefore checks the **deadline hit-rate** — the fraction
//! of frames with latency within `max_latency_ns` — against an explicit
//! [`QosRequirements::min_hit_rate`] threshold (1.0 by default: every
//! frame must make it).

use anyhow::{bail, Result};

use crate::netsim::event::{from_secs, SimTime};

#[derive(Clone, Copy, Debug)]
pub struct QosRequirements {
    /// Maximum acceptable per-frame latency.
    pub max_latency_ns: Option<SimTime>,
    /// Minimum acceptable classification accuracy in [0, 1].
    pub min_accuracy: Option<f64>,
    /// Minimum fraction of frames that must meet `max_latency_ns`, in
    /// (0, 1]. Defaults to 1.0 (the paper's hard per-frame deadline);
    /// loosen via [`QosRequirements::and_hit_rate`] for soft-real-time
    /// applications that tolerate occasional misses.
    pub min_hit_rate: f64,
}

impl QosRequirements {
    pub fn none() -> Self {
        QosRequirements {
            max_latency_ns: None,
            min_accuracy: None,
            min_hit_rate: 1.0,
        }
    }

    /// The ICE-Lab conveyor-belt requirement from the paper: 20 FPS.
    pub fn ice_lab() -> Self {
        QosRequirements {
            max_latency_ns: Some(from_secs(0.05)),
            min_accuracy: None,
            min_hit_rate: 1.0,
        }
    }

    /// A per-frame latency bound of one frame period at `fps`.
    /// Rejects non-positive or non-finite rates (a zero or negative FPS
    /// would silently turn into an infinite/garbage bound) and rates
    /// beyond 1 GHz (a sub-nanosecond frame period is not representable
    /// in [`SimTime`] and would silently collapse to 0).
    pub fn with_fps(fps: f64) -> Result<Self> {
        if !fps.is_finite() || fps <= 0.0 || fps > 1e9 {
            bail!(
                "QoS frame rate must be a positive number <= 1e9, got {fps}"
            );
        }
        Ok(QosRequirements {
            max_latency_ns: Some(from_secs(1.0 / fps)),
            min_accuracy: None,
            min_hit_rate: 1.0,
        })
    }

    /// Build requirements from optional parsed bounds (the clients-spec /
    /// sweep JSON form), validating each: a latency bound must be a
    /// positive finite millisecond count, accuracy in [0, 1], hit-rate in
    /// (0, 1]. All `None` yields [`QosRequirements::none`].
    pub fn from_bounds(
        max_latency_ms: Option<f64>,
        min_accuracy: Option<f64>,
        min_hit_rate: Option<f64>,
    ) -> Result<Self> {
        let mut q = QosRequirements::none();
        if let Some(ms) = max_latency_ms {
            if !ms.is_finite() || ms <= 0.0 {
                bail!(
                    "max_latency_ms must be a positive number, got {ms}"
                );
            }
            q.max_latency_ns = Some(from_secs(ms / 1e3));
        }
        if let Some(a) = min_accuracy {
            if !a.is_finite() || !(0.0..=1.0).contains(&a) {
                bail!("min_accuracy must be in [0, 1], got {a}");
            }
            q.min_accuracy = Some(a);
        }
        if let Some(h) = min_hit_rate {
            if !h.is_finite() || h <= 0.0 || h > 1.0 {
                bail!("min_hit_rate must be in (0, 1], got {h}");
            }
            q.min_hit_rate = h;
        }
        Ok(q)
    }

    pub fn and_accuracy(mut self, min: f64) -> Self {
        self.min_accuracy = Some(min);
        self
    }

    /// Require only `rate` of the frames to meet the latency bound
    /// (soft-real-time). `rate` must be in (0, 1].
    pub fn and_hit_rate(mut self, rate: f64) -> Self {
        assert!(
            rate > 0.0 && rate <= 1.0,
            "hit-rate threshold must be in (0, 1], got {rate}"
        );
        self.min_hit_rate = rate;
        self
    }

    /// Does a measured deadline hit-rate satisfy the latency constraint?
    /// (`None` = unmeasured, which fails a latency-constrained QoS
    /// rather than silently passing it.) The single source of truth for
    /// the per-frame latency verdict — the scenario, streaming and sweep
    /// reductions all route through here.
    pub fn latency_ok(&self, deadline_hit_rate: Option<f64>) -> bool {
        match (self.max_latency_ns, deadline_hit_rate) {
            (None, _) => true,
            (Some(_), Some(hit)) => hit >= self.min_hit_rate,
            (Some(_), None) => false,
        }
    }

    /// Does a measured stream satisfy the requirements?
    ///
    /// `deadline_hit_rate` is the fraction of frames whose latency was
    /// within `max_latency_ns` (see [`QosRequirements::latency_ok`]).
    pub fn satisfied_by(
        &self,
        deadline_hit_rate: Option<f64>,
        accuracy: f64,
    ) -> bool {
        self.latency_ok(deadline_hit_rate)
            && self.min_accuracy.map_or(true, |m| accuracy >= m)
    }

    pub fn describe(&self) -> String {
        let mut parts = Vec::new();
        if let Some(l) = self.max_latency_ns {
            let frames = if self.min_hit_rate >= 1.0 {
                "every frame".to_string()
            } else {
                format!(">= {:.1}% of frames", self.min_hit_rate * 100.0)
            };
            parts.push(format!(
                "latency <= {:.1} ms ({:.0} FPS) for {frames}",
                l as f64 / 1e6,
                1e9 / l as f64
            ));
        }
        if let Some(a) = self.min_accuracy {
            parts.push(format!("accuracy >= {:.1}%", a * 100.0));
        }
        if parts.is_empty() {
            "no constraints".to_string()
        } else {
            parts.join(", ")
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ice_lab_is_20fps() {
        let q = QosRequirements::ice_lab();
        assert_eq!(q.max_latency_ns, Some(50_000_000));
        assert_eq!(q.min_hit_rate, 1.0);
    }

    #[test]
    fn with_fps_rejects_non_positive() {
        assert!(QosRequirements::with_fps(0.0).is_err());
        assert!(QosRequirements::with_fps(-20.0).is_err());
        assert!(QosRequirements::with_fps(f64::NAN).is_err());
        assert!(QosRequirements::with_fps(f64::INFINITY).is_err());
        // Sub-nanosecond frame periods are not representable.
        assert!(QosRequirements::with_fps(2e9).is_err());
        let q = QosRequirements::with_fps(20.0).unwrap();
        assert_eq!(q.max_latency_ns, Some(50_000_000));
    }

    #[test]
    fn verdict_is_per_frame_not_mean() {
        // One 10 ms frame and one 90 ms frame have a 50 ms mean, but only
        // half the frames hit a 50 ms deadline: the default (strict)
        // verdict must be "violated".
        let q = QosRequirements::with_fps(20.0).unwrap();
        assert!(!q.satisfied_by(Some(0.5), 1.0));
        assert!(q.satisfied_by(Some(1.0), 1.0));
        // A soft-real-time application that tolerates 50% misses passes.
        assert!(q.and_hit_rate(0.5).satisfied_by(Some(0.5), 1.0));
    }

    #[test]
    fn satisfaction_logic() {
        let q = QosRequirements::with_fps(20.0).unwrap().and_accuracy(0.9);
        assert!(q.satisfied_by(Some(1.0), 0.95));
        assert!(!q.satisfied_by(Some(0.99), 0.95));
        assert!(!q.satisfied_by(Some(1.0), 0.85));
        // Unmeasured hit-rate cannot satisfy a latency constraint.
        assert!(!q.satisfied_by(None, 0.95));
    }

    #[test]
    fn no_constraints_always_satisfied() {
        assert!(QosRequirements::none().satisfied_by(None, 0.0));
        assert!(QosRequirements::none().satisfied_by(Some(0.0), 0.0));
    }

    #[test]
    fn describe_mentions_both() {
        let d = QosRequirements::with_fps(20.0)
            .unwrap()
            .and_accuracy(0.9)
            .describe();
        assert!(d.contains("50.0 ms") && d.contains("90.0%"), "{d}");
        let soft = QosRequirements::with_fps(20.0)
            .unwrap()
            .and_hit_rate(0.95)
            .describe();
        assert!(soft.contains("95.0% of frames"), "{soft}");
    }

    #[test]
    #[should_panic]
    fn hit_rate_threshold_validated() {
        let _ = QosRequirements::ice_lab().and_hit_rate(0.0);
    }

    #[test]
    fn from_bounds_validates_each_field() {
        let q = QosRequirements::from_bounds(None, None, None).unwrap();
        assert!(q.max_latency_ns.is_none() && q.min_accuracy.is_none());
        assert_eq!(q.min_hit_rate, 1.0);

        let q = QosRequirements::from_bounds(
            Some(50.0),
            Some(0.9),
            Some(0.95),
        )
        .unwrap();
        assert_eq!(q.max_latency_ns, Some(50_000_000));
        assert_eq!(q.min_accuracy, Some(0.9));
        assert_eq!(q.min_hit_rate, 0.95);

        assert!(QosRequirements::from_bounds(Some(0.0), None, None)
            .is_err());
        assert!(QosRequirements::from_bounds(Some(f64::NAN), None, None)
            .is_err());
        assert!(QosRequirements::from_bounds(None, Some(1.5), None)
            .is_err());
        assert!(QosRequirements::from_bounds(None, Some(-0.1), None)
            .is_err());
        assert!(QosRequirements::from_bounds(None, None, Some(0.0))
            .is_err());
        assert!(QosRequirements::from_bounds(None, None, Some(1.1))
            .is_err());
    }
}
