//! The `--clients-spec` JSON surface: parse errors must name the
//! offending entry (`clients[i]: ...`), bulk `count` expansion and
//! defaults must apply, and a parsed spec must run end-to-end through
//! [`serve_clients`] on the analytic backend with per-tenant QoS
//! verdicts in the report.

use std::path::Path;

use sei::coordinator::batcher::BatchPolicy;
use sei::coordinator::{
    parse_clients_spec, serve_clients, Fairness, ModelScale,
    MultiStreamConfig, QosRequirements, ScenarioKind,
};
use sei::model::{Arch, DeviceProfile};
use sei::netsim::transfer::{NetworkConfig, Protocol};
use sei::netsim::QueueKind;
use sei::runtime::{load_backend_for, InferenceBackend};

fn err_of(doc: &str) -> String {
    format!("{:#}", parse_clients_spec(doc).unwrap_err())
}

#[test]
fn errors_name_the_offending_entry() {
    // Missing required key on the *second* entry: the index must point
    // at it, not at the document.
    let e = err_of(r#"[{"scenario": "rc"}, {"fps": 30}]"#);
    assert!(e.contains("clients[1]"), "{e}");
    assert!(e.contains("missing required key 'scenario'"), "{e}");

    let e = err_of(r#"[{"scenario": "rc", "fsp": 30}]"#);
    assert!(e.contains("clients[0]: unknown key 'fsp'"), "{e}");
    // The message lists the known keys so the typo is self-correcting.
    assert!(e.contains("fps"), "{e}");

    let e = err_of(
        r#"[{"scenario": "rc", "fps": 30, "frame_period_ns": 1000}]"#,
    );
    assert!(
        e.contains("clients[0]: give 'fps' or 'frame_period_ns', not both"),
        "{e}"
    );

    let e = err_of(
        r#"[{"scenario": "rc"}, {"scenario": "lc", "min_accuracy": 1.5}]"#,
    );
    assert!(e.contains("clients[1]"), "{e}");
    assert!(e.contains("min_accuracy"), "{e}");

    let e = err_of(r#"[{"scenario": "rc", "frames": 0}]"#);
    assert!(e.contains("clients[0]: frames must be >= 1"), "{e}");

    let e = err_of(r#"[{"scenario": "rc", "weight": 0}]"#);
    assert!(e.contains("clients[0]: weight must be >= 1"), "{e}");

    let e = err_of(r#"[{"scenario": "rc", "count": 0}]"#);
    assert!(e.contains("clients[0]: count must be >= 1"), "{e}");

    let e = err_of(r#"[{"scenario": "tc"}]"#);
    assert!(e.contains("clients[0]"), "{e}");

    let e = err_of(r#"[{"scenario": "rc"}, 7]"#);
    assert!(
        e.contains("clients[1]: each entry must be a JSON object"),
        "{e}"
    );

    let e = err_of("42");
    assert!(e.contains("clients spec must be a JSON array"), "{e}");

    let e = err_of("[]");
    assert!(e.contains("no client entries"), "{e}");

    let e = err_of(r#"[{"scenario": "rc", "fps": -5}]"#);
    assert!(e.contains("clients[0]"), "{e}");
    assert!(e.contains("fps must be a positive number"), "{e}");
}

#[test]
fn count_expands_and_defaults_apply() {
    let spec = parse_clients_spec(
        r#"{"clients": [
            {"scenario": "rc", "count": 3, "fps": 200},
            {"scenario": "sc@5", "arch": "resnet18", "scale": "full",
             "frames": 7, "weight": 4, "frame_period_ns": 250000}
        ]}"#,
    )
    .unwrap();
    assert_eq!(spec.len(), 4);
    for c in &spec[..3] {
        assert_eq!(c.kind, ScenarioKind::Rc);
        assert_eq!(c.arch, Arch::Vgg16);
        assert_eq!(c.scale, ModelScale::Slim);
        // fps 200 -> 5 ms period; defaults: 64 frames, weight 1, no QoS.
        assert_eq!(c.frame_period_ns, 5_000_000);
        assert_eq!(c.frames, 64);
        assert_eq!(c.weight, 1);
        assert!(c.qos.max_latency_ns.is_none());
    }
    let d = &spec[3];
    assert_eq!(d.kind, ScenarioKind::Sc { split: 5 });
    assert_eq!(d.arch, Arch::ResNet18);
    assert_eq!(d.scale, ModelScale::Full);
    assert_eq!(d.frame_period_ns, 250_000);
    assert_eq!(d.frames, 7);
    assert_eq!(d.weight, 4);
}

#[test]
fn parsed_spec_serves_end_to_end() {
    let clients = parse_clients_spec(
        r#"[
            {"scenario": "rc", "count": 2, "fps": 100, "frames": 4,
             "max_latency_ms": 200.0},
            {"scenario": "sc@5", "arch": "resnet18", "frames": 3,
             "weight": 2, "max_latency_ms": 500.0, "min_hit_rate": 0.5}
        ]"#,
    )
    .unwrap();
    assert_eq!(clients.len(), 3);

    let owned: Vec<(Arch, Box<dyn InferenceBackend>)> =
        [Arch::Vgg16, Arch::ResNet18]
            .into_iter()
            .map(|a| {
                (
                    a,
                    load_backend_for(Path::new("artifacts"), a)
                        .expect("backend"),
                )
            })
            .collect();
    let engines: Vec<(Arch, &dyn InferenceBackend)> =
        owned.iter().map(|(a, b)| (*a, &**b)).collect();
    let dataset = owned[0].1.dataset("test").unwrap();

    let cfg = MultiStreamConfig {
        clients,
        hop_nets: vec![NetworkConfig::gigabit(Protocol::Udp, 0.0, 5)],
        tiers: vec![DeviceProfile::edge_gpu(), DeviceProfile::server_gpu()],
        batch: BatchPolicy::immediate(),
        fairness: Fairness::Drr,
        admission: true,
        queue: QueueKind::Calendar,
    };
    let served =
        serve_clients(&engines, &cfg, &dataset, &QosRequirements::none())
            .unwrap();
    let r = &served.report;
    assert_eq!(r.outcomes.len(), 3);
    assert_eq!(r.admitted(), 3);
    assert_eq!(r.aggregate.frames, 4 + 4 + 3);
    for o in &r.outcomes {
        assert_eq!(o.frames, cfg.clients[o.client].frames);
        // Full-mode serving measures accuracy, and every tenant here has
        // a latency bound, so each gets a definite per-tenant verdict
        // (the generous bounds make it a pass).
        assert!(o.accuracy.is_some());
        assert_eq!(o.qos_satisfied, Some(true), "client {}", o.client);
    }
    assert!(served.wall_seconds >= 0.0);
    assert!(served.wall_fps > 0.0);
}
