//! The sweep engine's headline guarantee: the same `SweepSpec` produces a
//! byte-identical `SweepReport` at every worker-thread count, and the
//! reduced Pareto frontier is well-formed.

use std::path::Path;

use sei::coordinator::{
    run_sweep, ModelScale, ScenarioKind, SweepMode, SweepSpec,
};
use sei::model::Arch;
use sei::netsim::transfer::Protocol;
use sei::report::pareto::dominates;
use sei::runtime::{load_backend_for, InferenceBackend};

fn factory(arch: Arch) -> anyhow::Result<Box<dyn InferenceBackend>> {
    // No artifacts directory in the test environment: this loads the
    // hermetic analytic backend, which is bit-reproducible per seed.
    load_backend_for(Path::new("artifacts"), arch)
}

fn grid_spec() -> SweepSpec {
    let mut spec = SweepSpec::new("determinism");
    spec.scenarios = vec![
        ScenarioKind::Lc,
        ScenarioKind::Rc,
        ScenarioKind::Sc { split: 11 },
        ScenarioKind::Sc { split: 15 },
    ];
    spec.protocols = vec![Protocol::Tcp, Protocol::Udp];
    spec.loss_rates = vec![0.0, 0.05];
    spec.frames = 24;
    spec.seeds_per_point = 2;
    spec.frame_period_ns = 50_000_000;
    spec.max_latency_ms = 50.0;
    spec.min_accuracy = 0.9;
    spec
}

#[test]
fn report_is_identical_at_one_and_eight_threads() {
    let spec = grid_spec();
    let sequential = run_sweep(&spec, 1, &factory).unwrap();
    let parallel = run_sweep(&spec, 8, &factory).unwrap();
    assert_eq!(
        sequential.to_json().to_string(),
        parallel.to_json().to_string(),
        "sweep JSON must not depend on the thread count"
    );
    assert_eq!(
        sequential.to_csv().to_string(),
        parallel.to_csv().to_string(),
        "sweep CSV must not depend on the thread count"
    );
    assert_eq!(sequential.pareto, parallel.pareto);
}

#[test]
fn points_come_back_in_expansion_order() {
    let spec = grid_spec();
    let jobs = spec.expand().unwrap();
    let report = run_sweep(&spec, 3, &factory).unwrap();
    assert_eq!(report.points.len(), jobs.len());
    for (job, point) in jobs.iter().zip(&report.points) {
        assert_eq!(job.index, point.index);
        assert_eq!(job.kind, point.kind);
        assert_eq!(job.protocol, point.protocol);
        assert!((job.loss - point.loss).abs() < 1e-12);
    }
}

#[test]
fn frontier_is_nondominated_and_sorted_over_real_points() {
    let report = run_sweep(&grid_spec(), 4, &factory).unwrap();
    assert!(!report.pareto.is_empty());
    let coord = |i: usize| {
        let p = &report.points[i];
        (p.accuracy.unwrap(), p.mean_latency_ns)
    };
    for w in report.pareto.windows(2) {
        let (a, b) = (coord(w[0]), coord(w[1]));
        assert!(b.1 >= a.1, "frontier not sorted by latency: {a:?} {b:?}");
        assert!(b.0 > a.0, "frontier accuracy not increasing: {a:?} {b:?}");
    }
    for &f in &report.pareto {
        for i in 0..report.points.len() {
            if i != f {
                assert!(
                    !dominates(coord(i), coord(f)),
                    "frontier point {f} dominated by {i}"
                );
            }
        }
    }
}

#[test]
fn latency_only_sweep_is_thread_count_invariant_too() {
    let mut spec = grid_spec();
    spec.mode = SweepMode::LatencyOnly;
    spec.min_accuracy = 0.0;
    let one = run_sweep(&spec, 1, &factory).unwrap();
    let six = run_sweep(&spec, 6, &factory).unwrap();
    assert_eq!(one.to_json().to_string(), six.to_json().to_string());
    assert!(one.points.iter().all(|p| p.accuracy.is_none()));
}

#[test]
fn streaming_axes_are_thread_count_invariant() {
    // The new clients × offered_fps load axes (and the batched server
    // behind them) must preserve the headline guarantee: byte-identical
    // reports at every worker-thread count.
    let mut spec = SweepSpec::new("streaming-determinism");
    spec.scenarios = vec![ScenarioKind::Rc, ScenarioKind::Sc { split: 13 }];
    spec.protocols = vec![Protocol::Tcp, Protocol::Udp];
    spec.loss_rates = vec![0.0, 0.05];
    spec.frames = 10;
    spec.clients = vec![1, 3];
    spec.offered_fps = vec![60.0, 240.0];
    spec.max_batch = 4;
    spec.batch_wait_us = 500.0;
    spec.max_latency_ms = 50.0;
    spec.min_hit_rate = 0.9;
    let one = run_sweep(&spec, 1, &factory).unwrap();
    let eight = run_sweep(&spec, 8, &factory).unwrap();
    assert_eq!(one.points.len(), 2 * 2 * 2 * 2 * 2);
    assert_eq!(
        one.to_json().to_string(),
        eight.to_json().to_string(),
        "streaming sweep JSON must not depend on the thread count"
    );
    assert_eq!(one.to_csv().to_string(), eight.to_csv().to_string());
    for p in &one.points {
        assert!(p.throughput_fps > 0.0);
        assert!(p.frames > 0);
        assert!(p.deadline_hit_rate.is_some());
    }
    // Sanity on the load axes: achieved throughput can never meaningfully
    // exceed the aggregate offered rate. (The stream duration spans
    // frames-1 inter-arrival gaps, so the ratio is bounded by
    // frames/(frames-1); use a safely larger margin.)
    for p in &one.points {
        let offered_agg = p.offered_fps.unwrap() * p.clients as f64;
        assert!(
            p.throughput_fps <= offered_agg * 1.25,
            "throughput {} cannot exceed offered {}",
            p.throughput_fps,
            offered_agg
        );
    }
}

#[test]
fn arch_axis_is_thread_count_invariant() {
    // The new arch grid axis must preserve the headline guarantee: a
    // sweep spanning the whole zoo produces byte-identical reports at
    // every worker-thread count (workers open per-arch backends lazily,
    // in whatever order the job counter deals them).
    let mut spec = SweepSpec::new("arch-determinism");
    spec.scenarios = vec![
        ScenarioKind::Lc,
        ScenarioKind::Rc,
        ScenarioKind::Sc { split: 5 },
    ];
    spec.protocols = vec![Protocol::Tcp, Protocol::Udp];
    spec.loss_rates = vec![0.0, 0.05];
    spec.scales = vec![ModelScale::Slim, ModelScale::Full];
    spec.archs = vec![Arch::Vgg16, Arch::ResNet18, Arch::MobileNetV2];
    spec.frames = 12;
    spec.max_latency_ms = 50.0;
    spec.min_accuracy = 0.9;
    let one = run_sweep(&spec, 1, &factory).unwrap();
    let eight = run_sweep(&spec, 8, &factory).unwrap();
    assert_eq!(one.points.len(), 3 * 2 * 2 * 2 * 3);
    assert_eq!(
        one.to_json().to_string(),
        eight.to_json().to_string(),
        "arch-axis sweep JSON must not depend on the thread count"
    );
    assert_eq!(one.to_csv().to_string(), eight.to_csv().to_string());
    // Every zoo arch actually reported points.
    for arch in Arch::ALL {
        assert!(one.points.iter().any(|p| p.arch == arch));
    }
}

#[test]
fn spec_roundtrips_through_json_with_identical_results() {
    let spec = grid_spec();
    let reparsed = SweepSpec::from_json(&spec.to_json().to_string()).unwrap();
    let a = run_sweep(&spec, 2, &factory).unwrap();
    let b = run_sweep(&reparsed, 2, &factory).unwrap();
    assert_eq!(a.to_json().to_string(), b.to_json().to_string());
}
