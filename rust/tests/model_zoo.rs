//! Model IR & zoo property tests: for every architecture in the zoo and
//! every enumerated graph cut, compute is conserved (head MACs + tail
//! MACs == whole-network MACs), the crossing-tensor byte count equals the
//! cut edge's shape, split-point ids are stable across scales, and
//! residual interiors are never offered as cuts.

use sei::model::{
    self, split_points, valid_cuts, Arch, LayerKind, Network, Shape,
};

fn zoo() -> Vec<Network> {
    let mut nets = Vec::new();
    for arch in Arch::ALL {
        nets.push(arch.full_network());
        nets.push(arch.slim_network(32, 0.5, 64, 10));
    }
    // The actual trained slim geometry too.
    nets.push(model::vgg16_slim(32, 0.125, 64, 10));
    nets
}

#[test]
fn every_cut_conserves_mult_adds() {
    for net in zoo() {
        let total = net.mult_adds();
        let cuts = valid_cuts(&net);
        assert!(!cuts.is_empty(), "{}", net.name);
        for c in cuts.iter().chain(split_points(&net).iter()) {
            assert_eq!(
                c.head_mult_adds + c.tail_mult_adds,
                total,
                "{} cut '{}' at pos {}",
                net.name,
                c.name,
                c.pos
            );
        }
    }
}

#[test]
fn crossing_bytes_equal_the_cut_edge_shape() {
    for net in zoo() {
        for c in valid_cuts(&net) {
            // The crossing tensor is the source node's output: its f32
            // byte count is what the netsim would transfer uncompressed.
            assert_eq!(
                c.crossing_bytes(),
                net.layer(c.source).out.bytes_f32() as u64,
                "{} cut '{}'",
                net.name,
                c.name
            );
            assert_eq!(c.out, net.layer(c.source).out);
            // The 50% bottleneck halves the leading dimension.
            assert!(c.latent_bytes() <= c.crossing_bytes());
            // Bottleneck compute is strictly positive: split serving is
            // never free.
            let (enc, dec) = c.bottleneck_mult_adds();
            assert!(enc > 0 && dec > 0);
        }
    }
}

#[test]
fn split_point_ids_are_dense_and_scale_stable() {
    for arch in Arch::ALL {
        let full = split_points(&arch.full_network());
        let slim = split_points(&arch.slim_network(32, 0.5, 64, 10));
        assert_eq!(full.len(), slim.len(), "{}", arch.as_str());
        for (i, (f, s)) in full.iter().zip(&slim).enumerate() {
            assert_eq!(f.index, i);
            assert_eq!(s.index, i);
            assert_eq!(f.name, s.name, "{} id {i}", arch.as_str());
        }
        // Head compute grows monotonically with the cut id.
        for w in full.windows(2) {
            assert!(w[1].head_mult_adds >= w[0].head_mult_adds);
        }
    }
}

#[test]
fn skip_connections_exclude_interior_cuts() {
    // Every Add merge in the zoo implies a contiguous run of invalid cut
    // positions strictly between its fork and the merge node.
    for net in [Arch::ResNet18.full_network(),
                Arch::MobileNetV2.full_network()] {
        let cuts = valid_cuts(&net);
        let valid: Vec<usize> = cuts.iter().map(|c| c.pos).collect();
        let mut residual_blocks = 0;
        for (v, node) in net.nodes.iter().enumerate() {
            if !matches!(node.layer.kind, LayerKind::Add) {
                continue;
            }
            residual_blocks += 1;
            // Positions strictly between the merge's earliest input and
            // the merge itself have a second edge (the other branch)
            // crossing the frontier — none may be offered as a cut.
            let earliest = *node.inputs.iter().min().unwrap();
            for pos in earliest + 1..v {
                assert!(
                    !valid.contains(&pos),
                    "{}: cut at {pos} crosses a branch of merge '{}'",
                    net.name,
                    node.layer.name
                );
            }
            // The post-merge frontier is always a valid single-tensor cut.
            assert!(valid.contains(&v), "{}", node.layer.name);
        }
        assert!(residual_blocks >= 6, "{}", net.name);
        // And no split point is ever an interior position.
        for p in split_points(&net) {
            assert!(valid.contains(&p.pos), "{} '{}'", net.name, p.name);
        }
    }
}

#[test]
fn zoo_goldens() {
    assert_eq!(Arch::Vgg16.full_network().total_params(), 138_357_544);
    assert_eq!(Arch::ResNet18.full_network().total_params(), 11_689_512);
    assert_eq!(
        Arch::MobileNetV2.full_network().total_params(),
        3_504_872
    );
}

#[test]
fn table_renderers_accept_every_arch() {
    // Table I/II generation is DAG-agnostic: it renders any zoo network.
    for arch in Arch::ALL {
        let net = arch.full_network();
        let t1 = model::render_table1(&net, 16);
        let t2 = model::render_table2(&net, 16);
        assert!(t1.contains("Conv2d"), "{}", arch.as_str());
        assert!(t2.contains("Total params"), "{}", arch.as_str());
        match arch {
            Arch::Vgg16 => assert!(t2.contains("138.357.544")),
            Arch::ResNet18 => {
                assert!(t1.contains("BatchNorm2d"));
                assert!(t2.contains("11.689.512"));
            }
            Arch::MobileNetV2 => {
                assert!(t1.contains("ReLU6"));
                assert!(t2.contains("3.504.872"));
            }
        }
    }
}

#[test]
fn cut_shapes_are_chw_in_the_feature_extractor() {
    // Split points (the transmittable candidates) are all feature maps —
    // the classifier tail is never offered as a cut.
    for net in zoo() {
        for p in split_points(&net) {
            assert!(
                matches!(p.out, Shape::Chw(..)),
                "{} '{}' crosses {:?}",
                net.name,
                p.name,
                p.out
            );
        }
    }
}
