//! Cross-module integration tests that do not require built artifacts:
//! netsim x model metadata x report generators x suggestion logic.

use sei::model::{self, DeviceProfile, Shape};
use sei::netsim::transfer::{Channel, NetworkConfig, Protocol};
use sei::netsim::Dir;
use sei::report::{fig3_report, fig4_report};
use sei::util::json::Json;

/// The Fig. 3 mechanism, end to end on the netsim with paper-scale
/// volumetrics: at 1 Gb/s TCP, the L11 latent (256x28x28 f32 ≈ 803 kB)
/// suffers more from loss than the L15 latent (256x14x14 ≈ 201 kB), and
/// the gap grows with the loss rate.
#[test]
fn fig3_mechanism_l11_vs_l15() {
    let feats = model::feature_layers(&model::vgg16_full());
    let l11 = feats[11].latent_bytes();
    let l15 = feats[15].latent_bytes();
    assert_eq!(l11, 4 * l15); // 28^2 vs 14^2

    let mean_latency = |bytes: u64, loss: f64| -> f64 {
        let mut total = 0.0;
        let frames = 40;
        for seed in 0..6u64 {
            let mut ch = Channel::new(NetworkConfig::gigabit(
                Protocol::Tcp, loss, seed,
            ));
            for f in 0..frames {
                ch.advance_to(f * 50_000_000);
                let r = ch.send(Dir::Up, bytes).unwrap();
                total += r.latency_ns() as f64;
            }
        }
        total / (6.0 * frames as f64)
    };

    let base11 = mean_latency(l11, 0.0);
    let base15 = mean_latency(l15, 0.0);
    assert!(base11 > base15, "more bytes must take longer");

    let lossy11 = mean_latency(l11, 0.06);
    let lossy15 = mean_latency(l15, 0.06);
    assert!(lossy11 > 2.0 * base11, "loss should inflate L11 latency");
    // The penalty for the bigger transfer must exceed the smaller one's.
    assert!(
        lossy11 - base11 > lossy15 - base15,
        "L11 penalty {:.0} <= L15 penalty {:.0}",
        lossy11 - base11,
        lossy15 - base15
    );
}

/// The Fig. 4 mechanism: same payload, TCP latency grows with loss while
/// UDP latency does not.
#[test]
fn fig4_mechanism_tcp_vs_udp_latency() {
    let payload = (3 * 224 * 224 * 4) as u64; // RC input at paper scale
    let mean = |proto: Protocol, loss: f64| -> f64 {
        let mut total = 0.0;
        for seed in 0..6u64 {
            let mut ch =
                Channel::new(NetworkConfig::gigabit(proto, loss, seed));
            for f in 0..30u64 {
                ch.advance_to(f * 50_000_000);
                total += ch.send(Dir::Up, payload).unwrap().latency_ns()
                    as f64;
            }
        }
        total / 180.0
    };
    let tcp0 = mean(Protocol::Tcp, 0.0);
    let tcp8 = mean(Protocol::Tcp, 0.08);
    let udp0 = mean(Protocol::Udp, 0.0);
    let udp8 = mean(Protocol::Udp, 0.08);
    assert!(tcp8 > 1.5 * tcp0, "TCP latency must grow: {tcp0} -> {tcp8}");
    assert_eq!(udp0, udp8, "UDP latency must be loss-independent");
}

#[test]
fn split_compute_of_paper_splits_fits_edge_budget() {
    // Device-profile sanity for the ICE-Lab scenario: the head at L11/L15
    // of the full VGG16 on the edge GPU stays under the 50 ms frame budget
    // while the full model on the edge CPU does not.
    let net = model::vgg16_full();
    let edge = DeviceProfile::edge_gpu();
    for split in [11usize, 15] {
        let (head, _) = model::split_compute(&net, split);
        let t = edge.compute_ns(head);
        assert!(t < 50_000_000, "head@L{split} = {t} ns on edge GPU");
    }
    let cpu = DeviceProfile::edge_cpu();
    assert!(cpu.compute_ns(net.mult_adds()) > 50_000_000);
}

#[test]
fn feature_shapes_consistent_between_slim_and_full() {
    let full = model::feature_layers(&model::vgg16_full());
    let slim = model::feature_layers(&model::vgg16_slim(32, 0.125, 64, 10));
    assert_eq!(full.len(), slim.len());
    for (f, s) in full.iter().zip(&slim) {
        assert_eq!(f.name, s.name);
        assert_eq!(f.is_pool, s.is_pool);
        let (Shape::Chw(_, fh, _), Shape::Chw(_, sh, _)) = (f.out, s.out)
        else {
            panic!("non-CHW feature");
        };
        // Same topology: spatial sizes scale by the same 224/32 factor.
        assert_eq!(fh * 32, sh * 224, "layer {}", f.name);
    }
}

#[test]
fn report_generators_accept_real_series() {
    let loss = vec![0.0, 0.03, 0.06];
    let fig3 = fig3_report(
        &loss,
        &[
            ("SC@L11".to_string(), vec![0.02, 0.04, 0.08]),
            ("SC@L15".to_string(), vec![0.01, 0.015, 0.02]),
        ],
        0.05,
    );
    assert!(fig3.contains("VIOLATED") && fig3.contains("SC@L15"));
    let fig4 = fig4_report(
        &loss,
        &[0.97, 0.97, 0.97],
        &[0.97, 0.9, 0.8],
        &[0.001, 0.002, 0.004],
        &[0.001, 0.001, 0.001],
    );
    assert!(fig4.contains("TCP acc"));
}

#[test]
fn json_handles_manifest_scale_documents() {
    // Round-trip a manifest-shaped document through our JSON substrate.
    let doc = r#"{"executables": [{"name": "x", "weights": [], "shape":
        [1, 2, 3]}], "value": 1e-3, "t": true}"#;
    let j = Json::parse(doc).unwrap();
    let again = Json::parse(&j.to_string()).unwrap();
    assert_eq!(j, again);
}

#[test]
fn channel_presets_order_latency_physically() {
    // Same transfer across presets: gigabit < fast-ethernet; wifi pays
    // both lower rate and higher propagation latency.
    let bytes = 500_000u64;
    let lat = |net: NetworkConfig| -> u64 {
        Channel::new(net).send(Dir::Up, bytes).unwrap().latency_ns()
    };
    let g = lat(NetworkConfig::gigabit(Protocol::Tcp, 0.0, 1));
    let f = lat(NetworkConfig::fast_ethernet(Protocol::Tcp, 0.0, 1));
    let w = lat(NetworkConfig::wifi(Protocol::Tcp, 0.0, 1));
    assert!(g < f, "gigabit {g} vs fast-ethernet {f}");
    assert!(g < w, "gigabit {g} vs wifi {w}");
}

#[test]
fn rc_vs_sc_wire_volume_tradeoff() {
    // SC's raison d'être (paper Sec. II): the latent at a deep split is
    // far smaller than the raw input RC must ship.
    let feats = model::feature_layers(&model::vgg16_full());
    let rc_bytes = (3 * 224 * 224 * 4) as u64;
    for split in [13usize, 15] {
        assert!(feats[split].latent_bytes() * 2 < rc_bytes, "L{split}");
    }
    // ...but an early split would ship MORE than the input (dense data!),
    // which is exactly why saliency-guided selection matters.
    assert!(feats[1].latent_bytes() > rc_bytes);
}
