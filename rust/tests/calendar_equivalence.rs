//! Differential pin of the event-queue backends: the hierarchical timing
//! wheel and the indexed event calendar (binary heap on the packed
//! `(time, seq)` key) must reproduce the retained linear next-event scan
//! **byte for byte** — identical `StreamFrameRecord` streams and
//! identical processed-event counts — across randomized draws over
//! architecture × transport × loss × tier chain × scenario kind
//! (including MC cut chains) × client count × source period × batching ×
//! seed.
//!
//! All backends pop the event with the smallest packed key and every
//! key is unique (the sequence number breaks time ties), so any
//! divergence is an ordering bug in one of them, not a modeling change.
//! The suite also carries the `mc@[i] == sc@i` two-tier pin under both
//! backends: a one-cut MC chain is the same deployment as a split
//! computing scenario, and the calendar must agree on that equivalence.

use std::path::Path;

use sei::coordinator::batcher::BatchPolicy;
use sei::coordinator::{
    run_stream_with_queue, ModelScale, QosRequirements, ScenarioConfig,
    ScenarioKind, StreamConfig,
};
use sei::model::{split_points, Arch, DeviceProfile};
use sei::netsim::transfer::{NetworkConfig, Protocol};
use sei::netsim::QueueKind;
use sei::runtime::{load_backend_for, InferenceBackend};

/// Deterministic xorshift64* draw source — the test is randomized but
/// reproducible (fixed seed, no thread or time dependence).
struct Rng(u64);

impl Rng {
    fn next(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.0 = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    fn below(&mut self, n: u64) -> u64 {
        self.next() % n
    }
}

fn engine(arch: Arch) -> Box<dyn InferenceBackend> {
    load_backend_for(Path::new("artifacts"), arch).expect("backend")
}

/// Cut ids usable for SC / MC on `arch` (away from the input and the
/// terminal classifier, matching the analytic backend's validity rule).
fn valid_cuts(arch: Arch) -> Vec<usize> {
    let n = split_points(&arch.full_network()).len();
    (1..n.saturating_sub(1)).collect()
}

#[test]
fn randomized_draws_pin_calendar_to_linear_scan() {
    let archs = [Arch::Vgg16, Arch::ResNet18, Arch::MobileNetV2];
    let engines: Vec<Box<dyn InferenceBackend>> =
        archs.iter().map(|&a| engine(a)).collect();
    let datasets: Vec<_> = engines
        .iter()
        .map(|e| e.dataset("test").expect("dataset"))
        .collect();
    let qos = QosRequirements::ice_lab();
    let mut rng = Rng(0x5EED_CA1E_4DA2_0001);

    for draw in 0..24usize {
        let ai = rng.below(archs.len() as u64) as usize;
        let arch = archs[ai];
        let cuts = valid_cuts(arch);
        let protocol = if rng.below(2) == 0 {
            Protocol::Tcp
        } else {
            Protocol::Udp
        };
        let loss = [0.0, 0.03, 0.08][rng.below(3) as usize];
        let three_tier = rng.below(2) == 0;
        let tiers = if three_tier {
            vec![
                DeviceProfile::parse("sensor-npu").unwrap(),
                DeviceProfile::edge_gpu(),
                DeviceProfile::server_gpu(),
            ]
        } else {
            vec![DeviceProfile::edge_gpu(), DeviceProfile::server_gpu()]
        };
        let kind = if three_tier {
            // Two ordered cuts for the 3-tier chain.
            let i = rng.below(cuts.len() as u64 - 1) as usize;
            let j = i + 1 + rng.below((cuts.len() - i - 1) as u64) as usize;
            ScenarioKind::Mc { cuts: vec![cuts[i], cuts[j]] }
        } else {
            let s = cuts[rng.below(cuts.len() as u64) as usize];
            match rng.below(4) {
                0 => ScenarioKind::Lc,
                1 => ScenarioKind::Rc,
                2 => ScenarioKind::Sc { split: s },
                _ => ScenarioKind::Mc { cuts: vec![s] },
            }
        };
        let clients = 1 + rng.below(3) as usize;
        let frames = 3 + rng.below(5) as usize;
        let period = [0u64, 1_500_000][rng.below(2) as usize];
        let batch = if rng.below(2) == 0 {
            BatchPolicy::immediate()
        } else {
            BatchPolicy::from_micros(4, 500.0).unwrap()
        };
        let seed = rng.next();
        let cfg = StreamConfig {
            scenario: ScenarioConfig {
                kind: kind.clone(),
                hop_nets: vec![NetworkConfig::gigabit(protocol, loss, seed)],
                tiers,
                scale: ModelScale::Slim,
                frame_period_ns: period,
            },
            clients,
            frames_per_client: frames,
            batch,
        };
        // Every fourth draw runs real inference so the pinned records
        // carry correctness bits too, not just timing.
        let dataset =
            if draw % 4 == 0 { Some(&datasets[ai]) } else { None };
        let cal = run_stream_with_queue(
            &*engines[ai], &cfg, dataset, &qos, QueueKind::Calendar,
        )
        .unwrap();
        let lin = run_stream_with_queue(
            &*engines[ai], &cfg, dataset, &qos, QueueKind::LinearScan,
        )
        .unwrap();
        let whl = run_stream_with_queue(
            &*engines[ai], &cfg, dataset, &qos, QueueKind::Wheel,
        )
        .unwrap();
        assert_eq!(
            cal.records, lin.records,
            "draw {draw}: {kind} {} records diverged between backends",
            arch.as_str()
        );
        assert_eq!(
            cal.records, whl.records,
            "draw {draw}: {kind} {} wheel records diverged from calendar",
            arch.as_str()
        );
        assert_eq!(
            cal.stats.events_processed, lin.stats.events_processed,
            "draw {draw}: processed-event counts diverged"
        );
        assert_eq!(
            cal.stats.events_processed, whl.stats.events_processed,
            "draw {draw}: wheel processed-event count diverged"
        );
        assert!(cal.stats.events_processed > 0, "draw {draw}: empty run");
        assert_eq!(cal.records.len(), clients * frames, "draw {draw}");
    }
}

#[test]
fn single_cut_mc_matches_sc_under_both_backends() {
    for arch in [Arch::Vgg16, Arch::ResNet18, Arch::MobileNetV2] {
        let engine = engine(arch);
        let test = engine.dataset("test").unwrap();
        let qos = QosRequirements::ice_lab();
        let cuts = valid_cuts(arch);
        let split = cuts[cuts.len() / 2];
        let make = |kind: ScenarioKind| StreamConfig {
            scenario: ScenarioConfig {
                kind,
                hop_nets: vec![NetworkConfig::gigabit(
                    Protocol::Udp,
                    0.05,
                    7,
                )],
                tiers: vec![
                    DeviceProfile::edge_gpu(),
                    DeviceProfile::server_gpu(),
                ],
                scale: ModelScale::Slim,
                frame_period_ns: 2_000_000,
            },
            clients: 2,
            frames_per_client: 6,
            batch: BatchPolicy::immediate(),
        };
        let sc = make(ScenarioKind::Sc { split });
        let mc = make(ScenarioKind::Mc { cuts: vec![split] });
        let mut reports = Vec::new();
        for queue in [
            QueueKind::Calendar,
            QueueKind::LinearScan,
            QueueKind::Wheel,
        ] {
            for cfg in [&sc, &mc] {
                reports.push(
                    run_stream_with_queue(
                        &*engine,
                        cfg,
                        Some(&test),
                        &qos,
                        queue,
                    )
                    .unwrap(),
                );
            }
        }
        // All six runs — {sc, mc@[split]} × {calendar, linear scan,
        // wheel} — must produce the same record stream.
        for r in &reports[1..] {
            assert_eq!(
                reports[0].records, r.records,
                "{}: mc@[{split}] / sc@{split} records diverged",
                arch.as_str()
            );
        }
    }
}
