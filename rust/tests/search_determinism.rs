//! The evaluation core's headline guarantees, end to end:
//!
//! - the work-stealing sweep pool (and the retained wave baseline)
//!   produce byte-identical reports at every thread count;
//! - the bound-guided prefilter (`"prefilter": true`) never drops an
//!   accuracy-vs-latency Pareto-frontier point — on the repo's own
//!   `examples/specs/grid.json` and on a tightened variant engineered
//!   so the prefilter provably fires;
//! - the successive-halving co-design search (`sei search`) returns a
//!   thread-count-invariant report whose unlimited-budget winner equals
//!   the exhaustive sweep's best point at final-rung fidelity.

use std::cmp::Ordering;
use std::path::Path;

use sei::coordinator::{
    run_search, run_sweep, run_sweep_with, ScenarioKind, SearchSpec,
    SweepPoint, SweepScheduler, SweepSpec,
};
use sei::model::Arch;
use sei::netsim::transfer::Protocol;
use sei::runtime::{load_backend_for, InferenceBackend};

fn factory(arch: Arch) -> anyhow::Result<Box<dyn InferenceBackend>> {
    // No artifacts directory in the test environment: this loads the
    // hermetic analytic backend, which is bit-reproducible per seed.
    load_backend_for(Path::new("artifacts"), arch)
}

/// The committed example grid, exactly as CI's smoke run uses it.
fn grid_json_spec() -> SweepSpec {
    let text = std::fs::read_to_string("../examples/specs/grid.json")
        .expect("examples/specs/grid.json");
    SweepSpec::from_json(&text).expect("grid.json parses")
}

/// A small programmatic grid for the search tests.
fn search_grid() -> SweepSpec {
    let mut spec = SweepSpec::new("codesign");
    spec.scenarios = vec![
        ScenarioKind::Lc,
        ScenarioKind::Rc,
        ScenarioKind::Sc { split: 5 },
    ];
    spec.protocols = vec![Protocol::Tcp, Protocol::Udp];
    spec.loss_rates = vec![0.0, 0.05];
    spec.archs = vec![Arch::Vgg16, Arch::ResNet18];
    spec.frames = 24;
    spec.frame_period_ns = 50_000_000;
    spec.max_latency_ms = 50.0;
    spec.min_accuracy = 0.9;
    spec
}

/// The search's published ranking, replicated independently: QoS
/// satisfaction rank, then mean latency, then accuracy (unmeasured
/// worst), then grid index.
fn search_rank(a: &SweepPoint, b: &SweepPoint) -> Ordering {
    let sat = |p: &SweepPoint| match p.satisfies {
        Some(true) => 2,
        None => 1,
        Some(false) => 0,
    };
    sat(b)
        .cmp(&sat(a))
        .then(a.mean_latency_ns.partial_cmp(&b.mean_latency_ns).unwrap())
        .then(
            b.accuracy
                .unwrap_or(f64::NEG_INFINITY)
                .partial_cmp(&a.accuracy.unwrap_or(f64::NEG_INFINITY))
                .unwrap(),
        )
        .then(a.index.cmp(&b.index))
}

#[test]
fn grid_json_report_is_identical_at_one_and_eight_threads() {
    let mut spec = grid_json_spec();
    spec.frames = 24; // keep the full 56-point grid, trim the runtime
    let one = run_sweep(&spec, 1, &factory).unwrap();
    let eight = run_sweep(&spec, 8, &factory).unwrap();
    assert_eq!(
        one.to_json().to_string(),
        eight.to_json().to_string(),
        "work-stealing sweep JSON must not depend on the thread count"
    );
    assert_eq!(
        one.to_csv().to_string(),
        eight.to_csv().to_string(),
        "work-stealing sweep CSV must not depend on the thread count"
    );
}

#[test]
fn wave_scheduler_matches_work_stealing_byte_for_byte() {
    let mut spec = grid_json_spec();
    spec.frames = 16;
    let stealing =
        run_sweep_with(&spec, 4, SweepScheduler::Stealing, &factory).unwrap();
    let waves =
        run_sweep_with(&spec, 4, SweepScheduler::Waves, &factory).unwrap();
    assert_eq!(
        stealing.to_json().to_string(),
        waves.to_json().to_string(),
        "the retained wave baseline must stay output-equivalent"
    );
}

#[test]
fn prefilter_preserves_the_grid_json_frontier() {
    let mut off = grid_json_spec();
    off.frames = 24;
    let mut on = off.clone();
    on.prefilter = true;
    let r_off = run_sweep(&off, 4, &factory).unwrap();
    let r_on = run_sweep(&on, 4, &factory).unwrap();
    assert_eq!(r_off.points.len(), r_on.points.len());
    // Same frontier, point for point (positions == grid indices here).
    assert_eq!(
        r_off.pareto, r_on.pareto,
        "prefilter must never change the Pareto frontier"
    );
    for &i in &r_on.pareto {
        assert!(
            !r_on.points[i].skipped,
            "a frontier point must never be prefilter-skipped (index {i})"
        );
    }
    assert_eq!(r_on.evaluated + r_on.skipped, r_on.points.len());
}

#[test]
fn prefilter_fires_on_provably_infeasible_points_and_keeps_frontier() {
    // Tighten the committed grid with a far-latency axis: every 200 ms
    // point's analytic bound alone exceeds the 50 ms deadline (bound >=
    // propagation latency), so the prefilter must skip it; and each such
    // point is dominated by its 1 µs twin (identical loss process and
    // accuracy, strictly larger latency), so the frontier provably
    // cannot contain it.
    let mut off = grid_json_spec();
    off.frames = 16;
    off.latencies_us = vec![1.0, 200_000.0];
    let mut on = off.clone();
    on.prefilter = true;
    let r_off = run_sweep(&off, 4, &factory).unwrap();
    let r_on = run_sweep(&on, 4, &factory).unwrap();
    assert!(
        r_on.skipped > 0,
        "every 200 ms point must be provably skipped"
    );
    assert_eq!(
        r_on.skipped,
        r_on.points.iter().filter(|p| p.skipped).count()
    );
    for p in r_on.points.iter().filter(|p| p.skipped) {
        assert_eq!(p.latency_us, Some(200_000.0));
        assert_eq!(p.satisfies, Some(false));
        assert_eq!(p.deadline_hit_rate, Some(0.0));
        assert_eq!(p.frames, 0);
        assert!(p.accuracy.is_none());
        // The reported latency is the admissible bound: at least the
        // 200 ms of propagation it provably contains.
        assert!(p.mean_latency_ns >= 200e6);
    }
    // Skipping must not move the frontier.
    assert_eq!(r_off.pareto, r_on.pareto);
    // And the prefilter is deterministic: same skip set at any thread
    // count.
    let again = run_sweep(&on, 1, &factory).unwrap();
    assert_eq!(
        r_on.to_json().to_string(),
        again.to_json().to_string()
    );
}

#[test]
fn search_report_is_invariant_across_thread_counts() {
    let mut spec = SearchSpec::new(search_grid());
    spec.rung_frames = vec![6, 24];
    spec.eta = 2;
    // A real halving run: enough budget for all of rung 0 but only part
    // of rung 1.
    let n = spec.sweep.expand().unwrap().len();
    spec.budget = 6 * n + 24 * n.div_ceil(2);
    let one = run_search(&spec, 1, &factory).unwrap();
    let eight = run_search(&spec, 8, &factory).unwrap();
    assert_eq!(
        one.to_json().to_string(),
        eight.to_json().to_string(),
        "search report must not depend on the thread count"
    );
    assert_eq!(one.rungs.len(), 2);
    assert!(one.rungs[1].entrants <= n.div_ceil(2));
    assert!(one.total_cost <= spec.budget);
}

#[test]
fn unlimited_budget_search_equals_the_exhaustive_sweep() {
    let mut spec = SearchSpec::new(search_grid());
    spec.rung_frames = vec![6, 24];
    spec.budget = 0; // unlimited: no halving, final rung == full sweep
    let report = run_search(&spec, 4, &factory).unwrap();

    let mut sweep = search_grid();
    sweep.frames = 24; // final-rung fidelity
    let exhaustive = run_sweep(&sweep, 4, &factory).unwrap();
    let best = exhaustive
        .points
        .iter()
        .min_by(|a, b| search_rank(a, b))
        .unwrap();
    assert_eq!(
        report.winner.index, best.index,
        "unlimited-budget search must crown the exhaustive winner"
    );
    assert_eq!(report.winner.mean_latency_ns, best.mean_latency_ns);
    assert_eq!(report.winner.accuracy, best.accuracy);
    assert_eq!(report.winner.satisfies, best.satisfies);
    assert_eq!(report.never_evaluated, 0);
}
